"""§6.1/§6.5 analytical model: R* vs N, leader-bottleneck asymptotics, and
the JAX Monte-Carlo cross-check of the rotation amortization."""
from repro.core import analytical
from repro.core.jaxsim import mc_summary

from .common import Timer, row


def run(quick: bool = True):
    out = []
    for n in (5, 9, 25, 49, 101):
        out.append(row(f"analytical/N={n}", 0, 1,
                           f"bestR_rot={analytical.best_r_rotating(n)} "
                           f"bestR_static={analytical.best_r_static(n)} "
                       f"M_l(R=1)={analytical.leader_messages(1)} "
                       f"M_f={analytical.follower_messages(n,1):.3f}"))
    # JAX Monte-Carlo cross-check at every scale the DES sweeps reach
    # (25 = paper testbed, 49/101 = the extended fig8/sim_engine regimes)
    rounds = 1024 if quick else 4096
    for n in (25, 49, 101):
        with Timer() as t:
            mc = mc_summary(n, 1, rounds=rounds)
        out.append(row(f"analytical/mc_check_N{n}_R1", t.dt, rounds,
                       f"mc_leader={float(mc['leader']):.2f} "
                       f"mc_follower={float(mc['follower_mean']):.3f} "
                       f"closed_form={analytical.follower_messages(n,1):.3f}"))
    out.append(row("analytical/asymptote", 0, 1,
                   "lim M_f = 4 = M_l(R=1): leader remains the bottleneck "
                   "for every N (paper §6.5)"))
    return out
