"""§6.1/§6.5 analytical model: R* vs N, leader-bottleneck asymptotics, and
the JAX Monte-Carlo cross-check of the rotation amortization."""
from repro.core import analytical
from repro.core.jaxsim import mc_summary

from .common import Timer, row


def run(quick: bool = True):
    out = []
    with Timer() as t:
        mc = mc_summary(25, 1, rounds=2048)
    for n in (5, 9, 25, 49, 101):
        out.append(row(f"analytical/N={n}", 0, 1,
                           f"bestR_rot={analytical.best_r_rotating(n)} "
                           f"bestR_static={analytical.best_r_static(n)} "
                       f"M_l(R=1)={analytical.leader_messages(1)} "
                       f"M_f={analytical.follower_messages(n,1):.3f}"))
    out.append(row("analytical/mc_check_N25_R1", t.dt, 2048,
                   f"mc_leader={float(mc['leader']):.2f} "
                   f"mc_follower={float(mc['follower_mean']):.3f} "
                   f"closed_form={analytical.follower_messages(25,1):.3f}"))
    out.append(row("analytical/asymptote", 0, 1,
                   "lim M_f = 4 = M_l(R=1): leader remains the bottleneck "
                   "for every N (paper §6.5)"))
    return out
