"""Shared benchmark helpers: throughput/latency measurement on the DES."""
from __future__ import annotations

import time

from repro.core import Cluster, PigConfig, WorkloadConfig


def measure(proto: str, n: int, pig=None, clients: int = 60,
            duration: float = 0.6, warmup: float = 0.3, seed: int = 2,
            workload=None, failures=(), leader_timeout: float = 50e-3,
            topo=None, engine: str = "exact"):
    c = Cluster(proto, n, pig=pig, seed=seed, topo=topo,
                leader_timeout=leader_timeout, engine=engine)
    for nid, t in failures:
        c.crash_at(nid, t)
    st = c.measure(duration=duration, warmup=warmup, clients=clients,
                   workload=workload)
    return st, c


def max_throughput(proto: str, n: int, pig=None, client_grid=(20, 60, 120),
                   duration: float = 0.5, warmup: float = 0.25, seed: int = 2,
                   workload=None, engine: str = "exact"):
    """The paper's 'maximum throughput' methodology: sweep offered load
    (client count) and report the best sustained rate."""
    best = None
    for k in client_grid:
        st, _ = measure(proto, n, pig=pig, clients=k, duration=duration,
                        warmup=warmup, seed=seed, workload=workload,
                        engine=engine)
        if best is None or st.throughput > best.throughput:
            best = st
    return best


def row(name: str, wall_s: float, calls: int, derived: str) -> str:
    us = wall_s * 1e6 / max(calls, 1)
    return f"{name},{us:.1f},{derived}"


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.dt = time.time() - self.t0
