"""Shared benchmark row/timing helpers.

Cluster measurement and the offered-load ("max throughput") sweep moved to
``repro.experiments.runner`` — the single implementation of the paper's
methodology, shared by every registry scenario.  The CSV row contract lives
in ``repro.experiments.report.csv_row``; ``row`` here is the framework
benches' alias for it."""
from __future__ import annotations

import time

from repro.experiments.report import csv_row as row  # noqa: F401


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.dt = time.time() - self.t0
