"""Batch-backend benchmark: a fig8-style sweep grid (protocol x R x
clients x seeds) in ONE jitted call, versus the DES process pool.

Three measurements, written to BENCH_vectorsim.json at the repo root:

* ``grid``    — the full protocol x R x clients x 32-seed grid (one
  ``vectorsim.simulate_grid`` call: one XLA compile + one device dispatch),
  cold and warm wall clock.
* ``sharded`` — the same grid through ``vectorsim.simulate_grid_sharded``
  (device-sharded chunked dispatch, bit-identical results): per-chunk
  walls, cells/s, device count, kernel flag.
* ``des``     — the same grid on ``Cluster(engine="fast")``: a stratified
  sample of units (every (config, clients) point, subset of seeds) is
  measured serially AND through a real ``multiprocessing`` pool at
  ``run.py --parallel`` concurrency, then extrapolated to the full grid
  using the *measured* pool speedup (pools on small boxes scale ~1.6x on
  2 cores, not 2x — assuming ideal scaling would overstate the DES).
  The sampled units double as the DES<->batch cross-check points (max
  throughput / median deviation recorded).
* ``sweep1025`` — an N=1025 PigPaxos (R=32) multi-seed sweep, a grid no
  DES run can touch interactively (~10^3 x the paper's 25-node testbed
  state space), with its wall clock.
"""
import json
import os
import time

import numpy as np

from repro.core import Cluster, PigConfig
from repro.core import vectorsim as vs

from .common import row

BENCH_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_vectorsim.json")

DUR, WARM = 0.4, 0.2
CLIENTS = (20, 60, 120)


def _grid_configs():
    """The fig8-style axes: classic Paxos plus rotating PigPaxos R sweep.
    R=8 at 120 clients crosses the leader-timeout retry boundary (the DES
    re-proposes, the timeout-free batch model doesn't — see
    ``vectorsim.simulate_scenario``), so the cross-checked grid stops at
    R=5; R=8+ still runs fine via the ``scale`` catalog family."""
    cfgs = [("paxos", "paxos", None)]
    for r in (2, 3, 5):
        cfgs.append((f"pig_R{r}", "pigpaxos", PigConfig(n_groups=r, prc=1)))
    return cfgs


def _des_unit(proto, pig, k, seed):
    t0 = time.perf_counter()
    c = Cluster(proto, 25, pig=pig, seed=seed, engine="fast")
    st = c.measure(duration=DUR, warmup=WARM, clients=k)
    return st.throughput, st.median_ms, time.perf_counter() - t0


def _pool_speedup(unit_args, workers: int, serial_wall: float) -> float:
    """Measured speedup of a real worker pool over the serial walk of the
    SAME units (run.py --parallel scales sublinearly on small boxes)."""
    import multiprocessing

    t0 = time.perf_counter()
    with multiprocessing.get_context().Pool(workers) as pool:
        pool.starmap(_des_unit, unit_args, chunksize=1)
    pool_wall = time.perf_counter() - t0
    return max(serial_wall / max(pool_wall, 1e-9), 1.0)


def run(quick: bool = True):
    out = []
    seeds = list(range(32))
    cfgs = _grid_configs()
    sims = [vs.build_config(proto, 25, pig=pig, label=label)
            for label, proto, pig in cfgs]
    grid = [(ci, k, s) for ci in range(len(cfgs))
            for k in CLIENTS for s in seeds]

    t0 = time.perf_counter()
    res = vs.simulate_grid(sims, grid, DUR, WARM)
    cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = vs.simulate_grid(sims, grid, DUR, WARM)
    warm = time.perf_counter() - t0
    assert not res["exhausted"].any()
    out.append(row("vectorsim/grid", cold, len(grid),
                   f"{len(cfgs)}cfgs x {len(CLIENTS)}clients x "
                   f"{len(seeds)}seeds = {len(grid)} cells in ONE call: "
                   f"cold={cold:.1f}s warm={warm:.1f}s "
                   f"steps={int(res['steps'][0])}"))

    # ---- the same grid through the device-sharded chunked dispatcher
    # (bit-identical results; on this CPU container device_count is 1 —
    # multi-device walls come from the forced-host-device CI smoke and
    # GPU/TPU runs)
    import jax
    t0 = time.perf_counter()
    sres = vs.simulate_grid_sharded(sims, grid, DUR, WARM, chunk=128)
    sh_wall = time.perf_counter() - t0
    np.testing.assert_array_equal(np.asarray(res["throughput"]),
                                  sres["throughput"])
    shard = sres["sharding"]
    out.append(row("vectorsim/sharded", sh_wall, len(grid),
                   f"devices={shard['devices']} impl={shard['impl']} "
                   f"kernel={shard['kernel']} chunk={shard['chunk']} "
                   f"{len(shard['chunks'])}chunks "
                   f"{len(grid)/max(sh_wall, 1e-9):.0f}cells/s "
                   f"wall={sh_wall:.1f}s (== unsharded grid bit-for-bit)"))

    # ---- DES reference: stratified sample, extrapolated to the full grid
    n_sample_seeds = 1 if quick else 2
    workers = os.cpu_count() or 1
    des_wall = 0.0
    errs_t, errs_m = [], []
    sample_args = []
    by_cell = {g: i for i, g in enumerate(grid)}
    for ci, (label, proto, pig) in enumerate(cfgs):
        for k in CLIENTS:
            d_t, d_m, d_w = [], [], 0.0
            for s in range(n_sample_seeds):
                sample_args.append((proto, pig, k, seeds[s]))
                tput, med, w = _des_unit(proto, pig, k, seeds[s])
                d_t.append(tput)
                d_m.append(med)
                d_w += w
            des_wall += d_w
            b_t = float(np.mean([res["throughput"][by_cell[(ci, k, s)]]
                                 for s in seeds]))
            b_m = float(np.mean([res["median_s"][by_cell[(ci, k, s)]]
                                 for s in seeds])) * 1e3
            errs_t.append(b_t / max(np.mean(d_t), 1e-9) - 1)
            errs_m.append(b_m / max(np.mean(d_m), 1e-9) - 1)
    sampled = len(sample_args)
    pool_speedup = _pool_speedup(sample_args, workers, des_wall)
    des_est_total = des_wall / sampled * len(grid)
    des_est_parallel = des_est_total / pool_speedup
    speedup = des_est_parallel / max(cold, 1e-9)
    speedup_serial = des_est_total / max(cold, 1e-9)
    out.append(row("vectorsim/speedup", des_wall, sampled,
                   f"batch={cold:.1f}s vs run.py --parallel est="
                   f"{des_est_parallel:.0f}s ({workers} workers, measured "
                   f"pool speedup {pool_speedup:.2f}x) -> {speedup:.0f}x "
                   f"({speedup_serial:.0f}x vs serial DES est "
                   f"{des_est_total:.0f}s)  "
                   f"[{sampled} DES units measured, {des_wall:.0f}s]"))
    max_t = max(abs(e) for e in errs_t)
    max_m = max(abs(e) for e in errs_m)
    out.append(row("vectorsim/xcheck", 0, 1,
                   f"DES overlap ({len(errs_t)} points): max |tput err|="
                   f"{max_t:.1%} max |median err|={max_m:.1%} "
                   f"(acceptance: <10%)"))

    # ---- the N=1025 sweep the DES cannot touch
    n_big_seeds = 4 if quick else 8
    big = vs.build_config("pigpaxos", 1025,
                          pig=PigConfig(n_groups=32, prc=1), label="N1025")
    big_grid = [(0, 60, s) for s in range(n_big_seeds)]
    t0 = time.perf_counter()
    bres = vs.simulate_grid([big], big_grid, DUR, WARM)
    big_wall = time.perf_counter() - t0
    bt = float(np.mean(bres["throughput"]))
    bm = float(np.mean(bres["median_s"])) * 1e3
    out.append(row("vectorsim/N=1025", big_wall, n_big_seeds,
                   f"PigPaxos N=1025 R=32 x {n_big_seeds} seeds: "
                   f"tput={bt:.0f}req/s median={bm:.2f}ms "
                   f"wall={big_wall:.1f}s (acceptance: <60s)"))

    payload = {
        "bench": "vectorsim",
        "grid": {"configs": [c[0] for c in cfgs], "clients": list(CLIENTS),
                 "seeds": len(seeds), "cells": len(grid),
                 "duration_s": DUR, "warmup_s": WARM,
                 "steps": int(res["steps"][0])},
        "batch": {"wall_cold_s": round(cold, 2),
                  "wall_warm_s": round(warm, 2)},
        "sharded": {"wall_s": round(sh_wall, 2),
                    "cells_per_s": round(len(grid) / max(sh_wall, 1e-9), 1),
                    "device_count": shard["devices"],
                    "impl": shard["impl"], "kernel": shard["kernel"],
                    "chunk": shard["chunk"],
                    "chunks": [{"cells": m["cells"],
                                "wall_s": round(m["wall_s"], 3),
                                "steps": m["steps"]}
                               for m in shard["chunks"]]},
        "des_sample": {"units": sampled, "wall_s": round(des_wall, 1),
                       "est_total_s": round(des_est_total, 1),
                       "est_parallel_s": round(des_est_parallel, 1),
                       "workers": workers,
                       "pool_speedup_measured": round(pool_speedup, 2)},
        "speedup_vs_parallel_est": round(speedup, 1),
        "speedup_vs_serial_est": round(speedup_serial, 1),
        "xcheck": {"points": len(errs_t),
                   "max_abs_tput_err": round(max_t, 4),
                   "max_abs_median_err": round(max_m, 4)},
        "sweep1025": {"seeds": n_big_seeds, "wall_s": round(big_wall, 2),
                      "throughput": round(bt), "median_ms": round(bm, 3)},
    }
    with open(BENCH_PATH, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    out.append(row("vectorsim/json", 0, 1, f"wrote {BENCH_PATH}"))
    return out
