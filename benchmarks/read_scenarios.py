"""Read-path scenario families (ISSUE 10):

- ``reads/*`` — linearizable read paths under read-heavy closed-loop
  traffic: quorum-granted leader leases (the leader serves gets locally,
  no commit round), PQR-style quorum reads (random majority on
  paxos/epaxos, the geo-closest relay subgroup + leader on pigpaxos),
  and the log read path as the baseline.  Every DES cell runs the
  read-aware linearizability auditor; the summarizer emits the
  leased-vs-log speedup (gated >= 2x), the Pig-vs-Paxos read-ratio
  crossover, and DES<->batch fidelity ratios for the leased-read
  vectorsim model (gated [0.90, 1.10]).
- ``lease/expiry/d=*`` — leader crash + failover with the lease duration
  swept: follower lease promises block the successor's phase 1 until the
  old lease drains, so the measured unavailability window grows with the
  duration (audited: no stale read may slip through the failover).

Scenarios: ``repro.experiments.catalog``; this module is the
``run.py --only`` shim."""
from repro.experiments import report

FAMILIES = ["reads", "lease"]


def run(quick: bool = True):
    return report.family_rows(FAMILIES, quick=quick)
