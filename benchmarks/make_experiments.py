"""Generate the §Dry-run and §Roofline tables of EXPERIMENTS.md from the
dry-run artifacts (baseline = artifacts/dryrun, optimized = artifacts/dryrun_opt)."""
from __future__ import annotations

import glob
import json
import os


def cells(dirname: str, mesh: str):
    out = {}
    for f in sorted(glob.glob(os.path.join(dirname, f"{mesh}--*.json"))):
        base = os.path.basename(f)[:-5]
        if base.count("-iter") or base.endswith("-direct"):
            continue
        d = json.load(open(f))
        out[(d.get("arch"), d.get("shape"))] = d
    return out


def fmt_s(x):
    return f"{x:8.2f}" if x < 1e4 else f"{x:8.2e}"


def roofline_table(dirname: str, mesh: str) -> str:
    rows = []
    hdr = ("| arch | shape | t_compute (s) | t_memory (s) | t_collective (s) "
           "| bound | roofline frac | useful FLOPs | note |")
    sep = "|---|---|---|---|---|---|---|---|---|"
    for (arch, shape), d in sorted(cells(dirname, mesh).items()):
        if "skipped" in d:
            rows.append(f"| {arch} | {shape} | — | — | — | — | — | — | "
                        f"SKIP: sub-quadratic required |")
            continue
        if "error" in d:
            rows.append(f"| {arch} | {shape} | — | — | — | — | — | — | ERROR |")
            continue
        rows.append(
            f"| {arch} | {shape} | {d['t_compute']:.3f} | {d['t_memory']:.2f} "
            f"| {d['t_collective']:.2f} | {d['bottleneck']} "
            f"| {d['roofline_fraction']:.4f} | {min(d['useful_flops_ratio'],99):.2f} | |")
    return "\n".join([hdr, sep] + rows)


def memory_table(dirname: str, mesh: str) -> str:
    hdr = "| arch | shape | args (GB/dev) | temp (GB/dev) | cross-pod (GB/chip) | collectives |"
    sep = "|---|---|---|---|---|---|"
    rows = []
    for (arch, shape), d in sorted(cells(dirname, mesh).items()):
        if "skipped" in d or "error" in d:
            continue
        m = d["memory"]
        ck = ", ".join(f"{k}:{v/1e9:.0f}G" for k, v in
                       sorted(d["collectives"].items(), key=lambda kv: -kv[1])[:3])
        rows.append(f"| {arch} | {shape} | {(m['argument_bytes'] or 0)/1e9:.1f} "
                    f"| {(m['temp_bytes'] or 0)/1e9:.1f} "
                    f"| {d['cross_pod_bytes_per_chip']/1e9:.2f} | {ck} |")
    return "\n".join([hdr, sep] + rows)


def before_after(base_dir: str, opt_dir: str, mesh: str) -> str:
    b = cells(base_dir, mesh)
    o = cells(opt_dir, mesh)
    hdr = ("| arch | shape | frac before | frac after | Δ | coll GB/chip "
           "before→after |")
    sep = "|---|---|---|---|---|---|"
    rows = []
    for key in sorted(set(b) & set(o)):
        db, do = b[key], o[key]
        if "skipped" in db or "error" in db or "skipped" in do or "error" in do:
            continue
        fb, fo = db["roofline_fraction"], do["roofline_fraction"]
        cb = db["coll_bytes"] / db["chips"] / 1e9
        co = do["coll_bytes"] / do["chips"] / 1e9
        delta = "=" if abs(fo - fb) < 1e-4 else (f"+{(fo/max(fb,1e-9)):.1f}x"
                                                 if fo > fb else f"{fo/fb:.2f}x")
        rows.append(f"| {key[0]} | {key[1]} | {fb:.4f} | {fo:.4f} | {delta} "
                    f"| {cb:.0f} → {co:.0f} |")
    return "\n".join([hdr, sep] + rows)


if __name__ == "__main__":
    import sys
    which = sys.argv[1] if len(sys.argv) > 1 else "roofline"
    mesh = sys.argv[2] if len(sys.argv) > 2 else "single"
    if which == "roofline":
        print(roofline_table("artifacts/dryrun_opt", mesh))
    elif which == "memory":
        print(memory_table("artifacts/dryrun_opt", mesh))
    elif which == "before_after":
        print(before_after("artifacts/dryrun", "artifacts/dryrun_opt", mesh))
