"""Benchmark driver: scenario families run through the experiment registry
(``repro.experiments``); framework benches stay one module each.

Prints ``name,us_per_call,derived`` CSV rows (the perf-trajectory contract).

- ``--full``          paper-length measurement windows
- ``--only M1,M2``    restrict to specific modules (legacy entry points)
- ``--filter GLOBS``  comma-separated fnmatch globs over *scenario* names
                      (e.g. ``'fig8/rotating/*,fig9/paxos'``; a bare family
                      name matches the whole family).  Skips the
                      non-scenario modules entirely.
- ``--parallel [N]``  run scenario units ((scenario, clients, seed) triples)
                      in an N-process pool (no N: one per CPU).  The DES is
                      single-threaded, so scenarios x seeds scale ~linearly
                      with cores.
- ``--list-scenarios``  print every registry entry and exit
- ``--json PATH``     persist all rows + the full experiments artifact
                      (per-seed replicates, summary stats) + the engine
                      events/sec numbers from BENCH_sim.json
"""
import argparse
import importlib
import json
import os
import sys
import time

MODULES = [
    "table1_message_load",
    "table2_message_load_small",
    "fig8_relay_groups",
    "fig9_latency_throughput",
    "fig10_wan",
    "fig11_small5",
    "fig12_cluster9",
    "fig13_payload",
    "fig14_prc",
    "fig15_graylist",
    "fig16_group_failure",
    "fig17_heatmap",
    "fault_scenarios",
    "extra_scenarios",
    "overload_scenarios",
    "obs_scenarios",
    "read_scenarios",
    "serialization_cost",
    "analytical_sweep",
    "sim_engine_bench",
    "vectorsim_bench",
    "collective_schedules",
    "kernel_bench",
    "roofline",
]

# A module that declares FAMILIES = [...] is a scenario-registry shim: its
# families' units all run in ONE suite pass (shared --parallel pool), then
# each module slot formats its families' legacy rows.  The mapping lives in
# the modules themselves — this driver just reads it.


def _scenario_families(module_name: str):
    try:
        mod = importlib.import_module(f"benchmarks.{module_name}")
    except Exception:   # noqa: BLE001  (unknown module: reported at run time)
        return None
    return getattr(mod, "FAMILIES", None)


def _parse_row(line: str) -> dict:
    name, us, derived = line.split(",", 2)
    try:
        us_val = float(us)
    except ValueError:
        us_val = None
    return {"name": name, "us_per_call": us_val, "derived": derived}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--filter", default=None, metavar="GLOBS",
                    help="comma-separated scenario-name globs; scenario "
                         "families only (framework benches are skipped)")
    ap.add_argument("--parallel", nargs="?", const=0, default=None, type=int,
                    metavar="N", help="pool size for scenario units "
                                      "(no value: one per CPU)")
    ap.add_argument("--list-scenarios", action="store_true")
    ap.add_argument("--backend", default=None, choices=("des", "batch"),
                    help="override the simulation backend: 'batch' runs "
                         "every batch-eligible scenario's whole grid as one "
                         "jitted vectorsim call; 'des' forces the DES")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write all rows (+ artifact + engine stats) to a "
                         "BENCH json")
    ap.add_argument("--plot", default=None, metavar="DIR",
                    help="render throughput-vs-load / latency-CDF SVGs for "
                         "every family that ran (dependency-free)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="write the Perfetto trace-event JSON collected "
                         "from every traced scenario unit that ran (open "
                         "at https://ui.perfetto.dev)")
    args = ap.parse_args()

    from repro import experiments

    if args.list_scenarios:
        for name in experiments.names():
            sc = experiments.get(name)
            print(f"{name}  [{sc.protocol} n={sc.n} grid={sc.grid_mode} "
                  f"engine={sc.engine}]")
        return

    processes = args.parallel
    if processes == 0:
        processes = os.cpu_count() or 1
    processes = processes or 0

    mods = MODULES if not args.only else args.only.split(",")
    mod_families = {m: _scenario_families(m) for m in mods}
    if args.filter:
        mods = [m for m in mods if mod_families[m]]
    quick = not args.full

    print("name,us_per_call,derived")
    t00 = time.time()
    failures = 0
    rows = []
    artifact = None

    # one suite pass over every selected scenario unit (shared pool)
    fams = [f for m in mods for f in (mod_families[m] or [])]
    if fams:
        t0 = time.time()
        try:
            artifact = experiments.run_families(
                fams, quick=quick, processes=processes,
                filter_expr=args.filter, backend_override=args.backend)
            n_units = sum(len(sa["units"]) for sa in artifact["scenarios"])
            print(f"# scenario suite: {len(artifact['scenarios'])} scenarios"
                  f", {n_units} units, processes={processes}, "
                  f"{time.time()-t0:.1f}s wall", flush=True)
        except Exception as e:   # noqa: BLE001
            failures += 1
            line = f"scenario_suite,0,ERROR: {type(e).__name__}: {e}"
            rows.append(_parse_row(line))
            print(line, flush=True)

    for m in mods:
        t0 = time.time()
        try:
            if mod_families[m]:
                if artifact is None:
                    continue   # suite itself failed; already reported
                lines = experiments.report.rows_for_artifact(
                    artifact, mod_families[m])
            else:
                mod = importlib.import_module(f"benchmarks.{m}")
                lines = mod.run(quick=quick)
            for line in lines:
                rows.append(_parse_row(line))
                print(line, flush=True)
        except Exception as e:   # noqa: BLE001
            failures += 1
            line = f"{m},0,ERROR: {type(e).__name__}: {e}"
            rows.append(_parse_row(line))
            print(line, flush=True)
        print(f"# {m} done in {time.time()-t0:.1f}s", flush=True)
    total = time.time() - t00
    print(f"# total {total:.1f}s, failures={failures}")
    if args.plot and artifact is not None:
        from repro.experiments import plot
        written = plot.render_artifact(artifact, args.plot)
        print(f"# wrote {len(written)} plots to {args.plot}")
    if args.trace and artifact is not None:
        # merge the per-unit Perfetto events the traced scenarios embedded
        # in their obs extras into one ui.perfetto.dev-openable file
        evs, traced_units = [], 0
        for sa in artifact["scenarios"]:
            for u in sa["units"]:
                pf = ((u.get("extras") or {}).get("obs") or {}) \
                    .get("perfetto")
                if pf and pf.get("events"):
                    evs.extend(pf["events"])
                    traced_units += 1
        with open(args.trace, "w") as f:
            json.dump({"traceEvents": evs, "displayTimeUnit": "ms",
                       "otherData": {"traced_units": traced_units}}, f)
        print(f"# wrote {len(evs)} trace events from {traced_units} "
              f"traced units to {args.trace}")
    if args.json:
        payload = {"rows": rows, "total_s": round(total, 1),
                   "failures": failures, "full": bool(args.full)}
        if artifact is not None:
            payload["experiments"] = artifact
        # fold in the engine events/sec trajectory if the engine bench ran
        try:
            from benchmarks.sim_engine_bench import BENCH_PATH
            if os.path.exists(BENCH_PATH):
                with open(BENCH_PATH) as f:
                    payload["sim_engine"] = json.load(f)
        except Exception:   # noqa: BLE001
            pass
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {args.json}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
