"""Benchmark driver: one module per paper table/figure + framework benches.
Prints ``name,us_per_call,derived`` CSV rows.  --full for longer windows."""
import argparse
import importlib
import sys
import time

MODULES = [
    "table1_message_load",
    "table2_message_load_small",
    "fig8_relay_groups",
    "fig9_latency_throughput",
    "fig10_wan",
    "fig11_small5",
    "fig12_cluster9",
    "fig13_payload",
    "fig14_prc",
    "fig15_graylist",
    "fig16_group_failure",
    "fig17_heatmap",
    "serialization_cost",
    "analytical_sweep",
    "collective_schedules",
    "kernel_bench",
    "roofline",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    mods = MODULES if not args.only else args.only.split(",")
    print("name,us_per_call,derived")
    t00 = time.time()
    failures = 0
    for m in mods:
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{m}")
            for line in mod.run(quick=not args.full):
                print(line, flush=True)
        except Exception as e:   # noqa: BLE001
            failures += 1
            print(f"{m},0,ERROR: {type(e).__name__}: {e}", flush=True)
        print(f"# {m} done in {time.time()-t0:.1f}s", flush=True)
    print(f"# total {time.time()-t00:.1f}s, failures={failures}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
