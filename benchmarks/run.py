"""Benchmark driver: one module per paper table/figure + framework benches.
Prints ``name,us_per_call,derived`` CSV rows.  --full for longer windows;
--json PATH additionally persists all rows (plus the engine events/sec
numbers from sim_engine_bench's BENCH_sim.json) for the perf trajectory."""
import argparse
import importlib
import json
import os
import sys
import time

MODULES = [
    "table1_message_load",
    "table2_message_load_small",
    "fig8_relay_groups",
    "fig9_latency_throughput",
    "fig10_wan",
    "fig11_small5",
    "fig12_cluster9",
    "fig13_payload",
    "fig14_prc",
    "fig15_graylist",
    "fig16_group_failure",
    "fig17_heatmap",
    "serialization_cost",
    "analytical_sweep",
    "sim_engine_bench",
    "collective_schedules",
    "kernel_bench",
    "roofline",
]


def _parse_row(line: str) -> dict:
    name, us, derived = line.split(",", 2)
    try:
        us_val = float(us)
    except ValueError:
        us_val = None
    return {"name": name, "us_per_call": us_val, "derived": derived}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write all rows (+ engine stats) to a BENCH json")
    args = ap.parse_args()
    mods = MODULES if not args.only else args.only.split(",")
    print("name,us_per_call,derived")
    t00 = time.time()
    failures = 0
    rows = []
    for m in mods:
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{m}")
            for line in mod.run(quick=not args.full):
                rows.append(_parse_row(line))
                print(line, flush=True)
        except Exception as e:   # noqa: BLE001
            failures += 1
            line = f"{m},0,ERROR: {type(e).__name__}: {e}"
            rows.append(_parse_row(line))
            print(line, flush=True)
        print(f"# {m} done in {time.time()-t0:.1f}s", flush=True)
    total = time.time() - t00
    print(f"# total {total:.1f}s, failures={failures}")
    if args.json:
        payload = {"rows": rows, "total_s": round(total, 1),
                   "failures": failures, "full": bool(args.full)}
        # fold in the engine events/sec trajectory if the engine bench ran
        try:
            from benchmarks.sim_engine_bench import BENCH_PATH
            if os.path.exists(BENCH_PATH):
                with open(BENCH_PATH) as f:
                    payload["sim_engine"] = json.load(f)
        except Exception:   # noqa: BLE001
            pass
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {args.json}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
