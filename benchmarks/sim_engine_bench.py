"""Simulation-engine benchmark: seed stack vs fused slab engine vs flattened
fast path, plus the large-N sweep the new headroom unlocks.

Workload: the canonical 25-node PigPaxos measure run (R=3, 40 closed-loop
clients, 0.6s of virtual time — the configuration behind Figs 8/9).  Every
engine simulates the *same* virtual execution, so rates are comparable:

  * ``heap events/s``  — engine-internal heap entries executed per wall
    second.  The seed chains 3 heap events per message hop; the exact engine
    keeps the identical event structure (golden-trace guarantee), so
    exact-vs-seed on this metric isolates the per-event overhead win.
  * ``deliveries/s``   — delivered protocol messages per wall second, the
    model-level throughput.  Comparable across ALL engines including the
    flattened fast path (1 heap event per hop).

Emits BENCH_sim.json at the repo root so successive PRs can track the
perf trajectory (``benchmarks/run.py --json`` folds it into the full dump).
"""
import json
import os
import time

from repro.core import Cluster, PigConfig

from .common import row

BENCH_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_sim.json")

ENGINES = ("ref", "exact", "fast")


def _one(engine: str, n: int = 25, groups: int = 3, clients: int = 40,
         dur: float = 0.6, obs=None):
    """One measure-style run; returns (heap_events, deliveries, wall_s,
    committed, cpu_s).  ``cpu_s`` is process time: on a shared box it
    excludes co-tenant scheduling noise, which wall time does not."""
    c = Cluster("pigpaxos", n, pig=PigConfig(n_groups=groups), seed=2,
                engine=engine, obs=obs)
    c.add_clients(clients, stop_at=dur)
    t0 = time.perf_counter()
    p0 = time.process_time()
    heap_events = c.sched.run(until=dur + 0.1)
    cpu = time.process_time() - p0
    wall = time.perf_counter() - t0
    deliveries = int(c.net.msgs_in.sum())
    committed = sum(getattr(nd, "committed_count", 0) for nd in c.nodes)
    return heap_events, deliveries, wall, committed, cpu


def _timer_churn(label: str, events: int = 20_000, chains: int = 512):
    """Timer-only churn: ``chains`` self-rescheduling timers with ~100us
    exponential gaps plus a cancel/re-arm per fire (the Scheduler timer
    regime, minus the fused message loop).  Returns (events, wall_s)."""
    from repro.core.events import CalendarScheduler, Scheduler
    sched = Scheduler(seed=3) if label == "heap" else CalendarScheduler(seed=3)
    rng = sched.rng
    backup = [None]

    def fire():
        sched.after(rng.exponential(1e-4), fire)
        if backup[0] is not None:
            sched.cancel(backup[0])
        backup[0] = sched.after(1e-3, fire)

    for _ in range(chains):
        sched.after(rng.exponential(1e-4), fire)
    t0 = time.perf_counter()
    n = sched.run(max_events=events)
    return n, time.perf_counter() - t0


def run(quick: bool = True):
    out = []
    rounds = 3 if quick else 5
    dur = 0.4 if quick else 0.8
    # interleave the engines round-robin so each speedup ratio is computed
    # from back-to-back runs under the same machine conditions (wall-clock
    # noise on shared boxes otherwise dominates cross-engine ratios)
    samples = {e: [] for e in ENGINES}
    ratios_events, ratios_deliv = [], []
    for _ in range(rounds):
        rnd = {}
        for engine in ENGINES:
            rnd[engine] = _one(engine, dur=dur)
            samples[engine].append(rnd[engine])
        ref_ev, ref_de, ref_w, _, _ = rnd["ref"]
        ex_ev, _, ex_w, _, _ = rnd["exact"]
        _, fa_de, fa_w, _, _ = rnd["fast"]
        ratios_events.append((ex_ev / ex_w) / (ref_ev / ref_w))
        ratios_deliv.append((fa_de / fa_w) / (ref_de / ref_w))
    results = {}
    for engine in ENGINES:
        ev, deliv, wall, committed, _ = min(samples[engine], key=lambda s: s[2])
        results[engine] = {
            "heap_events": ev,
            "deliveries": deliv,
            "wall_s": round(wall, 3),
            "heap_events_per_sec": round(ev / wall),
            "deliveries_per_sec": round(deliv / wall),
            "committed": committed,
        }
        r = results[engine]
        out.append(row(f"sim_engine/{engine}", wall, ev,
                       f"events/s={r['heap_events_per_sec']} "
                       f"deliveries/s={r['deliveries_per_sec']} "
                       f"committed={committed}"))
    # median across interleaved rounds: robust to one-off load spikes in
    # either direction (max would pick whichever round the seed engine got
    # unlucky in, inflating the trajectory headline)
    speedup_events = sorted(ratios_events)[len(ratios_events) // 2]
    speedup_deliv = sorted(ratios_deliv)[len(ratios_deliv) // 2]
    out.append(row("sim_engine/speedup", 0, 1,
                   f"exact_vs_seed={speedup_events:.2f}x(events/s) "
                   f"fast_vs_seed={speedup_deliv:.2f}x(deliveries/s) "
                   f"[median of {rounds} interleaved rounds; per-round "
                   f"events={['%.2f' % r for r in ratios_events]} "
                   f"deliv={['%.2f' % r for r in ratios_deliv]}]"))

    # ---- tracing overhead (ISSUE 9): traced vs untraced, interleaved ----
    # Span tracing on the exact engine against the identical untraced run
    # (tracing is event-neutral, so heap_events match and the cpu-seconds
    # ratio isolates the hook cost).  Methodology: per-round PAIRED
    # overheads from adjacent traced/untraced runs, gated on the MINIMUM
    # across rounds.  On a shared box both wall and process time swing
    # +-10% with co-tenant load — far more than the effect measured — so
    # any single-round estimate flaps.  A genuine hook regression above
    # the ceiling shows up in EVERY round; taking the most favorable
    # round keeps the gate's false-failure rate near zero while still
    # tripping on real regressions (the median is reported alongside).
    # The GATED number is the production configuration — sample_rate=0.05,
    # every 20th op traced, the rate regime the obs/* catalog cells use —
    # where an unsampled op costs one ``Msg._tctx`` slot test per event;
    # the regression gate holds it to <= 5%.  Full-rate (every op, ~170
    # spans/op) is reported informationally: it is the worst case nobody
    # runs in measurement mode, not a regression signal.
    tr_rates = (0.05, 1.0)
    tr_cfgs = [("untraced", None)] + [
        (f"rate={r}", {"sample_rate": r, "max_spans": 2_000_000})
        for r in tr_rates]
    tr_cpu = {k: [] for k, _ in tr_cfgs}
    ev_ref = None
    tr_rounds = max(6, rounds)
    for i in range(tr_rounds):
        order = tr_cfgs if i % 2 == 0 else list(reversed(tr_cfgs))
        for k, obs in order:
            ev, _, _, _, cpu = _one("exact", dur=dur, obs=obs)
            if ev_ref is None:
                ev_ref = ev
            assert ev == ev_ref, "tracing must not change the event trace"
            tr_cpu[k].append(cpu)

    def _med(xs):
        s = sorted(xs)
        return s[len(s) // 2]

    overheads, overheads_med = {}, {}
    for rate in tr_rates:
        per_round = [max(0.0, 1.0 - u / t)
                     for u, t in zip(tr_cpu["untraced"],
                                     tr_cpu[f"rate={rate}"])]
        overheads[rate] = min(per_round)
        overheads_med[rate] = _med(per_round)
        gated = " (gate ceiling: 5%)" if rate == 0.05 else " (informational)"
        out.append(row(f"sim_engine/tracing_overhead/rate={rate}", 0, 1,
                       f"overhead={overheads[rate] * 100:.1f}% events/cpu-s"
                       f"{gated}; median={overheads_med[rate] * 100:.1f}% "
                       f"per-round {['%.1f%%' % (o * 100) for o in per_round]}"))
    tracing_overhead = overheads[0.05]

    # ---- large-N sweep unlocked by the headroom (paper stops at N=25) ----
    sweep = {}
    sweep_dur = 0.3 if quick else 0.5
    for n in (25, 49, 101):
        t0 = time.perf_counter()
        c = Cluster("pigpaxos", n, pig=PigConfig(n_groups=3, prc=1), seed=2,
                    engine="fast")
        st = c.measure(duration=sweep_dur, warmup=0.15, clients=60)
        wall = time.perf_counter() - t0
        sweep[n] = {"wall_s": round(wall, 2),
                    "throughput": round(st.throughput),
                    "median_ms": round(st.median_ms, 3)}
        out.append(row(f"sim_engine/sweep/N={n}", wall, max(st.count, 1),
                       f"tput={st.throughput:.0f}req/s "
                       f"median={st.median_ms:.2f}ms wall={wall:.1f}s"))

    # ---- scheduler-structure experiment: slab heap vs calendar queue ----
    # Timer-only churn mirroring the DES timer distribution (dense chained
    # timers + steady cancel/re-arm).  The fused message loop pushes heap
    # tuples into Scheduler._heap directly, so the calendar queue can only
    # ever back the timer path — the verdict records both the measured
    # ratio and that structural constraint.
    cal_rounds = []
    churn = 20_000 if quick else 60_000
    for _ in range(rounds):
        rnd = {}
        for label in ("heap", "calendar"):
            rnd[label] = _timer_churn(label, events=churn)
        cal_rounds.append(rnd["heap"][1] / rnd["calendar"][1])
        for label in ("heap", "calendar"):
            ev, wall = rnd[label]
            out.append(row(f"sim_engine/scheduler/{label}", wall, ev,
                           f"timer_events/s={ev / wall:.0f}"))
    cal_speed = sorted(cal_rounds)[len(cal_rounds) // 2]
    verdict = ("keep-heap" if cal_speed < 1.10 else "calendar-wins-timers")
    verdict_note = (
        f"{verdict}: calendar/heap wall={cal_speed:.2f}x on timer churn; "
        "fused message loop requires the slab heap either way "
        "(network.py pushes heap tuples directly)")
    out.append(row("sim_engine/scheduler/verdict", 0, 1, verdict_note))

    payload = {
        "bench": "sim_engine",
        "workload": "pigpaxos N=25 R=3 closed-loop clients=40",
        "engines": results,
        "speedup_exact_vs_seed_events_per_sec": round(speedup_events, 2),
        "speedup_fast_vs_seed_deliveries_per_sec": round(speedup_deliv, 2),
        "per_round_speedups_events": [round(r, 2) for r in ratios_events],
        "per_round_speedups_deliveries": [round(r, 2) for r in ratios_deliv],
        "tracing_overhead_frac": round(tracing_overhead, 4),
        "tracing_overhead_median_frac": round(overheads_med[0.05], 4),
        "tracing_overhead_fullrate_frac": round(overheads[1.0], 4),
        "tracing_cpu_s": {k: [round(c, 3) for c in v]
                          for k, v in tr_cpu.items()},
        "sweep_fast_engine_R3": {str(k): v for k, v in sweep.items()},
        "sweep101_wall_s": sweep[101]["wall_s"],
        "scheduler_calendar_vs_heap_wall": round(cal_speed, 2),
        "scheduler_verdict": verdict_note,
    }
    with open(BENCH_PATH, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    out.append(row("sim_engine/json", 0, 1, f"wrote {BENCH_PATH}"))
    return out
