"""Simulation-engine benchmark: seed stack vs fused slab engine vs flattened
fast path, plus the large-N sweep the new headroom unlocks.

Workload: the canonical 25-node PigPaxos measure run (R=3, 40 closed-loop
clients, 0.6s of virtual time — the configuration behind Figs 8/9).  Every
engine simulates the *same* virtual execution, so rates are comparable:

  * ``heap events/s``  — engine-internal heap entries executed per wall
    second.  The seed chains 3 heap events per message hop; the exact engine
    keeps the identical event structure (golden-trace guarantee), so
    exact-vs-seed on this metric isolates the per-event overhead win.
  * ``deliveries/s``   — delivered protocol messages per wall second, the
    model-level throughput.  Comparable across ALL engines including the
    flattened fast path (1 heap event per hop).

Emits BENCH_sim.json at the repo root so successive PRs can track the
perf trajectory (``benchmarks/run.py --json`` folds it into the full dump).
"""
import json
import os
import time

from repro.core import Cluster, PigConfig

from .common import row

BENCH_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_sim.json")

ENGINES = ("ref", "exact", "fast")


def _one(engine: str, n: int = 25, groups: int = 3, clients: int = 40,
         dur: float = 0.6):
    """One measure-style run; returns (heap_events, deliveries, wall_s,
    committed)."""
    c = Cluster("pigpaxos", n, pig=PigConfig(n_groups=groups), seed=2,
                engine=engine)
    c.add_clients(clients, stop_at=dur)
    t0 = time.perf_counter()
    heap_events = c.sched.run(until=dur + 0.1)
    wall = time.perf_counter() - t0
    deliveries = int(c.net.msgs_in.sum())
    committed = sum(getattr(nd, "committed_count", 0) for nd in c.nodes)
    return heap_events, deliveries, wall, committed


def _timer_churn(label: str, events: int = 20_000, chains: int = 512):
    """Timer-only churn: ``chains`` self-rescheduling timers with ~100us
    exponential gaps plus a cancel/re-arm per fire (the Scheduler timer
    regime, minus the fused message loop).  Returns (events, wall_s)."""
    from repro.core.events import CalendarScheduler, Scheduler
    sched = Scheduler(seed=3) if label == "heap" else CalendarScheduler(seed=3)
    rng = sched.rng
    backup = [None]

    def fire():
        sched.after(rng.exponential(1e-4), fire)
        if backup[0] is not None:
            sched.cancel(backup[0])
        backup[0] = sched.after(1e-3, fire)

    for _ in range(chains):
        sched.after(rng.exponential(1e-4), fire)
    t0 = time.perf_counter()
    n = sched.run(max_events=events)
    return n, time.perf_counter() - t0


def run(quick: bool = True):
    out = []
    rounds = 3 if quick else 5
    dur = 0.4 if quick else 0.8
    # interleave the engines round-robin so each speedup ratio is computed
    # from back-to-back runs under the same machine conditions (wall-clock
    # noise on shared boxes otherwise dominates cross-engine ratios)
    samples = {e: [] for e in ENGINES}
    ratios_events, ratios_deliv = [], []
    for _ in range(rounds):
        rnd = {}
        for engine in ENGINES:
            rnd[engine] = _one(engine, dur=dur)
            samples[engine].append(rnd[engine])
        ref_ev, ref_de, ref_w, _ = rnd["ref"]
        ex_ev, _, ex_w, _ = rnd["exact"]
        _, fa_de, fa_w, _ = rnd["fast"]
        ratios_events.append((ex_ev / ex_w) / (ref_ev / ref_w))
        ratios_deliv.append((fa_de / fa_w) / (ref_de / ref_w))
    results = {}
    for engine in ENGINES:
        ev, deliv, wall, committed = min(samples[engine], key=lambda s: s[2])
        results[engine] = {
            "heap_events": ev,
            "deliveries": deliv,
            "wall_s": round(wall, 3),
            "heap_events_per_sec": round(ev / wall),
            "deliveries_per_sec": round(deliv / wall),
            "committed": committed,
        }
        r = results[engine]
        out.append(row(f"sim_engine/{engine}", wall, ev,
                       f"events/s={r['heap_events_per_sec']} "
                       f"deliveries/s={r['deliveries_per_sec']} "
                       f"committed={committed}"))
    # median across interleaved rounds: robust to one-off load spikes in
    # either direction (max would pick whichever round the seed engine got
    # unlucky in, inflating the trajectory headline)
    speedup_events = sorted(ratios_events)[len(ratios_events) // 2]
    speedup_deliv = sorted(ratios_deliv)[len(ratios_deliv) // 2]
    out.append(row("sim_engine/speedup", 0, 1,
                   f"exact_vs_seed={speedup_events:.2f}x(events/s) "
                   f"fast_vs_seed={speedup_deliv:.2f}x(deliveries/s) "
                   f"[median of {rounds} interleaved rounds; per-round "
                   f"events={['%.2f' % r for r in ratios_events]} "
                   f"deliv={['%.2f' % r for r in ratios_deliv]}]"))

    # ---- large-N sweep unlocked by the headroom (paper stops at N=25) ----
    sweep = {}
    sweep_dur = 0.3 if quick else 0.5
    for n in (25, 49, 101):
        t0 = time.perf_counter()
        c = Cluster("pigpaxos", n, pig=PigConfig(n_groups=3, prc=1), seed=2,
                    engine="fast")
        st = c.measure(duration=sweep_dur, warmup=0.15, clients=60)
        wall = time.perf_counter() - t0
        sweep[n] = {"wall_s": round(wall, 2),
                    "throughput": round(st.throughput),
                    "median_ms": round(st.median_ms, 3)}
        out.append(row(f"sim_engine/sweep/N={n}", wall, max(st.count, 1),
                       f"tput={st.throughput:.0f}req/s "
                       f"median={st.median_ms:.2f}ms wall={wall:.1f}s"))

    # ---- scheduler-structure experiment: slab heap vs calendar queue ----
    # Timer-only churn mirroring the DES timer distribution (dense chained
    # timers + steady cancel/re-arm).  The fused message loop pushes heap
    # tuples into Scheduler._heap directly, so the calendar queue can only
    # ever back the timer path — the verdict records both the measured
    # ratio and that structural constraint.
    cal_rounds = []
    churn = 20_000 if quick else 60_000
    for _ in range(rounds):
        rnd = {}
        for label in ("heap", "calendar"):
            rnd[label] = _timer_churn(label, events=churn)
        cal_rounds.append(rnd["heap"][1] / rnd["calendar"][1])
        for label in ("heap", "calendar"):
            ev, wall = rnd[label]
            out.append(row(f"sim_engine/scheduler/{label}", wall, ev,
                           f"timer_events/s={ev / wall:.0f}"))
    cal_speed = sorted(cal_rounds)[len(cal_rounds) // 2]
    verdict = ("keep-heap" if cal_speed < 1.10 else "calendar-wins-timers")
    verdict_note = (
        f"{verdict}: calendar/heap wall={cal_speed:.2f}x on timer churn; "
        "fused message loop requires the slab heap either way "
        "(network.py pushes heap tuples directly)")
    out.append(row("sim_engine/scheduler/verdict", 0, 1, verdict_note))

    payload = {
        "bench": "sim_engine",
        "workload": "pigpaxos N=25 R=3 closed-loop clients=40",
        "engines": results,
        "speedup_exact_vs_seed_events_per_sec": round(speedup_events, 2),
        "speedup_fast_vs_seed_deliveries_per_sec": round(speedup_deliv, 2),
        "per_round_speedups_events": [round(r, 2) for r in ratios_events],
        "per_round_speedups_deliveries": [round(r, 2) for r in ratios_deliv],
        "sweep_fast_engine_R3": {str(k): v for k, v in sweep.items()},
        "sweep101_wall_s": sweep[101]["wall_s"],
        "scheduler_calendar_vs_heap_wall": round(cal_speed, 2),
        "scheduler_verdict": verdict_note,
    }
    with open(BENCH_PATH, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    out.append(row("sim_engine/json", 0, 1, f"wrote {BENCH_PATH}"))
    return out
