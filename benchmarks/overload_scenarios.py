"""Batching/pipelining and overload scenario families (ISSUE 8):

- ``batching`` — leader-side request batching (up to m commands per slot,
  one phase-2 fan-out amortized over the batch) and finite slot-pipelining
  depths, swept at saturation on paxos/pigpaxos/epaxos.  The m=1 cells ARE
  the unbatched baselines; paxos/pigpaxos cells also run on the batch
  backend and the summarizer emits DES<->batch fidelity ratios the
  regression gate bounds.
- ``overload`` — open-loop Poisson/bursty/diurnal arrivals pushed to ~4x
  saturation, with and without admission control
  (``repro.runtime.AdmissionPolicy``: queue-length backpressure +
  token-bucket shedding).  Units carry p99.9, goodput under the 50 ms SLO
  and every shed counter; the audited smoke cells run the linearizability
  auditor over shed/bounce/batch interleavings.

Scenarios: ``repro.experiments.catalog``; this module is the
``run.py --only`` shim."""
from repro.experiments import report

FAMILIES = ["batching", "overload"]


def run(quick: bool = True):
    return report.family_rows(FAMILIES, quick=quick)
