"""Table 2: message load in a small 5-node cluster."""
from repro.core import analytical

from .common import Timer, row


def run(quick: bool = True):
    with Timer() as t:
        rows = analytical.load_table(5)
    return [row(f"table2/R={x['R']}", t.dt, 1,
                f"M_l={x['M_l']} M_f={x['M_f']} ratio={x['ratio']}")
            for x in rows]
