"""Table 2: message load in a small 5-node cluster — analytical formulas
validated against DES-measured counts (asserted in the summarizer).

Scenarios: ``repro.experiments.catalog`` family ``table2``."""
from repro.experiments import report

FAMILIES = ["table2"]


def run(quick: bool = True):
    return report.family_rows(FAMILIES, quick=quick)
