"""Fig 12: 9-node cluster with R=2 and R=3 vs Paxos."""
from repro.core import PigConfig

from .common import Timer, max_throughput, row


def run(quick: bool = True):
    out = []
    grid = (40, 120) if quick else (20, 60, 120)
    dur = 0.4 if quick else 1.0
    res = {}
    for label, proto, pig in (
            ("paxos", "paxos", None),
            ("pig_R2", "pigpaxos", PigConfig(n_groups=2, prc=1)),
            ("pig_R3", "pigpaxos", PigConfig(n_groups=3, prc=1))):
        with Timer() as t:
            st = max_throughput(proto, 9, pig=pig, client_grid=grid, duration=dur)
        res[label] = st.throughput
        out.append(row(f"fig12/{label}", t.dt, st.count,
                       f"tput={st.throughput:.0f}req/s median={st.median_ms:.2f}ms"))
    gain = (res["pig_R2"] / res["paxos"] - 1) * 100
    out.append(row("fig12/summary", 0, 1,
                   f"R2_gain_over_paxos={gain:.0f}% (paper: ~57%)"))
    return out
