"""Fig 12: 9-node cluster with R=2 and R=3 vs Paxos.

Scenarios: ``repro.experiments.catalog`` family ``fig12``."""
from repro.experiments import report

FAMILIES = ["fig12"]


def run(quick: bool = True):
    return report.family_rows(FAMILIES, quick=quick)
