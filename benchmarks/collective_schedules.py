"""TPU adaptation bench: cross-pod (DCN) bytes per chip for the three
gradient-sync schedules, closed form + (if artifacts exist) measured from
the multi-pod dry-run HLO."""
import glob
import json

from repro.collectives.schedules import dcn_bytes_per_chip

from .common import Timer, row


def run(quick: bool = True):
    out = []
    with Timer() as t:
        for params_gb, name in ((3.7, "danube-1.8b"), (65.5, "qwen2.5-32b"),
                                (463.5, "qwen3-moe-235b")):
            p = params_gb * 1e9
            d = dcn_bytes_per_chip(p, 1, 2, "direct")
            g = dcn_bytes_per_chip(p, 16, 2, "pig")
            q = dcn_bytes_per_chip(p, 16, 2, "pig_q8")
            out.append(row(f"collective/{name}", 0, 1,
                           f"direct={d/1e9:.2f}GB pig={g/1e9:.3f}GB "
                           f"pig_q8={q/1e9:.3f}GB per-chip DCN/step"))
    for f in sorted(glob.glob("artifacts/dryrun/multi--*--train_4k.json")):
        d = json.load(open(f))
        if "error" in d or "skipped" in d:
            continue
        out.append(row(f"collective/measured/{d['arch']}", t.dt, 1,
                       f"cross_pod={d['cross_pod_bytes_per_chip']/1e9:.3f}GB/chip "
                       f"in_pod={d['in_pod_bytes_per_chip']/1e9:.2f}GB/chip"))
    return out
