"""Observability scenario family (ISSUE 9):

- ``obs/<proto>/traced`` — per-op distributed tracing on all three
  protocols: sampled span trees (client -> leader -> relay -> follower ->
  ack) decomposed into the critical-path segments (queue wait, CPU
  service, serialization, relay aggregation, network, residual wait) that
  sum to each op's measured latency.  The rows print the mean per-segment
  milliseconds — the bottleneck-attribution numbers.
- ``obs/fairness/{rotating,static}`` — fig8-style cells whose per-follower
  busy seconds the summarizer folds into max/mean and Gini: the paper's
  "relay rotation spreads the load" claim as an empirical comparison.
- ``obs/pigpaxos/backlog/batch`` — the batch backend's timelines-only
  counterpart (leader-backlog series from the vectorized kernel).

Scenarios: ``repro.experiments.catalog``; this module is the
``run.py --only`` shim."""
from repro.experiments import report

FAMILIES = ["obs"]


def run(quick: bool = True):
    return report.family_rows(FAMILIES, quick=quick)
