"""Per-scenario regression gate over ``repro-experiments/v1`` artifacts.

Diffs one or more fresh BENCH JSONs (as written by ``benchmarks/run.py
--json``, or a raw suite artifact) against the committed reference bounds in
``benchmarks/reference_bounds.json`` and exits non-zero when a scenario's
``summary.throughput.mean`` falls outside its [lo, hi] window — the CI
workflow runs it after the scenario smoke, so a throughput regression (or
an accidental 10x "improvement" from a broken measurement window) fails the
build instead of drifting silently.

The DES runs in virtual time, so quick-mode throughput is deterministic per
seed; the bounds carry a ±25% margin only to absorb *intentional*
model/engine retunes — bump the bounds in the same PR as the retune.

Additionally, any audited scenario whose units report a consistency
violation fails the gate regardless of throughput.

Usage::

    python -m benchmarks.regression_gate BENCH_scenarios.json [more.json...]
        [--bounds benchmarks/reference_bounds.json]
        [--write-bounds PATH]     # regenerate bounds (±25%) from the run
"""
from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_BOUNDS = os.path.join(os.path.dirname(__file__),
                              "reference_bounds.json")
MARGIN = 0.25


def _scenarios(path: str) -> list:
    with open(path) as f:
        payload = json.load(f)
    art = payload.get("experiments", payload)   # BENCH json or raw artifact
    return art.get("scenarios", [])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("artifacts", nargs="+", metavar="BENCH_JSON")
    ap.add_argument("--bounds", default=DEFAULT_BOUNDS)
    ap.add_argument("--write-bounds", default=None, metavar="PATH")
    args = ap.parse_args()

    seen = {}
    for path in args.artifacts:
        for sa in _scenarios(path):
            seen[sa["name"]] = sa

    if args.write_bounds:
        with open(args.bounds) as f:
            ref = json.load(f)
        for name in ref["bounds"]:
            sa = seen.get(name)
            if sa is None:
                continue
            mean = sa["summary"]["throughput"]["mean"]
            ref["bounds"][name] = [round(mean * (1 - MARGIN)),
                                   round(mean * (1 + MARGIN))]
        with open(args.write_bounds, "w") as f:
            json.dump(ref, f, indent=2)
            f.write("\n")
        print(f"wrote {args.write_bounds}")
        return

    with open(args.bounds) as f:
        bounds = json.load(f)["bounds"]

    failures = []
    for name, (lo, hi) in sorted(bounds.items()):
        sa = seen.get(name)
        if sa is None:
            failures.append(f"{name}: MISSING from the artifact(s) — the "
                            f"gate must not silently shrink")
            continue
        mean = sa["summary"]["throughput"]["mean"]
        ok = mean is not None and lo <= mean <= hi
        status = "ok" if ok else "FAIL"
        print(f"{status:4s} {name:40s} tput={mean if mean is not None else 'n/a':>10} "
              f"bounds=[{lo}, {hi}]")
        if not ok:
            failures.append(f"{name}: throughput {mean} outside "
                            f"[{lo}, {hi}]")
    for name, sa in sorted(seen.items()):
        bad = [u for u in sa.get("units", [])
               if u.get("consistency") == "violation"]
        if bad:
            failures.append(
                f"{name}: {len(bad)} unit(s) FAILED the linearizability "
                f"audit: {bad[0].get('audit', {}).get('violations')}")

    if failures:
        print("\nREGRESSION GATE FAILED:", file=sys.stderr)
        for f_ in failures:
            print(f"  - {f_}", file=sys.stderr)
        sys.exit(1)
    print(f"\nregression gate passed: {len(bounds)} scenario bounds, "
          f"{len(seen)} scenarios audited for consistency verdicts")


if __name__ == "__main__":
    main()
