"""Per-scenario regression gate over ``repro-experiments/v1`` artifacts.

Diffs one or more fresh BENCH JSONs (as written by ``benchmarks/run.py
--json``, or a raw suite artifact) against the committed reference bounds in
``benchmarks/reference_bounds.json`` and exits non-zero when:

* a scenario's ``summary.throughput.mean`` falls outside its [lo, hi]
  window (``"bounds"``) — so a throughput regression (or an accidental 10x
  "improvement" from a broken measurement window) fails the build instead
  of drifting silently;
* a DES<->batch **fidelity pair** (``"fidelity"``: base name -> ratio
  window, checked as ``<base>/batch`` over ``<base>`` throughput means)
  leaves its window — the batch backend drifting away from the DES is a
  model regression even when both stay inside their own bounds;
* a **speedup pair** (``"speedup"``: name -> {"over": base, "min": r})
  drops below its floor — the ISSUE-8 claim that leader-side batching
  buys >= 2x at saturation is pinned here, so a change that quietly
  erodes the batching win fails the build;
* an **overload scenario** (``"overload"``: name -> {"goodput_at_max":
  [lo, hi]}) leaves its goodput window at the highest-load grid point —
  admission control must hold goodput near capacity under ~4x offered
  load (floor), and the no-admission baseline must still exhibit the
  collapse the study documents (ceiling ~0);
* any audited scenario's units report a consistency violation (always
  fatal, regardless of throughput);
* a gated scenario is missing from the artifacts, or an artifact is
  corrupt — the gate must fail loudly, never silently shrink;
* a ``BENCH_vectorsim.json`` payload passed alongside (nightly regenerates
  it with the sharded-dispatch numbers) violates the ``"vectorsim"``
  reference section: DES<->batch xcheck error caps, the deterministic
  N=1025 sweep throughput window, or a missing ``sharded`` section
  (wall-clock metrics are hardware-bound and deliberately NOT gated);
* a ``BENCH_sim.json`` payload (``bench: "sim_engine"``) reports a
  sampled-tracing CPU overhead above the ``"sim_engine"`` section's
  ceiling — the obs layer's hooks must stay near-free at the catalog
  sample rates (the gated number is the paired-minimum across
  interleaved rounds; see ``sim_engine_bench.py`` for why);
* the ``"obs_fairness"`` relay-fairness pair inverts: rotating relays
  must yield a *lower* follower busy max/mean hotspot factor than static
  relays (the paper's Fig 8 claim, recomputed from the obs sections of
  the ``obs/fairness/*`` cells in ``BENCH_obs.json``).

The DES runs in virtual time, so quick-mode throughput is deterministic per
seed; the bounds carry a ±25% margin only to absorb *intentional*
model/engine retunes — bump the bounds in the same PR as the retune.

Usage::

    python -m benchmarks.regression_gate BENCH_scenarios.json [more.json...]
        [--bounds benchmarks/reference_bounds.json]
        [--write-bounds PATH]     # regenerate bounds (±25%) from the run
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Tuple

DEFAULT_BOUNDS = os.path.join(os.path.dirname(__file__),
                              "reference_bounds.json")
MARGIN = 0.25


class GateError(Exception):
    """A corrupt or unreadable artifact — always a loud failure."""


def _scenarios(path: str) -> list:
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, ValueError) as e:
        raise GateError(f"{path}: unreadable artifact ({e})") from e
    if not isinstance(payload, dict):
        raise GateError(f"{path}: artifact is not a JSON object")
    art = payload.get("experiments", payload)   # BENCH json or raw artifact
    scenarios = art.get("scenarios", [])
    if not isinstance(scenarios, list):
        raise GateError(f"{path}: 'scenarios' is not a list")
    return scenarios


def load_artifacts(paths) -> Dict[str, dict]:
    """Scenario artifacts by name, later paths winning on duplicates."""
    seen: Dict[str, dict] = {}
    for path in paths:
        for sa in _scenarios(path):
            if not isinstance(sa, dict) or "name" not in sa \
                    or "summary" not in sa:
                raise GateError(f"{path}: malformed scenario entry "
                                f"{str(sa)[:80]!r}")
            seen[sa["name"]] = sa
    return seen


def load_vectorsim(paths) -> Dict[str, dict]:
    """``bench: "vectorsim"`` payloads among ``paths`` (BENCH_vectorsim.json
    as written by ``benchmarks.vectorsim_bench``), keyed by path."""
    out: Dict[str, dict] = {}
    for path in paths:
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, ValueError) as e:
            raise GateError(f"{path}: unreadable artifact ({e})") from e
        if isinstance(payload, dict) and payload.get("bench") == "vectorsim":
            out[path] = payload
    return out


def load_sim_engine(paths) -> Dict[str, dict]:
    """``bench: "sim_engine"`` payloads among ``paths`` (BENCH_sim.json as
    written by ``benchmarks.sim_engine_bench``), keyed by path."""
    out: Dict[str, dict] = {}
    for path in paths:
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, ValueError) as e:
            raise GateError(f"{path}: unreadable artifact ({e})") from e
        if isinstance(payload, dict) and payload.get("bench") == "sim_engine":
            out[path] = payload
    return out


def evaluate_sim_engine(payload: dict, ref: dict,
                        path: str = "BENCH_sim.json"
                        ) -> Tuple[List[str], List[str]]:
    """Gate one sim_engine bench payload against the ``"sim_engine"``
    reference section: the sampled-tracing overhead ceiling (the catalog
    obs cells run at sample_rate 0.05-0.1, so the gated fraction is the
    production cost of the obs hooks).  Full-rate overhead is recorded in
    the payload but informational only — nobody measures at rate 1.0."""
    failures: List[str] = []
    lines: List[str] = []
    cap = ref.get("tracing_overhead_max")
    if cap is None:
        return failures, lines
    try:
        got = payload["tracing_overhead_frac"]
    except (KeyError, TypeError) as e:
        raise GateError(f"{path}: malformed sim_engine payload ({e})") from e
    ok = got <= cap
    lines.append(f"{'ok' if ok else 'FAIL':4s} "
                 f"{'sim_engine/tracing_overhead':40s} "
                 f"frac={got:>10.4f} cap={cap}")
    if not ok:
        failures.append(f"{path}: sampled-tracing overhead {got:.4f} "
                        f"above the {cap} ceiling")
    return failures, lines


def _follower_hotspot(sa: dict):
    """Follower busy max/mean from a scenario artifact's obs section
    (representative = highest-throughput replicate, as in the report
    summarizer)."""
    reps = sa.get("replicates") or []
    if not reps:
        raise GateError(f"{sa.get('name')}: no replicates for the "
                        f"fairness check")
    rep = max(reps, key=lambda u: u.get("throughput") or 0.0)
    try:
        busy = rep["extras"]["obs"]["cpu_busy_s"]
        n = sa["spec"]["n"]
    except (KeyError, TypeError) as e:
        raise GateError(f"{sa.get('name')}: replicate lacks obs busy "
                        f"accounting ({e})") from e
    vals = [float(busy.get(str(i), 0.0)) for i in range(1, n)]
    if not vals or sum(vals) <= 0:
        raise GateError(f"{sa.get('name')}: follower busy seconds are all "
                        f"zero — obs accounting broken")
    return max(vals) / (sum(vals) / len(vals))


def evaluate_obs_fairness(seen: Dict[str, dict],
                          spec: dict) -> Tuple[List[str], List[str]]:
    """The Fig 8 relay-fairness claim as a gate: the rotating cell's
    follower busy max/mean must stay below the static cell's AND below an
    absolute ceiling (rotation keeps followers near-uniform)."""
    failures: List[str] = []
    lines: List[str] = []
    rot_name = spec.get("rotating", "obs/fairness/rotating")
    stat_name = spec.get("static", "obs/fairness/static")
    rot_sa, stat_sa = seen.get(rot_name), seen.get(stat_name)
    if rot_sa is None or stat_sa is None:
        missing = rot_name if rot_sa is None else stat_name
        failures.append(f"obs_fairness: {missing} MISSING from the "
                        f"artifact(s) — the gate must not silently shrink")
        return failures, lines
    rot, stat = _follower_hotspot(rot_sa), _follower_hotspot(stat_sa)
    cap = spec.get("rotating_max_over_mean_max")
    ok = rot < stat and (cap is None or rot <= cap)
    lines.append(f"{'ok' if ok else 'FAIL':4s} "
                 f"{'obs/fairness [rotating<static]':40s} "
                 f"rotating={rot:>7.2f} static={stat:.2f}"
                 f"{'' if cap is None else f' cap={cap}'}")
    if not ok:
        failures.append(f"obs_fairness: follower busy max/mean "
                        f"rotating={rot:.2f} vs static={stat:.2f} "
                        f"(need rotating < static"
                        f"{'' if cap is None else f' and <= {cap}'})")
    return failures, lines


def evaluate_vectorsim(payload: dict, ref: dict,
                       path: str = "BENCH_vectorsim.json"
                       ) -> Tuple[List[str], List[str]]:
    """Gate one vectorsim bench payload against the ``"vectorsim"``
    reference section.  Only determinism-safe metrics are bounded: the
    virtual-time DES<->batch xcheck errors and the N=1025 sweep throughput;
    ``require_sharded`` just asserts the sharded section exists and is
    self-consistent (its walls are hardware-bound)."""
    failures: List[str] = []
    lines: List[str] = []
    try:
        for key, cap_key in (("max_abs_tput_err", "xcheck_max_abs_tput_err"),
                             ("max_abs_median_err",
                              "xcheck_max_abs_median_err")):
            cap = ref.get(cap_key)
            if cap is None:
                continue
            got = payload["xcheck"][key]
            ok = got <= cap
            lines.append(f"{'ok' if ok else 'FAIL':4s} "
                         f"{'vectorsim/' + key:40s} {got:>10} cap={cap}")
            if not ok:
                failures.append(f"{path}: xcheck {key} {got} > {cap}")
        win = ref.get("sweep1025_throughput")
        if win is not None:
            got = payload["sweep1025"]["throughput"]
            lo, hi = win
            ok = lo <= got <= hi
            lines.append(f"{'ok' if ok else 'FAIL':4s} "
                         f"{'vectorsim/sweep1025':40s} tput={got:>10} "
                         f"bounds=[{lo}, {hi}]")
            if not ok:
                failures.append(f"{path}: sweep1025 throughput {got} "
                                f"outside [{lo}, {hi}]")
        if ref.get("require_sharded"):
            sh = payload.get("sharded")
            if not sh or sh.get("device_count", 0) < 1 \
                    or not sh.get("chunks"):
                failures.append(f"{path}: sharded section missing or empty "
                                f"(nightly must publish sharded numbers)")
            else:
                total = sum(c["cells"] for c in sh["chunks"])
                ok = total == payload["grid"]["cells"]
                lines.append(f"{'ok' if ok else 'FAIL':4s} "
                             f"{'vectorsim/sharded':40s} "
                             f"devices={sh['device_count']} "
                             f"kernel={sh['kernel']} chunks="
                             f"{len(sh['chunks'])} cells={total}")
                if not ok:
                    failures.append(
                        f"{path}: sharded chunk cells {total} != grid "
                        f"cells {payload['grid']['cells']}")
    except (KeyError, TypeError) as e:
        raise GateError(f"{path}: malformed vectorsim payload ({e})") from e
    return failures, lines


def _mean_tput(sa: dict):
    try:
        return sa["summary"]["throughput"]["mean"]
    except (KeyError, TypeError) as e:
        raise GateError(f"{sa.get('name')}: malformed summary ({e})") from e


def _goodput_at_max(sa: dict) -> Tuple[float, int]:
    """Mean goodput (completions under the SLO per second) across the units
    at the scenario's highest client count — the deep-overload grid point."""
    units = sa.get("units", [])
    try:
        cmax = max(u["clients"] for u in units)
        gs = [u["extras"]["goodput"] for u in units if u["clients"] == cmax]
        return sum(gs) / len(gs), cmax
    except (KeyError, TypeError, ValueError, ZeroDivisionError) as e:
        raise GateError(f"{sa.get('name')}: units lack overload extras "
                        f"({e})") from e


def evaluate(seen: Dict[str, dict], ref: dict) -> Tuple[List[str], List[str]]:
    """Run every check; return (failures, report lines).  Pure over plain
    data so tests can feed corrupted fixtures directly."""
    failures: List[str] = []
    lines: List[str] = []

    for name, (lo, hi) in sorted(ref.get("bounds", {}).items()):
        sa = seen.get(name)
        if sa is None:
            failures.append(f"{name}: MISSING from the artifact(s) — the "
                            f"gate must not silently shrink")
            continue
        mean = _mean_tput(sa)
        ok = mean is not None and lo <= mean <= hi
        status = "ok" if ok else "FAIL"
        lines.append(
            f"{status:4s} {name:40s} "
            f"tput={mean if mean is not None else 'n/a':>10} "
            f"bounds=[{lo}, {hi}]")
        if not ok:
            failures.append(f"{name}: throughput {mean} outside [{lo}, {hi}]")

    # DES<->batch fidelity: <base>/batch over <base> throughput ratio
    for base, (lo, hi) in sorted(ref.get("fidelity", {}).items()):
        des, batch = seen.get(base), seen.get(base + "/batch")
        if des is None or batch is None:
            missing = base if des is None else base + "/batch"
            failures.append(f"{base}: fidelity pair incomplete — "
                            f"{missing} missing from the artifact(s)")
            continue
        td, tb = _mean_tput(des), _mean_tput(batch)
        if not td or tb is None:
            failures.append(f"{base}: fidelity pair has no throughput "
                            f"(des={td}, batch={tb})")
            continue
        ratio = tb / td
        ok = lo <= ratio <= hi
        status = "ok" if ok else "FAIL"
        lines.append(f"{status:4s} {base + ' [xcheck]':40s} "
                     f"batch/des={ratio:>10.3f} bounds=[{lo}, {hi}]")
        if not ok:
            failures.append(f"{base}: DES<->batch throughput ratio "
                            f"{ratio:.3f} outside [{lo}, {hi}]")

    # batching speedup floors: <name> over its unbatched baseline
    for name, spec in sorted(ref.get("speedup", {}).items()):
        base = spec["over"]
        fast, slow = seen.get(name), seen.get(base)
        if fast is None or slow is None:
            missing = name if fast is None else base
            failures.append(f"{name}: speedup pair incomplete — "
                            f"{missing} missing from the artifact(s)")
            continue
        tf, ts = _mean_tput(fast), _mean_tput(slow)
        if not ts or tf is None:
            failures.append(f"{name}: speedup pair has no throughput "
                            f"(fast={tf}, base={ts})")
            continue
        ratio = tf / ts
        ok = ratio >= spec["min"]
        status = "ok" if ok else "FAIL"
        lines.append(f"{status:4s} {name + ' [speedup]':40s} "
                     f"over={ratio:>10.2f}x min={spec['min']}x "
                     f"(vs {base})")
        if not ok:
            failures.append(f"{name}: speedup {ratio:.2f}x over {base} "
                            f"below the {spec['min']}x floor")

    # overload goodput windows at the highest-load grid point
    for name, spec in sorted(ref.get("overload", {}).items()):
        sa = seen.get(name)
        if sa is None:
            failures.append(f"{name}: MISSING from the artifact(s) — the "
                            f"gate must not silently shrink")
            continue
        goodput, cmax = _goodput_at_max(sa)
        lo, hi = spec["goodput_at_max"]
        ok = lo <= goodput <= hi
        status = "ok" if ok else "FAIL"
        lines.append(f"{status:4s} {name + ' [overload]':40s} "
                     f"goodput={goodput:>7.0f} bounds=[{lo}, {hi}] "
                     f"(clients={cmax})")
        if not ok:
            failures.append(f"{name}: goodput {goodput:.0f} at the "
                            f"highest-load point (clients={cmax}) outside "
                            f"[{lo}, {hi}]")

    for name, sa in sorted(seen.items()):
        bad = [u for u in sa.get("units", [])
               if u.get("consistency") == "violation"]
        if bad:
            failures.append(
                f"{name}: {len(bad)} unit(s) FAILED the linearizability "
                f"audit: {bad[0].get('audit', {}).get('violations')}")

    return failures, lines


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("artifacts", nargs="+", metavar="BENCH_JSON")
    ap.add_argument("--bounds", default=DEFAULT_BOUNDS)
    ap.add_argument("--write-bounds", default=None, metavar="PATH")
    args = ap.parse_args()

    try:
        seen = load_artifacts(args.artifacts)
    except GateError as e:
        print(f"\nREGRESSION GATE FAILED:\n  - {e}", file=sys.stderr)
        sys.exit(1)

    if args.write_bounds:
        with open(args.bounds) as f:
            ref = json.load(f)
        for name in ref["bounds"]:
            sa = seen.get(name)
            if sa is None:
                continue
            mean = sa["summary"]["throughput"]["mean"]
            ref["bounds"][name] = [round(mean * (1 - MARGIN)),
                                   round(mean * (1 + MARGIN))]
        with open(args.write_bounds, "w") as f:
            json.dump(ref, f, indent=2)
            f.write("\n")
        print(f"wrote {args.write_bounds}")
        return

    with open(args.bounds) as f:
        ref = json.load(f)

    try:
        failures, lines = evaluate(seen, ref)
        vs_ref = ref.get("vectorsim", {})
        for path, payload in load_vectorsim(args.artifacts).items():
            vf, vl = evaluate_vectorsim(payload, vs_ref, path)
            failures += vf
            lines += vl
        se_ref = ref.get("sim_engine", {})
        for path, payload in load_sim_engine(args.artifacts).items():
            sf, sl = evaluate_sim_engine(payload, se_ref, path)
            failures += sf
            lines += sl
        fair_spec = ref.get("obs_fairness")
        if fair_spec is not None and any(
                name in seen for name in (
                    fair_spec.get("rotating", "obs/fairness/rotating"),
                    fair_spec.get("static", "obs/fairness/static"))):
            ff, fl = evaluate_obs_fairness(seen, fair_spec)
            failures += ff
            lines += fl
    except GateError as e:
        failures, lines = [str(e)], []
    for line in lines:
        print(line)
    if failures:
        print("\nREGRESSION GATE FAILED:", file=sys.stderr)
        for f_ in failures:
            print(f"  - {f_}", file=sys.stderr)
        sys.exit(1)
    print(f"\nregression gate passed: {len(ref.get('bounds', {}))} scenario "
          f"bounds, {len(ref.get('fidelity', {}))} fidelity pairs, "
          f"{len(ref.get('speedup', {}))} speedup floors, "
          f"{len(ref.get('overload', {}))} overload windows, "
          f"{len(seen)} scenarios audited for consistency verdicts")


if __name__ == "__main__":
    main()
