"""Fig 15: PRC x gray-list latency matrix under one node failure.

Uses the §4.2 group shape (f+1 / f) where the faulty group is REQUIRED for
majority — the configuration in which the paper's failure mechanisms
(relay-wait timeout; dead node picked as relay) are visible.  Paper claim:
PRC + gray lists ~ fault-free median.

Scenarios: ``repro.experiments.catalog`` family ``fig15``."""
from repro.experiments import report

FAMILIES = ["fig15"]


def run(quick: bool = True):
    return report.family_rows(FAMILIES, quick=quick)
