"""Fig 15: PRC x gray-list latency matrix under one node failure.

Uses the §4.2 group shape (f+1 / f) where the faulty group is REQUIRED for
majority — the configuration in which the paper's failure mechanisms
(relay-wait timeout; dead node picked as relay) are visible.  Paper claim:
PRC + gray lists ~ fault-free median."""
from repro.core import PigConfig

from .common import Timer, measure, row


def run(quick: bool = True):
    out = []
    A = list(range(1, 14))
    B = list(range(14, 25))
    dur = 0.8 if quick else 2.0
    base = None
    for prc, gray in ((0, False), (1, False), (0, True), (1, True)):
        pig = PigConfig(n_groups=2, groups=[A, B], prc=prc, use_gray_list=gray)
        with Timer() as t:
            st, _ = measure("pigpaxos", 25, pig=pig, clients=30, duration=dur,
                            failures=[(7, 0.1)], seed=5)
        out.append(row(f"fig15/PRC={prc}/gray={int(gray)}", t.dt, st.count,
                       f"median={st.median_ms:.2f}ms "
                       f"IQR=[{st.p25_ms:.2f},{st.p75_ms:.2f}]ms "
                       f"tput={st.throughput:.0f}"))
        if prc == 1 and gray:
            base = st.median_ms
    with Timer() as t:
        st0, _ = measure("pigpaxos", 25,
                         pig=PigConfig(n_groups=2, groups=[A, B]),
                         clients=30, duration=dur, seed=5)
    out.append(row("fig15/fault_free", t.dt, st0.count,
                   f"median={st0.median_ms:.2f}ms; "
                   f"prc+gray within {abs(base-st0.median_ms):.2f}ms of fault-free"))
    return out
