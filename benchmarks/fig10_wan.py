"""Fig 10: 15-node WAN (Virginia/California/Oregon), per-region relay groups,
leader + clients in Virginia."""
from repro.core import PigConfig, wan_topology

from .common import Timer, measure, row


def _topo():
    # one-way ms between regions (VA, CA, OR)
    return wan_topology([5, 5, 5], [[0.15, 31, 35],
                                    [31, 0.15, 11],
                                    [35, 11, 0.15]])


def run(quick: bool = True):
    out = []
    groups = [[1, 2, 3, 4], [5, 6, 7, 8, 9], [10, 11, 12, 13, 14]]
    dur = 0.8 if quick else 2.0
    for proto, pig in (("paxos", None),
                       ("pigpaxos", PigConfig(n_groups=3, groups=groups, prc=1))):
        for k in ((20, 120) if quick else (10, 40, 120, 200)):
            with Timer() as t:
                st, _ = measure(proto, 15, pig=pig, clients=k, duration=dur,
                                topo=_topo(), leader_timeout=400e-3)
            out.append(row(f"fig10/{proto}/clients={k}", t.dt, st.count,
                           f"tput={st.throughput:.0f}req/s "
                           f"median={st.median_ms:.1f}ms"))
    return out
