"""Fig 10: 15-node WAN (Virginia/California/Oregon), per-region relay groups,
leader + clients in Virginia.

Scenarios: ``repro.experiments.catalog`` family ``fig10``."""
from repro.experiments import report

FAMILIES = ["fig10"]


def run(quick: bool = True):
    return report.family_rows(FAMILIES, quick=quick)
