"""Roofline table from dry-run artifacts (see EXPERIMENTS.md §Roofline)."""
import glob
import json

from .common import row


def load_cells(pattern="artifacts/dryrun/*.json"):
    cells = []
    for f in sorted(glob.glob(pattern)):
        with open(f) as fh:
            cells.append(json.load(fh))
    return cells


def run(quick: bool = True):
    out = []
    for d in load_cells():
        name = f"roofline/{d.get('mesh')}/{d.get('arch')}/{d.get('shape')}"
        if "skipped" in d:
            out.append(row(name, 0, 1, "SKIPPED: " + d["skipped"][:60]))
            continue
        if "error" in d:
            out.append(row(name, 0, 1, "ERROR: " + d["error"][:80]))
            continue
        out.append(row(name, 0, 1,
                       f"tC={d['t_compute']*1e3:.2f}ms tM={d['t_memory']*1e3:.2f}ms "
                       f"tN={d['t_collective']*1e3:.2f}ms "
                       f"bound={d['bottleneck']} frac={d['roofline_fraction']:.3f} "
                       f"useful={min(d['useful_flops_ratio'],9.99):.2f}"))
    return out
