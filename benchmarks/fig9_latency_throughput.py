"""Fig 9: latency vs throughput curves, 25-node cluster, Paxos vs EPaxos vs
PigPaxos(R=3).  Paper: Paxos saturates ~2k, EPaxos ~3k, PigPaxos >7k req/s.

Scenarios: ``repro.experiments.catalog`` family ``fig9``."""
from repro.experiments import report

FAMILIES = ["fig9"]


def run(quick: bool = True):
    return report.family_rows(FAMILIES, quick=quick)
