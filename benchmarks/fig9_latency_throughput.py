"""Fig 9: latency vs throughput, 25-node cluster, Paxos vs EPaxos vs
PigPaxos(R=3).  Paper: Paxos saturates ~2k, EPaxos ~3k, PigPaxos >7k req/s."""
from repro.core import PigConfig
from repro.core.jaxsim import saturation_point

from .common import Timer, measure, row


def run(quick: bool = True):
    out = []
    grid = (10, 40, 120) if quick else (5, 10, 20, 40, 80, 120)
    dur = 0.4 if quick else 1.0
    sat = {}
    for proto, pig in (("paxos", None),
                       ("epaxos", None),
                       ("pigpaxos", PigConfig(n_groups=3, prc=1))):
        best = 0.0
        for k in grid:
            with Timer() as t:
                st, _ = measure(proto, 25, pig=pig, clients=k, duration=dur)
            best = max(best, st.throughput)
            out.append(row(f"fig9/{proto}/clients={k}", t.dt, st.count,
                           f"tput={st.throughput:.0f}req/s "
                           f"median={st.median_ms:.2f}ms p99={st.p99_ms:.2f}ms"))
        sat[proto] = best
    ratio = sat["pigpaxos"] / max(sat["paxos"], 1)
    out.append(row("fig9/summary", 0, 1,
                   f"paxos={sat['paxos']:.0f} epaxos={sat['epaxos']:.0f} "
                   f"pigpaxos={sat['pigpaxos']:.0f} pig/paxos={ratio:.1f}x "
                   f"(paper >3x); queueing-model paxos="
                   f"{saturation_point(25, 24, protocol='paxos'):.0f}"))
    return out
