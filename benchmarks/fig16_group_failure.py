"""Fig 16: throughput timeline with one of 3 relay groups faulty (several
nodes crashed mid-run), 25 nodes, relay timeout 50ms, no extra optimizations.
Paper: max throughput declines only ~3%."""
import numpy as np

from repro.core import Cluster, PigConfig

from .common import Timer, row


def run(quick: bool = True):
    pig = PigConfig(n_groups=3, relay_timeout=50e-3)
    c = Cluster("pigpaxos", 25, pig=pig, seed=9)
    # group 2 (nodes 3,6,9,...) partially fails at t=0.8
    fail_at = 0.8
    for nid in (3, 6, 9):
        c.crash_at(nid, fail_at)
    with Timer() as t:
        st = c.measure(duration=1.2 if quick else 3.0, warmup=0.3, clients=60)
    lat = [(tt, l) for cl in c.clients for (tt, l) in cl.latencies]
    pre = [1 for (tt, _) in lat if 0.3 <= tt < fail_at]
    post = [1 for (tt, _) in lat if fail_at <= tt < fail_at + 0.5]
    tput_pre = len(pre) / (fail_at - 0.3)
    tput_post = len(post) / 0.5
    drop = (1 - tput_post / max(tput_pre, 1)) * 100
    return [row("fig16/group_failure", t.dt, st.count,
                f"tput_before={tput_pre:.0f} tput_during={tput_post:.0f} "
                f"drop={drop:.1f}% (paper: ~3%)")]
