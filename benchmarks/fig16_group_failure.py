"""Fig 16: throughput timeline with one of 3 relay groups faulty (several
nodes crashed mid-run), 25 nodes, relay timeout 50ms, no extra optimizations.
Paper: max throughput declines only ~3%.

Scenarios: ``repro.experiments.catalog`` family ``fig16`` (the timeline
comes from the runner's ``collect=("timeline",)`` extra)."""
from repro.experiments import report

FAMILIES = ["fig16"]


def run(quick: bool = True):
    return report.family_rows(FAMILIES, quick=quick)
