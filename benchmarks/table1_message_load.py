"""Table 1: message load at leader/followers, 25-node cluster — analytical
formulas validated against DES-measured counts."""
from repro.core import Cluster, PigConfig, analytical

from .common import Timer, row


def run(quick: bool = True):
    out = []
    with Timer() as t:
        rows = analytical.load_table(25)
        # validate two representative rows against the simulator
        for r in (1, 3):
            c = Cluster("pigpaxos", 25, pig=PigConfig(n_groups=r), seed=7)
            st = c.measure(duration=0.4 if quick else 1.0, warmup=0.2,
                           clients=20)
            ml = st.messages_per_op(0)
            mf = sum(st.messages_per_op(i) for i in range(1, 25)) / 24
            ana = next(x for x in rows if x["R"] == r)
            assert abs(ml - ana["M_l"]) < 0.2, (ml, ana)
            assert abs(mf - ana["M_f"]) < 0.2, (mf, ana)
    for x in rows:
        out.append(row(f"table1/R={x['R']}", t.dt, 1,
                       f"M_l={x['M_l']} M_f={x['M_f']} ratio={x['ratio']}"))
    return out
