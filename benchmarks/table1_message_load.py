"""Table 1: message load at leader/followers, 25-node cluster — analytical
formulas validated against DES-measured counts (asserted in the summarizer).

Scenarios: ``repro.experiments.catalog`` family ``table1``."""
from repro.experiments import report

FAMILIES = ["table1"]


def run(quick: bool = True):
    return report.family_rows(FAMILIES, quick=quick)
