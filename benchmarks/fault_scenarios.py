"""Fault-injection scenario families (``repro.faults``):

- ``avail`` — leader / relay crash-recover windows at N in {25, 49} with
  the linearizability auditor on; reports the unavailability window and
  throughput-dip depth, cross-checked between the exact/fast DES engines
  and the batch backend's availability-mask runs.  ``avail/epaxos/*``
  crashes an opportunistic command leader: in-flight instances heal via
  the explicit-prepare recovery phase (no hung clients).
- ``storm`` — seeded randomized crash-recover storms (Poisson arrivals,
  concurrency-capped) on pigpaxos/paxos/epaxos at N up to 101 on the fast
  engine, audit always on.  ``storm/epaxos-recovery/N=25`` runs the full
  pigpaxos storm intensity against EPaxos — survivable only with
  instance recovery.
- ``reconfig`` — single-server membership changes under load (add a spare,
  remove a follower, replace the leader, planned handoff) on pigpaxos and
  epaxos, audited against the time-varying membership.
- ``rolling`` — restart every node in sequence (the rolling-upgrade
  model); per-restart unavailability windows in the artifact, audit on.
- ``failover`` — the leader dies for good; an external failover policy
  (``repro.runtime.FailoverPolicy``) promotes a successor, swept over its
  detection budget.

Scenarios: ``repro.experiments.catalog`` families above.
"""
from repro.experiments import report

FAMILIES = ["avail", "storm", "reconfig", "rolling", "failover"]


def run(quick: bool = True):
    return report.family_rows(FAMILIES, quick=quick)
