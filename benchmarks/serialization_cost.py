"""§5.3 microbenchmark analogue: wire sizes + modeled CPU cost of PigPaxos
aggregated P2b vs EPaxos PreAcceptReply, and the N-scaling of EPaxos
messages (paper: 25-node messages ~4x slower to serialize than 5-node)."""
from repro.core.messages import (Command, CostModel, P2b, PigAggregate,
                                 PreAcceptReply)

from .common import Timer, row


def run(quick: bool = True):
    cm = CostModel()
    with Timer() as t:
        agg = PigAggregate(acks=8, voters=tuple(range(8)), missing=())
        par5 = PreAcceptReply(deps=frozenset([("a", 1)]), n_cluster=5)
        par25 = PreAcceptReply(deps=frozenset([("a", 1)]), n_cluster=25)
        c_agg = cm.cpu_cost(agg)
        c5 = cm.cpu_cost(par5)
        c25 = cm.cpu_cost(par25)
    return [
        row("serialization/pig_aggregated_p2b", t.dt, 1,
            f"bytes={agg.wire_size()} cpu={c_agg*1e6:.1f}us"),
        row("serialization/epaxos_preacceptreply_n25", 0, 1,
            f"bytes={par25.wire_size()} cpu={c25*1e6:.1f}us "
            f"(pig aggregate {100*(1-c_agg/c25):.0f}% cheaper; paper: 8-14%)"),
        row("serialization/epaxos_n_scaling", 0, 1,
            f"cost25/cost5={c25/c5:.2f}x (paper: ~4x)"),
    ]
