"""Fig 11: 5-node cluster; PigPaxos R=1 (single-relay majority optimization)
and R=2 vs Paxos vs EPaxos.

Scenarios: ``repro.experiments.catalog`` family ``fig11``."""
from repro.experiments import report

FAMILIES = ["fig11"]


def run(quick: bool = True):
    return report.family_rows(FAMILIES, quick=quick)
