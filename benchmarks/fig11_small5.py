"""Fig 11: 5-node cluster; PigPaxos R=1 (single-relay majority optimization)
and R=2 vs Paxos vs EPaxos."""
from repro.core import PigConfig

from .common import Timer, max_throughput, row


def run(quick: bool = True):
    out = []
    grid = (40, 120) if quick else (20, 60, 120)
    dur = 0.4 if quick else 1.0
    res = {}
    for label, proto, pig in (
            ("paxos", "paxos", None),
            ("epaxos", "epaxos", None),
            ("pig_R1", "pigpaxos", PigConfig(n_groups=1, single_group_majority=True)),
            ("pig_R2", "pigpaxos", PigConfig(n_groups=2))):
        with Timer() as t:
            st = max_throughput(proto, 5, pig=pig, client_grid=grid, duration=dur)
        res[label] = st.throughput
        out.append(row(f"fig11/{label}", t.dt, st.count,
                       f"tput={st.throughput:.0f}req/s median={st.median_ms:.2f}ms"))
    out.append(row("fig11/summary", 0, 1,
                   f"R1_beats_all={res['pig_R1'] >= max(res.values()) - 1} "
                   f"(paper: R=1 outperforms all at N=5)"))
    return out
