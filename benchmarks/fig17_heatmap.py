"""Fig 17: in-flight message heatmap, 9-node Paxos vs PigPaxos(R=3).
Prints per-node totals + max cell; full matrix saved to artifacts/."""
import json
import os

import numpy as np

from repro.core import PigConfig

from .common import Timer, measure, row


def run(quick: bool = True):
    out = []
    os.makedirs("artifacts", exist_ok=True)
    mats = {}
    for proto, pig in (("paxos", None), ("pigpaxos", PigConfig(n_groups=3))):
        with Timer() as t:
            st, c = measure(proto, 9, pig=pig, clients=15,
                            duration=0.5 if quick else 1.5)
        m = st.flight.astype(float) / max(st.committed, 1)
        mats[proto] = m.tolist()
        leader_share = (m[0].sum() + m[:, 0].sum()) / m.sum()
        out.append(row(f"fig17/{proto}", t.dt, st.count,
                       f"leader_traffic_share={leader_share:.2f} "
                       f"max_cell={m.max():.2f}msg/op"))
    with open("artifacts/fig17_heatmap.json", "w") as f:
        json.dump(mats, f)
    out.append(row("fig17/summary", 0, 1,
                   "pigpaxos spreads load: see artifacts/fig17_heatmap.json"))
    return out
