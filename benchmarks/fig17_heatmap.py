"""Fig 17: in-flight message heatmap, 9-node Paxos vs PigPaxos(R=3).
Prints per-node totals + max cell; full matrix saved to artifacts/.

Scenarios: ``repro.experiments.catalog`` family ``fig17`` (matrices come
from the runner's ``collect=("flight",)`` extra)."""
from repro.experiments import report

FAMILIES = ["fig17"]


def run(quick: bool = True):
    return report.family_rows(FAMILIES, quick=quick)
