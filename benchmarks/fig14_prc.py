"""Fig 14: steady-state median/IQR latency vs partial-response-collection
level, R=1 vs R=3, 25 nodes, fixed moderate load.

Scenarios: ``repro.experiments.catalog`` family ``fig14``."""
from repro.experiments import report

FAMILIES = ["fig14"]


def run(quick: bool = True):
    return report.family_rows(FAMILIES, quick=quick)
