"""Fig 14: steady-state median/IQR latency vs partial-response-collection
level, R=1 vs R=3, 25 nodes, fixed moderate load."""
from repro.core import PigConfig

from .common import Timer, measure, row


def run(quick: bool = True):
    out = []
    dur = 0.6 if quick else 2.0
    for r in (1, 3):
        for prc in (0, 1, 2):
            pig = PigConfig(n_groups=r, prc=prc,
                            single_group_majority=False)
            with Timer() as t:
                st, _ = measure("pigpaxos", 25, pig=pig, clients=18,
                                duration=dur)
            out.append(row(f"fig14/R={r}/PRC={prc}", t.dt, st.count,
                           f"median={st.median_ms:.2f}ms "
                           f"IQR=[{st.p25_ms:.2f},{st.p75_ms:.2f}]ms"))
    return out
