"""Kernel micro-bench: Pallas (interpret on CPU) vs jnp reference — numbers
here measure the *oracle agreement path*, not TPU performance (CPU-only
container); flops are reported for the roofline context."""
import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.pig_aggregate import quantize_blockwise

from .common import Timer, row


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps


def run(quick: bool = True):
    out = []
    B, S, H, D = 1, 256, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    t_p = _time(lambda a, b, c: ops.flash_attention(a, b, c), q, k, v)
    flops = 4 * B * H * S * S * D
    out.append(row("kernel/flash_attention_256", t_p, 1,
                   f"pallas_interp={t_p*1e3:.1f}ms flops={flops:.2e}"))
    la = -jnp.abs(jax.random.normal(ks[3], (B, S, H, D))) * 0.5 - 0.01
    t_s = _time(lambda a, b, c, d: ops.ssm_scan(a, b, c, d, chunk=64),
                q, k, v, la)
    out.append(row("kernel/ssm_scan_256", t_s, 1,
                   f"pallas_interp={t_s*1e3:.1f}ms"))
    x = jax.random.normal(ks[0], (8, 8192), jnp.float32)
    qs, ss = zip(*[quantize_blockwise(x[g], 1024) for g in range(8)])
    sh, sc = jnp.stack(qs), jnp.stack(ss)
    t_a = _time(lambda a, b: ops.pig_aggregate(a, b, block=1024), sh, sc)
    out.append(row("kernel/pig_aggregate_8x8192", t_a, 1,
                   f"pallas_interp={t_a*1e3:.2f}ms"))
    return out
