"""Kernel micro-bench: Pallas (interpret on CPU) vs jnp reference — numbers
here measure the *oracle agreement path*, not TPU performance (CPU-only
container); flops are reported for the roofline context.  Timings land in
``BENCH_kernels.json`` at the repo root (committed)."""
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.kernels.pig_aggregate import quantize_blockwise

from .common import Timer, row

BENCH_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_kernels.json")


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps


def _fanin_case(key, B, G, gsize, mask_per_seg=1):
    """A vectorsim-shaped fan-in burst: F = G*gsize contiguous slots,
    segment-constant coef/kcap, one +inf masked slot per segment (a down
    follower), kcap <= gsize - 2 so every segment stays consumable."""
    F = G * gsize
    ks = jax.random.split(key, 4)
    vals = jax.random.uniform(ks[0], (B, F), jnp.float32, 1.0, 2.0)
    segid = jnp.repeat(jnp.arange(G), gsize)
    coef = jnp.repeat(jax.random.uniform(ks[1], (B, G), jnp.float32,
                                         0.0, 1e-3), gsize, axis=1)
    kcap = jnp.repeat(
        jax.random.randint(ks[2], (G,), 0, gsize - mask_per_seg),
        gsize).astype(jnp.float32)
    if mask_per_seg:
        drop = jax.random.randint(ks[3], (G,), 0, gsize)
        vals = vals.at[:, drop + jnp.arange(G) * gsize].set(jnp.inf)
    anchor = jnp.full((B,), 1.0, jnp.float32)
    return (vals, coef, segid, kcap, -0.5, 3e-4, 2e-5, anchor)


def run(quick: bool = True):
    out = []
    B, S, H, D = 1, 256, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    t_p = _time(lambda a, b, c: ops.flash_attention(a, b, c), q, k, v)
    flops = 4 * B * H * S * S * D
    out.append(row("kernel/flash_attention_256", t_p, 1,
                   f"pallas_interp={t_p*1e3:.1f}ms flops={flops:.2e}"))
    la = -jnp.abs(jax.random.normal(ks[3], (B, S, H, D))) * 0.5 - 0.01
    t_s = _time(lambda a, b, c, d: ops.ssm_scan(a, b, c, d, chunk=64),
                q, k, v, la)
    out.append(row("kernel/ssm_scan_256", t_s, 1,
                   f"pallas_interp={t_s*1e3:.1f}ms"))
    x = jax.random.normal(ks[0], (8, 8192), jnp.float32)
    qs, ss = zip(*[quantize_blockwise(x[g], 1024) for g in range(8)])
    sh, sc = jnp.stack(qs), jnp.stack(ss)
    t_a = _time(lambda a, b: ops.pig_aggregate(a, b, block=1024), sh, sc)
    out.append(row("kernel/pig_aggregate_8x8192", t_a, 1,
                   f"pallas_interp={t_a*1e3:.2f}ms"))

    # ---- segmented quorum fan-in: the batch backend's hot inner kernel,
    # Pallas rank-by-counting vs the production lax sort+segscan path
    fanin = {}
    for tag, B, G, gsize in (("8x4x6", 8, 4, 6), ("8x8x16", 8, 8, 16)):
        args = _fanin_case(jax.random.PRNGKey(7), B, G, gsize)
        t_k = _time(lambda *a: ops.seg_fanin(*a), *args)
        t_r = _time(lambda *a: ref.seg_fanin_ref(*a), *args)
        mk = np.asarray(ops.seg_fanin(*args))
        mr = np.asarray(ref.seg_fanin_ref(*args))
        err = float(np.max(np.abs(mk - mr) / np.maximum(np.abs(mr), 1e-9)))
        assert err < 1e-5, f"seg_fanin parity broke: rel err {err}"
        out.append(row(f"kernel/seg_fanin_{tag}", t_k, 1,
                       f"pallas_interp={t_k*1e3:.2f}ms "
                       f"lax_ref={t_r*1e3:.2f}ms max_rel_err={err:.1e}"))
        fanin[tag] = {"pallas_interp_ms": round(t_k * 1e3, 3),
                      "lax_ref_ms": round(t_r * 1e3, 3),
                      "max_rel_err": err}

    payload = {
        "bench": "kernels",
        "backend": jax.default_backend(),
        "mode": "interpret" if jax.default_backend() != "tpu" else "native",
        "flash_attention_256": {"pallas_ms": round(t_p * 1e3, 2)},
        "ssm_scan_256": {"pallas_ms": round(t_s * 1e3, 2)},
        "pig_aggregate_8x8192": {"pallas_ms": round(t_a * 1e3, 3)},
        "seg_fanin": fanin,
    }
    with open(BENCH_PATH, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    out.append(row("kernel/json", 0, 1, f"wrote {BENCH_PATH}"))
    return out
