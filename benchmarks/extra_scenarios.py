"""Post-paper scenario families enabled by the generalized workload layer:

- ``zipf``     — Zipf-skewed PigPaxos (key popularity skew vs uniform);
- ``openloop`` — open-loop Poisson fig9 variant (offered load independent
  of completion rate);
- ``conflict`` — EPaxos conflict-rate sweeps at N in {25, 49}.

All are data-only entries in ``repro.experiments.catalog``; this module is
the ``run.py --only`` shim."""
from repro.experiments import report

FAMILIES = ["zipf", "openloop", "conflict"]


def run(quick: bool = True):
    return report.family_rows(FAMILIES, quick=quick)
