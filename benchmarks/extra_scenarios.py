"""Post-paper scenario families enabled by the generalized workload layer:

- ``zipf``     — Zipf-skewed PigPaxos (key popularity skew vs uniform);
- ``openloop`` — open-loop Poisson fig9 variant (offered load independent
  of completion rate);
- ``conflict`` — EPaxos conflict-rate sweeps at N in {25, 49};
- ``wan``      — the fig10 three-region WAN scaled to N in {25, 49, 101},
  run on both the fast DES engine and the batch backend (cross-check);
- ``scale``    — batch-backend headroom grids: N up to 1025 and
  64-128-seed replicate sweeps, one jitted call per scenario.

All are data-only entries in ``repro.experiments.catalog``; this module is
the ``run.py --only`` shim."""
from repro.experiments import report

FAMILIES = ["zipf", "openloop", "conflict", "wan", "scale"]


def run(quick: bool = True):
    return report.family_rows(FAMILIES, quick=quick)
