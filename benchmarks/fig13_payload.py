"""Fig 13: max throughput vs payload size (8..1280 bytes), write-only
workload; PigPaxos R=3 vs Paxos; absolute + normalized."""
from repro.core import PigConfig, WorkloadConfig

from .common import Timer, max_throughput, row


def run(quick: bool = True):
    out = []
    sizes = (8, 256, 1280) if quick else (8, 64, 256, 512, 1024, 1280)
    grid = (120,) if quick else (60, 150)
    base = {}
    for proto, pig in (("paxos", None), ("pigpaxos", PigConfig(n_groups=3, prc=1))):
        tputs = {}
        for s in sizes:
            wl = WorkloadConfig(payload_bytes=s, write_fraction=1.0)
            with Timer() as t:
                st = max_throughput(proto, 25, pig=pig, client_grid=grid,
                                    duration=0.4 if quick else 1.0, workload=wl)
            tputs[s] = st.throughput
            out.append(row(f"fig13/{proto}/payload={s}", t.dt, st.count,
                           f"tput={st.throughput:.0f}req/s"))
        mx = max(tputs.values())
        for s in sizes:
            out.append(row(f"fig13/{proto}/norm/payload={s}", 0, 1,
                           f"normalized={tputs[s]/mx:.3f} (paper: >0.86)"))
        base[proto] = tputs
    r = min(base["pigpaxos"][s] / base["paxos"][s] for s in sizes)
    out.append(row("fig13/summary", 0, 1,
                   f"min_pig_over_paxos={r:.1f}x (paper: ~3x at all sizes)"))
    return out
