"""Fig 13: max throughput vs payload size (8..1280 bytes), write-only
workload; PigPaxos R=3 vs Paxos; absolute + normalized.

Scenarios: ``repro.experiments.catalog`` family ``fig13``."""
from repro.experiments import report

FAMILIES = ["fig13"]


def run(quick: bool = True):
    return report.family_rows(FAMILIES, quick=quick)
