"""Fig 8: max throughput vs number of relay groups, rotating vs static
relays, 25-node cluster.  Reproduces: rotating => R=1 best; static => sqrt(N)
best (and catastrophically worse at small R).

Extended beyond the paper: the same relay-group sweep at N in {25, 49, 101}
(the paper's testbed stopped at 25 nodes) on the flattened fast engine —
large-N scaling regimes comparable to Compartmentalized Paxos / HT-Paxos
evaluations, reachable since the engine overhaul."""
import math

from repro.core import PigConfig

from .common import Timer, max_throughput, row


def run(quick: bool = True):
    out = []
    rs = (1, 2, 3, 5) if quick else (1, 2, 3, 4, 5, 6, 8)
    grid = (40, 120) if quick else (20, 60, 120)
    dur = 0.4 if quick else 1.0
    results = {}
    for rotate in (True, False):
        for r in rs:
            pig = PigConfig(n_groups=r, prc=1, rotate_relays=rotate,
                            single_group_majority=(r == 1 and rotate))
            with Timer() as t:
                st = max_throughput("pigpaxos", 25, pig=pig, client_grid=grid,
                                    duration=dur)
            label = "rotating" if rotate else "static"
            results[(rotate, r)] = st.throughput
            out.append(row(f"fig8/{label}/R={r}", t.dt, st.count,
                           f"tput={st.throughput:.0f}req/s median={st.median_ms:.2f}ms"))
    rot = {r: results[(True, r)] for r in rs}
    stat = {r: results[(False, r)] for r in rs}
    best_rot = min(rot, key=lambda r: -rot[r])
    best_stat = min(stat, key=lambda r: -stat[r])
    out.append(row("fig8/summary", 0, 1,
                   f"best_R_rotating={best_rot} best_R_static={best_stat} "
                   f"(paper: 1 and ~sqrt(N)=5)"))

    # ---- scale sweep: N in {25, 49, 101}, R in {3, ~sqrt(N)} ----
    sweep_dur = 0.3 if quick else 0.6
    for n in (25, 49, 101):
        for r in sorted({3, int(round(math.sqrt(n)))}):
            pig = PigConfig(n_groups=r, prc=1)
            with Timer() as t:
                st = max_throughput("pigpaxos", n, pig=pig,
                                    client_grid=(60,) if quick else (60, 120),
                                    duration=sweep_dur, engine="fast")
            out.append(row(f"fig8/scale/N={n}/R={r}", t.dt, st.count,
                           f"tput={st.throughput:.0f}req/s "
                           f"median={st.median_ms:.2f}ms"))
    return out
