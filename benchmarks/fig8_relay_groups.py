"""Fig 8: max throughput vs number of relay groups, rotating vs static
relays, 25-node cluster.  Reproduces: rotating => R=1 best; static => sqrt(N)
best (and catastrophically worse at small R).  Also carries the beyond-paper
N in {25, 49, 101} scale sweep on the fast engine.

Scenarios live in ``repro.experiments.catalog`` (family ``fig8``); run.py reads FAMILIES
and routes them through the shared suite pass; run() is the direct-import
entry (serial, no shared pool)."""
from repro.experiments import report

FAMILIES = ["fig8"]


def run(quick: bool = True):
    return report.family_rows(FAMILIES, quick=quick)
