"""Read-path quickstart: leased leader reads vs quorum reads, audited.

1. run a read-heavy (90% gets) 25-node Paxos cluster with a leader
   lease — the leader serves reads locally, skipping the whole commit
   round — and print the read/write latency split plus the stale-read
   auditor verdict;
2. run the same read mix as PQR-style quorum reads on PigPaxos — the
   client probes its relay subgroup + the leader and read-repairs on
   commit-frontier disagreement — no lease, no leader dependency;
3. compare both against what the log read path costs.

The semantics (and why the auditor can trust either path) are in
docs/consistency.md.

    PYTHONPATH=src python examples/read_paths_quickstart.py
"""
from repro.core import Cluster, PigConfig, WorkloadConfig
from repro.faults import audit_cluster


def run(title, protocol, read_path, **kw):
    wl = WorkloadConfig(read_ratio=0.9, read_path=read_path)
    c = Cluster(protocol, 25, seed=1, record_history=True, **kw)
    st = c.measure(duration=0.5, warmup=0.25, clients=60, workload=wl)
    rw = c.read_write_split()
    res = audit_cluster(c)
    print(f"=== {title} ===")
    print(f"  throughput: {st.throughput:7.0f} req/s   "
          f"({rw['reads']} reads / {rw['writes']} writes)")
    print(f"  read  mean: {rw['read_mean_ms']:6.2f} ms   "
          f"p99 {rw['read_p99_ms']:6.2f} ms"
          + (f"   ({rw['lease_reads']} served leader-local under the lease)"
             if rw["lease_reads"] else ""))
    print(f"  write mean: {rw['write_mean_ms']:6.2f} ms   (full commit round)")
    print(f"  stale-read audit: "
          f"{'ok' if res.ok else 'VIOLATION: ' + res.violations[0]}"
          f"  [{res.reads_checked} read values checked]")
    print()
    return st.throughput


# 1. leader lease: a quorum of followers promises not to elect anyone
#    else for 200 ms (drift-margined), so the leader's applied store IS
#    linearizable to read locally.
leased = run("leased reads — paxos N=25, read_ratio=0.9", "paxos", "lease",
             lease={"duration_ms": 200.0})

# 2. quorum reads: the client probes the geo-closest relay subgroup +
#    the leader, takes the freshest applied value, and rinses while any
#    probed replica has accepted-but-unapplied writes.
run("quorum reads — pigpaxos N=25 (relay-subgroup probes)", "pigpaxos",
    "quorum", pig=PigConfig(n_groups=3, prc=1))

# 3. baseline: the same mix with every read ordered through the log.
logged = run("log reads — paxos N=25 (every read is a commit round)",
             "paxos", "log")

print(f"leased reads are {leased / logged:.1f}x the log read path at "
      f"read_ratio=0.9 (the reads/ family gates this >= 2x)")
