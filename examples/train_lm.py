"""Train a (reduced) LM end to end with consensus-committed checkpoints:
data pipeline -> train step -> PigPaxos manifest commit -> restart.

    PYTHONPATH=src python examples/train_lm.py
"""
import subprocess
import sys

subprocess.run(
    [sys.executable, "-m", "repro.launch.train", "--arch", "h2o-danube-1.8b",
     "--smoke", "--steps", "40", "--batch", "8", "--seq", "64",
     "--ckpt-every", "20", "--ckpt-dir", "/tmp/repro_example_ckpt"],
    check=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
print("\n-- now resuming from the committed checkpoint --\n")
subprocess.run(
    [sys.executable, "-m", "repro.launch.train", "--arch", "h2o-danube-1.8b",
     "--smoke", "--steps", "60", "--batch", "8", "--seq", "64",
     "--ckpt-every", "20", "--ckpt-dir", "/tmp/repro_example_ckpt",
     "--resume"],
    check=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
