"""Geo-replication (Fig 10 / §6.2): 15 nodes across 3 regions; each region
is one relay group, so each write crosses the WAN once per region instead of
once per node — the WAN-cost argument of §6.2.

    PYTHONPATH=src python examples/geo_replication.py
"""
from repro.core import Cluster, PigConfig, wan_topology

topo = wan_topology([5, 5, 5], [[0.15, 31, 35],
                                [31, 0.15, 11],
                                [35, 11, 0.15]])
groups = [[1, 2, 3, 4], [5, 6, 7, 8, 9], [10, 11, 12, 13, 14]]

for label, proto, pig in (
        ("Paxos   ", "paxos", None),
        ("PigPaxos", "pigpaxos", PigConfig(n_groups=3, groups=groups, prc=1))):
    c = Cluster(proto, 15, pig=pig, seed=3, topo=topo, leader_timeout=400e-3)
    st = c.measure(duration=1.5, warmup=0.5, clients=60)
    # WAN messages: those between different regions
    import numpy as np
    m = st.flight
    region = lambda i: 0 if i < 5 else (1 if i < 10 else 2)
    wan = sum(m[i][j] for i in range(15) for j in range(15)
              if region(i) != region(j))
    print(f"{label}: {st.throughput:6.0f} req/s  median {st.median_ms:5.1f} ms  "
          f"WAN msgs/op {wan/max(st.committed,1):.2f}")
print("\npaper §6.2: R=#regions sends each payload across the WAN once per"
      "\nregion (2 msgs/op at 3 regions) vs Paxos' once per remote node.")
