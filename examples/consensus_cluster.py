"""Protocol shoot-out: Paxos vs EPaxos vs PigPaxos at N=25 (mini Fig 9).

    PYTHONPATH=src python examples/consensus_cluster.py
"""
from repro.core import Cluster, PigConfig

for label, proto, pig in (
        ("Multi-Paxos        ", "paxos", None),
        ("EPaxos (no conflicts)", "epaxos", None),
        ("PigPaxos R=3        ", "pigpaxos", PigConfig(n_groups=3, prc=1)),
        ("PigPaxos R=1        ", "pigpaxos",
         PigConfig(n_groups=1, single_group_majority=True))):
    c = Cluster(proto, 25, pig=pig, seed=2)
    st = c.measure(duration=0.5, warmup=0.25, clients=120)
    print(f"{label}: {st.throughput:7.0f} req/s  "
          f"median {st.median_ms:6.2f} ms  p99 {st.p99_ms:7.2f} ms")
print("\npaper: Paxos ~2k, EPaxos ~3k, PigPaxos >7k req/s (>3x)")
