"""Trace quickstart: where does a PigPaxos millisecond go?

1. run a traced 25-node cluster (every 10th client op gets a span tree);
2. print one op's span waterfall (client -> leader -> relay -> followers);
3. decompose commit latency into critical-path segments (the empirical
   counterpart of the paper's Eq. 1-3 bottleneck terms);
4. export a Perfetto JSON you can drop into https://ui.perfetto.dev.

    PYTHONPATH=src python examples/trace_quickstart.py
"""
from repro.core import Cluster, PigConfig
from repro.obs import SEGMENTS, critical_path, decompose, write_perfetto

print("=== traced 25-node PigPaxos (R=5, PRC) on the event simulator ===")
cluster = Cluster("pigpaxos", 25, pig=PigConfig(n_groups=5, prc=1), seed=2,
                  obs={"sample_rate": 0.1, "metrics_dt": 0.01})
stats = cluster.measure(duration=0.5, warmup=0.2, clients=40)
tracer = cluster.obs_tracer
print(f"  throughput: {stats.throughput:.0f} req/s, "
      f"median latency {stats.median_ms:.2f} ms")
s = tracer.summary()
print(f"  traced {s['ops_finished']} of {s['ops_seen']} ops "
      f"({s['spans']} spans)")

# -- one op's waterfall -----------------------------------------------
tid = tracer.finished[len(tracer.finished) // 2]
spans = tracer.trace_of(tid)
t0 = spans[0][4]
print(f"\n=== trace {tid}: one op, {len(spans)} spans, "
      f"{tracer.op_latency(tid) * 1e3:.2f} ms ===")
for sid, parent, cat, node, a, b in spans[:14]:
    off = (a - t0) * 1e3
    bar = " " * min(40, int(off * 8)) + "#" * max(1, int((b - a) * 1e3 * 8))
    print(f"  {cat:>5} node={node:<3} +{off:6.2f}ms "
          f"{(b - a) * 1e3:6.3f}ms |{bar}")
if len(spans) > 14:
    print(f"  ... {len(spans) - 14} more spans")

# -- critical-path attribution ----------------------------------------
segs = decompose(spans)
print("\n=== critical path: segments sum exactly to the op latency ===")
for cat in SEGMENTS:
    frac = segs[cat] / segs["total"] if segs["total"] else 0.0
    print(f"  {cat:>5}: {segs[cat] * 1e3:6.3f} ms  {'#' * int(frac * 40)}")
cp = critical_path(tracer)
worst = max(cp["mean_ms"], key=cp["mean_ms"].get)
print(f"  fleet mean over {cp['n_ops']} traced ops: bottleneck segment "
      f"is '{worst}' ({cp['mean_ms'][worst]:.2f} ms/op)")

# -- timelines + Perfetto export --------------------------------------
tl = stats.timelines["series"]
busiest = max((k for k in tl if k.startswith("busy_frac/")),
              key=lambda k: max(tl[k]["v"], default=0.0))
print(f"\n  hottest node: {busiest.split('/')[1]} "
      f"(peak busy {max(tl[busiest]['v']):.0%} of a sampling period)")
n = write_perfetto("trace_quickstart.json", tracer, limit=20_000)
print(f"  wrote {n} Perfetto events -> trace_quickstart.json "
      f"(open at https://ui.perfetto.dev)")
