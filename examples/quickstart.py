"""Quickstart: the Pig primitive end to end in 60 seconds.

1. analytical model (Table 1);  2. a live 9-node PigPaxos cluster on the
discrete-event simulator;  3. agreement check across replicas.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import Cluster, PigConfig, agreement_ok, analytical

print("=== Table 1 (N=25): message load per request ===")
for r in analytical.load_table(25):
    print(f"  R={r['R']:>2} ({r['label']:>8}): leader={r['M_l']:>4.0f} "
          f"follower={r['M_f']:.2f}  ratio={r['ratio']:.2f}")
print(f"  best R, rotating relays: {analytical.best_r_rotating(25)} (paper: 1)")
print(f"  best R, static relays:   {analytical.best_r_static(25)} (paper: ~sqrt(N))")

print("\n=== live 9-node PigPaxos (R=3) on the event simulator ===")
cluster = Cluster("pigpaxos", 9, pig=PigConfig(n_groups=3, prc=1), seed=1)
stats = cluster.measure(duration=0.5, warmup=0.2, clients=20)
print(f"  throughput: {stats.throughput:.0f} req/s, "
      f"median latency {stats.median_ms:.2f} ms")
print(f"  leader handles {stats.messages_per_op(0):.2f} msg/op "
      f"(analytical: {analytical.leader_messages(3):.0f})")

for node in cluster.nodes:
    if getattr(node, "is_leader", False):
        node.flush_commits()
cluster.run(cluster.sched.now + 0.3)
print(f"  all replicas agree on the log: {agreement_ok(cluster)}")
