"""Per-architecture smoke tests: reduced configs, one forward + one grad step
on CPU; asserts output shapes, finiteness, and param-count formula accuracy.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import (decode_step, forward, init_params, lm_loss,
                          make_cache, prefill)

B, S = 2, 32


def _batch(cfg, key):
    kt, kl = jax.random.split(key)
    if cfg.frontend:
        emb = jax.random.normal(kt, (B, S, cfg.d_model), jnp.bfloat16) * 0.1
        labels = jax.random.randint(kl, (B, S), 0, cfg.vocab)
        return {"embeds": emb, "labels": labels}
    toks = jax.random.randint(kt, (B, S), 0, cfg.vocab)
    labels = jax.random.randint(kl, (B, S), 0, cfg.vocab)
    return {"tokens": toks, "labels": labels}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits = forward(params, cfg, tokens=batch.get("tokens"),
                     embeds=batch.get("embeds"))
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_train_grad_step(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))

    loss, grads = jax.value_and_grad(lm_loss)(params, cfg, batch, remat=True)
    assert np.isfinite(float(loss))
    # rough sanity: CE near log(V) at init
    assert 0.1 * np.log(cfg.vocab) < float(loss) < 3.0 * np.log(cfg.vocab)
    leaves = jax.tree.leaves(grads)
    assert leaves, "no grads"
    for g in leaves:
        assert np.isfinite(np.asarray(g, np.float32)).all()
    # at least one nonzero gradient per top-level group
    total = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) for g in leaves)
    assert total > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_formula_matches_init(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    predicted = cfg.param_count()
    assert abs(actual - predicted) / actual < 0.02, (actual, predicted)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """prefill(t0..tn) + decode(t_{n+1}) must equal forward over the full
    sequence (teacher forcing) position-by-position."""
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    if cfg.frontend:
        pytest.skip("stub-frontend archs decode from tokens; covered below")
    T = 8
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0, cfg.vocab)
    full = forward(params, cfg, tokens=toks)                  # (B,T,V)
    cache = make_cache(cfg, B, max_len=T)
    last_logits, cache = prefill(params, cfg, tokens=toks[:, :T - 1],
                                 cache=cache)
    np.testing.assert_allclose(
        np.asarray(last_logits, np.float32),
        np.asarray(full[:, T - 2], np.float32), rtol=2e-2, atol=2e-2)
    pos = jnp.full((B,), T - 1, jnp.int32)
    step_logits, _ = decode_step(params, cfg, cache, toks[:, T - 1], pos)
    np.testing.assert_allclose(
        np.asarray(step_logits, np.float32),
        np.asarray(full[:, T - 1], np.float32), rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ["internvl2_76b", "musicgen_large"])
def test_frontend_stub_decode(arch):
    """VLM/audio: prefill from precomputed embeddings, decode from tokens."""
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    emb = jax.random.normal(jax.random.PRNGKey(3), (B, 6, cfg.d_model),
                            jnp.bfloat16) * 0.1
    cache = make_cache(cfg, B, max_len=16)
    logits, cache = prefill(params, cfg, embeds=emb, cache=cache)
    assert logits.shape == (B, cfg.vocab)
    tok = jnp.argmax(logits, -1)
    logits2, _ = decode_step(params, cfg, cache, tok,
                             jnp.full((B,), 6, jnp.int32))
    assert logits2.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


def test_sliding_window_masks_old_tokens():
    """Danube SWA: token beyond the window must not influence the output."""
    cfg = get_smoke_config("h2o_danube_1_8b").replace(sliding_window=4, n_layers=1)
    params = init_params(cfg, jax.random.PRNGKey(0))
    t1 = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab)
    t2 = t1.at[0, 0].set((t1[0, 0] + 7) % cfg.vocab)   # differs outside window
    f1 = forward(params, cfg, tokens=t1)
    f2 = forward(params, cfg, tokens=t2)
    np.testing.assert_allclose(np.asarray(f1[0, -1], np.float32),
                               np.asarray(f2[0, -1], np.float32),
                               rtol=1e-5, atol=1e-5)
    assert not np.allclose(np.asarray(f1[0, 1], np.float32),
                           np.asarray(f2[0, 1], np.float32), atol=1e-5)
