"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp oracle,
swept over shapes and dtypes, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is an optional dev dep (requirements-dev.txt): only the
# property tests skip without it, the deterministic sweeps always run
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:
    def _needs_hypothesis(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    given = settings = _needs_hypothesis

    class _St:
        def __getattr__(self, name):
            return lambda *a, **k: None
    st = _St()

from repro.kernels import ops, ref
from repro.kernels.pig_aggregate import quantize_blockwise


# ------------------------------------------------------------- flash attention
@pytest.mark.parametrize("B,S,Hq,Hkv,Dh", [
    (1, 128, 4, 4, 64),       # MHA, aligned
    (2, 256, 8, 2, 64),       # GQA 4:1
    (1, 200, 4, 1, 64),       # MQA, unaligned seq (padding path)
    (1, 128, 4, 4, 112),      # zamba2 head_dim 112 (pad to 128)
    (2, 96, 8, 8, 256),       # gemma head_dim 256
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_vs_ref(B, S, Hq, Hkv, Dh, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, Dh), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, Dh), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, Dh), dtype)
    got = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    qb, kb, vb = (a.transpose(0, 2, 1, 3) for a in (q, k, v))
    want = ref.flash_attention_ref(qb, kb, vb, causal=True).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_matches_model_attention():
    """flash path == attention_ref used inside the models (causal, GQA)."""
    from repro.models.layers import attention_ref
    B, S, Hq, Hkv, Dh = 2, 128, 8, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, Dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, Dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, Dh), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    want = attention_ref(q, k, v, pos, pos)
    got = ops.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------------- ssm scan
@pytest.mark.parametrize("B,T,H,Dk,Dv,chunk", [
    (1, 128, 2, 64, 64, 32),
    (2, 96, 4, 64, 64, 32),     # pad path (96 % 32 == 0, but use 64 below)
    (1, 100, 1, 32, 64, 32),    # unaligned T
    (2, 64, 2, 16, 64, 16),     # rwkv-style chunk 16
])
@pytest.mark.parametrize("scalar_decay", [True, False])
def test_ssm_scan_vs_ref(B, T, H, Dk, Dv, chunk, scalar_decay):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    q = jax.random.normal(ks[0], (B, T, H, Dk), jnp.float32) * 0.3
    k = jax.random.normal(ks[1], (B, T, H, Dk), jnp.float32) * 0.3
    v = jax.random.normal(ks[2], (B, T, H, Dv), jnp.float32) * 0.3
    la = -jnp.abs(jax.random.normal(ks[3], (B, T, H, Dk))) * 0.5 - 0.01
    if scalar_decay:
        la = jnp.broadcast_to(la[..., :1], la.shape)
    got = ops.ssm_scan(q, k, v, la, chunk=chunk)
    fold = lambda a: a.transpose(0, 2, 1, 3).reshape(B * H, T, a.shape[-1])
    want = ref.ssm_scan_ref(fold(q), fold(k), fold(v), fold(la), chunk=chunk)
    want = want.reshape(B, H, T, Dv).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_ssm_scan_bonus_rwkv_mode():
    B, T, H, D = 1, 64, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    q = jax.random.normal(ks[0], (B, T, H, D)) * 0.3
    k = jax.random.normal(ks[1], (B, T, H, D)) * 0.3
    v = jax.random.normal(ks[2], (B, T, H, D)) * 0.3
    la = -jnp.abs(jax.random.normal(ks[3], (B, T, H, D))) * 0.5 - 0.01
    u = jax.random.normal(ks[4], (H, D)) * 0.1
    got = ops.ssm_scan(q, k, v, la, u=u, chunk=16)
    fold = lambda a: a.transpose(0, 2, 1, 3).reshape(B * H, T, a.shape[-1])
    want = ref.ssm_scan_ref(fold(q), fold(k), fold(v), fold(la),
                            u=jnp.tile(u, (B, 1)), chunk=16)
    want = want.reshape(B, H, T, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_ssm_scan_equals_sequential_recurrence():
    """Chunked kernel == naive sequential recurrence (independent oracle)."""
    B, T, H, D = 1, 48, 1, 8
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    q = jax.random.normal(ks[0], (B, T, H, D)) * 0.5
    k = jax.random.normal(ks[1], (B, T, H, D)) * 0.5
    v = jax.random.normal(ks[2], (B, T, H, D)) * 0.5
    la = -jnp.abs(jax.random.normal(ks[3], (B, T, H, D))) * 0.3 - 0.01
    got = np.asarray(ops.ssm_scan(q, k, v, la, chunk=16))
    S = np.zeros((D, D))
    qn, kn, vn, ln = (np.asarray(a[0, :, 0], np.float64) for a in (q, k, v, la))
    for t in range(T):
        S = S * np.exp(ln[t])[:, None] + np.outer(kn[t], vn[t])
        np.testing.assert_allclose(got[0, t, 0], qn[t] @ S, rtol=1e-3, atol=1e-3)


# -------------------------------------------------------------- pig aggregate
@pytest.mark.parametrize("G,N,block", [(2, 2048, 1024), (5, 8192, 512),
                                       (16, 4096, 256)])
def test_pig_aggregate_vs_ref(G, N, block):
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (G, N), jnp.float32)
    qs, ss = [], []
    for g in range(G):
        q, s = quantize_blockwise(x[g], block)
        qs.append(q)
        ss.append(s)
    shards = jnp.stack(qs)
    scales = jnp.stack(ss)
    got = ops.pig_aggregate(shards, scales, block=block)
    want = ref.pig_aggregate_ref(shards, scales, block=block)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    # dequantized sum approximates the true sum to int8 precision
    true = np.asarray(x.sum(0))
    err = np.abs(np.asarray(got) - true).max()
    amax = np.abs(np.asarray(x)).max()
    assert err <= G * amax / 127.0 * 0.6


# ---------------------------------------------------------- seg fan-in
def _fanin_case(key, B, G, gsize, mask_per_seg=0):
    """A vectorsim-shaped burst: F = G*gsize contiguous slots, segment-
    constant coef/kcap, optionally one +inf-masked slot per segment."""
    F = G * gsize
    ks = jax.random.split(key, 4)
    vals = jax.random.uniform(ks[0], (B, F), jnp.float32, 1.0, 2.0)
    segid = jnp.repeat(jnp.arange(G), gsize)
    coef = jnp.repeat(jax.random.uniform(ks[1], (B, G), jnp.float32,
                                         0.0, 1e-3), gsize, axis=1)
    kcap = jnp.repeat(
        jax.random.randint(ks[2], (G,), 0, gsize - mask_per_seg),
        gsize).astype(jnp.float32)
    if mask_per_seg:
        drop = jax.random.randint(ks[3], (G,), 0, gsize)
        vals = vals.at[:, drop + jnp.arange(G) * gsize].set(jnp.inf)
    anchor = jnp.full((B,), 1.0, jnp.float32)
    return (vals, coef, segid, kcap, -0.5, 3e-4, 2e-5, anchor)


@pytest.mark.parametrize("B,G,gsize", [
    (1, 1, 4),        # single segment
    (8, 4, 6),        # the production shape (N=25, R=4)
    (8, 8, 16),       # wide, pads 128 -> 128 exactly
    (3, 5, 7),        # odd everything (padding path, 35 -> 128)
])
@pytest.mark.parametrize("mask", [0, 1])
def test_seg_fanin_vs_ref(B, G, gsize, mask):
    args = _fanin_case(jax.random.PRNGKey(B * 100 + G * 10 + gsize),
                       B, G, gsize, mask_per_seg=mask)
    got = np.asarray(ops.seg_fanin(*args))
    want = np.asarray(ref.seg_fanin_ref(*args))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_seg_fanin_ties_match_stable_sort():
    """Duplicate values: the kernel's (value, index) tie-break must equal
    lax.sort's stable order, so rank-dependent outputs agree exactly."""
    B, G, gsize = 4, 3, 5
    vals = jnp.tile(jnp.array([1.5, 1.25, 1.5, 1.25, 1.5], jnp.float32),
                    (B, G))
    segid = jnp.repeat(jnp.arange(G), gsize)
    coef = jnp.zeros((B, G * gsize), jnp.float32)
    kcap = jnp.full((G * gsize,), 2.0, jnp.float32)
    anchor = jnp.ones((B,), jnp.float32)
    args = (vals, coef, segid, kcap, -0.5, 3e-4, 2e-5, anchor)
    np.testing.assert_array_equal(np.asarray(ops.seg_fanin(*args)),
                                  np.asarray(ref.seg_fanin_ref(*args)))


def test_seg_fanin_empty_admissible_set_is_neg_inf():
    """A fully-masked segment (all followers down) yields -inf, never NaN
    (the vcoef * inf hazard the kernel's precondition rules out)."""
    B, F = 2, 6
    vals = jnp.where(jnp.arange(F)[None, :] < 3, jnp.inf,
                     jnp.ones((B, F), jnp.float32))
    segid = jnp.repeat(jnp.arange(2), 3)
    coef = jnp.zeros((B, F), jnp.float32)
    kcap = jnp.ones((F,), jnp.float32)
    out = np.asarray(ops.seg_fanin(vals, coef, segid, kcap, -0.5, 0.0,
                                   1e-5, jnp.ones((B,), jnp.float32)))
    assert np.all(np.isneginf(out[:, :3]))
    assert np.all(np.isfinite(out[:, 3:]))


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 4), st.lists(st.integers(2, 9), min_size=1,
                                   max_size=5), st.integers(0, 10 ** 6))
def test_seg_fanin_property(B, sizes, salt):
    """Random ragged segment layouts: kernel == lax oracle bit for bit
    (both paths are f32 with the same operation order per slot)."""
    ks = jax.random.split(jax.random.PRNGKey(salt), 3)
    F = sum(sizes)
    segid = jnp.asarray(np.repeat(np.arange(len(sizes)), sizes))
    vals = jax.random.uniform(ks[0], (B, F), jnp.float32, 0.5, 1.5)
    coef = jnp.asarray(np.repeat(
        np.asarray(jax.random.uniform(ks[1], (B, len(sizes)), jnp.float32,
                                      0.0, 1e-3)), sizes, axis=1))
    kcap = jnp.asarray(np.repeat(
        np.asarray(jax.random.randint(ks[2], (len(sizes),), 0, 3)),
        sizes)).astype(jnp.float32)
    kcap = jnp.minimum(kcap, jnp.asarray(np.repeat(sizes, sizes) - 1,
                                         jnp.float32))
    args = (vals, coef, segid, kcap, -0.3, 1e-4, 3e-5,
            jnp.full((B,), 0.5, jnp.float32))
    got = np.asarray(ops.seg_fanin(*args))
    want = np.asarray(ref.seg_fanin_ref(*args))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 8), st.integers(1, 6))
def test_pig_aggregate_property(G, nb):
    """Quantize->aggregate error is bounded by the per-block quant step."""
    block = 256
    N = nb * block
    x = jax.random.normal(jax.random.PRNGKey(G * 31 + nb), (G, N), jnp.float32)
    shards, scales = [], []
    for g in range(G):
        q, s = quantize_blockwise(x[g], block)
        shards.append(q)
        scales.append(s)
    got = np.asarray(ops.pig_aggregate(jnp.stack(shards), jnp.stack(scales),
                                       block=block))
    true = np.asarray(x.sum(0))
    step = np.asarray(jnp.stack(scales)).max()
    assert np.abs(got - true).max() <= G * step * 0.51 + 1e-6
