"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp oracle,
swept over shapes and dtypes, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")   # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops, ref
from repro.kernels.pig_aggregate import quantize_blockwise


# ------------------------------------------------------------- flash attention
@pytest.mark.parametrize("B,S,Hq,Hkv,Dh", [
    (1, 128, 4, 4, 64),       # MHA, aligned
    (2, 256, 8, 2, 64),       # GQA 4:1
    (1, 200, 4, 1, 64),       # MQA, unaligned seq (padding path)
    (1, 128, 4, 4, 112),      # zamba2 head_dim 112 (pad to 128)
    (2, 96, 8, 8, 256),       # gemma head_dim 256
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_vs_ref(B, S, Hq, Hkv, Dh, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, Dh), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, Dh), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, Dh), dtype)
    got = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    qb, kb, vb = (a.transpose(0, 2, 1, 3) for a in (q, k, v))
    want = ref.flash_attention_ref(qb, kb, vb, causal=True).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_matches_model_attention():
    """flash path == attention_ref used inside the models (causal, GQA)."""
    from repro.models.layers import attention_ref
    B, S, Hq, Hkv, Dh = 2, 128, 8, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, Dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, Dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, Dh), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    want = attention_ref(q, k, v, pos, pos)
    got = ops.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------------- ssm scan
@pytest.mark.parametrize("B,T,H,Dk,Dv,chunk", [
    (1, 128, 2, 64, 64, 32),
    (2, 96, 4, 64, 64, 32),     # pad path (96 % 32 == 0, but use 64 below)
    (1, 100, 1, 32, 64, 32),    # unaligned T
    (2, 64, 2, 16, 64, 16),     # rwkv-style chunk 16
])
@pytest.mark.parametrize("scalar_decay", [True, False])
def test_ssm_scan_vs_ref(B, T, H, Dk, Dv, chunk, scalar_decay):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    q = jax.random.normal(ks[0], (B, T, H, Dk), jnp.float32) * 0.3
    k = jax.random.normal(ks[1], (B, T, H, Dk), jnp.float32) * 0.3
    v = jax.random.normal(ks[2], (B, T, H, Dv), jnp.float32) * 0.3
    la = -jnp.abs(jax.random.normal(ks[3], (B, T, H, Dk))) * 0.5 - 0.01
    if scalar_decay:
        la = jnp.broadcast_to(la[..., :1], la.shape)
    got = ops.ssm_scan(q, k, v, la, chunk=chunk)
    fold = lambda a: a.transpose(0, 2, 1, 3).reshape(B * H, T, a.shape[-1])
    want = ref.ssm_scan_ref(fold(q), fold(k), fold(v), fold(la), chunk=chunk)
    want = want.reshape(B, H, T, Dv).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_ssm_scan_bonus_rwkv_mode():
    B, T, H, D = 1, 64, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    q = jax.random.normal(ks[0], (B, T, H, D)) * 0.3
    k = jax.random.normal(ks[1], (B, T, H, D)) * 0.3
    v = jax.random.normal(ks[2], (B, T, H, D)) * 0.3
    la = -jnp.abs(jax.random.normal(ks[3], (B, T, H, D))) * 0.5 - 0.01
    u = jax.random.normal(ks[4], (H, D)) * 0.1
    got = ops.ssm_scan(q, k, v, la, u=u, chunk=16)
    fold = lambda a: a.transpose(0, 2, 1, 3).reshape(B * H, T, a.shape[-1])
    want = ref.ssm_scan_ref(fold(q), fold(k), fold(v), fold(la),
                            u=jnp.tile(u, (B, 1)), chunk=16)
    want = want.reshape(B, H, T, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_ssm_scan_equals_sequential_recurrence():
    """Chunked kernel == naive sequential recurrence (independent oracle)."""
    B, T, H, D = 1, 48, 1, 8
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    q = jax.random.normal(ks[0], (B, T, H, D)) * 0.5
    k = jax.random.normal(ks[1], (B, T, H, D)) * 0.5
    v = jax.random.normal(ks[2], (B, T, H, D)) * 0.5
    la = -jnp.abs(jax.random.normal(ks[3], (B, T, H, D))) * 0.3 - 0.01
    got = np.asarray(ops.ssm_scan(q, k, v, la, chunk=16))
    S = np.zeros((D, D))
    qn, kn, vn, ln = (np.asarray(a[0, :, 0], np.float64) for a in (q, k, v, la))
    for t in range(T):
        S = S * np.exp(ln[t])[:, None] + np.outer(kn[t], vn[t])
        np.testing.assert_allclose(got[0, t, 0], qn[t] @ S, rtol=1e-3, atol=1e-3)


# -------------------------------------------------------------- pig aggregate
@pytest.mark.parametrize("G,N,block", [(2, 2048, 1024), (5, 8192, 512),
                                       (16, 4096, 256)])
def test_pig_aggregate_vs_ref(G, N, block):
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (G, N), jnp.float32)
    qs, ss = [], []
    for g in range(G):
        q, s = quantize_blockwise(x[g], block)
        qs.append(q)
        ss.append(s)
    shards = jnp.stack(qs)
    scales = jnp.stack(ss)
    got = ops.pig_aggregate(shards, scales, block=block)
    want = ref.pig_aggregate_ref(shards, scales, block=block)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    # dequantized sum approximates the true sum to int8 precision
    true = np.asarray(x.sum(0))
    err = np.abs(np.asarray(got) - true).max()
    amax = np.abs(np.asarray(x)).max()
    assert err <= G * amax / 127.0 * 0.6


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 8), st.integers(1, 6))
def test_pig_aggregate_property(G, nb):
    """Quantize->aggregate error is bounded by the per-block quant step."""
    block = 256
    N = nb * block
    x = jax.random.normal(jax.random.PRNGKey(G * 31 + nb), (G, N), jnp.float32)
    shards, scales = [], []
    for g in range(G):
        q, s = quantize_blockwise(x[g], block)
        shards.append(q)
        scales.append(s)
    got = np.asarray(ops.pig_aggregate(jnp.stack(shards), jnp.stack(scales),
                                       block=block))
    true = np.asarray(x.sum(0))
    step = np.asarray(jnp.stack(scales)).max()
    assert np.abs(got - true).max() <= G * step * 0.51 + 1e-6
