"""Experiment-subsystem tests: registry integrity, scenario resolution,
runner artifact schema (serial and process-parallel), grid policies, and the
legacy-row report layer."""
import json

import pytest

from repro import experiments
from repro.experiments import report, runner
from repro.experiments.scenario import Scenario, build_topology


# ------------------------------------------------------------------ registry
def test_catalog_covers_all_paper_reproductions():
    fams = set(experiments.families())
    assert {"table1", "table2", "fig8", "fig9", "fig10", "fig11", "fig12",
            "fig13", "fig14", "fig15", "fig16", "fig17"} <= fams
    # the post-paper data-only families
    assert {"zipf", "openloop", "conflict"} <= fams
    # the fault-injection families (ISSUE 4)
    assert {"avail", "storm"} <= fams
    # the membership-change families (PR 6)
    assert {"reconfig", "rolling", "failover"} <= fams


def test_every_family_has_a_summarizer():
    for fam in experiments.families():
        assert fam in report.SUMMARIZERS, fam


def test_registry_names_unique_and_specs_serializable():
    names = experiments.names()
    assert len(names) == len(set(names))
    for name in names:
        spec = experiments.get(name).spec_dict()
        json.dumps(spec)   # must be JSON-clean
        assert spec["name"] == name


def test_register_rejects_duplicates():
    sc = experiments.get("fig11/paxos")
    with pytest.raises(ValueError):
        experiments.register(sc)


def test_select_filter_semantics():
    assert [s.name for s in experiments.select("fig11/pig_*")] == \
        ["fig11/pig_R1", "fig11/pig_R2"]
    # a bare family name matches the whole family
    assert {s.family for s in experiments.select("fig16")} == {"fig16"}
    # comma-separated globs union
    got = {s.name for s in experiments.select("fig16,fig11/paxos")}
    assert got == {"fig16/group_failure", "fig11/paxos"}
    # families_subset restricts
    assert all(s.family == "fig9"
               for s in experiments.select(None, families_subset=["fig9"]))
    # a pattern matching nothing must fail loudly (CI smoke protection),
    # naming the dead pattern
    with pytest.raises(ValueError, match="fig11/renamed"):
        experiments.select("fig16,fig11/renamed")


def test_quick_resolution_and_skip():
    sc = experiments.get("fig8/rotating/R=1")
    rq = sc.resolve(quick=True)
    rf = sc.resolve(quick=False)
    assert rq.clients == sc.quick_clients
    assert rf.clients == sc.clients
    assert rq.duration < rf.duration
    assert experiments.get("fig8/rotating/R=8").quick_skip
    skipped = runner.run_scenarios([experiments.get("fig8/rotating/R=8")],
                                   quick=True)
    assert skipped["scenarios"] == []
    # ...but an explicit --filter selection overrides quick_skip: an
    # explicitly requested scenario must never be a silent green no-op
    forced = runner.run_families(["fig8"], quick=True,
                                 filter_expr="fig8/rotating/R=8")
    assert [s["name"] for s in forced["scenarios"]] == ["fig8/rotating/R=8"]
    assert forced["scenarios"][0]["units"]


def test_wan_topology_spec_builds():
    sc = experiments.get("fig10/pigpaxos")
    topo = sc.build_topology()
    assert topo.n == 15
    assert build_topology(None) is None
    with pytest.raises(ValueError):
        build_topology({"kind": "ring"})


# ------------------------------------------------------------------- runner
_TINY = Scenario(name="t/max", protocol="pigpaxos", n=5, clients=(4, 8),
                 seeds=(1, 2), duration=0.15, warmup=0.05)
_TINY_CURVE = Scenario(name="t/curve", protocol="paxos", n=3,
                       grid_mode="curve", clients=(3, 6), seeds=(1,),
                       duration=0.15, warmup=0.05)


def test_runner_artifact_schema_and_replicates():
    art = runner.run_scenarios([_TINY, _TINY_CURVE], quick=False)
    assert art["schema"] == runner.ARTIFACT_SCHEMA
    json.dumps(art)
    by_name = {s["name"]: s for s in art["scenarios"]}
    tm = by_name["t/max"]
    # 2 clients x 2 seeds = 4 units; max grid policy -> 1 replicate per seed
    assert len(tm["units"]) == 4
    assert len(tm["replicates"]) == 2
    assert {u["seed"] for u in tm["replicates"]} == {1, 2}
    for rep in tm["replicates"]:
        per_seed = [u for u in tm["units"] if u["seed"] == rep["seed"]]
        assert rep["throughput"] == max(u["throughput"] for u in per_seed)
    s = tm["summary"]["throughput"]
    assert s["n"] == 2 and s["min"] <= s["mean"] <= s["max"]
    # curve mode: per-grid-point aggregates
    tc = by_name["t/curve"]
    assert [p["clients"] for p in tc["points"]] == [3, 6]
    assert len(tc["replicates"]) == len(tc["units"]) == 2


def test_runner_parallel_matches_serial():
    """The DES is deterministic per (scenario, clients, seed) unit, so a
    process pool must produce identical measurements to the inline path."""
    serial = runner.run_scenarios([_TINY], quick=False, processes=0)
    par = runner.run_scenarios([_TINY], quick=False, processes=2)
    strip = lambda art: [
        {k: v for k, v in u.items() if k != "wall_s"}
        for s in art["scenarios"] for u in s["units"]]
    assert strip(serial) == strip(par)
    assert par["processes"] == 2


def test_runner_failure_schedule_applied():
    sc = Scenario(name="t/crash", protocol="pigpaxos", n=5,
                  failures=(("crash", 3, 0.05),),
                  clients=(4,), seeds=(1,), duration=0.2, warmup=0.05)
    art = runner.run_scenarios([sc], quick=False)
    rep = art["scenarios"][0]["replicates"][0]
    assert rep["committed"] > 0   # cluster survives the crash


def test_runner_collect_extras():
    sc = Scenario(name="t/extras", protocol="pigpaxos", n=5,
                  clients=(4,), seeds=(1,), duration=0.2, warmup=0.05,
                  collect=("per_node_msgs", "flight", "timeline"))
    art = runner.run_scenarios([sc], quick=False)
    ex = art["scenarios"][0]["units"][0]["extras"]
    assert ex["leader_msgs_per_op"] > 0
    assert len(ex["flight_per_op"]) == 5
    assert sum(ex["timeline"]["counts"]) > 0


# ------------------------------------------------------------------- report
def test_report_rows_preserve_legacy_contract():
    art = runner.run_scenarios(
        [experiments.get("fig11/paxos"), experiments.get("fig11/epaxos"),
         experiments.get("fig11/pig_R1"), experiments.get("fig11/pig_R2")],
        quick=True)
    rows = report.rows_for_artifact(art)
    names = [r.split(",", 1)[0] for r in rows]
    assert names == ["fig11/paxos", "fig11/epaxos", "fig11/pig_R1",
                     "fig11/pig_R2", "fig11/summary"]
    for r in rows:
        name, us, derived = r.split(",", 2)
        float(us)
        assert derived


def test_report_degrades_gracefully_under_filter():
    """A partial family (as produced by --filter) emits rows for what ran
    and skips cross-scenario summary rows."""
    art = runner.run_scenarios([experiments.get("fig11/paxos")], quick=True)
    rows = report.rows_for_artifact(art)
    assert [r.split(",", 1)[0] for r in rows] == ["fig11/paxos"]


def test_family_rows_end_to_end():
    rows = report.family_rows(["fig16"], quick=True)
    assert rows and rows[0].startswith("fig16/group_failure,")
    assert "drop=" in rows[0]
