"""Observability invariants (ISSUE 9): tracing is a pure observer.

Four contracts pin the obs layer to the engines:

1. **Bit-identity** — a run with tracing enabled (any sample rate,
   ``metrics_dt=0``) is indistinguishable from an untraced run: same
   event count, same tie-break sequence, same applied logs, same stats.
   This is the golden-trace guarantee extended to the obs hooks.
2. **Span-tree well-formedness** — every finished trace is a single
   rooted tree: exactly one root, every parent id resolves to an earlier
   span, every closed span has ``t1 >= t0`` monotone timestamps.
3. **Critical-path sum** — ``decompose`` partitions the op window
   exactly, so the segment seconds sum to the measured op latency
   (the empirical counterpart of the paper's Eq. 1-3 decomposition).
4. **Relay fairness** — the attribution machinery reproduces Fig 8's
   hotspot claim: rotating relays flatten per-follower CPU busy time
   relative to a static relay assignment.

Plus the latency-driven admission policy (PR 8 ROADMAP remainder), the
scenario-registry validation rules for obs knobs, and the Stats/warmup
timeline plumbing.
"""
import json

import numpy as np
import pytest

from repro.core import Cluster, PigConfig
from repro.obs import (ObsConfig, SEGMENTS, critical_path, decompose,
                       write_perfetto)
from repro.runtime.policy import (LatencyAdmissionPolicy,
                                  attach_latency_admission)


def _applied(cluster):
    return [[(slot, c.client_id, c.seq, c.op, c.key) for slot, c in nd.applied_log]
            for nd in cluster.nodes]


def _run(proto, pig, engine, seed=7, obs=None):
    c = Cluster(proto, 5, pig=pig, seed=seed, engine=engine, obs=obs)
    st = c.measure(duration=0.3, warmup=0.1, clients=8)
    return c, st


def _fingerprint(c, st):
    return (c.sched.events, c.sched._seq, c.sched.now, _applied(c),
            st.committed, st.throughput, st.median_ms)


CONFIGS = [
    ("paxos", None),
    ("pigpaxos", PigConfig(n_groups=2)),
    ("epaxos", None),
]
IDS = ["paxos", "pigpaxos", "epaxos"]


# ---------------------------------------------------------------- identity

@pytest.mark.parametrize("proto,pig", CONFIGS, ids=IDS)
@pytest.mark.parametrize("engine", ["exact", "fast"])
def test_tracing_is_bit_identical(proto, pig, engine):
    """Full-rate tracing, sparse sampling, sample_rate=0 (hooks installed,
    nothing sampled) and no obs at all must produce the same execution."""
    base_c, base_st = _run(proto, pig, engine)
    base = _fingerprint(base_c, base_st)
    for obs in ({"sample_rate": 1.0}, {"sample_rate": 0.1},
                {"sample_rate": 0.0}):
        c, st = _run(proto, pig, engine, obs=obs)
        assert _fingerprint(c, st) == base, f"obs={obs} perturbed the run"
        np.testing.assert_array_equal(base_st.msg_out, st.msg_out)
        np.testing.assert_array_equal(base_st.msg_in, st.msg_in)


@pytest.mark.parametrize("proto,pig", CONFIGS, ids=IDS)
def test_traced_exact_matches_seed_stack(proto, pig):
    """The golden-trace bar itself: traced exact engine vs the verbatim
    seed stack (which has no obs hooks at all)."""
    ref_c, ref_st = _run(proto, pig, "ref")
    new_c, new_st = _run(proto, pig, "exact", obs={"sample_rate": 1.0})
    assert _fingerprint(new_c, new_st) == _fingerprint(ref_c, ref_st)


# ------------------------------------------------------------- span trees

def _traced_cluster(proto="pigpaxos", pig=PigConfig(n_groups=2), **kw):
    c, st = _run(proto, pig, "exact", obs={"sample_rate": 1.0}, **kw)
    tr = c.obs_tracer
    assert tr is not None and tr.finished, "no finished traces collected"
    return c, st, tr


@pytest.mark.parametrize("proto,pig", CONFIGS, ids=IDS)
def test_span_trees_well_formed(proto, pig):
    _, _, tr = _traced_cluster(proto, pig)
    for tid in tr.finished:
        spans = tr.trace_of(tid)
        roots = [sp for sp in spans if sp[1] == -1]
        assert len(roots) == 1 and roots[0] is spans[0], \
            f"trace {tid}: expected exactly one root, first"
        assert spans[0][2] == "op" and spans[0][5] is not None
        for sp in spans:
            sid, parent, cat, node, t0, t1 = sp
            assert sid == spans.index(sp)          # ids are positional
            if parent != -1:
                assert 0 <= parent < sid, \
                    f"trace {tid}: span {sid} orphaned (parent {parent})"
            assert t1 is not None and t1 >= t0, \
                f"trace {tid}: span {sid} not monotone ({t0} .. {t1})"


def test_sampling_is_every_kth_op():
    c, _, tr = _traced_cluster()
    assert tr.sample_every == 1
    assert tr.n_ops == tr._next_tid        # rate 1.0: every op traced
    c2, _ = _run("pigpaxos", PigConfig(n_groups=2), "exact",
                 obs={"sample_rate": 0.1})
    tr2 = c2.obs_tracer
    assert tr2.sample_every == 10
    assert tr2._next_tid == tr2.n_ops // 10
    c0, st0 = _run("pigpaxos", PigConfig(n_groups=2), "exact",
                   obs={"sample_rate": 0.0})
    assert c0.obs_tracer._next_tid == 0    # installed, samples nothing
    assert st0.committed > 0


def test_hop_table_drains():
    """The per-destination hop table is popped at each K_HANDLE — after a
    run it must not have accumulated entries (no leak, no purge pass)."""
    _, _, tr = _traced_cluster()
    assert len(tr._hop) == 0


# ---------------------------------------------------------- critical path

@pytest.mark.parametrize("proto,pig", CONFIGS, ids=IDS)
def test_critical_path_segments_sum_to_latency(proto, pig):
    _, _, tr = _traced_cluster(proto, pig)
    for tid in tr.finished:
        segs = decompose(tr.trace_of(tid))
        total = sum(segs[s] for s in SEGMENTS)
        lat = tr.op_latency(tid)
        assert segs["total"] == pytest.approx(lat, abs=1e-12)
        assert total == pytest.approx(lat, abs=1e-9), \
            f"trace {tid}: segments sum {total} != latency {lat}"


def test_critical_path_aggregate():
    _, _, tr = _traced_cluster()
    cp = critical_path(tr)
    assert cp["n_ops"] == len(tr.finished)
    assert set(cp["mean_ms"]) == set(SEGMENTS)
    mean_total = sum(cp["mean_ms"].values())
    lats = [tr.op_latency(t) * 1e3 for t in tr.finished]
    assert mean_total == pytest.approx(np.mean(lats), rel=1e-9)
    # a replicated commit spends *some* time on the wire and in service
    assert cp["mean_ms"]["net"] > 0.0
    assert cp["mean_ms"]["svc"] > 0.0


def test_decompose_refuses_unfinished():
    with pytest.raises(ValueError):
        decompose([[0, -1, "op", 0, 0.0, None]])


# ----------------------------------------------------------- relay fairness

def test_rotating_relays_flatten_follower_load():
    """Fig 8 claim, reproduced from the obs CPU attribution: with static
    relays the relay nodes are hotspots (high max/mean follower busy);
    rotation spreads the relay work evenly."""
    ratio = {}
    for rotate in (True, False):
        c = Cluster("pigpaxos", 25,
                    pig=PigConfig(n_groups=5, rotate_relays=rotate),
                    seed=2, engine="fast")
        st = c.measure(duration=0.4, warmup=0.1, clients=40)
        followers = [st.cpu_busy[i] for i in range(25) if i != c.leader_id]
        ratio[rotate] = max(followers) / np.mean(followers)
    assert ratio[True] < ratio[False], \
        f"rotating max/mean {ratio[True]:.2f} !< static {ratio[False]:.2f}"
    assert ratio[True] < 1.5          # rotation keeps followers near-uniform


# ------------------------------------------------- latency-driven admission

def test_latency_admission_policy_validation():
    for bad in ({"slo_ms": 0.0}, {"slo_ms": -1.0}, {"ewma_alpha": 0.0},
                {"ewma_alpha": 1.5}, {"check_interval": 0.0},
                {"resume_frac": 0.0}, {"resume_frac": 1.2}):
        with pytest.raises(ValueError):
            LatencyAdmissionPolicy(**bad)


def test_latency_admission_sheds_on_slo_breach():
    """An unattainably tight SLO must trip the breaker; a generous one
    must never shed."""
    def run(slo_ms):
        c = Cluster("paxos", 5, seed=2, engine="exact")
        stats = attach_latency_admission(
            c, LatencyAdmissionPolicy(slo_ms=slo_ms, check_interval=0.005),
            stop_at=0.4)
        c.measure(duration=0.3, warmup=0.1, clients=16)
        return stats

    tight = run(slo_ms=0.5)           # commit latency is a few ms
    assert tight["shed_latency"] > 0
    assert tight["p99_ewma_ms"] > 0.5
    loose = run(slo_ms=10_000.0)
    assert loose["shed_latency"] == 0
    assert loose["admitted"] > 0


def test_latency_admission_records_timelines():
    c = Cluster("paxos", 5, seed=2, engine="exact",
                obs={"sample_rate": 0.0, "metrics_dt": 0.01})
    attach_latency_admission(
        c, LatencyAdmissionPolicy(slo_ms=0.5, check_interval=0.005),
        stop_at=0.4)
    st = c.measure(duration=0.3, warmup=0.1, clients=16)
    series = st.timelines["series"]
    assert "adm_p99_ewma_ms" in series and "adm_shedding" in series
    assert max(series["adm_shedding"]["v"]) == 1.0


# ----------------------------------------------------- scenario validation

def test_scenario_rejects_obs_on_ref_engine():
    from repro.experiments.scenario import Scenario
    with pytest.raises(ValueError, match="observability"):
        Scenario(name="x/ref", protocol="paxos", n=5, engine="ref",
                 obs={"sample_rate": 1.0})


def test_scenario_rejects_obs_on_batch_epaxos():
    from repro.experiments.scenario import Scenario
    with pytest.raises(ValueError, match="group-kernel"):
        Scenario(name="x/be", protocol="epaxos", n=5, backend="batch",
                 obs={"sample_rate": 0.0, "metrics_dt": 0.01},
                 clients=(60,))


def test_scenario_validates_obs_knobs():
    from repro.experiments.scenario import Scenario
    with pytest.raises(ValueError, match="sample_rate"):
        Scenario(name="x/knob", protocol="paxos", n=5,
                 obs={"sample_rate": 2.0})
    with pytest.raises(ValueError):
        ObsConfig(metrics_dt=-0.1)


# -------------------------------------------------- timelines & stats plumb

def test_stats_carries_timelines_and_warmup_reset():
    warmup = 0.2
    c = Cluster("pigpaxos", 5, pig=PigConfig(n_groups=2), seed=2,
                engine="exact", obs={"sample_rate": 0.0, "metrics_dt": 0.02})
    st = c.measure(duration=0.4, warmup=warmup, clients=8)
    tl = st.timelines
    assert tl is not None
    series = tl["series"]
    for name in ("busy_frac/0", "leader_qdepth", "inflight_slots",
                 "commit_ewma_ms"):
        assert name in series, f"missing timeline {name}"
    # Network.reset_stats resets the ring buffers at the warmup boundary:
    # every surviving sample is post-warmup
    for name, s in series.items():
        assert all(t >= warmup for t in s["t"]), \
            f"{name} retained warmup samples: {s['t'][:3]}"
    # the latency gauge was reset with the rings: it only counts
    # post-warmup commits (>= because it keeps counting during drain)
    assert tl["latency"]["count"] >= st.count > 0


def test_stats_timelines_none_without_obs():
    _, st = _run("paxos", None, "exact")
    assert st.timelines is None


# ---------------------------------------------------------------- exporters

def test_perfetto_export(tmp_path):
    _, _, tr = _traced_cluster()
    path = tmp_path / "trace.json"
    n = write_perfetto(str(path), tr)
    assert n > 0
    evs = json.loads(path.read_text())["traceEvents"]
    assert len(evs) == n
    for ev in evs[:50]:
        assert ev["ph"] == "X" and ev["dur"] >= 0
        assert {"name", "ts", "pid", "tid"} <= set(ev)


def test_obs_artifact_section():
    from repro.obs import obs_artifact_section
    c, _, _ = _traced_cluster()
    sec = obs_artifact_section(c)
    assert sec["trace"]["ops_finished"] > 0
    assert set(sec["critical_path"]["mean_ms"]) == set(SEGMENTS)
    assert sec["perfetto"]["events"]
    assert sec["cpu_busy_s"]["0"] > 0
