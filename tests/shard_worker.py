"""Worker script for multi-device batch-backend tests.

Run in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=4
so the main pytest process keeps its single-device view.  Asserts the
sharded grid runner (both shard_map and pmap impls, chunked and not) is
bit-identical to the single-call ``simulate_grid`` on the same cells.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax                                    # noqa: E402
import numpy as np                            # noqa: E402

from repro.core import PigConfig              # noqa: E402
from repro.core import vectorsim as vs        # noqa: E402


def main() -> None:
    assert jax.device_count() == 4, jax.device_count()
    cfgs = [vs.build_config("pigpaxos", 9, pig=PigConfig(n_groups=2, prc=1)),
            vs.build_config("paxos", 9),
            vs.build_config("pigpaxos", 9, pig=PigConfig(n_groups=4))]
    grid = [(ci, k, s) for ci in range(3) for k in (4, 8)
            for s in range(10)]                      # 60 cells, not % 4 == 0
    want = vs.simulate_grid(cfgs, grid, 0.1, 0.05)

    for impl in ("shard_map", "pmap"):
        for chunk in (len(grid) + 4, 16):            # one chunk / many
            got = vs.simulate_grid_sharded(cfgs, grid, 0.1, 0.05,
                                           impl=impl, chunk=chunk)
            sh = got["sharding"]
            assert sh["devices"] == 4 and sh["impl"] == impl, sh
            for key in ("throughput", "median_s", "p99_s", "committed"):
                np.testing.assert_array_equal(
                    np.asarray(want[key]), got[key],
                    err_msg=f"{impl} chunk={chunk} key={key}")
            print(f"OK {impl} chunk={chunk} "
                  f"({len(sh['chunks'])} chunks, 4 devices)")

    # epaxos kind through the same path
    ecfg = vs.build_config("epaxos", 5)
    egrid = [(0, k, s) for k in (2, 4) for s in range(6)]
    ewant = vs.simulate_grid([ecfg], egrid, 0.1, 0.05)
    egot = vs.simulate_grid_sharded([ecfg], egrid, 0.1, 0.05, chunk=8)
    np.testing.assert_array_equal(np.asarray(ewant["throughput"]),
                                  egot["throughput"])
    print("OK epaxos")
    print("OK all")


if __name__ == "__main__":
    main()
