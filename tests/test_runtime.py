"""Runtime integration tests: training loop, checkpoint/restart with
consensus-committed manifests, coordination plane under failures, elastic
re-mesh decisions."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import DataConfig, SyntheticLMStream
from repro.optim import AdamWConfig
from repro.runtime import CoordinationService, ElasticController, HeartbeatMonitor
from repro.checkpoint import CheckpointManager
from repro.train import TrainOptions, build_train_step, init_train_state


def _train(cfg, steps, state=None, start_step=0, seed=0):
    data = DataConfig(global_batch=4, seq_len=32, seed=seed)
    stream = SyntheticLMStream(cfg, data)
    opts = TrainOptions(remat=False,
                        adamw=AdamWConfig(lr=1e-2, warmup_steps=5,
                                          total_steps=200))
    step_fn = jax.jit(build_train_step(cfg, opts))
    if state is None:
        state = init_train_state(cfg, jax.random.PRNGKey(0))
    losses = []
    for s in range(start_step, start_step + steps):
        state, metrics = step_fn(state, stream.batch_at(s))
        losses.append(float(metrics["loss"]))
    return state, losses


def test_training_loss_decreases():
    cfg = get_smoke_config("h2o_danube_1_8b").replace(n_layers=2, vocab=128)
    _, losses = _train(cfg, 30)
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.8, losses


def test_microbatch_equivalence():
    """Gradient accumulation must match the single-shot step (same data)."""
    cfg = get_smoke_config("granite_8b").replace(n_layers=1, vocab=128)
    data = DataConfig(global_batch=8, seq_len=16)
    stream = SyntheticLMStream(cfg, data)
    batch = stream.batch_at(0)
    state = init_train_state(cfg, jax.random.PRNGKey(1))
    opts1 = TrainOptions(remat=False, adamw=AdamWConfig(lr=1e-3))
    optsk = TrainOptions(remat=False, microbatch=4, adamw=AdamWConfig(lr=1e-3))
    s1, m1 = jax.jit(build_train_step(cfg, opts1))(state, batch)
    sk, mk = jax.jit(build_train_step(cfg, optsk))(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(mk["loss"]),
                               rtol=5e-3)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(sk.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=3e-2, atol=3e-3)


def test_checkpoint_restart_bitexact(tmp_path):
    """Crash after step k, restore committed manifest, replay: identical."""
    cfg = get_smoke_config("musicgen_large").replace(n_layers=1, vocab=64)
    coord = CoordinationService(n_nodes=5, n_groups=2)
    mgr = CheckpointManager(str(tmp_path), coord=coord, async_save=False)

    state, _ = _train(cfg, 5)
    mgr.save(5, state)
    assert mgr.latest_step() == 5

    # continue to step 8 (the "lost" work)
    ref_state, _ = _train(cfg, 3, state=state, start_step=5)

    # simulated crash + restart: restore from the committed manifest
    like = init_train_state(cfg, jax.random.PRNGKey(0))
    restored, step = mgr.restore(like)
    assert step == 5
    re_state, _ = _train(cfg, 3, state=restored, start_step=5)
    for a, b in zip(jax.tree.leaves(ref_state.params),
                    jax.tree.leaves(re_state.params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_manifest_commit_survives_coordinator_failures():
    """Manifest commits keep working with a crashed coordination node, and
    the committed value survives a leader failover."""
    coord = CoordinationService(n_nodes=5, n_groups=2)
    coord.put("ckpt/latest", {"step": 7, "dir": "step_7"})
    coord.crash_node(3)                       # follower crash
    coord.put("ckpt/latest", {"step": 9, "dir": "step_9"})
    assert coord.get("ckpt/latest")["step"] == 9
    coord.crash_node(0)                       # leader crash => failover
    coord.put("ckpt/latest", {"step": 11, "dir": "step_11"})
    assert coord.get("ckpt/latest")["step"] == 11


def test_elastic_remesh_and_batch():
    coord = CoordinationService(n_nodes=5, n_groups=2, seed=3)
    ctl = ElasticController(coord, n_pods=2, data=16, model=16)
    assert ctl.mesh_shape() == (2, 16, 16)
    assert ctl.effective_batch(256) == 256
    ctl.remove_pods([1])                      # pod failure
    assert ctl.mesh_shape() == (16, 16)
    assert ctl.effective_batch(256) == 128
    m = ctl.membership()
    assert m["epoch"] == 1 and m["pods"] == [0]


def test_heartbeat_straggler_detection():
    hb = HeartbeatMonitor(timeout=10.0)
    for t in range(8):
        hb.beat(0, step_time=1.0, now=float(t))
        hb.beat(1, step_time=1.05, now=float(t))
        hb.beat(2, step_time=3.5, now=float(t))   # straggler
    assert hb.stragglers() == [2]
    assert hb.dead_pods(now=7.0) == []
    # pod 2 stops beating
    for t in range(8, 20):
        hb.beat(0, now=float(t))
        hb.beat(1, now=float(t))
    assert hb.dead_pods(now=19.0) == [2]


def test_restore_with_resharding(tmp_path):
    """Elastic restart: restore host arrays and device_put with a new
    (smaller) mesh's shardings."""
    cfg = get_smoke_config("gemma_7b").replace(n_layers=1, vocab=128)
    mgr = CheckpointManager(str(tmp_path), coord=None, async_save=False)
    state = init_train_state(cfg, jax.random.PRNGKey(2))
    mgr.save(1, state)
    like = init_train_state(cfg, jax.random.PRNGKey(2))
    restored, step = mgr.restore(like)
    assert step == 1
    for a, b in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(restored.params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
