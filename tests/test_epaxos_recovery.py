"""EPaxos explicit-prepare recovery (ISSUE 5 tentpole): coordinator-crash
fault plans heal instead of wedging keys — peers run a per-instance prepare
phase with a higher ballot, adopt the highest (pre-)accepted attributes, and
re-commit (or no-op) in-flight instances; the linearizability auditor stays
green throughout.  Plus the vectorsim conflict/slow-path model's tolerance
against the fast DES at c in {0.1, 0.5}."""
import numpy as np
import pytest

from repro.core import Cluster, WorkloadConfig
from repro.core.epaxos import EPaxosNode, _Inst
from repro.core.messages import ClientRequest, Command, PreAccept
from repro.faults import apply_plan, audit_cluster, crash_window, storm

WL_RT = WorkloadConfig(request_timeout=25e-3)


def _incomplete_before(cluster, t):
    """Client ops invoked before ``t`` that never completed (hung clients)."""
    return [h for cl in cluster.clients for h in cl.history
            if h["invoke"] < t and not h["ok"]]


def _applied_len(cluster):
    return max(len(nd.applied_log) for nd in cluster.nodes)


# ==================================================== crash-recover healing
@pytest.mark.parametrize("engine", ["exact", "fast"])
def test_coordinator_crash_mid_instance_heals(engine):
    """The acceptance criterion: a coordinator crash-recover window heals
    via explicit prepare — the applied prefix grows past the crash point,
    no client hangs, and the audit passes."""
    c = Cluster("epaxos", 7, seed=5, engine=engine, record_history=True)
    apply_plan(c, crash_window(2, 0.3, 0.5), horizon=2.0)
    assert all(nd.recovery_enabled for nd in c.nodes)
    c.measure(duration=0.7, warmup=0.1, clients=8, workload=WL_RT)
    # service kept flowing after the window
    post = [t for cl in c.clients for (t, _l) in cl.latencies if t > 0.55]
    assert post, engine
    res = audit_cluster(c)
    assert res.ok, (engine, res.violations)
    assert res.completed > 0 and res.reads_checked > 0
    # no hung clients: every op invoked well before stop completed
    assert _incomplete_before(c, 0.6) == []
    # the applied prefix grew past the pre-crash point on every node
    c.run(until=2.5)
    assert min(len(nd.applied_log) for nd in c.nodes) > 0
    n_applied = _applied_len(c)
    assert n_applied > 100


def test_crashed_coordinator_never_returns_peers_recover():
    """With NO recover event the coordinator stays down — peers alone must
    recover its in-flight instances (re-commit or no-op) so the keys
    unwedge; without recovery these clients hang forever."""
    c = Cluster("epaxos", 7, seed=1, engine="exact", record_history=True)
    apply_plan(c, crash_window(2, 0.3), horizon=2.0)
    c.measure(duration=0.7, warmup=0.1, clients=8, workload=WL_RT)
    res = audit_cluster(c)
    assert res.ok, res.violations
    assert _incomplete_before(c, 0.6) == []
    # the control run (recovery off, same crash through the seed-era API)
    # demonstrably wedges clients on the dead coordinator's in-flight
    # instances — recovery is what makes the difference
    c0 = Cluster("epaxos", 7, seed=1, engine="exact", record_history=True)
    c0.crash_at(2, 0.3)
    assert not any(nd.recovery_enabled for nd in c0.nodes)
    c0.measure(duration=0.7, warmup=0.1, clients=8, workload=WL_RT)
    assert _incomplete_before(c0, 0.6), \
        "control run did not wedge — the scenario no longer exercises recovery"


def test_storm_with_recovery_audits_clean_at_full_intensity():
    """The epaxos-recovery storm variant: the SAME storm intensity as the
    pigpaxos family (rate 6, two concurrent crashes) stays audit-green."""
    c = Cluster("epaxos", 25, seed=3, engine="fast", record_history=True)
    apply_plan(c, storm(targets=tuple(range(25)), rate_hz=6.0, t0=0.35,
                        t1=1.3, mean_downtime=0.15, seed=19,
                        max_concurrent=2), horizon=2.0)
    st = c.measure(duration=1.2, warmup=0.3, clients=30, workload=WL_RT)
    assert st.committed > 1000
    res = audit_cluster(c)
    assert res.ok, res.violations
    assert _incomplete_before(c, 1.2) == []


def test_recovery_stays_off_without_a_fault_plan():
    """Golden-trace guard: fault-free runs (and seed-API crash runs) never
    arm recovery timers — apply_plan with real events is the only switch."""
    c = Cluster("epaxos", 5, seed=7, engine="exact")
    assert not any(nd.recovery_enabled for nd in c.nodes)
    from repro.faults import FaultPlan
    assert apply_plan(c, FaultPlan(), horizon=1.0) == []
    assert not any(nd.recovery_enabled for nd in c.nodes)


# ============================================================ no-op recovery
def test_unseen_instance_recovers_to_noop_and_preserves_at_most_once():
    """An instance known only as a dependency (its PreAccept never reached a
    quorum) recovers to a committed NO-OP: successors unblock, nothing is
    applied for it, and a later duplicate of the real command still applies
    exactly once (answered from the session cache)."""
    c = Cluster("epaxos", 5, seed=1, engine="exact")
    for nd in c.nodes:
        nd.enable_recovery()
    ghost = (0, 7)          # never proposed anywhere — a lost instance
    cmd = Command(client_id=99, seq=1, op="put", key=3, value=b"xxxxxxxx")
    # node 1 coordinates the real command but believes ghost interferes
    # (e.g. the crashed node 0 broadcast it and only node 1's copy was
    # lost to the crash window): its PreAccept carries deps={ghost}
    n1 = c.nodes[1]
    n1.insts[ghost] = _Inst()          # known by id only — no command body
    n1._note_interf(3, ghost)
    c.net.send(c.topo.n + 99, 1, ClientRequest(cmd=cmd))
    c.run(until=0.05)
    real = (1, 0)
    assert c.nodes[1].insts[real].deps == frozenset({ghost})
    # committed everywhere but executable nowhere: the ghost dep blocks
    assert all(nd.insts[real].state == "committed" for nd in c.nodes)
    assert all(not nd.applied_log for nd in c.nodes)
    # probe timers fire ~recovery_timeout after the block; the prepare
    # quorum reports state "none" everywhere -> no-op commit
    c.run(until=0.6)
    for nd in c.nodes:
        assert nd.insts[ghost].state == "executed"
        assert nd.insts[ghost].cmd is None
        assert nd.insts[real].state == "executed"
        # the no-op applied nothing; the real command applied exactly once
        assert [iid for iid, _cmd in nd.applied_log] == [real]
        assert nd.store.data.get(3) == b"xxxxxxxx"
    # a client-timeout duplicate of the real command creates a second
    # instance; execution dedups it against the op-id table (at-most-once)
    c.net.send(c.topo.n + 99, 2, ClientRequest(cmd=cmd))
    c.run(until=1.0)
    for nd in c.nodes:
        applied = [iid for iid, _cmd in nd.applied_log]
        assert applied == [real], applied
        assert nd.store.applied_ops == 1


def test_prepare_ballots_beat_the_original_round():
    """Per-instance ballots: a prepare at (1, recoverer) blocks the original
    (0, 0) round from resurrecting state, and a second prepare needs a
    higher epoch."""
    c = Cluster("epaxos", 5, seed=1, engine="exact")
    nd: EPaxosNode = c.nodes[3]
    inst_id = (0, 0)
    nd.insts[inst_id] = _Inst(state="preaccepted",
                              cmd=Command(client_id=1, seq=1, op="put",
                                          key=1, value=b"x"),
                              max_ballot=(1, 2))
    # a stale original-ballot PreAccept must not demote the promise
    pa = PreAccept(inst=inst_id, cmd=nd.insts[inst_id].cmd, deps=frozenset(),
                   seq=1, n_cluster=5)
    pa.src = 0
    nd.on_PreAccept(pa)
    assert nd.insts[inst_id].max_ballot == (1, 2)


# ==================================== vectorsim conflict model vs fast DES
@pytest.mark.parametrize("conflict", [0.1, 0.5])
def test_batch_conflict_model_matches_fast_des(conflict):
    """Acceptance criterion: the batch EPaxos conflict/slow-path model's
    throughput lands within ~10% of the fast DES at c <= 0.5 (one jitted
    call for the whole grid)."""
    pytest.importorskip("jax")
    from repro.core import vectorsim as vs

    wl = WorkloadConfig(key_dist="conflict", conflict_rate=conflict)
    dur, warm, k = 0.3, 0.15, 40
    des = []
    for s in (1, 2):
        cl = Cluster("epaxos", 25, seed=s, engine="fast")
        des.append(cl.measure(duration=dur, warmup=warm, clients=k,
                              workload=wl).throughput)
    units = vs.simulate_scenario("epaxos", 25, workload=wl, clients=(k,),
                                 seeds=(1, 2), duration=dur, warmup=warm)
    bt = float(np.mean([u["throughput"] for u in units]))
    dt = float(np.mean(des))
    assert bt == pytest.approx(dt, rel=0.12), (conflict, dt, bt)
    # the conflict penalty is real on both backends at c=0.5
    if conflict == 0.5:
        base = vs.simulate_scenario("epaxos", 25, clients=(k,), seeds=(1, 2),
                                    duration=dur, warmup=warm)
        b0 = float(np.mean([u["throughput"] for u in base]))
        assert bt < 0.9 * b0, (bt, b0)


def test_batch_zipf_epaxos_runs_and_slows_vs_uniform():
    """The zipfian key draw reuses the cached CDF: heavy skew produces
    measurable interference (slow paths) relative to uniform keys."""
    pytest.importorskip("jax")
    from repro.core import vectorsim as vs

    kw = dict(clients=(40,), seeds=(1, 2), duration=0.3, warmup=0.15)
    uni = vs.simulate_scenario("epaxos", 25, **kw)
    zipf = vs.simulate_scenario(
        "epaxos", 25, workload=WorkloadConfig(key_dist="zipfian",
                                              zipf_theta=1.2), **kw)
    tu = float(np.mean([u["throughput"] for u in uni]))
    tz = float(np.mean([u["throughput"] for u in zipf]))
    assert tz < tu            # skew must cost throughput in EPaxos
    assert tz > 0.5 * tu      # ... but not collapse the model
