"""Read paths (ISSUE 10): leader leases under clock drift, quorum reads,
the auditor's stale-read checks, scenario validation boundaries, and the
batch backend's leased-read model.

The centerpiece mirrors the PR 6 broken-catchup pattern: the SAME
adversarial-drift run twice — ``lease_safety=True`` must audit clean at
the maximum modeled drift, and the deliberately-broken
``lease_safety=False`` control (the leader keeps believing the lease
after a quorum of promises has really expired) must be flagged as stale
reads by the auditor.
"""
import numpy as np
import pytest

from repro.core import Cluster, PigConfig
from repro.core.cluster import Client, WorkloadConfig
from repro.core.paxos import LeaseConfig
from repro.faults.audit import audit_cluster, check_history


# ---------------------------------------------------------------- leases

def _drift_run(safety: bool):
    """Adversarial-but-in-bounds drift: the leader's clock runs at the
    slowest allowed rate, every follower's at the fastest, so the leader's
    believed lease window overhangs the followers' promise windows by the
    maximum the model allows.  The old leader is then partitioned from the
    followers (clients still reach it) while a successor campaigns and
    commits fresh writes — a reader pinned to the old leader is exactly
    the stale-read hazard the safety margin exists for."""
    c = Cluster("paxos", 5, seed=11, record_history=True,
                lease=LeaseConfig(duration_ms=400, drift_bound=0.2,
                                  lease_safety=safety))
    c.nodes[0].clock_rate = -0.2
    for nd in c.nodes[1:]:
        nd.clock_rate = +0.2
    stop = 1.2
    # writers route to the current leader (they fail over to node 1)
    wwl = WorkloadConfig(read_ratio=0.0, n_keys=1, request_timeout=25e-3)
    c.add_clients(4, wwl, stop_at=stop)
    # one leased reader pinned to the OLD leader
    rwl = WorkloadConfig(read_ratio=1.0, read_path="lease", n_keys=1)
    rd = Client(c, len(c.clients), lambda: 0, rwl, stop)
    c.clients.append(rd)
    c.sched.at(20e-3, rd.start)
    for j in range(1, 5):
        c.partition_at(0, j, 0.3)
    c.sched.at(0.35, c.nodes[1].start_phase1)
    c.run(until=stop + 0.2)
    return c, audit_cluster(c)


def test_lease_safe_under_max_drift():
    c, res = _drift_run(safety=True)
    assert res.ok, res.violations
    # the run exercised the hazard: leased reads were actually served,
    # and the successor really took over and committed writes
    assert sum(nd.lease_reads for nd in c.nodes) > 0
    assert c.leader_id == 1


def test_lease_safety_broken_control_is_flagged():
    c, res = _drift_run(safety=False)
    assert not res.ok
    assert any("stale read" in v and "lease read" in v
               for v in res.violations), res.violations
    # same physics as the safe run — only the margin differs
    assert c.leader_id == 1


def test_successor_blocked_until_lease_drains():
    # the lease/expiry family's mechanism at unit scale: with a held
    # 400 ms lease, a successor campaigning at t=0.35 cannot win phase 1
    # until the followers' promise windows expire
    c, _res = _drift_run(safety=True)
    # node 1 became leader eventually, but only after the grant expired:
    # its first committed write must land well after the campaign start
    t_first = min((t for cl in c.clients[:4] for (t, _l) in cl.latencies
                   if t > 0.35), default=None)
    assert t_first is not None and t_first > 0.45, t_first


@pytest.mark.parametrize("protocol,kw", [
    ("paxos", {}),
    ("pigpaxos", {"pig": PigConfig(n_groups=3, prc=1)}),
])
def test_leased_reads_audit_ok_and_fast(protocol, kw):
    wl = WorkloadConfig(read_ratio=0.9, read_path="lease")
    c = Cluster(protocol, 25, seed=1, record_history=True,
                lease={"duration_ms": 200.0}, **kw)
    st = c.measure(duration=0.3, warmup=0.15, clients=40, workload=wl)
    rw = c.read_write_split()
    assert rw["lease_reads"] > 0 and rw["reads"] > 0
    # leased reads skip the commit round: reads must be much cheaper
    assert rw["read_mean_ms"] < 0.7 * rw["write_mean_ms"]
    assert st.throughput > 0
    res = audit_cluster(c)
    assert res.ok, res.violations
    assert res.reads_checked >= rw["reads"]


# ---------------------------------------------------------- quorum reads

@pytest.mark.parametrize("protocol,kw", [
    ("paxos", {}),
    ("epaxos", {}),
    ("pigpaxos", {"pig": PigConfig(n_groups=3, prc=1)}),
])
def test_quorum_reads_audit_ok(protocol, kw):
    wl = WorkloadConfig(read_ratio=0.7, read_path="quorum", n_keys=8)
    c = Cluster(protocol, 9, seed=3, record_history=True, **kw)
    c.measure(duration=0.3, warmup=0.15, clients=20, workload=wl)
    rw = c.read_write_split()
    assert rw["reads"] > 0 and rw["writes"] > 0
    assert rw["lease_reads"] == 0          # no lease armed
    res = audit_cluster(c)
    assert res.ok, res.violations


# ------------------------------------------------- auditor check 6 units

def _h(cid, seq, op, key, invoke, resp, *, rtag=None, wtag=None, path=None):
    d = {"cid": cid, "seq": seq, "op": op, "key": key, "invoke": invoke,
         "resp": resp, "ok": resp is not None, "rtag": rtag, "wtag": wtag}
    if path is not None:
        d["path"] = path
    return d


def test_audit_synthetic_stale_read_flagged():
    # put A completes at t=1, put B completes at t=3; a leased read
    # invoked at t=4 returns A — stale, no linearization explains it
    logs = [[(1, 0, "put", 0), (1, 1, "put", 0)]] * 3
    hist = [
        _h(1, 0, "put", 0, 0.0, 1.0, wtag=(1, 0)),
        _h(1, 1, "put", 0, 2.0, 3.0, wtag=(1, 1)),
        _h(2, 0, "get", 0, 4.0, 4.5, rtag=(1, 0), path="lease"),
    ]
    res = check_history(hist, logs)
    assert not res.ok and any("stale read" in v for v in res.violations)
    # the fresh value is fine
    hist[-1]["rtag"] = (1, 1)
    assert check_history(hist, logs).ok


def test_audit_synthetic_phantom_and_future_reads_flagged():
    logs = [[(1, 0, "put", 0)]] * 3
    hist = [_h(1, 0, "put", 0, 0.0, 1.0, wtag=(1, 0)),
            _h(2, 0, "get", 0, 2.0, 2.5, rtag=(9, 9), path="quorum")]
    res = check_history(hist, logs)
    assert not res.ok and any("phantom read" in v for v in res.violations)
    # future read: the put is invoked after the read completed
    logs2 = [[(1, 0, "put", 0), (1, 1, "put", 0)]] * 3
    hist2 = [_h(1, 0, "put", 0, 0.0, 1.0, wtag=(1, 0)),
             _h(1, 1, "put", 0, 5.0, 6.0, wtag=(1, 1)),
             _h(2, 0, "get", 0, 2.0, 2.5, rtag=(1, 1), path="quorum")]
    res2 = check_history(hist2, logs2)
    assert not res2.ok and any("future read" in v for v in res2.violations)


def test_audit_synthetic_read_inversion_flagged():
    # read X sees put B and completes; read Y invoked later returns put A
    logs = [[(1, 0, "put", 0), (1, 1, "put", 0)]] * 3
    hist = [
        _h(1, 0, "put", 0, 0.0, 1.0, wtag=(1, 0)),
        # concurrent with both reads: neither read is forced to see it by
        # real time alone — only the first read's observation forces it
        _h(1, 1, "put", 0, 1.5, 9.0, wtag=(1, 1)),
        _h(2, 0, "get", 0, 2.0, 2.5, rtag=(1, 1), path="lease"),
        _h(3, 0, "get", 0, 3.0, 3.5, rtag=(1, 0), path="lease"),
    ]
    res = check_history(hist, logs)
    assert not res.ok and any("read inversion" in v for v in res.violations)


def test_audit_lease_reads_exempt_from_durability():
    # an acknowledged non-logged read appears in no log — that is its
    # point, not a lost update
    logs = [[(1, 0, "put", 0)]] * 3
    hist = [_h(1, 0, "put", 0, 0.0, 1.0, wtag=(1, 0)),
            _h(2, 0, "get", 0, 2.0, 2.5, rtag=(1, 0), path="lease")]
    assert check_history(hist, logs).ok


# ------------------------------------------------- validation boundaries

def test_scenario_rejects_reads_on_ref_engine():
    from repro.experiments.scenario import Scenario
    with pytest.raises(ValueError, match="verbatim"):
        Scenario(name="x/ref", protocol="paxos", n=5, engine="ref",
                 workload=WorkloadConfig(read_ratio=0.5))


def test_scenario_rejects_lease_on_epaxos():
    from repro.experiments.scenario import Scenario
    with pytest.raises(ValueError, match="leaderless"):
        Scenario(name="x/ep", protocol="epaxos", n=5,
                 lease={"duration_ms": 200.0},
                 workload=WorkloadConfig(read_ratio=0.5, read_path="lease"))


def test_scenario_rejects_lease_path_without_lease():
    from repro.experiments.scenario import Scenario
    with pytest.raises(ValueError, match="requires lease="):
        Scenario(name="x/nolease", protocol="paxos", n=5,
                 workload=WorkloadConfig(read_ratio=0.5, read_path="lease"))


def test_scenario_rejects_quorum_reads_on_batch():
    from repro.experiments.scenario import Scenario
    with pytest.raises(ValueError, match="need the\n?\\s*DES"):
        Scenario(name="x/bq", protocol="paxos", n=5, backend="batch",
                 workload=WorkloadConfig(read_ratio=0.5, read_path="quorum"))


def test_vectorsim_read_boundaries_raise():
    from repro.core import vectorsim
    wl_q = WorkloadConfig(read_ratio=0.5, read_path="quorum")
    with pytest.raises(ValueError, match="no array form"):
        vectorsim.build_config("paxos", 5, workload=wl_q)
    wl_l = WorkloadConfig(read_ratio=0.5, read_path="lease")
    with pytest.raises(ValueError, match="leaderless"):
        vectorsim.build_config("epaxos", 5, workload=wl_l)
    with pytest.raises(ValueError, match="batch buffer"):
        vectorsim.build_config("paxos", 5, workload=wl_l, batch_m=4)
    masks = {"down_t0": np.zeros((5, 1)), "down_t1": np.zeros((5, 1)),
             "slow_extra": np.zeros(5), "slow_factor": np.ones(5)}
    with pytest.raises(ValueError, match="held for the"):
        vectorsim.build_config("paxos", 5, workload=wl_l, masks=masks)


def test_workload_read_knob_validation():
    with pytest.raises(ValueError, match="read_ratio"):
        WorkloadConfig(read_ratio=1.5)
    with pytest.raises(ValueError, match="read_path"):
        WorkloadConfig(read_path="psychic")
    with pytest.raises(ValueError, match="closed-loop"):
        WorkloadConfig(read_ratio=0.5, read_path="quorum",
                       arrival="poisson", rate_hz=100.0)


# -------------------------------------------------- batch backend: reads

def test_batch_leased_reads_model():
    from repro.core import vectorsim
    kw = dict(clients=(20,), seeds=(1,), duration=0.4, warmup=0.2)
    lease = vectorsim.simulate_scenario(
        "paxos", 5,
        workload=WorkloadConfig(read_ratio=0.9, read_path="lease"), **kw)[0]
    log = vectorsim.simulate_scenario(
        "paxos", 5,
        workload=WorkloadConfig(read_ratio=0.9, read_path="log"), **kw)[0]
    # leased reads skip the commit round: much higher throughput, and the
    # unit carries the read/write split
    assert lease["throughput"] > 2.0 * log["throughput"]
    rw = lease["rw"]
    assert rw["reads"] > 0 and rw["writes"] > 0
    assert rw["read_mean_ms"] < rw["write_mean_ms"]
    # the log read path has no rw split from the kernel (reads ARE writes
    # there), and read_ratio=r must be byte-equivalent to the seed's
    # write_fraction=1-r semantics — the same classic kernel, no read lane
    base = vectorsim.simulate_scenario(
        "paxos", 5, workload=WorkloadConfig(read_ratio=0.3), **kw)[0]
    plain = vectorsim.simulate_scenario(
        "paxos", 5, workload=WorkloadConfig(write_fraction=0.7), **kw)[0]
    assert base["throughput"] == pytest.approx(plain["throughput"], rel=1e-6)


def test_batch_des_lease_fidelity_smoke():
    # the gate pins [0.90, 1.10] on the catalog cells; this is the cheap
    # in-tree version of the same cross-check at N=5
    from repro.core import vectorsim
    wl = WorkloadConfig(read_ratio=0.9, read_path="lease")
    b = vectorsim.simulate_scenario("paxos", 5, workload=wl, clients=(20,),
                                    seeds=(1,), duration=0.5, warmup=0.25)[0]
    c = Cluster("paxos", 5, seed=1, lease={"duration_ms": 200.0})
    st = c.measure(duration=0.5, warmup=0.25, clients=20, workload=wl)
    assert b["throughput"] == pytest.approx(st.throughput, rel=0.15)


# -------------------------------------------------- registry + reporting

def test_read_families_registered_with_summarizers():
    from repro.experiments import registry, report
    reads = registry.select("reads")
    lease = registry.select("lease")
    assert {sc.name for sc in reads} >= {
        "reads/paxos/lease/r=0.9", "reads/paxos/log/r=0.9",
        "reads/paxos/lease/r=0.9/batch", "reads/paxos/quorum/r=0.9",
        "reads/epaxos/quorum/r=0.9", "reads/pigpaxos/subgroup/r=0.9"}
    assert {sc.name for sc in lease} >= {"lease/expiry/d=50ms",
                                         "lease/expiry/d=400ms"}
    assert "reads" in report.SUMMARIZERS and "lease" in report.SUMMARIZERS
    # every audited reads cell records history (the auditor needs it) and
    # every batch twin is lease/log only
    for sc in reads:
        if sc.backend == "batch":
            assert sc.workload.read_path in ("lease", "log")
        else:
            assert sc.audit
