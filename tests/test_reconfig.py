"""Membership change as a first-class fault (PR 6): single-server
reconfiguration through the normal log on all three DES protocols, joiner
catch-up (snapshot + log suffix), the one-at-a-time invariant, rolling
restarts, failover policies, the time-varying-membership auditor, and the
deliberately broken control (catch-up disabled) the auditor must catch."""
import pytest

from repro.core import Cluster, PigConfig, WorkloadConfig, agreement_ok
from repro.faults import (add_node, apply_plan, audit_cluster,
                          commit_apply_gap, crash_window, remove_node,
                          replace_leader, rolling_restart)
from repro.runtime import FailoverPolicy, attach_failover

WL_RT = WorkloadConfig(request_timeout=25e-3)


# ===================================================== add / remove under load
def test_add_node_under_load_audits_clean():
    """A spare joins mid-run: snapshot + log suffix, then the add_node cfg
    command commits; every live node converges on the grown membership and
    the audit (agreement as infix, durability over final members) is green.
    """
    for proto in ("pigpaxos", "epaxos"):
        pig = PigConfig(n_groups=2, prc=1) if proto == "pigpaxos" else None
        c = Cluster(proto, 5, pig=pig, seed=11, engine="exact",
                    record_history=True, spare_nodes=1)
        apply_plan(c, add_node(5, 0.25), horizon=2.0)
        c.measure(duration=0.6, warmup=0.1, clients=6, workload=WL_RT)
        assert c.members == [0, 1, 2, 3, 4, 5], proto
        assert sorted(c.nodes[5].members) == [0, 1, 2, 3, 4, 5], proto
        assert not c.nodes[5].joining, proto
        res = audit_cluster(c)
        assert res.ok, (proto, res.violations)
        assert res.completed > 0


def test_remove_follower_shrinks_quorums_and_audits_clean():
    for proto in ("pigpaxos", "epaxos"):
        pig = PigConfig(n_groups=2, prc=1) if proto == "pigpaxos" else None
        c = Cluster(proto, 5, pig=pig, seed=3, engine="exact",
                    record_history=True)
        apply_plan(c, remove_node(4, 0.25), horizon=2.0)
        c.measure(duration=0.6, warmup=0.1, clients=6, workload=WL_RT)
        assert c.members == [0, 1, 2, 3], proto
        # a live member's quorum math now runs over 4 nodes
        survivor = c.nodes[0]
        assert sorted(survivor.members) == [0, 1, 2, 3], proto
        res = audit_cluster(c)
        assert res.ok, (proto, res.violations)


def test_remove_the_leader_moves_leadership():
    c = Cluster("pigpaxos", 5, pig=PigConfig(n_groups=2), seed=7,
                engine="exact", record_history=True)
    apply_plan(c, remove_node(0, 0.25), horizon=2.0)
    c.measure(duration=0.6, warmup=0.1, clients=6, workload=WL_RT)
    assert c.members == [1, 2, 3, 4]
    assert c.leader_id != 0
    assert c.nodes[0].removed and not c.nodes[0].is_leader
    # service resumed under the new leader
    post = [t for cl in c.clients for (t, _l) in cl.latencies if t > 0.4]
    assert post
    res = audit_cluster(c)
    assert res.ok, res.violations


def test_add_during_leader_crash_lands_after_recovery():
    """JoinReq retries ride out a crashed leader: the join request keeps
    re-arming until a leader answers, so an add issued mid-outage completes
    once the leader recovers (or a new one is elected)."""
    c = Cluster("pigpaxos", 5, pig=PigConfig(n_groups=2), seed=5,
                engine="exact", record_history=True, spare_nodes=1)
    apply_plan(c, crash_window(0, 0.3, 0.6) + add_node(5, 0.35), horizon=3.0)
    c.measure(duration=1.2, warmup=0.1, clients=6, workload=WL_RT)
    assert 5 in c.members
    assert not c.nodes[5].joining
    res = audit_cluster(c)
    assert res.ok, res.violations


# ================================================== one-at-a-time invariant
def test_concurrent_reconfig_rejected_paxos():
    c = Cluster("paxos", 5, seed=1, engine="exact")
    c.run(until=0.1)                       # initial election settles
    leader = c.nodes[c.leader_id]
    assert leader.propose_reconfig("remove_node", 4)
    # second cfg while the first is in flight: refused
    assert not leader.propose_reconfig("remove_node", 3)
    c.run(until=0.5)                       # first cfg applies
    assert c.members == [0, 1, 2, 3]
    assert leader.propose_reconfig("remove_node", 3)
    c.run(until=0.9)
    assert c.members == [0, 1, 2]


def test_concurrent_reconfig_rejected_epaxos():
    c = Cluster("epaxos", 5, seed=1, engine="exact")
    c.run(until=0.1)
    nd = c.nodes[0]
    assert nd.propose_reconfig("remove_node", 4)
    assert not nd.propose_reconfig("remove_node", 3)
    c.run(until=0.5)
    assert c.members == [0, 1, 2, 3]
    # no-op reconfigs are refused outright
    assert not nd.propose_reconfig("remove_node", 4)
    assert not c.nodes[1].propose_reconfig("add_node", 2)


# ============================================================ leader handoff
def test_replace_leader_planned_handoff():
    """A higher-ballot phase-1 from the nominee makes the incumbent step
    down — leadership moves with no crash and service continues."""
    c = Cluster("pigpaxos", 5, pig=PigConfig(n_groups=2), seed=9,
                engine="exact", record_history=True)
    apply_plan(c, replace_leader(3, 0.3), horizon=2.0)
    c.measure(duration=0.6, warmup=0.1, clients=6, workload=WL_RT)
    assert c.leader_id == 3
    assert c.nodes[3].is_leader and not c.nodes[0].is_leader
    post = [t for cl in c.clients for (t, _l) in cl.latencies if t > 0.45]
    assert post
    res = audit_cluster(c)
    assert res.ok, res.violations


# =========================================================== rolling restart
def test_rolling_restart_full_cycle_audits_clean():
    """Every node restarted in sequence (leader first) under closed-loop
    load: zero violations, zero lost acknowledged writes, and the cluster
    settles with committed == applied."""
    c = Cluster("pigpaxos", 7, pig=PigConfig(n_groups=2, prc=1), seed=13,
                engine="exact", record_history=True)
    plan = rolling_restart(tuple(range(7)), t0=0.2, downtime=0.05, gap=0.12)
    evs = apply_plan(c, plan, horizon=3.0)
    assert sum(1 for ev in evs if ev[0] == "crash") == 7
    st = c.measure(duration=1.0, warmup=0.1, clients=6, workload=WL_RT)
    assert st.committed > 0
    res = audit_cluster(c)
    assert res.ok, res.violations
    assert res.completed > 0
    c.run(until=3.0)                        # settle
    assert commit_apply_gap(c) == 0
    assert agreement_ok(c)


def test_rolling_restart_rejects_overlapping_windows():
    with pytest.raises(ValueError, match="exceed downtime"):
        rolling_restart((0, 1, 2), t0=0.1, downtime=0.2, gap=0.1)


# =========================================================== failover policy
def test_failover_policy_promotes_successor():
    """Leader dies for good; the external detector notices the commit stall
    and promotes the next live member — service resumes and the audit stays
    green across the handover."""
    c = Cluster("pigpaxos", 5, pig=PigConfig(n_groups=2), seed=17,
                engine="exact", record_history=True)
    apply_plan(c, crash_window(0, 0.3), horizon=2.0)
    events = attach_failover(
        c, FailoverPolicy(detect_timeout=0.05, check_interval=0.01),
        stop_at=0.8)
    c.measure(duration=0.7, warmup=0.1, clients=6, workload=WL_RT)
    assert events and events[0]["to"] != 0
    assert c.leader_id != 0 and c.nodes[c.leader_id].is_leader
    post = [t for cl in c.clients for (t, _l) in cl.latencies if t > 0.5]
    assert post
    res = audit_cluster(c)
    assert res.ok, res.violations


def test_failover_policy_validates():
    with pytest.raises(ValueError, match="successor"):
        FailoverPolicy(successor="coin-flip")
    with pytest.raises(ValueError, match="positive"):
        FailoverPolicy(detect_timeout=0.0)


# ================================================== broken control (auditor)
def test_broken_catchup_control_is_caught_by_auditor():
    """The acceptance-criterion control: a joiner with state transfer
    DISABLED (catch_up=False) becomes leader and serves reads from its
    empty store — the auditor must flag the run.  The identical run with
    catch-up on is green.  The key space is wide enough (512, uniform)
    that many keys are written before the join and only *read* after the
    handoff — exactly the reads a skipped snapshot corrupts; a handful of
    hot keys would mask the hole behind constant re-puts."""
    def run(catch_up):
        wl = WorkloadConfig(request_timeout=25e-3, n_keys=512)
        c = Cluster("pigpaxos", 5, pig=PigConfig(n_groups=2), seed=21,
                    engine="exact", record_history=True, spare_nodes=1)
        c.sched.at(0.25, lambda: c.add_node(5, catch_up=catch_up))
        apply_plan(c, replace_leader(5, 0.55), horizon=2.0)
        c.measure(duration=0.8, warmup=0.1, clients=8, workload=wl)
        assert 5 in c.members
        assert c.leader_id == 5
        return audit_cluster(c)

    good = run(catch_up=True)
    assert good.ok, good.violations
    bad = run(catch_up=False)
    assert not bad.ok
    assert any("stale" in v or "lost update" in v for v in bad.violations)


# ====================================== satellite: reconfig-free golden pin
def test_reconfig_free_runs_stay_bit_identical_to_seed():
    """The membership machinery must be invisible when no reconfiguration
    happens: exact-engine traces stay bit-identical to the verbatim seed
    stack (engine='ref') for pigpaxos AND epaxos."""
    def run(proto, engine):
        pig = (PigConfig(n_groups=2, prc=1) if proto == "pigpaxos" else None)
        c = Cluster(proto, 5, pig=pig, seed=23, engine=engine)
        st = c.measure(duration=0.3, warmup=0.1, clients=8)
        logs = [[(cmd.client_id, cmd.seq, cmd.key) for _s, cmd in
                 nd.applied_log] for nd in c.nodes]
        return logs, st.committed, c.sched.events, c.sched._seq

    for proto in ("pigpaxos", "epaxos"):
        assert run(proto, "exact") == run(proto, "ref"), proto


# =================================== satellite: batch-boundary loud errors
def test_membership_plans_are_des_only_with_loud_error():
    plan = add_node(5, 0.3)
    assert not plan.mask_expressible(1.0)
    with pytest.raises(ValueError, match="time-varying replica set"):
        plan.to_masks(6, 1.0)
    with pytest.raises(ValueError, match="time-varying replica set"):
        (remove_node(2, 0.3)).to_masks(6, 1.0)


def test_partition_and_drop_mask_errors_name_the_boundary():
    from repro.faults import drop_window, partition_window
    with pytest.raises(ValueError, match="per-link connectivity"):
        partition_window(1, 2, 0.1, 0.2).to_masks(5, 1.0)
    with pytest.raises(ValueError, match="per-message randomness"):
        drop_window(1, 0.1, 0.2, 0.5).to_masks(5, 1.0)


def test_scenario_rejects_membership_on_batch_and_ref():
    from repro.experiments.scenario import Scenario
    with pytest.raises(ValueError, match="spare_nodes"):
        Scenario(name="t/bad", protocol="pigpaxos", n=5,
                 pig=PigConfig(n_groups=2), backend="batch", spare_nodes=1)
    with pytest.raises(ValueError, match="failover"):
        Scenario(name="t/bad2", protocol="paxos", n=5, backend="batch",
                 failover={"detect_timeout": 0.1})
    with pytest.raises(ValueError, match="ref"):
        Cluster("paxos", 5, engine="ref", spare_nodes=1)
    # membership events may target spares: n + spare_nodes is the bound
    sc = Scenario(name="t/ok", protocol="pigpaxos", n=5,
                  pig=PigConfig(n_groups=2), spare_nodes=1,
                  faults=add_node(5, 0.3), audit=True,
                  clients=(4,), seeds=(1,), duration=0.5, warmup=0.1)
    assert sc.fault_plan() is not None
    with pytest.raises(ValueError, match="targets node 6"):
        Scenario(name="t/bad3", protocol="pigpaxos", n=5,
                 pig=PigConfig(n_groups=2), spare_nodes=1,
                 faults=add_node(6, 0.3))


# =========================================== experiment-layer registration
def test_membership_families_registered_and_wired():
    from repro import experiments
    from repro.experiments import report

    fams = set(experiments.families())
    assert {"reconfig", "rolling", "failover"} <= fams
    assert {"reconfig", "rolling", "failover"} <= set(report.SUMMARIZERS)
    names = {s.name for s in experiments.select("reconfig")}
    assert {"reconfig/add/N=25", "reconfig/remove/N=25",
            "reconfig/replace/N=25", "reconfig/epaxos/N=25"} <= names
    rolling = {s.name for s in experiments.select("rolling")}
    assert "rolling/pigpaxos/N=25" in rolling
    for s in experiments.select("reconfig,rolling,failover"):
        assert s.audit and s.backend == "des"
        assert s.fault_plan() is not None
    # the rolling acceptance scenario restarts ALL 25 nodes even in quick
    sc = next(s for s in experiments.select("rolling/pigpaxos/N=25"))
    rs = sc.resolve(quick=True)
    evs = sc.fault_plan().materialize(rs.warmup + rs.duration + 0.5)
    assert sum(1 for ev in evs if ev[0] == "crash") == 25
    assert sum(1 for ev in evs if ev[0] == "recover") == 25
