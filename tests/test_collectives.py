"""Multi-device collective schedule tests (subprocess: 8 host devices)."""
import os
import subprocess
import sys

import pytest


def test_pig_schedules_multidevice():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "tests/collective_worker.py"],
                       capture_output=True, text=True, env=env, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "OK all" in r.stdout


def test_dcn_byte_model():
    from repro.collectives.schedules import dcn_bytes_per_chip
    P = 1e9
    d = dcn_bytes_per_chip(P, 1, 2, "direct")
    p = dcn_bytes_per_chip(P, 256, 2, "pig")
    q = dcn_bytes_per_chip(P, 256, 2, "pig_q8")
    assert p == pytest.approx(d / 256)
    assert q < p                      # compression halves the bf16 wire bytes
    assert q == pytest.approx(p * (1 + 4 / 1024) / 2)
