"""Batch-backend tests: DES<->batch tolerance on overlapping grid points,
message loads vs Eq. 1-3, bit-determinism under a fixed PRNGKey, and the
single-compilation guarantee across a grid (no per-cell retrace)."""
import numpy as np
import pytest

pytest.importorskip("jax")

from repro.core import Cluster, PigConfig, analytical, wan_topology
from repro.core import vectorsim as vs
from repro.core.pig import PigComm
from repro.experiments import runner
from repro.experiments.scenario import Scenario

DUR, WARM = 0.4, 0.2
SEEDS = (1, 2)


def _des_mean(protocol, n, pig, clients, topo=None, engine="fast"):
    t, m = [], []
    for s in SEEDS:
        c = Cluster(protocol, n, pig=pig, seed=s, engine=engine, topo=topo)
        st = c.measure(duration=DUR, warmup=WARM, clients=clients)
        t.append(st.throughput)
        m.append(st.median_ms)
    return float(np.mean(t)), float(np.mean(m))


def _batch_mean(units, clients):
    us = [u for u in units if u["clients"] == clients]
    return (float(np.mean([u["throughput"] for u in us])),
            float(np.mean([u["median_ms"] for u in us])))


# ------------------------------------------------------- DES <-> batch
def test_pigpaxos_matches_fast_engine_within_tolerance():
    pig = PigConfig(n_groups=3, prc=1)
    units = vs.simulate_scenario("pigpaxos", 25, pig=pig, clients=(20, 60),
                                 seeds=SEEDS, duration=DUR, warmup=WARM)
    for k in (20, 60):
        dt, dm = _des_mean("pigpaxos", 25, pig, k)
        bt, bm = _batch_mean(units, k)
        assert bt == pytest.approx(dt, rel=0.10), (k, dt, bt)
        assert bm == pytest.approx(dm, rel=0.10), (k, dm, bm)


def test_paxos_matches_fast_engine_within_tolerance():
    units = vs.simulate_scenario("paxos", 25, clients=(40,), seeds=SEEDS,
                                 duration=DUR, warmup=WARM)
    dt, dm = _des_mean("paxos", 25, None, 40)
    bt, bm = _batch_mean(units, 40)
    assert bt == pytest.approx(dt, rel=0.10)
    assert bm == pytest.approx(dm, rel=0.10)


def test_epaxos_matches_fast_engine():
    # the symmetric random-leader kernel is a coarser fit (conflict-free
    # fast path only): hold it to 12% throughput / 15% median
    units = vs.simulate_scenario("epaxos", 25, clients=(40,), seeds=SEEDS,
                                 duration=DUR, warmup=WARM)
    dt, dm = _des_mean("epaxos", 25, None, 40)
    bt, bm = _batch_mean(units, 40)
    assert bt == pytest.approx(dt, rel=0.12)
    assert bm == pytest.approx(dm, rel=0.15)


def test_wan_region_matrix_latency():
    """Three-region WAN: commit needs a remote region, so the latency floor
    is ~2x the 31ms one-way — and the batch backend matches the DES."""
    topo = {"npr": [5, 5, 5],
            "ms": [[0.15, 31, 35], [31, 0.15, 11], [35, 11, 0.15]]}
    groups = [[1, 2, 3, 4], [5, 6, 7, 8, 9], [10, 11, 12, 13, 14]]
    pig = PigConfig(n_groups=3, groups=groups, prc=1)
    units = vs.simulate_scenario(
        "pigpaxos", 15, pig=pig,
        topo=wan_topology(topo["npr"], topo["ms"]),
        clients=(20,), seeds=SEEDS, duration=DUR, warmup=WARM,
        leader_timeout=400e-3)
    bt, bm = _batch_mean(units, 20)
    assert 60.0 < bm < 70.0
    assert bt > 0


# ------------------------------------------------------------ Eq. 1-3
def test_message_loads_match_analytical():
    for r in (1, 3, 5):
        units = vs.simulate_scenario(
            "pigpaxos", 25, pig=PigConfig(n_groups=r), clients=(20,),
            seeds=(7,), duration=0.3, warmup=0.15)
        u = units[0]
        assert u["leader_msgs_per_op"] == pytest.approx(
            analytical.leader_messages(r), abs=0.25)
        assert u["follower_msgs_per_op"] == pytest.approx(
            analytical.follower_messages(25, r), abs=0.25)
    u = vs.simulate_scenario("paxos", 25, clients=(20,), seeds=(7,),
                             duration=0.3, warmup=0.15)[0]
    assert u["leader_msgs_per_op"] == pytest.approx(2 * 24 + 2, abs=0.25)
    assert u["follower_msgs_per_op"] == pytest.approx(2.0, abs=0.25)


def test_required_per_group_shared_with_pigcomm():
    """The batch backend and the DES comm layer consume the SAME §4.1
    threshold implementation (pig.required_per_group) — and PigComm's
    delegating method agrees with it."""
    from repro.core.pig import partition_followers, required_per_group
    assert vs.required_per_group is required_per_group
    assert vs.partition_followers is partition_followers
    for n, r, prc, sgm in ((25, 3, 1, False), (25, 8, 3, False),
                           (25, 1, 0, True), (9, 2, 1, False)):
        cfg = PigConfig(n_groups=r, prc=prc, single_group_majority=sgm)
        pc = PigComm.__new__(PigComm)
        pc.cfg = cfg
        pc.all_nodes = list(range(n))
        groups = partition_followers([i for i in range(1, n)], r)
        assert PigComm._partition([i for i in range(1, n)], r) == groups
        assert (required_per_group(groups, n, prc, sgm)
                == pc._required_per_group(groups))


# ------------------------------------------------------- determinism
def test_bit_determinism_under_fixed_key():
    kw = dict(pig=PigConfig(n_groups=3, prc=1), clients=(10, 20),
              seeds=(0, 1), duration=0.15, warmup=0.05)
    a = vs.simulate_scenario("pigpaxos", 25, **kw)
    b = vs.simulate_scenario("pigpaxos", 25, **kw)
    assert a == b  # bit-identical, not approx


def test_seeds_differ():
    units = vs.simulate_scenario("pigpaxos", 25,
                                 pig=PigConfig(n_groups=3, prc=1),
                                 clients=(20,), seeds=(0, 1),
                                 duration=0.15, warmup=0.05)
    assert units[0]["throughput"] != units[1]["throughput"]


# ------------------------------------------------ compilation contract
def test_single_compilation_across_grid():
    """A whole multi-config grid is ONE trace, and re-running the same
    shapes hits the jit cache (no per-cell retrace)."""
    cfgs = [vs.build_config("pigpaxos", 9, pig=PigConfig(n_groups=2)),
            vs.build_config("pigpaxos", 9, pig=PigConfig(n_groups=4))]
    grid = [(ci, k, s) for ci in range(2) for k in (4, 8) for s in (0, 1, 2)]
    before = vs.trace_counts()
    out = vs.simulate_grid(cfgs, grid, 0.1, 0.05)
    after = vs.trace_counts()
    new = {k: v - before.get(k, 0) for k, v in after.items()
           if v != before.get(k, 0)}
    assert sum(new.values()) == 1, new          # one compile for 12 cells
    assert not out["exhausted"].any()
    out2 = vs.simulate_grid(cfgs, grid, 0.1, 0.05)
    assert vs.trace_counts() == after           # cache hit on re-run
    assert np.array_equal(out["throughput"], out2["throughput"])


def test_exhausted_grid_retries_with_larger_budget():
    cfg = vs.build_config("pigpaxos", 9, pig=PigConfig(n_groups=2))
    out = vs.simulate_grid([cfg], [(0, 8, 0)], 0.2, 0.05, steps=32)
    assert not out["exhausted"].any()
    assert out["steps"][0] > 32                 # budget was doubled


# ------------------------------------------------- sharded dispatch
def _small_grid():
    cfgs = [vs.build_config("pigpaxos", 9, pig=PigConfig(n_groups=2, prc=1)),
            vs.build_config("paxos", 9)]
    grid = [(ci, k, s) for ci in range(2) for k in (4, 8) for s in range(6)]
    return cfgs, grid


def test_sharded_equals_unsharded_single_device():
    """chunked sharded dispatch == the one-call grid, bit for bit (this
    process sees one device; the 4-device check is the subprocess test)."""
    cfgs, grid = _small_grid()
    want = vs.simulate_grid(cfgs, grid, 0.1, 0.05)
    for chunk in (64, 7):                       # one chunk / ragged chunks
        got = vs.simulate_grid_sharded(cfgs, grid, 0.1, 0.05, chunk=chunk)
        for key in ("throughput", "median_s", "p99_s", "committed"):
            np.testing.assert_array_equal(np.asarray(want[key]), got[key],
                                          err_msg=f"chunk={chunk} {key}")
        sh = got["sharding"]
        assert sh["devices"] >= 1
        assert sum(m["cells"] for m in sh["chunks"]) == len(grid)
        assert all(m["wall_s"] > 0 for m in sh["chunks"])


def test_sharded_exhausted_cells_retry():
    cfgs, _ = _small_grid()
    out = vs.simulate_grid_sharded(cfgs, [(0, 8, 0), (1, 8, 1)], 0.2, 0.05,
                                   steps=32, chunk=2)
    assert not out["exhausted"].any()
    assert (out["steps"] > 32).all()


def test_sharded_grid_multidevice_subprocess():
    """shard_map AND pmap over 4 forced host devices == single device,
    bit for bit, chunked and unchunked (subprocess keeps pytest's own
    jax single-device)."""
    import os
    import subprocess
    import sys
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "tests/shard_worker.py"],
        capture_output=True, text=True, env=env, timeout=600,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "OK all" in r.stdout


# ------------------------------------------------- pallas fan-in kernel
def test_kernel_pallas_matches_lax_path():
    """The Pallas segmented fan-in kernel is a drop-in for the sort-based
    lax path: same grid, same tolerances as the DES cross-check."""
    pig = PigConfig(n_groups=3, prc=1)
    kw = dict(pig=pig, clients=(10, 20), seeds=(0, 1),
              duration=0.15, warmup=0.05)
    lax_u = vs.simulate_scenario("pigpaxos", 25, kernel="lax", **kw)
    pal_u = vs.simulate_scenario("pigpaxos", 25, kernel="pallas", **kw)
    for a, b in zip(lax_u, pal_u):
        assert b["throughput"] == pytest.approx(a["throughput"], rel=1e-5)
        assert b["median_ms"] == pytest.approx(a["median_ms"], rel=1e-4)
        assert b["p99_ms"] == pytest.approx(a["p99_ms"], rel=1e-4)


def test_kernel_pallas_multigroup_and_faulty():
    """Kernel parity holds across R (segment shapes) and under fault masks
    (down followers = +inf arrivals, the kernel's masked-slot path)."""
    from repro.faults import crash_window
    for r in (1, 4):
        cfgs = [vs.build_config("pigpaxos", 13, pig=PigConfig(n_groups=r))]
        grid = [(0, 8, s) for s in range(4)]
        a = vs.simulate_grid(cfgs, grid, 0.1, 0.05, kernel="lax")
        b = vs.simulate_grid(cfgs, grid, 0.1, 0.05, kernel="pallas")
        np.testing.assert_allclose(np.asarray(a["throughput"]),
                                   np.asarray(b["throughput"]), rtol=1e-5)
    masks = crash_window(5, 0.02, 0.08).to_masks(13, 0.2)
    cfgs = [vs.build_config("pigpaxos", 13, pig=PigConfig(n_groups=3),
                            masks=masks)]
    grid = [(0, 8, s) for s in range(4)]
    a = vs.simulate_grid(cfgs, grid, 0.2, 0.0, kernel="lax")
    b = vs.simulate_grid(cfgs, grid, 0.2, 0.0, kernel="pallas")
    np.testing.assert_allclose(np.asarray(a["throughput"]),
                               np.asarray(b["throughput"]), rtol=1e-5)


def test_resolve_kernel():
    assert vs._resolve_kernel("auto", "epaxos") == "lax"
    assert vs._resolve_kernel("lax", "group") == "lax"
    assert vs._resolve_kernel("pallas", "group") == "pallas"
    with pytest.raises(ValueError):
        vs._resolve_kernel("nope", "group")


# ------------------------------------------------------ runner / spec
def test_runner_batch_backend_artifact():
    sc = Scenario(name="t/batch", protocol="pigpaxos", n=9,
                  pig=PigConfig(n_groups=2), backend="batch",
                  clients=(4, 8), seeds=(1, 2), duration=0.15, warmup=0.05)
    art = runner.run_scenarios([sc], quick=False)
    sa = art["scenarios"][0]
    assert sa["backend"] == "batch"
    assert len(sa["units"]) == 4
    assert len(sa["replicates"]) == 2
    for u in sa["units"]:
        assert u["backend"] == "batch"
        assert u["throughput"] > 0
        assert "retry_risk" in u
    assert sa["summary"]["throughput"]["mean"] > 0


def test_backend_override_switches_batch_ok_scenarios():
    des = Scenario(name="t/ovr", protocol="pigpaxos", n=9,
                   pig=PigConfig(n_groups=2), batch_ok=True,
                   clients=(4,), seeds=(1,), duration=0.15, warmup=0.05)
    art = runner.run_scenarios([des], quick=False, backend_override="batch")
    assert art["scenarios"][0]["backend"] == "batch"
    # not batch_ok -> stays on the DES
    des2 = Scenario(name="t/ovr2", protocol="pigpaxos", n=9,
                    pig=PigConfig(n_groups=2),
                    clients=(4,), seeds=(1,), duration=0.15, warmup=0.05)
    art2 = runner.run_scenarios([des2], quick=False,
                                backend_override="batch")
    assert art2["scenarios"][0]["backend"] == "des"


def test_batch_backend_rejects_unsupported_specs():
    # crash/recover windows ARE mask-expressible since the fault subsystem
    # (see tests/test_faults.py) — partitions and friends still are not
    with pytest.raises(ValueError):
        Scenario(name="t/bad1", protocol="pigpaxos", n=9, backend="batch",
                 failures=(("partition", 1, 2, 0.1),))
    Scenario(name="t/ok1", protocol="pigpaxos", n=9, backend="batch",
             failures=(("crash", 3, 0.1), ("recover", 3, 0.2)))
    # timeline collection needs a fault plan on the batch backend
    with pytest.raises(ValueError):
        Scenario(name="t/bad2", protocol="pigpaxos", n=9, backend="batch",
                 collect=("timeline",))
    with pytest.raises(ValueError):
        Scenario(name="t/bad3", protocol="pigpaxos", n=9, backend="nope")
    from repro.core import WorkloadConfig
    with pytest.raises(ValueError):
        vs.build_config("pigpaxos", 9, pig=PigConfig(n_groups=2),
                        workload=WorkloadConfig(arrival="poisson"))
