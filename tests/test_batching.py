"""Leader-side batching / slot pipelining / admission control (ISSUE 8).

Pins the tentpole's contracts:

* ``BatchConfig(max_batch=1)`` is BYTE-IDENTICAL to the unbatched engine —
  the exact engine with a degenerate batch config must reproduce the seed
  stack's golden traces event-for-event (same heap sequence, same RNG
  consumption, same applied logs).
* Batching at saturation buys real throughput (the >= 2x regression-gate
  floor for paxos/N=25 lives here too, so a local run catches the erosion
  before CI does).
* Finite pipeline depths bound leader state at a throughput cost but never
  break agreement.
* Batched runs survive a leader crash+recovery under the linearizability
  auditor: batch buffers are dropped on crash, held batches re-proposed by
  the new leader, per-command session dedup intact.
* The DES<->batch-backend cross-check tolerance for batched cells is
  pinned to the same [0.90, 1.10] window the regression gate enforces.
* ``repro.runtime.AdmissionPolicy`` sheds by queue length and token
  bucket, with exact counters, and open-loop clients honor
  ``reject_action="drop"``.
"""
import numpy as np
import pytest

from repro.core import (BatchConfig, Cluster, PigConfig, WorkloadConfig,
                        agreement_ok)
from repro.faults import audit_cluster, crash_window, apply_plan
from repro.runtime import AdmissionPolicy, attach_admission

WL_RT = WorkloadConfig(request_timeout=25e-3)


def _applied(cluster):
    return [[(slot, c.client_id, c.seq, c.op, c.key)
             for slot, c in nd.applied_log] for nd in cluster.nodes]


# ============================================== max_batch=1 golden neutrality
@pytest.mark.parametrize("proto,pig", [
    ("paxos", None),
    ("pigpaxos", PigConfig(n_groups=2)),
    ("epaxos", None),
], ids=["paxos", "pig_r2", "epaxos"])
def test_max_batch_1_is_bit_identical_to_seed_stack(proto, pig):
    ref = Cluster(proto, 5, pig=pig, seed=7, engine="ref")
    st_ref = ref.measure(duration=0.3, warmup=0.1, clients=8)
    new = Cluster(proto, 5, pig=pig, seed=7, engine="exact",
                  batch=BatchConfig(max_batch=1, max_delay_ms=1.0))
    st_new = new.measure(duration=0.3, warmup=0.1, clients=8)
    # identical virtual execution: every event fired in the same order
    assert ref.sched.events == new.sched.events
    assert ref.sched._seq == new.sched._seq
    assert ref.sched.now == new.sched.now
    assert _applied(ref) == _applied(new)
    assert st_ref.committed == st_new.committed
    np.testing.assert_array_equal(st_ref.msg_out, st_new.msg_out)
    np.testing.assert_array_equal(st_ref.msg_in, st_new.msg_in)
    assert st_ref.throughput == st_new.throughput
    assert st_ref.median_ms == st_new.median_ms


def test_batching_rejected_on_seed_engine():
    with pytest.raises(ValueError, match="seed stack"):
        Cluster("paxos", 5, engine="ref", batch=BatchConfig(max_batch=4))
    with pytest.raises(ValueError, match="seed stack"):
        Cluster("paxos", 5, engine="ref", pipeline_depth=2)


# ================================================= throughput at saturation
def test_batching_doubles_saturated_throughput_paxos_n25():
    """The regression-gate claim, runnable locally: m=8 >= 2x m=1 on the
    saturated paxos/N=25 cell (CI measures ~6x; 2x is the erosion floor)."""
    tput = {}
    for m in (1, 8):
        c = Cluster("paxos", 25, seed=1, engine="fast",
                    batch=BatchConfig(max_batch=m, max_delay_ms=1.0))
        st = c.measure(duration=0.3, warmup=0.15, clients=64)
        tput[m] = st.throughput
        assert agreement_ok(c)
    assert tput[8] >= 2.0 * tput[1], tput


def test_pipeline_depth_throttles_but_preserves_agreement():
    """depth=1 serializes slots (strictly slower than the unbounded
    native default) yet commits and agrees; deeper pipelines recover."""
    tput = {}
    for depth in (0, 1, 4):
        c = Cluster("paxos", 5, seed=3, engine="exact",
                    pipeline_depth=depth)
        st = c.measure(duration=0.3, warmup=0.1, clients=8)
        assert st.committed > 0
        assert agreement_ok(c)
        tput[depth] = st.throughput
    assert tput[1] < tput[0]
    assert tput[1] <= tput[4]


# ==================================================== faults under batching
@pytest.mark.parametrize("proto,pig", [
    ("paxos", None),
    ("pigpaxos", PigConfig(n_groups=2, prc=1)),
], ids=["paxos", "pigpaxos"])
def test_batched_leader_crash_recovery_audits_clean(proto, pig):
    c = Cluster(proto, 7, pig=pig, seed=5, engine="exact",
                record_history=True,
                batch=BatchConfig(max_batch=4, max_delay_ms=1.0))
    apply_plan(c, crash_window(0, 0.3, 0.5), horizon=1.5)
    st = c.measure(duration=0.7, warmup=0.1, clients=6, workload=WL_RT)
    assert st.committed > 0
    # service resumed after the new leader re-proposes held batches
    post = [t for cl in c.clients for (t, _l) in cl.latencies if t > 0.55]
    assert post
    res = audit_cluster(c)
    assert res.ok, (proto, res.violations)
    c.run(until=2.0)
    assert agreement_ok(c)


# ================================================== DES <-> batch fidelity
def test_des_batch_xcheck_tolerance_is_pinned():
    """The batched paxos cell's DES<->batch throughput ratio must sit in
    the same [0.90, 1.10] window benchmarks/reference_bounds.json gates."""
    from repro import experiments
    scs = [experiments.get("batching/paxos/m=8"),
           experiments.get("batching/paxos/m=8/batch")]
    art = experiments.run_scenarios(scs, quick=True, ignore_quick_skip=True)
    means = {sa["name"]: sa["summary"]["throughput"]["mean"]
             for sa in art["scenarios"]}
    ratio = (means["batching/paxos/m=8/batch"]
             / means["batching/paxos/m=8"])
    assert 0.90 <= ratio <= 1.10, means


# ======================================================== admission control
def test_admission_policy_validation():
    with pytest.raises(ValueError, match="max_queue"):
        AdmissionPolicy(max_queue=-1)
    with pytest.raises(ValueError, match="rate_hz"):
        AdmissionPolicy(rate_hz=-1.0)
    with pytest.raises(ValueError, match="burst"):
        AdmissionPolicy(rate_hz=10.0, burst=0.5)
    with pytest.raises(ValueError, match="disabled"):
        AdmissionPolicy(max_queue=0, rate_hz=0.0)


def test_workload_reject_action_validation():
    with pytest.raises(ValueError, match="reject_action"):
        WorkloadConfig(reject_action="bounce")


def test_token_bucket_sheds_and_open_loop_drop_frees_slots():
    """Open-loop load far above the bucket rate: the policy sheds the
    excess, the 'drop' client abandons shed ops (no 5 ms retry storm),
    and admissions stay within rate x time + burst."""
    wl = WorkloadConfig(arrival="poisson", rate_hz=400.0, max_outstanding=8,
                        reject_action="drop")
    c = Cluster("paxos", 5, seed=2, engine="exact", record_history=True)
    pol = AdmissionPolicy(max_queue=0, rate_hz=100.0, burst=4.0)
    stats = attach_admission(c, pol)
    st = c.measure(duration=0.4, warmup=0.1, clients=6, workload=wl)
    assert stats["shed_rate"] > 0
    assert stats["shed_queue"] == 0
    assert sum(cl.rejected for cl in c.clients) == stats["shed_rate"]
    # token bucket cap: admitted <= rate * elapsed + burst (+1 rounding)
    assert stats["admitted"] <= 100.0 * c.sched.now + pol.burst + 1
    assert st.committed > 0
    assert audit_cluster(c).ok


def test_queue_backpressure_sheds_under_closed_loop_saturation():
    c = Cluster("paxos", 5, seed=4, engine="exact")
    stats = attach_admission(c, AdmissionPolicy(max_queue=1))
    st = c.measure(duration=0.3, warmup=0.1, clients=16, workload=WL_RT)
    assert stats["shed_queue"] > 0
    # closed-loop clients ride the bounce->retry path and still complete
    assert st.committed > 0
    assert agreement_ok(c)


def test_scenario_validation_for_batching_knobs():
    from repro.experiments import Scenario
    with pytest.raises(ValueError, match="max_batch"):
        Scenario(name="x", protocol="paxos", n=5,
                 batch={"max_batch": 0, "max_delay_ms": 1.0})
    with pytest.raises(ValueError, match="pipeline_depth"):
        Scenario(name="x", protocol="paxos", n=5, pipeline_depth=-1)
    with pytest.raises(ValueError, match="batch backend"):
        Scenario(name="x", protocol="paxos", n=5, backend="batch",
                 batch_ok=True, admission={"max_queue": 8})
