"""Worker script for multi-device collective tests.

Run in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the main pytest process keeps its single-device view.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax                                    # noqa: E402
import jax.numpy as jnp                       # noqa: E402
import numpy as np                            # noqa: E402
from jax.sharding import PartitionSpec as P   # noqa: E402
from jax.experimental.shard_map import shard_map  # noqa: E402

from repro.collectives import (direct_allreduce, pig_allreduce,  # noqa: E402
                               pig_allreduce_quantized)
from repro.collectives.schedules import dcn_bytes_per_chip  # noqa: E402
from repro.roofline import collective_stats  # noqa: E402


def main() -> None:
    assert jax.device_count() == 8, jax.device_count()
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    key = jax.random.PRNGKey(0)
    # per-device distinct values along (pod, data); replicated over model
    x = jax.random.normal(key, (4, 1031), jnp.float32)    # odd size: pad path

    def run(fn):
        m = shard_map(fn, mesh=mesh, in_specs=P(("pod", "data")),
                      out_specs=P(("pod", "data")), check_rep=False)
        return jax.jit(m)

    def direct(xs):
        return direct_allreduce(xs, ("pod", "data"))

    def pig(xs):
        return pig_allreduce(xs, group_axis="data", pod_axis="pod")

    def pig_rot(xs):
        return pig_allreduce(xs, group_axis="data", pod_axis="pod", rotation=3)

    want = np.asarray(jax.jit(run(direct))(x))
    got = np.asarray(jax.jit(run(pig))(x))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    got_rot = np.asarray(jax.jit(run(pig_rot))(x))
    np.testing.assert_allclose(got_rot, want, rtol=1e-5, atol=1e-5)
    print("OK equivalence")

    # quantized path: error bounded by quant step and EF residual is exact
    def pigq(xs):
        y, r = pig_allreduce_quantized(xs, None, group_axis="data",
                                       pod_axis="pod", block=256)
        return y, r

    y, r = jax.jit(shard_map(pigq, mesh=mesh, in_specs=P(("pod", "data")),
                             out_specs=(P(("pod", "data")), P(("pod", "data"))),
                             check_rep=False))(x)
    y = np.asarray(y)
    err = np.abs(y - want)
    step = np.abs(x).max() / 127.0
    assert err.max() <= 2 * 2 * step + 1e-5, (err.max(), step)   # 2 pods
    print("OK quantized")

    # HLO accounting: the pig schedule must move fewer bytes over the pod
    # (DCN) boundary than the direct schedule (the whole point)
    from repro.roofline import collective_stats

    def stats_of(fn, out_specs=P(("pod", "data"))):
        m = shard_map(fn, mesh=mesh, in_specs=P(("pod", "data")),
                      out_specs=out_specs, check_rep=False)
        txt = jax.jit(m).lower(x).compile().as_text()
        return collective_stats(txt, pod_size=4)   # 8 devices / 2 pods

    s_direct = stats_of(direct)
    s_pig = stats_of(pig)
    print("direct:", s_direct)
    print("pig:", s_pig)
    assert s_direct["cross_pod"] > 0
    # group size 2 => the DCN hop carries ~1/2 of the direct bytes
    assert s_pig["cross_pod"] <= 0.55 * s_direct["cross_pod"], (
        s_pig["cross_pod"], s_direct["cross_pod"])

    # closed-form model sanity
    assert dcn_bytes_per_chip(100.0, 4, 2, "pig") == dcn_bytes_per_chip(
        100.0, 1, 2, "direct") / 4
    print("OK all")


if __name__ == "__main__":
    main()
