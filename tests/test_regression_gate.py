"""Regression-gate unit tests (ISSUE 5 satellite): the gate itself was
untested — corrupt artifacts and out-of-bounds fixtures must fail loudly,
in-bounds fixtures must pass, and the DES<->batch fidelity pairs must be
checked as ratios."""
import json

import pytest

from benchmarks.regression_gate import (GateError, evaluate, load_artifacts)


def _sa(name, tput, units=()):
    return {"name": name, "summary": {"throughput": {"mean": tput}},
            "units": list(units)}


def _write(tmp_path, payload, fname="bench.json"):
    p = tmp_path / fname
    p.write_text(payload if isinstance(payload, str) else json.dumps(payload))
    return str(p)


# ------------------------------------------------------------------ bounds
def test_in_bounds_passes_and_reports():
    seen = {"fam/a": _sa("fam/a", 100.0)}
    failures, lines = evaluate(seen, {"bounds": {"fam/a": [80, 120]}})
    assert failures == []
    assert any("ok" in ln and "fam/a" in ln for ln in lines)


def test_out_of_bounds_fails():
    seen = {"fam/a": _sa("fam/a", 150.0)}
    failures, _ = evaluate(seen, {"bounds": {"fam/a": [80, 120]}})
    assert failures and "outside" in failures[0]
    # a broken measurement window (None mean) is just as fatal
    failures, _ = evaluate({"fam/a": _sa("fam/a", None)},
                           {"bounds": {"fam/a": [80, 120]}})
    assert failures


def test_missing_scenario_fails_never_shrinks():
    failures, _ = evaluate({}, {"bounds": {"fam/gone": [80, 120]}})
    assert failures and "MISSING" in failures[0]


# ---------------------------------------------------------------- fidelity
def test_fidelity_ratio_inside_window_passes():
    seen = {"fam/a": _sa("fam/a", 100.0),
            "fam/a/batch": _sa("fam/a/batch", 95.0)}
    failures, lines = evaluate(seen, {"fidelity": {"fam/a": [0.9, 1.1]}})
    assert failures == []
    assert any("xcheck" in ln for ln in lines)


def test_fidelity_ratio_outside_window_fails():
    seen = {"fam/a": _sa("fam/a", 100.0),
            "fam/a/batch": _sa("fam/a/batch", 80.0)}
    failures, _ = evaluate(seen, {"fidelity": {"fam/a": [0.9, 1.1]}})
    assert failures and "ratio" in failures[0]


def test_fidelity_missing_half_fails():
    seen = {"fam/a": _sa("fam/a", 100.0)}
    failures, _ = evaluate(seen, {"fidelity": {"fam/a": [0.9, 1.1]}})
    assert failures and "incomplete" in failures[0]


# ----------------------------------------------------------------- speedup
def test_speedup_floor_passes_and_fails():
    ref = {"speedup": {"fam/m=8": {"over": "fam/m=1", "min": 2.0}}}
    seen = {"fam/m=1": _sa("fam/m=1", 100.0),
            "fam/m=8": _sa("fam/m=8", 250.0)}
    failures, lines = evaluate(seen, ref)
    assert failures == []
    assert any("speedup" in ln and "2.50x" in ln for ln in lines)
    seen["fam/m=8"] = _sa("fam/m=8", 150.0)
    failures, _ = evaluate(seen, ref)
    assert failures and "below the 2.0x floor" in failures[0]


def test_speedup_missing_half_fails():
    ref = {"speedup": {"fam/m=8": {"over": "fam/m=1", "min": 2.0}}}
    failures, _ = evaluate({"fam/m=8": _sa("fam/m=8", 250.0)}, ref)
    assert failures and "incomplete" in failures[0]


# ---------------------------------------------------------------- overload
def _ovl_sa(name, goodputs_by_clients):
    units = [{"clients": c, "extras": {"goodput": g}}
             for c, gs in goodputs_by_clients.items() for g in gs]
    return {"name": name, "summary": {"throughput": {"mean": 1.0}},
            "units": units}


def test_overload_window_uses_highest_load_point_only():
    # goodput holds at the top point -> pass, even though low-load differs
    sa = _ovl_sa("ovl/adm", {20: [1900.0], 80: [1700.0, 1800.0]})
    ref = {"overload": {"ovl/adm": {"goodput_at_max": [1300, 2200]}}}
    failures, lines = evaluate({"ovl/adm": sa}, ref)
    assert failures == []
    assert any("clients=80" in ln for ln in lines)
    # collapse ceiling: the no-admission baseline must stay collapsed
    sa = _ovl_sa("ovl/noadm", {20: [2000.0], 80: [900.0]})
    ref = {"overload": {"ovl/noadm": {"goodput_at_max": [0, 400]}}}
    failures, _ = evaluate({"ovl/noadm": sa}, ref)
    assert failures and "outside" in failures[0]


def test_overload_missing_or_malformed_fails():
    ref = {"overload": {"ovl/adm": {"goodput_at_max": [1300, 2200]}}}
    failures, _ = evaluate({}, ref)
    assert failures and "MISSING" in failures[0]
    with pytest.raises(GateError, match="overload extras"):
        evaluate({"ovl/adm": _sa("ovl/adm", 100.0,
                                 units=[{"clients": 80}])}, ref)


# ------------------------------------------------------------------- audit
def test_audit_violation_fails_regardless_of_throughput():
    sa = _sa("fam/a", 100.0,
             units=[{"consistency": "violation",
                     "audit": {"violations": ["stale read on key 3"]}}])
    failures, _ = evaluate({"fam/a": sa}, {"bounds": {"fam/a": [80, 120]}})
    assert failures and "linearizability" in failures[0]


# --------------------------------------------------------------- artifacts
def test_corrupt_artifact_fails_loudly(tmp_path):
    with pytest.raises(GateError, match="unreadable"):
        load_artifacts([_write(tmp_path, "{not json")])
    with pytest.raises(GateError, match="not a JSON object"):
        load_artifacts([_write(tmp_path, json.dumps([1, 2]))])
    with pytest.raises(GateError, match="malformed scenario"):
        load_artifacts([_write(tmp_path,
                               {"scenarios": [{"name": "x"}]})])
    with pytest.raises(GateError, match="unreadable"):
        load_artifacts([str(tmp_path / "does-not-exist.json")])


def test_load_artifacts_reads_both_shapes(tmp_path):
    raw = {"scenarios": [_sa("fam/a", 10.0)]}
    wrapped = {"experiments": {"scenarios": [_sa("fam/b", 20.0)]}}
    seen = load_artifacts([_write(tmp_path, raw, "a.json"),
                           _write(tmp_path, wrapped, "b.json")])
    assert set(seen) == {"fam/a", "fam/b"}


def test_malformed_summary_is_a_gate_error():
    with pytest.raises(GateError, match="malformed summary"):
        evaluate({"fam/a": {"name": "fam/a", "summary": {}}},
                 {"bounds": {"fam/a": [1, 2]}})


# ------------------------------------------------------- committed bounds
def test_committed_bounds_file_is_well_formed():
    from benchmarks.regression_gate import DEFAULT_BOUNDS
    with open(DEFAULT_BOUNDS) as f:
        ref = json.load(f)
    assert ref["bounds"], "bounds must never be empty"
    for name, window in {**ref["bounds"], **ref.get("fidelity", {})}.items():
        lo, hi = window
        assert 0 <= lo < hi, (name, window)
    # every fidelity base pairs a committed bound or at least a DES name
    for base in ref.get("fidelity", {}):
        assert not base.endswith("/batch"), base
    for name, spec in ref.get("speedup", {}).items():
        assert spec["over"] != name and spec["min"] > 1.0, (name, spec)
    for name, spec in ref.get("overload", {}).items():
        lo, hi = spec["goodput_at_max"]
        assert 0 <= lo < hi, (name, spec)


# ------------------------------------------------------- vectorsim payload
def _vs_payload(**over):
    base = {
        "bench": "vectorsim",
        "grid": {"cells": 4},
        "xcheck": {"max_abs_tput_err": 0.04, "max_abs_median_err": 0.03},
        "sweep1025": {"throughput": 1500},
        "sharded": {"device_count": 1, "kernel": "lax",
                    "chunks": [{"cells": 2}, {"cells": 2}]},
    }
    base.update(over)
    return base


_VS_REF = {"xcheck_max_abs_tput_err": 0.10, "xcheck_max_abs_median_err": 0.10,
           "sweep1025_throughput": [1100, 1900], "require_sharded": True}


def test_vectorsim_payload_in_bounds_passes():
    from benchmarks.regression_gate import evaluate_vectorsim
    failures, lines = evaluate_vectorsim(_vs_payload(), _VS_REF)
    assert failures == []
    assert sum("ok" in ln for ln in lines) == 4


def test_vectorsim_xcheck_and_sweep_fail_out_of_bounds():
    from benchmarks.regression_gate import evaluate_vectorsim
    bad = _vs_payload(xcheck={"max_abs_tput_err": 0.2,
                              "max_abs_median_err": 0.03})
    failures, _ = evaluate_vectorsim(bad, _VS_REF)
    assert failures and "max_abs_tput_err" in failures[0]
    bad = _vs_payload(sweep1025={"throughput": 3000})
    failures, _ = evaluate_vectorsim(bad, _VS_REF)
    assert failures and "sweep1025" in failures[0]


def test_vectorsim_missing_sharded_section_fails():
    from benchmarks.regression_gate import evaluate_vectorsim
    p = _vs_payload()
    del p["sharded"]
    failures, _ = evaluate_vectorsim(p, _VS_REF)
    assert failures and "sharded" in failures[0]
    # chunk cells must account for every grid cell
    p = _vs_payload(sharded={"device_count": 1, "kernel": "lax",
                             "chunks": [{"cells": 1}]})
    failures, _ = evaluate_vectorsim(p, _VS_REF)
    assert failures and "!= grid cells" in failures[0]


def test_load_vectorsim_picks_only_vectorsim_payloads(tmp_path):
    from benchmarks.regression_gate import load_vectorsim
    a = _write(tmp_path, _vs_payload(), "BENCH_vectorsim.json")
    b = _write(tmp_path, {"scenarios": []}, "other.json")
    found = load_vectorsim([a, b])
    assert list(found) == [a]


def test_malformed_vectorsim_payload_is_a_gate_error():
    from benchmarks.regression_gate import evaluate_vectorsim
    with pytest.raises(GateError):
        evaluate_vectorsim({"bench": "vectorsim", "xcheck": {}}, _VS_REF)


# ------------------------------------------- sim_engine tracing overhead
def test_sim_engine_overhead_under_cap_passes():
    from benchmarks.regression_gate import evaluate_sim_engine
    ref = {"tracing_overhead_max": 0.05}
    failures, lines = evaluate_sim_engine(
        {"bench": "sim_engine", "tracing_overhead_frac": 0.012}, ref)
    assert failures == []
    assert any("ok" in ln and "tracing_overhead" in ln for ln in lines)
    # no section configured -> nothing checked, nothing reported
    assert evaluate_sim_engine({"bench": "sim_engine"}, {}) == ([], [])


def test_sim_engine_overhead_over_cap_fails():
    from benchmarks.regression_gate import evaluate_sim_engine
    failures, _ = evaluate_sim_engine(
        {"bench": "sim_engine", "tracing_overhead_frac": 0.09},
        {"tracing_overhead_max": 0.05})
    assert failures and "ceiling" in failures[0]
    with pytest.raises(GateError):
        evaluate_sim_engine({"bench": "sim_engine"},
                            {"tracing_overhead_max": 0.05})


def test_load_sim_engine_picks_only_sim_payloads(tmp_path):
    from benchmarks.regression_gate import load_sim_engine
    a = _write(tmp_path, {"bench": "sim_engine", "tracing_overhead_frac": 0.0},
               "BENCH_sim.json")
    b = _write(tmp_path, _vs_payload(), "BENCH_vectorsim.json")
    assert list(load_sim_engine([a, b])) == [a]


# ---------------------------------------------------- obs relay fairness
def _fair_sa(name, busy):
    return {"name": name, "summary": {"throughput": {"mean": 1000.0}},
            "spec": {"n": 1 + len(busy)},
            "replicates": [{"throughput": 1000.0, "extras": {"obs": {
                "cpu_busy_s": {str(i + 1): b for i, b in enumerate(busy)}}}}]}


_FAIR_SPEC = {"rotating": "obs/fairness/rotating",
              "static": "obs/fairness/static",
              "rotating_max_over_mean_max": 1.5}


def test_obs_fairness_rotating_flatter_passes():
    from benchmarks.regression_gate import evaluate_obs_fairness
    seen = {"obs/fairness/rotating": _fair_sa("obs/fairness/rotating",
                                              [1.0, 1.1, 0.9, 1.0]),
            "obs/fairness/static": _fair_sa("obs/fairness/static",
                                            [3.0, 0.5, 0.5, 0.5])}
    failures, lines = evaluate_obs_fairness(seen, _FAIR_SPEC)
    assert failures == []
    assert any("ok" in ln and "fairness" in ln for ln in lines)


def test_obs_fairness_inverted_or_hot_fails():
    from benchmarks.regression_gate import evaluate_obs_fairness
    flat = _fair_sa("obs/fairness/static", [1.0, 1.0, 1.0, 1.1])
    hot = _fair_sa("obs/fairness/rotating", [3.0, 0.5, 0.5, 0.5])
    failures, _ = evaluate_obs_fairness(
        {"obs/fairness/rotating": hot, "obs/fairness/static": flat},
        _FAIR_SPEC)
    assert failures and "rotating" in failures[0]
    # missing half of the pair must fail loudly, never shrink
    failures, _ = evaluate_obs_fairness(
        {"obs/fairness/rotating": hot}, _FAIR_SPEC)
    assert failures and "MISSING" in failures[0]
    # zero busy accounting is a broken obs export, not a pass
    dead = _fair_sa("obs/fairness/rotating", [0.0, 0.0])
    with pytest.raises(GateError):
        evaluate_obs_fairness(
            {"obs/fairness/rotating": dead, "obs/fairness/static": flat},
            _FAIR_SPEC)
