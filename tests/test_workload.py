"""Workload-layer tests: Zipfian key skew, open-loop Poisson arrivals,
conflict (hot-key) workloads, mixed payloads, and WAN topology geometry."""
import numpy as np
import pytest

from repro.core import (Cluster, OpenLoopClient, PigConfig, Topology,
                        WorkloadConfig, wan_topology, zipf_cdf)


# ----------------------------------------------------------------- zipfian
def test_zipf_cdf_shape():
    cdf = zipf_cdf(1000, 0.99)
    assert cdf.shape == (1000,)
    assert cdf[-1] == 1.0
    assert np.all(np.diff(cdf) > 0)
    # rank-1 mass dominates rank-2 by ~2^theta
    p1, p2 = cdf[0], cdf[1] - cdf[0]
    assert p1 / p2 == pytest.approx(2 ** 0.99, rel=1e-6)


def _key_histogram(workload, n_ops=4000, proto="paxos"):
    c = Cluster(proto, 5, seed=3)
    c.add_clients(8, workload, stop_at=10.0)
    c.run(until=10.0)
    keys = [cmd.key for _s, cmd in c.nodes[0].applied_log]
    assert len(keys) >= n_ops
    return np.bincount(keys[:n_ops], minlength=workload.n_keys)


def test_zipfian_key_frequency_sanity():
    """Observed key frequencies must follow the Zipf law: the hottest key is
    rank 0, and the head holds far more mass than under uniform draws."""
    wl = WorkloadConfig(key_dist="zipfian", zipf_theta=0.99, n_keys=100)
    hist = _key_histogram(wl)
    assert int(np.argmax(hist)) == 0
    n_ops = hist.sum()
    cdf = zipf_cdf(100, 0.99)
    # top-10 mass matches the analytic head probability within noise
    expect_head = cdf[9]
    got_head = hist[:10].sum() / n_ops
    assert got_head == pytest.approx(expect_head, abs=0.05)
    # and is far above the uniform head mass (0.10)
    assert got_head > 0.4


def test_uniform_keys_stay_uniform():
    wl = WorkloadConfig(key_dist="uniform", n_keys=100)
    hist = _key_histogram(wl)
    assert hist[:10].sum() / hist.sum() == pytest.approx(0.10, abs=0.04)


# ---------------------------------------------------------------- conflict
def test_conflict_workload_hot_key_rate():
    wl = WorkloadConfig(key_dist="conflict", conflict_rate=0.3, n_keys=100)
    hist = _key_histogram(wl)
    assert hist[0] / hist.sum() == pytest.approx(0.3, abs=0.05)
    # non-hot keys exclude key 0 and stay roughly uniform
    assert hist[1:].min() >= 0


def test_conflict_workload_epaxos_agreement():
    """EPaxos orders only *interfering* commands; under a hot-key workload
    every replica must apply the same-key (conflicting) commands in the
    same order, even though cross-key order may differ."""
    wl = WorkloadConfig(key_dist="conflict", conflict_rate=0.5)
    c = Cluster("epaxos", 5, seed=4)
    c.add_clients(6, wl, stop_at=0.4)
    c.run(until=0.6)
    per_key = []
    for nd in c.nodes:
        d = {}
        for _s, cmd in nd.applied_log:
            d.setdefault(cmd.key, []).append((cmd.client_id, cmd.seq))
        per_key.append(d)
    keys = set().union(*per_key)
    assert 0 in keys   # the hot key saw traffic
    for k in keys:
        seqs = [d.get(k, []) for d in per_key]
        ref = max(seqs, key=len)
        assert all(s == ref[:len(s)] for s in seqs), k
    assert sum(nd.committed_count for nd in c.nodes) > 0


# ------------------------------------------------------------- open loop
def _openloop_run(seed, rate=150.0, protocol="pigpaxos"):
    wl = WorkloadConfig(arrival="poisson", rate_hz=rate)
    c = Cluster(protocol, 5, pig=PigConfig(n_groups=2), seed=seed)
    st = c.measure(duration=0.4, warmup=0.1, clients=6, workload=wl)
    arrivals = sorted(t - lat for cl in c.clients for (t, lat) in cl.latencies)
    return st, arrivals, c


def test_openloop_clients_are_used():
    _, _, c = _openloop_run(1)
    assert all(isinstance(cl, OpenLoopClient) for cl in c.clients)


def test_openloop_poisson_interarrival_determinism_per_seed():
    """Same seed -> bit-identical arrival process and results; different
    seed -> a different draw."""
    st_a, arr_a, _ = _openloop_run(7)
    st_b, arr_b, _ = _openloop_run(7)
    st_c, arr_c, _ = _openloop_run(8)
    assert arr_a == arr_b
    assert st_a.throughput == st_b.throughput
    assert st_a.median_ms == st_b.median_ms
    assert arr_a != arr_c


def test_openloop_offered_load_is_met_below_saturation():
    """6 clients x 150 req/s = 900 req/s offered — far below a 5-node
    PigPaxos deployment's capacity, so achieved ~= offered."""
    st, _, _ = _openloop_run(2)
    assert st.throughput == pytest.approx(900, rel=0.15)


def test_openloop_interarrival_is_exponential_like():
    """Mean inter-arrival per client ~= 1/rate (CV ~ 1 for exponential)."""
    _, _, c = _openloop_run(3, rate=400.0)
    cl = max(c.clients, key=lambda cl: len(cl.latencies))
    arr = sorted(t - lat for (t, lat) in cl.latencies)
    gaps = np.diff(arr)
    assert len(gaps) > 30
    assert gaps.mean() == pytest.approx(1 / 400.0, rel=0.35)
    cv = gaps.std() / gaps.mean()
    assert 0.6 < cv < 1.4


# ---------------------------------------------------------- mixed payloads
def test_mixed_payload_distribution():
    wl = WorkloadConfig(write_fraction=1.0, n_keys=10,
                        payload_choices=(8, 1024),
                        payload_weights=(0.75, 0.25))
    c = Cluster("paxos", 3, seed=5)
    c.add_clients(4, wl, stop_at=0.5)
    c.run(until=0.7)
    sizes = [len(cmd.value) for _s, cmd in c.nodes[0].applied_log]
    assert set(sizes) <= {8, 1024}
    frac_small = sizes.count(8) / len(sizes)
    assert frac_small == pytest.approx(0.75, abs=0.08)


def test_workload_config_rejects_unknown_modes():
    with pytest.raises(ValueError):
        WorkloadConfig(key_dist="zipf")       # typo of "zipfian"
    with pytest.raises(ValueError):
        WorkloadConfig(arrival="open")        # typo of "poisson"


def test_payload_cdf_terminal_clamp():
    # 7 uniform weights: cumsum rounds below 1.0 without the clamp
    wl = WorkloadConfig(payload_choices=(8, 64, 256, 512, 1024, 1280, 2048))
    c = Cluster("paxos", 3, seed=6)
    from repro.core.cluster import Client
    cl = Client(c, 0, lambda: 0, wl, stop_at=0.0)
    assert cl._payload_cdf[-1] == 1.0
    class _One:                                    # rng.random() -> max float < 1
        def random(self):
            return 1.0 - 2**-53
    assert len(cl._pick_payload(_One())) == 2048   # last choice, no IndexError


# ------------------------------------------------------------ wan topology
def test_wan_topology_symmetry_and_diagonal():
    ms = [[0.15, 31, 35], [31, 0.15, 11], [35, 11, 0.15]]
    topo = wan_topology([2, 2, 2], ms)
    assert topo.n == 6
    assert topo.region_of == [0, 0, 1, 1, 2, 2]
    lat = topo.region_latency
    # symmetric cross-region latencies; intra-region (diagonal) is LAN-fast
    np.testing.assert_allclose(lat, lat.T)
    assert np.all(np.diag(lat) < 1e-3)
    assert np.all(lat[~np.eye(3, dtype=bool)] > np.diag(lat).max())
    # seconds, not milliseconds
    np.testing.assert_allclose(lat, np.asarray(ms) * 1e-3)


def test_wan_latency_sampling_matches_regions():
    ms = [[0.15, 31, 35], [31, 0.15, 11], [35, 11, 0.15]]
    topo = wan_topology([2, 2, 2], ms)
    rng = np.random.default_rng(0)
    # node 0 (region 0) -> node 4 (region 2): base 35ms + jitter
    samples = [topo.latency(rng, 0, 4) for _ in range(200)]
    assert min(samples) >= 35e-3
    assert np.mean(samples) == pytest.approx(35e-3 + topo.jitter, rel=0.2)
    # clients (ids >= n) are co-located with region 0
    s_client = [topo.latency(rng, topo.n + 3, 4) for _ in range(200)]
    assert min(s_client) >= 35e-3


def test_lan_topology_latency_positive():
    topo = Topology(n=3)
    rng = np.random.default_rng(1)
    s = [topo.latency(rng, 0, 1) for _ in range(100)]
    assert min(s) >= topo.base_latency
