"""Protocol behaviour tests: safety, liveness, message-count model validation."""
import pytest

from repro.core import (Cluster, PigConfig, WorkloadConfig, agreement_ok,
                        analytical)


def _flush_and_drain(c: Cluster, extra: float = 0.5) -> None:
    for nd in c.nodes:
        if getattr(nd, "is_leader", False) and not nd.crashed:
            nd.flush_commits()
    c.run(c.sched.now + extra)


# ------------------------------------------------------------------ safety
@pytest.mark.parametrize("proto,pig", [
    ("paxos", None),
    ("pigpaxos", PigConfig(n_groups=1, single_group_majority=True)),
    ("pigpaxos", PigConfig(n_groups=3)),
    ("pigpaxos", PigConfig(n_groups=3, prc=1, use_gray_list=True)),
])
def test_replica_agreement(proto, pig):
    c = Cluster(proto, 9, pig=pig, seed=11)
    st = c.measure(duration=0.4, warmup=0.1, clients=10)
    assert st.throughput > 500
    _flush_and_drain(c)
    assert agreement_ok(c)
    # every replica applied the same final state
    states = [nd.store.data for nd in c.nodes]
    assert all(s == states[0] for s in states)


def test_agreement_under_follower_crash():
    c = Cluster("pigpaxos", 9, pig=PigConfig(n_groups=3, prc=1), seed=13)
    c.crash_at(4, 0.15)
    st = c.measure(duration=0.5, warmup=0.1, clients=10)
    assert st.throughput > 200   # stays live (f < majority)
    _flush_and_drain(c)
    alive = Cluster.__new__(Cluster)  # reuse checker on alive nodes only
    alive.nodes = [n for n in c.nodes if not n.crashed]
    assert agreement_ok(alive)


def test_agreement_under_relay_crashes():
    """Relay failures delay but never violate safety (§3.4)."""
    c = Cluster("pigpaxos", 9, pig=PigConfig(n_groups=2), seed=17,
                leader_timeout=30e-3)
    c.crash_at(1, 0.12)
    c.crash_at(5, 0.18)
    st = c.measure(duration=0.6, warmup=0.1, clients=8)
    assert st.throughput > 100
    _flush_and_drain(c)
    alive = Cluster.__new__(Cluster)
    alive.nodes = [n for n in c.nodes if not n.crashed]
    assert agreement_ok(alive)


def test_leader_failover_preserves_committed():
    c = Cluster("pigpaxos", 5, pig=PigConfig(n_groups=2), seed=19)
    st_pre = c.measure(duration=0.2, warmup=0.05, clients=5)
    committed_before = {s: cmd for s, cmd in c.nodes[0].committed.items()}
    c.nodes[0].crash()
    # node 1 takes over
    c.sched.after(0.01, c.nodes[1].start_phase1)
    c.leader_id = 1
    c.run(c.sched.now + 0.5)
    assert c.nodes[1].is_leader
    # new leader must agree with every committed slot of the old leader
    for s, cmd in committed_before.items():
        if s in c.nodes[1].committed:
            got = c.nodes[1].committed[s]
            assert (got.client_id, got.seq) == (cmd.client_id, cmd.seq)
    # and the cluster keeps committing
    before = c.nodes[1].committed_count
    c.add_clients(5, stop_at=c.sched.now + 0.3)
    c.run(c.sched.now + 0.4)
    assert c.nodes[1].committed_count > before


def test_stale_leader_rejected():
    """A deposed leader's ballot must be rejected (§3.4)."""
    c = Cluster("pigpaxos", 5, pig=PigConfig(n_groups=2), seed=23)
    c.run(0.05)
    assert c.nodes[0].is_leader
    c.nodes[1].start_phase1()
    c.run(c.sched.now + 0.1)
    assert c.nodes[1].is_leader
    assert c.nodes[1].promised > (1, 0)


# ------------------------------------------------------------------ liveness
def test_liveness_with_random_relay_failures():
    """Random rotation circumvents minority failures denying progress (§3.3)."""
    c = Cluster("pigpaxos", 11, pig=PigConfig(n_groups=2, prc=1), seed=29,
                leader_timeout=25e-3)
    for nid in (3, 7):   # two crashed followers, leader + 8 alive >= majority 6
        c.crash_at(nid, 0.1)
    st = c.measure(duration=0.8, warmup=0.2, clients=10)
    assert st.throughput > 100


# --------------------------------------------------------- message-count model
@pytest.mark.parametrize("n,r", [(9, 1), (9, 2), (9, 3), (25, 3), (25, 5)])
def test_message_load_matches_analytical(n, r):
    """DES per-node message counts must match Eq. 1-3 (Table 1/2)."""
    c = Cluster("pigpaxos", n, pig=PigConfig(n_groups=r), seed=31)
    st = c.measure(duration=0.6, warmup=0.3, clients=12)
    ml = st.messages_per_op(0)
    mf = sum(st.messages_per_op(i) for i in range(1, n)) / (n - 1)
    assert abs(ml - analytical.leader_messages(r)) < 0.15
    assert abs(mf - analytical.follower_messages(n, r)) < 0.15


def test_paxos_message_load():
    c = Cluster("paxos", 9, seed=37)
    st = c.measure(duration=0.5, warmup=0.25, clients=12)
    assert abs(st.messages_per_op(0) - (2 * 8 + 2)) < 0.15
    mf = sum(st.messages_per_op(i) for i in range(1, 9)) / 8
    assert abs(mf - 2.0) < 0.1


def test_total_messages_constant_in_r():
    """§6.4: total messages per round = 2N-1 regardless of R."""
    n = 13
    totals = []
    for r in (1, 2, 3, 4):
        c = Cluster("pigpaxos", n, pig=PigConfig(n_groups=r), seed=41)
        st = c.measure(duration=0.5, warmup=0.25, clients=10)
        server_msgs = float(st.msg_out[:n].sum()) / max(st.committed, 1)
        totals.append(server_msgs)
        # exactly 2N-1 server-side sends per round (client reply included)
        assert abs(server_msgs - (2 * n - 1)) < 0.5, (r, server_msgs)
    assert max(totals) - min(totals) < 0.5


# ------------------------------------------------------------------ EPaxos
def test_epaxos_conflict_free_fast_path():
    c = Cluster("epaxos", 5, seed=43)
    st = c.measure(duration=0.4, warmup=0.1, clients=10,
                   workload=WorkloadConfig(n_keys=1000))
    assert st.throughput > 1000
    # all committed instances executed on every node eventually
    c.run(c.sched.now + 0.5)
    for nd in c.nodes:
        assert not nd._pending_exec


def test_epaxos_conflicting_ops_serialize_consistently():
    """With a single hot key, all replicas must apply conflicting writes in
    the same order (per-key linearization)."""
    c = Cluster("epaxos", 5, seed=47)
    st = c.measure(duration=0.4, warmup=0.05, clients=8,
                   workload=WorkloadConfig(n_keys=1, write_fraction=1.0))
    assert st.throughput > 100
    c.run(c.sched.now + 1.0)
    orders = []
    for nd in c.nodes:
        orders.append([(c2.client_id, c2.seq) for _, c2 in nd.applied_log])
    ref = max(orders, key=len)
    for o in orders:
        assert o == ref[:len(o)], "replicas disagree on conflicting-op order"


# ------------------------------------------------------------------ gray list
def test_gray_list_suspects_only_on_timeout():
    """PRC early flushes must not gray healthy nodes (§4.2 regression)."""
    pig = PigConfig(n_groups=2, prc=2, use_gray_list=True)
    c = Cluster("pigpaxos", 15, pig=pig, seed=53)
    c.measure(duration=0.5, warmup=0.1, clients=20)
    assert len(c.nodes[0].comm.gray) == 0


def test_gray_list_catches_crashed_node():
    A = list(range(1, 9)); B = list(range(9, 15))
    pig = PigConfig(n_groups=2, groups=[A, B], prc=1, use_gray_list=True)
    c = Cluster("pigpaxos", 15, pig=pig, seed=59)
    c.crash_at(3, 0.1)
    c.measure(duration=0.6, warmup=0.2, clients=10)
    gray = c.nodes[0].comm.gray
    assert 3 in gray
    healthy_grayed = [g for g in gray if g != 3]
    assert not healthy_grayed


def test_pig_composes_with_flexible_quorums():
    """FPaxos (paper §7.1): Q2 < majority with Q1+Q2 > N, over Pig comms."""
    from repro.core.quorums import QuorumSystem
    qs = QuorumSystem(9, q1=7, q2=3)
    c = Cluster("pigpaxos", 9, pig=PigConfig(n_groups=2), seed=61, quorums=qs)
    st = c.measure(duration=0.4, warmup=0.1, clients=10)
    assert st.throughput > 500
    _flush_and_drain(c)
    assert agreement_ok(c)
    # smaller Q2 must still agree across all replicas
    states = [nd.store.data for nd in c.nodes]
    assert all(s == states[0] for s in states)
