"""Docs integrity: no dead relative links in docs/ or the READMEs.

The docs layer (ISSUE 10) is navigation — a dead relative link is a
broken build, same as a dead import.  This is the CI docs-link check:
it runs in tier-1, so every PR that moves/renames a file must fix the
links that pointed at it.  External links (http/https/mailto) and
pure in-page anchors are out of scope — only repo-relative paths are
checked, anchors stripped.
"""
import os
import re

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# [text](target) — excluding images' srcsets etc.; good enough for our md
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")


def _doc_files():
    out = [os.path.join(REPO, "README.md")]
    docs = os.path.join(REPO, "docs")
    for root, _dirs, files in os.walk(docs):
        out += [os.path.join(root, f) for f in files if f.endswith(".md")]
    for sub in ("benchmarks", "examples", "tests", "src"):
        for root, _dirs, files in os.walk(os.path.join(REPO, sub)):
            out += [os.path.join(root, f) for f in files
                    if f.lower() == "readme.md"]
    return sorted(p for p in out if os.path.exists(p))


def _relative_links(path):
    text = open(path, encoding="utf-8").read()
    for m in _LINK.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        yield target.split("#", 1)[0]


def test_docs_exist():
    # the docs site itself is part of the contract, not just its links
    for rel in ("README.md", "docs/architecture.md", "docs/consistency.md",
                "docs/adding-a-scenario.md",
                "examples/read_paths_quickstart.py"):
        assert os.path.exists(os.path.join(REPO, rel)), f"missing {rel}"


@pytest.mark.parametrize("path", _doc_files(),
                         ids=lambda p: os.path.relpath(p, REPO))
def test_no_dead_relative_links(path):
    base = os.path.dirname(path)
    dead = []
    for target in _relative_links(path):
        if not target:          # pure-anchor link, already handled
            continue
        resolved = os.path.normpath(os.path.join(base, target))
        if not os.path.exists(resolved):
            dead.append(target)
    assert not dead, (f"{os.path.relpath(path, REPO)}: dead relative "
                      f"link(s): {dead}")
