"""Fault-injection & consistency-audit subsystem tests: the FaultPlan DSL
(materialization, storms, masks), DES compilation (crash-RECOVER with
protocol re-election, partitions, gray nodes), the linearizability auditor
(passes on real runs of all three protocols, rejects corrupted fixtures),
batch-backend availability masks vs the fast DES, and the experiment-layer
threading (Scenario(faults=...), audit fields, avail/storm families)."""
import json
import math

import pytest

from repro.core import Cluster, PigConfig, WorkloadConfig, agreement_ok
from repro.faults import (FaultPlan, apply_plan, audit_cluster, check_history,
                          commit_apply_gap, crash_window, drop_window,
                          partition_window, periodic_crash, slow_window,
                          storm)

WL_RT = WorkloadConfig(request_timeout=25e-3)


# ================================================================= plan DSL
def test_plan_builders_compose_and_materialize_sorted():
    plan = (crash_window(0, 0.8, 1.2) + slow_window(2, 0.0, 3.0,
                                                    extra_latency=1e-3)
            + partition_window(1, 3, 0.5, 0.6)
            + periodic_crash(4, period=1.0, downtime=0.1, t0=0.2, t1=2.5))
    from repro.faults.plan import _event_time
    evs = plan.materialize(horizon=3.0)
    times = [_event_time(ev) for ev in evs]
    assert times == sorted(times)
    kinds = {ev[0] for ev in evs}
    assert kinds == {"crash", "recover", "slow", "partition", "heal"}
    # periodic expansion: crashes at 0.2, 1.2, 2.2 inside the horizon
    pc = [ev for ev in evs if ev[0] == "crash" and ev[1] == 4]
    assert [ev[2] for ev in pc] == [0.2, 1.2, 2.2]


def test_plan_rejects_unknown_kinds_and_bad_arity():
    with pytest.raises(ValueError, match="unknown fault event kind"):
        FaultPlan(events=(("explode", 3, 0.1),))
    with pytest.raises(ValueError, match="expected"):
        FaultPlan(events=(("crash", 3),))
    with pytest.raises(ValueError, match="overlapping degradation"):
        (slow_window(2, 0.0, 1.0, extra_latency=1e-3)
         + drop_window(2, 0.5, 0.8, prob=0.3)).materialize(2.0)


def test_storm_is_deterministic_and_respects_concurrency_cap():
    plan = storm(targets=tuple(range(1, 9)), rate_hz=40.0, t0=0.1, t1=1.1,
                 mean_downtime=0.2, seed=7, max_concurrent=2)
    a = plan.materialize(2.0)
    b = plan.materialize(2.0)
    assert a == b and len(a) > 4
    assert plan.materialize(2.0) != storm(
        targets=tuple(range(1, 9)), rate_hz=40.0, t0=0.1, t1=1.1,
        mean_downtime=0.2, seed=8, max_concurrent=2).materialize(2.0)
    # replay the schedule: never more than 2 nodes down at once
    down = {}
    for ev in a:
        if ev[0] == "crash":
            down[ev[1]] = True
            assert len(down) <= 2, a
        elif ev[0] == "recover":
            down.pop(ev[1], None)


def test_masks_lowering_and_expressibility():
    plan = crash_window(0, 0.4, 0.7) + slow_window(2, 0.0, 2.0,
                                                   extra_latency=2e-3)
    assert plan.mask_expressible(2.0)
    m = plan.to_masks(5, 2.0)
    assert m["down"].shape[0] == 5
    assert tuple(m["down"][0, 0]) == (0.4, 0.7)
    assert not (m["down"][1] < float("inf")).any()
    assert m["slow"][2] == 2e-3 and m["slow"][0] == 0.0
    # crash with no recover -> open window to +inf
    m2 = crash_window(3, 0.5).to_masks(5, 2.0)
    assert m2["down"][3, 0, 0] == 0.5 and math.isinf(m2["down"][3, 0, 1])
    # partitions / drops / transient slow windows are DES-only
    assert not partition_window(1, 2, 0.1, 0.2).mask_expressible(2.0)
    assert not drop_window(1, 0.1, 0.2, 0.5).mask_expressible(2.0)
    assert not slow_window(1, 0.5, 0.9, extra_latency=1e-3).mask_expressible(2.0)


# ======================================================= DES fault execution
def test_leader_crash_recover_resumes_service_and_audits_clean():
    """The tentpole's core path: leader down for a window, recovery re-runs
    phase 1 and re-arms in-flight slots; clients ride request timeouts; the
    auditor and the committed==applied invariant hold on both engines."""
    for engine in ("exact", "fast"):
        c = Cluster("pigpaxos", 7, pig=PigConfig(n_groups=2, prc=1), seed=5,
                    engine=engine, record_history=True)
        apply_plan(c, crash_window(0, 0.3, 0.5), horizon=1.5)
        st = c.measure(duration=0.7, warmup=0.1, clients=6, workload=WL_RT)
        # service resumed: post-recovery completions exist
        post = [t for cl in c.clients for (t, _l) in cl.latencies if t > 0.55]
        assert post, engine
        assert sum(cl.retries for cl in c.clients) > 0
        res = audit_cluster(c)
        assert res.ok, (engine, res.violations)
        assert res.reads_checked > 0
        c.run(until=2.0)                    # settle
        assert commit_apply_gap(c) == 0
        assert agreement_ok(c)
        # the outage is visible: no completions well inside the window
        mid = [t for cl in c.clients for (t, _l) in cl.latencies
               if 0.36 <= t <= 0.48]
        assert not mid


def test_crash_recover_all_protocols_audit_clean():
    for proto in ("paxos", "pigpaxos", "epaxos"):
        pig = PigConfig(n_groups=2, prc=1) if proto == "pigpaxos" else None
        # epaxos is symmetric: crash a non-leader id for it too
        node = 2 if proto == "epaxos" else 0
        c = Cluster(proto, 5, pig=pig, seed=9, engine="exact",
                    record_history=True)
        apply_plan(c, crash_window(node, 0.25, 0.4), horizon=1.2)
        c.measure(duration=0.5, warmup=0.1, clients=5, workload=WL_RT)
        res = audit_cluster(c)
        assert res.ok, (proto, res.violations)
        assert res.ops > 0 and res.completed > 0


def test_gray_slow_node_raises_latency_and_drop_forces_retries():
    base = Cluster("paxos", 5, seed=4, engine="exact")
    st0 = base.measure(duration=0.4, warmup=0.1, clients=4)
    slow = Cluster("paxos", 5, seed=4, engine="exact")
    apply_plan(slow, slow_window(0, 0.0, 9.0, extra_latency=2e-3),
               horizon=9.0)
    st1 = slow.measure(duration=0.4, warmup=0.1, clients=4)
    assert st1.median_ms > st0.median_ms + 3.0   # >= 2 leader hops x 2ms
    lossy = Cluster("paxos", 5, seed=4, engine="exact", record_history=True)
    apply_plan(lossy, drop_window(1, 0.1, 0.6, prob=0.9), horizon=9.0)
    st2 = lossy.measure(duration=0.5, warmup=0.1, clients=4, workload=WL_RT)
    assert st2.committed > 0
    assert audit_cluster(lossy).ok


def test_asymmetric_partition_blocks_one_direction():
    from repro.core.messages import P3

    c = Cluster("paxos", 3, seed=1, engine="exact")
    c.run(until=0.05)                  # let the initial election settle
    c.net.reset_stats()
    c.net.partition_oneway(0, 1)
    c.net.send(0, 1, P3(commit_index=-1))
    c.net.send(1, 0, P3(commit_index=-1))
    c.run(until=0.1)
    assert c.net.msgs_in[1] == 0       # 0 -> 1 dropped
    assert c.net.msgs_in[0] == 1       # 1 -> 0 delivered
    c.net.heal_oneway(0, 1)
    c.net.send(0, 1, P3(commit_index=-1))
    c.run(until=0.2)
    assert c.net.msgs_in[1] == 1


# ===================================================== gray-list interaction
def test_empty_plan_keeps_golden_trace_equivalence():
    """Satellite: applying an EMPTY FaultPlan must not perturb the exact
    engine's golden traces (PRC + gray-list config, vs the seed stack)."""
    def run(engine, with_plan):
        c = Cluster("pigpaxos", 5,
                    pig=PigConfig(n_groups=3, prc=1, use_gray_list=True),
                    seed=23, engine=engine)
        if with_plan:
            assert apply_plan(c, FaultPlan(), horizon=1.0) == []
        st = c.measure(duration=0.3, warmup=0.1, clients=8)
        logs = [[(s, cmd.client_id, cmd.seq) for s, cmd in nd.applied_log]
                for nd in c.nodes]
        return logs, st.committed, c.sched.events, c.sched._seq
    ref = run("ref", with_plan=False)
    assert run("exact", with_plan=True) == ref


def test_prc_graylist_partition_heal_keeps_committed_equals_applied():
    """Satellite: PigPaxos PRC + gray list under a mid-run partition-then-
    heal plan — safety invariants hold and every commit reaches the applied
    prefix once the cluster settles."""
    c = Cluster("pigpaxos", 7,
                pig=PigConfig(n_groups=2, prc=1, use_gray_list=True),
                seed=23, engine="exact", record_history=True)
    plan = (partition_window(0, 3, 0.2, 0.45)
            + partition_window(2, 5, 0.25, 0.5, oneway=True))
    apply_plan(c, plan, horizon=2.0)
    st = c.measure(duration=0.6, warmup=0.1, clients=6, workload=WL_RT)
    assert st.committed > 0
    res = audit_cluster(c)
    assert res.ok, res.violations
    c.run(until=2.5)
    assert commit_apply_gap(c) == 0
    assert agreement_ok(c)


# ================================================================== auditor
def _h(cid, seq, op, key, invoke, resp, rtag=None):
    return {"cid": cid, "seq": seq, "op": op, "key": key, "invoke": invoke,
            "resp": resp, "ok": resp is not None, "rtag": rtag,
            "wtag": (cid, seq) if op == "put" else None}


def test_auditor_accepts_a_valid_history():
    history = [_h(0, 1, "put", 7, 0.0, 0.1),
               _h(1, 1, "get", 7, 0.2, 0.3, rtag=(0, 1)),
               _h(0, 2, "put", 7, 0.35, 0.5),
               _h(1, 2, "get", 7, 0.6, 0.7, rtag=(0, 2))]
    log = [(0, 1, "put", 7), (1, 1, "get", 7), (0, 2, "put", 7),
           (1, 2, "get", 7)]
    res = check_history(history, [log, log[:2]])
    assert res.ok and res.reads_checked == 2 and res.ops == 4


def test_auditor_rejects_corrupted_fixtures():
    """The acceptance-criterion fixture: each corruption must be caught."""
    # 1) stale read: the get returns the first put after the second applied
    history = [_h(0, 1, "put", 7, 0.0, 0.1), _h(0, 2, "put", 7, 0.2, 0.3),
               _h(1, 1, "get", 7, 0.4, 0.5, rtag=(0, 1))]
    log = [(0, 1, "put", 7), (0, 2, "put", 7), (1, 1, "get", 7)]
    res = check_history(history, [log])
    assert not res.ok and any("stale" in v for v in res.violations)
    # 2) real-time inversion: op B completed before A was invoked, but the
    #    (corrupted) witness orders A first
    history = [_h(0, 1, "put", 3, 0.5, 0.6), _h(1, 1, "put", 3, 0.0, 0.1)]
    bad_log = [(0, 1, "put", 3), (1, 1, "put", 3)]
    res = check_history(history, [bad_log])
    assert not res.ok and any("real-time" in v for v in res.violations)
    # 3) duplicate apply of one client op
    history = [_h(0, 1, "put", 3, 0.0, 0.1)]
    res = check_history(history, [[(0, 1, "put", 3), (0, 1, "put", 3)]])
    assert not res.ok and any("at-most-once" in v for v in res.violations)
    # 4) acknowledged-but-lost op
    history = [_h(0, 1, "put", 3, 0.0, 0.1), _h(0, 2, "put", 4, 0.2, 0.3)]
    res = check_history(history, [[(0, 1, "put", 3)]])
    assert not res.ok and any("lost update" in v for v in res.violations)
    # 5) replica divergence on a key
    history = [_h(0, 1, "put", 3, 0.0, 0.1), _h(1, 1, "put", 3, 0.0, 0.1)]
    res = check_history(history, [[(0, 1, "put", 3), (1, 1, "put", 3)],
                                  [(1, 1, "put", 3), (0, 1, "put", 3)]])
    assert not res.ok and any("divergence" in v for v in res.violations)


def test_not_leader_retry_never_conflates_commands():
    """A retried op must re-send the SAME command: regenerating under an
    in-flight (cid, seq) would let the session dedup ack one op with
    another's result.  Pin: every acknowledged op's key in the history
    matches the committed command's key in the leader's log."""
    from repro.faults import applied_ops, periodic_crash

    c = Cluster("paxos", 5, seed=6, engine="exact", record_history=True)
    # repeated re-election windows in which node 0 answers ok=False, with
    # aggressive resends so retries land inside them
    apply_plan(c, periodic_crash(0, period=0.15, downtime=0.05,
                                 t0=0.1, t1=0.6), horizon=1.5)
    c.measure(duration=0.7, warmup=0.05, clients=12,
              workload=WorkloadConfig(request_timeout=5e-3))
    committed_keys = {(cid, seq): key
                      for (cid, seq, _op, key) in applied_ops(c.nodes[0])}
    acked = 0
    for cl in c.clients:
        for h in cl.history:
            if h["ok"]:
                acked += 1
                assert committed_keys[(h["cid"], h["seq"])] == h["key"]
    assert acked > 100
    assert audit_cluster(c).ok


def test_duplicate_retries_are_deduped_not_double_applied():
    """A tiny request timeout forces real duplicate sends; the session layer
    must keep the applied logs duplicate-free (the auditor checks this)."""
    c = Cluster("paxos", 5, seed=2, engine="exact", record_history=True)
    wl = WorkloadConfig(request_timeout=1e-3)   # < round-trip: many dupes
    c.measure(duration=0.3, warmup=0.05, clients=20, workload=wl)
    assert sum(cl.retries for cl in c.clients) > 50
    res = audit_cluster(c)
    assert res.ok, res.violations


# ========================================================== batch fault path
@pytest.mark.parametrize("role,node", [("leader", 0), ("relay", 3)])
def test_batch_masks_match_fast_des_dip(role, node):
    jax = pytest.importorskip("jax")  # noqa: F841
    import numpy as np

    from repro.core import vectorsim as vs

    plan = crash_window(node, 0.4, 0.7)
    N, K, dur, warm = 15, 20, 1.0, 0.2
    pig = PigConfig(n_groups=3, prc=1, use_gray_list=True)

    def dip(tl):
        b = 0.05
        pre = np.mean(tl[round(warm / b):round(0.4 / b)])
        mid = np.mean(tl[round(0.4 / b):round(0.7 / b)])
        return 1.0 - mid / max(pre, 1e-9)

    tls = []
    for seed in (1, 2):
        c = Cluster("pigpaxos", N, pig=pig, seed=seed, engine="fast")
        apply_plan(c, plan, horizon=2.0)
        c.measure(duration=dur, warmup=warm, clients=K, workload=WL_RT)
        counts = [0] * 29
        for cl in c.clients:
            for (t, _l) in cl.latencies:
                bkt = int(t / 0.05)
                if bkt < len(counts):
                    counts[bkt] += 1
        tls.append(counts)
    des_dip = dip(np.mean(tls, axis=0))

    units = vs.simulate_scenario(
        "pigpaxos", N, pig=pig, clients=(K,), seeds=(1, 2),
        duration=dur, warmup=warm, masks=plan.to_masks(N, 2.0))
    batch_dip = dip(np.mean([u["timeline"]["counts"] for u in units],
                            axis=0))
    # acceptance criterion: fast-vs-batch throughput-dip depth within ~10%
    assert abs(des_dip - batch_dip) < 0.1, (role, des_dip, batch_dip)
    # and the post-recovery throughput recovers on both
    assert all(u["committed"] > 0 for u in units)


def test_batch_gray_relay_slow_mask_raises_median():
    pytest.importorskip("jax")
    from repro.core import vectorsim as vs

    pig = PigConfig(n_groups=2, prc=0)
    kw = dict(pig=pig, clients=(8,), seeds=(1,), duration=0.3, warmup=0.1)
    u0 = vs.simulate_scenario("pigpaxos", 9, **kw)
    slow = slow_window(1, 0.0, 1.0, extra_latency=2e-3).to_masks(9, 0.6)
    u1 = vs.simulate_scenario("pigpaxos", 9, masks=slow, **kw)
    assert u1[0]["median_ms"] > u0[0]["median_ms"]


def test_per_cell_retry_budgets():
    """Satellite: exhausted cells re-run alone with a doubled budget while
    finished cells keep their first-pass results (and their step budget)."""
    pytest.importorskip("jax")
    from repro.core import vectorsim as vs

    cfg = vs.build_config("pigpaxos", 9, pig=PigConfig(n_groups=2))
    # scan length = steps/breq (breq=8): cell 0's 2 clients progress 2
    # requests per scan step, so 1024 steps = 128 scan steps cover its
    # ~240 requests; cell 1 (16 clients, ~1700 reqs) exhausts and re-runs
    grid = [(0, 2, 0), (0, 16, 0)]
    out = vs.simulate_grid([cfg], grid, 0.2, 0.05, steps=1024)
    assert not out["exhausted"].any()
    assert out["steps"][0] == 1024 and out["steps"][1] > 1024
    # the retried cell's result is bit-identical to a full-budget run
    full = vs.simulate_grid([cfg], grid, 0.2, 0.05,
                            steps=int(out["steps"][1]))
    assert out["throughput"][1] == full["throughput"][1]


# ======================================================== experiments layer
def test_scenario_fault_roundtrip_spec_to_schedule():
    """Satellite: fault-plan spec -> scenario -> engine schedule round-trip,
    including legacy ``failures`` tuples (recover is now a real API)."""
    from repro.experiments import runner
    from repro.experiments.scenario import Scenario

    sc = Scenario(name="t/faults", protocol="pigpaxos", n=5,
                  pig=PigConfig(n_groups=2),
                  failures=(("crash", 3, 0.1), ("recover", 3, 0.2),
                            ("partition", 1, 2, 0.15), ("heal", 1, 2, 0.25)),
                  faults=crash_window(0, 0.3, 0.4),
                  workload=WL_RT, audit=True,
                  clients=(4,), seeds=(1,), duration=0.5, warmup=0.1)
    json.dumps(sc.spec_dict())            # JSON-clean incl. the plan
    evs = sc.fault_plan().materialize(sc.horizon)
    assert [ev[0] for ev in evs] == ["crash", "partition", "recover",
                                     "heal", "crash", "recover"]
    art = runner.run_scenarios([sc], quick=False)
    sa = art["scenarios"][0]
    assert sa["consistency"] == "audited"
    assert [ev[0] for ev in sa["faults"]] == [ev[0] for ev in evs]
    unit = sa["units"][0]
    assert unit["consistency"] == "ok", unit["audit"]
    assert unit["extras"]["unavail_ms"] > 50     # the 0.3-0.4 leader window
    assert unit["committed"] > 0


def test_scenario_rejects_bad_failures_and_non_mask_batch():
    from repro.experiments.scenario import Scenario

    with pytest.raises(ValueError, match="unknown fault event kind"):
        Scenario(name="t/bad", protocol="paxos", n=5,
                 failures=(("meteor", 1, 0.1),))
    # a typo'd node id fails at registration, not mid-suite
    with pytest.raises(ValueError, match="targets node 12"):
        Scenario(name="t/bad-node", protocol="paxos", n=5,
                 faults=crash_window(12, 0.1, 0.2))
    with pytest.raises(ValueError, match="mask-expressible"):
        Scenario(name="t/bad2", protocol="paxos", n=5, backend="batch",
                 faults=partition_window(1, 2, 0.1, 0.2))
    # mask-expressible plans ARE batch-eligible now (PR 3 follow-up)
    sc = Scenario(name="t/ok", protocol="pigpaxos", n=9,
                  pig=PigConfig(n_groups=2, prc=1), backend="batch",
                  faults=crash_window(0, 0.2, 0.3), collect=("timeline",),
                  clients=(4,), seeds=(1,), duration=0.4, warmup=0.1)
    assert sc.fault_plan().mask_expressible(sc.horizon)


def test_batch_fault_scenario_through_runner():
    pytest.importorskip("jax")
    from repro.experiments import runner
    from repro.experiments.scenario import Scenario

    sc = Scenario(name="t/bfault", protocol="pigpaxos", n=9,
                  pig=PigConfig(n_groups=2, prc=1), backend="batch",
                  faults=crash_window(0, 0.2, 0.3), collect=("timeline",),
                  clients=(6,), seeds=(1, 2), duration=0.4, warmup=0.1)
    art = runner.run_scenarios([sc], quick=False)
    sa = art["scenarios"][0]
    assert sa["consistency"] == "model"
    assert sa["faults"]
    for u in sa["units"]:
        assert u["consistency"] == "model"
        tl = u["extras"]["timeline"]["counts"]
        # the 0.2-0.3 window is dark (bucket 4 may catch pre-crash
        # stragglers that arrived just before the window)
        assert tl[5] == 0 and sum(tl[4:6]) <= 5
        assert sum(tl) > 0


def test_avail_and_storm_families_registered():
    from repro import experiments
    from repro.experiments import report

    fams = set(experiments.families())
    assert {"avail", "storm"} <= fams
    assert {"avail", "storm"} <= set(report.SUMMARIZERS)
    names = {s.name for s in experiments.select("avail")}
    assert "avail/leader/N=25" in names
    assert "avail/leader/N=25/batch" in names
    assert {s.name for s in experiments.select("storm/*N=101")} \
        == {"storm/pigpaxos/N=101"}
    for s in experiments.select("avail,storm"):
        assert s.audit or s.backend == "batch"
        assert s.fault_plan() is not None
