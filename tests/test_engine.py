"""Engine invariants guarding the slab scheduler / fused network loop:
tie-break determinism, generation-counter timer cancellation, message-count
conservation, and safety on a seeded 25-node PigPaxos run."""
import numpy as np
import pytest

from repro.core import Cluster, PigConfig, agreement_ok
from repro.core.events import Scheduler


# ----------------------------------------------------------------- scheduler
def test_same_time_events_fire_in_schedule_order():
    s = Scheduler(seed=0)
    fired = []
    s.at(1.0, lambda: fired.append("a"))
    s.at(1.0, lambda: fired.append("b"))
    s.at(0.5, lambda: fired.append("early"))
    s.at(1.0, lambda: fired.append("c"))
    n = s.run()
    assert n == 4
    assert fired == ["early", "a", "b", "c"]   # FIFO among equal timestamps
    assert s.now == 1.0


def test_run_until_is_inclusive_and_advances_now():
    s = Scheduler(seed=0)
    fired = []
    s.at(1.0, lambda: fired.append(1))
    s.at(2.0, lambda: fired.append(2))
    assert s.run(until=1.0) == 1            # t == until executes
    assert fired == [1]
    assert s.now == 1.0
    assert s.run(until=1.5) == 0
    assert s.now == 1.5                     # idle time still advances
    assert s.run(until=3.0) == 1
    assert s.idle()


def test_timer_cancellation_semantics():
    s = Scheduler(seed=0)
    fired = []
    tid = s.at(1.0, lambda: fired.append("cancelled"))
    s.at(1.0, lambda: fired.append("kept"))
    s.cancel(tid)
    s.cancel(tid)                           # double-cancel is a no-op
    n = s.run()
    assert fired == ["kept"]
    assert n == 1                           # cancelled events are not counted
    # cancel after fire is a no-op (generation already advanced)
    tid2 = s.at(2.0, lambda: fired.append("late"))
    s.run()
    s.cancel(tid2)
    assert fired == ["kept", "late"]


def test_timer_slab_is_bounded_under_churn():
    """Generation counters recycle slots: memory is bounded by the peak
    number of outstanding timers, unlike the seed's unbounded cancel set."""
    s = Scheduler(seed=0)
    for i in range(10_000):
        tid = s.at(float(i), lambda: None)
        if i % 2 == 0:
            s.cancel(tid)
        s.run(until=float(i))
    assert len(s._gen) < 64                 # slots recycled, not accumulated
    assert len(s._heap) <= 1


def test_deterministic_across_identical_runs():
    def trace(engine):
        c = Cluster("pigpaxos", 9, pig=PigConfig(n_groups=3), seed=5,
                    engine=engine)
        st = c.measure(duration=0.3, warmup=0.1, clients=10)
        logs = [[(s_, cmd.client_id, cmd.seq) for s_, cmd in nd.applied_log]
                for nd in c.nodes]
        return logs, st.committed, c.sched.events
    assert trace("exact") == trace("exact")
    assert trace("fast") == trace("fast")


# ------------------------------------------------------------- conservation
@pytest.mark.parametrize("engine", ["exact", "fast"])
def test_message_count_conservation(engine):
    """Every send is accounted at both endpoints once delivered: with no
    failures and a drained network, sum(msgs_out) == sum(msgs_in)."""
    c = Cluster("pigpaxos", 9, pig=PigConfig(n_groups=3), seed=3,
                engine=engine)
    c.add_clients(10, stop_at=0.4)
    c.sched.run(until=float("inf"))         # drain everything
    assert c.sched.idle()
    out = c.net.msgs_out
    inn = c.net.msgs_in
    assert out.sum() == inn.sum()
    assert out.sum() > 10_000               # the run actually did work
    # flight matrix row/col sums match the per-node counters
    fl = c.net.flight_matrix
    np.testing.assert_array_equal(fl.sum(axis=1), out)


def test_conservation_accounts_partition_drops():
    """Messages dropped by a partition are counted out but never in."""
    c = Cluster("paxos", 5, seed=3, engine="exact")
    c.partition_at(0, 3, 0.0)
    c.add_clients(5, stop_at=0.3)
    c.sched.run(until=float("inf"))
    out, inn = c.net.msgs_out, c.net.msgs_in
    dropped = int(c.net.flight_matrix[0, 3] + c.net.flight_matrix[3, 0])
    assert dropped > 0
    assert out.sum() - inn.sum() == dropped


# ------------------------------------------------------------------ safety
def test_agreement_on_seeded_25_node_pigpaxos():
    c = Cluster("pigpaxos", 25, pig=PigConfig(n_groups=5, prc=1), seed=42,
                engine="exact")
    st = c.measure(duration=0.4, warmup=0.1, clients=30)
    assert st.throughput > 2000
    for nd in c.nodes:
        if getattr(nd, "is_leader", False) and not nd.crashed:
            nd.flush_commits()
    c.run(c.sched.now + 0.5)
    assert agreement_ok(c)
    states = [nd.store.data for nd in c.nodes]
    assert all(s == states[0] for s in states)


def test_stats_identical_between_deferred_and_materialized_reads():
    """Reading stats mid-run (forcing materialization) must not change the
    final counters."""
    c1 = Cluster("pigpaxos", 9, pig=PigConfig(n_groups=3), seed=9,
                 engine="exact")
    c1.add_clients(8, stop_at=0.3)
    c1.sched.run(until=0.15)
    _ = c1.net.msgs_out, c1.net.flight_matrix    # force mid-run materialize
    c1.sched.run(until=float("inf"))
    c2 = Cluster("pigpaxos", 9, pig=PigConfig(n_groups=3), seed=9,
                 engine="exact")
    c2.add_clients(8, stop_at=0.3)
    c2.sched.run(until=float("inf"))
    np.testing.assert_array_equal(c1.net.msgs_out, c2.net.msgs_out)
    np.testing.assert_array_equal(c1.net.flight_matrix, c2.net.flight_matrix)
