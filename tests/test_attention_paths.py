"""Equivalence of the three attention implementations + DES/queueing-model
cross-validation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import attention_chunked, attention_ref


@pytest.mark.parametrize("Sq,Sk,window,chunk", [
    (64, 64, None, 16),
    (100, 100, None, 32),      # unaligned + padding path
    (64, 64, 24, 16),          # sliding window
])
def test_chunked_attention_matches_ref(Sq, Sk, window, chunk):
    B, Hq, Hkv, Dh = 2, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, Dh))
    k = jax.random.normal(ks[1], (B, Sk, Hkv, Dh))
    v = jax.random.normal(ks[2], (B, Sk, Hkv, Dh))
    pos = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))
    kpos = jnp.broadcast_to(jnp.arange(Sk)[None], (B, Sk))
    want = attention_ref(q, k, v, pos, kpos, window=window)
    got = attention_chunked(q, k, v, pos, kpos, window=window, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_chunked_attention_respects_k_valid():
    B, S, H, Dh = 1, 32, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, Dh))
    k = jax.random.normal(ks[1], (B, S, H, Dh))
    v = jax.random.normal(ks[2], (B, S, H, Dh))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    valid = jnp.arange(S)[None] < 16          # last 16 keys masked out
    got = attention_chunked(q, k, v, pos, pos, k_valid=valid, chunk=8)
    want = attention_ref(q, k, v, pos, pos, k_valid=valid)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_des_matches_queueing_model_saturation():
    """The DES saturation point must agree with the analytical M/D/1 model
    within 20% for Paxos (whose leader is a clean single-server queue)."""
    from repro.core import Cluster
    from repro.core.jaxsim import saturation_point
    c = Cluster("paxos", 15, seed=4)
    st = c.measure(duration=0.6, warmup=0.3, clients=120)
    model = saturation_point(15, 14, protocol="paxos")
    assert abs(st.throughput - model) / model < 0.2, (st.throughput, model)
