"""Golden-trace equivalence: the fast engine vs the verbatim seed stack.

``Cluster(engine="ref")`` runs the seed snapshot preserved in
``repro.core.refengine`` — seed scheduler (closure-chain heap), seed network
(three events per hop, numpy accounting), seed dispatch (string getattr per
delivery), and the seed protocol classes.  ``engine="exact"`` is the fused
slab engine with all shared-layer optimizations.  For fixed seeds the two
must be indistinguishable: identical applied command logs, committed counts,
executed event counts, and message accounting.
"""
import numpy as np
import pytest

from repro.core import Cluster, PigConfig


def _applied(cluster):
    return [[(slot, c.client_id, c.seq, c.op, c.key) for slot, c in nd.applied_log]
            for nd in cluster.nodes]


def _run(proto, pig, engine, seed):
    c = Cluster(proto, 5, pig=pig, seed=seed, engine=engine)
    st = c.measure(duration=0.3, warmup=0.1, clients=8)
    return c, st


CONFIGS = [
    ("paxos", None),
    ("pigpaxos", PigConfig(n_groups=2)),
    ("pigpaxos", PigConfig(n_groups=1, single_group_majority=True)),
    ("pigpaxos", PigConfig(n_groups=3, prc=1, use_gray_list=True)),
    ("epaxos", None),
]


@pytest.mark.parametrize("proto,pig", CONFIGS,
                         ids=["paxos", "pig_r2", "pig_r1maj", "pig_prc_gray",
                              "epaxos"])
@pytest.mark.parametrize("seed", [7, 23])
def test_exact_engine_matches_seed_stack(proto, pig, seed):
    ref, st_ref = _run(proto, pig, "ref", seed)
    new, st_new = _run(proto, pig, "exact", seed)
    # identical virtual execution: every event fired in the same order
    assert ref.sched.events == new.sched.events
    assert ref.sched._seq == new.sched._seq
    assert ref.sched.now == new.sched.now
    # identical replicated state machine traces
    assert _applied(ref) == _applied(new)
    assert st_ref.committed == st_new.committed
    # identical accounting (message conservation transfers to the new engine)
    np.testing.assert_array_equal(st_ref.msg_out, st_new.msg_out)
    np.testing.assert_array_equal(st_ref.msg_in, st_new.msg_in)
    np.testing.assert_array_equal(st_ref.flight, st_new.flight)
    assert st_ref.throughput == st_new.throughput
    assert st_ref.median_ms == st_new.median_ms


def test_exact_engine_matches_seed_under_failures():
    """Crash + leader-failover path: traces must still be identical."""
    runs = {}
    for engine in ("ref", "exact"):
        c = Cluster("pigpaxos", 5, pig=PigConfig(n_groups=2), seed=19,
                    engine=engine)
        c.crash_at(3, 0.12)
        st = c.measure(duration=0.4, warmup=0.1, clients=6)
        runs[engine] = (_applied(c), st.committed, c.sched.events)
    assert runs["ref"] == runs["exact"]


def test_fast_engine_preserves_aggregates():
    """The flattened engine is not bit-identical (documented), but must
    preserve protocol outcomes and aggregate statistics closely."""
    from repro.core import agreement_ok
    c_ref = Cluster("pigpaxos", 9, pig=PigConfig(n_groups=3), seed=11,
                    engine="ref")
    st_ref = c_ref.measure(duration=0.4, warmup=0.1, clients=10)
    c_fast = Cluster("pigpaxos", 9, pig=PigConfig(n_groups=3), seed=11,
                     engine="fast")
    st_fast = c_fast.measure(duration=0.4, warmup=0.1, clients=10)
    assert agreement_ok(c_fast)
    assert st_fast.committed == pytest.approx(st_ref.committed, rel=0.05)
    assert st_fast.throughput == pytest.approx(st_ref.throughput, rel=0.05)
    assert st_fast.median_ms == pytest.approx(st_ref.median_ms, rel=0.10)
