"""Property tests (hypothesis) for the analytical model + JAX MC simulator."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")   # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import analytical
from repro.core.jaxsim import latency_curve, mc_summary, saturation_point


# ------------------------------------------------------- closed-form invariants
@given(st.integers(min_value=3, max_value=101).filter(lambda n: n % 2 == 1),
       st.integers(min_value=1, max_value=100))
def test_follower_load_bounded(n, r):
    r = min(r, n - 1)
    mf = analytical.follower_messages(n, r)
    assert 2.0 <= mf <= 4.0           # §6.5: asymptote is 4


@given(st.integers(min_value=5, max_value=101).filter(lambda n: n % 2 == 1))
def test_leader_remains_bottleneck(n):
    """§6.5: for every R, leader load >= amortized follower load."""
    for r in range(1, n):
        assert analytical.leader_messages(r) >= analytical.follower_messages(n, r) - 1e-9


@given(st.integers(min_value=5, max_value=101).filter(lambda n: n % 2 == 1),
       st.integers(min_value=1, max_value=100))
def test_total_messages_r_independent(n, r):
    r = max(1, min(r, n - 1))
    g = (n - 1) / r
    total = (r + 1) + r * ((g - 1) + 1) + (n - 1 - r) * 1
    assert abs(total - analytical.total_messages_per_round(n)) < 1e-9


def test_best_r_rotating_is_one():
    for n in (5, 9, 15, 25, 49, 99):
        assert analytical.best_r_rotating(n) == 1     # headline finding


def test_best_r_static_near_sqrt():
    for n in (9, 16, 25, 49, 100):
        r = analytical.best_r_static(n)
        assert abs(r - np.sqrt(n - 1)) <= 2           # §5.2


def test_table1_values():
    rows = {row["R"]: row for row in analytical.load_table(25)}
    # exact values from Table 1 of the paper
    assert rows[1]["M_l"] == 4 and abs(rows[1]["M_f"] - 3.92) < 0.01
    assert rows[3]["M_l"] == 8 and abs(rows[3]["M_f"] - 3.75) < 0.01
    assert rows[6]["M_l"] == 14 and abs(rows[6]["M_f"] - 3.50) < 0.01
    assert rows[24]["M_l"] == 50 and rows[24]["M_f"] == 2.0
    assert abs(rows[1]["ratio"] - 1.020) < 0.01
    assert abs(rows[24]["ratio"] - 25.0) < 0.01


def test_table2_values():
    rows = {row["R"]: row for row in analytical.load_table(5)}
    assert rows[1]["M_l"] == 4 and abs(rows[1]["M_f"] - 3.5) < 0.01
    assert rows[2]["M_l"] == 6 and abs(rows[2]["M_f"] - 3.0) < 0.01
    assert rows[4]["M_l"] == 10 and rows[4]["M_f"] == 2.0


# ------------------------------------------------------- MC vs closed form
@pytest.mark.parametrize("n,r", [(9, 1), (9, 3), (25, 1), (25, 3), (25, 6)])
def test_mc_matches_closed_form(n, r):
    out = mc_summary(n, r, rounds=8192)
    assert abs(out["leader"] - analytical.leader_messages(r)) < 1e-3
    assert abs(out["follower_mean"] - analytical.follower_messages(n, r)) < 0.05


def test_mc_static_hotspot():
    """Without rotation the static relay's average load is the group cost."""
    out = mc_summary(25, 3, rounds=1024, rotating=False)
    assert abs(out["maxavg"] - analytical.static_relay_load(25, 3)) < 1e-3
    rot = mc_summary(25, 3, rounds=8192, rotating=True)
    assert rot["maxavg"] < out["maxavg"]   # rotation amortizes the hotspot


# ------------------------------------------------------- queueing model
def test_latency_curve_hockey_stick():
    import jax.numpy as jnp
    offered = jnp.asarray([100.0, 1000.0, 1800.0])
    out = latency_curve(offered, n=25, r=24, protocol="paxos")
    lat = np.asarray(out["latency"])
    assert lat[0] < lat[1] < lat[2]
    assert np.all(np.isfinite(lat))
    out_sat = latency_curve(jnp.asarray([2100.0]), n=25, r=24, protocol="paxos")
    assert not np.isfinite(np.asarray(out_sat["latency"]))[0]


def test_saturation_ordering_matches_paper():
    """Fig 9: PigPaxos >> EPaxos > Paxos at N=25."""
    paxos = saturation_point(25, 24, protocol="paxos")
    pig = saturation_point(25, 3, protocol="pigpaxos")
    assert pig > 3 * paxos    # ">3 folds improved throughput" (abstract)


# -------------------------------------------------- EPaxos fast-quorum dedupe
def test_epaxos_messages_pins_both_jaxsim_call_sites():
    """analytical.epaxos_messages is THE fast-quorum message-load formula;
    both jaxsim call sites (latency_curve, saturation_point) must agree
    with it exactly."""
    import jax.numpy as jnp
    for n in (5, 9, 25, 49):
        m = analytical.epaxos_messages(n)
        # saturation_point(n, ..) == 1 / (m * cpu_per_msg)
        cpu = 10e-6
        assert saturation_point(n, 1, cpu_per_msg=cpu, protocol="epaxos") \
            == pytest.approx(1.0 / (m * cpu))
        # latency_curve's per-node utilization == offered * m * cpu
        out = latency_curve(jnp.asarray([100.0]), n=n, r=1,
                            cpu_per_msg=cpu, protocol="epaxos")
        assert float(np.asarray(out["rho_follower"])[0]) \
            == pytest.approx(100.0 * m * cpu, rel=1e-5)
