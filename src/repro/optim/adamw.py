"""AdamW from scratch (no optax in this container).

Moments are fp32 regardless of param dtype; updates are computed in fp32 and
cast back (bf16 params + fp32 m/v is the memory layout assumed by the
roofline analysis: 2+2+4+4 bytes/param with grads).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000


class OptState(NamedTuple):
    mu: dict
    nu: dict
    step: jax.Array


def adamw_init(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(mu=jax.tree.map(zeros, params),
                    nu=jax.tree.map(zeros, params),
                    step=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def adamw_update(grads, opt: OptState, params, cfg: AdamWConfig):
    """Returns (new_params, new_opt, stats)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    step = opt.step + 1
    lr = cosine_schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:   # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt.mu)
    flat_v = treedef.flatten_up_to(opt.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    stats = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(mu=new_m, nu=new_v, step=step), stats
