"""Pig collective schedules: the paper's primitive, adapted to a TPU mesh.

Paper -> TPU mapping (DESIGN.md §3): the leader's fan-out/fan-in over a
cluster becomes cross-pod gradient synchronization over DCN; a relay group
becomes a pod; the rotating relay becomes the shard owner after an in-group
reduce-scatter (every chip relays 1/G of the payload, and the shard->chip
assignment can additionally rotate per step); aggregated piggybacked acks
become int8-compressed cross-pod payloads with error feedback.

All functions here run *inside* a shard_map manual context over the named
axes (see ``sync_grads`` for the entry point used by the training runtime).

Cross-DCN byte accounting per chip for payload P bytes, G chips per group,
npods pods:
  direct  : flat all-reduce over ('pod','group') ~ 2 P (pods-1)/pods  over DCN
  pig     : RS(group) -> AR(pod) -> AG(group)    ~ 2 (P/G) (pods-1)/pods
  pig+q8  : int8 payload + f32 block scales      ~ direct / G / 2 (vs bf16)
i.e. the paper's "shift the hot resource's work into the group" effect: the
expensive link sees 1/G (or 1/2G) of the traffic.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..kernels.ops import pig_aggregate as pig_aggregate_op
from ..kernels.pig_aggregate import quantize_blockwise


def _axis_size(axis_name: str) -> int:
    """Static size of a named mesh axis inside a shard_map/pmap context.

    ``jax.lax.axis_size`` only exists in newer JAX releases; ``psum`` of the
    constant 1 folds to the axis size as a static Python int on every
    version, so reshapes depending on it stay shape-static.
    """
    size = getattr(jax.lax, "axis_size", None)
    if size is not None:
        return size(axis_name)
    return jax.lax.psum(1, axis_name)


def _flatten(x: jax.Array, mult: int):
    """Flatten to 1-D and pad to a multiple of ``mult``."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % mult
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, pad


def direct_allreduce(x: jax.Array, axes) -> jax.Array:
    """Baseline: flat psum over all sync axes (GSPMD default behaviour)."""
    return jax.lax.psum(x, axes)


def pig_allreduce(x: jax.Array, group_axis: str = "data",
                  pod_axis: str = "pod", rotation: int = 0) -> jax.Array:
    """Hierarchical grouped all-reduce (bf16/f32 path).

    1. reduce-scatter within the group: each chip becomes the *relay* for a
       1/G shard (rotation built in: relay duty is spread uniformly, the
       paper's amortization argument);
    2. psum across pods on the scattered shard only (the DCN hop carries
       1/G of the bytes — the aggregated, deduplicated "ack");
    3. all-gather within the group.

    ``rotation`` (e.g. the step counter) additionally rotates which chip
    owns which shard across steps for uniform sustained link wear.
    """
    G = _axis_size(group_axis)
    flat, pad = _flatten(x, G)
    if rotation:
        flat = jnp.roll(flat, (rotation % G) * (flat.shape[0] // G))
    shard = jax.lax.psum_scatter(flat.reshape(G, -1), group_axis,
                                 scatter_dimension=0, tiled=False)
    shard = jax.lax.psum(shard, pod_axis)
    out = jax.lax.all_gather(shard, group_axis, axis=0, tiled=False)
    out = out.reshape(-1)
    if rotation:
        out = jnp.roll(out, -(rotation % G) * (out.shape[0] // G))
    if pad:
        out = out[:-pad]
    return out.reshape(x.shape)


def pig_allreduce_quantized(x: jax.Array, residual: Optional[jax.Array],
                            group_axis: str = "data", pod_axis: str = "pod",
                            block: int = 1024, rotation: int = 0):
    """Pig schedule with int8-compressed cross-pod hop + error feedback.

    The relay's deduplicated aggregate (§6.4) maps to block-quantized int8:
    the DCN hop carries ~1/4 the f32 bytes (1/2 of bf16).  Quantization error
    is fed back into the next step's gradient (residual), so the *average*
    update is unbiased — the PRC analogue: accept an approximate aggregate
    now, repay later.

    Returns (synced, new_residual); both shaped like x.
    """
    G = _axis_size(group_axis)
    npods = _axis_size(pod_axis)
    flat, pad = _flatten(x, G * block)
    if residual is not None:
        flat = flat + residual.reshape(-1)
    # 1) in-group reduce-scatter (full precision inside the pod: ICI is cheap)
    shard = jax.lax.psum_scatter(flat.reshape(G, -1), group_axis,
                                 scatter_dimension=0, tiled=False)   # (P/G,)
    # 2) quantize the shard, exchange across pods, fused dequant-accumulate
    q, scales = quantize_blockwise(shard.astype(jnp.float32), block)
    q_all = jax.lax.all_gather(q, pod_axis, axis=0)                  # (pods, P/G) int8
    s_all = jax.lax.all_gather(scales, pod_axis, axis=0)             # (pods, nb) f32
    agg = pig_aggregate_op(q_all, s_all, block=block)                # (P/G,) f32
    # error feedback: what the other pods saw vs what we contributed
    my_deq = (q.reshape(-1, block).astype(jnp.float32)
              * scales[:, None]).reshape(-1)
    local_err = shard.astype(jnp.float32) - my_deq
    # 3) in-group all-gather of the aggregated shard
    out = jax.lax.all_gather(agg.astype(x.dtype), group_axis, axis=0,
                             tiled=False).reshape(-1)
    err_full = jax.lax.all_gather(local_err.astype(x.dtype), group_axis,
                                  axis=0, tiled=False).reshape(-1)
    if pad:
        out = out[:-pad]
        err_full = err_full[:-pad]
    return out.reshape(x.shape), err_full.reshape(x.shape)


def sync_grads(grads, schedule: str = "pig", group_axis: str = "data",
               pod_axis: str = "pod", residuals=None, rotation: int = 0,
               block: int = 1024):
    """Synchronize a gradient pytree across ``(pod_axis, group_axis)``.

    schedule: 'direct' | 'pig' | 'pig_q8'.  Returns (grads, residuals)."""
    if schedule == "direct":
        return jax.tree.map(lambda g: direct_allreduce(g, (pod_axis, group_axis)),
                            grads), residuals
    if schedule == "pig":
        return jax.tree.map(
            lambda g: pig_allreduce(g, group_axis, pod_axis, rotation), grads), residuals
    if schedule == "pig_q8":
        if residuals is None:
            residuals = jax.tree.map(jnp.zeros_like, grads)
        pairs = jax.tree.map(
            lambda g, r: pig_allreduce_quantized(g, r, group_axis, pod_axis,
                                                 block, rotation), grads, residuals)
        synced = jax.tree.map(lambda p: p[0], pairs,
                              is_leaf=lambda p: isinstance(p, tuple))
        res = jax.tree.map(lambda p: p[1], pairs,
                           is_leaf=lambda p: isinstance(p, tuple))
        return synced, res
    raise ValueError(schedule)


def dcn_bytes_per_chip(param_bytes: int, group_size: int, npods: int,
                       schedule: str) -> float:
    """Closed-form DCN traffic model (the byte analogue of Eq. 1-3)."""
    f = 2.0 * (npods - 1) / npods
    if schedule == "direct":
        return f * param_bytes
    if schedule == "pig":
        return f * param_bytes / group_size
    if schedule == "pig_q8":
        # int8 payload + f32 scale per 1024 block, vs bf16 wire dtype
        return f * (param_bytes / group_size) * (1.0 + 4.0 / 1024) / 2.0
    raise ValueError(schedule)
