from .schedules import (direct_allreduce, pig_allreduce,  # noqa: F401
                        pig_allreduce_quantized, sync_grads)
