"""Failover and elasticity policies over the DES cluster.

This promotes the pod-level ideas in :mod:`repro.runtime.elastic`
(HeartbeatMonitor liveness detection, ElasticController membership-driven
resharding) into policies that drive the *replication* cluster itself:

* :class:`FailoverPolicy` + :func:`attach_failover` — a virtual-time failure
  detector that watches cluster-wide commit progress and, when the committed
  count stalls past ``detect_timeout`` while the known leader is dead (or has
  lost leadership), nominates a successor to run phase-1.  This models an
  external orchestrator with a configurable detection budget, so failover
  sweeps can measure the unavailability window as a function of
  ``detect_timeout`` — independent of the protocol's own ``leader_timeout``
  retry machinery.
* :class:`AdmissionPolicy` + :func:`attach_admission` — replica-side
  admission control for the overload regime: queue-length backpressure
  (shed a request when the receiving replica's queued + uncommitted work
  exceeds a threshold) plus token-bucket shedding (cap the cluster-wide
  sustained admit rate).  Shed requests get an immediate ``ok=False``
  reply — the cheap bounce path — instead of a consensus slot, so admitted
  work keeps committing within latency bounds while offered load runs past
  saturation.
* :class:`LatencyAdmissionPolicy` + :func:`attach_latency_admission` —
  admission control driven by the *observed* commit-latency p99 EWMA (the
  quantity the SLO is written in) instead of queue length; a scheduler tick
  recomputes the gauge and a bang-bang breaker with hysteresis sheds while
  it exceeds the SLO.
* :class:`ElasticityPolicy` — sizing rules for PigPaxos under membership
  change: the relay-group count tracks sqrt(followers) as nodes come and go
  (§3.2's balance point between leader fan-out and relay depth).

Policies are plain data + one attach function; they touch the cluster only
through its public surface (``members``, ``leader_id``, ``nodes``,
``sched``), so they work on both DES engines.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

from ..core.messages import ClientReply, ClientRequest
from ..core.pig import auto_group_count

_INF = float("inf")


@dataclass(frozen=True)
class FailoverPolicy:
    """External-detector failover: declare the leader failed after commit
    progress stalls for ``detect_timeout`` virtual seconds, then promote a
    successor.  ``successor`` picks who: ``"next"`` = the first live member
    after the failed leader's id (wrapping), ``"lowest"`` = the lowest live
    member id."""

    detect_timeout: float = 0.1
    check_interval: float = 0.02
    successor: str = "next"

    def __post_init__(self):
        if self.successor not in ("next", "lowest"):
            raise ValueError(f"unknown successor rule {self.successor!r}")
        if self.check_interval <= 0 or self.detect_timeout <= 0:
            raise ValueError("failover intervals must be positive")


def attach_failover(cluster, policy: FailoverPolicy,
                    stop_at: float = _INF) -> List[dict]:
    """Arm ``policy`` on ``cluster``; returns the (live) failover event list
    — one ``{"t", "from", "to"}`` dict per promotion, filled in as the run
    executes, so callers can record it in artifacts afterwards."""
    events: List[dict] = []
    state = {"count": -1, "progress_at": cluster.sched.now}

    def _total_committed() -> int:
        return sum(getattr(cluster.nodes[i], "committed_count", 0)
                   for i in cluster.members)

    def _live() -> List[int]:
        return [i for i in cluster.members
                if not cluster.nodes[i].crashed
                and not getattr(cluster.nodes[i], "joining", False)]

    def _pick(cur: Optional[int]) -> Optional[int]:
        live = [i for i in _live() if i != cur]
        if not live:
            return None
        if policy.successor == "lowest":
            return live[0]
        pivot = -1 if cur is None else cur
        return next((i for i in live if i > pivot), live[0])

    def _leader_ok() -> bool:
        lid = cluster.leader_id
        if lid is None or lid not in cluster.members:
            return False
        nd = cluster.nodes[lid]
        return not nd.crashed and nd.is_leader

    def _tick() -> None:
        now = cluster.sched.now
        if now >= stop_at:
            return
        total = _total_committed()
        if total != state["count"]:
            state["count"] = total
            state["progress_at"] = now
        elif (not _leader_ok()
              and now - state["progress_at"] >= policy.detect_timeout):
            succ = _pick(cluster.leader_id)
            if succ is not None:
                events.append({"t": now, "from": cluster.leader_id,
                               "to": succ})
                state["progress_at"] = now     # election gets one full budget
                cluster.nodes[succ].start_phase1()
        cluster.sched.after(policy.check_interval, _tick)

    cluster.sched.after(policy.check_interval, _tick)
    return events


@dataclass(frozen=True)
class AdmissionPolicy:
    """Replica-side admission control (the overload-study knob set).

    * ``max_queue`` — queue-length backpressure: a request is shed when the
      receiving replica's backlog (buffered batch commands + allocated but
      uncommitted slots, or unexecuted instances for EPaxos) is at or above
      this many commands.  ``0`` disables the queue check.
    * ``rate_hz`` / ``burst`` — token-bucket shedding: the cluster admits at
      most ``rate_hz`` sustained requests per virtual second with bursts of
      up to ``burst`` tokens.  ``rate_hz == 0`` disables the bucket.

    Shed requests are answered immediately with ``ok=False`` (the same
    bounce clients already handle for not-the-leader), so shedding costs
    one cheap reply instead of a consensus round."""

    max_queue: int = 256
    rate_hz: float = 0.0
    burst: float = 64.0

    def __post_init__(self):
        if self.max_queue < 0:
            raise ValueError("max_queue must be >= 0")
        if self.rate_hz < 0:
            raise ValueError("rate_hz must be >= 0")
        if self.rate_hz > 0 and self.burst < 1:
            raise ValueError("token bucket needs burst >= 1")
        if self.max_queue == 0 and self.rate_hz == 0:
            raise ValueError("AdmissionPolicy with every mechanism disabled")


def _backlog(nd) -> int:
    """Commands accepted but not yet committed/executed at one replica:
    leader batch buffers + (paxos family) allocated uncommitted slots, or
    (epaxos) committed-but-unexecuted instances."""
    q = len(getattr(nd, "_buf", ()))
    for b in getattr(nd, "_held", ()):
        q += len(b)
    ns = getattr(nd, "next_slot", None)
    if ns is not None:
        q += max(0, ns - 1 - nd.commit_index)
    else:
        q += len(getattr(nd, "_pending_exec", ()))
    return q


def attach_admission(cluster, policy: AdmissionPolicy,
                     stop_at: float = _INF) -> dict:
    """Arm ``policy`` on every node of ``cluster`` by wrapping the
    ``ClientRequest`` handler; returns live counters ``{"admitted",
    "shed_queue", "shed_rate"}`` that fill in as the run executes.

    The token bucket is shared cluster-wide (it caps the *admitted* rate,
    wherever requests land); the queue check is per receiving replica.
    After ``stop_at`` the wrapper passes requests straight through."""
    stats = {"admitted": 0, "shed_queue": 0, "shed_rate": 0}
    bucket = {"tokens": float(policy.burst), "last": cluster.sched.now}
    sched = cluster.sched

    def _admit_rate() -> bool:
        if policy.rate_hz <= 0:
            return True
        now = sched.now
        tok = min(policy.burst,
                  bucket["tokens"] + (now - bucket["last"]) * policy.rate_hz)
        bucket["last"] = now
        if tok < 1.0:
            bucket["tokens"] = tok
            return False
        bucket["tokens"] = tok - 1.0
        return True

    def _wrap(nd):
        orig = nd.on_ClientRequest

        def on_ClientRequest(msg):
            if sched.now >= stop_at:
                orig(msg)
                return
            if policy.max_queue and _backlog(nd) >= policy.max_queue:
                stats["shed_queue"] += 1
            elif not _admit_rate():
                stats["shed_rate"] += 1
            else:
                stats["admitted"] += 1
                orig(msg)
                return
            cmd = msg.cmd
            nd.send(msg.src, ClientReply(client_id=cmd.client_id,
                                         seq=cmd.seq, ok=False, value=None))

        nd.on_ClientRequest = on_ClientRequest
        # the fused engines dispatch through the cached table, not getattr
        nd._dispatch[ClientRequest] = on_ClientRequest

    for nd in cluster.nodes:
        _wrap(nd)
    return stats


@dataclass(frozen=True)
class LatencyAdmissionPolicy:
    """Admission control driven by *observed commit latency* instead of
    queue length (the PR 8 ROADMAP remainder, enabled by the obs layer).

    A self-rescheduling tick (``Scheduler.every``) recomputes a p99 EWMA
    over the client latencies completed since the previous tick; while the
    EWMA exceeds ``slo_ms`` every incoming request is shed with the cheap
    ok=False bounce (a bang-bang circuit breaker — the EWMA supplies the
    smoothing, ``resume_frac`` the hysteresis: admission resumes once the
    EWMA falls back below ``resume_frac * slo_ms``).

    Compared to :class:`AdmissionPolicy`'s queue threshold, this sheds on
    the quantity the SLO is actually written in — it reacts later (latency
    is a trailing indicator of queue growth) but needs no model of how
    much queue a given latency budget buys, so it is robust to cost-model
    and batching changes that re-scale the queue/latency relationship."""

    slo_ms: float = 50.0
    ewma_alpha: float = 0.3
    check_interval: float = 0.01
    resume_frac: float = 0.8

    def __post_init__(self):
        if self.slo_ms <= 0:
            raise ValueError("slo_ms must be > 0")
        if not (0.0 < self.ewma_alpha <= 1.0):
            raise ValueError("ewma_alpha must be in (0, 1]")
        if self.check_interval <= 0:
            raise ValueError("check_interval must be > 0")
        if not (0.0 < self.resume_frac <= 1.0):
            raise ValueError("resume_frac must be in (0, 1]")


def attach_latency_admission(cluster, policy: LatencyAdmissionPolicy,
                             stop_at: float = _INF) -> dict:
    """Arm ``policy`` on every node of ``cluster``; returns live counters
    ``{"admitted", "shed_latency", "p99_ewma_ms", "shedding"}``.

    Latencies are read from ``cluster.clients`` lazily each tick (clients
    are created inside ``measure()``, after attach).  When the cluster runs
    with observability enabled, the tick also records ``adm_p99_ewma_ms``
    and ``adm_shedding`` timelines."""
    stats = {"admitted": 0, "shed_latency": 0,
             "p99_ewma_ms": 0.0, "shedding": False}
    seen: dict = {}        # client id -> latencies already consumed
    sched = cluster.sched

    def _tick() -> None:
        fresh = []
        for cl in cluster.clients:
            k = seen.get(cl.id, 0)
            lats = cl.latencies
            if len(lats) > k:
                fresh.extend(l for _, l in lats[k:])
                seen[cl.id] = len(lats)
        if fresh:
            fresh.sort()
            p99 = fresh[min(len(fresh) - 1, int(0.99 * len(fresh)))] * 1e3
            a = policy.ewma_alpha
            prev = stats["p99_ewma_ms"]
            stats["p99_ewma_ms"] = (p99 if prev == 0.0
                                    else a * p99 + (1.0 - a) * prev)
        e = stats["p99_ewma_ms"]
        if stats["shedding"]:
            if e < policy.resume_frac * policy.slo_ms:
                stats["shedding"] = False
        elif e > policy.slo_ms:
            stats["shedding"] = True
        obs = getattr(cluster.net, "obs", None)
        if obs is not None:
            obs.add("adm_p99_ewma_ms", sched.now, e)
            obs.add("adm_shedding", sched.now,
                    1.0 if stats["shedding"] else 0.0)

    sched.every(policy.check_interval, _tick, stop_at=stop_at)

    def _wrap(nd):
        orig = nd.on_ClientRequest

        def on_ClientRequest(msg):
            if sched.now >= stop_at or not stats["shedding"]:
                stats["admitted"] += 1
                orig(msg)
                return
            stats["shed_latency"] += 1
            cmd = msg.cmd
            nd.send(msg.src, ClientReply(client_id=cmd.client_id,
                                         seq=cmd.seq, ok=False, value=None))

        nd.on_ClientRequest = on_ClientRequest
        # the fused engines dispatch through the cached table, not getattr
        nd._dispatch[ClientRequest] = on_ClientRequest

    for nd in cluster.nodes:
        _wrap(nd)
    return stats


@dataclass(frozen=True)
class ElasticityPolicy:
    """Relay-group sizing under a changing membership: keep the PigPaxos
    group count at sqrt(followers) as nodes join and leave, re-deriving the
    partition from the membership in force (``PigConfig.auto_groups`` makes
    the comm layer apply this automatically on every reconfiguration)."""

    track_sqrt_groups: bool = True

    def groups_for(self, n_members: int) -> int:
        return auto_group_count(n_members)
