from .coordination import CoordinationService  # noqa: F401
from .elastic import ElasticController, HeartbeatMonitor  # noqa: F401
from .policy import (ElasticityPolicy, FailoverPolicy,  # noqa: F401
                     attach_failover)
