from .coordination import CoordinationService  # noqa: F401
from .elastic import ElasticController, HeartbeatMonitor  # noqa: F401
from .policy import (AdmissionPolicy, ElasticityPolicy,  # noqa: F401
                     FailoverPolicy, attach_admission, attach_failover)
