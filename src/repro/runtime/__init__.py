from .coordination import CoordinationService  # noqa: F401
from .elastic import ElasticController, HeartbeatMonitor  # noqa: F401
