from .coordination import CoordinationService  # noqa: F401
from .elastic import ElasticController, HeartbeatMonitor  # noqa: F401
from .policy import (AdmissionPolicy, ElasticityPolicy,  # noqa: F401
                     FailoverPolicy, LatencyAdmissionPolicy,
                     attach_admission, attach_failover,
                     attach_latency_admission)
