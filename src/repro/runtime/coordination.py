"""The trainer's control plane: cluster metadata replicated with the actual
PigPaxos implementation from ``repro.core``.

Checkpoint manifests, membership changes (elastic scaling), and gray lists
are *consensus operations*: a manifest is durable only once the PigPaxos
majority has committed it, exactly how production training services use
Paxos/Raft-backed stores (Chubby/etcd/ZooKeeper — paper §1) for run state.
The coordination cluster is simulated in-process on the DES, which makes the
whole failure matrix (leader crash, relay crash, partition) testable.
"""
from __future__ import annotations

import json
from typing import Dict, Optional

from ..core import Cluster, Command, PigConfig
from ..core.messages import ClientRequest


class _InlineClient:
    """Synchronous client that drives the DES until its op completes."""

    def __init__(self, cluster: Cluster, cid: int):
        self.cluster = cluster
        self.id = cid
        self.net_id = cluster.topo.n + cid
        self.crashed = False
        self.reply = None
        self.seq = 0
        cluster.net.register(self.net_id, self)

    def deliver(self, msg) -> None:
        if msg.seq == self.seq:
            self.reply = msg

    def call(self, op: str, key: int, value: Optional[bytes] = None,
             timeout: float = 5.0) -> Optional[bytes]:
        sched = self.cluster.sched
        deadline = sched.now + timeout
        while sched.now < deadline:
            self.seq += 1
            self.reply = None
            cmd = Command(client_id=self.id, seq=self.seq, op=op, key=key,
                          value=value)
            target = self.cluster.leader_id
            self.cluster.net.send(self.net_id, target, ClientRequest(cmd=cmd))
            # drive virtual time until the reply lands or a retry is due
            retry_at = sched.now + 0.25
            while self.reply is None and sched.now < retry_at:
                if sched.idle():
                    break
                sched.run(until=sched.now + 0.01, max_events=10_000)
            if self.reply is not None and self.reply.ok:
                return self.reply.value
            # leader may have failed: probe other nodes for leadership
            for nd in self.cluster.nodes:
                if getattr(nd, "is_leader", False) and not nd.crashed:
                    self.cluster.leader_id = nd.id
                    break
            else:
                # elect the lowest-id alive node
                alive = [nd for nd in self.cluster.nodes if not nd.crashed]
                if alive:
                    self.cluster.leader_id = alive[0].id
                    alive[0].start_phase1()
                    sched.run(until=sched.now + 0.2)
        raise TimeoutError(f"coordination op {op} key={key} did not commit")


class CoordinationService:
    """Dict-like strongly-consistent metadata store backed by PigPaxos."""

    def __init__(self, n_nodes: int = 5, n_groups: int = 2, seed: int = 0):
        self.cluster = Cluster(
            "pigpaxos", n_nodes,
            pig=PigConfig(n_groups=n_groups, prc=1, use_gray_list=True),
            seed=seed)
        self.cluster.run(0.05)        # initial leader election
        self._client = _InlineClient(self.cluster, cid=900)
        self._keymap: Dict[str, int] = {}

    def _key(self, name: str) -> int:
        if name not in self._keymap:
            self._keymap[name] = len(self._keymap) + 10_000
        return self._keymap[name]

    # ---------------------------------------------------------------- API
    def put(self, name: str, obj) -> None:
        payload = json.dumps(obj).encode()
        self._client.call("put", self._key(name), payload)

    def get(self, name: str):
        raw = self._client.call("get", self._key(name))
        return None if raw is None else json.loads(raw.decode())

    # -------------------------------------------------------- fault hooks
    def crash_node(self, node_id: int) -> None:
        self.cluster.nodes[node_id].crash()

    def recover_node(self, node_id: int) -> None:
        self.cluster.nodes[node_id].recover()

    @property
    def leader_gray_list(self) -> dict:
        ld = self.cluster.nodes[self.cluster.leader_id]
        return dict(getattr(ld.comm, "gray", {}))
