"""Failure detection + elastic scaling decisions for multi-pod training.

HeartbeatMonitor implements the paper's gray-list semantics (§4.2) at the
pod level: pods that miss heartbeats are suspects; persistent suspects are
proposed (through the PigPaxos coordination plane) for removal, and the mesh
is shrunk along the data-parallel axis.  Straggler mitigation follows the
same path with a latency threshold instead of a liveness one.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from .coordination import CoordinationService


@dataclass
class HeartbeatMonitor:
    timeout: float = 30.0                 # liveness (s)
    straggler_factor: float = 2.0         # step time > factor*median => gray
    last_beat: Dict[int, float] = field(default_factory=dict)
    step_times: Dict[int, List[float]] = field(default_factory=dict)
    gray: Dict[int, float] = field(default_factory=dict)

    def beat(self, pod: int, step_time: Optional[float] = None,
             now: Optional[float] = None) -> None:
        now = time.monotonic() if now is None else now
        self.last_beat[pod] = now
        if step_time is not None:
            self.step_times.setdefault(pod, []).append(step_time)
            self.step_times[pod] = self.step_times[pod][-16:]

    def dead_pods(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return [p for p, t in self.last_beat.items() if now - t > self.timeout]

    def stragglers(self) -> List[int]:
        meds = {p: sorted(v)[len(v) // 2] for p, v in self.step_times.items() if v}
        if len(meds) < 2:
            return []
        overall = sorted(meds.values())[len(meds) // 2]
        return [p for p, m in meds.items() if m > self.straggler_factor * overall]


class ElasticController:
    """Drives membership through the coordination plane and computes the
    post-failure mesh.  Recovery contract: on any membership change, restore
    from the last *committed* checkpoint manifest and re-shard."""

    def __init__(self, coord: CoordinationService, n_pods: int,
                 data: int, model: int):
        self.coord = coord
        self.n_pods = n_pods
        self.data = data
        self.model = model
        coord.put("membership", {"pods": list(range(n_pods)), "epoch": 0})

    def membership(self) -> dict:
        return self.coord.get("membership")

    def remove_pods(self, pods: List[int]) -> dict:
        m = self.membership()
        alive = [p for p in m["pods"] if p not in pods]
        new = {"pods": alive, "epoch": m["epoch"] + 1}
        self.coord.put("membership", new)     # consensus-committed
        return new

    def mesh_shape(self) -> tuple:
        """Current mesh: shrink the pod axis to the alive pods; keep
        (data, model) intact inside each pod."""
        alive = len(self.membership()["pods"])
        if alive == 0:
            raise RuntimeError("no pods alive")
        if alive == 1:
            return (self.data, self.model)
        return (alive, self.data, self.model)

    def effective_batch(self, global_batch: int) -> int:
        """Keep per-pod batch constant: global batch shrinks with pods
        (synchronous elastic scaling)."""
        alive = len(self.membership()["pods"])
        return global_batch * alive // self.n_pods
