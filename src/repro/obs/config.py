"""Observability configuration."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ObsConfig:
    """Knobs for the observability layer, passed as ``Cluster(obs=...)``.

    ``sample_rate`` drives tracing only; the ``Tracer`` samples every
    k-th client op deterministically (k = round(1/rate)) so it never
    consumes RNG.  Tracing adds no scheduler events and mutates no
    messages, so it is safe even for golden-trace comparisons.

    ``metrics_dt`` > 0 arms the timeline sampler: a repeating scheduler
    timer that reads gauges (per-node CPU busy fraction, leader queue
    depth, in-flight slots, batch fill, shed count, commit-latency
    EWMA/p99) into ring-buffer timelines every ``metrics_dt`` seconds of
    sim time.  The timer adds K_CALL events (RNG- and message-order
    neutral, but not event-count neutral) — leave it at 0 when an
    event-count-identical run matters.
    """

    sample_rate: float = 1.0     # fraction of client ops traced (0 disables)
    metrics_dt: float = 0.0      # timeline sampling period, seconds (0 disables)
    max_spans: int = 200_000     # stop sampling new ops past this many spans
    timeline_cap: int = 4096     # ring-buffer capacity per timeline series
    perfetto_limit: int = 20_000  # max trace events kept in artifact exports

    def __post_init__(self):
        if not (0.0 <= self.sample_rate <= 1.0):
            raise ValueError(f"sample_rate must be in [0, 1], got {self.sample_rate}")
        if self.metrics_dt < 0.0:
            raise ValueError(f"metrics_dt must be >= 0, got {self.metrics_dt}")
        if self.max_spans <= 0 or self.timeline_cap <= 0:
            raise ValueError("max_spans and timeline_cap must be positive")

    @staticmethod
    def coerce(obs) -> "ObsConfig":
        """Accept an ObsConfig, a plain dict of kwargs, or True (defaults)."""
        if isinstance(obs, ObsConfig):
            return obs
        if obs is True:
            return ObsConfig()
        if isinstance(obs, dict):
            return ObsConfig(**obs)
        raise TypeError(f"obs must be ObsConfig, dict, or True, got {type(obs).__name__}")
