"""Exporters: Chrome/Perfetto trace-event JSON and artifact sections.

:func:`perfetto_events` flattens a :class:`~repro.obs.trace.Tracer` into
the Chrome trace-event format (``"X"`` complete events, microsecond
``ts``/``dur``) that https://ui.perfetto.dev and ``chrome://tracing``
open directly.  Lanes: ``pid`` is the trace (op) id so each sampled op
gets its own process group, ``tid`` is the node id — so one op renders
as a waterfall of per-node rows, and the relay fan-in structure of
PigPaxos is visible at a glance.
"""
from __future__ import annotations

import json
from typing import List, Optional


def perfetto_events(tracer, limit: Optional[int] = None,
                    per_op_lanes: bool = True) -> List[dict]:
    """Closed spans as Chrome trace events, time-ordered.

    ``per_op_lanes=True`` groups rows per sampled op (pid = trace id);
    ``False`` collapses everything onto one timeline (pid = 0), which
    suits utilization views.  ``limit`` caps the event count for
    artifact embedding (earliest events win; the drop count is recorded
    on the caller's side via ``len`` before/after)."""
    evs = []
    for tid, spans in tracer.spans.items():
        pid = tid if per_op_lanes else 0
        for sid, parent, cat, node, t0, t1 in spans:
            if t1 is None:
                continue
            evs.append({
                "name": cat,
                "cat": cat,
                "ph": "X",
                "ts": round(t0 * 1e6, 3),
                "dur": round((t1 - t0) * 1e6, 3),
                "pid": pid,
                "tid": int(node),
                "args": {"trace": tid, "span": sid, "parent": parent},
            })
    evs.sort(key=lambda e: (e["ts"], e["pid"], e["tid"]))
    if limit is not None and len(evs) > limit:
        evs = evs[:limit]
    return evs


def write_perfetto(path: str, tracer, limit: Optional[int] = None) -> int:
    """Write a Perfetto-openable JSON file; returns the event count."""
    evs = perfetto_events(tracer, limit=limit)
    doc = {
        "traceEvents": evs,
        "displayTimeUnit": "ms",
        "otherData": tracer.summary(),
    }
    with open(path, "w") as f:
        json.dump(doc, f)
    return len(evs)


def obs_artifact_section(cluster, perfetto_limit: Optional[int] = None) -> dict:
    """The ``obs`` section of a ``repro-experiments/v1`` unit: tracer
    summary + critical-path means + timelines + per-node busy seconds.
    Safe to call on clusters without observability (returns {}).
    ``perfetto_limit`` defaults to the cluster's ``ObsConfig`` value."""
    tracer = getattr(cluster, "obs_tracer", None)
    tl = getattr(cluster, "obs_timelines", None)
    if tracer is None and tl is None:
        return {}
    if perfetto_limit is None:
        cfg = getattr(cluster, "obs_cfg", None)
        perfetto_limit = cfg.perfetto_limit if cfg is not None else 20_000
    out = {}
    if tracer is not None:
        from .critpath import critical_path
        cp = critical_path(tracer)
        out["trace"] = tracer.summary()
        out["critical_path"] = {"n_ops": cp["n_ops"], "mean_ms": cp["mean_ms"]}
        evs = perfetto_events(tracer, limit=perfetto_limit)
        out["perfetto"] = {"events": evs,
                           "truncated": tracer.n_spans > len(evs)}
    if tl is not None:
        out["timelines"] = tl.export()
    out["cpu_busy_s"] = {str(i): round(b, 9)
                         for i, b in cluster.net.cpu_busy.items()}
    return out
