"""Critical-path attribution: decompose commit latency into segments.

Each finished trace is a set of closed spans inside the root op window
``[t0, t1]``.  :func:`decompose` sweeps the window's elementary intervals
and charges each to the highest-priority span category covering it —
``svc`` (CPU service) > ``ser`` (CPU serialize) > ``queue`` (CPU queue
wait) > ``relay`` (Pig aggregation wait) > ``net`` (wire latency) — with
the uncovered residual charged to ``wait`` (client-side or scheduling
slack the engines don't attribute).  Because the sweep partitions
``[t0, t1]`` exactly, the segments sum to the measured op latency by
construction (tested to float tolerance in ``tests/test_obs.py``).

The priority order resolves overlap the way a bottleneck hunt wants it:
when a hop is simultaneously "on the wire" and "waiting in a relay
window", the relay window is the actionable cause; when CPU service
overlaps anything, the CPU is the scarce resource (the paper's Eq. 1-3
bottleneck terms are all CPU terms).
"""
from __future__ import annotations

from typing import Dict, List

# Highest priority first; "wait" is the implicit residual.
CAT_PRIORITY = ("svc", "ser", "queue", "relay", "net")
SEGMENTS = CAT_PRIORITY + ("wait",)

_RANK = {c: i for i, c in enumerate(CAT_PRIORITY)}
_NCAT = len(CAT_PRIORITY)


def decompose(spans: List[list]) -> Dict[str, float]:
    """Segment one trace's latency; ``spans`` is ``Tracer.trace_of(tid)``.

    Returns ``{cat: seconds}`` over :data:`SEGMENTS` plus ``"total"``;
    the segment values sum to ``total`` exactly (modulo float addition).
    Raises ``ValueError`` on an unfinished root."""
    root = spans[0]
    t0, t1 = root[4], root[5]
    if t1 is None:
        raise ValueError("cannot decompose an unfinished trace")
    out = {c: 0.0 for c in SEGMENTS}
    out["total"] = t1 - t0
    if t1 <= t0:
        return out

    # Sweep events: (time, +1/-1, rank), clipped to the op window.
    evs = []
    for sp in spans:
        cat = sp[2]
        r = _RANK.get(cat)
        if r is None or sp[5] is None:
            continue
        a = sp[4] if sp[4] > t0 else t0
        b = sp[5] if sp[5] < t1 else t1
        if b > a:
            evs.append((a, 1, r))
            evs.append((b, -1, r))
    if not evs:
        out["wait"] = t1 - t0
        return out
    evs.sort()

    active = [0] * _NCAT
    prev = t0
    k = 0
    n_ev = len(evs)
    while k < n_ev:
        t = evs[k][0]
        if t > prev:
            top = next((i for i in range(_NCAT) if active[i]), None)
            out[CAT_PRIORITY[top] if top is not None else "wait"] += t - prev
            prev = t
        # apply every event at this timestamp before charging further
        while k < n_ev and evs[k][0] == t:
            active[evs[k][2]] += evs[k][1]
            k += 1
    if t1 > prev:
        top = next((i for i in range(_NCAT) if active[i]), None)
        out[CAT_PRIORITY[top] if top is not None else "wait"] += t1 - prev
    return out


def critical_path(tracer) -> dict:
    """Aggregate decomposition over every finished trace.

    Returns per-op rows (trace id, latency, segments) and the mean
    seconds-per-op by segment — the repo's empirical counterpart to the
    paper's Eq. 1-3 analytical decomposition."""
    ops = []
    sums = {c: 0.0 for c in SEGMENTS}
    for tid in tracer.finished:
        segs = decompose(tracer.trace_of(tid))
        total = segs.pop("total")
        for c in SEGMENTS:
            sums[c] += segs[c]
        ops.append({"trace": tid, "latency_ms": total * 1e3,
                    "segments_ms": {c: segs[c] * 1e3 for c in SEGMENTS}})
    n = len(ops)
    return {
        "n_ops": n,
        "mean_ms": {c: (sums[c] / n * 1e3 if n else 0.0) for c in SEGMENTS},
        "ops": ops,
    }
