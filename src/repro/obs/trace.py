"""Per-op distributed tracing for the DES engines.

A *trace* is the span tree of one client op.  A *context* is the pair
``(trace_id, span_id)`` naming the span that caused whatever happens
next; it rides messages in the ``Msg._tctx`` slot (set once per message
by :meth:`Tracer.attach` — broadcasts share one instance, so the op that
caused a broadcast owns all its hops) and rides handler invocations
through the ambient ``Tracer.cur`` attribute, which the engines set for
the duration of each message handler.  Any ``Network.send`` during a
handler inherits the ambient context automatically, which is how a trace
follows the causal chain client -> leader -> relay -> follower -> ack
without per-protocol plumbing; protocols only stash contexts explicitly
where a *timer* re-drives work (slot retry, batch flush, relay timeout
flush).

The slot (rather than an id-keyed side table) is a hot-path decision:
the engine loops test ``msg._tctx`` once per event, so the whole
per-event cost of an installed tracer on an unsampled op is a slot load
— no ``id()`` call, no dict probe, no tuple key.

Everything here is observation only: no scheduler events, no RNG draws,
no message mutation.  A run with tracing enabled is bit-identical to one
without (pinned by ``tests/test_obs.py``).

Span record layout (list, mutated once to close the span):
``[span_id, parent_id, cat, node, t0, t1]`` with ``cat`` one of
``op | ser | net | queue | svc | relay`` and ``t1 is None`` while open.
"""
from __future__ import annotations


class Tracer:
    """Samples client ops deterministically and collects span trees.

    Sampling is every-k-th-op (k = round(1/sample_rate)) so the tracer
    never consumes RNG; ``sample_rate=0`` keeps the tracer installed but
    samples nothing (hooks still run, contexts are never created).  When
    observability is disabled entirely, ``Network.tracer`` is ``None``
    and every engine hook is a single attribute test.
    """

    __slots__ = (
        "sample_every", "max_spans", "n_spans", "n_ops", "dropped",
        "spans", "meta", "_next_tid", "_hop", "cur",
        "_open", "finished",
    )

    def __init__(self, sample_rate: float = 1.0, max_spans: int = 200_000):
        if sample_rate <= 0.0:
            self.sample_every = 0          # sampling off
        else:
            self.sample_every = max(1, int(round(1.0 / sample_rate)))
        self.max_spans = max_spans
        self.n_spans = 0                   # spans across all traces
        self.n_ops = 0                     # client ops seen (sampled or not)
        self.dropped = 0                   # ops skipped due to max_spans
        self.spans = {}                    # tid -> [span records]
        self.meta = {}                     # tid -> {"client": .., "ok": ..}
        self._next_tid = 0
        self._hop = {}                     # id(msg) -> {dst: (tid, sid)}
        self.cur = None                    # ambient ctx inside a handler
        self._open = set()                 # tids still awaiting finish/abort
        self.finished = []                 # tids with a committed reply

    # -- op lifecycle -------------------------------------------------

    def begin_op(self, client: int, t0: float):
        """Maybe start a trace for a client op; returns a ctx or None."""
        self.n_ops += 1
        k = self.sample_every
        if k == 0 or self.n_ops % k:
            return None
        if self.n_spans >= self.max_spans:
            self.dropped += 1
            return None
        tid = self._next_tid
        self._next_tid = tid + 1
        self.spans[tid] = [[0, -1, "op", client, t0, None]]
        self.meta[tid] = {"client": client, "ok": None}
        self.n_spans += 1
        self._open.add(tid)
        return (tid, 0)

    def finish_op(self, ctx, t1: float):
        """Close a trace's root span at commit-reply time."""
        tid = ctx[0]
        root = self.spans[tid][0]
        if root[5] is None:
            root[5] = t1
            self.meta[tid]["ok"] = True
            self._open.discard(tid)
            self.finished.append(tid)

    def abort_op(self, ctx, t1: float):
        """Close a trace whose op was shed/abandoned (excluded from stats)."""
        tid = ctx[0]
        root = self.spans[tid][0]
        if root[5] is None:
            root[5] = t1
            self.meta[tid]["ok"] = False
            self._open.discard(tid)

    # -- message context ----------------------------------------------

    def attach(self, msg, ctx):
        """Bind a context to a message instance (first binding wins —
        broadcasts share one instance, so the op that caused the
        broadcast owns all its hops).  The context dies with the
        message; ``_hop`` entries (per-destination svc-span parents) are
        popped by the engine at each K_HANDLE, so neither needs a purge
        pass."""
        if msg._tctx is None:
            msg._tctx = ctx

    def ctx_of(self, msg):
        return msg._tctx

    # -- spans --------------------------------------------------------

    def add_span(self, ctx, cat: str, node: int, t0: float, t1: float) -> int:
        """Record a closed span under ctx's trace; returns its span id.

        Spans for already-closed traces are refused (returns -1): stale
        contexts linger on long-lived messages and in protocol stashes
        after an op finishes, and accepting their spans would grow finished
        traces without bound."""
        tid, parent = ctx
        if tid not in self._open:
            return -1
        sp = self.spans[tid]
        sid = len(sp)
        sp.append([sid, parent, cat, node, t0, t1])
        self.n_spans += 1
        return sid

    # -- accessors ----------------------------------------------------

    def trace_of(self, tid: int):
        """All spans of one trace (root first)."""
        return self.spans[tid]

    def op_latency(self, tid: int) -> float:
        root = self.spans[tid][0]
        return root[5] - root[4]

    def summary(self) -> dict:
        return {
            "ops_seen": self.n_ops,
            "ops_traced": self._next_tid,
            "ops_finished": len(self.finished),
            "ops_dropped": self.dropped,
            "spans": self.n_spans,
            "sample_every": self.sample_every,
        }
