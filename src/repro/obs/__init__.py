"""Observability subsystem (ISSUE 9): per-op distributed tracing,
time-series metrics, and critical-path bottleneck attribution.

The paper's headline claims are observability claims — rotating relays keep
any single node from becoming a hotspot (Fig 8), and throughput is governed
by a leader/relay bottleneck decomposition (Eq. 1-3) — so this layer makes
*where a millisecond goes* and *which node is hot at second t* first-class
outputs of every execution path:

* :class:`Tracer` (``trace.py``) — per-op distributed tracing.  A sampled
  client op gets a trace context that rides every message of its causal
  chain (client -> leader -> relay -> follower -> ack); the engines record
  serialize / network / queue-wait / CPU-service spans per hop and the Pig
  relay layer records aggregation spans.  Purely observational: no
  scheduled events, no RNG draws, no message mutation — traces are
  bit-identical with tracing enabled (pinned by ``tests/test_obs.py``
  against ``engine="ref"``), and ``net.tracer is None`` short-circuits
  every hook when disabled.
* :class:`Timelines` (``metrics.py``) — a time-series metrics registry:
  counters / gauges / ring-buffer timelines (per-node CPU busy fraction,
  leader queue depth, in-flight slots, batch fill, shed count,
  commit-latency EWMA/p99) sampled on a scheduler repeat timer
  (``Scheduler.every``).  ``Network.reset_stats`` resets the ring buffers
  at warmup, so warmup samples never pollute reported series.
* ``critpath.py`` — walks each finished span tree and decomposes commit
  latency into queue-wait / CPU-service / serialize / relay-aggregation /
  network segments with an exact sum-to-latency invariant (tested).
* ``export.py`` — Chrome/Perfetto trace-event JSON (``run.py --trace``)
  and the ``obs`` section of ``repro-experiments/v1`` artifacts.

Enable with ``Cluster(obs=ObsConfig(sample_rate=..., metrics_dt=...))`` (a
plain dict also works).  ``sample_rate`` controls tracing only and is
event-neutral; ``metrics_dt`` > 0 arms the sampler timer, which adds
K_CALL events (still RNG- and message-order-neutral, but not
event-count-identical — keep it 0 for golden-trace comparisons).

Model boundaries (where this layer's numbers do and don't exist):

* ``engine="ref"`` has **no obs surface** — ``Cluster(obs=...)`` raises on
  the verbatim seed stack rather than silently skipping hooks.
* the batch backend (``core/vectorsim.py``) is **timelines-only**: the
  vectorized kernel emits leader-backlog series but has no per-op span
  trees or critical-path decomposition — traced runs need a DES engine.
* span trees cover **logged** operations' causal chains; leased
  leader-local reads are served without any fan-out, so their traces are
  single-node by construction (see ``docs/consistency.md`` for the read
  paths and ``docs/architecture.md`` for the full selection matrix).
"""
from .config import ObsConfig  # noqa: F401
from .critpath import CAT_PRIORITY, SEGMENTS, critical_path, decompose  # noqa: F401
from .export import obs_artifact_section, perfetto_events, write_perfetto  # noqa: F401
from .metrics import LatencyGauge, Timeline, Timelines, install_sampler  # noqa: F401
from .trace import Tracer  # noqa: F401
