"""Time-series metrics: ring-buffer timelines sampled on a scheduler timer.

A :class:`Timelines` registry holds named :class:`Timeline` ring buffers
plus a :class:`LatencyGauge` (commit-latency EWMA + windowed p99).  The
sampler installed by :func:`install_sampler` reads cluster gauges every
``metrics_dt`` virtual seconds — per-node CPU busy fraction, leader queue
depth, in-flight slots, batch fill, shed count, commit-latency EWMA/p99 —
via ``Scheduler.every``.  ``Network.reset_stats`` calls
``Timelines.reset`` at the warmup boundary so warmup samples never
pollute the reported series.

The sampler timer adds K_CALL events (it never draws RNG and never
perturbs message order), so runs with ``metrics_dt > 0`` are not
event-count-identical to untraced runs; tracing alone (``sample_rate``)
stays fully event-neutral.
"""
from __future__ import annotations

_INF = float("inf")


class Timeline:
    """Fixed-capacity ring buffer of ``(t, value)`` samples."""

    __slots__ = ("cap", "_buf", "_i", "total")

    def __init__(self, cap: int = 4096):
        self.cap = cap
        self._buf = []
        self._i = 0        # overwrite cursor once full
        self.total = 0     # samples ever added (including overwritten)

    def add(self, t: float, v: float) -> None:
        buf = self._buf
        if len(buf) < self.cap:
            buf.append((t, v))
        else:
            buf[self._i] = (t, v)
            self._i += 1
            if self._i == self.cap:
                self._i = 0
        self.total += 1

    def clear(self) -> None:
        self._buf.clear()
        self._i = 0

    def __len__(self) -> int:
        return len(self._buf)

    def items(self):
        """Samples in time order (oldest surviving first)."""
        buf = self._buf
        if len(buf) < self.cap:
            return list(buf)
        i = self._i
        return buf[i:] + buf[:i]

    def export(self) -> dict:
        pts = self.items()
        return {"t": [round(t, 9) for t, _ in pts],
                "v": [v for _, v in pts],
                "dropped": max(0, self.total - len(pts))}


class LatencyGauge:
    """Commit-latency EWMA plus a windowed p99 estimate.

    ``note`` is called per completed client op (cheap: one EWMA update
    and a ring write); ``p99_ms`` sorts the window on demand, so call it
    at sampler frequency, not per op."""

    __slots__ = ("alpha", "window", "_ring", "_i", "count", "ewma_s")

    def __init__(self, alpha: float = 0.1, window: int = 512):
        self.alpha = alpha
        self.window = window
        self._ring = []
        self._i = 0
        self.count = 0
        self.ewma_s = 0.0

    def note(self, lat_s: float) -> None:
        a = self.alpha
        self.ewma_s = lat_s if self.count == 0 else a * lat_s + (1 - a) * self.ewma_s
        ring = self._ring
        if len(ring) < self.window:
            ring.append(lat_s)
        else:
            ring[self._i] = lat_s
            self._i += 1
            if self._i == self.window:
                self._i = 0
        self.count += 1

    @property
    def ewma_ms(self) -> float:
        return self.ewma_s * 1e3

    def p99_ms(self) -> float:
        ring = self._ring
        if not ring:
            return 0.0
        s = sorted(ring)
        return s[min(len(s) - 1, int(0.99 * len(s)))] * 1e3

    def reset(self) -> None:
        self._ring.clear()
        self._i = 0
        self.count = 0
        self.ewma_s = 0.0


class Timelines:
    """Registry of named timelines + the shared latency gauge."""

    __slots__ = ("cap", "series", "latency", "counters")

    def __init__(self, cap: int = 4096):
        self.cap = cap
        self.series = {}               # name -> Timeline
        self.latency = LatencyGauge()
        self.counters = {}             # name -> running count

    def timeline(self, name: str) -> Timeline:
        tl = self.series.get(name)
        if tl is None:
            tl = self.series[name] = Timeline(self.cap)
        return tl

    def add(self, name: str, t: float, v: float) -> None:
        self.timeline(name).add(t, v)

    def bump(self, name: str, by: int = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + by

    def reset(self) -> None:
        """Called by ``Network.reset_stats`` at the warmup boundary."""
        for tl in self.series.values():
            tl.clear()
        self.latency.reset()
        self.counters.clear()

    def export(self) -> dict:
        return {
            "series": {k: tl.export() for k, tl in sorted(self.series.items())},
            "counters": dict(sorted(self.counters.items())),
            "latency": {"ewma_ms": self.latency.ewma_ms,
                        "p99_ms": self.latency.p99_ms(),
                        "count": self.latency.count},
        }


def _leader_gauges(nd):
    """(queue depth, in-flight, batch fill) at one node, protocol-agnostic.

    Mirrors ``runtime.policy._backlog`` but split into components so the
    timelines can show buffered vs in-flight work separately."""
    buf = len(getattr(nd, "_buf", ()))
    for b in getattr(nd, "_held", ()):
        buf += len(b)
    ns = getattr(nd, "next_slot", None)
    if ns is not None:
        inflight = max(0, ns - 1 - nd.commit_index)
    else:
        inflight = len(getattr(nd, "_pending_exec", ()))
    return buf + inflight, inflight, len(getattr(nd, "_buf", ()))


def install_sampler(cluster, tl: Timelines, dt: float,
                    stop_at: float = _INF) -> None:
    """Arm the timeline sampler on ``cluster``'s scheduler.

    Samples per-node CPU busy fraction (delta of ``Network._cpu_busy``
    over the period, robust to the warmup stats reset), leader queue
    depth / in-flight slots / batch fill, cumulative shed count (when an
    admission policy published its counters as ``cluster.admission_stats``),
    and the commit-latency EWMA/p99 gauges."""
    net = cluster.net
    sched = cluster.sched
    n = len(cluster.nodes)
    last_busy = [0.0] * n

    def _tick() -> None:
        t = sched.now
        busy = net._cpu_busy
        for i in range(n):
            d = busy[i] - last_busy[i]
            if d < 0.0:          # reset_stats zeroed the counters mid-window
                d = busy[i]
            last_busy[i] = busy[i]
            tl.add(f"busy_frac/{i}", t, d / dt)
        lid = cluster.leader_id
        if lid is not None and lid < len(cluster.nodes):
            nd = cluster.nodes[lid]
            qd, infl, fill = _leader_gauges(nd)
            tl.add("leader_qdepth", t, qd)
            tl.add("inflight_slots", t, infl)
            tl.add("batch_fill", t, fill)
        adm = getattr(cluster, "admission_stats", None)
        if adm:
            shed = sum(v for k, v in adm.items() if k.startswith("shed_"))
            tl.add("shed_total", t, shed)
        lat = tl.latency
        if lat.count:
            tl.add("commit_ewma_ms", t, lat.ewma_ms)
            tl.add("commit_p99_ms", t, lat.p99_ms())

    sched.every(dt, _tick, stop_at=stop_at)
