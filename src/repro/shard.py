"""Logical-axis sharding annotations.

Model code annotates activations with *logical* axis names
(``constrain(x, 'batch', 'seq', 'embed')``).  The launcher installs a mesh +
logical->mesh rules; without an installed context the calls are no-ops, so
the same model code runs in single-device smoke tests and 512-device dry-runs.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX = threading.local()

MeshAxes = Union[None, str, Tuple[str, ...]]


@contextmanager
def sharding_rules(mesh: Mesh, rules: Dict[str, MeshAxes]):
    prev = getattr(_CTX, "state", None)
    _CTX.state = (mesh, dict(rules))
    try:
        yield
    finally:
        _CTX.state = prev


def current_mesh() -> Optional[Mesh]:
    st = getattr(_CTX, "state", None)
    return st[0] if st else None


def logical_to_spec(names: Sequence[Optional[str]]) -> Optional[P]:
    st = getattr(_CTX, "state", None)
    if st is None:
        return None
    _, rules = st
    return P(*[rules.get(n) if n is not None else None for n in names])


def constrain(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """Apply a logical sharding constraint; no-op outside a rules context.

    Axes that don't divide the dimension are dropped: constraining e.g. 8 kv
    heads over a 16-way 'model' axis makes GSPMD pad + reshard, replicating
    the tensor across other axes (measured at ~275GB/chip/step on
    qwen2.5-32b train_4k — §Perf iteration B1)."""
    st = getattr(_CTX, "state", None)
    if st is None:
        return x
    mesh, rules = st
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    entries = []
    for dim, n in zip(x.shape, names):
        a = rules.get(n) if n is not None else None
        axes = a if isinstance(a, tuple) else ((a,) if a else ())
        prod = 1
        for ax in axes:
            prod *= sizes[ax]
        entries.append(a if (axes and dim % prod == 0) else None)
    spec = P(*entries)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(*names: Optional[str]) -> Optional[NamedSharding]:
    st = getattr(_CTX, "state", None)
    if st is None:
        return None
    mesh, rules = st
    spec = P(*[rules.get(n) if n is not None else None for n in names])
    return NamedSharding(mesh, spec)
