"""Mamba2-style SSD (state-space duality) blocks + the shared chunked
linear-recurrence scan used by both Mamba2 and RWKV6.

The recurrence (matrix-valued state S in R^{Dk x Dv} per head):
    S_t = a_t * S_{t-1} + k_t v_t^T          (a_t scalar or diag per channel)
    y_t = q_t^T S_t (+ bonus u: q_t^T (u ⊙ k_t) v_t for RWKV)

``chunked_linear_scan`` evaluates it chunk-parallel (the same algorithm the
Pallas ssm_scan kernel implements; kernels/ref.py delegates here).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..shard import constrain
from .config import ModelConfig


def chunked_linear_scan(q: jax.Array, k: jax.Array, v: jax.Array,
                        log_a: jax.Array, chunk: int = 64,
                        bonus: Optional[jax.Array] = None,
                        s0: Optional[jax.Array] = None,
                        return_state: bool = False):
    """q,k: (B,T,H,Dk); v: (B,T,H,Dv); log_a: (B,T,H) scalar decay or
    (B,T,H,Dk) per-channel decay; bonus: (H,Dk) current-token bonus (RWKV);
    s0: initial state (B,H,Dk,Dv).  Returns y: (B,T,H,Dv) and, when
    return_state, the final state.  T must be divisible by chunk."""
    B, T, H, Dk = q.shape
    Dv = v.shape[-1]
    nc = T // chunk
    diag = log_a.ndim == 4
    f32 = jnp.float32
    qc = q.astype(f32).reshape(B, nc, chunk, H, Dk)
    kc = k.astype(f32).reshape(B, nc, chunk, H, Dk)
    vc = v.astype(f32).reshape(B, nc, chunk, H, Dv)
    la = log_a.astype(f32).reshape((B, nc, chunk, H, Dk) if diag else (B, nc, chunk, H))

    # shard the recurrence over the state feature dim (head counts are often
    # not mesh-divisible; Dk usually is).  The inter-chunk einsum contracts
    # Dk -> one small psum per chunk instead of re-gathering the state
    # (§Perf iteration C2).
    qc = constrain(qc, "batch", None, None, None, "state_dk")
    kc = constrain(kc, "batch", None, None, None, "state_dk")

    A = jnp.cumsum(la, axis=2)                     # inclusive cumulative decay
    Atot = A[:, :, -1]                             # (B,nc,H[,Dk])

    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    strict = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)

    if diag:
        # per-channel decay: fold decays into q/k
        q_in = qc * jnp.exp(A)                     # q_t e^{A_t}
        k_in = kc * jnp.exp(-A)                    # k_s e^{-A_s}
        mask = strict if bonus is not None else causal
        scores = jnp.einsum("bcthd,bcshd->bchts", q_in, k_in)
        scores = jnp.where(mask[None, None, None], scores, 0.0)
        y_intra = jnp.einsum("bchts,bcshv->bcthv", scores, vc)
        if bonus is not None:
            # RWKV current-token bonus: y_t += (q_t . (u ⊙ k_t)) v_t
            s_diag = jnp.einsum("bcthd,bcthd->bcth",
                                qc * bonus.astype(f32)[None, None, None], kc)
            y_intra += s_diag[..., None] * vc
        k_state = kc * jnp.exp(Atot[:, :, None] - A)   # k_s e^{A_c - A_s}
        q_cm = q_in.transpose(1, 0, 2, 3, 4)           # (nc,B,chunk,H,Dk) -- q e^{A}
        kst_cm = k_state.transpose(1, 0, 2, 3, 4)
        v_cm = vc.transpose(1, 0, 2, 3, 4)
        at_cm = Atot.transpose(1, 0, 2, 3)             # (nc,B,H,Dk)

        def stepd(S, xs):
            q_i, kst, v_i, at = xs
            y_inter = jnp.einsum("bthd,bhdv->bthv", q_i, S)
            S = S * jnp.exp(at)[..., None] + jnp.einsum("bthd,bthv->bhdv", kst, v_i)
            return S, y_inter

        S0 = jnp.zeros((B, H, Dk, Dv), f32) if s0 is None else s0.astype(f32)
        Sf, y_inter = jax.lax.scan(stepd, S0, (q_cm, kst_cm, v_cm, at_cm))
        y = y_intra + y_inter.transpose(1, 0, 2, 3, 4)
    else:
        decay_qk = jnp.exp(A[:, :, :, None, :] - A[:, :, None, :, :])  # (B,nc,t,s,H)
        mask = causal
        scores = jnp.einsum("bcthd,bcshd->bchts", qc, kc)
        scores = scores * jnp.where(mask[None, None, None],
                                    decay_qk.transpose(0, 1, 4, 2, 3), 0.0)
        y_intra = jnp.einsum("bchts,bcshv->bcthv", scores, vc)
        k_state = kc * jnp.exp(Atot[:, :, None] - A)[..., None]
        q_cm = (qc * jnp.exp(A)[..., None]).transpose(1, 0, 2, 3, 4)
        kst_cm = k_state.transpose(1, 0, 2, 3, 4)
        v_cm = vc.transpose(1, 0, 2, 3, 4)
        at_cm = Atot.transpose(1, 0, 2)                # (nc,B,H)

        def steps(S, xs):
            q_i, kst, v_i, at = xs
            y_inter = jnp.einsum("bthd,bhdv->bthv", q_i, S)
            S = S * jnp.exp(at)[..., None, None] + jnp.einsum("bthd,bthv->bhdv", kst, v_i)
            return S, y_inter

        S0 = jnp.zeros((B, H, Dk, Dv), f32) if s0 is None else s0.astype(f32)
        Sf, y_inter = jax.lax.scan(steps, S0, (q_cm, kst_cm, v_cm, at_cm))
        y = y_intra + y_inter.transpose(1, 0, 2, 3, 4)

    y = y.reshape(B, T, H, Dv).astype(v.dtype)
    if return_state:
        return y, Sf
    return y


def linear_scan_step(S: jax.Array, q: jax.Array, k: jax.Array, v: jax.Array,
                     log_a: jax.Array, bonus: Optional[jax.Array] = None):
    """Single-token recurrence for decode.  S: (B,H,Dk,Dv); q/k: (B,H,Dk);
    v: (B,H,Dv); log_a: (B,H) or (B,H,Dk).  Returns (S', y: (B,H,Dv))."""
    f32 = jnp.float32
    Sf = S.astype(f32)
    a = jnp.exp(log_a.astype(f32))
    a = a[..., None, None] if a.ndim == 2 else a[..., None]
    kv = jnp.einsum("bhd,bhv->bhdv", k.astype(f32), v.astype(f32))
    S_new = Sf * a + kv
    if bonus is None:
        # matches the inclusive (s<=t) chunked mask: current kv attended
        y = jnp.einsum("bhd,bhdv->bhv", q.astype(f32), S_new)
    else:
        # RWKV: attend decayed previous state + u-weighted current token
        y = jnp.einsum("bhd,bhdv->bhv", q.astype(f32), Sf * a)
        y += jnp.einsum("bhd,bhd->bh", q.astype(f32),
                        bonus.astype(f32)[None] * k.astype(f32))[..., None] * v.astype(f32)
    return S_new.astype(S.dtype), y.astype(v.dtype)


# --------------------------------------------------------------- Mamba2 block
def _ssm_dims(cfg: ModelConfig):
    d_inner = 2 * cfg.d_model
    P = 64                                   # head dim
    H = cfg.ssm_heads or d_inner // P
    N = cfg.ssm_state
    return d_inner, H, P, N


def ssm_block(p: dict, x: jax.Array, cfg: ModelConfig,
              cache: Optional[dict] = None, chunk: int = 64) -> tuple:
    """Mamba2(SSD) block.  x: (B,T,D).  cache: {'conv': (B,W-1,d_inner),
    'state': (B,H,N,P)} for decode.  Returns (y, new_cache)."""
    B, T, D = x.shape
    d_inner, H, P, N = _ssm_dims(cfg)
    zxbcdt = x @ p["in_proj"]
    z, xs, B_, C_, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N], axis=-1)
    # causal conv1d over xs
    W = cfg.conv_width
    if cache is None:
        pad = jnp.zeros((B, W - 1, d_inner), xs.dtype)
        xpad = jnp.concatenate([pad, xs], axis=1)
        new_conv = xpad[:, -(W - 1):] if W > 1 else None
    else:
        xpad = jnp.concatenate([cache["conv"], xs], axis=1)
        new_conv = xpad[:, -(W - 1):]
    idx = jnp.arange(T)[:, None] + jnp.arange(W)[None, :]
    xc = xpad[:, idx]                                  # (B,T,W,d_inner)
    xs = jax.nn.silu(jnp.einsum("btwd,wd->btd", xc.astype(jnp.float32),
                                p["conv_w"].astype(jnp.float32))).astype(x.dtype)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # (B,T,H)
    log_a = -jnp.exp(p["A_log"].astype(jnp.float32))[None, None] * dt                # (B,T,H)
    v = (xs.reshape(B, T, H, P).astype(jnp.float32) * dt[..., None]).astype(x.dtype)
    k = jnp.broadcast_to(B_[:, :, None, :], (B, T, H, N)).astype(x.dtype)
    q = jnp.broadcast_to(C_[:, :, None, :], (B, T, H, N)).astype(x.dtype)

    if cache is None or T > 1:
        pad_to = (-T) % chunk
        s0 = None if cache is None else cache["state"]
        if pad_to:
            zp = lambda a: jnp.pad(a, [(0, 0), (0, pad_to)] + [(0, 0)] * (a.ndim - 2))
            y, new_state = chunked_linear_scan(zp(q), zp(k), zp(v), zp(log_a),
                                               chunk, s0=s0, return_state=True)
            y = y[:, :T]
        else:
            y, new_state = chunked_linear_scan(q, k, v, log_a, chunk, s0=s0,
                                               return_state=True)
        if cache is None:
            new_state = None   # training path does not thread state
    else:
        S, y1 = linear_scan_step(cache["state"], q[:, 0], k[:, 0], v[:, 0], log_a[:, 0])
        y = y1[:, None]
        new_state = S
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xs.reshape(B, T, H, P)
    y = y.reshape(B, T, d_inner)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    y = constrain(y, "batch", "seq", "ff")
    out = y @ p["out_proj"]
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv, "state": new_state}
    return constrain(out, "batch", "seq", "embed"), new_cache


def init_ssm(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    d = cfg.d_model
    d_inner, H, P, N = _ssm_dims(cfg)
    ks = jax.random.split(key, 4)
    in_dim = 2 * d_inner + 2 * N + H
    return {
        "in_proj": (jax.random.normal(ks[0], (d, in_dim)) / math.sqrt(d)).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, d_inner)) * 0.5).astype(dtype),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),     # A = -1
        "D": jnp.ones((H,), jnp.float32),
        "out_proj": (jax.random.normal(ks[2], (d_inner, d)) / math.sqrt(d_inner)).astype(dtype),
    }


def empty_ssm_cache(cfg: ModelConfig, batch: int, n_layers: Optional[int] = None,
                    dtype=jnp.bfloat16) -> dict:
    d_inner, H, P, N = _ssm_dims(cfg)
    L = cfg.n_layers if n_layers is None else n_layers
    return {
        "conv": jnp.zeros((L, batch, cfg.conv_width - 1, d_inner), dtype),
        "state": jnp.zeros((L, batch, H, N, P), jnp.float32),
    }
