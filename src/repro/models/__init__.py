from .config import ModelConfig  # noqa: F401
from .model import (decode_step, forward, forward_hidden, init_params,  # noqa: F401
                    lm_loss, make_cache, prefill)
