"""Transformer building blocks: RMSNorm, RoPE, GQA attention (full, sliding
window, KV-cached decode), gated MLPs, embeddings.  Pure JAX, params as dicts.

Weight layout conventions (chosen for GSPMD-friendly sharding):
  wq: (d_model, n_heads*dh)    wk/wv: (d_model, n_kv*dh)   wo: (n_heads*dh, d_model)
  w1/w3: (d_model, d_ff)       w2: (d_ff, d_model)
Stacked-layer variants prepend the layer axis L for lax.scan consumption.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from ..shard import constrain
from .config import ModelConfig


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def rope_tables(positions: jax.Array, dh: int, theta: float) -> tuple:
    """positions: (...,) int32 -> cos/sin of shape (..., dh/2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, Dh); cos/sin: (B?, S, Dh/2) broadcast over heads."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    c = cos[..., None, :]
    s = sin[..., None, :]
    out = jnp.stack([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return out.reshape(x.shape).astype(dt)


def _attn_mask(q_pos: jax.Array, k_pos: jax.Array,
               window: Optional[int]) -> jax.Array:
    """Causal (+ sliding window) mask: (..., Sq, Sk) boolean, True = keep."""
    m = k_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        m &= k_pos[..., None, :] > (q_pos[..., :, None] - window)
    return m


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  q_pos: jax.Array, k_pos: jax.Array,
                  window: Optional[int] = None,
                  k_valid: Optional[jax.Array] = None) -> jax.Array:
    """Reference GQA attention.  q: (B,Sq,Hq,Dh), k/v: (B,Sk,Hkv,Dh).
    q_pos: (B,Sq) absolute positions; k_pos: (B,Sk).  O(Sq*Sk) memory."""
    B, Sq, Hq, Dh = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    qf = q.astype(jnp.float32) / math.sqrt(Dh)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qf = qf.reshape(B, Sq, Hkv, rep, Dh)
    logits = jnp.einsum("bqhrd,bkhd->bhrqk", qf, kf)
    mask = _attn_mask(q_pos, k_pos, window)[:, None, None]      # (B,1,1,Sq,Sk)
    if k_valid is not None:
        mask &= k_valid[:, None, None, None, :]
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", p, vf)
    return out.reshape(B, Sq, Hq, Dh).astype(q.dtype)


def attention_chunked(q: jax.Array, k: jax.Array, v: jax.Array,
                      q_pos: jax.Array, k_pos: jax.Array,
                      window: Optional[int] = None,
                      k_valid: Optional[jax.Array] = None,
                      chunk: int = 512) -> jax.Array:
    """Flash-style online-softmax attention with a lax.scan over key chunks.

    O(Sq * chunk) live memory instead of O(Sq * Sk) — the pure-jnp analogue
    of the Pallas flash kernel, used for long sequences on any backend (and
    for the CPU dry-run, where interpret-mode Pallas would unroll the grid).
    """
    B, Sq, Hq, Dh = q.shape
    Sk = k.shape[1]
    Hkv = k.shape[2]
    rep = Hq // Hkv
    pad = (-Sk) % chunk
    if pad:
        zp = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        k, v = zp(k), zp(v)
        k_pos = jnp.pad(k_pos, [(0, 0), (0, pad)], constant_values=2**30)
        k_valid = zp(k_valid) if k_valid is not None else None
        Sk += pad
    nk = Sk // chunk
    qf = (q.astype(jnp.float32) / math.sqrt(Dh)).reshape(B, Sq, Hkv, rep, Dh)
    kc = k.astype(jnp.float32).reshape(B, nk, chunk, Hkv, Dh).transpose(1, 0, 2, 3, 4)
    vc = v.astype(jnp.float32).reshape(B, nk, chunk, Hkv, Dh).transpose(1, 0, 2, 3, 4)
    pc = k_pos.reshape(B, nk, chunk).transpose(1, 0, 2)
    valc = (k_valid.reshape(B, nk, chunk).transpose(1, 0, 2)
            if k_valid is not None else jnp.ones((nk, B, chunk), bool))

    m0 = jnp.full((B, Hkv, rep, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Hkv, rep, Sq), jnp.float32)
    a0 = jnp.zeros((B, Sq, Hkv, rep, Dh), jnp.float32)

    def step(carry, xs):
        m, l, acc = carry
        kj, vj, pj, valj = xs
        s = jnp.einsum("bqhrd,bkhd->bhrqk", qf, kj)          # (B,Hkv,rep,Sq,ck)
        mask = pj[:, None, None, None, :] <= q_pos[:, None, None, :, None]
        if window is not None:
            mask &= pj[:, None, None, None, :] > (
                q_pos[:, None, None, :, None] - window)
        mask &= valj[:, None, None, None, :]
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        pexp = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(pexp, axis=-1)
        upd = jnp.einsum("bhrqk,bkhd->bqhrd", pexp, vj)
        acc_new = acc * alpha.transpose(0, 3, 1, 2)[..., None] + upd
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, pc, valc))
    l = jnp.maximum(l, 1e-30)
    out = acc / l.transpose(0, 3, 1, 2)[..., None]
    return out.reshape(B, Sq, Hq, Dh).astype(q.dtype)


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": partial(jax.nn.gelu, approximate=True)}[name]


def gated_mlp(p: dict, x: jax.Array, act: str = "silu") -> jax.Array:
    h = _act(act)(x @ p["w1"]) * (x @ p["w3"])
    h = constrain(h, "batch", "seq", "ff")
    return h @ p["w2"]


# ----------------------------------------------------------------- attention
def attention_block(p: dict, x: jax.Array, cfg: ModelConfig,
                    positions: jax.Array,
                    cache: Optional[dict] = None,
                    impl: str = "ref") -> tuple:
    """Full attention sublayer (projections + rope + attention + out-proj).

    cache=None            : training/prefill over the whole sequence.
    cache={'k','v','len'} : cached mode; writes current k/v at ``positions``
                            and attends over the cache (decode or chunked
                            prefill).  Returns (y, new_cache).
    """
    B, S, D = x.shape
    dh = cfg.dh
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, dh)
    k = (x @ p["wk"]).reshape(B, S, cfg.n_kv_heads, dh)
    v = (x @ p["wv"]).reshape(B, S, cfg.n_kv_heads, dh)
    if cfg.qkv_bias:
        q = q + p["bq"].reshape(cfg.n_heads, dh)
        k = k + p["bk"].reshape(cfg.n_kv_heads, dh)
        v = v + p["bv"].reshape(cfg.n_kv_heads, dh)
    q = constrain(q, "batch", "seq", "heads", None)
    k = constrain(k, "batch", "seq", "kv_heads", None)
    cos, sin = rope_tables(positions, dh, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    def _uncached_attention():
        if impl == "flash" and cfg.sliding_window is None:
            from ..kernels.ops import flash_attention
            return flash_attention(q, k, v, causal=True)
        if impl == "chunked" or (impl in ("ref", "auto") and S > 1024):
            # linear-memory path: required at 4k+ sequence lengths
            return attention_chunked(q, k, v, positions, positions,
                                     window=cfg.sliding_window)
        return attention_ref(q, k, v, positions, positions,
                             window=cfg.sliding_window)

    if cache is None:
        y = _uncached_attention()
        new_cache = None
    else:
        ck, cv, cpos = cache["k"], cache["v"], cache["pos"]
        W = ck.shape[1]
        # ring-buffer slots (full cache: W >= max_len so slot == position)
        slots = positions % W
        bidx = jnp.arange(B, dtype=jnp.int32)[:, None]
        ck = ck.at[bidx, slots].set(k)
        cv = cv.at[bidx, slots].set(v)
        cpos = cpos.at[bidx, slots].set(positions)
        valid = cpos >= 0
        new_cache = {"k": ck, "v": cv, "pos": cpos}
        if S > 1:
            # prefill: attention over the freshly written sequence itself
            # (prefill starts from an empty cache, so causal attention over
            # the current chunk == attention over the cache)
            y = _uncached_attention()
        else:
            y = attention_ref(q, ck, cv, positions, cpos,
                              window=cfg.sliding_window, k_valid=valid)

    y = y.reshape(B, S, cfg.q_dim) @ p["wo"]
    return constrain(y, "batch", "seq", "embed"), new_cache


def init_attention(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    d = cfg.d_model
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": (jax.random.normal(k1, (d, cfg.q_dim)) * s).astype(dtype),
        "wk": (jax.random.normal(k2, (d, cfg.kv_dim)) * s).astype(dtype),
        "wv": (jax.random.normal(k3, (d, cfg.kv_dim)) * s).astype(dtype),
        "wo": (jax.random.normal(k4, (cfg.q_dim, d)) * (1.0 / math.sqrt(cfg.q_dim))).astype(dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), dtype)
        p["bk"] = jnp.zeros((cfg.kv_dim,), dtype)
        p["bv"] = jnp.zeros((cfg.kv_dim,), dtype)
    return p


def init_mlp(key, d: int, d_ff: int, dtype=jnp.bfloat16) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": (jax.random.normal(k1, (d, d_ff)) / math.sqrt(d)).astype(dtype),
        "w3": (jax.random.normal(k2, (d, d_ff)) / math.sqrt(d)).astype(dtype),
        "w2": (jax.random.normal(k3, (d_ff, d)) / math.sqrt(d_ff)).astype(dtype),
    }


def empty_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                   n_layers: Optional[int] = None, dtype=jnp.bfloat16) -> dict:
    """Stacked per-layer KV cache.  Sliding-window models only keep W slots."""
    W = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    L = cfg.n_layers if n_layers is None else n_layers
    shape = (L, batch, W, cfg.n_kv_heads, cfg.dh)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "pos": jnp.full((L, batch, W), -1, jnp.int32),
    }
