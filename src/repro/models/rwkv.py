"""RWKV6 "Finch" blocks: time-mix with data-dependent per-channel decay and
matrix-valued state, plus squared-ReLU channel-mix.  Attention-free.

Simplifications vs the released checkpoint (documented in DESIGN.md):
static token-shift mixing coefficients (the low-rank data-dependent mixing of
the full model is folded into the decay LoRA only), GroupNorm replaced by a
per-head RMSNorm.  The recurrence itself (data-dependent diag decay w_t,
bonus u) is the faithful Finch kernel and is what the ssm_scan Pallas kernel
executes.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..shard import constrain
from .config import ModelConfig
from .layers import rmsnorm
from .ssm import chunked_linear_scan, linear_scan_step

HEAD_SIZE = 64


def _dims(cfg: ModelConfig):
    H = cfg.ssm_heads or cfg.d_model // HEAD_SIZE
    return H, HEAD_SIZE


def _shift(x: jax.Array, prev: Optional[jax.Array]) -> jax.Array:
    """Token shift: x_{t-1} (zeros or cache['shift'] for t=0)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def time_mix(p: dict, x: jax.Array, cfg: ModelConfig,
             cache: Optional[dict] = None, chunk: int = 16) -> tuple:
    B, T, D = x.shape
    H, N = _dims(cfg)
    xx = _shift(x, None if cache is None else cache.get("shift_t"))
    mix = lambda mu: x + (xx - x) * mu.astype(x.dtype)
    r = (mix(p["mu_r"]) @ p["wr"]).reshape(B, T, H, N)
    k = (mix(p["mu_k"]) @ p["wk"]).reshape(B, T, H, N)
    v = (mix(p["mu_v"]) @ p["wv"]).reshape(B, T, H, N)
    g = jax.nn.silu(mix(p["mu_g"]) @ p["wg"])
    # data-dependent decay (LoRA): w_t = exp(-exp(w0 + tanh(x A) B))
    wx = jnp.tanh(mix(p["mu_w"]).astype(jnp.float32) @ p["w_lora_a"].astype(jnp.float32))
    logw = -jnp.exp(p["w0"].astype(jnp.float32)[None, None]
                    + (wx @ p["w_lora_b"].astype(jnp.float32)))
    # clamp per-step decay so the factored chunk form (q e^{A}) (k e^{-A})
    # stays inside f32 range: |chunk| * 2.3 << log(f32_max) ~ 88
    logw = jnp.clip(logw, -2.3, -1e-4)
    logw = logw.reshape(B, T, H, N)                    # per-channel decay

    if cache is None or T > 1:
        pad_to = (-T) % chunk
        s0 = None if cache is None else cache["state"]
        if pad_to:
            zp = lambda a: jnp.pad(a, [(0, 0), (0, pad_to)] + [(0, 0)] * (a.ndim - 2))
            y, new_state = chunked_linear_scan(zp(r), zp(k), zp(v), zp(logw),
                                               chunk, bonus=p["u"], s0=s0,
                                               return_state=True)
            y = y[:, :T]
        else:
            y, new_state = chunked_linear_scan(r, k, v, logw, chunk,
                                               bonus=p["u"], s0=s0,
                                               return_state=True)
        if cache is None:
            new_state = None
    else:
        S, y1 = linear_scan_step(cache["state"], r[:, 0], k[:, 0], v[:, 0],
                                 logw[:, 0], bonus=p["u"])
        y = y1[:, None]
        new_state = S
    # per-head norm (GroupNorm stand-in), gate, output proj
    y = rmsnorm(y.reshape(B, T, H, N), p["ln_x"].reshape(H, N), cfg.norm_eps)
    y = y.reshape(B, T, D) * g
    out = y @ p["wo"]
    new_cache = None
    if cache is not None:
        new_cache = {"shift_t": x[:, -1:], "state": new_state}
    return constrain(out, "batch", "seq", "embed"), new_cache


def channel_mix(p: dict, x: jax.Array, cfg: ModelConfig,
                cache: Optional[dict] = None) -> tuple:
    xx = _shift(x, None if cache is None else cache.get("shift_c"))
    xk = x + (xx - x) * p["mu_ck"].astype(x.dtype)
    xr = x + (xx - x) * p["mu_cr"].astype(x.dtype)
    h = jnp.square(jax.nn.relu(xk @ p["w_in"]))
    h = constrain(h, "batch", "seq", "ff")
    out = jax.nn.sigmoid(xr @ p["w_recv"]) * (h @ p["w_out"])
    new_cache = {"shift_c": x[:, -1:]} if cache is not None else None
    return out, new_cache


def rwkv_block(p: dict, x: jax.Array, cfg: ModelConfig,
               cache: Optional[dict] = None, chunk: int = 16) -> tuple:
    y, c1 = time_mix(p["time"], rmsnorm(x, p["ln1"], cfg.norm_eps), cfg,
                     cache=cache, chunk=chunk)
    x = x + y
    y, c2 = channel_mix(p["chan"], rmsnorm(x, p["ln2"], cfg.norm_eps), cfg,
                        cache=cache)
    x = x + y
    new_cache = None
    if cache is not None:
        new_cache = {**(c1 or {}), **(c2 or {})}
    return x, new_cache


def init_rwkv_block(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    D = cfg.d_model
    H, N = _dims(cfg)
    lora = 64
    ks = jax.random.split(key, 12)
    s = 1.0 / math.sqrt(D)
    nrm = lambda k, shape, sc: (jax.random.normal(k, shape) * sc).astype(dtype)
    time = {
        "mu_r": jnp.full((D,), 0.5, jnp.float32),
        "mu_k": jnp.full((D,), 0.5, jnp.float32),
        "mu_v": jnp.full((D,), 0.5, jnp.float32),
        "mu_g": jnp.full((D,), 0.5, jnp.float32),
        "mu_w": jnp.full((D,), 0.5, jnp.float32),
        "wr": nrm(ks[0], (D, D), s), "wk": nrm(ks[1], (D, D), s),
        "wv": nrm(ks[2], (D, D), s), "wg": nrm(ks[3], (D, D), s),
        "wo": nrm(ks[4], (D, D), s),
        "w_lora_a": nrm(ks[5], (D, lora), s),
        "w_lora_b": jnp.zeros((lora, D), dtype),   # LoRA-B zero init
        "w0": jnp.full((D,), 0.5, jnp.float32),       # exp(-exp(.5)) ~ .19 decay
        "u": (jax.random.normal(ks[7], (H, N)) * 0.1).astype(jnp.float32),
        "ln_x": jnp.zeros((D,), jnp.float32),
    }
    chan = {
        "mu_ck": jnp.full((D,), 0.5, jnp.float32),
        "mu_cr": jnp.full((D,), 0.5, jnp.float32),
        "w_in": nrm(ks[8], (D, cfg.d_ff), s),
        "w_out": nrm(ks[9], (cfg.d_ff, D), 1.0 / math.sqrt(cfg.d_ff)),
        "w_recv": nrm(ks[10], (D, D), s),
    }
    return {"time": time, "chan": chan,
            "ln1": jnp.zeros((D,), jnp.float32),
            "ln2": jnp.zeros((D,), jnp.float32)}


def empty_rwkv_cache(cfg: ModelConfig, batch: int,
                     n_layers: Optional[int] = None, dtype=jnp.bfloat16) -> dict:
    H, N = _dims(cfg)
    L = cfg.n_layers if n_layers is None else n_layers
    return {
        "shift_t": jnp.zeros((L, batch, 1, cfg.d_model), dtype),
        "shift_c": jnp.zeros((L, batch, 1, cfg.d_model), dtype),
        "state": jnp.zeros((L, batch, H, N, N), jnp.float32),
    }
