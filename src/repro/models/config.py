"""Model configuration schema for the architecture zoo."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | rwkv | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None       # override (gemma-7b: 256)
    mlp_act: str = "silu"                # silu => SwiGLU, gelu => GeGLU
    qkv_bias: bool = False               # qwen2.5 style
    sliding_window: Optional[int] = None  # danube SWA
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # SSM / RWKV
    ssm_state: int = 0
    ssm_heads: int = 0
    conv_width: int = 4
    # hybrid (zamba2): one shared attention block applied every k ssm layers
    attn_every: int = 0
    # modality stub: 'vision' | 'audio' -> input is precomputed embeddings
    frontend: Optional[str] = None
    # serving
    max_seq_len: int = 4096

    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.dh

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.dh

    @property
    def attention_free(self) -> bool:
        return self.family in ("ssm", "rwkv")

    @property
    def subquadratic(self) -> bool:
        """Eligible for the long_500k shape: SSM/hybrid/sliding-window."""
        return self.family in ("ssm", "rwkv", "hybrid") or self.sliding_window is not None

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------ accounting
    def param_count(self) -> int:
        """Closed-form parameter count (embedding + blocks + head)."""
        d, v = self.d_model, self.vocab
        n = v * d                        # embedding
        if not self.tie_embeddings:
            n += v * d                   # head
        n += d                           # final norm
        per_layer = 0
        if self.family in ("dense", "vlm", "audio", "moe"):
            att = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            if self.qkv_bias:
                att += self.q_dim + 2 * self.kv_dim
            per_layer += att + 2 * d     # attn + 2 norms
            if self.family == "moe":
                per_layer += d * self.n_experts                      # router
                per_layer += self.n_experts * 3 * d * self.moe_d_ff  # experts
                if self.n_shared_experts:
                    per_layer += 3 * d * (self.n_shared_experts * self.moe_d_ff)
            else:
                per_layer += 3 * d * self.d_ff
        elif self.family in ("ssm", "hybrid"):
            per_layer += self._ssm_block_params() + d      # block + 1 norm
        elif self.family == "rwkv":
            lora = 64
            per_layer += (5 * d * d                        # r,k,v,g,o projections
                          + 2 * lora * d + 2 * d           # decay LoRA + w0/ln_x
                          + 5 * d                          # mixing mus
                          + (self.ssm_heads or d // 64) * 64)   # bonus u
            per_layer += 2 * d * self.d_ff + d * d + 2 * d  # channel mix + mus
            per_layer += 2 * d                              # block norms
        total = n + self.n_layers * per_layer
        if self.family == "hybrid" and self.attn_every:
            att = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d + 2 * d
            total += att + 3 * d * self.d_ff   # ONE shared attention+MLP block
        return total

    def _ssm_block_params(self) -> int:
        d = self.d_model
        h = self.ssm_heads or max(1, d // 128)
        n_state = self.ssm_state
        d_inner = 2 * d
        return (d * (2 * d_inner + 2 * n_state + h)         # in_proj (x,z,B,C,dt)
                + self.conv_width * d_inner                 # conv1d
                + h + h                                     # A_log, D
                + d_inner * d)                              # out_proj

    def active_param_count(self) -> int:
        """For MoE: params touched per token (6*N_active*D flops model)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        dense = self.param_count() - self.n_layers * self.n_experts * 3 * d * self.moe_d_ff
        return dense + self.n_layers * self.top_k * 3 * d * self.moe_d_ff
