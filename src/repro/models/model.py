"""Top-level model zoo API: init_params / forward / prefill / decode_step.

All families share one parameter layout convention: per-layer params are
*stacked* along a leading L axis and consumed with ``jax.lax.scan`` so the
lowered HLO stays compact for 100-layer models (critical for the 512-device
dry-run compile times) and remat applies uniformly per layer.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from ..shard import constrain
from .config import ModelConfig
from .layers import (attention_block, empty_kv_cache, gated_mlp,
                     init_attention, init_mlp, rmsnorm)
from .moe import init_moe, moe_block
from .rwkv import empty_rwkv_cache, init_rwkv_block, rwkv_block
from .ssm import empty_ssm_cache, init_ssm, ssm_block


# ================================================================== init
def init_params(cfg: ModelConfig, key: jax.Array, dtype=jnp.bfloat16) -> dict:
    keys = jax.random.split(key, 8)
    D, V = cfg.d_model, cfg.vocab
    p = {
        "embed": (jax.random.normal(keys[0], (V, D)) * 0.02).astype(dtype),
        "final_norm": jnp.zeros((D,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        p["head"] = (jax.random.normal(keys[1], (D, V)) / math.sqrt(D)).astype(dtype)

    lkeys = jax.random.split(keys[2], cfg.n_layers)
    fam = cfg.family
    if fam in ("dense", "vlm", "audio", "moe"):
        def one(k):
            k1, k2 = jax.random.split(k)
            lp = {"attn": init_attention(k1, cfg, dtype),
                  "ln1": jnp.zeros((D,), jnp.float32),
                  "ln2": jnp.zeros((D,), jnp.float32)}
            if fam == "moe":
                lp["moe"] = init_moe(k2, cfg, dtype)
            else:
                lp["mlp"] = init_mlp(k2, D, cfg.d_ff, dtype)
            return lp
        p["layers"] = jax.vmap(one)(lkeys)
    elif fam == "rwkv":
        p["layers"] = jax.vmap(lambda k: init_rwkv_block(k, cfg, dtype))(lkeys)
    elif fam in ("ssm", "hybrid"):
        def one(k):
            return {"ssm": init_ssm(k, cfg, dtype),
                    "ln": jnp.zeros((D,), jnp.float32)}
        p["layers"] = jax.vmap(one)(lkeys)
        if fam == "hybrid":
            k1, k2 = jax.random.split(keys[3])
            p["shared_attn"] = {
                "attn": init_attention(k1, cfg, dtype),
                "mlp": init_mlp(k2, D, cfg.d_ff, dtype),
                "ln1": jnp.zeros((D,), jnp.float32),
                "ln2": jnp.zeros((D,), jnp.float32),
            }
    else:
        raise ValueError(f"unknown family {fam}")
    return p


# ================================================================== blocks
def _dense_block(lp: dict, x, cfg: ModelConfig, positions, cache, impl):
    h, nc = attention_block(lp["attn"], rmsnorm(x, lp["ln1"], cfg.norm_eps),
                            cfg, positions, cache, impl)
    x = x + h
    xn = rmsnorm(x, lp["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        h = moe_block(lp["moe"], xn, cfg)
    else:
        h = gated_mlp(lp["mlp"], xn, cfg.mlp_act)
    return x + h, nc


def _ssm_layer(lp: dict, x, cfg: ModelConfig, cache, chunk=64):
    h, nc = ssm_block(lp["ssm"], rmsnorm(x, lp["ln"], cfg.norm_eps), cfg,
                      cache=cache, chunk=chunk)
    return x + h, nc


def _shared_attn_block(sp: dict, x, cfg: ModelConfig, positions, cache, impl):
    h, nc = attention_block(sp["attn"], rmsnorm(x, sp["ln1"], cfg.norm_eps),
                            cfg, positions, cache, impl)
    x = x + h
    x = x + gated_mlp(sp["mlp"], rmsnorm(x, sp["ln2"], cfg.norm_eps), cfg.mlp_act)
    return x, nc


def _hybrid_split(cfg: ModelConfig, tree):
    """Split stacked-layer pytree into (n_super, k, ...) main + (rem, ...) tail."""
    k = cfg.attn_every
    n_super = cfg.n_layers // k
    main = jax.tree.map(lambda a: a[: n_super * k].reshape((n_super, k) + a.shape[1:]), tree)
    tail = jax.tree.map(lambda a: a[n_super * k:], tree)
    return main, tail, n_super, cfg.n_layers - n_super * k


# ================================================================== forward
def forward_hidden(params: dict, cfg: ModelConfig, tokens=None, embeds=None,
                   positions=None, impl: str = "ref", remat: bool = False):
    """Training / evaluation forward pass -> final hidden states (B,S,D)."""
    if embeds is not None:
        x = embeds
        B, S = x.shape[:2]
    else:
        B, S = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = constrain(x, "batch", "seq", "embed")
    fam = cfg.family

    if fam in ("dense", "vlm", "audio", "moe"):
        def body(carry, lp):
            y, _ = _dense_block(lp, carry, cfg, positions, None, impl)
            return y, None
        # NOTE (§Perf A2, refuted): saving the MoE combine buffer via
        # save_only_these_names('moe_combine') removes the backward re-gather
        # (-1TB/chip collectives) but keeps 94 x 10.7GB f32 buffers live --
        # 1.6TB/device, far over HBM.  Default nothing-saved remat it is.
        fn = jax.checkpoint(body) if remat else body
        x, _ = jax.lax.scan(fn, x, params["layers"])
    elif fam == "rwkv":
        def body(carry, lp):
            y, _ = rwkv_block(lp, carry, cfg)
            return y, None
        fn = jax.checkpoint(body) if remat else body
        x, _ = jax.lax.scan(fn, x, params["layers"])
    elif fam == "ssm":
        def body(carry, lp):
            y, _ = _ssm_layer(lp, carry, cfg, None)
            return y, None
        fn = jax.checkpoint(body) if remat else body
        x, _ = jax.lax.scan(fn, x, params["layers"])
    elif fam == "hybrid":
        main, tail, n_super, rem = _hybrid_split(cfg, params["layers"])
        sp = params["shared_attn"]

        def inner(carry, lp):
            y, _ = _ssm_layer(lp, carry, cfg, None)
            return y, None

        def super_body(carry, lp_k):
            y, _ = jax.lax.scan(inner, carry, lp_k)
            y, _ = _shared_attn_block(sp, y, cfg, positions, None, impl)
            return y, None
        fn = jax.checkpoint(super_body) if remat else super_body
        x, _ = jax.lax.scan(fn, x, main)
        if rem:
            fn_t = jax.checkpoint(inner) if remat else inner
            x, _ = jax.lax.scan(fn_t, x, tail)
    else:
        raise ValueError(fam)
    return rmsnorm(x, params["final_norm"], cfg.norm_eps)


def logits_from_hidden(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["head"]
    return constrain(logits, "batch", "seq", "vocab")


def forward(params, cfg, tokens=None, embeds=None, positions=None,
            impl="ref", remat=False):
    x = forward_hidden(params, cfg, tokens, embeds, positions, impl, remat)
    return logits_from_hidden(params, cfg, x)


# ================================================================== loss
def lm_loss(params: dict, cfg: ModelConfig, batch: dict,
            impl: str = "ref", remat: bool = True) -> jax.Array:
    """Next-token CE, fp32 accumulation; labels < 0 are masked."""
    x = forward_hidden(params, cfg, tokens=batch.get("tokens"),
                       embeds=batch.get("embeds"), impl=impl, remat=remat)
    logits = logits_from_hidden(params, cfg, x).astype(jnp.float32)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None],
                             axis=-1)[..., 0]
    nll = (lse - ll) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)


# ================================================================== serving
def make_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    fam = cfg.family
    if fam in ("dense", "vlm", "audio", "moe"):
        return {"kv": empty_kv_cache(cfg, batch, max_len, dtype=dtype)}
    if fam == "rwkv":
        return {"rwkv": empty_rwkv_cache(cfg, batch, dtype=dtype)}
    if fam == "ssm":
        return {"ssm": empty_ssm_cache(cfg, batch, dtype=dtype)}
    if fam == "hybrid":
        n_super = cfg.n_layers // cfg.attn_every
        return {
            "ssm": empty_ssm_cache(cfg, batch, dtype=dtype),
            "kv": empty_kv_cache(cfg, batch, max_len, n_layers=n_super, dtype=dtype),
        }
    raise ValueError(fam)


def _run_cached(params, cfg, x, positions, cache, impl):
    """Shared cached-mode layer stack (prefill T>=1 and decode T==1)."""
    fam = cfg.family
    if fam in ("dense", "vlm", "audio", "moe"):
        def body(carry, xs):
            lp, cl = xs
            y, nc = _dense_block(lp, carry, cfg, positions, cl, impl)
            return y, nc
        x, kv = jax.lax.scan(body, x, (params["layers"], cache["kv"]))
        return x, {"kv": kv}
    if fam == "rwkv":
        def body(carry, xs):
            lp, cl = xs
            y, nc = rwkv_block(lp, carry, cfg, cache=cl)
            return y, nc
        x, rc = jax.lax.scan(body, x, (params["layers"], cache["rwkv"]))
        return x, {"rwkv": rc}
    if fam == "ssm":
        def body(carry, xs):
            lp, cl = xs
            y, nc = _ssm_layer(lp, carry, cfg, cl)
            return y, nc
        x, sc = jax.lax.scan(body, x, (params["layers"], cache["ssm"]))
        return x, {"ssm": sc}
    if fam == "hybrid":
        main, tail, n_super, rem = _hybrid_split(cfg, params["layers"])
        cmain, ctail, _, _ = _hybrid_split(cfg, cache["ssm"])
        sp = params["shared_attn"]

        def inner(carry, xs):
            lp, cl = xs
            y, nc = _ssm_layer(lp, carry, cfg, cl)
            return y, nc

        def super_body(carry, xs):
            lp_k, cl_k, kv_l = xs
            y, nc = jax.lax.scan(inner, carry, (lp_k, cl_k))
            y, nkv = _shared_attn_block(sp, y, cfg, positions, kv_l, impl)
            return y, (nc, nkv)
        x, (cm, kv) = jax.lax.scan(super_body, x, (main, cmain, cache["kv"]))
        if rem:
            x, ct = jax.lax.scan(inner, x, (tail, ctail))
        else:
            ct = ctail
        flat = jax.tree.map(
            lambda m, t: jnp.concatenate([m.reshape((-1,) + m.shape[2:]), t]), cm, ct)
        return x, {"ssm": flat, "kv": kv}
    raise ValueError(fam)


def prefill(params: dict, cfg: ModelConfig, tokens=None, embeds=None,
            cache: Optional[dict] = None, impl: str = "ref"):
    """Process a prompt, filling the cache.  Returns (last_logits, cache)."""
    if embeds is not None:
        x = embeds
        B, S = x.shape[:2]
    else:
        B, S = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0)
    if cache is None:
        cache = make_cache(cfg, B, max_len=S)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = constrain(x, "batch", "seq", "embed")
    x, new_cache = _run_cached(params, cfg, x, positions, cache, impl)
    x = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    return logits_from_hidden(params, cfg, x)[:, 0], new_cache


def decode_step(params: dict, cfg: ModelConfig, cache: dict,
                tokens: jax.Array, pos: jax.Array, impl: str = "ref"):
    """One decode step.  tokens: (B,) int32; pos: (B,) absolute positions.
    Returns (logits (B,V), new_cache)."""
    x = jnp.take(params["embed"], tokens[:, None], axis=0)
    positions = pos[:, None]
    x = constrain(x, "batch", "seq", "embed")
    x, new_cache = _run_cached(params, cfg, x, positions, cache, impl)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return logits_from_hidden(params, cfg, x)[:, 0], new_cache
