"""Mixture-of-Experts sublayer: shared experts + routed top-k experts.

Dispatch is sort-based (megablocks-style) rather than one-hot-einsum based:
a (T,E,C) one-hot dispatch tensor is O(T*E*C) and blows past HBM at
global-batch scale, while argsort + gather/scatter is O(T*k).  Tokens are
grouped per sequence (G=B groups of S tokens) so dispatch stays local to the
data shard; expert matmuls run with E sharded over the 'model' mesh axis
(expert parallelism — the token movement lowers to all-to-alls under pjit).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..shard import constrain
from .config import ModelConfig
from .layers import _act, gated_mlp, init_mlp


def _group_dispatch_indices(topi: jax.Array, E: int, C: int):
    """topi: (S, k) expert choices for one token group.
    Returns (slot (S,k) int32 into a flat (E*C) buffer, keep (S,k) bool)."""
    S, k = topi.shape
    flat_e = topi.reshape(-1)                               # (S*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    start = jnp.searchsorted(sorted_e, jnp.arange(E))       # (E,)
    pos_sorted = jnp.arange(S * k) - start[sorted_e]
    pos = jnp.zeros((S * k,), jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))
    keep = pos < C
    slot = jnp.where(keep, flat_e * C + pos, E * C)         # E*C = drop slot
    return slot.reshape(S, k), keep.reshape(S, k)


def moe_block(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x: (B, S, D) -> (B, S, D)."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = max(1, int(cfg.capacity_factor * S * k / E))
    gates = jax.nn.softmax(
        x.astype(jnp.float32) @ p["router"].astype(jnp.float32), axis=-1)
    topv, topi = jax.lax.top_k(gates, k)                    # (B,S,k)
    topv = topv / (jnp.sum(topv, axis=-1, keepdims=True) + 1e-9)

    slot, keep = jax.vmap(lambda t: _group_dispatch_indices(t, E, C))(topi)
    slot = jnp.where(keep, slot, E * C)                     # dropped -> trash slot

    # Dispatch stays BATCH-LOCAL: only small int32 index buffers are
    # scattered; the wide (D) rows move via gathers over an unsharded dim.
    # The only cross-chip movement is the explicit batch<->expert reshard of
    # the dense buffers below (all-to-all under GSPMD) — without this, GSPMD
    # replicates the scatter/gather operands per layer (~50TB/chip/step on
    # qwen3-moe; see EXPERIMENTS.md §Perf iteration A1).
    tok = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :, None],
                           (B, S, k))
    bidx = jnp.broadcast_to(jnp.arange(B)[:, None, None], (B, S, k))
    buf_idx = jnp.full((B, E * C + 1), S, jnp.int32)        # S -> zero row
    buf_idx = buf_idx.at[bidx.reshape(B, -1), slot.reshape(B, -1)].set(
        tok.reshape(B, -1))
    buf_idx = constrain(buf_idx, "batch", None)
    x_pad = jnp.concatenate([x, jnp.zeros((B, 1, D), x.dtype)], axis=1)
    ex_in = jnp.take_along_axis(x_pad, buf_idx[:, :E * C, None], axis=1)
    ex_in = ex_in.reshape(B, E, C, D)
    ex_in = constrain(ex_in, "batch", "experts", None, None)   # a2a -> EP

    # bf16 dot outputs: otherwise XLA hoists the f32->bf16 convert past the
    # combine all-gather and moves the buffer at twice the width (§Perf A3)
    pet = x.dtype
    h = _act(cfg.mlp_act)(jnp.einsum("becd,edf->becf", ex_in, p["w1"],
                                     preferred_element_type=pet))
    h = h * jnp.einsum("becd,edf->becf", ex_in, p["w3"],
                       preferred_element_type=pet)
    h = constrain(h, "batch", "experts", None, None)
    ex_out = jnp.einsum("becf,efd->becd", h, p["w2"],
                        preferred_element_type=pet)
    ex_out = constrain(ex_out, "batch", "experts", None, None)

    # a2a back to batch-local layout, then gather + weighted combine.
    # (§Perf A3, refuted twice: neither preferred_element_type nor an
    # optimization barrier stops the CPU lowering from hoisting the f32->bf16
    # convert past this all-gather; on a real TPU backend the dot emits bf16
    # directly, so we keep the clean form.)
    flat_out = jnp.concatenate(
        [ex_out.reshape(B, E * C, D),
         jnp.zeros((B, 1, D), ex_out.dtype)], axis=1)       # trash slot reads 0
    flat_out = constrain(flat_out, "batch", None, None)
    # saved under remat (EXPERIMENTS.md §Perf A2): re-gathering this in the
    # backward pass would repeat the most expensive collective of the layer
    from jax.ad_checkpoint import checkpoint_name
    flat_out = checkpoint_name(flat_out, "moe_combine")
    y = jnp.take_along_axis(flat_out, slot.reshape(B, -1, 1), axis=1)
    y = y.reshape(B, S, k, D)
    w = (topv * keep).astype(y.dtype)
    y = jnp.einsum("bskd,bsk->bsd", y, w)
    if cfg.n_shared_experts:
        y = y + gated_mlp(p["shared"], x, cfg.mlp_act)
    return y


def init_moe(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    d, E, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": (jax.random.normal(ks[0], (d, E)) / math.sqrt(d)).astype(jnp.float32),
        "w1": (jax.random.normal(ks[1], (E, d, f)) / math.sqrt(d)).astype(dtype),
        "w3": (jax.random.normal(ks[2], (E, d, f)) / math.sqrt(d)).astype(dtype),
        "w2": (jax.random.normal(ks[3], (E, f, d)) / math.sqrt(f)).astype(dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], d, cfg.n_shared_experts * f, dtype)
    return p


def aux_load_balance_loss(gates: jax.Array, k: int) -> jax.Array:
    """Switch-style auxiliary loss (mean fraction * mean gate per expert)."""
    T, E = gates.shape
    topi = jax.lax.top_k(gates, k)[1]
    counts = jnp.sum(jax.nn.one_hot(topi, E, dtype=jnp.float32), axis=(0, 1))
    frac = counts / jnp.maximum(1.0, T * k)
    imp = jnp.mean(gates, axis=0)
    return E * jnp.sum(frac * imp)
