"""Declarative fault plans.

A :class:`FaultPlan` is pure data describing *when* and *how* the cluster
misbehaves, independent of the engine that executes it:

* **timed events** — concrete ``(kind, ...args, t)`` tuples: ``crash`` /
  ``recover`` a node, ``partition`` / ``heal`` a link (symmetric), the
  ``_oneway`` variants (asymmetric), and windowed degradations ``slow``
  (extra one-way latency and/or a latency factor — the "gray node" model)
  and ``drop`` (probabilistic message loss at a node);
* **periodic events** — ``crash_recover`` cycles expanded over a horizon;
* **storms** — seeded randomized fault generators parameterized by rate,
  target set, mean downtime, and a concurrency cap (the liveness guard:
  a storm never downs more than ``max_concurrent`` targets at once).

``materialize(horizon)`` expands everything into one sorted concrete event
list — the single source of truth consumed by both compilers:

* ``apply_plan(cluster, plan)`` schedules the events as virtual-time
  callbacks on the DES scheduler (exact and fast engines);
* ``plan.to_masks(n, horizon)`` lowers *mask-expressible* plans (crash /
  recover windows plus whole-run ``slow`` extra latency) to per-node
  availability windows + slow vectors for the batch backend
  (``repro.core.vectorsim``); anything else raises, so a scenario can
  validate batch eligibility at registration time.

Plans are frozen dataclasses of tuples: picklable, JSON-clean via
``dataclasses.asdict``, and composable with ``+``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

_INF = float("inf")

# concrete event forms (all times are virtual seconds):
#   ("crash", node, t)
#   ("recover", node, t)
#   ("partition", a, b, t) / ("heal", a, b, t)             symmetric
#   ("partition_oneway", a, b, t) / ("heal_oneway", a, b, t)  a -> b only
#   ("slow", node, t0, t1, extra_latency_s, latency_factor)
#   ("drop", node, t0, t1, drop_prob)
#   ("add_node", node, t) / ("remove_node", node, t)   membership change
#   ("replace_leader", node, t)                        planned handoff
EVENT_ARITY = {
    "crash": 3, "recover": 3,
    "partition": 4, "heal": 4,
    "partition_oneway": 4, "heal_oneway": 4,
    "slow": 6, "drop": 5,
    "add_node": 3, "remove_node": 3, "replace_leader": 3,
}

# membership-change kinds: DES-only (the batch model's replica set is fixed)
_MEMBERSHIP_KINDS = ("add_node", "remove_node", "replace_leader")

# kinds the batch backend can express as masks (see to_masks)
_MASK_KINDS = ("crash", "recover", "slow")


def _event_time(ev: tuple) -> float:
    """The *start* time of a concrete event (window kinds carry t0 at [2])."""
    return float(ev[2] if ev[0] in ("slow", "drop") else ev[-1])


def validate_event(ev: tuple) -> None:
    if not ev or ev[0] not in EVENT_ARITY:
        raise ValueError(f"unknown fault event kind in {ev!r} "
                         f"(known: {sorted(EVENT_ARITY)})")
    if len(ev) != EVENT_ARITY[ev[0]]:
        raise ValueError(f"fault event {ev!r}: expected "
                         f"{EVENT_ARITY[ev[0]]} fields")


@dataclass(frozen=True)
class FaultPlan:
    """One declarative fault schedule (see module docstring for the forms)."""

    events: Tuple[tuple, ...] = ()
    # ("crash_recover", node, period, downtime, t0, t1)
    periodic: Tuple[tuple, ...] = ()
    # {"kind": "crash"|"partition", "rate_hz", "t0", "t1", "mean_downtime",
    #  "targets": (ids...), "seed", "max_concurrent"}
    storms: Tuple[dict, ...] = ()

    def __post_init__(self):
        for ev in self.events:
            validate_event(tuple(ev))
        for p in self.periodic:
            if p[0] != "crash_recover" or len(p) != 6:
                raise ValueError(f"unknown periodic fault {p!r}")
        for s in self.storms:
            if s.get("kind", "crash") not in ("crash", "partition"):
                raise ValueError(f"unknown storm kind {s.get('kind')!r}")

    def __bool__(self) -> bool:
        return bool(self.events or self.periodic or self.storms)

    def __add__(self, other: "FaultPlan") -> "FaultPlan":
        return FaultPlan(events=self.events + other.events,
                         periodic=self.periodic + other.periodic,
                         storms=self.storms + other.storms)

    # ------------------------------------------------------------ expansion
    def materialize(self, horizon: float) -> List[tuple]:
        """Expand periodic entries and storms into the sorted concrete event
        list for a run of ``horizon`` virtual seconds.  Deterministic: storms
        draw from their own seeded generator, never the simulation RNG."""
        evs = [tuple(ev) for ev in self.events if _event_time(ev) < horizon]
        for (_, node, period, downtime, t0, t1) in self.periodic:
            t = float(t0)
            while t < min(t1, horizon):
                evs.append(("crash", node, t))
                evs.append(("recover", node, min(t + downtime, horizon)))
                t += period
        for s in self.storms:
            evs.extend(_expand_storm(s, horizon))
        evs.sort(key=_event_time)
        self._check_degradation_overlap(evs)
        return evs

    @staticmethod
    def _check_degradation_overlap(evs: Sequence[tuple]) -> None:
        """The Network holds ONE degradation state per node, so overlapping
        slow/drop windows on the same node would silently clobber each other
        — reject them loudly instead."""
        wins: Dict[int, List[Tuple[float, float]]] = {}
        for ev in evs:
            if ev[0] in ("slow", "drop"):
                node, t0, t1 = ev[1], float(ev[2]), float(ev[3])
                for (a, b) in wins.get(node, ()):
                    if t0 < b and a < t1:
                        raise ValueError(
                            f"overlapping degradation windows on node {node}: "
                            f"[{a},{b}) and [{t0},{t1})")
                wins.setdefault(node, []).append((t0, t1))

    def validate_targets(self, n: int, horizon: float) -> None:
        """Every materialized event must target node ids < ``n`` — the
        registry-time guard: a typo'd id fails at registration, not as an
        IndexError halfway through a suite run.  For plans with membership
        events, pass the TOTAL node count (members + spares): ``add_node``
        legitimately names a node outside the initial membership."""
        for ev in self.materialize(horizon):
            nodes = (ev[1], ev[2]) if ev[0] in (
                "partition", "heal", "partition_oneway", "heal_oneway") \
                else (ev[1],)
            for x in nodes:
                if not 0 <= int(x) < n:
                    raise ValueError(f"fault event {ev!r} targets node {x} "
                                     f"outside 0..{n - 1}")

    # ------------------------------------------------------------- batching
    def mask_expressible(self, horizon: float) -> bool:
        """True iff the batch backend can run this plan (see to_masks)."""
        try:
            self.to_masks(1 + self._max_node(horizon), horizon)
            return True
        except ValueError:
            return False

    def _max_node(self, horizon: float) -> int:
        nodes = [0]
        for ev in self.materialize(horizon):
            if ev[0] in ("partition", "heal", "partition_oneway",
                         "heal_oneway"):
                nodes.extend((int(ev[1]), int(ev[2])))
            else:           # single-node kinds (ev[2] may be a time, not a node)
                nodes.append(int(ev[1]))
        return max(nodes)

    def to_masks(self, n: int, horizon: float,
                 max_windows: int = 8) -> Dict[str, np.ndarray]:
        """Lower the plan to batch-backend masks.

        Returns ``{"down": (n, W, 2) float64 [lo, hi) down-windows padded
        with +inf, "slow": (n,) float64 extra one-way seconds}``.  Raises
        ``ValueError`` for anything the round-level model cannot express:
        partitions, drops, latency factors, or ``slow`` windows that do not
        span the whole run (the "gray relay throughout" form is supported;
        transient gray windows need the DES).
        """
        windows: Dict[int, List[List[float]]] = {}
        open_at: Dict[int, float] = {}
        slow = np.zeros(n, dtype=np.float64)
        for ev in self.materialize(horizon):
            kind = ev[0]
            if kind == "crash":
                node = int(ev[1])
                if node in open_at:
                    raise ValueError(f"node {node} crashed twice without "
                                     "recovering — not mask-expressible")
                open_at[node] = float(ev[2])
            elif kind == "recover":
                node = int(ev[1])
                t0 = open_at.pop(node, None)
                if t0 is None:
                    raise ValueError(f"recover of node {node} without a "
                                     "preceding crash")
                windows.setdefault(node, []).append([t0, float(ev[2])])
            elif kind == "slow":
                node, t0, t1, extra, factor = (int(ev[1]), float(ev[2]),
                                               float(ev[3]), float(ev[4]),
                                               float(ev[5]))
                if factor != 1.0 or t0 > 0.0 or t1 < horizon:
                    raise ValueError(
                        "batch masks support only whole-run additive slow "
                        f"nodes (factor=1, window [0, horizon)); got {ev!r}")
                slow[node] += extra
            elif kind in _MEMBERSHIP_KINDS:
                raise ValueError(
                    f"fault kind {kind!r} is not mask-expressible: the batch "
                    "backend models a FIXED replica set with per-node "
                    "availability windows, and membership change needs a "
                    "time-varying replica set — use the DES "
                    "(engine='exact'/'fast')")
            elif kind in ("partition", "heal", "partition_oneway",
                          "heal_oneway"):
                raise ValueError(
                    f"fault kind {kind!r} is not mask-expressible: the batch "
                    "backend has per-node availability masks but no per-link "
                    "connectivity state, so partitions cannot be lowered — "
                    "use the DES (engine='exact'/'fast')")
            elif kind == "drop":
                raise ValueError(
                    "fault kind 'drop' is not mask-expressible: probabilistic "
                    "per-message loss needs per-message randomness the "
                    "round-level batch model does not simulate — use the DES "
                    "(engine='exact'/'fast')")
            else:
                raise ValueError(f"fault kind {kind!r} is not "
                                 "mask-expressible — use the DES")
        for node, t0 in open_at.items():          # crash with no recover
            windows.setdefault(node, []).append([t0, _INF])
        w = max([len(v) for v in windows.values()] + [1])
        if w > max_windows:
            raise ValueError(f"{w} down-windows on one node exceeds the "
                             f"mask budget ({max_windows})")
        down = np.full((n, w, 2), _INF, dtype=np.float64)
        for node, ws in windows.items():
            if node >= n:
                raise ValueError(f"fault targets node {node} >= n={n}")
            for i, (lo, hi) in enumerate(ws):
                down[node, i] = (lo, hi)
        return {"down": down, "slow": slow}


# ---------------------------------------------------------------- builders
def crash_window(node: int, t0: float, t1: Optional[float] = None) -> FaultPlan:
    """Crash ``node`` at ``t0``; recover at ``t1`` (None = never)."""
    evs = [("crash", node, float(t0))]
    if t1 is not None:
        evs.append(("recover", node, float(t1)))
    return FaultPlan(events=tuple(evs))


def partition_window(a: int, b: int, t0: float, t1: Optional[float] = None,
                     oneway: bool = False) -> FaultPlan:
    """Cut the a<->b link (or only a->b with ``oneway``) at ``t0``, heal at
    ``t1`` (None = never)."""
    cut = "partition_oneway" if oneway else "partition"
    heal = "heal_oneway" if oneway else "heal"
    evs = [(cut, a, b, float(t0))]
    if t1 is not None:
        evs.append((heal, a, b, float(t1)))
    return FaultPlan(events=tuple(evs))


def slow_window(node: int, t0: float = 0.0, t1: float = _INF,
                extra_latency: float = 0.0, factor: float = 1.0) -> FaultPlan:
    """Gray/slow node: every hop touching ``node`` in [t0, t1) pays
    ``latency * factor + extra_latency``."""
    return FaultPlan(events=(("slow", node, float(t0), float(t1),
                              float(extra_latency), float(factor)),))


def drop_window(node: int, t0: float, t1: float, prob: float) -> FaultPlan:
    """Gray/lossy node: hops touching ``node`` in [t0, t1) drop w.p. ``prob``."""
    return FaultPlan(events=(("drop", node, float(t0), float(t1),
                              float(prob)),))


def add_node(node: int, t: float) -> FaultPlan:
    """Join spare ``node`` to the cluster at ``t``: the node catches up from
    a leader snapshot + log suffix, then the leader commits a single-server
    ``add_node`` reconfiguration through the normal log."""
    return FaultPlan(events=(("add_node", int(node), float(t)),))


def remove_node(node: int, t: float) -> FaultPlan:
    """Remove ``node`` from the membership at ``t`` via a single-server
    reconfiguration command (the node may be the leader — leadership moves)."""
    return FaultPlan(events=(("remove_node", int(node), float(t)),))


def replace_leader(node: int, t: float) -> FaultPlan:
    """Planned leadership handoff: ``node`` runs phase-1 with a higher ballot
    at ``t``; the sitting leader steps down on seeing the higher promise."""
    return FaultPlan(events=(("replace_leader", int(node), float(t)),))


def rolling_restart(nodes: Sequence[int], t0: float, downtime: float = 0.06,
                    gap: float = 0.15) -> FaultPlan:
    """Restart every node in ``nodes`` in sequence: node i crashes at
    ``t0 + i*gap`` and recovers ``downtime`` later.  ``gap`` must exceed
    ``downtime`` so at most one node is ever down (the rolling-upgrade
    availability model)."""
    if gap <= downtime:
        raise ValueError(f"rolling_restart gap ({gap}) must exceed downtime "
                         f"({downtime}) — otherwise restarts overlap")
    evs: List[tuple] = []
    for i, node in enumerate(nodes):
        t = float(t0) + i * float(gap)
        evs.append(("crash", int(node), t))
        evs.append(("recover", int(node), t + float(downtime)))
    return FaultPlan(events=tuple(evs))


def periodic_crash(node: int, period: float, downtime: float,
                   t0: float = 0.0, t1: float = _INF) -> FaultPlan:
    """Crash ``node`` every ``period`` seconds for ``downtime`` each time."""
    return FaultPlan(periodic=(("crash_recover", node, float(period),
                                float(downtime), float(t0), float(t1)),))


def storm(targets: Sequence[int], rate_hz: float, t0: float, t1: float,
          mean_downtime: float = 0.15, seed: int = 0,
          kind: str = "crash", max_concurrent: int = 1) -> FaultPlan:
    """Randomized fault storm: Poisson fault arrivals at ``rate_hz`` over
    [t0, t1), each crashing (or partitioning a pair of) a random target for
    Exp(``mean_downtime``) seconds.  ``max_concurrent`` is the liveness
    guard — arrivals that would exceed it are skipped, so a storm can never
    down a quorum by accident.  Fully determined by ``seed``."""
    return FaultPlan(storms=({
        "kind": kind, "rate_hz": float(rate_hz), "t0": float(t0),
        "t1": float(t1), "mean_downtime": float(mean_downtime),
        "targets": tuple(int(x) for x in targets), "seed": int(seed),
        "max_concurrent": int(max_concurrent)},))


def _expand_storm(s: dict, horizon: float) -> List[tuple]:
    rng = np.random.default_rng(int(s.get("seed", 0)))
    kind = s.get("kind", "crash")
    rate = float(s["rate_hz"])
    targets = list(s["targets"])
    mean_dt = float(s.get("mean_downtime", 0.15))
    cap = int(s.get("max_concurrent", 1))
    end = min(float(s["t1"]), horizon)
    t = float(s["t0"])
    down_until: Dict[int, float] = {}
    evs: List[tuple] = []
    while True:
        t += float(rng.exponential(1.0 / rate))
        if t >= end:
            break
        down_until = {x: r for x, r in down_until.items() if r > t}
        if len(down_until) >= cap:
            continue                       # liveness guard: skip this arrival
        up = [x for x in targets if x not in down_until]
        if kind == "partition":
            if len(up) < 2:
                continue
            a, b = rng.choice(up, size=2, replace=False)
            dur = max(0.02, float(rng.exponential(mean_dt)))
            evs.append(("partition", int(a), int(b), t))
            evs.append(("heal", int(a), int(b), min(t + dur, horizon)))
            down_until[int(a)] = t + dur   # count partitioned pair vs the cap
            down_until[int(b)] = t + dur
        else:
            if not up:
                continue
            node = int(rng.choice(up))
            dur = max(0.02, float(rng.exponential(mean_dt)))
            evs.append(("crash", node, t))
            evs.append(("recover", node, min(t + dur, horizon)))
            down_until[node] = t + dur
    return evs


# ------------------------------------------------------------- DES compiler
def apply_plan(cluster, plan: FaultPlan, horizon: float = _INF) -> List[tuple]:
    """Schedule every materialized event of ``plan`` on ``cluster``'s
    scheduler.  Works on both DES engines (exact and fast): crash/recover go
    through the node API (recovery re-election included, see
    ``PaxosNode.recover``), partitions and degradations through the
    ``Network`` failure API.  Returns the materialized events (the run's
    fault timeline, recorded in artifacts)."""
    sched, net = cluster.sched, cluster.net
    evs = plan.materialize(horizon)
    if evs:
        # fault mode: protocols with an opt-in recovery path switch it on
        # (EPaxos explicit-prepare instance recovery — off by default so
        # fault-free runs keep their golden traces and hot path)
        for nd in getattr(cluster, "nodes", ()):
            enable = getattr(nd, "enable_recovery", None)
            if enable is not None:
                enable()
    for ev in evs:
        kind = ev[0]
        if kind == "crash":
            cluster.crash_at(ev[1], ev[2])
        elif kind == "recover":
            cluster.recover_at(ev[1], ev[2])
        elif kind == "partition":
            cluster.partition_at(ev[1], ev[2], ev[3])
        elif kind == "heal":
            sched.at(ev[3], lambda a=ev[1], b=ev[2]: net.heal(a, b))
        elif kind == "partition_oneway":
            sched.at(ev[3], lambda a=ev[1], b=ev[2]: net.partition_oneway(a, b))
        elif kind == "heal_oneway":
            sched.at(ev[3], lambda a=ev[1], b=ev[2]: net.heal_oneway(a, b))
        elif kind == "slow":
            _, node, t0, t1, extra, factor = ev
            sched.at(t0, lambda n=node, e=extra, f=factor:
                     net.degrade(n, extra_latency=e, factor=f))
            if t1 < _INF:
                sched.at(t1, lambda n=node: net.restore(n))
        elif kind == "drop":
            _, node, t0, t1, prob = ev
            sched.at(t0, lambda n=node, p=prob: net.degrade(n, drop_prob=p))
            if t1 < _INF:
                sched.at(t1, lambda n=node: net.restore(n))
        elif kind == "add_node":
            sched.at(ev[2], lambda n=ev[1]: cluster.add_node(n))
        elif kind == "remove_node":
            sched.at(ev[2], lambda n=ev[1]: cluster.remove_node(n))
        elif kind == "replace_leader":
            sched.at(ev[2], lambda n=ev[1]: cluster.replace_leader(n))
    return evs


def jsonify_events(evs: Sequence[tuple]) -> List[list]:
    """Materialized events as JSON-clean lists (inf -> None)."""
    out = []
    for ev in evs:
        out.append([None if isinstance(x, float) and math.isinf(x) else x
                    for x in ev])
    return out
