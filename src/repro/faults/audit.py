"""Consistency auditor: linearizability of the applied logs vs client
histories.

The cluster is a keyed register store, and linearizability is *composable*
(local): a multi-object history is linearizable iff every per-object
subhistory is.  The replicas' applied logs supply a candidate linearization
directly — the commit/execution order — so instead of a Wing–Gong search the
check verifies, per key, that this witness order is a *valid* linearization
of what the clients observed:

1. **replica agreement** — every node's per-key applied projection is a
   contiguous *window* of one merged witness order (for (Pig)Paxos the whole
   log is totally ordered; for EPaxos only interfering — same-key — commands
   are ordered, which is exactly the per-key projection).  Windows rather
   than prefixes because the replica set is time-varying: a node joined from
   a snapshot starts applying mid-stream, a removed node stops early, and
   the current leader applies at commit so it can run ahead of every
   follower's end;
2. **at-most-once** — no ``(client_id, seq)`` appears twice in the witness
   (client timeout-retries must not double-apply);
3. **durability** — every operation a client saw complete (``ok`` reply)
   appears in the log of some replica in the FINAL membership (a copy held
   only by a removed node does not count — the cluster walked away from it);
4. **real-time order** — if operation A completed before operation B was
   invoked (on the same key), A precedes B in the witness;
5. **read values** — every completed ``get`` returned the value written by
   the latest ``put`` preceding it in the witness (write identity comes
   from the per-op value tags the history-recording clients attach);
6. **non-logged reads** (``path`` in ``{"lease", "quorum"}`` — leased
   leader-local reads and client-side quorum reads never enter the log, so
   checks 1–5 cannot see them): each must return a value that (a) is a real
   witness put or the initial value (no phantoms), (b) is at least as fresh
   as every put — and every other non-logged read — that COMPLETED before
   this read was invoked (no stale reads, no read inversion), and (c) was
   not written by a put invoked after the read completed (no reads from the
   future).  These reads are exempt from the durability check: not being
   logged is their point.

Model boundary: the auditor sees the DES histories only — batch-backend
cells are never audited directly (their read/write semantics are
cross-checked against audited DES twins by the `reads` scenario family).

``check_history`` is a pure function over plain data so tests can feed it
deliberately corrupted fixtures; ``audit_cluster`` adapts a finished
``Cluster`` run (requires ``Cluster(record_history=True)``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_INF = float("inf")
_MAX_VIOLATIONS = 20


@dataclass
class AuditResult:
    ok: bool
    ops: int = 0                 # witness operations checked
    completed: int = 0           # client-completed operations
    reads_checked: int = 0       # gets with verified return values
    violations: List[str] = field(default_factory=list)

    def summary(self) -> dict:
        return {"ok": self.ok, "ops": self.ops, "completed": self.completed,
                "reads_checked": self.reads_checked,
                "violations": self.violations[:5]}


def client_histories(cluster) -> List[dict]:
    """Flatten the per-client operation records of a history-recording run."""
    out: List[dict] = []
    for cl in cluster.clients:
        if cl.history is None:
            raise ValueError("cluster was not run with record_history=True")
        out.extend(cl.history)
    return out


def applied_ops(node) -> List[Tuple[int, int, str, int]]:
    """A node's applied log as (client_id, seq, op, key) in apply order."""
    return [(c.client_id, c.seq, c.op, c.key) for _, c in node.applied_log]


def check_history(history: List[dict],
                  logs: List[List[Tuple[int, int, str, int]]],
                  durable_logs: Optional[List[int]] = None) -> AuditResult:
    """Run the five checks above.  ``history`` entries are dicts with keys
    ``cid, seq, op, key, invoke, resp, ok, rtag, wtag`` (``resp`` None for
    incomplete ops; ``rtag`` is the tag of the value a get returned, ``wtag``
    the tag a put wrote — both None-able).  ``logs`` is one (cid, seq, op,
    key) list per replica, in that replica's apply order.  ``durable_logs``
    names the indices into ``logs`` that count for the durability check —
    the membership in force at the end of the run; None means all replicas
    (the fixed-membership case)."""
    res = AuditResult(ok=True)
    hist: Dict[Tuple[int, int], dict] = {}
    for h in history:
        hist[(h["cid"], h["seq"])] = h
    res.completed = sum(1 for h in history if h.get("ok"))

    def violate(msg: str) -> None:
        res.ok = False
        if len(res.violations) < _MAX_VIOLATIONS:
            res.violations.append(msg)

    # per-key projections per replica (data ops only — membership-change
    # commands ride the same logs but their "key" is a node id, not a
    # register, so they are excluded from the linearizability space)
    proj: List[Dict[int, list]] = []
    for lg in logs:
        p: Dict[int, list] = {}
        for (cid, seq, op, key) in lg:
            if op in ("put", "get"):
                p.setdefault(key, []).append((cid, seq, op))
        proj.append(p)

    # non-logged reads (leased / quorum) never appear in any applied log:
    # they get their own per-key freshness checks against the witness below
    nl_reads: Dict[int, list] = {}
    for h in history:
        if (h.get("op") == "get" and h.get("ok")
                and h.get("path") in ("lease", "quorum")):
            nl_reads.setdefault(h["key"], []).append(h)

    for key in sorted({k for p in proj for k in p} | set(nl_reads)):
        ps = [p[key] for p in proj if key in p]
        if not ps:
            # only non-logged reads touched this key: empty witness, every
            # read must have returned the initial value
            self_reads = nl_reads.get(key, ())
            for h in self_reads:
                res.reads_checked += 1
                if h.get("rtag") is not None:
                    violate(f"phantom read on key {key}: {h.get('path')} "
                            f"read (client={h['cid']}, seq={h['seq']}) "
                            f"returned {h.get('rtag')} but no put to the "
                            f"key was ever applied")
            continue
        # Merge the per-replica orders into one witness.  Every replica's
        # projection must be a contiguous *window* of a single total order:
        # long-lived replicas hold prefixes, snapshot-joined replicas hold
        # infixes, and the current leader can overhang everyone's end (it
        # applies at commit; followers apply when the commit message lands).
        # Windows must agree wherever they overlap; consistent overhangs are
        # grafted onto the witness so the downstream checks cover them too.
        witness = list(max(ps, key=len))
        for p in ps:
            if not p or p == witness[:len(p)]:
                continue                              # prefix: the usual case
            pos = {e: i for i, e in enumerate(witness)}
            if p[0] in pos:
                j = pos[p[0]]
                k = min(len(p), len(witness) - j)
                ext = p[k:]                   # overhang past the witness end
                # grafted entries must be NEW — an "overhang" that re-orders
                # entries already in the witness is a cycle, i.e. divergence
                if p[:k] != witness[j:j + k] or any(e in pos for e in ext):
                    violate(f"replica divergence on key {key}: one replica's "
                            f"apply order conflicts with the merged witness "
                            f"order on their overlap")
                    break
                witness.extend(ext)
            elif witness[0] in p:
                j = p.index(witness[0])
                k = min(len(witness), len(p) - j)
                head, tail = p[:j], p[j + k:]
                if witness[:k] != p[j:j + k] or \
                        any(e in pos for e in head) or \
                        any(e in pos for e in tail):
                    violate(f"replica divergence on key {key}: one replica's "
                            f"apply order conflicts with the merged witness "
                            f"order on their overlap")
                    break
                witness[:0] = head            # p starts earlier: prepend head
                witness.extend(tail)
            # else: windows are disjoint — no shared history to cross-check
        last_put: Optional[Tuple[int, int]] = None
        max_invoke = -_INF
        seen_key = set()
        for (cid, seq, op) in witness:
            res.ops += 1
            if (cid, seq) in seen_key:
                violate(f"duplicate apply of op (client={cid}, seq={seq}) "
                        f"on key {key} — at-most-once violated")
            seen_key.add((cid, seq))
            h = hist.get((cid, seq))
            if h is not None and h.get("key") == key:
                resp = h["resp"] if (h.get("ok") and h["resp"] is not None) \
                    else _INF
                if resp < max_invoke:
                    violate(f"real-time order violated on key {key}: op "
                            f"(client={cid}, seq={seq}) completed at "
                            f"{resp:.6f} but follows an op invoked later "
                            f"in the witness order")
                if h["invoke"] > max_invoke:
                    max_invoke = h["invoke"]
                if op == "get" and h.get("ok"):
                    res.reads_checked += 1
                    if h.get("rtag") != last_put:
                        violate(f"stale/phantom read on key {key}: op "
                                f"(client={cid}, seq={seq}) returned "
                                f"{h.get('rtag')} but the witness says "
                                f"{last_put}")
            if op == "put":
                last_put = (cid, seq)

        # ---- check 6: non-logged (lease/quorum) reads on this key ----
        nls = nl_reads.get(key)
        if nls:
            put_pos: Dict[Tuple[int, int], int] = {}
            for i, (cid, seq, op) in enumerate(witness):
                if op == "put":
                    put_pos[(cid, seq)] = i
            # freshness floors by sweep: puts (and other non-logged reads)
            # that COMPLETED before a read's invoke lower-bound the witness
            # position the read must return
            puts_done = sorted(
                (hist[t]["resp"], i) for t, i in put_pos.items()
                if (ph := hist.get(t)) is not None and ph.get("ok")
                and ph["resp"] is not None)
            reads_done = sorted(
                (h["resp"], put_pos.get(h.get("rtag"), -1)) for h in nls)
            jp = jr = 0
            floor = rfloor = -1
            for h in sorted(nls, key=lambda h: h["invoke"]):
                inv = h["invoke"]
                while jp < len(puts_done) and puts_done[jp][0] < inv:
                    if puts_done[jp][1] > floor:
                        floor = puts_done[jp][1]
                    jp += 1
                while jr < len(reads_done) and reads_done[jr][0] < inv:
                    if reads_done[jr][1] > rfloor:
                        rfloor = reads_done[jr][1]
                    jr += 1
                rt = h.get("rtag")
                path = h.get("path")
                if rt is not None and rt not in put_pos:
                    violate(f"phantom read on key {key}: {path} read "
                            f"(client={h['cid']}, seq={h['seq']}) returned "
                            f"{rt}, which no replica ever applied")
                    continue
                res.reads_checked += 1
                rpos = put_pos[rt] if rt is not None else -1
                if rpos < floor:
                    violate(f"stale read on key {key}: {path} read "
                            f"(client={h['cid']}, seq={h['seq']}) returned "
                            f"witness position {rpos} ({rt}) but the put at "
                            f"position {floor} completed before the read "
                            f"was invoked")
                elif rpos < rfloor:
                    violate(f"stale read on key {key}: {path} read "
                            f"(client={h['cid']}, seq={h['seq']}) returned "
                            f"witness position {rpos} ({rt}) but an earlier "
                            f"completed read already saw position {rfloor} "
                            f"— read inversion")
                if rt is not None:
                    ph = hist.get(rt)
                    if (ph is not None and ph["invoke"] > h["resp"]):
                        violate(f"future read on key {key}: {path} read "
                                f"(client={h['cid']}, seq={h['seq']}) "
                                f"returned a value whose put was invoked "
                                f"after the read completed")

    # durability: every acknowledged op must survive on a replica that is
    # still a member at the end of the run
    idxs = range(len(logs)) if durable_logs is None else durable_logs
    durable_seen = set()
    for i in idxs:
        for (cid, seq, _op, _key) in logs[i]:
            durable_seen.add((cid, seq))
    where = "every replica's" if durable_logs is None \
        else "every final-membership replica's"
    for h in history:
        if h.get("path") in ("lease", "quorum"):
            continue   # non-logged read paths: durability does not apply
        if h.get("ok") and (h["cid"], h["seq"]) not in durable_seen:
            violate(f"acknowledged op (client={h['cid']}, seq={h['seq']}) "
                    f"on key {h['key']} is missing from {where} "
                    f"applied log — lost update")
    return res


def audit_cluster(cluster) -> AuditResult:
    """Audit one finished DES run (``Cluster(record_history=True)``).
    Clusters that track a time-varying membership restrict durability to the
    replicas in the final membership."""
    members = getattr(cluster, "members", None)
    durable = sorted(members) if members is not None else None
    return check_history(client_histories(cluster),
                         [applied_ops(nd) for nd in cluster.nodes],
                         durable_logs=durable)


def commit_apply_gap(cluster) -> int:
    """Committed-but-unapplied slots across the cluster after a run has
    settled (0 on a healthy drained run: every commit reaches the applied
    prefix).  Only meaningful for the (Pig)Paxos slot-log protocols."""
    gap = 0
    for nd in cluster.nodes:
        committed = getattr(nd, "committed", None)
        if committed is None:
            continue
        ci = nd.commit_index
        gap += sum(1 for s in committed if s > ci)
    return gap
