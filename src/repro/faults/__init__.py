"""Fault-injection & consistency-audit subsystem (see ISSUE 4 / ROADMAP).

- ``plan``  — the declarative :class:`FaultPlan` DSL: timed / periodic /
  randomized ("storm") fault events — crash, recover, symmetric and
  asymmetric partition, heal, gray/slow nodes with a latency-or-drop
  severity — compiled to engine-specific forms: scheduler callbacks for the
  exact/fast DES engines (``apply_plan``) and time-varying per-node
  availability masks for the batch backend (``FaultPlan.to_masks``).
- ``audit`` — the consistency auditor: per-key linearizability checking of
  client operation histories against the replicas' applied logs
  (``audit_cluster`` / ``check_history``).

The package is deliberately independent of ``repro.experiments`` (scenarios
import it, not the other way around) and touches ``repro.core`` only through
the public ``Cluster``/``Network`` surface, so plans stay pure data:
picklable for pool workers and JSON-serializable for artifacts.
"""
from .audit import (AuditResult, applied_ops, audit_cluster,  # noqa: F401
                    check_history, commit_apply_gap)
from .plan import (FaultPlan, add_node, apply_plan, crash_window,  # noqa: F401
                   drop_window, partition_window, periodic_crash,
                   remove_node, replace_leader, rolling_restart,
                   slow_window, storm)
