"""Batched serving driver: prefill a batch of prompts, then greedy-decode.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, get_smoke_config
from ..models import make_cache
from ..train import build_prefill_step, build_serve_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    print(f"arch={cfg.name} family={cfg.family}")
    from ..models import init_params
    params = init_params(cfg, jax.random.PRNGKey(args.seed))

    B, Lp = args.batch, args.prompt_len
    max_len = Lp + args.gen
    key = jax.random.PRNGKey(args.seed + 1)
    cache = make_cache(cfg, B, max_len=max_len)

    prefill_step = jax.jit(build_prefill_step(cfg, impl="auto"),
                           static_argnames=())
    serve_step = jax.jit(build_serve_step(cfg, impl="auto"))

    t0 = time.time()
    if cfg.frontend:
        emb = jax.random.normal(key, (B, Lp, cfg.d_model), jnp.bfloat16) * 0.1
        logits, cache = prefill_step(params, cache, embeds=emb)
    else:
        prompts = jax.random.randint(key, (B, Lp), 0, cfg.vocab)
        logits, cache = prefill_step(params, cache, tokens=prompts)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    t_prefill = time.time() - t0

    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.full((B,), Lp + i, jnp.int32)
        cache, tok = serve_step(params, cache, tok, pos)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = jnp.stack(out, axis=1)
    print(f"prefill: {t_prefill*1e3:.0f}ms for {B}x{Lp} tokens")
    print(f"decode: {t_decode*1e3:.0f}ms for {args.gen-1} steps "
          f"({(args.gen-1)*B/max(t_decode,1e-9):.0f} tok/s)")
    print("generated token ids (first sequence):", gen[0].tolist())


if __name__ == "__main__":
    main()
