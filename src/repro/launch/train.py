"""End-to-end training driver.

On real hardware this runs under the production mesh; on CPU it drives the
reduced (smoke) configs end to end — data pipeline, train step, PigPaxos-
committed checkpoints, heartbeat/gray-list monitoring, elastic re-mesh
decisions — i.e. the full control/data plane wiring at laptop scale.

  PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-1.8b \
      --smoke --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..checkpoint import CheckpointManager
from ..configs import get_config, get_smoke_config
from ..data import DataConfig, SyntheticLMStream
from ..optim import AdamWConfig
from ..runtime import CoordinationService, ElasticController, HeartbeatMonitor
from ..train import TrainOptions, build_train_step, init_train_state


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    print(f"arch={cfg.name} family={cfg.family} params={cfg.param_count()/1e6:.1f}M")

    coord = CoordinationService(n_nodes=5, n_groups=2, seed=args.seed)
    mgr = CheckpointManager(args.ckpt_dir, coord=coord, async_save=True)
    hb = HeartbeatMonitor(timeout=60.0)

    data = DataConfig(global_batch=args.batch, seq_len=args.seq, seed=args.seed)
    stream = SyntheticLMStream(cfg, data)
    opts = TrainOptions(
        remat=True, impl="auto", microbatch=args.microbatch,
        adamw=AdamWConfig(lr=args.lr, warmup_steps=10, total_steps=args.steps))
    step_fn = jax.jit(build_train_step(cfg, opts))

    state = init_train_state(cfg, jax.random.PRNGKey(args.seed))
    start = 0
    if args.resume:
        got = mgr.restore(state)
        if got is not None:
            state, start = got
            print(f"resumed from committed step {start}")

    losses = []
    for s in range(start, args.steps):
        t0 = time.time()
        state, metrics = step_fn(state, stream.batch_at(s))
        dt = time.time() - t0
        hb.beat(pod=0, step_time=dt)
        losses.append(float(metrics["loss"]))
        if (s + 1) % 10 == 0 or s == start:
            print(f"step {s+1:4d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} {dt*1e3:.0f}ms")
        if (s + 1) % args.ckpt_every == 0:
            mgr.save(s + 1, state)
    mgr.wait()
    committed = coord.get("ckpt/latest")
    print(f"final loss {np.mean(losses[-5:]):.4f} "
          f"(first 5: {np.mean(losses[:5]):.4f}); "
          f"last committed checkpoint: {committed}")


if __name__ == "__main__":
    main()
