"""Rebuild dry-run JSON artifacts from stored (gzipped) HLO without
recompiling — used when the roofline accounting itself is iterated on."""
from __future__ import annotations

import argparse
import glob
import gzip
import json
import os

from ..configs import get_config
from ..launch.mesh import chips
from ..roofline import RooflineReport, analyze_hlo


def reanalyze(json_path: str) -> bool:
    hlo_path = json_path.replace(".json", ".hlo.gz")
    if not os.path.exists(hlo_path):
        return False
    with open(json_path) as f:
        d = json.load(f)
    if "skipped" in d or "error" in d:
        return False
    with gzip.open(hlo_path, "rt") as f:
        txt = f.read()
    multi = d["mesh"] == "multi"
    corr = analyze_hlo(txt, pod_size=256 if multi else None)
    n = chips(multi)
    rep = RooflineReport(
        arch=d["arch"], shape=d["shape"], mesh=d["mesh"], chips=n,
        hlo_flops=corr["flops"] * n, hlo_bytes=corr["traffic_bytes"] * n,
        coll_bytes=corr["coll_total"] * n,
        coll_cross_pod=corr["coll_cross_pod"] * n,
        model_flops=d["model_flops"])
    d.update(rep.to_dict())
    d["collectives"] = corr["by_kind"]
    d["loops"] = corr["loops"][:16]
    d["in_pod_bytes_per_chip"] = corr["coll_in_pod"]
    d["cross_pod_bytes_per_chip"] = corr["coll_cross_pod"]
    with open(json_path, "w") as f:
        json.dump(d, f, indent=1)
    return True


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    args = ap.parse_args()
    n = 0
    for p in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        if reanalyze(p):
            n += 1
            print("reanalyzed", p)
    print(f"done: {n} artifacts")


if __name__ == "__main__":
    main()
