"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — required because the dry-run
must set XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_axes(multi_pod: bool) -> tuple:
    return ("pod", "data", "model") if multi_pod else ("data", "model")


def chips(multi_pod: bool) -> int:
    return 512 if multi_pod else 256
