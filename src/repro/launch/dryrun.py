import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first initialization).

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs import ARCHS, get_config                     # noqa: E402
from ..data.pipeline import make_batch_specs                # noqa: E402
from ..launch.mesh import chips, make_production_mesh       # noqa: E402
from ..models import make_cache                             # noqa: E402
from ..roofline import (RooflineReport, analyze_hlo,        # noqa: E402
                        model_flops_decode, model_flops_train)
from ..shard import sharding_rules                          # noqa: E402
from ..train import (TrainOptions, activation_rules,        # noqa: E402
                     build_prefill_step, build_serve_step, build_train_step,
                     init_train_state, param_shardings)
from ..train.sharding import cache_shardings                # noqa: E402

SHAPES = {
    "train_4k": {"kind": "train", "seq": 4096, "batch": 256},
    "prefill_32k": {"kind": "prefill", "seq": 32768, "batch": 32},
    "decode_32k": {"kind": "decode", "seq": 32768, "batch": 128},
    "long_500k": {"kind": "decode", "seq": 524288, "batch": 1},
}


def input_specs(cfg, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    sh = SHAPES[shape_name]
    if sh["kind"] == "train":
        return make_batch_specs(cfg, sh["batch"], sh["seq"])
    if sh["kind"] == "prefill":
        if cfg.frontend:
            return {"embeds": jax.ShapeDtypeStruct(
                (sh["batch"], sh["seq"], cfg.d_model), jnp.bfloat16)}
        return {"tokens": jax.ShapeDtypeStruct((sh["batch"], sh["seq"]),
                                               jnp.int32)}
    # decode: one new token against a cache of seq_len
    return {
        "tokens": jax.ShapeDtypeStruct((sh["batch"],), jnp.int32),
        "pos": jax.ShapeDtypeStruct((sh["batch"],), jnp.int32),
    }


def _as_specs(tree):
    return jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)


def _opt_shardings(state_shapes, mesh, fsdp: bool):
    from ..train.step import TrainState
    p_sh = param_shardings(state_shapes.params, mesh, fsdp=fsdp)
    mu_sh = param_shardings(state_shapes.opt.mu, mesh, fsdp=fsdp)
    nu_sh = param_shardings(state_shapes.opt.nu, mesh, fsdp=fsdp)
    step_sh = NamedSharding(mesh, P())
    return TrainState(params=p_sh, opt=type(state_shapes.opt)(
        mu=mu_sh, nu=nu_sh, step=step_sh))


def run_cell(arch: str, shape_name: str, multi_pod: bool, fsdp: bool = True,
             microbatch: int = 1, hlo_path: str = None) -> dict:
    cfg = get_config(arch)
    sh = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.subquadratic:
        return {"arch": cfg.name, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "skipped": "pure full attention: 500k dense-KV decode is "
                           "not sub-quadratic (see DESIGN.md §5)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = activation_rules(multi_pod,
                             shard_kv_seq=(shape_name == "long_500k"))
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    t0 = time.time()
    with sharding_rules(mesh, rules):
        key = jax.random.PRNGKey(0)
        if sh["kind"] == "train":
            state_shapes = jax.eval_shape(
                lambda: init_train_state(cfg, key))
            state_sh = _opt_shardings(state_shapes, mesh, fsdp)
            batch = input_specs(cfg, shape_name)
            batch_sh = jax.tree.map(
                lambda l: NamedSharding(mesh, P(batch_axes)), batch)
            opts = TrainOptions(remat=True, impl="auto",
                                microbatch=microbatch)
            step = build_train_step(cfg, opts)
            metrics_sh = {"loss": NamedSharding(mesh, P()),
                          "grad_norm": NamedSharding(mesh, P()),
                          "lr": NamedSharding(mesh, P())}
            jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, metrics_sh))
            lowered = jitted.lower(_as_specs(state_shapes), batch)
            tokens = sh["batch"] * sh["seq"]
            mflops = model_flops_train(cfg.active_param_count(), tokens)
        else:
            params_shapes = jax.eval_shape(
                lambda: init_train_state(cfg, key)).params
            p_sh = param_shardings(params_shapes, mesh, fsdp=fsdp)
            cache_shapes = jax.eval_shape(
                lambda: make_cache(cfg, sh["batch"], max_len=sh["seq"]))
            c_sh = cache_shardings(cache_shapes, mesh, multi_pod,
                                   shard_kv_seq=(shape_name == "long_500k"))
            if sh["kind"] == "prefill":
                step = build_prefill_step(cfg, impl="auto")
                inp = input_specs(cfg, shape_name)
                in_sh = jax.tree.map(
                    lambda l: NamedSharding(mesh, P(batch_axes)), inp)
                logits_sh = NamedSharding(mesh, P(batch_axes, "model"))
                kw = ("embeds",) if cfg.frontend else ("tokens",)
                fn = (lambda params, cache, x: step(params, cache, embeds=x)) \
                    if cfg.frontend else \
                    (lambda params, cache, x: step(params, cache, tokens=x))
                jitted = jax.jit(fn, in_shardings=(p_sh, c_sh, in_sh[kw[0]]),
                                 out_shardings=(logits_sh, c_sh))
                lowered = jitted.lower(_as_specs(params_shapes), cache_shapes,
                                       inp[kw[0]])
                tokens = sh["batch"] * sh["seq"]
            else:
                step = build_serve_step(cfg, impl="auto")
                inp = input_specs(cfg, shape_name)
                tok_sh = NamedSharding(
                    mesh, P(batch_axes) if sh["batch"] > 1 else P())
                jitted = jax.jit(step, in_shardings=(p_sh, c_sh, tok_sh, tok_sh),
                                 out_shardings=(c_sh, tok_sh))
                lowered = jitted.lower(_as_specs(params_shapes), cache_shapes,
                                       inp["tokens"], inp["pos"])
                tokens = sh["batch"]
            mflops = model_flops_decode(cfg.active_param_count(), tokens)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        txt = compiled.as_text()
        if hlo_path:
            import gzip
            with gzip.open(hlo_path, "wt") as f:
                f.write(txt)
        pod_size = 256 if multi_pod else None
        nchips = chips(multi_pod)
        # loop-corrected whole-program accounting (XLA's cost_analysis visits
        # while bodies once; see roofline.analyze_hlo)
        corr = analyze_hlo(txt, pod_size=pod_size)
        rep = RooflineReport(
            arch=cfg.name, shape=shape_name,
            mesh="multi" if multi_pod else "single", chips=nchips,
            hlo_flops=corr["flops"] * nchips,
            hlo_bytes=corr["traffic_bytes"] * nchips,
            coll_bytes=corr["coll_total"] * nchips,
            coll_cross_pod=corr["coll_cross_pod"] * nchips,
            model_flops=mflops)
        out = rep.to_dict()
        out.update({
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
            },
            "collectives": corr["by_kind"],
            "loops": corr["loops"][:16],
            "in_pod_bytes_per_chip": corr["coll_in_pod"],
            "cross_pod_bytes_per_chip": corr["coll_cross_pod"],
            "raw_cost_analysis": {
                "flops_per_device": float(cost.get("flops", 0.0)),
                "bytes_per_device": float(cost.get("bytes accessed", 0.0)),
            },
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "fsdp": fsdp, "microbatch": microbatch,
        })
        return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--fsdp", type=int, default=1)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--tag", default="")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = ARCHS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = args.mesh.split(",")
    os.makedirs(args.out, exist_ok=True)

    n_ok = n_skip = n_fail = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                tag = f"-{args.tag}" if args.tag else ""
                path = os.path.join(args.out,
                                    f"{mesh_kind}--{arch}--{shape}{tag}.json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip-existing] {path}", flush=True)
                    continue
                t0 = time.time()
                try:
                    out = run_cell(arch, shape, multi_pod=(mesh_kind == "multi"),
                                   fsdp=bool(args.fsdp),
                                   microbatch=args.microbatch,
                                   hlo_path=path.replace(".json", ".hlo.gz"))
                    if "skipped" in out:
                        n_skip += 1
                        print(f"[SKIP] {mesh_kind} {arch} {shape}: "
                              f"{out['skipped']}", flush=True)
                    else:
                        n_ok += 1
                        print(f"[OK]   {mesh_kind} {arch} {shape} "
                              f"({time.time()-t0:.0f}s) "
                              f"bottleneck={out['bottleneck']} "
                              f"frac={out['roofline_fraction']:.3f}", flush=True)
                except Exception as e:   # noqa: BLE001
                    n_fail += 1
                    out = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                    print(f"[FAIL] {mesh_kind} {arch} {shape}: {e}", flush=True)
                with open(path, "w") as f:
                    json.dump(out, f, indent=1)
                jax.clear_caches()
    print(f"done: ok={n_ok} skip={n_skip} fail={n_fail}", flush=True)
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
