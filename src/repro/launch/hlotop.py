"""Inspect a dry-run cell's stored HLO: top collectives / dots / traffic ops
by loop-corrected bytes.  The 'profile' of the CPU-only workflow (§Perf).

  PYTHONPATH=src python -m repro.launch.hlotop artifacts/dryrun/<cell>.hlo.gz
"""
from __future__ import annotations

import argparse
import gzip
import re
import sys

from collections import defaultdict, deque

from ..roofline import (_COLLECTIVES, _TRIP_RE, _BODY_RE, _COND_RE,
                        _APPLY_RE, _OPERAND_NAME_RE, _parse_instr,
                        _shape_bytes, _split_computations, _operand_section)


def top_ops(txt: str, k: int = 15):
    comps, entry = _split_computations(txt)
    parsed = {}
    shape_of = {}
    for cname, lines in comps.items():
        pl = []
        for ln in lines:
            p = _parse_instr(ln)
            if p:
                shape_of[p[0]] = p[1]
                pl.append(p)
        parsed[cname] = pl
    mult = defaultdict(float)
    mult[entry] = 1.0
    q = deque([entry])
    seen = set()
    while q:
        c = q.popleft()
        for (name, shape, opcode, ln) in parsed.get(c, []):
            if opcode == "while":
                t = _TRIP_RE.search(ln)
                trip = int(t.group(1)) if t else 1
                for rex in (_BODY_RE, _COND_RE):
                    mm = rex.search(ln)
                    if mm and (c, mm.group(1), name) not in seen:
                        seen.add((c, mm.group(1), name))
                        mult[mm.group(1)] += mult[c] * trip
                        q.append(mm.group(1))
            elif opcode in ("call", "conditional"):
                mm = _APPLY_RE.search(ln)
                if mm and (c, mm.group(1), name) not in seen:
                    seen.add((c, mm.group(1), name))
                    mult[mm.group(1)] += mult[c]
                    q.append(mm.group(1))
    from ..roofline import _NO_TRAFFIC_OPS
    colls, dots, traffic = [], [], []
    for cname, m in mult.items():
        for (name, shape, opcode, ln) in parsed.get(cname, []):
            kind = opcode[:-6] if opcode.endswith("-start") else opcode
            b = _shape_bytes(shape)
            meta = re.search(r'op_name="([^"]*)"', ln)
            tag = meta.group(1)[-70:] if meta else ""
            if kind in _COLLECTIVES:
                colls.append((m * b, kind, shape[:60], m, tag))
            elif kind == "dot":
                dots.append((m * b, "dot", shape[:60], m, tag))
            if kind in _NO_TRAFFIC_OPS:
                continue
            if kind in ("dynamic-slice", "gather", "slice"):
                t = 2 * b
            elif kind == "dynamic-update-slice":
                t = 2 * b
            else:
                opsec = _operand_section(ln, opcode)
                t = b + sum(_shape_bytes(shape_of.get(o, ""))
                            for o in _OPERAND_NAME_RE.findall(opsec))
            traffic.append((m * t, kind, shape[:60], m, tag))
    return (sorted(colls, reverse=True)[:k], sorted(dots, reverse=True)[:k],
            sorted(traffic, reverse=True)[:k])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("path")
    ap.add_argument("-k", type=int, default=15)
    args = ap.parse_args()
    with gzip.open(args.path, "rt") as f:
        txt = f.read()
    colls, dots, traffic = top_ops(txt, args.k)
    print("== top collectives (loop-corrected bytes/device) ==")
    for b, kind, shape, m, tag in colls:
        print(f"  {b/1e9:9.3f}GB x{m:5.0f} {kind:20s} {shape:40s} {tag}")
    print("== top dot outputs ==")
    for b, kind, shape, m, tag in dots:
        print(f"  {b/1e9:9.3f}GB x{m:5.0f} {kind:20s} {shape:40s} {tag}")
    print("== top traffic ops ==")
    for b, kind, shape, m, tag in traffic:
        print(f"  {b/1e9:9.3f}GB x{m:5.0f} {kind:20s} {shape:40s} {tag}")


if __name__ == "__main__":
    main()
