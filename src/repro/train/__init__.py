from .sharding import activation_rules, batch_sharding, param_shardings  # noqa: F401
from .step import (TrainOptions, TrainState, build_prefill_step,  # noqa: F401
                   build_serve_step, build_train_step, init_train_state)
