"""Train / prefill / serve step builders.

``build_train_step`` returns a pure (state, batch) -> (state, metrics)
function suitable for jax.jit with explicit in/out shardings; microbatching
(gradient accumulation), remat, and the attention-kernel choice are knobs.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..models import decode_step, init_params, lm_loss, make_cache, prefill
from ..models.config import ModelConfig
from ..optim import AdamWConfig, adamw_init, adamw_update


@dataclass(frozen=True)
class TrainOptions:
    microbatch: int = 1          # gradient-accumulation splits
    remat: bool = True
    impl: str = "ref"            # 'ref' | 'flash' attention implementation
    adamw: AdamWConfig = field(default_factory=AdamWConfig)


class TrainState(NamedTuple):
    params: dict
    opt: object


def init_train_state(cfg: ModelConfig, key: jax.Array) -> TrainState:
    params = init_params(cfg, key)
    return TrainState(params=params, opt=adamw_init(params))


def build_train_step(cfg: ModelConfig, opts: TrainOptions = TrainOptions()):
    def loss_fn(params, batch):
        return lm_loss(params, cfg, batch, impl=opts.impl, remat=opts.remat)

    def train_step(state: TrainState, batch: dict):
        if opts.microbatch > 1:
            k = opts.microbatch

            def split(x):
                return x.reshape((k, x.shape[0] // k) + x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc_step(carry, mb):
                loss_acc, g_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(state.params, mb)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / k, g_acc, grads)
                return (loss_acc + loss / k, g_acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (loss, grads), _ = jax.lax.scan(acc_step, (jnp.zeros(()), zeros),
                                            micro)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        new_params, new_opt, stats = adamw_update(grads, state.opt,
                                                  state.params, opts.adamw)
        metrics = {"loss": loss, **stats}
        return TrainState(new_params, new_opt), metrics

    return train_step


def build_prefill_step(cfg: ModelConfig, impl: str = "ref"):
    """(params, batch_tokens_or_embeds, cache) -> (last_logits, cache)."""
    def prefill_step(params, cache, tokens=None, embeds=None):
        return prefill(params, cfg, tokens=tokens, embeds=embeds,
                       cache=cache, impl=impl)
    return prefill_step


def build_serve_step(cfg: ModelConfig, impl: str = "ref"):
    """One batched greedy decode step: (params, cache, tokens, pos) ->
    (cache, next_tokens)."""
    def serve_step(params, cache, tokens, pos):
        logits, new_cache = decode_step(params, cfg, cache, tokens, pos,
                                        impl=impl)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return new_cache, nxt
    return serve_step
