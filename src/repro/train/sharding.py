"""Mesh sharding rules: logical-axis rules for activations and per-leaf
PartitionSpecs for parameters / optimizer state.

Strategy (DESIGN.md §4):
  * batch over ('pod','data') — DP across pods and the in-pod data axis;
  * TP/EP over 'model' (attention heads, ffn dim, experts, vocab);
  * FSDP: weight matrices additionally sharded over 'data' on their non-TP
    dim, so params + Adam moments scale 1/(data*model) per chip.  The
    backward pass then reduce-scatters gradients within the pod and
    all-reduces only the 1/G shard across pods — this IS the Pig schedule
    (GSPMD emits it once the shardings express it; see collectives/).
  * Params are replicated across pods (FSDP domain = one pod; ZeRO-3 over
    DCN would trade a cheap memory win for expensive per-layer DCN
    all-gathers).
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def activation_rules(multi_pod: bool, shard_kv_seq: bool = False) -> dict:
    batch = ("pod", "data") if multi_pod else ("data",)
    return {
        "batch": batch,
        "tokens": batch,          # flattened token dim in MoE dispatch
        "seq": None,
        "kv_seq": "data" if shard_kv_seq else None,
        "embed": None,
        "heads": "model",
        "kv_heads": "model",
        "ff": "model",
        "experts": "model",
        "vocab": "model",
        "state_dk": "model",
    }


# leaf name -> (spec with fsdp, spec without)
_MATRIX_RULES = {
    # (L, in, out) projections: out dim on 'model'
    "wq": (P(None, "data", "model"), P(None, None, "model")),
    "wk": (P(None, "data", "model"), P(None, None, "model")),
    "wv": (P(None, "data", "model"), P(None, None, "model")),
    "w1": (P(None, "data", "model"), P(None, None, "model")),
    "w3": (P(None, "data", "model"), P(None, None, "model")),
    "in_proj": (P(None, "data", "model"), P(None, None, "model")),
    "w_in": (P(None, "data", "model"), P(None, None, "model")),
    "wr": (P(None, "data", "model"), P(None, None, "model")),
    "wg": (P(None, "data", "model"), P(None, None, "model")),
    "w_recv": (P(None, "data", "model"), P(None, None, "model")),
    "router": (P(None, "data", "model"), P(None, None, "model")),
    # (L, in, out) with in on 'model'
    "wo": (P(None, "model", "data"), P(None, "model", None)),
    "w2": (P(None, "model", "data"), P(None, "model", None)),
    "out_proj": (P(None, "model", "data"), P(None, "model", None)),
    "w_out": (P(None, "model", "data"), P(None, "model", None)),
}

_MOE_RULES = {
    "w1": (P(None, "model", "data", None), P(None, "model", None, None)),
    "w3": (P(None, "model", "data", None), P(None, "model", None, None)),
    "w2": (P(None, "model", None, "data"), P(None, "model", None, None)),
}


def _leaf_spec(path: tuple, shape: tuple, fsdp: bool) -> P:
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    leaf = names[-1]
    in_moe = "moe" in names and "shared" not in names
    in_shared_attn = "shared_attn" in names   # single block: no leading L axis

    if leaf == "embed":
        return P("model", "data") if fsdp else P("model", None)
    if leaf == "head":
        return P("data", "model") if fsdp else P(None, "model")
    if in_moe and leaf in _MOE_RULES and len(shape) == 4:
        return _MOE_RULES[leaf][0 if fsdp else 1]
    if leaf in _MATRIX_RULES and len(shape) == 3:
        return _MATRIX_RULES[leaf][0 if fsdp else 1]
    if in_shared_attn and leaf in _MATRIX_RULES and len(shape) == 2:
        full = _MATRIX_RULES[leaf][0 if fsdp else 1]
        return P(*full[1:])               # drop the (absent) layer axis
    if leaf == "conv_w":
        return P(None, None, "model") if len(shape) == 3 else P(None, "model")
    return P()                            # norms, biases, scalars: replicate


def _axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def fit_spec(spec: P, shape: tuple, mesh: Mesh) -> P:
    """pjit argument shardings must divide the dim exactly: drop axes that
    don't, then try to re-place them on another (non-layer) dim so the leaf
    stays fully sharded (e.g. 60 experts can't split 16 ways -> fold 'model'
    onto the 'data' dim instead)."""
    sizes = _axis_sizes(mesh)
    dims = list(shape)
    entries = list(spec) + [None] * (len(dims) - len(spec))
    as_tuple = lambda a: a if isinstance(a, tuple) else ((a,) if a else ())
    prod = lambda axes: int(np.prod([sizes[x] for x in axes])) if axes else 1
    new = []
    dropped = []
    for dim, a in zip(dims, entries):
        axes = as_tuple(a)
        if axes and dim % prod(axes) != 0:
            keep = []
            for x in axes:   # keep a divisible prefix if possible
                if dim % prod(keep + [x]) == 0:
                    keep.append(x)
                else:
                    dropped.append(x)
            new.append(tuple(keep) if len(keep) > 1 else (keep[0] if keep else None))
        else:
            new.append(a)
    used = {x for a in new for x in as_tuple(a)}
    for ax in dropped:
        if ax in used:
            continue
        for i in range(len(dims) - 1, -1, -1):
            if len(dims) >= 3 and i == 0:
                continue            # dim 0 is the scan-over-layers axis
            cur = as_tuple(new[i])
            if ax in cur:
                continue
            if dims[i] % (prod(list(cur)) * sizes[ax]) == 0:
                new[i] = tuple(list(cur) + [ax])
                used.add(ax)
                break
    return P(*new)


def param_shardings(param_tree, mesh: Mesh, fsdp: bool = True):
    """Map a pytree of arrays/ShapeDtypeStructs to NamedShardings."""
    def one(path, leaf):
        spec = _leaf_spec(path, leaf.shape, fsdp)
        return NamedSharding(mesh, fit_spec(spec, leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(one, param_tree)


def batch_sharding(batch_tree, mesh: Mesh, multi_pod: bool):
    axes = ("pod", "data") if multi_pod else ("data",)

    def one(leaf):
        spec = P(axes) if leaf.ndim >= 1 else P()
        return NamedSharding(mesh, spec)
    return jax.tree.map(one, batch_tree)


def cache_shardings(cache_tree, mesh: Mesh, multi_pod: bool,
                    shard_kv_seq: bool = False):
    """KV/state caches: batch over DP axes; kv heads over 'model' (GSPMD pads
    non-divisible head counts); optionally seq over 'data' for long-context."""
    axes = ("pod", "data") if multi_pod else ("data",)

    sizes = _axis_sizes(mesh)

    def one(path, leaf):
        names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        leafname = names[-1]
        nd = leaf.ndim
        if leafname in ("k", "v"):        # (L, B, W, Hkv, Dh)
            seq = "data" if shard_kv_seq else None
            bat = None if shard_kv_seq else axes
            # model-axis placement priority: kv heads, else head_dim, else seq
            hkv, dh, w = leaf.shape[3], leaf.shape[4], leaf.shape[2]
            m = sizes["model"]
            if hkv % m == 0:
                spec = P(None, bat, seq, "model", None)
            elif dh % m == 0:
                spec = P(None, bat, seq, None, "model")
            elif seq is None and w % m == 0:
                spec = P(None, bat, "model", None, None)
            else:
                spec = P(None, bat, seq, None, None)
            return NamedSharding(mesh, fit_spec(spec, leaf.shape, mesh))
        if leafname == "pos":             # (L, B, W)
            seq = "data" if shard_kv_seq else None
            bat = None if shard_kv_seq else axes
            return NamedSharding(mesh, fit_spec(P(None, bat, seq),
                                                leaf.shape, mesh))
        if leafname == "state" and nd == 5:   # (L, B, H, Dk, Dv)
            h, dk = leaf.shape[2], leaf.shape[3]
            m = sizes["model"]
            spec = (P(None, axes, "model", None, None) if h % m == 0
                    else P(None, axes, None, "model", None))
            return NamedSharding(mesh, fit_spec(spec, leaf.shape, mesh))
        if nd >= 2:                        # conv/shift caches: (L, B, ...)
            return NamedSharding(mesh, fit_spec(P(None, axes), leaf.shape, mesh))
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map_with_path(one, cache_tree)
