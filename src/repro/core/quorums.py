"""Quorum systems: majority, flexible (FPaxos), and EPaxos fast quorums."""
from __future__ import annotations


def majority(n: int) -> int:
    return n // 2 + 1


def fast_quorum(n: int) -> int:
    """EPaxos fast-path quorum size (paper §5.3 uses 3N/4)."""
    return (3 * n) // 4 + (1 if (3 * n) % 4 else 0)


class QuorumSystem:
    """Flexible quorums (§7.1): |Q1| + |Q2| > N guarantees intersection."""

    def __init__(self, n: int, q1: int | None = None, q2: int | None = None):
        self.n = n
        self.q1 = q1 if q1 is not None else majority(n)
        self.q2 = q2 if q2 is not None else majority(n)
        if self.q1 + self.q2 <= n:
            raise ValueError(f"Q1({self.q1}) + Q2({self.q2}) must exceed N({n})")

    def phase1_satisfied(self, acks: int) -> bool:
        return acks >= self.q1

    def phase2_satisfied(self, acks: int) -> bool:
        return acks >= self.q2
