"""Cluster harness: protocol deployments, closed-loop clients, failure
injection, and measurement (throughput / latency percentiles / message loads).

Mirrors the paper's testbed (§5.1): closed-loop (synchronous) clients, a
YCSB-like uniform workload over a 1000-key in-memory KV store, latency
measured at the client, throughput driven by the number of clients.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

from .epaxos import EPaxosNode
from .events import Scheduler
from .messages import (ClientReply, ClientRequest, Command, CostModel,
                       ReadProbe, ReadReply)
from .network import Network, Topology
from .node import Node
from .paxos import PaxosNode
from .pig import PigConfig


@dataclass
class WorkloadConfig:
    n_keys: int = 1000
    payload_bytes: int = 8
    write_fraction: float = 0.5   # paper: even reads/writes, both replicated
    # --- read paths (PR 10) ---------------------------------------------
    # read_ratio: fraction of ops that are reads.  None (default) keeps the
    # seed behavior — ops split by ``write_fraction`` and reads go through
    # the log like writes (golden traces depend on this exact draw order).
    # When set, the op mix is read_ratio reads / (1 - read_ratio) writes
    # and clients keep a read/write latency split.
    # read_path: how reads are served —
    #   "log"    — through consensus, a slot per read (the seed behavior)
    #   "lease"  — sent to the leader, served locally while it holds a
    #              quorum lease (requires Cluster(lease=...); falls back to
    #              the log path when the lease is not held)
    #   "quorum" — client-side quorum read: probe a majority (PigPaxos: the
    #              geo-closest relay subgroup + the leader, which sits in
    #              every write quorum) for per-key commit frontiers, rinse
    #              while accepted > applied, serve the max-applied value
    read_ratio: Optional[float] = None
    read_path: str = "log"
    # --- key popularity -------------------------------------------------
    # "uniform"  — every key equally likely (the paper's YCSB-like setup)
    # "zipfian"  — YCSB-style skew: P(rank k) ∝ 1/k^theta
    # "conflict" — hot-spot model for EPaxos conflict sweeps: key 0 with
    #              probability conflict_rate, else a uniform non-zero key
    key_dist: str = "uniform"
    zipf_theta: float = 0.99
    conflict_rate: float = 0.0
    # --- arrival process ------------------------------------------------
    # "closed"  — one outstanding op per client, next op starts on reply
    # "poisson" — open loop: ops arrive at rate_hz per client regardless
    #             of replies (up to max_outstanding in flight)
    # "bursty"  — open loop, ON/OFF modulated: rate_hz*burst_factor for the
    #             first burst_on fraction of each burst_period, a reduced
    #             OFF rate the rest — the time-average stays rate_hz
    # "diurnal" — open loop, sinusoidally modulated:
    #             rate(t) = rate_hz * (1 + diurnal_amp*sin(2πt/period))
    # The modulated processes draw each inter-arrival gap from the
    # *instantaneous* rate (deterministic per seed; exact for gaps short
    # vs. the modulation period, which holds everywhere we sweep).
    arrival: str = "closed"
    rate_hz: float = 200.0
    max_outstanding: int = 64
    burst_factor: float = 8.0     # ON-phase rate multiplier
    burst_on: float = 0.1         # fraction of each period spent ON
    burst_period: float = 1.0     # seconds
    diurnal_period: float = 2.0   # seconds (compressed day)
    diurnal_amp: float = 0.8      # peak-to-mean swing, in [0, 1)
    # --- payload distribution -------------------------------------------
    # When payload_choices is set, each put draws its size from the mix
    # (weights default to uniform over the choices).
    payload_choices: Optional[tuple] = None
    payload_weights: Optional[tuple] = None
    # --- fault tolerance -------------------------------------------------
    # When set, a client that has waited this long for a reply re-sends the
    # SAME command (same client_id/seq — the leader's at-most-once session
    # dedup makes the retry safe) and keeps retrying until replied.  None
    # (the paper's setup) = wait forever; required for availability
    # scenarios, where requests sent to a crashed node are silently lost.
    request_timeout: Optional[float] = None
    # What an OPEN-LOOP client does with an ok=False reply (not-the-leader
    # bounce or an admission-control shed):
    # "retry" — re-send after 5 ms, forever (the native behavior; right
    #           for transient bounces like leader changes)
    # "drop"  — abandon the op (count it in ``rejected``, free the
    #           outstanding slot).  The open-loop overload model: a shed
    #           request costs the server ONE cheap bounce, instead of a
    #           5 ms retry storm from every capped-out client amplifying
    #           the overload it was shed to relieve.
    reject_action: str = "retry"

    def __post_init__(self):
        # scenarios are declarative data: a typo must fail loudly, not run a
        # mislabeled uniform/closed workload with green CI
        if self.key_dist not in ("uniform", "zipfian", "conflict"):
            raise ValueError(f"unknown key_dist {self.key_dist!r}")
        if self.arrival not in ("closed", "poisson", "bursty", "diurnal"):
            raise ValueError(f"unknown arrival {self.arrival!r}")
        if self.arrival == "bursty":
            if not (0.0 < self.burst_on < 1.0):
                raise ValueError("burst_on must be in (0, 1)")
            if self.burst_factor * self.burst_on > 1.0 + 1e-12:
                raise ValueError("burst_factor * burst_on must be <= 1 "
                                 "(the OFF-phase rate would go negative)")
        if self.arrival == "diurnal" and not (0.0 <= self.diurnal_amp < 1.0):
            raise ValueError("diurnal_amp must be in [0, 1)")
        if self.reject_action not in ("retry", "drop"):
            raise ValueError(f"unknown reject_action {self.reject_action!r}")
        if self.read_ratio is not None and not (0.0 <= self.read_ratio <= 1.0):
            raise ValueError("read_ratio must be in [0, 1]")
        if self.read_path not in ("log", "lease", "quorum"):
            raise ValueError(f"unknown read_path {self.read_path!r}")
        if self.read_path == "quorum" and self.arrival != "closed":
            raise ValueError("read_path='quorum' needs closed-loop clients — "
                             "the probe/rinse state machine tracks one "
                             "outstanding read per client")


_zipf_cdf_cache: Dict[tuple, np.ndarray] = {}


def zipf_cdf(n_keys: int, theta: float) -> np.ndarray:
    """Cumulative distribution of a Zipf(theta) law over ranks 1..n_keys
    (rank 1 == key 0).  Cached: building it is O(n_keys), sampling O(log n)."""
    key = (n_keys, float(theta))
    cdf = _zipf_cdf_cache.get(key)
    if cdf is None:
        p = np.arange(1, n_keys + 1, dtype=np.float64) ** -float(theta)
        cdf = np.cumsum(p / p.sum())
        cdf[-1] = 1.0
        _zipf_cdf_cache[key] = cdf
    return cdf


class TaggedBytes(bytes):
    """A put payload carrying the writer's identity (client_id, seq) — the
    write tag the consistency auditor (repro.faults.audit) matches against
    read returns.  Behaves exactly like ``bytes`` on the wire (same length,
    same costs); only history-recording runs allocate these."""

    def __new__(cls, data: bytes, tag: tuple):
        obj = super().__new__(cls, data)
        obj.tag = tag
        return obj


class Client:
    """Closed-loop client: one outstanding op; next op starts on reply."""

    def __init__(self, cluster: "Cluster", cid: int, pick_target: Callable[[], int],
                 workload: WorkloadConfig, stop_at: float):
        self.cluster = cluster
        self.id = cid
        self.net_id = cluster.topo.n + cid      # ids >= n bypass CPU queues
        self.pick_target = pick_target
        self.wl = workload
        self.stop_at = stop_at
        self.seq = 0
        self.sent_at = 0.0
        self.crashed = False
        self.latencies: List[tuple] = []   # (completion_time, latency)
        # op history for the consistency auditor: dicts of
        # {cid, seq, op, key, invoke, resp, ok, rtag, wtag} (audit.py)
        self.history: Optional[List[dict]] = \
            [] if cluster.record_history else None
        self._hist_cur: Optional[dict] = None
        self._last_cmd: Optional[Command] = None
        self.retries = 0                   # timeout re-sends (fault metric)
        # observability handles (None unless Cluster(obs=...)); the tracer
        # samples ops at issue time, the timelines gauge eats every latency
        # (getattr: the seed RefNetwork predates the obs surface)
        self._tracer = getattr(cluster.net, "tracer", None)
        self._obs = getattr(cluster.net, "obs", None)
        self._tctx = None                  # (seq, trace ctx) of a sampled op
        self.payload = bytes(workload.payload_bytes)
        self._key_cdf = (zipf_cdf(workload.n_keys, workload.zipf_theta)
                         if workload.key_dist == "zipfian" else None)
        if workload.payload_choices:
            self._payloads = [bytes(s) for s in workload.payload_choices]
            w = np.asarray(workload.payload_weights
                           or [1.0] * len(self._payloads), dtype=np.float64)
            self._payload_cdf = np.cumsum(w / w.sum())
            self._payload_cdf[-1] = 1.0   # cumsum can round below 1.0
        else:
            self._payloads = None
            self._payload_cdf = None
        # read-path state: per-op read/write latency split (read_ratio runs)
        # and the quorum-read probe state machine (read_path="quorum")
        self.rw_lat: tuple = ([], [])      # (read latencies, write latencies)
        self._probe: Optional[dict] = None
        self._rid = 0
        self._pig_pset: Optional[tuple] = None   # cached (leader, probe set)
        # fused-loop dispatch table (see network.Network._run)
        self._dispatch = {ClientReply: self.deliver,
                          ReadReply: self.on_ReadReply}
        cluster.net.register(self.net_id, self)

    def _bind_handler(self, cls):
        raise RuntimeError(f"Client has no handler for {cls.__name__}")

    def start(self) -> None:
        self._issue()

    # ------------------------------------------------------------ workload
    def _pick_key(self, rng) -> int:
        wl = self.wl
        if self._key_cdf is not None:
            return int(np.searchsorted(self._key_cdf, rng.random(), side="right"))
        if wl.key_dist == "conflict":
            if rng.random() < wl.conflict_rate:
                return 0
            return 1 + int(rng.integers(wl.n_keys - 1))
        return int(rng.integers(wl.n_keys))

    def _pick_payload(self, rng) -> bytes:
        if self._payloads is None:
            return self.payload
        return self._payloads[int(np.searchsorted(self._payload_cdf,
                                                  rng.random(), side="right"))]

    def _make_command(self, seq: int) -> Command:
        rng = self.cluster.sched.rng
        # read_ratio=None keeps the seed's exact draw semantics (golden
        # traces); when set, write_fraction is simply 1 - read_ratio
        wf = (self.wl.write_fraction if self.wl.read_ratio is None
              else 1.0 - self.wl.read_ratio)
        op = "put" if rng.random() < wf else "get"
        value = self._pick_payload(rng) if op == "put" else None
        if value is not None and self.history is not None:
            value = TaggedBytes(value, (self.id, seq))
        return Command(client_id=self.id, seq=seq, op=op,
                       key=self._pick_key(rng), value=value)

    # ------------------------------------------------------------ protocol
    def _issue(self) -> None:
        sched = self.cluster.sched
        if sched.now >= self.stop_at:
            return
        self.seq += 1
        cmd = self._make_command(self.seq)
        self._last_cmd = cmd
        self.sent_at = sched.now
        if self.history is not None:
            self._hist_cur = cur = {
                "cid": self.id, "seq": self.seq, "op": cmd.op,
                "key": cmd.key, "invoke": sched.now, "resp": None,
                "ok": False, "rtag": None,
                "wtag": getattr(cmd.value, "tag", None)}
            self.history.append(cur)
        if cmd.op == "get" and self.wl.read_path == "quorum":
            self._start_quorum_read(cmd)
            return
        req = ClientRequest(cmd=cmd)
        tr = self._tracer
        if tr is not None:
            ctx = tr.begin_op(self.net_id, sched.now)
            if ctx is not None:
                self._tctx = (self.seq, ctx)
                tr.attach(req, ctx)
            # a new op NEVER inherits ambient ctx: the closed-loop client
            # issues from inside the previous reply's handler, and without
            # this the next (unsampled) op's chain would keep growing the
            # finished trace through Network.send's ambient fallback
            tr.cur = None
        self.cluster.net.send(self.net_id, self.pick_target(), req)
        if self.wl.request_timeout:
            seq = self.seq
            sched.after(self.wl.request_timeout, lambda: self._resend(seq))

    def deliver(self, msg: ClientReply) -> None:
        if msg.seq != self.seq:
            return   # stale reply (e.g. from a retried request)
        sched = self.cluster.sched
        if not msg.ok:
            # not leader / not elected yet: back off and retry the op
            sched.after(5e-3, self._retry)
            return
        if self.history is not None:
            cur = self._hist_cur
            if cur is not None and cur["seq"] == msg.seq \
                    and cur["resp"] is None:
                cur["resp"] = sched.now
                cur["ok"] = True
                cur["rtag"] = getattr(msg.value, "tag", None)
                cur["path"] = msg.path
        lat = sched.now - self.sent_at
        self.latencies.append((sched.now, lat))
        if self.wl.read_ratio is not None:
            self.rw_lat[0 if self._last_cmd.op == "get" else 1].append(lat)
        tc = self._tctx
        if tc is not None and tc[0] == msg.seq:
            self._tracer.finish_op(tc[1], sched.now)
            self._tctx = None
        if self._obs is not None:
            self._obs.latency.note(lat)
        self._issue()

    # -------------------------------------------------------- quorum reads
    # PQR-style client-driven reads: probe a read quorum for per-key commit
    # frontiers, rinse (re-probe) while some member has ACCEPTED a write to
    # the key that nobody probed has APPLIED yet, then serve the max-applied
    # value.  Every acked write is accepted at a write quorum, and the probe
    # set intersects every write quorum (majority; PigPaxos: subgroup + the
    # leader), so the frontier check can never miss an acked write.
    RINSE_DELAY = 2e-3       # wait for the in-flight write to land
    MAX_RINSE = 8            # then fall back to a log read (wedged instance)
    PROBE_TIMEOUT = 10e-3    # re-probe a fresh set (crashed replica)

    def _quorum_probe_set(self) -> list:
        c = self.cluster
        if c.protocol == "pigpaxos":
            # geo-local relay subgroup + the leader.  The subgroup alone
            # need not intersect write quorums; the leader is in every one.
            leader = c.leader_id
            cached = self._pig_pset
            if cached is not None and cached[0] == leader:
                return cached[1]
            groups = c.nodes[leader].comm.groups_for(leader)
            topo = c.topo
            me = self.net_id
            best = min(groups, key=lambda g: sum(
                topo.base_between(me, m) for m in g) / max(len(g), 1))
            pset = sorted(set(best) | {leader})
            self._pig_pset = (leader, pset)
            return pset
        members = c.members
        rng = c.sched.rng
        m = len(members) // 2 + 1
        idx = rng.permutation(len(members))[:m]
        return [members[int(i)] for i in idx]

    def _start_quorum_read(self, cmd: Command) -> None:
        self._rid += 1
        rid = self._rid
        self._probe = {"rid": rid, "seq": cmd.seq, "key": cmd.key,
                       "replies": {}, "pset": self._quorum_probe_set(),
                       "rinse": 0}
        self._send_probes(rid)

    def _send_probes(self, rid: int) -> None:
        pr = self._probe
        probe = ReadProbe(key=pr["key"], rid=rid)
        net, me = self.cluster.net, self.net_id
        for nid in pr["pset"]:
            net.send(me, nid, probe)
        self.cluster.sched.after(self.PROBE_TIMEOUT,
                                 lambda: self._probe_timeout(rid))

    def _reprobe(self, rid: int, fresh_set: bool) -> None:
        pr = self._probe
        if pr is None or pr["rid"] != rid:
            return
        self._rid += 1
        pr["rid"] = self._rid
        pr["replies"] = {}
        if fresh_set:
            self._pig_pset = None
            pr["pset"] = self._quorum_probe_set()
        self._send_probes(pr["rid"])

    def _probe_timeout(self, rid: int) -> None:
        pr = self._probe
        if pr is None or pr["rid"] != rid:
            return
        if self.cluster.sched.now >= self.stop_at:
            self._probe = None
            return
        # a crashed/partitioned replica never replies: fresh set, fresh rid
        self._reprobe(rid, fresh_set=True)

    def on_ReadReply(self, msg: ReadReply) -> None:
        pr = self._probe
        if pr is None or msg.rid != pr["rid"]:
            return
        pr["replies"][msg.src] = msg
        if len(pr["replies"]) < len(pr["pset"]):
            return
        reps = list(pr["replies"].values())
        max_app = max(r.applied for r in reps)
        max_acc = max(r.accepted for r in reps)
        if max_acc > max_app:
            # read repair ("rinse"): a quorum member accepted a write to
            # this key that nobody probed has applied — wait it out
            if pr["rinse"] < self.MAX_RINSE:
                pr["rinse"] += 1
                rid = pr["rid"]
                self.cluster.sched.after(
                    self.RINSE_DELAY,
                    lambda: self._reprobe(rid, fresh_set=False))
                return
            # rinse budget exhausted (wedged write): log read settles it
            self._probe = None
            self._fallback_log_read()
            return
        best = max(reps, key=lambda r: r.applied)
        self._probe = None
        self._complete_quorum_read(best)

    def _fallback_log_read(self) -> None:
        self.cluster.net.send(self.net_id, self.pick_target(),
                              ClientRequest(cmd=self._last_cmd))
        if self.wl.request_timeout:
            seq = self.seq
            self.cluster.sched.after(self.wl.request_timeout,
                                     lambda: self._resend(seq))

    def _complete_quorum_read(self, best: ReadReply) -> None:
        sched = self.cluster.sched
        if self.history is not None:
            cur = self._hist_cur
            if cur is not None and cur["seq"] == self.seq \
                    and cur["resp"] is None:
                cur["resp"] = sched.now
                cur["ok"] = True
                cur["rtag"] = getattr(best.value, "tag", None)
                cur["path"] = "quorum"
        lat = sched.now - self.sent_at
        self.latencies.append((sched.now, lat))
        if self.wl.read_ratio is not None:
            self.rw_lat[0].append(lat)
        if self._obs is not None:
            self._obs.latency.note(lat)
        self._issue()

    def _retry(self) -> None:
        """Not-leader backoff path: re-send the SAME command.  Never
        regenerate under an in-flight seq — with crash-recover plans the
        original may already be proposed (and later committed via post-
        recovery re-arm), and the replicas' (client_id, seq) session dedup
        would conflate a regenerated command with it, acking the wrong
        operation's result."""
        if self.cluster.sched.now >= self.stop_at:
            return
        req = ClientRequest(cmd=self._last_cmd)
        tc = self._tctx
        if tc is not None and tc[0] == self.seq:
            self._tracer.attach(req, tc[1])   # the retry hops join the trace
        self.cluster.net.send(self.net_id, self.pick_target(), req)

    def _resend(self, seq: int) -> None:
        """Request-timeout path: re-send the SAME command (the replicas'
        at-most-once session dedup absorbs duplicates) until replied."""
        sched = self.cluster.sched
        if (seq != self.seq or self._last_cmd is None
                or self._last_cmd.seq != seq
                or (self._hist_cur is not None
                    and self._hist_cur["seq"] == seq
                    and self._hist_cur["resp"] is not None)
                or sched.now >= self.stop_at):
            return
        self.retries += 1
        self.cluster.net.send(self.net_id, self.pick_target(),
                              ClientRequest(cmd=self._last_cmd))
        sched.after(self.wl.request_timeout, lambda: self._resend(seq))


class OpenLoopClient(Client):
    """Open-loop client: ops arrive as a Poisson process at ``rate_hz``
    independent of replies, so offered load does not collapse when the
    system slows down — the saturation-probe regime the closed-loop paper
    setup cannot express.  At most ``max_outstanding`` ops are in flight;
    arrivals beyond that are shed (standard open-loop overload guard)."""

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self.outstanding: Dict[int, tuple] = {}   # seq -> (sent_at, cmd, rec)
        self.shed = 0        # arrivals dropped at the client (cap reached)
        self.rejected = 0    # ops abandoned on ok=False (reject_action="drop")
        self._tctxs: Dict[int, tuple] = {}        # seq -> trace ctx (sampled)

    def start(self) -> None:
        self._arrival()

    def _rate_at(self, t: float) -> float:
        """Instantaneous arrival rate (Hz) — constant for "poisson",
        modulated for "bursty"/"diurnal" (see WorkloadConfig)."""
        wl = self.wl
        a = wl.arrival
        if a == "bursty":
            if (t % wl.burst_period) / wl.burst_period < wl.burst_on:
                return wl.rate_hz * wl.burst_factor
            off = (wl.rate_hz * max(0.0, 1.0 - wl.burst_factor * wl.burst_on)
                   / (1.0 - wl.burst_on))
            return max(off, 1e-9)
        if a == "diurnal":
            return wl.rate_hz * max(
                1e-9, 1.0 + wl.diurnal_amp
                * math.sin(2.0 * math.pi * t / wl.diurnal_period))
        return wl.rate_hz

    def _arrival(self) -> None:
        sched = self.cluster.sched
        if sched.now >= self.stop_at:
            return
        rng = sched.rng
        if len(self.outstanding) < self.wl.max_outstanding:
            self.seq += 1
            cmd = self._make_command(self.seq)
            rec = None
            if self.history is not None:
                rec = {"cid": self.id, "seq": self.seq, "op": cmd.op,
                       "key": cmd.key, "invoke": sched.now, "resp": None,
                       "ok": False, "rtag": None,
                       "wtag": getattr(cmd.value, "tag", None)}
                self.history.append(rec)
            self.outstanding[self.seq] = (sched.now, cmd, rec)
            req = ClientRequest(cmd=cmd)
            tr = self._tracer
            if tr is not None:
                ctx = tr.begin_op(self.net_id, sched.now)
                if ctx is not None:
                    self._tctxs[self.seq] = ctx
                    tr.attach(req, ctx)
            self.cluster.net.send(self.net_id, self.pick_target(), req)
            if self.wl.request_timeout:
                seq = self.seq
                sched.after(self.wl.request_timeout,
                            lambda: self._timeout_seq(seq))
        else:
            self.shed += 1
        sched.after(rng.exponential(1.0 / self._rate_at(sched.now)),
                    self._arrival)

    def deliver(self, msg: ClientReply) -> None:
        entry = self.outstanding.get(msg.seq)
        if entry is None:
            return   # stale duplicate
        sched = self.cluster.sched
        if not msg.ok:
            if self.wl.reject_action == "drop":
                del self.outstanding[msg.seq]
                self.rejected += 1
                ctx = self._tctxs.pop(msg.seq, None)
                if ctx is not None:
                    self._tracer.abort_op(ctx, sched.now)
                return
            seq = msg.seq
            sched.after(5e-3, lambda: self._retry_seq(seq))
            return
        del self.outstanding[msg.seq]
        rec = entry[2]
        if rec is not None:
            rec["resp"] = sched.now
            rec["ok"] = True
            rec["rtag"] = getattr(msg.value, "tag", None)
            rec["path"] = msg.path
        lat = sched.now - entry[0]
        self.latencies.append((sched.now, lat))
        if self.wl.read_ratio is not None:
            self.rw_lat[0 if entry[1].op == "get" else 1].append(lat)
        ctx = self._tctxs.pop(msg.seq, None)
        if ctx is not None:
            self._tracer.finish_op(ctx, sched.now)
        if self._obs is not None:
            self._obs.latency.note(lat)

    def _retry_seq(self, seq: int) -> None:
        entry = self.outstanding.get(seq)
        if entry is None:
            return
        if self.cluster.sched.now >= self.stop_at:
            del self.outstanding[seq]
            return
        self.cluster.net.send(self.net_id, self.pick_target(),
                              ClientRequest(cmd=entry[1]))

    def _timeout_seq(self, seq: int) -> None:
        entry = self.outstanding.get(seq)
        if entry is None or self.cluster.sched.now >= self.stop_at:
            return
        self.retries += 1
        self.cluster.net.send(self.net_id, self.pick_target(),
                              ClientRequest(cmd=entry[1]))
        self.cluster.sched.after(self.wl.request_timeout,
                                 lambda: self._timeout_seq(seq))


class Cluster:
    """A protocol deployment + clients on one scheduler."""

    def __init__(self, protocol: str, n: int, topo: Optional[Topology] = None,
                 pig: Optional[PigConfig] = None, seed: int = 0,
                 cost: Optional[CostModel] = None, leader_timeout: float = 50e-3,
                 quorums=None, engine: str = "exact",
                 record_history: bool = False, spare_nodes: int = 0,
                 batch=None, pipeline_depth: int = 0, obs=None, lease=None):
        """``engine`` selects the simulation engine:

        * ``"exact"`` (default) — fused slab engine, trace-identical to the
          seed implementation (golden-trace guarantee);
        * ``"fast"``  — flattened single-event-per-hop delivery; aggregate
          stats preserved, traces not bit-identical (big-N sweeps);
        * ``"ref"``   — the seed engine kept verbatim in refengine.py
          (golden-trace baseline and speedup benchmarks).

        ``record_history`` makes every client keep an invoke/response record
        per operation (with tagged put values) for the consistency auditor
        (``repro.faults.audit``); off by default — the hot path is untouched.

        ``spare_nodes`` pre-provisions extra node objects (ids ``n`` ..
        ``n + spare_nodes - 1``) OUTSIDE the initial membership.  They sit
        inert (non-voting learners) until ``add_node`` joins them through
        the protocol's reconfiguration path.  DES engines only.

        ``batch`` (a ``core.paxos.BatchConfig``) enables leader-side
        request batching; ``pipeline_depth`` > 0 throttles the leader to
        that many uncommitted in-flight slots (0 = unbounded, the native
        behavior).  DES engines only — the verbatim seed stack has no
        batching surface.

        ``obs`` (a ``repro.obs.ObsConfig``, a kwargs dict, or ``True``)
        enables the observability layer: per-op distributed tracing
        (``sample_rate``, event/RNG-neutral) and timeline metrics sampling
        (``metrics_dt``).  DES engines only — the seed stack has no hook
        surface.  Exposed afterwards as ``cluster.obs_tracer`` /
        ``cluster.obs_timelines``; ``Stats.timelines`` carries the
        exported series.
        """
        self.protocol = protocol
        self.n = n
        self.engine = engine
        self.record_history = record_history
        self.batch = batch
        self.pipeline_depth = pipeline_depth
        if spare_nodes and engine == "ref":
            raise ValueError("membership change is not supported by the "
                             "verbatim seed stack (engine='ref') — use "
                             "'exact' or 'fast'")
        if (batch is not None or pipeline_depth) and engine == "ref":
            raise ValueError("batching/pipelining is not supported by the "
                             "verbatim seed stack (engine='ref') — use "
                             "'exact' or 'fast'")
        if lease is not None:
            from .paxos import LeaseConfig
            if engine == "ref":
                raise ValueError("leader leases are not supported by the "
                                 "verbatim seed stack (engine='ref') — use "
                                 "'exact' or 'fast'")
            if protocol == "epaxos":
                raise ValueError("leader leases need a distinguished leader "
                                 "— EPaxos is leaderless; use "
                                 "read_path='quorum' for EPaxos reads")
            if isinstance(lease, dict):
                lease = LeaseConfig(**lease)
        self.lease = lease
        total = n + spare_nodes
        self.topo = topo or Topology(n=total)
        if self.topo.n < total:
            raise ValueError(f"topology has {self.topo.n} nodes but "
                             f"n + spare_nodes = {total}")
        if engine == "ref":
            # the verbatim seed stack: seed scheduler/network AND seed
            # protocol classes (golden-trace baseline, see refengine.py)
            from .refengine import (RefEPaxosNode, RefNetwork, RefPaxosNode,
                                    RefScheduler)
            self.sched = RefScheduler(seed=seed)
            self.net = RefNetwork(self.sched, self.topo, cost=cost)
            paxos_cls, epaxos_cls = RefPaxosNode, RefEPaxosNode
        elif engine in ("exact", "fast"):
            self.sched = Scheduler(seed=seed)
            self.net = Network(self.sched, self.topo, cost=cost,
                               fast_path=(engine == "fast"))
            paxos_cls, epaxos_cls = PaxosNode, EPaxosNode
        else:
            raise ValueError(f"unknown engine {engine!r}")
        self.obs_cfg = None
        self.obs_tracer = None
        self.obs_timelines = None
        if obs is not None and obs is not False:
            if engine == "ref":
                raise ValueError("observability is not supported by the "
                                 "verbatim seed stack (engine='ref') — use "
                                 "'exact' or 'fast'")
            from ..obs import ObsConfig, Timelines, Tracer
            cfg = ObsConfig.coerce(obs)
            self.obs_cfg = cfg
            self.obs_tracer = Tracer(cfg.sample_rate, cfg.max_spans)
            self.net.tracer = self.obs_tracer
            self.obs_timelines = Timelines(cfg.timeline_cap)
            self.net.obs = self.obs_timelines
        self.pig = pig
        self.leader_timeout = leader_timeout
        peers = list(range(n))
        self.nodes: List[Node] = []
        bkw = ({} if engine == "ref"
               else {"batch": batch, "pipeline_depth": pipeline_depth})
        # per-node drifting clocks (lease runs only): rate uniform in
        # [-b, +b], a small offset for realism (offsets cancel in all
        # elapsed-local lease comparisons).  A SEPARATE generator — the
        # shared sched.rng draw order is pinned by golden traces.
        if lease is not None:
            crng = np.random.default_rng(int(seed) + 0x10EA5E)
            b = lease.drift_bound
            clock = [(float(crng.uniform(-b, b)),
                      float(crng.uniform(0.0, 1e-3))) for _ in range(total)]
        else:
            clock = [(0.0, 0.0)] * total
        for i in range(total):
            if protocol == "epaxos":
                # the seed class has no recovery surface; the new engines
                # probe stuck instances after 2 leader timeouts (fault runs)
                ekw = ({} if engine == "ref"
                       else {"recovery_timeout": 2 * leader_timeout, **bkw})
                self.nodes.append(epaxos_cls(i, self.net, self.sched, peers,
                                             **ekw))
            else:
                pkw = dict(bkw)
                if engine != "ref":
                    pkw.update(lease=lease, clock_rate=clock[i][0],
                               clock_offset=clock[i][1])
                self.nodes.append(paxos_cls(i, self.net, self.sched, peers,
                                            pig=pig if protocol == "pigpaxos" else None,
                                            leader_timeout=leader_timeout,
                                            quorums=quorums, **pkw))
        # cluster-level membership view, fed by node callbacks as cfg
        # commands apply (client routing + the auditor's durable set)
        self.members: List[int] = list(peers)
        for nd in self.nodes:
            nd.on_membership_change = self._on_membership_change
            if protocol in ("paxos", "pigpaxos"):
                nd.on_became_leader = self._on_became_leader
        for i in range(n, total):
            self.nodes[i].joining = True   # inert learner until add_node
        self.leader_id = 0
        self.clients: List[Client] = []
        if protocol in ("paxos", "pigpaxos"):
            self.nodes[0].start_phase1()

    # ----------------------------------------------------------- membership
    def _on_became_leader(self, node) -> None:
        self.leader_id = node.id

    def _on_membership_change(self, node, op: str, nid: int) -> None:
        """Fired by EVERY node as it applies a cfg command; the first
        application updates the cluster-level view (idempotent after that).
        """
        if op == "add_node":
            if nid not in self.members:
                self.members.append(nid)
                self.members.sort()
        else:
            if nid in self.members:
                self.members.remove(nid)
                if (nid == self.leader_id and self.members
                        and self.protocol in ("paxos", "pigpaxos")):
                    # remove-the-leader: hand leadership to the lowest
                    # member (deferred a tick: we're inside an apply loop)
                    succ = self.members[0]
                    self.sched.after(0.0, self.nodes[succ].start_phase1)

    def add_node(self, j: int, catch_up: bool = True) -> None:
        """Join node ``j`` (usually a spare) through the protocol's
        reconfiguration path: snapshot + log suffix first, voting only after
        the ``add_node`` cfg command applies.  ``catch_up=False`` is the
        deliberately-broken control (state transfer skipped) that the
        auditor must catch."""
        nd = self.nodes[j]
        if self.protocol == "epaxos":
            ref = lambda: min(self.members)
        else:
            ref = lambda: self.leader_id
        nd.begin_join(ref, catch_up=catch_up)

    def remove_node(self, j: int, _tries: int = 40) -> None:
        """Propose removing node ``j`` from the membership.  Retries on a
        timer while no proposer is available (mid-election, or another cfg
        command in flight — the one-at-a-time invariant)."""
        proposer = (min(self.members) if self.protocol == "epaxos"
                    else self.leader_id)
        ok = self.nodes[proposer].propose_reconfig("remove_node", j)
        if not ok and _tries > 0:
            self.sched.after(2 * self.leader_timeout,
                             lambda: self.remove_node(j, _tries - 1))

    def replace_leader(self, j: int) -> None:
        """Planned leader handoff: ``j`` campaigns with a higher ballot and
        the incumbent steps down on its P1a.  No-op for EPaxos (leaderless)
        and for non-members."""
        if self.protocol in ("paxos", "pigpaxos") and j in self.members:
            self.nodes[j].start_phase1()

    # ------------------------------------------------------------- clients
    def add_clients(self, k: int, workload: Optional[WorkloadConfig] = None,
                    stop_at: float = float("inf"),
                    start_at: float = 20e-3) -> None:
        wl = workload or WorkloadConfig()
        cls = Client if wl.arrival == "closed" else OpenLoopClient
        rng = self.sched.rng
        for c in range(k):
            if self.protocol == "epaxos":
                # uniform over the CURRENT membership (identical rng draws
                # to the seed's integers(n) while membership never changes)
                pick = lambda: self.members[int(rng.integers(len(self.members)))]
            else:
                pick = lambda: self.leader_id
            cl = cls(self, len(self.clients), pick, wl, stop_at)
            self.clients.append(cl)
            # stagger client start to avoid a thundering herd at t0
            self.sched.at(start_at + 1e-4 * c, cl.start)

    # ------------------------------------------------------------- failures
    def crash_at(self, node_id: int, t: float) -> None:
        self.sched.at(t, self.nodes[node_id].crash)

    def recover_at(self, node_id: int, t: float) -> None:
        self.sched.at(t, self.nodes[node_id].recover)

    def partition_at(self, a: int, b: int, t: float) -> None:
        self.sched.at(t, lambda: self.net.partition(a, b))

    # ------------------------------------------------------------- running
    def run(self, until: float) -> None:
        self.sched.run(until=until)

    def measure(self, duration: float, warmup: float = 0.5,
                clients: int = 60, workload: Optional[WorkloadConfig] = None,
                reset_stats_at_warmup: bool = True) -> "Stats":
        stop = warmup + duration
        if (self.obs_timelines is not None
                and self.obs_cfg.metrics_dt > 0.0):
            from ..obs import install_sampler
            install_sampler(self, self.obs_timelines, self.obs_cfg.metrics_dt,
                            stop_at=stop + 0.2)
        self.add_clients(clients, workload, stop_at=stop)
        if reset_stats_at_warmup:
            self.sched.at(warmup, self.net.reset_stats)
        mark = {}
        def _mark_commits():
            for i, nd in enumerate(self.nodes):
                mark[i] = getattr(nd, "committed_count", 0)
        self.sched.at(warmup, _mark_commits)
        self.run(until=stop + 0.2)   # drain in-flight ops
        lats = [l for c in self.clients for (t, l) in c.latencies
                if warmup <= t <= stop]
        committed = sum(getattr(nd, "committed_count", 0) for nd in self.nodes) \
            - sum(mark.values())
        return Stats.from_lat(lats, duration, self, committed)

    def read_write_split(self) -> Optional[dict]:
        """Read/write latency+count split across all clients (ms), plus the
        number of leader-local leased reads served.  None unless the
        workload set ``read_ratio``."""
        reads = [l for c in self.clients for l in c.rw_lat[0]]
        writes = [l for c in self.clients for l in c.rw_lat[1]]
        if not reads and not writes:
            return None
        return {
            "reads": len(reads), "writes": len(writes),
            "read_mean_ms": float(np.mean(reads)) * 1e3 if reads else None,
            "write_mean_ms": float(np.mean(writes)) * 1e3 if writes else None,
            "read_p99_ms": (float(np.percentile(np.asarray(reads), 99)) * 1e3
                            if reads else None),
            "lease_reads": sum(getattr(nd, "lease_reads", 0)
                               for nd in self.nodes),
        }


@dataclass
class Stats:
    throughput: float
    mean_ms: float
    median_ms: float
    p25_ms: float
    p75_ms: float
    p99_ms: float
    count: int
    committed: int
    msg_in: np.ndarray = None
    msg_out: np.ndarray = None
    flight: np.ndarray = None
    cpu_busy: Dict[int, float] = None
    # exported observability timelines (repro.obs.Timelines.export()) when
    # the cluster ran with obs enabled; None otherwise
    timelines: Optional[dict] = None

    @classmethod
    def from_lat(cls, lats: List[float], duration: float, cluster: Cluster,
                 committed: int) -> "Stats":
        a = np.asarray(lats) * 1e3 if lats else np.asarray([np.nan])
        n = cluster.n
        return cls(
            throughput=len(lats) / duration,
            mean_ms=float(np.mean(a)), median_ms=float(np.median(a)),
            p25_ms=float(np.percentile(a, 25)), p75_ms=float(np.percentile(a, 75)),
            p99_ms=float(np.percentile(a, 99)),
            count=len(lats), committed=committed,
            msg_in=cluster.net.msgs_in[:n].copy(),
            msg_out=cluster.net.msgs_out[:n].copy(),
            flight=cluster.net.flight_matrix[:n, :n].copy(),
            cpu_busy=dict(cluster.net.cpu_busy),
            timelines=(cluster.net.obs.export()
                       if getattr(cluster.net, "obs", None) is not None
                       else None),
        )

    def messages_per_op(self, node_id: int) -> float:
        ops = max(self.committed, 1)
        return float(self.msg_in[node_id] + self.msg_out[node_id]) / ops


def agreement_ok(cluster: Cluster) -> bool:
    """Safety check: all nodes applied the same commands in the same order.
    Each log must be a contiguous *window* of the longest one: laggards are
    prefixes, snapshot-joined nodes start mid-stream at their snapshot
    point, and a joiner promoted to leader may overhang the end (it applies
    at commit, before the commit messages land on followers)."""
    logs = []
    for nd in cluster.nodes:
        logs.append([(s, c.client_id, c.seq, c.op, c.key) for s, c in nd.applied_log])
    ref = max(logs, key=len)
    # slot/inst-id -> FIRST index (batched slots contribute one applied
    # entry per sub-command, so a slot id can repeat; windows start at
    # batch boundaries, i.e. the first entry of the slot)
    pos: Dict = {}
    for i, e in enumerate(ref):
        pos.setdefault(e[0], i)
    for lg in logs:
        if not lg or lg == ref[:len(lg)]:
            continue                               # prefix: the usual case
        i = pos.get(lg[0][0])
        if i is None:
            return False
        k = min(len(lg), len(ref) - i)
        # the window must match where it overlaps, and anything past the
        # ref's end must be genuinely new — a repeated slot is divergence
        if lg[:k] != ref[i:i + k] or any(e[0] in pos for e in lg[k:]):
            return False
    return True
