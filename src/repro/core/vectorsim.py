"""Batched round-level simulation backend: whole sweep grids in one
compiled call.

The discrete-event engines (``cluster.Cluster``) pay one Python event loop
per grid cell; a sweep (clients x seeds x configs) only scales with cores.
This module decouples scenario coverage from per-event Python dispatch the
same way *Compartmentalization* decouples the protocol from its bottleneck:
the per-request message flow of Paxos / PigPaxos / EPaxos is re-expressed
as pure array math — a ``lax.scan`` over requests, ``vmap`` over the grid —
so an entire scenario grid is ONE jitted XLA call.

Model (request level, mirroring the flattened ``engine="fast"`` semantics):

* **closed-loop client credit** — each client holds one outstanding request;
  the scan pops the earliest-ready client, walks its request through the
  protocol's hop/CPU pipeline, and credits the client back at reply time;
* **per-node CPU-queue accumulators** — every node is a FIFO server
  (service = CostModel cpu cost per message, §2.2); queueing is modeled by
  reserving CPU in request order (``max(arrival, cpu_free) + cost``), with
  exact FIFO ordering *within* a request's reply fan-in (sort + cumulative
  max over the group grid);
* **rotating relay choice** sampled per group per round (§3.1), static
  relays and explicit (e.g. per-region WAN) groups supported;
* **link latencies** drawn per hop from the ``Topology`` spec: LAN base +
  Exp(jitter), or the WAN one-way region matrix (§5.3);
* **PRC thresholds** q_i = n_i - PRC with the §4.1 liveness adjustment, and
  the §4.3 single-group global-majority shortcut.

Classic Paxos is the degenerate group structure (N-1 singleton groups with
direct-message costs); EPaxos gets its own symmetric kernel: random
per-request command leader, PreAccept broadcast, fast-quorum commit — and a
**conflict/slow-path model**: each request draws its key from the
workload's distribution (uniform / zipfian via the cached CDF / hot-key
conflict), requests whose PreAccept round races the previous same-key
instance's propagation window take the Paxos-accept slow path (a second
fan-out/fan-in round), and execution waits for the predecessor's commit to
be known (dependency-order gate).  Throughput tracks the fast DES within
~10% up to c=0.5 (tests/test_epaxos_recovery.py).

**Leased leader reads** (group kernel only): a workload with
``read_ratio`` > 0 and ``read_path="lease"`` models the leader serving
reads locally under a held lease — each scan-step burst draws a per-request
read mask (an extra fold of the step key; the write path's draw order is
untouched), the leader FIFO becomes a varying-service Lindley chain
(writes cost the full round's leader work, leased reads cost only
request-ingest + reply), and read requests skip the entire follower
fan-out: no relay hops, no follower CPU work, no aggregate fan-in, and no
commit (``committed`` counts writes only — reads never touch the log).
``read_path="log"`` needs no kernel support at all: log reads flow through
phase 2 exactly like writes, so only the expected wire sizes change (gets
carry no payload out, puts carry none back).  The lease itself is assumed
HELD for the whole run — grant/renewal traffic, expiry windows, and clock
drift are DES-only (that is where lease safety is audited); the batch
model is the steady-state throughput/latency envelope of an uncontested
lease.  Per-node message loads keep their write-path meaning (messages
per committed write; read traffic at the leader is not counted).

**Fault masks** (``repro.faults.FaultPlan.to_masks``): deterministic
crash/recover windows and whole-run gray/slow nodes are expressible as
time-varying per-node availability masks — a hop arriving at a down node is
*deferred* to the window's end (the node drains its backlog at recovery),
relays are sampled among the currently-up group members (matching the DES
leader's gray-listing behavior after one timeout), and slow nodes add a
constant one-way latency to every touching hop.  Group kernel only; mask
runs also emit a completion timeline (50 ms buckets, same format as the DES
``collect=("timeline",)`` extra) for throughput-dip/unavailability metrics.

Deliberately **not** modeled: partitions, drops, relay timeouts, late-vote
supplements, open-loop arrivals, (Pig)Paxos key sampling (keys never route
there), EPaxos fault masks (instance recovery is a DES-only protocol
phase), EPaxos dependency-graph wall-time (Tarjan costs no virtual
time), quorum/follower reads (the probe / rinse / re-probe state machine
has no array form — quorum-read scenarios are DES-authoritative), lease
grant/expiry dynamics and clock drift (see the leased-reads paragraph
above), and reads combined with fault masks, leader batching, or the
EPaxos kernel (``build_config`` rejects those loudly) — scenarios that
need those stay on the DES (`Scenario.batch_ok` marks the eligible
ones).  A crashed follower's
vote is deferred, not lost, so plans must leave every group's PRC threshold
reachable without the down members (single crashes with ``prc >= 1``, or
Paxos's singleton groups) — the DES relay-timeout fallback has no batch
equivalent.

Outputs match the DES ``Stats`` summary (committed throughput, latency
percentiles measured at the client over the [warmup, warmup+duration]
window, per-node message loads M_l / M_f) within a few percent of
``Cluster(engine="fast")`` — see tests/test_vectorsim.py.
"""
from __future__ import annotations

import dataclasses
import functools
import time
import warnings
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec

try:        # shard_map is the primary sharding path; pmap is the fallback
    from jax.experimental.shard_map import shard_map as _shard_map
except ImportError:                                   # pragma: no cover
    _shard_map = None

from .messages import HEADER_BYTES, CostModel
from .pig import partition_followers, required_per_group
from .quorums import fast_quorum, majority
from .segscan import seg_cummax, seg_cumsum

# measurement harness constants — keep identical to cluster.Cluster
_DRAIN_S = 0.2          # post-stop drain window (Cluster.measure)
_CLIENT_START = 20e-3   # Cluster.add_clients start_at
_CLIENT_STAGGER = 1e-4  # per-client start stagger
_TL_BUCKET = 0.05       # timeline bucket (= runner.TIMELINE_BUCKET_S)

_MAX_STEPS = 400_000    # hard cap for the exhausted-retry loop

# static-shape signature -> number of XLA traces (tests assert a whole grid
# compiles exactly once; see trace_counts())
_TRACE_COUNTS: Dict[tuple, int] = {}


def trace_counts() -> Dict[tuple, int]:
    return dict(_TRACE_COUNTS)


# ===================================================================== config
@dataclasses.dataclass
class SimConfig:
    """One protocol deployment, lowered to arrays (leader = node 0).

    ``kind`` selects the kernel: "group" covers Paxos (singleton groups,
    direct-message costs) and PigPaxos (relay groups); "epaxos" is the
    symmetric random-leader kernel.
    """
    kind: str
    n: int
    members: np.ndarray        # (r, g) follower node ids, -1 padding
    sizes: np.ndarray          # (r,) group sizes (0 = padded group)
    thresh: np.ndarray         # (r,) relay flush threshold incl. the relay
    static_relay: bool
    majority: int
    region_of: np.ndarray      # (n,) region per node (all 0 for LAN)
    region_latency: np.ndarray  # (nreg, nreg) one-way base seconds
    jitter: float
    costs: Dict[str, float]    # c_req/c_fanout/c_rel/c_repl/c_agg/c_replycl
    label: str = ""
    # fault masks (None = fault-free): down-windows (n, W, 2) [lo, hi) with
    # +inf padding, and per-node whole-run extra one-way latency (n,)
    down: Optional[np.ndarray] = None
    slow: Optional[np.ndarray] = None
    # EPaxos conflict model (epaxos kernel only): the workload's key
    # distribution — 0 uniform, 1 zipfian (key_cdf), 2 hot-key conflict
    key_mode: int = 0
    n_keys: int = 1000
    conflict_rate: float = 0.0
    key_cdf: Optional[np.ndarray] = None
    # leased-leader-read model (group kernel only): fraction of requests
    # served locally at the leader under a held lease (0 = write path only)
    read_ratio: float = 0.0

    @property
    def rmax(self) -> int:
        return self.members.shape[0]

    @property
    def gmax(self) -> int:
        return self.members.shape[1]


def _expected_wires(workload) -> Dict[str, float]:
    """Expected wire sizes per message role (costs are linear in bytes, so
    using the expectation is exact for mean CPU load)."""
    wf = 0.5
    payload = 8.0
    if workload is not None:
        wf = float(workload.write_fraction)
        if getattr(workload, "read_ratio", None) is not None:
            wf = 1.0 - float(workload.read_ratio)
        if workload.payload_choices:
            w = np.asarray(workload.payload_weights
                           or [1.0] * len(workload.payload_choices), float)
            sizes = np.asarray([float(s) for s in workload.payload_choices])
            payload = float((sizes * w / w.sum()).sum())
        else:
            payload = float(workload.payload_bytes)
    cmd = 16.0 + wf * payload                      # Command.wire_size
    return {
        "req": HEADER_BYTES + cmd,                 # ClientRequest
        "p2a": HEADER_BYTES + 16 + cmd,            # P2a
        "p2b": float(HEADER_BYTES),                # P2b
        # gets return the stored value (= a put payload); puts return None
        "reply_cl": HEADER_BYTES + 8 + (1.0 - wf) * payload,
        "cmd": cmd,
    }


def build_config(protocol: str, n: int, pig=None, topo=None, workload=None,
                 cost: Optional[CostModel] = None, label: str = "",
                 masks: Optional[Dict[str, np.ndarray]] = None,
                 batch_m: int = 1) -> SimConfig:
    """Lower a (protocol, n, PigConfig, Topology, WorkloadConfig) deployment
    to the array form the batched kernels consume.  ``masks`` is the fault
    lowering produced by ``repro.faults.FaultPlan.to_masks`` — down-windows
    and slow vectors (group kernel only).

    ``batch_m`` models leader-side request batching (``BatchConfig`` with a
    full batch of m on every slot — the saturation regime): one "request"
    through the kernel is a whole batch, with per-batch cost = fixed +
    per-command marginal, exactly the DES cost model — m ClientRequest
    ingests, ONE phase-2 fan-out carrying the batched P2a (8-byte batch
    header + m commands), fixed-size votes/aggregates unchanged, m serial
    client replies.  Callers divide the client count by m (m clients share
    one slot) and scale throughput back up; ``simulate_scenario`` does both.
    """
    cm = cost or CostModel()
    base, pb = cm.base, cm.per_byte
    w = _expected_wires(workload)
    if workload is not None and getattr(workload, "arrival", "closed") != "closed":
        raise ValueError("batch backend models closed-loop clients only")
    if batch_m < 1:
        raise ValueError("batch_m must be >= 1")
    if batch_m > 1 and protocol == "epaxos":
        raise ValueError("batch-backend batching is group-kernel only; "
                         "batched EPaxos runs are DES-authoritative "
                         "(leaderless per-node buffers interact with the "
                         "conflict model)")
    # leased-read model eligibility (see the module docstring): only the
    # group kernel's single-leader FIFO has a lease to serve reads under
    rr = (getattr(workload, "read_ratio", None)
          if workload is not None else None)
    rpath = (getattr(workload, "read_path", "log")
             if workload is not None else "log")
    lease_rr = 0.0
    if rr is not None and float(rr) > 0.0:
        if rpath == "quorum":
            raise ValueError(
                "batch backend models log and leased leader reads only; "
                "quorum reads (probe / rinse / re-probe rounds) have no "
                "array form — quorum-read scenarios are DES-authoritative")
        if rpath == "lease":
            if protocol == "epaxos":
                raise ValueError(
                    "leased reads are group-kernel only: epaxos is "
                    "leaderless (no leader lease to serve reads under) — "
                    "epaxos read scenarios need the DES quorum-read path")
            if masks is not None:
                raise ValueError(
                    "leased reads with fault masks need the DES: the "
                    "batch lease model assumes the lease is held for the "
                    "whole run, which a down-window invalidates")
            if batch_m > 1:
                raise ValueError(
                    "leased reads with leader batching are "
                    "DES-authoritative (reads bypass the batch buffer, so "
                    "the full-batch cost reparameterization no longer "
                    "describes the leader's service distribution)")
            lease_rr = float(rr)
    # batched P2a wire: BatchCmd = 8-byte batch header + m commands
    w_p2a = (w["p2a"] if batch_m == 1
             else HEADER_BYTES + 16 + 8 + batch_m * w["cmd"])
    down = slow = None
    if masks is not None:
        if protocol == "epaxos":
            raise ValueError("fault masks are group-kernel only; "
                             "EPaxos fault scenarios need the DES")
        d = np.asarray(masks["down"], dtype=np.float64)
        s = np.asarray(masks["slow"], dtype=np.float64)
        if d.shape[0] != n or s.shape[0] != n:
            raise ValueError(f"mask shape mismatch: n={n}, "
                             f"down={d.shape}, slow={s.shape}")
        if np.isfinite(d[..., 0]).any():
            down = d
        if (s > 0).any():
            slow = s
    # topology -> region arrays (LAN = one region)
    if topo is not None and topo.region_of is not None:
        region_of = np.asarray(topo.region_of, dtype=np.int32)
        region_latency = np.asarray(topo.region_latency, dtype=np.float64)
        jitter = float(topo.jitter)
    else:
        region_of = np.zeros(n, dtype=np.int32)
        blat = float(topo.base_latency) if topo is not None else 0.25e-3
        jitter = float(topo.jitter) if topo is not None else 0.05e-3
        region_latency = np.asarray([[blat]], dtype=np.float64)

    if protocol == "epaxos":
        # conflict model inputs: the workload's key distribution decides the
        # per-request conflict draw (interfering in-flight instances route
        # conflicted requests through the Paxos-accept slow path)
        key_mode, n_keys, crate, cdf = 0, 1000, 0.0, None
        if workload is not None:
            n_keys = int(getattr(workload, "n_keys", 1000))
            kd = getattr(workload, "key_dist", "uniform")
            if kd == "zipfian":
                from .cluster import zipf_cdf
                key_mode = 1
                cdf = zipf_cdf(n_keys, float(workload.zipf_theta))
            elif kd == "conflict":
                key_mode = 2
                crate = float(workload.conflict_rate)
        costs = {
            "c_req": base + pb * w["req"],
            # PreAccept / PreAcceptReply / ECommit all carry the O(N)
            # dependency bookkeeping term (CostModel §5.3)
            "c_pa": base + pb * (HEADER_BYTES + w["cmd"] + 12 + 8 * n)
            + cm.epaxos_extra_per_node * n,
            "c_par": base + pb * (HEADER_BYTES + 12 + 8 * n)
            + cm.epaxos_extra_per_node * n,
            "c_com": base + pb * (HEADER_BYTES + w["cmd"] + 12 + 8 * n)
            + cm.epaxos_extra_per_node * n,
            "c_replycl": base + pb * w["reply_cl"],
            # slow path (conflicts): EAccept carries the same O(N) payload
            # as PreAccept; EAcceptReply is a fixed-size ack
            "c_acc": base + pb * (HEADER_BYTES + w["cmd"] + 12 + 8 * n)
            + cm.epaxos_extra_per_node * n,
            "c_accr": base + pb * (HEADER_BYTES + 16),
        }
        return SimConfig(
            kind="epaxos", n=n,
            members=np.zeros((1, 1), np.int32), sizes=np.zeros(1, np.int32),
            thresh=np.zeros(1, np.int32), static_relay=False,
            majority=majority(n), region_of=region_of,
            region_latency=region_latency, jitter=jitter, costs=costs,
            label=label or f"epaxos/N={n}",
            key_mode=key_mode, n_keys=n_keys, conflict_rate=crate,
            key_cdf=cdf)

    followers = [i for i in range(1, n)]
    if protocol == "paxos" or pig is None:
        groups = [[f] for f in followers]
        thresh = [1] * len(groups)
        costs = {
            "c_req": batch_m * (base + pb * w["req"]),
            "c_fanout": base + pb * w_p2a,         # P2a direct (batched)
            "c_rel": 0.0,
            "c_repl": 0.0,
            "c_agg": base + pb * w["p2b"],         # P2b direct
            "c_replycl": batch_m * (base + pb * w["reply_cl"]),
        }
        static = True
    elif protocol == "pigpaxos":
        if pig.groups is not None:
            groups = [[m for m in grp if m != 0] for grp in pig.groups]
            groups = [g for g in groups if g]
        else:
            groups = partition_followers(followers, pig.n_groups)
        req = required_per_group(groups, n, pig.prc,
                                 pig.single_group_majority)
        thresh = [min(q, len(g)) for q, g in zip(req, groups)]
        pig_wrap = HEADER_BYTES + 8 + w_p2a        # PigFanout/PigRelayed(P2a)
        costs = {
            "c_req": batch_m * (base + pb * w["req"]),
            "c_fanout": base + pb * pig_wrap,
            "c_rel": base + pb * pig_wrap,
            "c_repl": base + pb * (HEADER_BYTES + 8 + w["p2b"]),  # PigReply
            "c_agg": base + pb * (HEADER_BYTES + 16),             # PigAggregate
            "c_replycl": batch_m * (base + pb * w["reply_cl"]),
        }
        static = not pig.rotate_relays
    else:
        raise ValueError(f"unknown protocol {protocol!r}")

    rmax = len(groups)
    gmax = max(len(g) for g in groups)
    members = np.full((rmax, gmax), -1, dtype=np.int32)
    sizes = np.zeros(rmax, dtype=np.int32)
    tarr = np.zeros(rmax, dtype=np.int32)
    for gi, g in enumerate(groups):
        members[gi, :len(g)] = g
        sizes[gi] = len(g)
        tarr[gi] = thresh[gi]
    return SimConfig(
        kind="group", n=n, members=members, sizes=sizes, thresh=tarr,
        static_relay=static, majority=majority(n), region_of=region_of,
        region_latency=region_latency, jitter=jitter, costs=costs,
        label=label or f"{protocol}/N={n}/R={rmax}", down=down, slow=slow,
        read_ratio=lease_rr)


# ================================================================ rate bound
def _estimate_rate(cfg: SimConfig, k: int) -> float:
    """Optimistic committed-req/s bound (steers the scan-step budget; an
    exhausted grid retries with 2x steps, so this only needs to be sane)."""
    c = cfg.costs
    reg_lat = cfg.region_latency
    leader_reg = int(cfg.region_of[0])
    b_cl = float(reg_lat[0, leader_reg])
    if cfg.kind == "epaxos":
        n = cfg.n
        per_node = 2.0 * (n - 1) * (c["c_pa"] + c["c_par"] + c["c_com"]) / n
        cpu_bound = 1.0 / per_node
        rt = 4 * (b_cl + cfg.jitter) + (n - 1) * c["c_pa"] + 3 * c["c_pa"]
        return min(cpu_bound, k / rt)
    sizes = cfg.sizes[cfg.sizes > 0].astype(float)
    ng = len(sizes)
    leader_cpu = c["c_req"] + ng * (c["c_fanout"] + c["c_agg"]) + c["c_replycl"]
    fol_cpu = (ng * (c["c_fanout"] + c["c_agg"])
               + 2.0 * float((sizes - 1).sum()) * (c["c_rel"] + c["c_repl"]))
    fol_bound = (cfg.n - 1) / fol_cpu if fol_cpu > 0 else float("inf")
    # unloaded round trip: client hops + 2 leader-side + 2 intra-group hops
    mem = cfg.members[cfg.members >= 0]
    b_med = float(np.median(reg_lat[leader_reg, cfg.region_of[mem]]))
    b_in = float(np.median(np.median(reg_lat, axis=0)))
    rt = (2 * b_cl + 2 * b_med + 2 * b_in + 6 * cfg.jitter + leader_cpu
          + c["c_fanout"] + float(sizes.max()) * (c["c_rel"] + c["c_repl"]))
    rr = cfg.read_ratio
    if rr > 0.0:
        # leased reads skip the fan-out entirely: leader work shrinks to
        # ingest + reply, followers see only the write fraction, and the
        # read round trip is two client hops plus the leader service
        w_read = c["c_req"] + c["c_replycl"]
        leader_cpu = rr * w_read + (1.0 - rr) * leader_cpu
        fol_bound = (fol_bound / (1.0 - rr)
                     if rr < 1.0 else float("inf"))
        rt = rr * (2 * b_cl + 2 * cfg.jitter + w_read) + (1.0 - rr) * rt
    return min(1.0 / leader_cpu, fol_bound, k / rt)


# ============================================================== group kernel
def _pct(sorted_vals, m, q):
    """np.percentile(..., q) with linear interpolation over the first ``m``
    entries of an ascending array (invalid entries sorted to +inf)."""
    mf = jnp.maximum(m.astype(jnp.float32), 1.0)
    idx = q * (mf - 1.0)
    lo = jnp.clip(jnp.floor(idx).astype(jnp.int32), 0, sorted_vals.shape[0] - 1)
    hi = jnp.clip(lo + 1, 0, sorted_vals.shape[0] - 1)
    frac = idx - lo.astype(jnp.float32)
    lov = sorted_vals[lo]
    hiv = jnp.where(hi < m, sorted_vals[hi], lov)
    v = lov * (1.0 - frac) + hiv * frac
    return jnp.where(m > 0, v, jnp.nan)


def _summarize(lat, t_fin, commit_t, active, ready, loadF, loadL, cell,
               nb: int = 0):
    stop, warmup, duration = cell["stop"], cell["warmup"], cell["duration"]
    in_lat = active & (t_fin >= warmup) & (t_fin <= stop)
    in_commit = active & (commit_t >= warmup) & (commit_t <= stop + _DRAIN_S)
    count = in_lat.sum()
    committed = in_commit.sum()
    vals = jnp.sort(jnp.where(in_lat, lat, jnp.inf))
    nf = jnp.maximum(count.astype(jnp.float32), 1.0)
    followers = cell["n_followers"].astype(jnp.float32)
    comf = jnp.maximum(committed.astype(jnp.float32), 1.0)
    out = {
        "throughput": count.astype(jnp.float32) / duration,
        "count": count,
        "committed": committed,
        "mean_s": jnp.where(count > 0,
                            jnp.where(in_lat, lat, 0.0).sum() / nf, jnp.nan),
        "median_s": _pct(vals, count, 0.5),
        "p25_s": _pct(vals, count, 0.25),
        "p75_s": _pct(vals, count, 0.75),
        "p99_s": _pct(vals, count, 0.99),
        "m_leader": loadL / comf,
        "m_follower": loadF / (followers * comf),
        "exhausted": jnp.min(ready) < stop,
    }
    if nb:
        # completion timeline (DES collect=("timeline",) format): counts of
        # client-visible completions per fixed virtual-time bucket from t=0
        ok = active & jnp.isfinite(t_fin) & (t_fin <= stop + _DRAIN_S)
        tb = jnp.where(ok, jnp.floor(t_fin / _TL_BUCKET), 0.0)
        tb = jnp.clip(tb.astype(jnp.int32), 0, nb - 1)
        out["timeline"] = jnp.zeros(nb, jnp.int32).at[tb].add(
            ok.astype(jnp.int32))
    return out


def _group_cell(cell, steps: int, kmax: int, breq: int,
                faulty: bool = False, nb: int = 0, kernel: str = "lax",
                obs: bool = False, read: bool = False):
    """Simulate one grid cell of the Paxos/PigPaxos group kernel.

    ``faulty`` (static) enables the fault-mask path: hop arrivals at a
    down node are deferred past its [lo, hi) window, relays are sampled
    among the currently-up group members, and slow nodes add their extra
    one-way latency to every touching hop.  The fault-free trace is
    untouched when False — the mask arrays are never read.

    ``obs`` (static) additionally emits a per-step leader-backlog series
    (the queueing wait W_L each scan step's first popped request just
    observed at the leader FIFO, bucketed over virtual time like the
    completion timeline) — the batch backend's cheap counterpart of the
    DES timeline sampler.  Requires ``nb > 0``; off by default so the
    scan's carry/output signature (and every cached compilation) is
    unchanged for existing callers.

    ``kernel`` (static) selects the reply fan-in implementation: "lax" is
    the sort + segmented-cummax oracle below; "pallas" routes the same
    order statistics through ``kernels.ops.seg_fanin`` (rank-counting
    Pallas kernel — interpret mode on CPU, native on TPU).

    ``read`` (static) enables the leased-leader-read model: each burst
    draws a per-request read mask (an EXTRA fold of the step key, so the
    write path's draw order is bit-identical to read=False), the leader
    ingress Lindley chain runs with per-request service (full round work
    for writes, ingest+reply for leased reads — exclusive prefix sums
    replace the constant-work ``kk_b * T_l`` terms), and read lanes skip
    the follower pipeline: no backlog contribution, no message loads, no
    commit (``commit_done = inf``), and the client reply returns straight
    from the leader.  When False the original constant-service expression
    is kept verbatim so existing compilations are unchanged.

    Two throughput tricks keep the scan XLA-friendly:

    * followers live on a FLAT axis (slots packed group-contiguously;
      ``grp``/``pos``/``gstart`` index the segments), so a heterogeneous
      config batch costs O(N-1) per step instead of O(rmax x gmax) padding;
      per-group order statistics are one lexicographic ``lax.sort`` (blocks
      stay in place) plus a segmented cumulative max;
    * each scan step pops the ``breq`` earliest-ready clients and pushes
      all of them through the pipeline at once — their leader ingress is
      serialized exactly (Lindley chain with constant per-request work),
      follower backlog reads within the burst share the pre-step snapshot
      (the same approximation the fluid model already makes across rounds).
    """
    f32 = jnp.float32
    grp = cell["grp"]                         # (F,) group of each slot
    pos = cell["pos"]                         # (F,) position within group
    gstart = cell["gstart"]                   # (G,) segment start offsets
    sizes = cell["sizes"]                     # (G,)
    thresh = cell["thresh"]
    regF = cell["regF"]                       # (F,) follower regions
    reg_lat = cell["reg_lat"]                 # (nreg, nreg)
    leader_reg = cell["leader_reg"]
    jitter = cell["jitter"]
    (c_req, c_fanout, c_rel, c_repl, c_agg, c_replycl) = [
        cell["costs"][i] for i in range(6)]
    majf = cell["majority"].astype(f32)
    ng = cell["n_groups"]                     # real group count (int)
    ngf = ng.astype(f32)
    stop, warmup = cell["stop"], cell["warmup"]
    key = cell["key"]
    G = sizes.shape[0]
    F = grp.shape[0]
    B = breq

    szf = sizes.astype(f32)
    grp_mask = sizes > 0
    valid = jnp.arange(F) < cell["n_followers"]
    seg_first = jnp.broadcast_to(pos == 0, (B, F))
    grp_b = jnp.broadcast_to(grp, (B, F))
    kk_r = jnp.arange(G, dtype=f32)
    kk_b = jnp.arange(B, dtype=f32)
    posf = pos.astype(f32)
    b_cl = reg_lat[0, leader_reg]
    npeers = jnp.maximum(sizes - 1, 0)
    acks = jnp.where(grp_mask, thresh, 0).astype(f32)
    # total leader work per request (early serialize + deferred late part)
    T_l = c_req + ngf * (c_fanout + c_agg) + c_replycl
    w_peer = c_rel + c_repl
    relay_work = c_fanout + npeers.astype(f32) * w_peer + c_agg  # (G,)

    # fault-mask state (read only when ``faulty``; see module docstring)
    downL = cell["downL"]                     # (W, 2) leader down-windows
    downF = cell["downF"]                     # (F, W, 2) per-slot windows
    slowF = cell["slowF"]                     # (F,) extra one-way seconds
    slowL = cell["slowL"]                     # scalar, node 0

    def defer(t, win):
        """Defer ``t`` past any [lo, hi) down-window containing it;
        ``win`` has shape (..., W, 2) broadcastable against t[..., None]."""
        inw = (t[..., None] >= win[..., 0]) & (t[..., None] < win[..., 1])
        return jnp.maximum(t, jnp.where(inw, win[..., 1], -jnp.inf).max(-1))

    ready0 = jnp.where(jnp.arange(kmax) < cell["k_clients"],
                       _CLIENT_START + _CLIENT_STAGGER * jnp.arange(kmax),
                       jnp.inf).astype(f32)

    def step_fn(carry, i):
        ready, cpuF, cpuL, loadF, loadL, dt_ewma, t_prev = carry
        k1, k2 = jax.random.split(jax.random.fold_in(key, i))
        neg, cids = lax.top_k(-ready, B)
        t0 = -neg                              # (B,) ascending issue times
        active = t0 < stop
        any_active = active[0]                 # actives are a prefix

        e = jax.random.exponential(k1, (B, 2 + 2 * G + 2 * F)) * jitter
        e_cl = e[:, :2]
        e_Lr = e[:, 2:2 + G]
        e_rL = e[:, 2 + G:2 + 2 * G]
        e_rp = e[:, 2 + 2 * G:2 + 2 * G + F]
        e_pr = e[:, 2 + 2 * G + F:]
        u_rel = jax.random.uniform(k2, (B, G))

        # leader ingress: exact FIFO over the burst (Lindley recursion with
        # constant work T_l), seeded by the accumulator.  W_L — the queueing
        # wait each request just experienced — doubles as the stationary
        # estimate of the wait its own aggregates will see one RTT later.
        aL = t0 + b_cl + e_cl[:, 0]
        if faulty:
            # a request arriving at a down leader waits out the window
            # (the DES client's timeout retries land right after recovery)
            aL = defer(aL + slowL, downL)
        if read:
            # leased reads serve at the leader only: service is ingest +
            # reply, writes keep the full round's work.  The exclusive
            # prefix sum Wc generalizes the constant-work kk_b * T_l chain
            # (it reduces to it when every service equals T_l).
            u_read = jax.random.uniform(jax.random.fold_in(k2, 1), (B,))
            is_read = u_read < cell["read_ratio"]
            w_serve = jnp.where(is_read, c_req + c_replycl, T_l)
            Wc = jnp.cumsum(w_serve) - w_serve
            start_b = jnp.maximum(lax.cummax(aL - Wc) + Wc, cpuL + Wc)
            cpuL_next = jnp.maximum(
                cpuL, jnp.where(active, start_b + w_serve, -jnp.inf).max())
        else:
            start_b = jnp.maximum(lax.cummax(aL - kk_b * T_l) + kk_b * T_l,
                                  cpuL + kk_b * T_l)
            cpuL_next = jnp.maximum(
                cpuL, jnp.where(active, start_b + T_l, -jnp.inf).max())
        W_L = start_b - aL
        L1 = start_b + c_req
        fan_done = L1[:, None] + (kk_r[None, :] + 1.0) * c_fanout
        cpuL2 = L1 + ngf * c_fanout

        # rotating-relay choice.  Fault path: sample uniformly among the
        # group members that are UP at the burst's pacing point (the DES
        # leader gray-lists a dead relay after one timeout and avoids it, so
        # steady-state relay duty falls on the live members) — reduces to
        # the plain floor(u * size) draw when everyone is up.  Static relays
        # are pinned to slot 0 even when down (the DES retries the same
        # dead relay forever in that mode; the round defers identically).
        if faulty:
            tref = L1[0]
            down0 = ((tref >= downF[:, :, 0])
                     & (tref < downF[:, :, 1])).any(-1)   # (F,)
            af = (valid & ~down0).astype(f32)
            rank = seg_cumsum(af, seg_first[0], axis=0) - af  # rank among up
            cnt = jnp.zeros(G, f32).at[grp].add(af)       # (G,) up members
            k_sel = jnp.minimum(jnp.floor(u_rel * cnt[None, :]),
                                jnp.maximum(cnt - 1.0, 0.0))   # (B, G)
            k_slot = jnp.take_along_axis(k_sel, grp_b, axis=1)  # (B, F)
            is_sel = (af > 0)[None, :] & (rank[None, :] == k_slot)
            j_dyn = jnp.zeros((B, G), f32).at[:, grp].add(
                jnp.where(is_sel, posf[None, :], 0.0))
            j_rel = jnp.where(cell["static_relay"], 0,
                              j_dyn.astype(jnp.int32))
        else:
            j_rel = jnp.where(cell["static_relay"], 0,
                              jnp.floor(u_rel * szf).astype(jnp.int32))
        j_rel = jnp.clip(j_rel, 0, jnp.maximum(sizes - 1, 0))
        rel_idx = jnp.clip(gstart + j_rel, 0, F - 1)      # (B, G) flat slots

        # online rate estimate (EWMA of the L1 pacing interval) -> follower
        # utilization rho and an M/D/1 stochastic-wait floor
        n_act = jnp.maximum(active.sum().astype(f32), 1.0)
        last_L1 = jnp.where(active, L1, -jnp.inf).max()
        dt_ewma = jnp.where(any_active,
                            0.95 * dt_ewma
                            + 0.05 * (last_L1 - t_prev) / n_act, dt_ewma)
        t_prev = jnp.where(any_active, last_L1, t_prev)
        rho = jnp.clip(cell["w_follower"] / jnp.maximum(dt_ewma, 1e-9),
                       0.0, 0.95)
        md1 = rho * w_peer / (2.0 * (1.0 - rho))

        # relay: receive the fanout, re-broadcast to its group peers.
        # Follower CPUs are fluid work-backlog accumulators anchored at L1,
        # the leader's pacing point (monotone over the scan): waits are the
        # outstanding WORK at the node with a fluid drain to the arrival
        # time plus the M/D/1 floor — never a wall-clock reservation.
        # Anchoring at the (late, cross-round out-of-order) arrival times
        # would let one round's pipeline latency masquerade as CPU backlog
        # for the next round and cascade; anchoring at the client issue time
        # t0 would let closed-loop reissue waves masquerade as backlog the
        # leader's serialization actually paces out.
        # LAN batches (reg_lat is 1x1 — a static shape) skip every region
        # gather: all link bases collapse to one scalar
        lan = reg_lat.shape[0] == 1
        if lan:
            b_Lr = b_rL = reg_lat[0, 0]
            b_rp = b_pr = reg_lat[0, 0]
        else:
            reg_relay = regF[rel_idx]                     # (B, G)
            b_Lr = reg_lat[leader_reg, reg_relay]
            b_rL = reg_lat[reg_relay, leader_reg]
            # per-direction bases: one-way matrices may be asymmetric
            reg_relay_f = jnp.take_along_axis(reg_relay, grp_b, axis=1)
            b_rp = reg_lat[reg_relay_f, regF[None, :]]    # (B, F) out
            b_pr = reg_lat[regF[None, :], reg_relay_f]    # (B, F) back
        arr_rel = fan_done + b_Lr + e_Lr
        if faulty:
            slow_rel = slowF[rel_idx]                     # (B, G)
            arr_rel = defer(arr_rel + slowL + slow_rel, downF[rel_idx])
        B_r = cpuF[rel_idx] - L1[:, None]
        W_r = jnp.maximum(B_r + (rho - 1.0) * (arr_rel - L1[:, None]),
                          0.0) + md1
        h = arr_rel + W_r + c_fanout
        is_relay = pos[None, :] == j_rel[:, grp]          # (B, F)
        peer_mask = valid[None, :] & ~is_relay
        order = (pos[None, :] - (pos[None, :] > j_rel[:, grp])).astype(f32)
        send_done = jnp.take_along_axis(h, grp_b, axis=1) \
            + (order + 1.0) * c_rel
        arr_p = send_done + b_rp + e_rp
        if faulty:
            # relay-out + peer-in slow extras; a down peer serves the
            # relayed message after it recovers (its vote arrives late and
            # simply sorts past the flush threshold if others cover it)
            slow_rel_f = jnp.take_along_axis(slow_rel, grp_b, axis=1)
            arr_p = defer(arr_p + slow_rel_f + slowF[None, :], downF)
        W_p = jnp.maximum(cpuF[None, :] - L1[:, None]
                          + (rho - 1.0) * (arr_p - L1[:, None]), 0.0) + md1
        doneP = arr_p + W_p + c_rel + c_repl
        arr_back = doneP + b_pr + e_pr
        if faulty:
            # the returning reply queues at the relay once IT is back up
            win_rel_f = jnp.take_along_axis(
                downF[rel_idx], grp_b[..., None, None], axis=1)  # (B,F,W,2)
            arr_back = defer(arr_back + slow_rel_f + slowF[None, :],
                             win_rel_f)

        # relay FIFO over its reply fan-in: k-th completion via key-sorted
        # arrivals + segmented cumulative max (done_k = max(arr_k,
        # done_{k-1}) + c); each returning reply queues behind the relay's
        # fluid-drained backlog and this round's own sends (relay_free0).
        # The lexicographic (group, arrival) sort keeps each group's segment
        # block in place with arrivals ascending, so the value at flat slot
        # f is group grp[f]'s pos[f]-th reply.
        relay_free0 = h + npeers.astype(f32)[None, :] * c_rel
        kg = jnp.maximum(thresh - 2, 0)
        if kernel == "pallas":
            # rank-counting Pallas kernel: emits each slot's capped segment
            # max directly (the thresh-2 order statistic), no sort needed
            from ..kernels import ops as _kops
            m = _kops.seg_fanin(
                jnp.where(peer_mask, arr_back, jnp.inf),
                jnp.take_along_axis(B_r, grp_b, axis=1),
                grp, kg[grp], rho - 1.0, md1, c_repl, L1)
            mg = jnp.take_along_axis(
                m, jnp.broadcast_to(jnp.clip(gstart, 0, F - 1), (B, G)),
                axis=1)
            done_g = (kg.astype(f32)[None, :] + 1.0) * c_repl \
                + jnp.maximum(relay_free0, mg)
        else:
            _, arr_s = lax.sort(
                (grp_b, jnp.where(peer_mask, arr_back, jnp.inf)), num_keys=2)
            w_fan = jnp.maximum(
                jnp.take_along_axis(B_r, grp_b, axis=1)
                + (rho - 1.0) * (arr_s - L1[:, None]), 0.0) + md1
            pref = seg_cummax(arr_s + w_fan - posf[None, :] * c_repl,
                              seg_first, axis=1)
            done_k = (posf[None, :] + 1.0) * c_repl + jnp.maximum(
                jnp.take_along_axis(relay_free0, grp_b, axis=1), pref)
            t_idx = jnp.clip(gstart + thresh - 2, 0, F - 1)
            done_g = jnp.take_along_axis(
                done_k, jnp.broadcast_to(t_idx, (B, G)), axis=1)
        flush = jnp.where((thresh >= 2)[None, :], done_g, relay_free0)
        agg_sent = flush + c_agg

        # leader FIFO over aggregates; commit at the quorum-completing one
        agg_in = agg_sent + b_rL + e_rL
        if faulty:
            agg_in = defer(agg_in + slow_rel + slowL, downL)
        arr_agg = jnp.where(grp_mask[None, :], agg_in, jnp.inf)
        acks_b = jnp.broadcast_to(acks, (B, G))
        arr_as, acks_s = lax.sort((arr_agg, acks_b), num_keys=1)
        cum = jnp.cumsum(acks_s, axis=1)
        got = 1.0 + cum >= majf
        kstar = jnp.argmax(got, axis=1)
        prefL = lax.cummax(arr_as + W_L[:, None] - kk_r[None, :] * c_agg,
                           axis=1)
        doneL = (kk_r[None, :] + 1.0) * c_agg \
            + jnp.maximum(cpuL2[:, None], prefL)
        commit_done = jnp.where(
            jnp.any(got, axis=1),
            jnp.take_along_axis(doneL, kstar[:, None], axis=1)[:, 0],
            jnp.inf)
        reply_done = commit_done + c_replycl
        t_fin = reply_done + reg_lat[leader_reg, 0] + e_cl[:, 1]
        if faulty:
            t_fin = t_fin + slowL
        if read:
            # leased reads never enter the log: the reply leaves the leader
            # at service completion, and commit_done = inf keeps them out
            # of `committed` and every commit-windowed load
            read_fin = (start_b + w_serve + reg_lat[leader_reg, 0]
                        + e_cl[:, 1])
            commit_done = jnp.where(is_read, jnp.inf, commit_done)
            t_fin = jnp.where(is_read, read_fin, t_fin)

        # state updates: follower backlogs grow by the burst's per-node WORK
        # from the anchor (the first active request's pacing point — every
        # round touches every follower, so that is the first toucher)
        act_b = ((active & ~is_read) if read else active)[:, None]
        add_w = (jnp.where(act_b & peer_mask, w_peer, 0.0).sum(axis=0)
                 .at[jnp.where(act_b & grp_mask[None, :], rel_idx, F)]
                 .add(jnp.broadcast_to(relay_work, (B, G)), mode="drop"))
        anchored = jnp.maximum(cpuF, jnp.where(any_active, L1[0], 0.0))
        cpuF = jnp.where(any_active, anchored + add_w, cpuF)
        cpuL = jnp.where(any_active, cpuL_next, cpuL)
        ready = ready.at[cids].set(jnp.where(active, t_fin, jnp.inf))

        # per-node message loads, accumulated over the measurement window
        in_win = active & (commit_done >= warmup) & (commit_done
                                                     <= stop + _DRAIN_S)
        win_b = in_win[:, None]
        loadF = loadF + (jnp.where(win_b & peer_mask, 2.0, 0.0).sum(axis=0)
                         .at[jnp.where(win_b & grp_mask[None, :],
                                       rel_idx, F)]
                         .add(jnp.broadcast_to(2.0 * szf, (B, G)),
                              mode="drop"))
        loadL = loadL + jnp.where(in_win, 2.0 * ngf + 2.0, 0.0).sum()

        ys = (t_fin - t0, t_fin, commit_done, active)
        if obs:
            # leader-backlog observation: the wait the step's first popped
            # request just experienced at the leader FIFO (= backlog in
            # seconds at its arrival instant), stamped with that arrival
            ys = ys + (jnp.where(any_active, aL[0], jnp.inf), W_L[0])
        if read:
            ys = ys + (is_read,)
        return ((ready, cpuF, cpuL, loadF, loadL, dt_ewma, t_prev),
                ys)

    carry0 = (ready0, jnp.zeros(F, f32), jnp.float32(0.0),
              jnp.zeros(F, f32), jnp.float32(0.0),
              jnp.float32(1.0), jnp.float32(0.0))
    (ready, _, _, loadF, loadL, _, _), ys = \
        lax.scan(step_fn, carry0, jnp.arange(steps))
    lat, t_fin, commit_t, active = ys[:4]
    out = _summarize(lat.reshape(-1), t_fin.reshape(-1),
                     commit_t.reshape(-1), active.reshape(-1), ready,
                     loadF.sum(), loadL, cell, nb=nb)
    if obs:
        t_obs, qlag = ys[4], ys[5]
        ok = jnp.isfinite(t_obs) & (t_obs <= stop + _DRAIN_S)
        tb = jnp.clip(jnp.where(ok, jnp.floor(t_obs / _TL_BUCKET), 0.0)
                      .astype(jnp.int32), 0, nb - 1)
        w = ok.astype(f32)
        qsum = jnp.zeros(nb, f32).at[tb].add(qlag * w)
        qn = jnp.zeros(nb, f32).at[tb].add(w)
        out["leader_backlog_s"] = jnp.where(qn > 0, qsum / jnp.maximum(qn, 1.0),
                                            0.0)
        out["leader_backlog_n"] = qn.astype(jnp.int32)
    if read:
        # read/write latency split over the same measurement window the
        # headline latencies use (DES counterpart: Cluster.read_write_split)
        isr = ys[-1].reshape(-1)
        latf, tf = lat.reshape(-1), t_fin.reshape(-1)
        in_lat = active.reshape(-1) & (tf >= cell["warmup"]) \
            & (tf <= cell["stop"])
        rm, wm = in_lat & isr, in_lat & ~isr
        rn, wn = rm.sum(), wm.sum()
        out["read_count"], out["write_count"] = rn, wn
        out["read_mean_s"] = jnp.where(
            rn > 0, jnp.where(rm, latf, 0.0).sum()
            / jnp.maximum(rn.astype(f32), 1.0), jnp.nan)
        out["write_mean_s"] = jnp.where(
            wn > 0, jnp.where(wm, latf, 0.0).sum()
            / jnp.maximum(wn.astype(f32), 1.0), jnp.nan)
        out["read_p99_s"] = _pct(jnp.sort(jnp.where(rm, latf, jnp.inf)),
                                 rn, 0.99)
    return out


# ============================================================= epaxos kernel
def _epaxos_cell(cell, steps: int, kmax: int, nb: int = 0):
    """One grid cell of the EPaxos kernel: random command leader per
    request, PreAccept broadcast to all peers, fast-quorum commit on the
    conflict-free path, ECommit broadcast — plus the conflict/slow-path
    model (ISSUE 5):

    * each request draws its key from the workload distribution (uniform /
      zipfian via the cached CDF / hot-key conflict);
    * a request CONFLICTS when the previous same-key instance's PreAccept
      round is still propagating at our fan-out time (``race[k]``) — then
      peers report divergent deps and the commit takes the slow path: a
      Paxos-accept fan-out + majority fan-in (second sorted-cummax round);
    * execution (and hence the client reply) additionally waits until the
      previous same-key instance's commit is known everywhere
      (``depk[k]``) — the dependency-order execution gate.
    """
    f32 = jnp.float32
    n = cell["reg_nodes"].shape[0]
    reg_nodes = cell["reg_nodes"]
    reg_lat = cell["reg_lat"]
    jitter = cell["jitter"]
    (c_req, c_pa, c_par, c_com, c_replycl, c_acc, c_accr) = [
        cell["costs"][i] for i in range(7)]
    fq = cell["fq"]
    maj = cell["majority"]
    stop, warmup = cell["stop"], cell["warmup"]
    key = cell["key"]
    ids = jnp.arange(n)
    kk = jnp.arange(n, dtype=f32)
    nk = cell["key_cdf"].shape[0]
    nkeysf = cell["n_keys"].astype(f32)
    key_mode = cell["key_mode"]
    crate = cell["conflict_rate"]

    ready0 = jnp.where(jnp.arange(kmax) < cell["k_clients"],
                       _CLIENT_START + _CLIENT_STAGGER * jnp.arange(kmax),
                       jnp.inf).astype(f32)

    def step_fn(carry, i):
        ready, cpu, load, race, depk = carry
        ks = jax.random.split(jax.random.fold_in(key, i), 5)
        cid = jnp.argmin(ready)
        t0 = ready[cid]
        active = t0 < stop

        coord = jax.random.randint(ks[0], (), 0, n)
        e_cl = jax.random.exponential(ks[1], (2,)) * jitter
        e_out = jax.random.exponential(ks[2], (n,)) * jitter
        e_back = jax.random.exponential(ks[3], (n,)) * jitter
        u_key = jax.random.uniform(ks[4], ())

        # per-request key draw from the workload's distribution
        k_uni = jnp.floor(u_key * nkeysf).astype(jnp.int32)
        k_zipf = jnp.searchsorted(cell["key_cdf"], u_key,
                                  side="right").astype(jnp.int32)
        k_conf = jnp.where(
            u_key < crate, 0,
            1 + jnp.floor((u_key - crate) / jnp.maximum(1.0 - crate, 1e-9)
                          * (nkeysf - 1.0)).astype(jnp.int32))
        k = jnp.where(key_mode == 1, k_zipf,
                      jnp.where(key_mode == 2, k_conf, k_uni))
        k = jnp.clip(k, 0, cell["n_keys"] - 1)

        coord_reg = reg_nodes[coord]
        b_cl = reg_lat[0, coord_reg]          # clients live in region 0
        b_cp = reg_lat[coord_reg, reg_nodes]  # coord -> peer bases (n,)
        b_pc = reg_lat[reg_nodes, coord_reg]  # peer -> coord (asymmetric ok)

        # every node's CPU is a fluid work-backlog anchored at t0 (see the
        # group kernel): the command-leader role rotates per request, so
        # wall-clock anchoring would cascade across requests
        aC = t0 + b_cl + e_cl[0]
        W_C = jnp.maximum(cpu[coord] - t0, 0.0)
        L1 = aC + W_C + c_req
        is_peer = ids != coord
        order = (ids - (ids > coord)).astype(f32)
        pa_done = L1 + (order + 1.0) * c_pa
        cpuC2 = L1 + (n - 1) * c_pa

        arr_p = pa_done + b_cp + e_out
        W_p = jnp.maximum(cpu - t0, 0.0)
        doneP = arr_p + W_p + c_pa + c_par
        arr_back = jnp.where(is_peer, doneP + b_pc + e_back, jnp.inf)

        # reply fan-in: the coordinator's backlog partially drains over the
        # round trip (it keeps serving while the round is in flight), so the
        # wait each reply sees decays from W_C with the elapsed time — the
        # 0.5 net-drain rate is calibrated against the fast DES (the node
        # also ingests new work while draining, see tests/test_vectorsim.py)
        arr_s = jnp.sort(arr_back)
        W_fan = jnp.maximum(W_C - 0.5 * (arr_s - L1), 0.0)
        pref = lax.cummax(arr_s + W_fan - kk * c_par)
        done_k = (kk + 1.0) * c_par + jnp.maximum(cpuC2, pref)
        # fast-path commit after fq-1 peer replies (the leader votes itself)
        fast_commit = done_k[jnp.clip(fq - 2, 0, n - 1)]

        # conflict draw: the previous same-key instance's PreAccept round is
        # still propagating when we fan out -> peers report divergent deps
        # and the coordinator falls back to the Paxos-accept slow path
        slow = active & (L1 < race[k])
        acc_done = fast_commit + (order + 1.0) * c_acc
        cpuC3 = fast_commit + (n - 1) * c_acc
        arr_p2 = acc_done + b_cp + e_out
        doneP2 = arr_p2 + W_p + c_acc + c_accr
        arr_back2 = jnp.where(is_peer, doneP2 + b_pc + e_back, jnp.inf)
        arr_s2 = jnp.sort(arr_back2)
        W_fan2 = jnp.maximum(W_C - 0.5 * (arr_s2 - L1), 0.0)
        pref2 = lax.cummax(arr_s2 + W_fan2 - kk * c_accr)
        done_k2 = (kk + 1.0) * c_accr + jnp.maximum(cpuC3, pref2)
        slow_commit = done_k2[jnp.clip(maj - 2, 0, n - 1)]
        commit_done = jnp.where(slow, slow_commit, fast_commit)

        # dependency-order execution: a same-key successor cannot execute
        # (and answer its client) before the predecessor's commit is known
        # at its coordinator
        exec_done = jnp.maximum(commit_done + (n - 1) * c_com, depk[k])
        reply_done = exec_done + c_replycl
        t_fin = reply_done + reg_lat[coord_reg, 0] + e_cl[1]

        slowf = slow.astype(f32)
        anchored = jnp.maximum(cpu, t0)
        coord_work = (c_req + (n - 1) * (c_pa + c_par + c_com) + c_replycl
                      + slowf * (n - 1) * (c_acc + c_accr))
        new_cpu = jnp.where(is_peer,
                            anchored + c_pa + c_par + c_com
                            + slowf * (c_acc + c_accr), cpu)
        new_cpu = new_cpu.at[coord].set(anchored[coord] + coord_work)
        cpu = jnp.where(active, new_cpu, cpu)
        ready = ready.at[cid].set(jnp.where(active, t_fin, jnp.inf))

        # conflict-tracking state: when every peer has processed this
        # request's PreAccept (race), and when its commit is known
        # everywhere (depk — ECommit broadcast plus a one-way hop)
        race_new = jnp.where(is_peer, arr_p + W_p + c_pa, -jnp.inf).max()
        b_prop = jnp.where(is_peer, b_cp, 0.0).sum() / jnp.maximum(n - 1, 1)
        dep_new = commit_done + (n - 1) * c_com + b_prop + jitter
        race = race.at[k].set(jnp.where(active, race_new, race[k]))
        depk = depk.at[k].set(jnp.where(active, dep_new, depk[k]))

        in_win = active & (commit_done >= warmup) & (commit_done
                                                     <= stop + _DRAIN_S)
        add = jnp.where(is_peer, 3.0 + 2.0 * slowf,
                        (3.0 * n - 1.0) + 2.0 * (n - 1) * slowf)
        load = load + jnp.where(in_win, 1.0, 0.0) * add

        return ((ready, cpu, load, race, depk),
                (t_fin - t0, t_fin, commit_done, active))

    carry0 = (ready0, jnp.zeros(n, f32), jnp.zeros(n, f32),
              jnp.zeros(nk, f32), jnp.zeros(nk, f32))
    (ready, _, load, _, _), (lat, t_fin, commit_t, active) = lax.scan(
        step_fn, carry0, jnp.arange(steps))
    # symmetric protocol: report node 0 as "leader", the rest as followers
    return _summarize(lat, t_fin, commit_t, active, ready,
                      load[1:].sum(), load[0], cell, nb=nb)


# ================================================================== batching
def _resolve_kernel(kernel: str, kind: str = "group") -> str:
    """"auto" -> the native fan-in for the current backend ("pallas" on
    TPU, the XLA "lax" path elsewhere).  The epaxos kernel has no grouped
    fan-in, so it always normalizes to "lax" (avoids spurious retraces)."""
    if kind != "group":
        return "lax"
    if kernel == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "lax"
    if kernel not in ("lax", "pallas"):
        raise ValueError(f"kernel must be auto|lax|pallas, got {kernel!r}")
    return kernel


def _cells_fn(batch, steps: int, kmax: int, kind: str, breq: int,
              faulty: bool = False, nb: int = 0, kernel: str = "lax",
              obs: bool = False, read: bool = False):
    """The unjitted whole-batch computation (vmap over cells); shared by
    the single-device jit below and the sharded per-device bodies."""
    if kind == "group":
        return jax.vmap(lambda c: _group_cell(c, steps, kmax, breq,
                                              faulty, nb, kernel,
                                              obs, read))(batch)
    return jax.vmap(lambda c: _epaxos_cell(c, steps, kmax, nb))(batch)


@functools.partial(jax.jit, static_argnames=("steps", "kmax", "kind",
                                             "breq", "faulty", "nb",
                                             "kernel", "obs", "read"))
def _run_cells(batch, steps: int, kmax: int, kind: str, breq: int,
               faulty: bool = False, nb: int = 0, kernel: str = "lax",
               obs: bool = False, read: bool = False):
    sig = (kind, steps, kmax, breq, faulty, nb, kernel, obs, read) + tuple(
        (k,) + tuple(v.shape) for k, v in sorted(batch.items()))
    _TRACE_COUNTS[sig] = _TRACE_COUNTS.get(sig, 0) + 1
    return _cells_fn(batch, steps, kmax, kind, breq, faulty, nb, kernel,
                     obs, read)


def _pad_spec(configs: Sequence[SimConfig], grid) -> Dict[str, int]:
    """The padded-shape signature a (configs, grid) batch compiles under.
    A sharded run computes this ONCE over the whole grid and passes it to
    every chunk's ``_stack_cells`` so all chunks share one compilation."""
    kind = configs[0].kind
    spec = {
        "nreg": max(c.region_latency.shape[0] for c in configs),
        "kmax": max(k for _, k, _ in grid),
        "wmax": max([c.down.shape[1] for c in configs
                     if c.down is not None] + [1]),
    }
    if kind == "group":
        spec["rmax"] = max(c.rmax for c in configs)
        spec["fmax"] = max(c.n - 1 for c in configs)
        spec["nmax"] = 1
        spec["nkeys_max"] = 1   # the group kernel never samples keys
    else:
        spec["rmax"] = spec["fmax"] = 1
        spec["nmax"] = max(c.n for c in configs)
        spec["nkeys_max"] = max(c.n_keys for c in configs)
    return spec


def _stack_cells(configs: Sequence[SimConfig], grid, duration: float,
                 warmup: float, pad_to: Optional[Dict[str, int]] = None):
    """Stack (config_idx, clients, seed) grid points into one batch dict.

    ``pad_to`` (a ``_pad_spec`` dict, possibly from a larger grid) pins the
    padded shapes so different chunks of one sharded run stay signature-
    compatible with each other."""
    kind = configs[0].kind
    if any(c.kind != kind for c in configs):
        raise ValueError("cannot mix group and epaxos kernels in one batch")
    spec = pad_to or _pad_spec(configs, grid)
    nreg = spec["nreg"]
    kmax = spec["kmax"]
    stop = warmup + duration
    cells: Dict[str, list] = {k: [] for k in (
        "sizes", "thresh", "grp", "pos", "gstart", "regF", "reg_lat",
        "leader_reg", "jitter", "costs",
        "majority", "n_groups", "static_relay", "k_clients", "key", "stop",
        "warmup", "duration", "n_followers", "reg_nodes", "fq",
        "w_follower", "downL", "downF", "slowF", "slowL",
        "key_mode", "n_keys", "conflict_rate", "key_cdf", "read_ratio")}
    wmax = spec["wmax"]
    rmax, fmax = spec["rmax"], spec["fmax"]
    nmax, nkeys_max = spec["nmax"], spec["nkeys_max"]
    if kind == "epaxos" and any(c.n != nmax for c in configs):
        raise ValueError("epaxos batches must share one cluster size")
    for ci, k, seed in grid:
        c = configs[ci]
        sizes = np.zeros(rmax, np.int32)
        thresh = np.zeros(rmax, np.int32)
        # flat group-contiguous follower layout (padding at the tail keeps
        # segment scans confined to real slots)
        grp = np.full(fmax, max(rmax - 1, 0), np.int32)
        pos = np.full(fmax, 1, np.int32)      # non-zero: never a segment start
        gstart = np.zeros(rmax, np.int32)
        regf = np.zeros(fmax, np.int32)
        # fault masks in flat-slot layout (inf-padded = never down)
        downf = np.full((fmax, wmax, 2), np.inf, np.float32)
        slowf = np.zeros(fmax, np.float32)
        downl = np.full((wmax, 2), np.inf, np.float32)
        slowl = np.float32(0.0)
        if kind == "group":
            sizes[:c.rmax] = c.sizes
            thresh[:c.rmax] = c.thresh
            off = 0
            for gi in range(c.rmax):
                sz = int(c.sizes[gi])
                grp[off:off + sz] = gi
                pos[off:off + sz] = np.arange(sz)
                gstart[gi] = off
                members = c.members[gi, :sz]
                regf[off:off + sz] = c.region_of[members]
                if c.down is not None:
                    downf[off:off + sz, :c.down.shape[1]] = c.down[members]
                if c.slow is not None:
                    slowf[off:off + sz] = c.slow[members]
                off += sz
            gstart[c.rmax:] = off
            if c.down is not None:
                downl[:c.down.shape[1]] = c.down[0]
            if c.slow is not None:
                slowl = np.float32(c.slow[0])
        rl = np.zeros((nreg, nreg), np.float64)
        nr = c.region_latency.shape[0]
        rl[:nr, :nr] = c.region_latency
        cells["sizes"].append(sizes)
        cells["thresh"].append(thresh)
        cells["grp"].append(grp)
        cells["pos"].append(pos)
        cells["gstart"].append(gstart)
        cells["regF"].append(regf)
        cells["downL"].append(downl)
        cells["downF"].append(downf)
        cells["slowF"].append(slowf)
        cells["slowL"].append(slowl)
        cells["reg_lat"].append(rl.astype(np.float32))
        cells["leader_reg"].append(np.int32(c.region_of[0]))
        cells["jitter"].append(np.float32(c.jitter))
        if kind == "group":
            order = ("c_req", "c_fanout", "c_rel", "c_repl", "c_agg",
                     "c_replycl")
        else:
            order = ("c_req", "c_pa", "c_par", "c_com", "c_replycl",
                     "c_acc", "c_accr")
        cells["costs"].append(np.asarray([c.costs[o] for o in order],
                                         np.float32))
        cells["key_mode"].append(np.int32(c.key_mode))
        cells["n_keys"].append(np.int32(c.n_keys if kind == "epaxos" else 1))
        cells["conflict_rate"].append(np.float32(c.conflict_rate))
        cdf = np.ones(nkeys_max, np.float32)
        if kind == "epaxos" and c.key_cdf is not None:
            cdf[:len(c.key_cdf)] = np.asarray(c.key_cdf, np.float32)
        cells["key_cdf"].append(cdf)
        cells["majority"].append(np.int32(c.majority))
        cells["n_groups"].append(np.int32(int((c.sizes > 0).sum())))
        cells["static_relay"].append(np.bool_(c.static_relay))
        cells["k_clients"].append(np.int32(k))
        cells["key"].append(np.asarray(
            jax.random.PRNGKey(int(seed) * 1_000_003 + ci)))
        cells["stop"].append(np.float32(stop))
        cells["warmup"].append(np.float32(warmup))
        cells["duration"].append(np.float32(duration))
        cells["n_followers"].append(np.int32(c.n - 1))
        if kind == "group":
            szs = c.sizes[c.sizes > 0].astype(float)
            wf = (len(szs) * (c.costs["c_fanout"] + c.costs["c_agg"])
                  + 2.0 * float((szs - 1).sum())
                  * (c.costs["c_rel"] + c.costs["c_repl"])) / max(c.n - 1, 1)
            # leased reads add no follower work: the utilization estimate
            # sees per-op work scaled to the write fraction
            wf *= 1.0 - c.read_ratio
        else:
            wf = 0.0
        cells["w_follower"].append(np.float32(wf))
        cells["read_ratio"].append(np.float32(c.read_ratio))
        cells["reg_nodes"].append(
            np.asarray(c.region_of[:nmax] if kind == "epaxos"
                       else np.zeros(1), np.int32))
        cells["fq"].append(np.int32(fast_quorum(c.n)))
    batch = {k: np.stack(v) for k, v in cells.items()}
    return batch, kind, kmax


def simulate_grid(configs: Sequence[SimConfig], grid, duration: float,
                  warmup: float, steps: Optional[int] = None,
                  timeline: bool = False,
                  kernel: str = "auto",
                  obs: bool = False) -> Dict[str, np.ndarray]:
    """Run every (config_idx, clients, seed) grid point in ONE jitted call.

    Returns dict of per-cell arrays (throughput, median_s, p99_s, committed,
    m_leader, m_follower, ...).  Step budgets are per cell: the first call
    uses the grid max (so unexhausted grids stay one compiled call), and
    when the optimistic rate bound underestimates some cells, ONLY the
    exhausted subset re-runs with a doubled budget — finished cells keep
    their first-pass results, which are bit-identical to what a full-grid
    retry would produce (extra scan steps past the stop time are no-ops).
    ``out["steps"]`` records each cell's final budget.

    ``timeline=True`` (implied by fault-mask configs) adds per-cell
    completion timelines (``_TL_BUCKET`` buckets).

    ``obs=True`` (group kernel only) adds the per-cell leader-backlog
    series (``leader_backlog_s`` / ``leader_backlog_n``; see
    ``_group_cell``) on the same buckets.

    ``kernel`` selects the group fan-in implementation ("auto" | "lax" |
    "pallas"; see ``_group_cell``) — "auto" picks the Pallas kernel on TPU
    and the XLA sort path elsewhere.
    """
    batch, kind, kmax = _stack_cells(configs, grid, duration, warmup)
    kernel = _resolve_kernel(kernel, kind)
    if obs and kind != "group":
        raise ValueError("obs timelines are group-kernel only — the epaxos "
                         "kernel has no single-leader FIFO to observe")
    faulty = any(c.down is not None or c.slow is not None for c in configs)
    read = any(c.read_ratio > 0.0 for c in configs)
    nb = (int(np.ceil((warmup + duration + _DRAIN_S) / _TL_BUCKET)) + 1
          if (faulty or timeline or obs) else 0)
    if steps is None:
        # requests are only issued inside [0, stop); the rate bound is
        # optimistic, and the exhausted-retry loop below is the safety net
        rate = max(_estimate_rate(configs[ci], k) for ci, k, _ in grid)
        steps = int(rate * (warmup + duration) * 1.15) + kmax + 64
    steps = min(steps, _MAX_STEPS)
    # the group kernel pops `breq` requests per scan step
    breq = min(8, kmax) if kind == "group" else 1
    out = _run_cells(batch, -(-steps // breq), kmax, kind, breq, faulty, nb,
                     kernel, obs, read)
    out = {k: np.asarray(v) for k, v in out.items()}
    steps_arr = np.full(len(grid), steps, np.int32)
    if out["exhausted"].any():
        out = {k: np.array(v) for k, v in out.items()}   # writable for merge
    while out["exhausted"].any() and steps < _MAX_STEPS:
        steps = min(steps * 2, _MAX_STEPS)
        idx = np.nonzero(out["exhausted"])[0]
        sub = {k: v[idx] for k, v in batch.items()}
        sub_out = _run_cells(sub, -(-steps // breq), kmax, kind, breq,
                             faulty, nb, kernel, obs, read)
        for k, v in sub_out.items():
            out[k][idx] = np.asarray(v)
        steps_arr[idx] = steps
    out["steps"] = steps_arr
    return out


# ================================================================= sharding
# compiled sharded runners, keyed by the full static signature (shapes,
# step budget, device count, impl) — chunks of one sharded run hit the
# same entry, so compile cost amortizes across the whole grid
_SHARD_CACHE: Dict[tuple, object] = {}


def _run_cells_sharded(batch, steps: int, kmax: int, kind: str, breq: int,
                       faulty: bool, nb: int, kernel: str,
                       devices, impl: str, read: bool = False):
    """One chunk through the device-sharded runner.  The cell axis (every
    leaf's leading axis) is split evenly across ``devices`` — cell count
    must be a multiple of the device count.  Inputs are DONATED: chunked
    callers stream results to host, so device memory stays bounded by one
    chunk regardless of grid size."""
    D = len(devices)
    shapes = tuple((k,) + tuple(v.shape) + (str(np.asarray(v).dtype),)
                   for k, v in sorted(batch.items()))
    sig = (kind, steps, kmax, breq, faulty, nb, kernel, D, impl,
           read) + shapes
    fn = _SHARD_CACHE.get(sig)
    if fn is None:
        def body(b):
            return _cells_fn(b, steps, kmax, kind, breq, faulty, nb,
                             kernel, read=read)
        if impl == "shard_map":
            mesh = Mesh(np.asarray(devices), ("cells",))
            fn = jax.jit(_shard_map(body, mesh=mesh,
                                    in_specs=PartitionSpec("cells"),
                                    out_specs=PartitionSpec("cells"),
                                    check_rep=False),
                         donate_argnums=0)
        elif impl == "pmap":
            pfn = jax.pmap(body, devices=devices, donate_argnums=0)

            def fn(b, _p=pfn, _D=D):
                split = {k: v.reshape((_D, v.shape[0] // _D) + v.shape[1:])
                         for k, v in b.items()}
                out = _p(split)
                return {k: v.reshape((-1,) + v.shape[2:])
                        for k, v in out.items()}
        else:
            raise ValueError(f"impl must be shard_map|pmap, got {impl!r}")
        _SHARD_CACHE[sig] = fn
    with warnings.catch_warnings():
        # scalar per-cell inputs can never be reused for the (bigger)
        # outputs; the donation of the large mask/key arrays is what counts
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        return fn(batch)


def simulate_grid_sharded(configs: Sequence[SimConfig], grid,
                          duration: float, warmup: float, *,
                          steps: Optional[int] = None,
                          timeline: bool = False, kernel: str = "auto",
                          chunk: int = 4096, devices=None,
                          impl: str = "auto") -> Dict[str, np.ndarray]:
    """``simulate_grid`` scaled out: the cell grid is partitioned across
    devices (``shard_map``; ``impl="pmap"`` fallback) and dispatched in
    fixed-size chunks whose inputs are donated, so device memory is
    bounded by one chunk and one compilation serves every chunk (the
    padded-shape signature is pinned grid-wide via ``_pad_spec``).

    On this CPU-only container, multi-device execution is exercised via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set before the
    process imports jax); on a real GPU/TPU host the same call sharding
    applies with no code change — device count comes from
    ``jax.devices()``.  Per-cell results are bit-identical to
    single-device ``simulate_grid`` (cells are independent vmap lanes).

    Returns the ``simulate_grid`` dict plus ``out["sharding"]``: device
    count, impl, chunk size, and per-chunk {cells, wall_s, steps} — the
    stream the megagrid study and the bench schema consume.
    """
    devices = list(devices if devices is not None else jax.devices())
    if impl == "auto":
        impl = "shard_map" if _shard_map is not None else "pmap"
    D = len(devices)
    chunk = max(chunk - chunk % D, D)
    kind = configs[0].kind
    kernel = _resolve_kernel(kernel, kind)
    spec = _pad_spec(configs, grid)
    faulty = any(c.down is not None or c.slow is not None for c in configs)
    read = any(c.read_ratio > 0.0 for c in configs)
    nb = (int(np.ceil((warmup + duration + _DRAIN_S) / _TL_BUCKET)) + 1
          if (faulty or timeline) else 0)
    if steps is None:
        rate = max(_estimate_rate(configs[ci], k) for ci, k, _ in grid)
        steps = int(rate * (warmup + duration) * 1.15) + spec["kmax"] + 64
    steps0 = min(steps, _MAX_STEPS)
    breq = min(8, spec["kmax"]) if kind == "group" else 1

    n_cells = len(grid)
    out: Dict[str, np.ndarray] = {}
    steps_arr = np.empty(n_cells, np.int32)
    meta = []
    for lo in range(0, n_cells, chunk):
        part = list(grid[lo:lo + chunk])
        real = len(part)
        part += [part[-1]] * (chunk - real)   # keep one static shape
        batch, _, _ = _stack_cells(configs, part, duration, warmup,
                                   pad_to=spec)
        t0 = time.perf_counter()
        steps_c = steps0
        cout = _run_cells_sharded(batch, -(-steps_c // breq), spec["kmax"],
                                  kind, breq, faulty, nb, kernel, devices,
                                  impl, read)
        cout = {k: np.array(v) for k, v in cout.items()}
        csteps = np.full(chunk, steps_c, np.int32)
        while cout["exhausted"][:real].any() and steps_c < _MAX_STEPS:
            steps_c = min(steps_c * 2, _MAX_STEPS)
            idx = np.nonzero(cout["exhausted"])[0]
            # retry the exhausted subset, padded back to a device multiple
            ridx = np.resize(idx, -(-len(idx) // D) * D)
            sub = {k: v[ridx] for k, v in batch.items()}
            sub_out = _run_cells_sharded(sub, -(-steps_c // breq),
                                         spec["kmax"], kind, breq, faulty,
                                         nb, kernel, devices, impl, read)
            for k, v in sub_out.items():
                cout[k][idx] = np.asarray(v)[:len(idx)]
            csteps[idx] = steps_c
        wall = time.perf_counter() - t0
        for k, v in cout.items():
            if k not in out:
                out[k] = np.empty((n_cells,) + v.shape[1:], v.dtype)
            out[k][lo:lo + real] = v[:real]
        steps_arr[lo:lo + real] = csteps[:real]
        meta.append({"cells": real, "wall_s": wall,
                     "steps": int(csteps[:real].max())})
    out["steps"] = steps_arr
    out["sharding"] = {"devices": D, "impl": impl, "kernel": kernel,
                       "chunk": chunk, "chunks": meta}
    return out


def simulate_scenario(protocol: str, n: int, *, pig=None, topo=None,
                      workload=None, clients: Sequence[int] = (60,),
                      seeds: Sequence[int] = (0,), duration: float = 0.6,
                      warmup: float = 0.3, leader_timeout: float = 50e-3,
                      masks: Optional[Dict[str, np.ndarray]] = None,
                      kernel: str = "auto", batch_m: int = 1,
                      obs: bool = False) -> List[dict]:
    """One scenario's full clients x seeds grid in one compiled call.

    Returns one dict per (clients, seed) in ``runner`` unit order, carrying
    the same measurement fields as a DES ``Cluster.measure`` run.

    ``retry_risk`` marks cells whose p99 latency reaches the leader timeout:
    there the real protocol starts re-proposing slots (extra load the
    timeout-free batch model does not simulate), so DES throughput can
    collapse below the batch prediction — treat those cells as the model's
    validity boundary, not as measurements.  (Fault-mask runs routinely
    trip it: a deferred commit's latency spans the down-window by design.)

    ``masks`` enables the fault path (``FaultPlan.to_masks``); fault units
    additionally carry a completion ``timeline`` in the DES extras format.

    ``batch_m`` > 1 runs the leader-batching model: every ``batch_m``
    clients share one slot (one kernel lane carries a whole batch, with the
    per-batch cost reparameterization of ``build_config``), so client
    counts must divide evenly; throughput/count/committed scale back up by
    m, and latencies are corrected by the mean reply-serialization rank
    ((m-1)/2 per-reply CPU slots — the model charges every sub-command the
    LAST reply's completion).  This models saturated full batches; the
    partial-batch `max_delay` regime is DES-authoritative.  Pipelined slot
    occupancy is inherent here: the Lindley-chain leader FIFO admits new
    slots while earlier ones are in flight, i.e. the DES default
    ``pipeline_depth=0`` (unbounded); finite-depth throttles are
    DES-authoritative too.

    ``obs=True`` (group kernel only) adds a batch-side observability
    extra to every unit: the leader-backlog series sampled at request
    arrivals (mean queueing wait per ``_TL_BUCKET`` bucket + sample
    counts) — the counterpart of the DES timeline sampler's queue-depth
    gauges.  Full span tracing is DES-only.
    """
    cfg = build_config(protocol, n, pig=pig, topo=topo, workload=workload,
                       masks=masks, batch_m=batch_m)
    m = int(batch_m)
    if m > 1:
        for k in clients:
            if int(k) % m:
                raise ValueError(f"clients={k} not divisible by "
                                 f"batch_m={m}: one kernel lane carries a "
                                 f"whole batch of {m} clients")
    grid = [(0, int(k) // m, int(s)) for k in clients for s in seeds]
    out = simulate_grid([cfg], grid, duration, warmup, kernel=kernel,
                        obs=obs)
    # mean reply rank correction (seconds); 0 when unbatched
    lat_adj = 0.0 if m == 1 else (m - 1) / 2.0 * (cfg.costs["c_replycl"] / m)
    units = []
    kidx = [int(k) for k in clients for _ in seeds]
    sidx = [int(s) for _ in clients for s in seeds]
    for i, (k, s) in enumerate(zip(kidx, sidx)):
        u = {
            "retry_risk": bool(out["p99_s"][i] - lat_adj >= leader_timeout),
            "clients": k, "seed": s,
            "throughput": float(out["throughput"][i]) * m,
            "mean_ms": float(out["mean_s"][i] - lat_adj) * 1e3,
            "median_ms": float(out["median_s"][i] - lat_adj) * 1e3,
            "p25_ms": float(out["p25_s"][i] - lat_adj) * 1e3,
            "p75_ms": float(out["p75_s"][i] - lat_adj) * 1e3,
            "p99_ms": float(out["p99_s"][i] - lat_adj) * 1e3,
            "count": int(out["count"][i]) * m,
            "committed": int(out["committed"][i]) * m,
            "leader_msgs_per_op": float(out["m_leader"][i]) / m,
            "follower_msgs_per_op": float(out["m_follower"][i]) / m,
            "exhausted": bool(out["exhausted"][i]),
        }
        if "timeline" in out:
            u["timeline"] = {"bucket_s": _TL_BUCKET,
                             "counts": out["timeline"][i].tolist()}
        if "leader_backlog_s" in out:
            u["obs"] = {"leader_backlog": {
                "bucket_s": _TL_BUCKET,
                "mean_ms": [round(float(v) * 1e3, 6)
                            for v in out["leader_backlog_s"][i]],
                "n": out["leader_backlog_n"][i].tolist()}}
        if "read_count" in out:
            # leased-read split (DES counterpart: Cluster.read_write_split)
            u["rw"] = {
                "reads": int(out["read_count"][i]),
                "writes": int(out["write_count"][i]),
                "read_mean_ms": float(out["read_mean_s"][i]) * 1e3,
                "write_mean_ms": float(out["write_mean_s"][i]) * 1e3,
                "read_p99_ms": float(out["read_p99_s"][i]) * 1e3,
            }
        units.append(u)
    return units
