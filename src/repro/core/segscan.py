"""Segmented-scan primitives over flat group-contiguous layouts.

The batch backend packs every config's follower groups on one FLAT axis
(slots group-contiguous; ``pos == 0`` marks each segment's first slot) so a
heterogeneous config batch costs O(N-1) per step instead of O(rmax x gmax)
padding.  Per-group order statistics then reduce to *segmented* cumulative
scans along that axis: an associative scan over (value, start-flag) pairs
where the flag resets the running aggregate at every segment boundary.

Shared by ``core.vectorsim`` (the production fan-in path) and the Pallas
segmented fan-in kernel's oracle (``kernels.ref.seg_fanin_ref``), so the
two backends agree on one definition of the scan semantics.

All functions take ``first`` — a boolean mask marking segment starts,
broadcastable against ``x`` (vectorsim passes its precomputed ``seg_first``
instead of recomputing ``pos == 0`` at each call site).
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def seg_cummax(x: jnp.ndarray, first: jnp.ndarray, axis: int = -1):
    """Within-segment inclusive cumulative max along ``axis``."""
    def comb(a, b):
        v1, f1 = a
        v2, f2 = b
        return jnp.where(f2, v2, jnp.maximum(v1, v2)), f1 | f2

    first = jnp.broadcast_to(first, x.shape)
    v, _ = lax.associative_scan(comb, (x, first), axis=axis)
    return v


def seg_cumsum(x: jnp.ndarray, first: jnp.ndarray, axis: int = -1):
    """Within-segment inclusive cumulative sum along ``axis``."""
    def comb(a, b):
        v1, f1 = a
        v2, f2 = b
        return jnp.where(f2, v2, v1 + v2), f1 | f2

    first = jnp.broadcast_to(first, x.shape)
    v, _ = lax.associative_scan(comb, (x, first), axis=axis)
    return v


def seg_start_index(first: jnp.ndarray, axis: int = -1):
    """Index of each slot's segment start (the ``gstart`` of its group),
    derived from the start flags alone — the oracle-side inverse of the
    packed ``gstart`` table."""
    n = first.shape[axis]
    shape = [1] * first.ndim
    shape[axis] = n
    iota = jnp.arange(n, dtype=jnp.float32).reshape(shape)
    iota = jnp.broadcast_to(iota, first.shape)
    return seg_cummax(jnp.where(first, iota, -jnp.inf), first,
                      axis=axis).astype(jnp.int32)
