"""Analytical bottleneck model from §6.1 / §6.4 / §6.5 of the paper.

  M_l = 2R + 2                                  (Eq. 1)
  M_f = 2 (R/(N-1)) ((N-R-1)/R) + 2
      = 2 (N-R-1)/(N-1) + 2                     (Eq. 2-3)
  total messages per round = 2N - 1             (§6.4, R-independent)

R = N-1 degenerates to classical Multi-Paxos (M_l = 2N, but the paper's
Table 1 lists 2(N-1)+2 = 50 for N=25 — client messages included).
"""
from __future__ import annotations

import numpy as np

from .quorums import fast_quorum


def leader_messages(r: int) -> float:
    """Messages handled by the leader per request, client I/O included."""
    return 2 * r + 2


def follower_messages(n: int, r: int) -> float:
    """Amortized messages per follower per request under relay rotation."""
    return 2 * (n - r - 1) / (n - 1) + 2


def relay_messages(n: int, r: int) -> float:
    """Messages at a node *while it serves as relay* (group size (N-1)/R):
    1 fanout in + 1 aggregate out + round trip with each group peer."""
    g = (n - 1) / r
    return 2 + 2 * (g - 1)


def epaxos_messages(n: int) -> float:
    """Per-node messages/request on the EPaxos conflict-free fast path,
    client I/O included (all nodes symmetric, §5.3): PreAccept + reply with
    the fast quorum (each message counted at both endpoints), the commit
    broadcast to the other N-1 replicas, and the client request/reply pair
    at the command leader — averaged over the N replicas."""
    fq = fast_quorum(n)
    return (2.0 * (fq - 1) * 2 + (n - 1) * 2 + 2) / n


def total_messages_per_round(n: int) -> int:
    """2N-1: R messages leader->relays + 1 client reply + per relay
    ((N-R-1)/R relays + 1 aggregate) + 1 message per plain follower (§6.4)."""
    return 2 * n - 1


def load_table(n: int, rs: list[int] | None = None) -> list[dict]:
    """Reproduces Table 1 (n=25) / Table 2 (n=5)."""
    if rs is None:
        rs = [1, 2, 3, 4, 5, 6, n - 1] if n > 9 else [1, 2, n - 1]
    rows = []
    for r in rs:
        ml = leader_messages(r)
        mf = follower_messages(n, r) if r < n - 1 else 2.0
        rows.append({
            "R": r,
            "M_l": ml,
            "M_f": round(mf, 2),
            "ratio": round(ml / mf, 3),
            "label": "Paxos" if r == n - 1 else "PigPaxos",
        })
    return rows


def static_relay_load(n: int, r: int) -> float:
    """Without rotation the relay pays the full group cost every round:
    M_relay = 2 + 2((N-1)/R - 1).  √N groups equalize leader & relay load
    for static relays (§5.2): 2R+2 = 2(N-1)/R  =>  R ≈ √(N-1)."""
    return relay_messages(n, r)


def best_r_static(n: int) -> int:
    """argmin over R of max(leader, static relay) message load."""
    rs = range(1, n)
    return min(rs, key=lambda r: max(leader_messages(r), static_relay_load(n, r)))


def best_r_rotating(n: int) -> int:
    """argmin over R of max(leader, amortized follower) load — always 1 (§6.5)."""
    rs = range(1, n)
    return min(rs, key=lambda r: max(leader_messages(r), follower_messages(n, r)))


def saturation_throughput(n: int, r: int, cpu_per_msg: float,
                          rotating: bool = True) -> float:
    """Upper-bound throughput: the busiest node's CPU is the bottleneck.
    Maps message counts to req/s via the per-message CPU cost (§2.2)."""
    if rotating:
        hottest = max(leader_messages(r), follower_messages(n, r))
    else:
        hottest = max(leader_messages(r), static_relay_load(n, r))
    return 1.0 / (hottest * cpu_per_msg)
