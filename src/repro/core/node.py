"""Node runtime: mailbox dispatch, timers, crash/recover, KV state machine."""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional

from .events import Scheduler
from .messages import Command, Msg
from .network import Network


class KVStore:
    """The in-memory key-value state machine (mirrors Paxi's internal store)."""

    __slots__ = ("data", "applied_ops")

    def __init__(self):
        self.data: Dict[int, bytes] = {}
        self.applied_ops = 0

    def apply(self, cmd: Command) -> Optional[bytes]:
        self.applied_ops += 1
        if cmd.op == "put":
            self.data[cmd.key] = cmd.value
            return None
        return self.data.get(cmd.key)


class Node:
    """Base class: protocol nodes subclass and add ``on_<MsgType>`` handlers.

    Handler dispatch is cached per message class in ``_dispatch`` — the fused
    engine loop (network.Network._run) calls the bound handler directly,
    skipping the per-message ``getattr("on_" + kind)`` of the seed engine.
    """

    def __init__(self, node_id: int, net: Network, sched: Scheduler):
        self.id = node_id
        self.net = net
        self.sched = sched
        self.crashed = False
        self.store = KVStore()
        self.applied_log: list = []   # sequence of (slot/inst, command) applied
        self._dispatch: dict = {}     # msg class -> bound on_* handler
        # bound fast path: self.send(dst, msg) == net.send(self.id, dst, msg)
        self.send = partial(net.send, node_id)
        net.register(node_id, self)

    # ------------------------------------------------------------ transport
    def _bind_handler(self, cls):
        name = getattr(cls, "_kind_name", None) or cls.__name__
        h = getattr(self, "on_" + name, None)
        if h is None:
            raise RuntimeError(f"{type(self).__name__} has no handler for {name}")
        self._dispatch[cls] = h
        return h

    def deliver(self, msg: Msg) -> None:
        """Seed-compatible entry point (used by refengine and tests); the
        fused loop inlines the crash check and dispatch instead."""
        if self.crashed:
            return
        cls = msg.__class__
        h = self._dispatch.get(cls)
        if h is None:
            h = self._bind_handler(cls)
        h(msg)

    # ------------------------------------------------------------ timers
    def set_timer(self, delay: float, fn) -> int:
        def _fire():
            if not self.crashed:
                fn()
        return self.sched.after(delay, _fire)

    def cancel_timer(self, timer_id: int) -> None:
        self.sched.cancel(timer_id)

    # ------------------------------------------------------------ failure
    def crash(self) -> None:
        self.crashed = True

    def recover(self) -> None:
        self.crashed = False
