"""Node runtime: mailbox dispatch, timers, crash/recover, KV state machine."""
from __future__ import annotations

from typing import Dict, Optional

from .events import Scheduler
from .messages import Command, Msg
from .network import Network


class KVStore:
    """The in-memory key-value state machine (mirrors Paxi's internal store)."""

    __slots__ = ("data", "applied_ops")

    def __init__(self):
        self.data: Dict[int, bytes] = {}
        self.applied_ops = 0

    def apply(self, cmd: Command) -> Optional[bytes]:
        self.applied_ops += 1
        if cmd.op == "put":
            self.data[cmd.key] = cmd.value
            return None
        return self.data.get(cmd.key)


class Node:
    """Base class: protocol nodes subclass and add ``on_<MsgType>`` handlers."""

    def __init__(self, node_id: int, net: Network, sched: Scheduler):
        self.id = node_id
        self.net = net
        self.sched = sched
        self.crashed = False
        self.store = KVStore()
        self.applied_log: list = []   # sequence of (slot/inst, command) applied
        net.register(node_id, self)

    # ------------------------------------------------------------ transport
    def send(self, dst: int, msg: Msg) -> None:
        self.net.send(self.id, dst, msg)

    def deliver(self, msg: Msg) -> None:
        if self.crashed:
            return
        handler = getattr(self, "on_" + msg.kind, None)
        if handler is None:
            raise RuntimeError(f"{type(self).__name__} has no handler for {msg.kind}")
        handler(msg)

    # ------------------------------------------------------------ timers
    def set_timer(self, delay: float, fn) -> int:
        def _fire():
            if not self.crashed:
                fn()
        return self.sched.after(delay, _fire)

    def cancel_timer(self, timer_id: int) -> None:
        self.sched.cancel(timer_id)

    # ------------------------------------------------------------ failure
    def crash(self) -> None:
        self.crashed = True

    def recover(self) -> None:
        self.crashed = False
