"""Simulated transport: per-link latency + per-node CPU service queues.

Model (matches the paper's observed bottleneck, §2.2):
  send(msg):  src CPU busy for cost(msg)   (serialize)
              -> link latency L(src,dst)   (propagation + jitter)
              -> dst CPU busy for cost(msg) (deserialize + handle)
              -> dst handler runs

Each node's CPU is a single FIFO server; leader saturation emerges naturally
when its CPU utilization approaches 1.  Message counts per (src,dst) and per
node are recorded to validate the analytical model (Table 1/2) and to draw
the in-flight heatmap (Fig 17).

Engine notes (the seed implementation is preserved in ``refengine.py``):

  * The three stages of a hop are slab events (see events.py) executed by
    the fused loop in :meth:`Network._run` — no closures, no per-event
    Python function call, no numpy scalars on the hot path.  Event times,
    tie-break order, and RNG consumption are identical to the seed engine;
    tests/test_golden_trace.py enforces this.
  * ``fast_path=True`` flattens each hop into a single delivery event whose
    CPU-queue start times are precomputed at send time (latency drawn and
    partitions checked at send instead of at serialize-done).  ~3x fewer
    heap operations; aggregate statistics (throughput, utilization, message
    counts) are preserved but traces are *not* bit-identical to the seed —
    use it for large-N sweeps, never for golden-trace comparisons.
  * Accounting uses plain Python ints (lists + a sparse flight dict); the
    numpy views are materialized lazily via properties.  Set
    ``accounting=False`` to skip it entirely in the hot loop.
"""
from __future__ import annotations

import gc
import heapq
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from .events import (K_ARRIVE, K_CALL, K_DELIVER, K_HANDLE, K_TRANSMIT,
                     Scheduler)
from .messages import CostModel, Msg

_INF = float("inf")


@dataclass
class Topology:
    """Latency model. ``region_of`` maps node id -> region index;
    ``rtt_matrix[r1][r2]`` is the one-way base latency between regions."""
    n: int
    base_latency: float = 0.25e-3          # LAN one-way
    jitter: float = 0.05e-3
    region_of: Optional[list] = None
    region_latency: Optional[np.ndarray] = None   # one-way seconds

    def latency(self, rng: np.random.Generator, src: int, dst: int) -> float:
        return self.base_between(src, dst) + rng.exponential(self.jitter)

    def base_between(self, src: int, dst: int) -> float:
        """Deterministic part of :meth:`latency` (no jitter draw).
        Endpoints >= n are clients: co-located with the leader's region
        (region 0), as in the paper's WAN setup (§5.3)."""
        if self.region_of is None:
            return self.base_latency
        rs = self.region_of[src] if src < self.n else 0
        rd = self.region_of[dst] if dst < self.n else 0
        return float(self.region_latency[rs][rd])


def wan_topology(nodes_per_region: list[int], oneway_ms: list[list[float]]) -> Topology:
    region_of = []
    for r, k in enumerate(nodes_per_region):
        region_of += [r] * k
    return Topology(
        n=len(region_of),
        jitter=0.05e-3,
        region_of=region_of,
        region_latency=np.asarray(oneway_ms) * 1e-3,
    )


class Network:
    """Transport + CPU queues + failure injection + accounting."""

    def __init__(self, sched: Scheduler, topo: Topology,
                 cost: CostModel | None = None, fast_path: bool = False):
        self.sched = sched
        sched._net = self              # sched.run() degrades to our fused loop
        self.topo = topo
        self.cost = cost or CostModel()
        self.fast_path = fast_path
        self.n_servers = topo.n        # ids >= n are clients (free CPUs)
        cap = topo.n + 1024            # room for client endpoints (ids >= n)
        self._cap = cap
        self.nodes: list = [None] * cap          # id -> node (has ._dispatch & .crashed)
        self.cpu_free: list = [0.0] * cap        # id -> time CPU becomes free
        self._cpu_busy: list = [0.0] * cap       # id -> total busy seconds
        self._msgs_out: list = [0] * cap
        self._msgs_in: list = [0] * cap
        # deferred send accounting: the hot path appends one encoded int per
        # send ((src << 20) | dst); _materialize() folds the log into
        # _msgs_out/_flight when stats are actually read
        self._send_log: list = []
        self._flight: dict = {}                  # (src<<20|dst) -> count
        self._fixed = self.cost._fixed           # class -> constant cpu cost
        self.partitioned: set[Tuple[int, int]] = set()
        # per-node link degradation (gray/slow nodes, repro.faults):
        # node -> (extra_latency_s, latency_factor, drop_prob), applied to
        # every hop touching the node.  Mutated in place by degrade/restore
        # so the fused loops' captured reference stays live (same pattern as
        # ``partitioned``); the empty-dict truthiness check keeps the
        # fault-free hot path unchanged.
        self._degraded: dict = {}
        self.accounting = True
        # fast-path jitter presampling: one rng call per hop is ~15% of the
        # flattened loop, so draw Exp(jitter) in blocks and hand out plain
        # Python floats.  The fast path is already not bit-identical to the
        # exact engine, so consuming the RNG in blocks is fair game (the
        # exact engine keeps its per-hop draws — golden traces depend on it).
        self._jitter_block: list = []
        self._jitter_idx = 0
        # observability (repro.obs): ``tracer`` collects per-op span trees
        # (purely observational — no events, no RNG, no message mutation, so
        # golden traces hold even with tracing on); ``obs`` is the Timelines
        # registry whose ring buffers reset with the rest of the stats at
        # the warmup boundary.  Both None unless Cluster(obs=...) wired them.
        self.tracer = None
        self.obs = None

    _JITTER_BLOCK = 4096

    def _next_jitter(self, rng, scale: float) -> float:
        i = self._jitter_idx
        block = self._jitter_block
        if i >= len(block):
            block = rng.exponential(scale, self._JITTER_BLOCK).tolist()
            self._jitter_block = block
            i = 0
        self._jitter_idx = i + 1
        return block[i]

    def register(self, node_id: int, node) -> None:
        if node_id >= self._cap:
            grow = node_id + 256 - self._cap
            self.nodes.extend([None] * grow)
            self.cpu_free.extend([0.0] * grow)
            self._cpu_busy.extend([0.0] * grow)
            self._msgs_out.extend([0] * grow)
            self._msgs_in.extend([0] * grow)
            self._cap = node_id + 256
        self.nodes[node_id] = node

    # -------------------------------------------------------------- failure
    def partition(self, a: int, b: int) -> None:
        self.partitioned.add((a, b))
        self.partitioned.add((b, a))

    def heal(self, a: int, b: int) -> None:
        self.partitioned.discard((a, b))
        self.partitioned.discard((b, a))

    def partition_oneway(self, a: int, b: int) -> None:
        """Asymmetric cut: a's messages to b are lost, b -> a still flows."""
        self.partitioned.add((a, b))

    def heal_oneway(self, a: int, b: int) -> None:
        self.partitioned.discard((a, b))

    def degrade(self, node: int, extra_latency: float = 0.0,
                factor: float = 1.0, drop_prob: float = 0.0) -> None:
        """Gray/slow node (§4.2 failure model): every hop touching ``node``
        pays ``latency * factor + extra_latency`` and is dropped with
        probability ``drop_prob``.  One degradation state per node — a new
        call replaces the previous one."""
        self._degraded[node] = (float(extra_latency), float(factor),
                                float(drop_prob))

    def restore(self, node: int) -> None:
        self._degraded.pop(node, None)

    def _degraded_latency(self, src: int, dst: int, lat: float, rng) -> float:
        """Latency for a hop with a degraded endpoint; -1.0 means dropped.
        The drop draw consumes the sim RNG only on degraded hops."""
        ds = self._degraded.get(src)
        dd = self._degraded.get(dst)
        drop = (ds[2] if ds else 0.0) + (dd[2] if dd else 0.0)
        if drop > 0.0 and rng.random() < drop:
            return -1.0
        if ds is not None:
            lat = lat * ds[1] + ds[0]
        if dd is not None:
            lat = lat * dd[1] + dd[0]
        return lat

    # -------------------------------------------------------------- send
    def send(self, src: int, dst: int, msg: Msg) -> None:
        msg.src = src
        node_src = self.nodes[src]
        if node_src is not None and node_src.crashed:
            return
        c = msg._cost
        if c < 0.0:
            c = self._fixed.get(msg.__class__)
            if c is None:
                c = self.cost.cpu_cost(msg)
        if self.accounting:
            self._send_log.append((src << 20) | dst)
        sched = self.sched
        if self.fast_path:
            self._send_fast(src, dst, msg, c, sched)
            return
        # serialize on the sender's CPU (clients, id >= n, have free CPUs)
        if src < self.n_servers:
            free = self.cpu_free[src]
            now = sched.now
            start = now if now > free else free
            done = start + c
            self.cpu_free[src] = done
            self._cpu_busy[src] += c
            tr = self.tracer
            if tr is not None:
                ctx = msg._tctx or tr.cur
                if ctx is not None:
                    tr.attach(msg, ctx)
                    tr.add_span(ctx, "ser", src, start, done)
        else:
            done = sched.now
            tr = self.tracer
            if tr is not None:
                ctx = msg._tctx or tr.cur
                if ctx is not None:
                    tr.attach(msg, ctx)
        sched._seq = seq = sched._seq + 1
        heapq.heappush(sched._heap, (done, seq, K_TRANSMIT, src, dst, msg, c))

    def _send_fast(self, src: int, dst: int, msg: Msg, c: float,
                   sched: Scheduler) -> None:
        """Flattened hop: ONE heap event per message.

        Serialize-reservation, partition check, and the latency draw all
        happen inline at send time; the single K_DELIVER event fires at the
        *arrival* time, where the loop reserves the receiver's CPU slot
        (preserving FIFO arrival-order queueing — reserving at send time
        would queue the receiver's own sends behind not-yet-arrived traffic)
        and runs the handler immediately with ``now`` advanced to the
        service-completion time.  Handler order per node and all CPU-queue
        occupancy match the exact engine; only the fine-grained interleaving
        across nodes (and hence RNG order) differs.
        """
        now = sched.now
        if src < self.n_servers:
            free = self.cpu_free[src]
            start = now if now > free else free
            done = start + c
            self.cpu_free[src] = done
            self._cpu_busy[src] += c
        else:
            done = now
        if self.partitioned and (src, dst) in self.partitioned:
            return
        topo = self.topo
        base = (topo.base_latency if topo.region_of is None
                else topo.base_between(src, dst))
        lat = base + self._next_jitter(sched.rng, topo.jitter)
        deg = self._degraded
        if deg and (src in deg or dst in deg):
            lat = self._degraded_latency(src, dst, lat, sched.rng)
            if lat < 0.0:
                return                     # dropped by a lossy gray node
        arrive = done + lat
        tr = self.tracer
        if tr is not None:
            ctx = msg._tctx or tr.cur
            if ctx is not None:
                tr.attach(msg, ctx)
                if src < self.n_servers:
                    tr.add_span(ctx, "ser", src, done - c, done)
                tr.add_span(ctx, "net", src, done, arrive)
        sched._seq = seq = sched._seq + 1
        heapq.heappush(sched._heap, (arrive, seq, K_DELIVER, dst, msg, c, None))

    # -------------------------------------------------------------- engine
    def _run(self, until: float, max_events: Optional[int]) -> int:
        """Fused event loop: executes message stages inline (no per-event
        Python call) and K_CALL timers via the scheduler slab.

        Semantics are identical to refengine.RefScheduler.run driving
        refengine.RefNetwork's closure chain (same times, same tie-breaks,
        same RNG order) — verified by tests/test_golden_trace.py.

        The collector is paused for the duration of the loop: the hot path
        churns short-lived tuples/messages that gen-0 collections rescan
        constantly (~25% of wall time).  Simulation state is effectively
        acyclic, so deferring collection to the end of the run is safe.
        """
        gc_was_enabled = gc.isenabled()
        if gc_was_enabled:
            gc.disable()
        try:
            if self.fast_path:
                return self._run_fast(until, max_events)
            return self._run_exact(until, max_events)
        finally:
            if gc_was_enabled:
                gc.enable()

    def _run_exact(self, until: float, max_events: Optional[int]) -> int:
        sched = self.sched
        heap = sched._heap
        pop = heapq.heappop
        push = heapq.heappush
        nodes = self.nodes
        cpu_free = self.cpu_free
        cpu_busy = self._cpu_busy
        msgs_in = self._msgs_in
        gens = sched._gen
        free_slots = sched._free
        nsrv = self.n_servers
        topo = self.topo
        lan = topo.region_of is None
        base = topo.base_latency
        jitter = topo.jitter
        rng = sched.rng
        rng_exp = rng.exponential
        part = self.partitioned
        deg = self._degraded
        acct = self.accounting
        tr = self.tracer
        # tracer cost contract: an unsampled op costs one ``_tctx`` slot
        # load per event here — no id() call, no dict probe (the hop map
        # is only touched for messages that actually carry a context)
        tr_hop = tr._hop if tr is not None else None
        n = 0
        while heap:
            ev = pop(heap)
            t = ev[0]
            if t > until:
                push(heap, ev)
                break
            kind = ev[2]
            if kind == K_HANDLE:
                dst = ev[3]
                node = nodes[dst]
                sched.now = t
                if tr is not None and ev[4]._tctx is not None:
                    # ambient ctx: sends inside the handler inherit the
                    # hop's svc span recorded at K_ARRIVE (popped even for
                    # crashed nodes so the hop map can't leak on this path).
                    # Unsampled messages skip this entirely: ``cur`` is
                    # always None between handlers (the post-handler clear
                    # below; timer paths save/restore).
                    mid = id(ev[4])
                    h = tr_hop.get(mid)
                    if h is None:
                        tr.cur = None
                    else:
                        tr.cur = h.pop(dst, None)
                        if not h:
                            del tr_hop[mid]
                if node is not None and not node.crashed:
                    msg = ev[4]
                    if acct:
                        msgs_in[dst] += 1
                    try:
                        d = node._dispatch
                    except AttributeError:
                        node.deliver(msg)   # duck-typed node (runtime layer)
                    else:
                        h = d.get(msg.__class__)
                        if h is None:
                            h = node._bind_handler(msg.__class__)
                        h(msg)
                if tr is not None:
                    tr.cur = None
            elif kind == K_ARRIVE:
                sched.now = t
                dst = ev[4]
                node = nodes[dst]
                if node is not None and not node.crashed:
                    if dst < nsrv:
                        c = ev[6]
                        free = cpu_free[dst]
                        start = t if t > free else free
                        done = start + c
                        cpu_free[dst] = done
                        cpu_busy[dst] += c
                        sched._seq = seq = sched._seq + 1
                        push(heap, (done, seq, K_HANDLE, dst, ev[5], None, None))
                        if tr is not None:
                            ctx = ev[5]._tctx
                            if ctx is not None:
                                # ev[7]: transmit time (net span recorded
                                # here so K_TRANSMIT needs no tracer hook)
                                tr.add_span(ctx, "net", ev[3], ev[7], t)
                                if start > t:
                                    tr.add_span(ctx, "queue", dst, t, start)
                                sid = tr.add_span(ctx, "svc", dst, start, done)
                                mid = id(ev[5])
                                h = tr_hop.get(mid)
                                if h is None:
                                    h = tr_hop[mid] = {}
                                h[dst] = (ctx[0], sid)
                    else:
                        sched._seq = seq = sched._seq + 1
                        push(heap, (t, seq, K_HANDLE, dst, ev[5], None, None))
                        if tr is not None:
                            ctx = ev[5]._tctx
                            if ctx is not None:
                                tr.add_span(ctx, "net", ev[3], ev[7], t)
                                mid = id(ev[5])
                                h = tr_hop.get(mid)
                                if h is None:
                                    h = tr_hop[mid] = {}
                                h[dst] = ctx
            elif kind == K_TRANSMIT:
                sched.now = t
                src = ev[3]
                dst = ev[4]
                if not part or (src, dst) not in part:
                    if lan:
                        lat = base + rng_exp(jitter)
                    else:
                        lat = topo.latency(rng, src, dst)
                    if deg and (src in deg or dst in deg):
                        lat = self._degraded_latency(src, dst, lat, rng)
                        if lat >= 0.0:     # not dropped by a gray node
                            sched._seq = seq = sched._seq + 1
                            push(heap, (t + lat, seq, K_ARRIVE, src, dst,
                                        ev[5], ev[6], t))
                    else:
                        sched._seq = seq = sched._seq + 1
                        push(heap, (t + lat, seq, K_ARRIVE, src, dst,
                                    ev[5], ev[6], t))
            else:  # K_CALL timer via the generation slab
                slot = ev[3]
                gen = ev[4]
                free_slots.append(slot)
                if gens[slot] != gen:
                    continue           # cancelled: skip, don't count
                gens[slot] = gen + 1
                sched.now = t
                ev[5]()
                acct = self.accounting   # timers may toggle/reset accounting
                tr = self.tracer
                tr_hop = tr._hop if tr is not None else None
            n += 1
            if max_events is not None and n >= max_events:
                break
        if sched.now < until < _INF:
            sched.now = until
        sched.events += n
        return n

    def _run_fast(self, until: float, max_events: Optional[int]) -> int:
        """Flattened-mode loop: only K_DELIVER + K_CALL events exist."""
        sched = self.sched
        heap = sched._heap
        pop = heapq.heappop
        push = heapq.heappush
        nodes = self.nodes
        cpu_free = self.cpu_free
        cpu_busy = self._cpu_busy
        msgs_in = self._msgs_in
        gens = sched._gen
        free_slots = sched._free
        nsrv = self.n_servers
        acct = self.accounting
        tr = self.tracer
        n = 0
        while heap:
            ev = pop(heap)
            t = ev[0]
            if t > until:
                push(heap, ev)
                break
            if ev[2] == K_DELIVER:
                # reserve the receiver CPU slot now (arrival order) and run
                # the handler at the service-completion time
                dst = ev[3]
                node = nodes[dst]
                sched.now = t
                if node is not None and not node.crashed:
                    msg = ev[4]
                    if dst < nsrv:
                        c = ev[5]
                        free = cpu_free[dst]
                        start = t if t > free else free
                        done = start + c
                        cpu_free[dst] = done
                        cpu_busy[dst] += c
                        sched.now = done
                        if tr is not None:
                            ctx = msg._tctx
                            if ctx is not None:
                                if start > t:
                                    tr.add_span(ctx, "queue", dst, t, start)
                                sid = tr.add_span(ctx, "svc", dst, start, done)
                                tr.cur = (ctx[0], sid)
                    elif tr is not None:
                        tr.cur = msg._tctx
                    if acct:
                        msgs_in[dst] += 1
                    try:
                        d = node._dispatch
                    except AttributeError:
                        node.deliver(msg)   # duck-typed node (runtime layer)
                    else:
                        h = d.get(msg.__class__)
                        if h is None:
                            h = node._bind_handler(msg.__class__)
                        h(msg)
                    if tr is not None:
                        tr.cur = None
            else:  # K_CALL
                slot = ev[3]
                gen = ev[4]
                free_slots.append(slot)
                if gens[slot] != gen:
                    continue
                gens[slot] = gen + 1
                sched.now = t
                ev[5]()
                acct = self.accounting
                tr = self.tracer
            n += 1
            if max_events is not None and n >= max_events:
                break
        if sched.now < until < _INF:
            sched.now = until
        sched.events += n
        return n

    # -------------------------------------------------------------- stats
    def _materialize(self) -> None:
        """Fold the deferred send log into per-node counts + flight pairs."""
        log = self._send_log
        if not log:
            return
        out = self._msgs_out
        f = self._flight
        fget = f.get
        for k in log:
            out[k >> 20] += 1
            f[k] = fget(k, 0) + 1
        log.clear()

    @property
    def msgs_out(self) -> np.ndarray:
        self._materialize()
        return np.asarray(self._msgs_out, dtype=np.int64)

    @property
    def msgs_in(self) -> np.ndarray:
        return np.asarray(self._msgs_in, dtype=np.int64)

    @property
    def flight_matrix(self) -> np.ndarray:
        self._materialize()
        cap = self._cap
        m = np.zeros((cap, cap), dtype=np.int64)
        for k, v in self._flight.items():
            m[k >> 20, k & 0xFFFFF] = v
        return m

    @property
    def cpu_busy(self) -> dict:
        return {i: b for i, b in enumerate(self._cpu_busy)
                if self.nodes[i] is not None}

    def reset_stats(self) -> None:
        cap = self._cap
        self._send_log.clear()
        self._msgs_out[:] = [0] * cap
        self._msgs_in[:] = [0] * cap
        self._flight.clear()
        self._cpu_busy[:] = [0.0] * cap
        if self.obs is not None:
            self.obs.reset()   # warmup samples never pollute timelines

    def message_load(self, node_id: int) -> int:
        self._materialize()
        return self._msgs_out[node_id] + self._msgs_in[node_id]
