"""Simulated transport: per-link latency + per-node CPU service queues.

Model (matches the paper's observed bottleneck, §2.2):
  send(msg):  src CPU busy for cost(msg)   (serialize)
              -> link latency L(src,dst)   (propagation + jitter)
              -> dst CPU busy for cost(msg) (deserialize + handle)
              -> dst handler runs

Each node's CPU is a single FIFO server; leader saturation emerges naturally
when its CPU utilization approaches 1.  Message counts per (src,dst) and per
node are recorded to validate the analytical model (Table 1/2) and to draw
the in-flight heatmap (Fig 17).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from .events import Scheduler
from .messages import CostModel, Msg


@dataclass
class Topology:
    """Latency model. ``region_of`` maps node id -> region index;
    ``rtt_matrix[r1][r2]`` is the one-way base latency between regions."""
    n: int
    base_latency: float = 0.25e-3          # LAN one-way
    jitter: float = 0.05e-3
    region_of: Optional[list] = None
    region_latency: Optional[np.ndarray] = None   # one-way seconds

    def latency(self, rng: np.random.Generator, src: int, dst: int) -> float:
        if self.region_of is not None:
            # endpoints >= n are clients: co-located with the leader's
            # region (region 0), as in the paper's WAN setup (§5.3)
            rs = self.region_of[src] if src < self.n else 0
            rd = self.region_of[dst] if dst < self.n else 0
            base = float(self.region_latency[rs][rd])
        else:
            base = self.base_latency
        return base + rng.exponential(self.jitter)


def wan_topology(nodes_per_region: list[int], oneway_ms: list[list[float]]) -> Topology:
    region_of = []
    for r, k in enumerate(nodes_per_region):
        region_of += [r] * k
    return Topology(
        n=len(region_of),
        jitter=0.05e-3,
        region_of=region_of,
        region_latency=np.asarray(oneway_ms) * 1e-3,
    )


class Network:
    """Transport + CPU queues + failure injection + accounting."""

    def __init__(self, sched: Scheduler, topo: Topology, cost: CostModel | None = None):
        self.sched = sched
        self.topo = topo
        self.cost = cost or CostModel()
        self.nodes: Dict[int, "object"] = {}      # id -> node (has .deliver & .crashed)
        self.cpu_free: Dict[int, float] = {}      # id -> time CPU becomes free
        self.cpu_busy: Dict[int, float] = {}      # id -> total busy seconds
        cap = topo.n + 1024  # room for client endpoints (ids >= n)
        self.msgs_out = np.zeros(cap, dtype=np.int64)
        self.msgs_in = np.zeros(cap, dtype=np.int64)
        self.flight_matrix = np.zeros((cap, cap), dtype=np.int64)
        self.partitioned: set[Tuple[int, int]] = set()
        self.accounting = True

    def register(self, node_id: int, node) -> None:
        self.nodes[node_id] = node
        self.cpu_free[node_id] = 0.0
        self.cpu_busy[node_id] = 0.0

    # -------------------------------------------------------------- failure
    def partition(self, a: int, b: int) -> None:
        self.partitioned.add((a, b))
        self.partitioned.add((b, a))

    def heal(self, a: int, b: int) -> None:
        self.partitioned.discard((a, b))
        self.partitioned.discard((b, a))

    # -------------------------------------------------------------- CPU
    def _cpu(self, node_id: int, cost: float, fn: Callable[[], None]) -> None:
        """Occupy ``node_id``'s CPU for ``cost`` seconds, then run ``fn``."""
        start = max(self.sched.now, self.cpu_free[node_id])
        done = start + cost
        self.cpu_free[node_id] = done
        self.cpu_busy[node_id] += cost
        self.sched.at(done, fn)

    # -------------------------------------------------------------- send
    def send(self, src: int, dst: int, msg: Msg) -> None:
        msg.src = src
        node_src = self.nodes.get(src)
        if node_src is not None and getattr(node_src, "crashed", False):
            return
        c = self.cost.cpu_cost(msg)
        if self.accounting:
            self.msgs_out[src] += 1
            self.flight_matrix[src][dst] += 1

        def _transmit() -> None:
            if (src, dst) in self.partitioned:
                return
            lat = self.topo.latency(self.sched.rng, src, dst)
            self.sched.after(lat, lambda: self._arrive(src, dst, msg, c))

        # serialize on the sender's CPU (clients, id >= n, have free CPUs)
        if src < self.topo.n:
            self._cpu(src, c, _transmit)
        else:
            self.sched.after(0.0, _transmit)

    def _arrive(self, src: int, dst: int, msg: Msg, c: float) -> None:
        node = self.nodes.get(dst)
        if node is None or getattr(node, "crashed", False):
            return

        def _handle() -> None:
            n2 = self.nodes.get(dst)
            if n2 is None or getattr(n2, "crashed", False):
                return
            if self.accounting:
                self.msgs_in[dst] += 1
            n2.deliver(msg)

        if dst < self.topo.n:
            self._cpu(dst, c, _handle)
        else:
            self.sched.after(0.0, _handle)

    # -------------------------------------------------------------- stats
    def reset_stats(self) -> None:
        self.msgs_out[:] = 0
        self.msgs_in[:] = 0
        self.flight_matrix[:] = 0
        for k in self.cpu_busy:
            self.cpu_busy[k] = 0.0

    def message_load(self, node_id: int) -> int:
        return int(self.msgs_out[node_id] + self.msgs_in[node_id])
