"""Protocol messages and the CPU/byte cost model.

The paper establishes (§2.2) that the leader bottleneck is CPU time spent
serializing/deserializing messages ("~100,000 phase-2a/2b messages saturate
one core" => ~10us/message), with a secondary dependence on payload size
(§5.5) and, for EPaxos, on cluster size N through dependency tracking
(§5.3: 25-node EPaxos messages serialize ~4x slower than 5-node ones).

Every message type reports ``wire_size()``; the cost model converts sizes to
CPU seconds at each endpoint.  Constants are calibrated in
benchmarks/fig9_latency_throughput.py against the paper's reported saturation
points (Paxos ~2k, EPaxos ~3k, PigPaxos >7k req/s at N=25).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

HEADER_BYTES = 24  # type tag + ballot + slot + ids


@dataclass(slots=True)
class Command:
    """A state-machine command (KV get/put)."""
    client_id: int
    seq: int          # per-client sequence number
    op: str           # 'get' | 'put'
    key: int
    value: Optional[bytes] = None

    def wire_size(self) -> int:
        return 16 + (len(self.value) if self.value is not None else 0)


@dataclass(slots=True)
class BatchCmd:
    """Several client commands packed into one slot by a batching leader.

    Quacks like :class:`Command` (same field names) so it can ride inside
    the existing ``P2a``/``PreAccept``/``ECommit`` envelopes and survive
    P1b / explicit-prepare recovery unchanged: recovery re-proposes the
    whole batch as one opaque value, so a batch commits or recovers
    atomically — sub-commands are never split across slots.
    """
    cmds: tuple = ()              # tuple[Command, ...]
    client_id: int = -1
    seq: int = 0
    op: str = "batch"
    key: int = -1
    value: Optional[bytes] = None

    def wire_size(self) -> int:
        # 8-byte batch header (count + framing) + concatenated commands
        return 8 + sum(c.wire_size() for c in self.cmds)


@dataclass(slots=True)
class Msg:
    src: int = -1
    # per-instance CPU-cost cache (CostModel.cpu_cost): broadcasts reuse one
    # message instance for every destination, so the cost is computed once.
    # Excluded from __eq__/__repr__ so caching never changes message identity.
    _cost: float = field(default=-1.0, compare=False, repr=False)
    # trace context (repro.obs): (trace_id, span_id) of the span that caused
    # this message, set once by Tracer.attach on sampled ops only.  A slot
    # (not a side table) because the engine loops test it per event — a slot
    # load is the only per-message tracing cost an unsampled op ever pays.
    _tctx: Any = field(default=None, compare=False, repr=False)

    def wire_size(self) -> int:
        return HEADER_BYTES

    @property
    def kind(self) -> str:
        # subclasses that must dispatch as another type (e.g. pig._P1Aggregate)
        # set ``_kind_name`` on the class instead of overriding this property
        cls = type(self)
        return getattr(cls, "_kind_name", None) or cls.__name__


# ---------------------------------------------------------------- client I/O
@dataclass(slots=True)
class ClientRequest(Msg):
    cmd: Command = None

    def wire_size(self) -> int:
        return HEADER_BYTES + self.cmd.wire_size()


@dataclass(slots=True)
class ClientReply(Msg):
    client_id: int = 0
    seq: int = 0
    ok: bool = True
    value: Optional[bytes] = None
    # which read path produced this reply: "log" (through consensus),
    # "lease" (leader-local leased read), or "quorum" (client-side quorum
    # read).  Metadata for the history/auditor — a real implementation
    # would not ship it, so it does not count toward wire_size().
    path: str = "log"

    def wire_size(self) -> int:
        return HEADER_BYTES + 8 + (len(self.value) if self.value else 0)


# ---------------------------------------------------------------- Paxos
@dataclass(slots=True)
class P1a(Msg):
    ballot: tuple = (0, 0)


@dataclass(slots=True)
class P1b(Msg):
    ballot: tuple = (0, 0)
    ok: bool = True
    # accepted: {slot: (ballot, Command)} for value recovery
    accepted: dict = field(default_factory=dict)
    # the follower's committed prefix: slots <= commit_index are pruned from
    # ``accepted``, so a behind new leader must catch them up instead of
    # re-proposing
    commit_index: int = -1

    def wire_size(self) -> int:
        return HEADER_BYTES + 8 + sum(24 + c.wire_size() for (_, c) in self.accepted.values())


@dataclass(slots=True)
class P2a(Msg):
    ballot: tuple = (0, 0)
    slot: int = 0
    cmd: Command = None
    commit_index: int = -1   # phase-3 piggybacked on phase-2 (§2.1)

    def wire_size(self) -> int:
        return HEADER_BYTES + 16 + self.cmd.wire_size()


class P2b(Msg):
    """Phase-2 vote. Hand-written init: this is the hottest message class
    (one per follower per slot), and the dataclass-generated __init__ costs
    ~100ns more per instantiation."""
    __slots__ = ("ballot", "slot", "ok")

    def __init__(self, ballot=(0, 0), slot=0, ok=True):
        self.src = -1
        self._cost = -1.0
        self._tctx = None
        self.ballot = ballot
        self.slot = slot
        self.ok = ok


@dataclass(slots=True)
class P3(Msg):
    """Explicit commit (used on idle / trailing slots)."""
    commit_index: int = -1


# ------------------------------------------------------- membership change
@dataclass(slots=True)
class JoinReq(Msg):
    """Joiner -> leader (Paxos) / config proposer (EPaxos): ask to be added
    to the replica set.  The receiver answers with a ``Snapshot`` and drives
    the ``add_node`` configuration command through the normal log."""
    node: int = -1


@dataclass(slots=True)
class Snapshot(Msg):
    """State transfer to a joining learner: applied KV state + client
    session table + the sender's membership view.  ``payload`` carries
    protocol-specific extras (EPaxos ships its interference map and executed
    instance ids; a zero-store Snapshot with ``payload={"confirm": True}``
    confirms a completed EPaxos join)."""
    commit_index: int = -1
    store: dict = field(default_factory=dict)
    session: dict = field(default_factory=dict)
    members: tuple = ()
    payload: Any = None

    def wire_size(self) -> int:
        extra = len(self.payload) if isinstance(self.payload, (dict, list)) else 0
        return (HEADER_BYTES + 16
                + 24 * (len(self.store) + len(self.session) + extra)
                + 2 * len(self.members))


# ------------------------------------------------------ leases + read paths
@dataclass(slots=True)
class LeaseGrant(Msg):
    """Leader -> members: ask for a read lease of ``duration`` seconds
    (measured on each receiver's LOCAL clock).  A follower that acks
    promises not to vote for a different leader until the lease expires
    locally — so a quorum of acks lets the leader serve reads from its own
    store without a round trip (Spinnaker-style leader leases)."""
    ballot: tuple = (0, 0)
    lseq: int = 0             # lease sequence number (one per renewal)
    duration: float = 0.0     # seconds, interpreted on the receiver's clock


@dataclass(slots=True)
class LeaseAck(Msg):
    """Member -> leader: the lease promise for (ballot, lseq) is in effect."""
    ballot: tuple = (0, 0)
    lseq: int = 0


@dataclass(slots=True)
class ReadProbe(Msg):
    """Client -> replica: report your commit frontier for ``key`` (quorum
    reads).  ``rid`` ties replies to one read attempt across rinse rounds."""
    key: int = 0
    rid: int = 0


@dataclass(slots=True)
class ReadReply(Msg):
    """Replica -> client: per-key frontier snapshot.  ``applied`` is the
    position of the latest locally-applied write to the key, ``accepted``
    the highest position the replica knows MIGHT hold a write to the key
    (accepted-but-not-applied).  The client rinses (re-probes) while any
    quorum member's ``accepted`` exceeds the quorum's max ``applied``."""
    rid: int = 0
    key: int = 0
    applied: int = -1
    accepted: int = -1
    value: Optional[bytes] = None
    wtag: Any = None          # (client_id, seq) of the witnessed write

    def wire_size(self) -> int:
        return HEADER_BYTES + 16 + (len(self.value) if self.value else 0)


# ---------------------------------------------------------------- Pig overlay
@dataclass(slots=True)
class PigFanout(Msg):
    """Leader -> relay: carry an inner message + the Pig round id (§3.1)."""
    pig_id: int = 0
    group: int = 0
    inner: Any = None
    required: int = 0   # acks the relay must gather before replying (PRC, §4.1)

    def wire_size(self) -> int:
        return HEADER_BYTES + 8 + self.inner.wire_size()


@dataclass(slots=True)
class PigRelayed(Msg):
    """Relay -> group peers: the re-broadcast inner message."""
    pig_id: int = 0
    relay: int = -1
    inner: Any = None

    def wire_size(self) -> int:
        return HEADER_BYTES + 8 + self.inner.wire_size()


class PigReply(Msg):
    """Follower -> relay: reply to the inner message, tagged with pig_id.
    Hand-written init like P2b: one instance per follower reply."""
    __slots__ = ("pig_id", "inner")

    def __init__(self, pig_id=0, inner=None):
        self.src = -1
        self._cost = -1.0
        self._tctx = None
        self.pig_id = pig_id
        self.inner = inner

    def wire_size(self) -> int:
        return HEADER_BYTES + 8 + self.inner.wire_size()


@dataclass(slots=True)
class PigAggregate(Msg):
    """Relay -> leader: aggregated acks.

    Deduplicated per §6.4: carries vote summary + ids of *missing* voters
    (usually empty), not the full voter list.
    """
    pig_id: int = 0
    group: int = 0
    ballot: tuple = (0, 0)
    slot: int = -1
    acks: int = 0
    voters: tuple = ()       # kept for leader-side dedup across retries
    missing: tuple = ()
    timed_out: bool = False  # True => missing nodes are failure suspects (§4.2)
    reject: bool = False
    reject_ballot: tuple = (0, 0)

    def wire_size(self) -> int:
        # leader needs only the missing-voter list on the wire (§6.4);
        # the voters tuple models state the leader can reconstruct.
        return HEADER_BYTES + 16 + 2 * len(self.missing)


# ---------------------------------------------------------------- EPaxos
@dataclass(slots=True)
class PreAccept(Msg):
    inst: tuple = (0, 0)      # (replica, instance_no)
    ballot: tuple = (0, 0)
    cmd: Command = None
    deps: frozenset = frozenset()
    seq: int = 0
    n_cluster: int = 0        # drives the O(N) serialization cost (§5.3)

    def wire_size(self) -> int:
        return HEADER_BYTES + self.cmd.wire_size() + 12 * max(len(self.deps), 1) + 8 * self.n_cluster


@dataclass(slots=True)
class PreAcceptReply(Msg):
    inst: tuple = (0, 0)
    ok: bool = True
    deps: frozenset = frozenset()
    seq: int = 0
    n_cluster: int = 0

    def wire_size(self) -> int:
        return HEADER_BYTES + 12 * max(len(self.deps), 1) + 8 * self.n_cluster


@dataclass(slots=True)
class EAccept(Msg):
    inst: tuple = (0, 0)
    ballot: tuple = (0, 0)
    cmd: Command = None       # None = recovery no-op
    deps: frozenset = frozenset()
    seq: int = 0
    n_cluster: int = 0

    def wire_size(self) -> int:
        return (HEADER_BYTES
                + (self.cmd.wire_size() if self.cmd is not None else 0)
                + 12 * max(len(self.deps), 1) + 8 * self.n_cluster)


@dataclass(slots=True)
class EAcceptReply(Msg):
    inst: tuple = (0, 0)
    ok: bool = True
    # ballot of the accept round being answered: (0, 0) on the original
    # coordinator's slow path, the prepare ballot on recovery rounds (so a
    # recoverer can tell its own round's acks from stale ones); rejects
    # carry the replier's promised ballot instead
    ballot: tuple = (0, 0)


@dataclass(slots=True)
class ECommit(Msg):
    inst: tuple = (0, 0)
    cmd: Command = None       # None = recovery no-op
    deps: frozenset = frozenset()
    seq: int = 0
    n_cluster: int = 0

    def wire_size(self) -> int:
        return (HEADER_BYTES
                + (self.cmd.wire_size() if self.cmd is not None else 0)
                + 12 * max(len(self.deps), 1) + 8 * self.n_cluster)


@dataclass(slots=True)
class EPrepare(Msg):
    """Explicit-prepare (EPaxos recovery, §4.7 of Moraru et al.): a peer
    suspecting a crashed command leader raises the per-instance ballot and
    asks everyone for their view of the instance."""
    inst: tuple = (0, 0)
    ballot: tuple = (0, 0)
    n_cluster: int = 0        # dependency bookkeeping cost ∝ N, like PreAccept

    def wire_size(self) -> int:
        return HEADER_BYTES + 16


@dataclass(slots=True)
class EPrepareReply(Msg):
    """A replica's instance snapshot: its state plus the attributes and the
    ballot they were (pre-)accepted at.  ``ok=False`` rejects a stale
    prepare ballot (``ballot`` then carries the replier's promise)."""
    inst: tuple = (0, 0)
    ok: bool = True
    ballot: tuple = (0, 0)
    state: str = "none"
    cmd: Command = None
    deps: frozenset = frozenset()
    seq: int = 0
    accepted_ballot: tuple = (0, 0)
    n_cluster: int = 0

    def wire_size(self) -> int:
        return (HEADER_BYTES + 24
                + (self.cmd.wire_size() if self.cmd is not None else 0)
                + 12 * max(len(self.deps), 1) + 8 * self.n_cluster)


# ---------------------------------------------------------------- cost model
# message classes carrying an O(N) dependency payload (resolved lazily so
# protocol modules can add their own Msg subclasses without registering here)
_HAS_N_CLUSTER: dict = {}
# wrapper classes whose wire size is HEADER + 8 + inner.wire_size()
_PIG_WRAPPERS = frozenset((PigFanout, PigRelayed, PigReply))


@dataclass
class CostModel:
    """CPU seconds charged per message at each endpoint.

    cpu = base + per_byte * wire_size       (serialize at src, parse at dst)

    Defaults give ~10us per small message per endpoint => a 25-node Paxos
    leader handling 2R+2=50 messages/request saturates at ~2000 req/s,
    matching §2.2 and Fig 9.

    Hot-path note: classes that inherit ``Msg.wire_size`` have a constant
    wire size, so their cost is computed once and cached per class (about
    half of all hops are fixed-size replies: P1a/P2b/P3/EAcceptReply/...).
    Costs depend only on the frozen constants above; mutate them only by
    constructing a fresh CostModel.
    """
    base: float = 10e-6
    per_byte: float = 0.7e-9        # ~1.4 GB/s serialization bandwidth
    epaxos_extra_per_node: float = 1.2e-6   # dependency-tracking cost ∝ N (§5.3)
    epaxos_exec_graph: float = 14e-6        # per-op dependency graph bookkeeping

    def __post_init__(self):
        self._fixed: dict = {}      # class -> constant cpu cost
        self._wrap_fixed: dict = {} # (wrapper cls, inner cls) -> cpu cost

    def cpu_cost(self, msg: Msg) -> float:
        c = msg._cost
        if c >= 0.0:
            return c                # instance cache (broadcast reuse)
        cls = msg.__class__
        c = self._fixed.get(cls)
        if c is not None:
            msg._cost = c
            return c
        if cls in _PIG_WRAPPERS:
            # Pig wrappers: wire = HEADER + 8 + inner.wire_size(); constant
            # per (wrapper, inner) pair when the inner is header-only
            icls = msg.inner.__class__
            key = (cls, icls)
            c = self._wrap_fixed.get(key)
            if c is None:
                if icls.wire_size is Msg.wire_size:
                    c = self.base + self.per_byte * (2 * HEADER_BYTES + 8)
                    self._wrap_fixed[key] = c
                else:
                    c = self.base + self.per_byte * msg.wire_size()
            msg._cost = c
            return c
        c = self.base + self.per_byte * msg.wire_size()
        has_n = _HAS_N_CLUSTER.get(cls)
        if has_n is None:
            has_n = _HAS_N_CLUSTER.setdefault(cls, hasattr(msg, "n_cluster"))
        if has_n:
            c += self.epaxos_extra_per_node * msg.n_cluster
        elif cls.wire_size is Msg.wire_size:
            self._fixed[cls] = c    # header-only message: constant per class
        msg._cost = c
        return c
