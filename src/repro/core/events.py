"""Deterministic discrete-event scheduler — slab-backed fast engine.

The entire protocol evaluation (Figs 8-17, Tables 1-2 of the paper) runs on
this virtual-time scheduler.  Determinism: a single seeded RNG drives every
stochastic choice (latency jitter, relay selection, client keys), and ties in
the event heap are broken by a monotone sequence number.

Engine design (see benchmarks/README.md for the perf contract):

  * Heap entries are plain tuples ``(t, seq, kind, a, b, c, d)`` — no
    closures are allocated on the message hot path.  ``kind`` selects an
    inline branch in the fused run loop (message events live in
    ``network.Network._run``); ``K_CALL`` entries carry a callable for
    timers and harness hooks.
  * Timer cancellation uses generation counters in a slot slab instead of
    the seed's unbounded ``_cancelled`` set: ``cancel`` bumps the slot's
    generation so the stale heap entry is skipped (and its slot recycled)
    when popped.  Memory is bounded by the peak number of outstanding
    timers; cancelling an already-fired timer is a no-op.
  * When a :class:`repro.core.network.Network` is attached, ``run`` degrades
    to the network's fused loop, which executes transmit/arrive/handle
    events without any per-event Python function call.

Behavioral equivalence with the seed engine (``refengine.py``) is enforced
by tests/test_golden_trace.py: identical event times, identical tie-break
order (the seq counter advances at exactly the same points), and identical
RNG consumption order.
"""
from __future__ import annotations

import heapq
from bisect import insort
from typing import Callable, Optional

import numpy as np

# Event kinds.  K_CALL is generic; the message kinds are produced and
# consumed by network.Network (kept here so the encoding has one home).
K_CALL = 0       # (t, seq, K_CALL, slot, gen, fn, None)
K_TRANSMIT = 1   # (t, seq, K_TRANSMIT, src, dst, msg, cpu_cost)
K_ARRIVE = 2     # (t, seq, K_ARRIVE, src, dst, msg, cpu_cost, t_transmit)
K_HANDLE = 3     # (t, seq, K_HANDLE, dst, msg, None, None)
K_DELIVER = 4    # (t, seq, K_DELIVER, dst, msg, None, None)  fast-path hop

_INF = float("inf")


class Scheduler:
    __slots__ = ("now", "_heap", "_seq", "rng", "_gen", "_free", "_net",
                 "events")

    def __init__(self, seed: int = 0):
        self.now: float = 0.0
        self._heap: list = []
        self._seq: int = 0
        self.rng = np.random.default_rng(seed)
        self._gen: list[int] = []      # timer slot -> generation counter
        self._free: list[int] = []     # recycled timer slots
        self._net = None               # set by network.Network
        self.events: int = 0           # cumulative executed events

    # ------------------------------------------------------------- timers
    def at(self, t: float, fn: Callable[[], None]) -> int:
        """Schedule ``fn`` at absolute virtual time ``t``. Returns a timer id."""
        gens = self._gen
        free = self._free
        if free:
            slot = free.pop()
            gen = gens[slot]
        else:
            slot = len(gens)
            gens.append(0)
            gen = 0
        self._seq += 1
        heapq.heappush(self._heap, (t, self._seq, K_CALL, slot, gen, fn, None))
        return (slot << 32) | gen

    def after(self, dt: float, fn: Callable[[], None]) -> int:
        return self.at(self.now + dt, fn)

    def every(self, dt: float, fn: Callable[[], None],
              stop_at: float = _INF) -> Callable[[], None]:
        """Repeating timer: run ``fn`` every ``dt`` seconds, starting at
        ``now + dt``, until past ``stop_at`` or until the returned cancel
        callable is invoked.  Built on :meth:`after`, so it composes with
        the fused network loop and slab cancellation like any timer.
        Used by the observability sampler (`repro.obs.metrics`) and
        latency-driven admission control (`repro.runtime.policy`)."""
        state = {"on": True, "tid": None}

        def _fire() -> None:
            if not state["on"]:
                return
            fn()
            if state["on"] and self.now + dt <= stop_at:
                state["tid"] = self.after(dt, _fire)

        def cancel() -> None:
            state["on"] = False
            if state["tid"] is not None:
                self.cancel(state["tid"])

        state["tid"] = self.after(dt, _fire)
        return cancel

    def cancel(self, timer_id: int) -> None:
        """O(1) cancellation: bump the slot generation so the heap entry is
        discarded when popped.  Cancelling a fired/cancelled timer is a no-op
        (the generation no longer matches)."""
        slot = timer_id >> 32
        gen = timer_id & 0xFFFFFFFF
        if self._gen[slot] == gen:
            self._gen[slot] = gen + 1

    # ------------------------------------------------------------- running
    def run(self, until: float = _INF, max_events: Optional[int] = None) -> int:
        """Run events until virtual time ``until``; returns #events executed."""
        if self._net is not None:
            return self._net._run(until, max_events)
        return self._run_generic(until, max_events)

    def _run_generic(self, until: float, max_events: Optional[int]) -> int:
        """Timer-only loop, used when no network is attached."""
        n = 0
        heap = self._heap
        pop = heapq.heappop
        gens = self._gen
        free = self._free
        while heap:
            ev = heap[0]
            t = ev[0]
            if t > until:
                break
            pop(heap)
            slot = ev[3]
            gen = ev[4]
            free.append(slot)
            if gens[slot] != gen:
                continue               # cancelled: skip, don't count
            gens[slot] = gen + 1
            self.now = t
            ev[5]()
            n += 1
            if max_events is not None and n >= max_events:
                break
        if self.now < until < _INF:
            self.now = until
        self.events += n
        return n

    def idle(self) -> bool:
        return not self._heap


# ---------------------------------------------------------------------------
# Calendar-queue experiment (Brown 1988).  A DES event set is near-uniform
# in time, which is the textbook case for an O(1)-amortized calendar queue
# vs the O(log n) binary heap.  This is an EXPERIMENT, not the engine:
# the fused run loop (network.Network._run / _run_exact) pushes event
# tuples straight into ``Scheduler._heap`` with heapq — the golden-trace
# event encoding — so the calendar can only back the timer-only generic
# loop.  ``benchmarks/sim_engine_bench.py`` races both structures on the
# engine's timer distribution and records the adoption verdict in
# BENCH_sim.json (``scheduler_verdict``).
# ---------------------------------------------------------------------------
class CalendarQueue:
    """Priority queue of event tuples ordered by ``(t, seq)``: an array of
    time buckets of fixed ``width``, dequeue scanning from the bucket of
    the last-popped priority.  Amortized O(1) push/pop when events spread
    evenly over time; degrades gracefully (direct min scan) when a year's
    scan comes up empty.  Resizes (and re-estimates width from the live
    event-gap distribution) when occupancy leaves the [n/2, 2n] band."""

    __slots__ = ("_w", "_n", "_buckets", "_size", "_last")

    def __init__(self, width: float = 1e-4, nbuckets: int = 64):
        self._w = float(width)
        self._n = int(nbuckets)
        self._buckets: list[list] = [[] for _ in range(self._n)]
        self._size = 0
        self._last = 0.0          # priority of the last pop (monotone)

    def __len__(self) -> int:
        return self._size

    def push(self, ev: tuple) -> None:
        insort(self._buckets[int(ev[0] / self._w) % self._n], ev)
        self._size += 1
        if self._size > 2 * self._n:
            self._resize(2 * self._n)

    def pop(self) -> tuple:
        if not self._size:
            raise IndexError("pop from empty CalendarQueue")
        w, n = self._w, self._n
        year = int(self._last / w)
        i = year % n
        top = (year + 1) * w
        for _ in range(n):
            b = self._buckets[i]
            if b and b[0][0] < top:
                ev = b.pop(0)
                self._size -= 1
                self._last = ev[0]
                if self._size < self._n // 2 and self._n > 64:
                    self._resize(self._n // 2)
                return ev
            i = (i + 1) % n
            top += w
        # sparse year: the whole calendar cycle was dry — take the global
        # minimum directly and resync the clock to it
        ev = min((b[0] for b in self._buckets if b))
        self._buckets[int(ev[0] / w) % n].remove(ev)
        self._size -= 1
        self._last = ev[0]
        return ev

    def _resize(self, m: int) -> None:
        evs = sorted(e for b in self._buckets for e in b)
        if len(evs) >= 2:
            # width ~ 2x the mean gap of the upcoming events: each bucket
            # holds a couple of events, the sweet spot for bucket scans
            k = min(len(evs), 64)
            gap = (evs[k - 1][0] - evs[0][0]) / max(k - 1, 1)
            if gap > 0.0:
                self._w = 2.0 * gap
        self._n = m
        self._buckets = [[] for _ in range(m)]
        for e in evs:                     # evs sorted -> insort appends
            insort(self._buckets[int(e[0] / self._w) % m], e)
        self._size = len(evs)


class CalendarScheduler(Scheduler):
    """``Scheduler`` with the timer path backed by a :class:`CalendarQueue`
    instead of the slab heap — same timer-id/cancellation protocol, same
    tie-break.  Timer-only: attaching a :class:`repro.core.network.Network`
    is refused (its fused loop owns the heap encoding)."""

    __slots__ = ("_cal",)

    def __init__(self, seed: int = 0, width: float = 1e-4):
        super().__init__(seed)
        self._cal = CalendarQueue(width=width)

    def at(self, t: float, fn: Callable[[], None]) -> int:
        gens = self._gen
        free = self._free
        if free:
            slot = free.pop()
            gen = gens[slot]
        else:
            slot = len(gens)
            gens.append(0)
            gen = 0
        self._seq += 1
        self._cal.push((t, self._seq, K_CALL, slot, gen, fn, None))
        return (slot << 32) | gen

    def run(self, until: float = _INF, max_events: Optional[int] = None) -> int:
        if self._net is not None:
            raise RuntimeError(
                "CalendarScheduler is a timer-only experiment: the fused "
                "network loop pushes heap tuples directly (see events.py)")
        n = 0
        cal = self._cal
        gens = self._gen
        free = self._free
        while cal:
            ev = cal.pop()
            t = ev[0]
            if t > until:
                cal.push(ev)           # beyond horizon: put it back
                break
            slot = ev[3]
            gen = ev[4]
            free.append(slot)
            if gens[slot] != gen:
                continue
            gens[slot] = gen + 1
            self.now = t
            ev[5]()
            n += 1
            if max_events is not None and n >= max_events:
                break
        if self.now < until < _INF:
            self.now = until
        self.events += n
        return n

    def idle(self) -> bool:
        return not self._cal
