"""Deterministic discrete-event scheduler.

The entire protocol evaluation (Figs 8-17, Tables 1-2 of the paper) runs on
this virtual-time scheduler.  Determinism: a single seeded RNG drives every
stochastic choice (latency jitter, relay selection, client keys), and ties in
the event heap are broken by a monotone sequence number.
"""
from __future__ import annotations

import heapq
from typing import Callable, Optional

import numpy as np


class Scheduler:
    __slots__ = ("now", "_heap", "_seq", "rng", "_cancelled")

    def __init__(self, seed: int = 0):
        self.now: float = 0.0
        self._heap: list = []
        self._seq: int = 0
        self.rng = np.random.default_rng(seed)
        self._cancelled: set[int] = set()

    def at(self, t: float, fn: Callable[[], None]) -> int:
        """Schedule ``fn`` at absolute virtual time ``t``. Returns a timer id."""
        self._seq += 1
        heapq.heappush(self._heap, (t, self._seq, fn))
        return self._seq

    def after(self, dt: float, fn: Callable[[], None]) -> int:
        return self.at(self.now + dt, fn)

    def cancel(self, timer_id: int) -> None:
        self._cancelled.add(timer_id)

    def run(self, until: float = float("inf"), max_events: Optional[int] = None) -> int:
        """Run events until virtual time ``until``; returns #events executed."""
        n = 0
        heap = self._heap
        cancelled = self._cancelled
        while heap:
            t, seq, fn = heap[0]
            if t > until:
                break
            heapq.heappop(heap)
            if seq in cancelled:
                cancelled.discard(seq)
                continue
            self.now = t
            fn()
            n += 1
            if max_events is not None and n >= max_events:
                break
        if self.now < until < float("inf"):
            self.now = until
        return n

    def idle(self) -> bool:
        return not self._heap
