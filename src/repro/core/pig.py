"""The Pig communication primitive (§3.1) and the classic direct layer.

Pig replaces the leader's direct fan-out/fan-in with relay-group overlays:

  leader --PigFanout--> relay --PigRelayed--> group peers
  leader <--PigAggregate-- relay <--PigReply-- group peers

Key properties implemented here, exactly as in the paper:
  * static non-overlapping relay groups (reference implementation, §3.2);
  * uniformly-random relay rotation per round (§3.1) — or static relays for
    the Fig 8 comparison (no liveness guarantee in that mode);
  * in-network aggregation with deduplicated missing-voter lists (§6.4);
  * relay timeout T_r << leader timeout T_l (§3.4);
  * partial response collection: reply after group_size - PRC acks (§4.1);
  * single-relay-group global-majority shortcut (§4.3);
  * gray lists with occasional probing of suspected nodes (§4.2);
  * reject short-circuit on higher ballots (§3.2 footnote).

The layer is deliberately protocol-agnostic: it moves opaque ``inner``
messages and vote summaries, so PigPaxos = Paxos + PigComm with *zero*
changes to the consensus core — mirroring the paper's claim that Pig only
changes the communication implementation (and hence inherits Paxos proofs).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

from .messages import (Msg, P1b, P2a, P2b, PigAggregate, PigFanout,
                       PigRelayed, PigReply)


# --------------------------------------------------------------------------
@dataclass
class PigConfig:
    n_groups: int = 1
    rotate_relays: bool = True          # False => static relay (Fig 8 baseline)
    prc: int = 0                        # of slowest group members to not wait on (§4.1)
    relay_timeout: float = 10e-3        # T_r (must be << leader timeout T_l, §3.4)
    single_group_majority: bool = False  # §4.3 optimization for R == 1
    use_gray_list: bool = False         # §4.2
    gray_duration: float = 2.0
    gray_probe_prob: float = 0.02
    groups: Optional[List[List[int]]] = None   # explicit (e.g. per-region, §5.3)
    # elasticity: re-derive R from the *current* membership on every
    # re-partition (R ~ sqrt(N), the paper's §5.2 sweet spot) instead of
    # keeping n_groups fixed while the cluster grows/shrinks
    auto_groups: bool = False


def auto_group_count(n_members: int) -> int:
    """Elasticity policy for the relay-group count: R ~ sqrt(N-1) balances
    the leader's R aggregates against each relay's (N-1)/R fan-out (paper
    §5.2 finds the throughput plateau around this point)."""
    return max(1, int(round(math.sqrt(max(n_members - 1, 1)))))


def partition_followers(members: Sequence[int], r: int) -> List[List[int]]:
    """Round-robin partition of the followers into ``r`` relay groups —
    THE cluster-wide static partition (§3.2), shared by the DES comm layer
    and the batched backend (``core.vectorsim``)."""
    r = max(1, min(r, len(members)))
    out: List[List[int]] = [[] for _ in range(r)]
    for i, m in enumerate(members):
        out[i % r].append(m)
    return out


def required_per_group(groups: List[List[int]], n: int, prc: int,
                       single_group_majority: bool) -> List[int]:
    """PRC thresholds q_i = n_i - PRC, subject to the paper's §4.1
    constraint sum(q_i) >= majority - 1 (the leader votes for itself);
    violating it would let a single crashed group block liveness.
    ``single_group_majority`` is the §4.3 R == 1 global-majority shortcut.
    Shared by PigComm and the batched backend (``core.vectorsim``)."""
    maj = n // 2 + 1
    if single_group_majority and len(groups) == 1:
        return [min(len(groups[0]), maj - 1)]     # §4.3: global majority
    req = [max(1, len(g) - prc) for g in groups]
    i = 0
    while sum(req) < maj - 1:
        if req[i % len(req)] < len(groups[i % len(req)]):
            req[i % len(req)] += 1
        i += 1
        if i > 4 * len(req):       # all groups already at n_i
            break
    return req


class DirectComm:
    """Classic Paxos communication: leader <-> every follower directly.

    Comm interface note: every comm strategy exposes ``_pending_sup``
    (slot -> pig round with batched late votes); PaxosNode._learn_commit
    peeks at it to skip the ``note_committed_up_to`` call on the commit hot
    path when no supplements are pending.  DirectComm never queues any.
    """

    name = "direct"

    def __init__(self, node, peers: Sequence[int]):
        self.node = node
        self.peers = [p for p in peers if p != node.id]
        self._pending_sup: Dict[int, int] = {}   # always empty (see above)

    # leader side -----------------------------------------------------------
    def broadcast(self, make_msg: Callable[[], Msg], round_key=None) -> list:
        # one shared instance: receivers never mutate messages, and the
        # network stamps the same .src on every send (cost computed once)
        m = make_msg()
        for p in self.peers:
            self.node.send(p, m)
        return []

    # follower side ---------------------------------------------------------
    def reply(self, to: int, msg: Msg) -> None:
        self.node.send(to, msg)

    def set_members(self, members: Sequence[int]) -> None:
        """Membership changed: rebuild the direct fan-out list."""
        self.peers = [p for p in members if p != self.node.id]

    # no-op hooks so Paxos can stay comm-agnostic
    def note_commit(self, slot: int) -> None:
        pass

    def note_committed_up_to(self, ci: int) -> None:
        pass

    def on_round_timeout(self, round_ids) -> None:
        pass


class PigComm:
    """Pig overlay communication used by the leader and all followers."""

    name = "pig"

    def __init__(self, node, peers: Sequence[int], cfg: PigConfig):
        self.node = node
        self.cfg = cfg
        self.all_nodes = list(peers)
        self._groups_cache: Dict[int, List[List[int]]] = {}
        self._peers_cache: Dict[tuple, tuple] = {}   # (leader, gi) -> (peers, expect)
        self._pig_seq = node.id << 40
        # relay-side aggregation state: pig_id -> dict
        self._agg: Dict[int, dict] = {}
        # leader-side: pig_id -> (group_idx, relay, round_key)
        self._outstanding: Dict[int, tuple] = {}
        self._pending_sup: Dict[int, int] = {}   # slot -> pig_id (late votes)
        self.gray: Dict[int, float] = {}     # node -> expiry time (§4.2)

    _partition = staticmethod(partition_followers)

    def groups_for(self, leader: int) -> List[List[int]]:
        """Relay groups are a cluster-wide static partition of the *followers*
        (paper §3.2) — i.e. of all nodes except the current leader.  Every
        node derives the same partition deterministically from the leader id,
        so relays and the leader agree without extra coordination."""
        g = self._groups_cache.get(leader)
        if g is None:
            if self.cfg.groups is not None:
                live = set(self.all_nodes)
                g = [[m for m in grp if m != leader and m in live]
                     for grp in self.cfg.groups]
                g = [grp for grp in g if grp]
            else:
                r = (auto_group_count(len(self.all_nodes))
                     if self.cfg.auto_groups else self.cfg.n_groups)
                g = self._partition([p for p in self.all_nodes if p != leader],
                                    r)
            self._groups_cache[leader] = g
        return g

    def set_members(self, members: Sequence[int]) -> None:
        """Membership changed: re-partition the relay groups.  Cached
        partitions (and the per-(leader, group) peer sets derived from them)
        are invalidated; rounds already in flight complete or fail over to
        the leader's timeout/retry path, which re-derives fresh groups."""
        self.all_nodes = list(members)
        self._groups_cache.clear()
        self._peers_cache.clear()

    # ---------------------------------------------------------------- leader
    def _pick_relay(self, group: List[int]) -> int:
        rng = self.node.sched.rng
        if not self.cfg.rotate_relays:
            return group[0]
        candidates = group
        if self.cfg.use_gray_list:
            now = self.node.sched.now
            healthy = [g for g in group if self.gray.get(g, 0.0) <= now]
            if healthy and (len(healthy) == len(group)
                            or rng.random() > self.cfg.gray_probe_prob):
                candidates = healthy
        return candidates[int(rng.integers(len(candidates)))]

    def _required_per_group(self, groups: List[List[int]]) -> List[int]:
        return required_per_group(groups, len(self.all_nodes), self.cfg.prc,
                                  self.cfg.single_group_majority)

    def broadcast(self, make_msg: Callable[[], Msg], round_key=None) -> list:
        """Start one Pig round per relay group.  Returns the pig ids used,
        so the caller can gray non-responsive relays on its own timeout."""
        ids = []
        groups = self.groups_for(self.node.id)
        required = self._required_per_group(groups)
        for gi, group in enumerate(groups):
            self._pig_seq += 1
            pid = self._pig_seq
            relay = self._pick_relay(group)
            self._outstanding[pid] = (gi, relay, round_key)
            self.node.send(relay, PigFanout(pig_id=pid, group=gi,
                                            inner=make_msg(),
                                            required=required[gi]))
            ids.append(pid)
        return ids

    def on_round_timeout(self, pig_ids) -> None:
        """Leader timed out on a round: gray the relays that never replied."""
        now = self.node.sched.now
        for pid in pig_ids:
            st = self._outstanding.pop(pid, None)
            if st is not None and self.cfg.use_gray_list:
                self.gray[st[1]] = now + self.cfg.gray_duration

    def leader_handle_aggregate(self, msg: PigAggregate) -> None:
        st = self._outstanding.pop(msg.pig_id, None)
        if st is None:
            return None
        # only nodes that made the relay *time out* are failure suspects;
        # nodes skipped by early PRC flushes are merely slow-this-round (§4.2)
        if self.cfg.use_gray_list and msg.timed_out:
            now = self.node.sched.now
            for m in msg.missing:
                self.gray[m] = now + self.cfg.gray_duration
        return None

    # ---------------------------------------------------------------- relay
    def _group_peers(self, leader: int, gi: int) -> tuple:
        """(peers, expect-set) for relay duty, cached per (leader, group).
        The expect set is shared across rounds — aggregation never mutates
        it (only reads / set-unions)."""
        key = (leader, gi)
        pe = self._peers_cache.get(key)
        if pe is None:
            groups = self.groups_for(leader)   # groups relative to the leader
            group = groups[gi] if gi < len(groups) else []
            peers = [p for p in group if p != self.node.id]
            pe = self._peers_cache.setdefault(key, (peers, set(peers)))
        return pe

    def on_PigFanout(self, msg: PigFanout) -> None:
        node = self.node
        peers, expect = self._group_peers(msg.src, msg.group)
        st = {
            "replies": [],
            "voters": set(),
            "required": msg.required,
            "leader": msg.src,
            "group": msg.group,
            "expect": expect,
            "done": False,
            "timer": None,
            # flush threshold: min(required, group size incl. the relay)
            "thresh": min(msg.required, len(peers) + 1),
        }
        tr = node.net.tracer
        if tr is not None:
            # remember the op's ctx + fan-in start so the timer-driven
            # flush can close a "relay" (aggregation-wait) span and the
            # PigAggregate rejoins the op's trace (repro.obs)
            ctx = tr.cur or tr.ctx_of(msg)
            if ctx is not None:
                st["trace"] = ctx
                st["t_fan"] = node.sched.now
        self._agg[msg.pig_id] = st
        # 1) act as a regular follower on the inner message (common case
        #    dispatched inline: P2a accept, skipping the process_inner frame)
        inner = msg.inner
        my_reply = (node._accept(inner) if inner.__class__ is P2a
                    else node.process_inner(inner))
        if my_reply is not None:
            self._accumulate(msg.pig_id, node.id, my_reply)
        # 2) re-transmit to the rest of the group (one shared wrapper:
        #    identical payload per peer, receivers don't mutate it)
        if peers:
            relayed = PigRelayed(pig_id=msg.pig_id, relay=node.id,
                                 inner=msg.inner)
            for p in peers:
                node.send(p, relayed)
        # 3) arm the relay timeout T_r (§3.4)
        st["timer"] = node.set_timer(self.cfg.relay_timeout,
                                     lambda: self._flush(msg.pig_id, timeout=True))
        self._maybe_flush(msg.pig_id)

    # ---------------------------------------------------------------- follower
    def on_PigRelayed(self, msg: PigRelayed) -> None:
        node = self.node
        inner = msg.inner
        reply = (node._accept(inner) if inner.__class__ is P2a
                 else node.process_inner(inner))
        if reply is not None:
            node.send(msg.relay, PigReply(pig_id=msg.pig_id, inner=reply))

    def on_PigReply(self, msg: PigReply) -> None:
        # fused accumulate + flush check (the per-reply hot path)
        pig_id = msg.pig_id
        st = self._agg.get(pig_id)
        if st is None:
            return
        reply = msg.inner
        if st["done"]:
            self._queue_late_vote(pig_id, st, msg.src, reply)
            return
        st["voters"].add(msg.src)
        st["replies"].append(reply)
        if reply.ok is False:
            # reject short-circuit (§3.2, footnote 1)
            self._flush(pig_id, reject=True)
        elif len(st["voters"]) >= st["thresh"]:
            self._flush(pig_id)

    # ---------------------------------------------------------------- agg
    def _accumulate(self, pig_id: int, voter: int, reply: Msg) -> None:
        st = self._agg.get(pig_id)
        if st is None:
            return
        if st["done"]:
            self._queue_late_vote(pig_id, st, voter, reply)
            return
        st["voters"].add(voter)
        st["replies"].append(reply)
        # reject short-circuit: don't wait for aggregation (§3.2, footnote 1).
        # process_inner only yields P1b/P2b replies, so .ok always exists.
        if reply.ok is False:
            self._flush(pig_id, reject=True)

    def _queue_late_vote(self, pig_id: int, st: dict, voter: int,
                         reply: Msg) -> None:
        """A vote arriving after the PRC/timeout flush.  The leader usually
        doesn't need it (other groups give the majority), so batch it for
        T_r and cancel if the slot is seen committed in the meantime; only a
        starved round actually pays the extra message (§4.1: 'requiring more
        communication to learn the missing votes')."""
        if voter in st["voters"] or not getattr(reply, "ok", True):
            return
        st["voters"].add(voter)
        if isinstance(reply, P1b):
            # leader election is liveness-critical: forward immediately
            sup = _P1Aggregate(PigAggregate(
                pig_id=pig_id, group=st["group"], ballot=reply.ballot,
                slot=-1, acks=1, voters=(voter,)), [reply])
            self.node.send(st["leader"], sup)
            return
        st.setdefault("late", []).append((voter, reply))
        if st.get("sup_timer") is None:
            st["sup_timer"] = self.node.set_timer(
                self.cfg.relay_timeout,
                lambda: self._send_supplement(pig_id))
            slot = getattr(reply, "slot", None)
            if slot is not None and slot >= 0:
                self._pending_sup[slot] = pig_id

    def _send_supplement(self, pig_id: int) -> None:
        st = self._agg.get(pig_id)
        if st is None or not st.get("late"):
            return
        late = st.pop("late")
        st["sup_timer"] = None
        first = late[0][1]
        self.node.send(st["leader"], PigAggregate(
            pig_id=pig_id, group=st["group"],
            ballot=getattr(first, "ballot", (0, 0)),
            slot=getattr(first, "slot", -1), acks=len(late),
            voters=tuple(v for v, _ in late), missing=()))

    def note_committed_up_to(self, ci: int) -> None:
        """Called when this node learns a commit index: pending supplements
        for committed slots are unnecessary — drop them."""
        if not self._pending_sup:
            return
        for slot in [s for s in self._pending_sup if s <= ci]:
            pid = self._pending_sup.pop(slot)
            st = self._agg.get(pid)
            if st is not None:
                st["late"] = []
                if st.get("sup_timer") is not None:
                    self.node.cancel_timer(st["sup_timer"])
                    st["sup_timer"] = None

    def _maybe_flush(self, pig_id: int) -> None:
        st = self._agg.get(pig_id)
        if st is None or st["done"]:
            return
        if len(st["voters"]) >= st["thresh"]:
            self._flush(pig_id)

    def _flush(self, pig_id: int, timeout: bool = False, reject: bool = False) -> None:
        st = self._agg.get(pig_id)
        if st is None or st["done"]:
            return
        st["done"] = True
        if st["timer"] is not None:
            self.node.cancel_timer(st["timer"])
        replies: List[Msg] = st["replies"]
        voters = st["voters"]
        if not timeout and not reject and len(voters) > len(st["expect"]):
            # fast path: full group voted, nothing missing, no rejects
            oks = replies
            rejects = []
            missing = ()
        else:
            oks = [r for r in replies if getattr(r, "ok", True)]
            rejects = [r for r in replies if not getattr(r, "ok", True)]
            missing = tuple(sorted((st["expect"] | {self.node.id}) - voters))
        proto = replies[0] if replies else None
        agg = PigAggregate(
            pig_id=pig_id,
            group=st["group"],
            ballot=getattr(proto, "ballot", (0, 0)),
            slot=getattr(proto, "slot", -1),
            acks=len(oks),
            voters=tuple(sorted(st["voters"])) if replies else (),
            missing=missing,
            timed_out=timeout,
            reject=bool(rejects) or reject,
            reject_ballot=max((getattr(r, "ballot", (0, 0)) for r in rejects),
                              default=(0, 0)),
        )
        # Phase-1 aggregation must carry the accepted-log bodies upward.
        p1 = [r for r in replies if isinstance(r, P1b)]
        if p1:
            agg = _P1Aggregate(agg, p1)
        tr = self.node.net.tracer
        if tr is not None:
            ctx = st.get("trace")
            if ctx is not None:
                # the relay-aggregation window: fan-in start -> flush
                sid = tr.add_span(ctx, "relay", self.node.id,
                                  st["t_fan"], self.node.sched.now)
                tr.attach(agg, (ctx[0], sid))
        self.node.send(st["leader"], agg)
        # keep the entry briefly so late votes become supplementary
        # aggregates (§4.1), then GC it
        st["replies"] = []
        self.node.set_timer(4 * self.cfg.relay_timeout,
                            lambda: self._agg.pop(pig_id, None))

    # ---------------------------------------------------------------- misc
    def note_commit(self, slot: int) -> None:
        pass


class _P1Aggregate(PigAggregate):
    """PigAggregate that additionally carries P1b bodies (value recovery)."""

    _kind_name = "PigAggregate"   # dispatch as the base type (see Msg.kind)

    def __init__(self, base: PigAggregate, p1bs: List[P1b]):
        super().__init__(pig_id=base.pig_id, group=base.group,
                         ballot=base.ballot, slot=base.slot, acks=base.acks,
                         voters=base.voters, missing=base.missing,
                         timed_out=base.timed_out,
                         reject=base.reject, reject_ballot=base.reject_ballot)
        self.p1bs = p1bs

    def wire_size(self) -> int:
        return super().wire_size() + sum(m.wire_size() for m in self.p1bs)
