"""JAX-vectorized performance model of Pig/Paxos communication.

Two complementary pieces (both jit/vmap-compiled, used by benchmarks and
property tests to cross-validate the discrete-event simulator and Eq. 1-3):

1. Monte-Carlo relay rotation (``relay_load_mc``): samples relay choices for
   thousands of rounds at once and returns per-node message-load statistics.
   Shows the amortization effect of rotation (§3.1) and reproduces M_f
   including its variance (which the closed form hides), plus the static
   relay hotspot that makes sqrt(N) optimal without rotation (§5.2).

2. Queueing model (``latency_curve``): each node is an M/D/1 server with
   service time = CPU cost/message (§2.2).  Request latency is the sum of
   hop latencies + queue waits along the Pig path; saturation = the busiest
   node reaching utilization 1.  Produces Fig 9-shaped hockey-stick curves
   analytically.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .analytical import epaxos_messages


# ---------------------------------------------------------------- Monte Carlo
@functools.partial(jax.jit, static_argnames=("n", "r", "rounds", "rotating"))
def relay_load_mc(key: jax.Array, n: int, r: int, rounds: int,
                  rotating: bool = True) -> dict:
    """Per-node messages/round across ``rounds`` Pig rounds (leader = node 0).

    Returns dict with 'mean' (n,), 'maxavg' (scalar: busiest node's mean
    load), 'leader' (scalar).  Message accounting matches network.py: every
    send counts at both endpoints.
    """
    followers = n - 1
    sizes = jnp.full((r,), followers // r).at[: followers % r].add(1)
    group_of = jnp.repeat(jnp.arange(r), sizes, total_repeat_length=followers)
    # followers are ids 1..n-1; follower f belongs to group_of[f-1]
    loads = jnp.zeros((rounds, n))
    # leader: 2R + 2 per round (client io included)
    loads = loads.at[:, 0].set(2 * r + 2)

    keys = jax.random.split(key, rounds)

    def per_round(k):
        # pick one relay per group
        u = jax.random.uniform(k, (followers,))
        if rotating:
            score = u
        else:
            score = jnp.arange(followers, dtype=jnp.float32)  # static: first member
        # relay of group g = argmin score within group
        masked = jnp.where(group_of[None, :] == jnp.arange(r)[:, None],
                           score[None, :], jnp.inf)
        relay_idx = jnp.argmin(masked, axis=1)              # (r,) follower index
        gsz = sizes[group_of]                               # (followers,)
        base = jnp.full((followers,), 2.0)                  # plain follower
        relay_load = 2.0 + 2.0 * (sizes - 1)                # fanout+agg + peers RT
        f_loads = base.at[relay_idx].set(relay_load)
        return f_loads

    f = jax.vmap(per_round)(keys)                           # (rounds, followers)
    loads = loads.at[:, 1:].set(f)
    mean = loads.mean(axis=0)
    return {"mean": mean, "maxavg": mean.max(), "leader": mean[0],
            "follower_mean": mean[1:].mean(), "per_round": loads}


def mc_summary(n: int, r: int, rounds: int = 4096, rotating: bool = True,
               seed: int = 0) -> dict:
    out = relay_load_mc(jax.random.PRNGKey(seed), n, r, rounds, rotating)
    return {k: np.asarray(v) for k, v in out.items() if k != "per_round"}


# ---------------------------------------------------------------- queueing
def _md1_wait(lam: jnp.ndarray, s: float) -> jnp.ndarray:
    """Mean wait in an M/D/1 queue with arrival rate lam, service time s."""
    rho = jnp.clip(lam * s, 0.0, 0.999)
    return rho * s / (2.0 * (1.0 - rho))


@functools.partial(jax.jit, static_argnames=("n", "r", "protocol"))
def latency_curve(offered: jnp.ndarray, n: int, r: int,
                  cpu_per_msg: float = 10e-6, hop: float = 0.25e-3,
                  protocol: str = "pigpaxos") -> dict:
    """Mean request latency vs offered load (req/s).  Returns latency (s)
    and per-node utilizations; latency -> inf past saturation."""
    if protocol == "paxos":
        m_l = 2.0 * (n - 1) + 2.0
        m_f = 2.0
        hops = 4          # client->L, L->F, F->L, L->client
        visits_l = m_l    # leader CPU touches per request
        visits_f = m_f
    elif protocol == "pigpaxos":
        m_l = 2.0 * r + 2.0
        m_f = 2.0 * (n - r - 1) / (n - 1) + 2.0
        hops = 6          # client->L, L->relay, relay->F, F->relay, relay->L, L->client
        visits_l = m_l
        visits_f = m_f
    else:  # epaxos (conflict-free fast path), all nodes symmetric
        m_f = epaxos_messages(n)
        m_l = m_f
        hops = 4
        visits_l = visits_f = m_f

    lam_l = offered * m_l
    lam_f = offered * m_f
    w_l = _md1_wait(lam_l, cpu_per_msg)
    w_f = _md1_wait(lam_f, cpu_per_msg)
    # each request pays leader queueing on its leader-CPU visits and one
    # follower/relay queue per remote hop
    lat = hops * hop + visits_l * (w_l + cpu_per_msg) + visits_f * (w_f + cpu_per_msg)
    rho_l = lam_l * cpu_per_msg
    sat = jnp.where(rho_l >= 1.0, jnp.inf, 0.0)
    return {"latency": lat + sat, "rho_leader": rho_l,
            "rho_follower": lam_f * cpu_per_msg}


def saturation_point(n: int, r: int, cpu_per_msg: float = 10e-6,
                     protocol: str = "pigpaxos") -> float:
    if protocol == "paxos":
        m = 2.0 * (n - 1) + 2.0
    elif protocol == "pigpaxos":
        m = max(2.0 * r + 2.0, 2.0 * (n - r - 1) / (n - 1) + 2.0)
    else:
        m = epaxos_messages(n)
    return 1.0 / (m * cpu_per_msg)
