"""Egalitarian Paxos (EPaxos) — the paper's strongest baseline (§5, §7.2).

Implemented faithfully enough for the paper's comparison:
  * every node is an opportunistic command leader (clients pick a random node);
  * PreAccept to the other replicas; fast-path commit when a fast quorum
    (3N/4, §5.3) returns identical (deps, seq); slow path runs an Accept
    round with a majority;
  * dependency tracking per key; commit before execute; execution orders
    strongly-connected components by sequence number;
  * message sizes grow with N (dependency bookkeeping), reproducing the
    paper's observation that 25-node EPaxos messages serialize ~4x slower
    than 5-node ones (§5.3) — see messages.CostModel.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from .events import Scheduler
from .messages import (ClientReply, ClientRequest, Command, EAccept,
                       EAcceptReply, ECommit, PreAccept, PreAcceptReply)
from .network import Network
from .node import Node
from .quorums import fast_quorum, majority


@dataclass
class _Inst:
    cmd: Optional[Command] = None
    deps: frozenset = frozenset()
    seq: int = 0
    state: str = "none"       # none|preaccepted|accepted|committed|executed
    client_src: int = -1
    replies: list = field(default_factory=list)
    accept_acks: int = 0
    is_mine: bool = False


class EPaxosNode(Node):
    def __init__(self, node_id: int, net: Network, sched: Scheduler,
                 peers: list[int]):
        super().__init__(node_id, net, sched)
        self.peers = list(peers)
        self.n = len(peers)
        self.fq = fast_quorum(self.n)
        self.maj = majority(self.n)
        self.next_inum = 0
        self.insts: Dict[tuple, _Inst] = {}
        # per-key: latest interfering instance per replica (standard EPaxos
        # optimization: depend on the most recent conflict per replica)
        self.interf: Dict[int, Dict[int, tuple]] = {}
        self._pending_exec: list = []
        # at-most-once execution: (client_id, seq) -> result.  A client
        # timeout retry can create a second instance of the same command at
        # a different command leader; both instances interfere (same key),
        # so every replica executes them in the same relative order and
        # makes the identical skip decision for the duplicate.  Keyed by the
        # exact op id (not a per-client high-water mark) because EPaxos only
        # orders *interfering* commands — a client's ops on different keys
        # may execute in different relative orders on different replicas.
        self._done_ops: Dict[tuple, Optional[bytes]] = {}
        self.committed_count = 0

    # ---------------------------------------------------------------- leader
    def on_ClientRequest(self, msg: ClientRequest) -> None:
        cmd = msg.cmd
        inst_id = (self.id, self.next_inum)
        self.next_inum += 1
        deps = self._conflicts(cmd.key, exclude=inst_id)
        seq = 1 + max([self.insts[d].seq for d in deps], default=0)
        inst = _Inst(cmd=cmd, deps=deps, seq=seq, state="preaccepted",
                     client_src=msg.src, is_mine=True)
        self.insts[inst_id] = inst
        self._note_interf(cmd.key, inst_id)
        # one shared instance per broadcast: receivers never mutate messages
        m = PreAccept(inst=inst_id, cmd=cmd, deps=deps, seq=seq,
                      n_cluster=self.n)
        for p in self.peers:
            if p != self.id:
                self.send(p, m)

    def _conflicts(self, key: int, exclude: tuple) -> frozenset:
        m = self.interf.get(key)
        if not m:
            return frozenset()
        return frozenset(v for v in m.values() if v != exclude)

    def _note_interf(self, key: int, inst_id: tuple) -> None:
        self.interf.setdefault(key, {})[inst_id[0]] = inst_id

    # -------------------------------------------------------------- replicas
    def on_PreAccept(self, msg: PreAccept) -> None:
        local = self._conflicts(msg.cmd.key, exclude=msg.inst)
        deps = msg.deps | local
        seq = max(msg.seq, 1 + max([self.insts[d].seq for d in local
                                    if d in self.insts], default=0))
        inst = self.insts.setdefault(msg.inst, _Inst())
        if inst.state in ("committed", "executed"):
            return
        inst.cmd, inst.deps, inst.seq, inst.state = msg.cmd, deps, seq, "preaccepted"
        self._note_interf(msg.cmd.key, msg.inst)
        self.send(msg.src, PreAcceptReply(inst=msg.inst, ok=True, deps=deps,
                                          seq=seq, n_cluster=self.n))

    def on_PreAcceptReply(self, msg: PreAcceptReply) -> None:
        inst = self.insts.get(msg.inst)
        if inst is None or not inst.is_mine or inst.state != "preaccepted":
            return
        inst.replies.append(msg)
        if len(inst.replies) < self.fq - 1:
            return
        # fast path: fast quorum (incl. self) agrees on (deps, seq)
        if all(r.deps == inst.deps and r.seq == inst.seq for r in inst.replies):
            self._commit(msg.inst, inst)
        else:
            # slow path: union deps, max seq, Paxos-accept round
            for r in inst.replies:
                inst.deps = inst.deps | r.deps
                inst.seq = max(inst.seq, r.seq)
            inst.state = "accepted"
            inst.accept_acks = 1
            m = EAccept(inst=msg.inst, cmd=inst.cmd, deps=inst.deps,
                        seq=inst.seq, n_cluster=self.n)
            for p in self.peers:
                if p != self.id:
                    self.send(p, m)

    def on_EAccept(self, msg: EAccept) -> None:
        inst = self.insts.setdefault(msg.inst, _Inst())
        if inst.state in ("committed", "executed"):
            return
        inst.cmd, inst.deps, inst.seq, inst.state = msg.cmd, msg.deps, msg.seq, "accepted"
        self._note_interf(msg.cmd.key, msg.inst)
        self.send(msg.src, EAcceptReply(inst=msg.inst, ok=True))

    def on_EAcceptReply(self, msg: EAcceptReply) -> None:
        inst = self.insts.get(msg.inst)
        if inst is None or not inst.is_mine or inst.state != "accepted":
            return
        inst.accept_acks += 1
        if inst.accept_acks >= self.maj:
            self._commit(msg.inst, inst)

    # ---------------------------------------------------------------- commit
    def _commit(self, inst_id: tuple, inst: _Inst) -> None:
        inst.state = "committed"
        self.committed_count += 1
        m = ECommit(inst=inst_id, cmd=inst.cmd, deps=inst.deps, seq=inst.seq,
                    n_cluster=self.n)
        for p in self.peers:
            if p != self.id:
                self.send(p, m)
        self._pending_exec.append(inst_id)
        self._drain_exec()

    def on_ECommit(self, msg: ECommit) -> None:
        inst = self.insts.setdefault(msg.inst, _Inst())
        inst.cmd, inst.deps, inst.seq = msg.cmd, msg.deps, msg.seq
        if inst.state != "executed":
            inst.state = "committed"
        self._note_interf(msg.cmd.key, msg.inst)
        self._pending_exec.append(msg.inst)
        self._drain_exec()

    def _drain_exec(self) -> None:
        """Retry blocked instances until no more progress can be made."""
        progress = True
        while progress:
            progress = False
            still = []
            for iid in self._pending_exec:
                if self.insts[iid].state == "executed":
                    progress = True
                    continue
                if self._try_execute(iid):
                    progress = True
                else:
                    still.append(iid)
            self._pending_exec = still

    # --------------------------------------------------------------- execute
    def _try_execute(self, start: tuple) -> bool:
        """Execute committed instances: SCCs in dependency order, ties by
        (seq, instance id) — the EPaxos execution algorithm."""
        # Tarjan over committed subgraph reachable from ``start``
        sys_stack = [start]
        index: Dict[tuple, int] = {}
        low: Dict[tuple, int] = {}
        onstack: Dict[tuple, bool] = {}
        stack: list = []
        counter = [0]
        sccs: list = []
        blocked = [False]

        def strongconnect(v: tuple) -> None:
            work = [(v, iter(sorted(self.insts[v].deps)))]
            index[v] = low[v] = counter[0]
            counter[0] += 1
            stack.append(v)
            onstack[v] = True
            while work:
                node, it = work[-1]
                advanced = False
                for w in it:
                    iw = self.insts.get(w)
                    if iw is None or iw.state in ("none", "preaccepted", "accepted"):
                        blocked[0] = True    # an uncommitted dep: defer
                        continue
                    if iw.state == "executed":
                        continue
                    if w not in index:
                        index[w] = low[w] = counter[0]
                        counter[0] += 1
                        stack.append(w)
                        onstack[w] = True
                        work.append((w, iter(sorted(self.insts[w].deps))))
                        advanced = True
                        break
                    elif onstack.get(w):
                        low[node] = min(low[node], index[w])
                if advanced:
                    continue
                work.pop()
                if work:
                    low[work[-1][0]] = min(low[work[-1][0]], low[node])
                if low[node] == index[node]:
                    scc = []
                    while True:
                        w = stack.pop()
                        onstack[w] = False
                        scc.append(w)
                        if w == node:
                            break
                    sccs.append(scc)

        inst0 = self.insts.get(start)
        if inst0 is None or inst0.state != "committed":
            return inst0 is not None and inst0.state == "executed"
        strongconnect(start)
        if blocked[0]:
            return False   # retried by _drain_exec when the dep commits
        for scc in sccs:   # Tarjan emits SCCs in reverse topological order
            for iid in sorted(scc, key=lambda i: (self.insts[i].seq, i)):
                self._execute(iid)
        return True

    def _execute(self, inst_id: tuple) -> None:
        inst = self.insts[inst_id]
        if inst.state == "executed":
            return
        cmd = inst.cmd
        op_id = (cmd.client_id, cmd.seq)
        done = self._done_ops
        if op_id in done:
            # duplicate instance of an already-executed op (client timeout
            # retry): skip the apply, answer from the cached result
            inst.state = "executed"
            if inst.is_mine and inst.client_src >= 0:
                self.send(inst.client_src,
                          ClientReply(client_id=cmd.client_id, seq=cmd.seq,
                                      ok=True, value=done[op_id]))
            return
        val = self.store.apply(cmd)
        done[op_id] = val
        self.applied_log.append((inst_id, cmd))
        inst.state = "executed"
        if inst.is_mine and inst.client_src >= 0:
            self.send(inst.client_src,
                      ClientReply(client_id=cmd.client_id,
                                  seq=cmd.seq, ok=True, value=val))
