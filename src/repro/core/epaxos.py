"""Egalitarian Paxos (EPaxos) — the paper's strongest baseline (§5, §7.2).

Implemented faithfully enough for the paper's comparison:
  * every node is an opportunistic command leader (clients pick a random node);
  * PreAccept to the other replicas; fast-path commit when a fast quorum
    (3N/4, §5.3) returns identical (deps, seq); slow path runs an Accept
    round with a majority;
  * dependency tracking per key; commit before execute; execution orders
    strongly-connected components by sequence number;
  * message sizes grow with N (dependency bookkeeping), reproducing the
    paper's observation that 25-node EPaxos messages serialize ~4x slower
    than 5-node ones (§5.3) — see messages.CostModel.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from .events import Scheduler
from .messages import (BatchCmd, ClientReply, ClientRequest, Command, EAccept,
                       EAcceptReply, ECommit, EPrepare, EPrepareReply,
                       JoinReq, PreAccept, PreAcceptReply, ReadProbe,
                       ReadReply, Snapshot)
from .network import Network
from .node import Node
from .paxos import BatchConfig
from .quorums import fast_quorum, majority


@dataclass
class _Inst:
    cmd: Optional[Command] = None
    deps: frozenset = frozenset()
    seq: int = 0
    state: str = "none"       # none|preaccepted|accepted|committed|executed
    client_src: int = -1
    replies: list = field(default_factory=list)
    accept_acks: int = 0
    is_mine: bool = False
    # explicit-prepare recovery: ballot the current attributes were
    # (pre-)accepted at, and the highest ballot promised for this instance.
    # The original command leader proposes at (0, 0); recovery ballots are
    # (epoch >= 1, recoverer_id), so they always win comparisons.
    ballot: tuple = (0, 0)
    max_ballot: tuple = (0, 0)
    # batching/pipelining extensions (None/False on the unbatched path)
    client_srcs: Optional[tuple] = None   # per-sub-command reply routing
    gated: bool = False                   # counted against pipeline_depth
    # observability: trace ctx of the proposing op (None when untraced) —
    # deferred execution (dep-wait) replies rejoin the span tree through it
    trace: Optional[tuple] = None


@dataclass
class _Recovery:
    """One in-flight explicit-prepare recovery (per instance)."""
    ballot: tuple
    phase: str = "prepare"              # "prepare" | "accept"
    replies: dict = field(default_factory=dict)   # src -> EPrepareReply
    acks: int = 0


class EPaxosNode(Node):
    def __init__(self, node_id: int, net: Network, sched: Scheduler,
                 peers: list[int], recovery_timeout: float = 100e-3,
                 batch: Optional[BatchConfig] = None,
                 pipeline_depth: int = 0):
        super().__init__(node_id, net, sched)
        self.peers = list(peers)
        self.n = len(peers)
        self.fq = fast_quorum(self.n)
        self.maj = majority(self.n)
        self.next_inum = 0
        self.insts: Dict[tuple, _Inst] = {}
        # leaderless batching: every node batches the requests IT receives
        # (clients pick random command leaders, so each node runs its own
        # buffer).  pipeline_depth throttles this node's own uncommitted
        # instances; 0 = unbounded (native behavior).
        if pipeline_depth < 0:
            raise ValueError("pipeline_depth must be >= 0")
        self.batch = batch
        self.pipeline_depth = pipeline_depth
        self._batching = batch is not None or pipeline_depth > 0
        self._buf: list = []            # (cmd, client_src) awaiting an inst
        self._buf_timer: Optional[int] = None
        self._held: list = []           # sealed batches awaiting pipeline room
        self._inflight = 0              # own gated insts proposed, uncommitted
        # ---- explicit-prepare recovery (off unless a fault plan enables
        # it: arming probe timers on every transiently-blocked dependency
        # would perturb the golden traces and the fault-free hot path) ----
        self.recovery_enabled = False
        self.recovery_timeout = recovery_timeout
        self._recover_armed: set = set()          # inst ids with a probe timer
        self._recoveries: Dict[tuple, _Recovery] = {}
        # per-key: latest interfering instance per replica (standard EPaxos
        # optimization: depend on the most recent conflict per replica)
        self.interf: Dict[int, Dict[int, tuple]] = {}
        # quorum-read frontier: key -> (executed-put count, wtag).  The
        # put-count is a consistent per-key version across replicas because
        # interfering commands execute in the same relative order everywhere.
        self._applied_ver: Dict[int, tuple] = {}
        self._pending_exec: list = []
        # at-most-once execution: (client_id, seq) -> result.  A client
        # timeout retry can create a second instance of the same command at
        # a different command leader; both instances interfere (same key),
        # so every replica executes them in the same relative order and
        # makes the identical skip decision for the duplicate.  Keyed by the
        # exact op id (not a per-client high-water mark) because EPaxos only
        # orders *interfering* commands — a client's ops on different keys
        # may execute in different relative orders on different replicas.
        self._done_ops: Dict[tuple, Optional[bytes]] = {}
        # membership state (single-server reconfiguration): cfg commands ride
        # the normal instance space but interfere with EVERY command (they
        # depend on all latest instances and everything after depends on
        # them), so all replicas execute the switch at the same point of the
        # dependency order.  One deterministic proposer (the lowest member,
        # routed by Cluster) approximates the one-at-a-time invariant.
        self.members: list = sorted(peers)
        self.joining = False
        self.removed = False
        self._last_cfg: Optional[tuple] = None    # latest cfg instance id
        self._cfg_seq = 0
        self._leader_ref = None
        self._join_catch_up = True
        self._snap_installed = False
        self.on_membership_change = None
        self.committed_count = 0

    # ---------------------------------------------------------------- leader
    def on_ClientRequest(self, msg: ClientRequest) -> None:
        if self.joining or self.removed:
            # not (yet / anymore) a member: bounce like a non-leader Paxos
            # node so the client re-picks from the current membership
            self.send(msg.src, ClientReply(client_id=msg.cmd.client_id,
                                           seq=msg.cmd.seq, ok=False))
            return
        if self._batching:
            self._enqueue(msg.cmd, msg.src)
            return
        self._propose_cmd(msg.cmd, msg.src)

    # ------------------------------------------------ batching + pipelining
    def _enqueue(self, cmd: Command, client_src: int) -> None:
        self._buf.append((cmd, client_src))
        b = self.batch
        if b is None or len(self._buf) >= b.max_batch:
            self._flush_buf()
        elif self._buf_timer is None:
            self._buf_timer = self.set_timer(b.max_delay_ms * 1e-3,
                                             self._buf_timeout)

    def _buf_timeout(self) -> None:
        self._buf_timer = None
        self._flush_buf()

    def _flush_buf(self) -> None:
        if self._buf_timer is not None:
            self.cancel_timer(self._buf_timer)
            self._buf_timer = None
        if not self._buf:
            return
        buf = self._buf
        self._buf = []
        d = self.pipeline_depth
        if d > 0 and self._inflight >= d:
            self._held.append(buf)     # pipeline full: hold the sealed batch
            return
        self._propose_batch(buf)

    def _propose_batch(self, buf: list) -> None:
        gated = self.pipeline_depth > 0
        if gated:
            self._inflight += 1
        if len(buf) == 1:
            cmd, src = buf[0]
            iid = self._propose_cmd(cmd, src)
        else:
            iid = self._propose_cmd(BatchCmd(cmds=tuple(c for c, _ in buf)),
                                    client_src=-1,
                                    client_srcs=tuple(s for _, s in buf))
        if gated:
            self.insts[iid].gated = True

    def _release_held(self) -> None:
        d = self.pipeline_depth
        while self._held and (d <= 0 or self._inflight < d):
            self._propose_batch(self._held.pop(0))

    def _drop_buffers(self, bounce: bool) -> None:
        if self._buf_timer is not None:
            self.cancel_timer(self._buf_timer)
            self._buf_timer = None
        pending = self._buf + [p for b in self._held for p in b]
        self._buf = []
        self._held = []
        self._inflight = 0
        if bounce:
            for cmd, src in pending:
                if src >= 0:
                    self.send(src, ClientReply(client_id=cmd.client_id,
                                               seq=cmd.seq, ok=False))

    def _propose_cmd(self, cmd: Command, client_src: int,
                     client_srcs: Optional[tuple] = None) -> tuple:
        inst_id = (self.id, self.next_inum)
        self.next_inum += 1
        deps = self._deps_for(cmd, exclude=inst_id)
        seq = 1 + max([self.insts[d].seq for d in deps
                       if d in self.insts], default=0)
        inst = _Inst(cmd=cmd, deps=deps, seq=seq, state="preaccepted",
                     client_src=client_src, is_mine=True,
                     client_srcs=client_srcs)
        tr = self.net.tracer
        if tr is not None:
            inst.trace = tr.cur   # ambient ClientRequest ctx (None on timers)
        self.insts[inst_id] = inst
        self._note_cmd(cmd, inst_id)
        # one shared instance per broadcast: receivers never mutate messages
        m = PreAccept(inst=inst_id, cmd=cmd, deps=deps, seq=seq,
                      n_cluster=self.n)
        if tr is not None and inst.trace is not None:
            tr.attach(m, inst.trace)
        for p in self.peers:
            if p != self.id:
                self.send(p, m)
        return inst_id

    def _conflicts(self, key: int, exclude: tuple) -> frozenset:
        m = self.interf.get(key)
        if not m:
            return frozenset()
        return frozenset(v for v in m.values() if v != exclude)

    def _deps_for(self, cmd: Command, exclude: tuple) -> frozenset:
        """Dependency set for a command: per-key conflicts for data ops
        (plus the latest cfg instance, so every command orders after the
        membership switch), ALL latest instances for cfg ops."""
        op = cmd.op
        if op == "put" or op == "get":
            deps = self._conflicts(cmd.key, exclude=exclude)
            lc = self._last_cfg
            if lc is not None and lc != exclude and lc not in deps:
                deps = deps | {lc}
            return deps
        if op == "batch":
            # a batch interferes with whatever any sub-command interferes with
            bs: set = set()
            for c in cmd.cmds:
                bs.update(self._conflicts(c.key, exclude=exclude))
            lc = self._last_cfg
            if lc is not None and lc != exclude:
                bs.add(lc)
            return frozenset(bs)
        ds: set = set()
        for m in self.interf.values():
            ds.update(m.values())
        if self._last_cfg is not None:
            ds.add(self._last_cfg)
        ds.discard(exclude)
        return frozenset(ds)

    def _note_interf(self, key: int, inst_id: tuple) -> None:
        self.interf.setdefault(key, {})[inst_id[0]] = inst_id

    def _note_cmd(self, cmd: Command, inst_id: tuple) -> None:
        op = cmd.op
        if op == "put" or op == "get":
            self._note_interf(cmd.key, inst_id)
        elif op == "batch":
            for c in cmd.cmds:
                self._note_interf(c.key, inst_id)
        else:
            # cfg commands live outside the per-key map (their ``key`` is a
            # node id and must not collide with data keys)
            self._last_cfg = inst_id

    # -------------------------------------------------------------- replicas
    def on_PreAccept(self, msg: PreAccept) -> None:
        local = self._deps_for(msg.cmd, exclude=msg.inst)
        deps = msg.deps | local
        seq = max(msg.seq, 1 + max([self.insts[d].seq for d in local
                                    if d in self.insts], default=0))
        inst = self.insts.setdefault(msg.inst, _Inst())
        if inst.state in ("committed", "executed"):
            return
        if msg.ballot < inst.max_ballot:
            return    # a recovery already raised this instance's ballot
        inst.cmd, inst.deps, inst.seq, inst.state = msg.cmd, deps, seq, "preaccepted"
        self._note_cmd(msg.cmd, msg.inst)
        if self.joining or self.removed:
            return    # non-members record state but never vote
        self.send(msg.src, PreAcceptReply(inst=msg.inst, ok=True, deps=deps,
                                          seq=seq, n_cluster=self.n))

    def on_PreAcceptReply(self, msg: PreAcceptReply) -> None:
        inst = self.insts.get(msg.inst)
        # max_ballot > ballot means a recovery prepare preempted the
        # original (0, 0) round: stop counting, or a delayed round could
        # fast-path commit attributes diverging from the recoverer's
        if inst is None or not inst.is_mine or inst.state != "preaccepted" \
                or inst.max_ballot > inst.ballot:
            return
        inst.replies.append(msg)
        if len(inst.replies) < self.fq - 1:
            return
        # fast path: fast quorum (incl. self) agrees on (deps, seq)
        if all(r.deps == inst.deps and r.seq == inst.seq for r in inst.replies):
            self._commit(msg.inst, inst)
        else:
            # slow path: union deps, max seq, Paxos-accept round
            for r in inst.replies:
                inst.deps = inst.deps | r.deps
                inst.seq = max(inst.seq, r.seq)
            inst.state = "accepted"
            inst.accept_acks = 1
            m = EAccept(inst=msg.inst, cmd=inst.cmd, deps=inst.deps,
                        seq=inst.seq, n_cluster=self.n)
            tr = self.net.tracer
            if tr is not None and inst.trace is not None:
                tr.attach(m, inst.trace)   # slow-path round stays on-trace
            for p in self.peers:
                if p != self.id:
                    self.send(p, m)

    def on_EAccept(self, msg: EAccept) -> None:
        inst = self.insts.setdefault(msg.inst, _Inst())
        if inst.state in ("committed", "executed"):
            return
        if msg.ballot < inst.max_ballot:
            # stale accept round (a recovery preempted it): reject so the
            # sender stops counting; never true on the fault-free path,
            # where every ballot is the original (0, 0)
            self.send(msg.src, EAcceptReply(inst=msg.inst, ok=False,
                                            ballot=inst.max_ballot))
            return
        inst.max_ballot = max(inst.max_ballot, msg.ballot)
        inst.ballot = msg.ballot
        inst.cmd, inst.deps, inst.seq, inst.state = msg.cmd, msg.deps, msg.seq, "accepted"
        if msg.cmd is not None:       # recovery no-ops carry no command
            self._note_cmd(msg.cmd, msg.inst)
        if self.joining or self.removed:
            return    # non-members record state but never vote
        self.send(msg.src, EAcceptReply(inst=msg.inst, ok=True,
                                        ballot=msg.ballot))

    def on_EAcceptReply(self, msg: EAcceptReply) -> None:
        rec = self._recoveries.get(msg.inst)
        if rec is not None and rec.phase == "accept":
            self._recovery_accept_reply(msg.inst, rec, msg)
            return
        inst = self.insts.get(msg.inst)
        # acks must match the ballot the attributes were accepted at — a
        # recovery that preempted the original round leaves its own ballot
        # on the instance, so stale (0, 0) acks stop counting
        if inst is None or not inst.is_mine or inst.state != "accepted" \
                or not msg.ok or msg.ballot != inst.ballot:
            return
        inst.accept_acks += 1
        if inst.accept_acks >= self.maj:
            self._commit(msg.inst, inst)

    # ---------------------------------------------------------------- commit
    def _commit(self, inst_id: tuple, inst: _Inst) -> None:
        inst.state = "committed"
        # count a commit once cluster-wide: at the owning coordinator only.
        # Recovery commits (is_mine False at the recoverer) stay uncounted —
        # dueling recoverers may both reach this point for one instance, and
        # a small undercount beats inflating the summed committed stat
        if inst.cmd is not None and inst.is_mine:
            self.committed_count += 1
        if inst.gated:
            inst.gated = False
            self._inflight -= 1
            if self._held:
                self._release_held()
        m = ECommit(inst=inst_id, cmd=inst.cmd, deps=inst.deps, seq=inst.seq,
                    n_cluster=self.n)
        tr = self.net.tracer
        if tr is not None and inst.trace is not None:
            tr.attach(m, inst.trace)
        for p in self.peers:
            if p != self.id:
                self.send(p, m)
        self._pending_exec.append(inst_id)
        self._drain_exec()

    def on_ECommit(self, msg: ECommit) -> None:
        inst = self.insts.setdefault(msg.inst, _Inst())
        if inst.state in ("committed", "executed"):
            return                    # recovery re-broadcasts are idempotent
        inst.cmd, inst.deps, inst.seq = msg.cmd, msg.deps, msg.seq
        inst.state = "committed"
        if msg.cmd is not None:
            self._note_cmd(msg.cmd, msg.inst)
        self._pending_exec.append(msg.inst)
        self._drain_exec()

    def _drain_exec(self) -> None:
        """Retry blocked instances until no more progress can be made."""
        progress = True
        while progress:
            progress = False
            still = []
            for iid in self._pending_exec:
                if self.insts[iid].state == "executed":
                    progress = True
                    continue
                if self._try_execute(iid):
                    progress = True
                else:
                    still.append(iid)
            self._pending_exec = still

    # --------------------------------------------------------------- execute
    def _try_execute(self, start: tuple) -> bool:
        """Execute committed instances: SCCs in dependency order, ties by
        (seq, instance id) — the EPaxos execution algorithm."""
        # Tarjan over committed subgraph reachable from ``start``
        sys_stack = [start]
        index: Dict[tuple, int] = {}
        low: Dict[tuple, int] = {}
        onstack: Dict[tuple, bool] = {}
        stack: list = []
        counter = [0]
        sccs: list = []
        blocked = [False]
        track = self.recovery_enabled

        def strongconnect(v: tuple) -> None:
            work = [(v, iter(sorted(self.insts[v].deps)))]
            index[v] = low[v] = counter[0]
            counter[0] += 1
            stack.append(v)
            onstack[v] = True
            while work:
                node, it = work[-1]
                advanced = False
                for w in it:
                    iw = self.insts.get(w)
                    if iw is None or iw.state in ("none", "preaccepted", "accepted"):
                        blocked[0] = True    # an uncommitted dep: defer
                        if track:
                            # fault mode: a dep stuck uncommitted past the
                            # probe timeout gets an explicit-prepare recovery
                            self._arm_recovery(w)
                        continue
                    if iw.state == "executed":
                        continue
                    if w not in index:
                        index[w] = low[w] = counter[0]
                        counter[0] += 1
                        stack.append(w)
                        onstack[w] = True
                        work.append((w, iter(sorted(self.insts[w].deps))))
                        advanced = True
                        break
                    elif onstack.get(w):
                        low[node] = min(low[node], index[w])
                if advanced:
                    continue
                work.pop()
                if work:
                    low[work[-1][0]] = min(low[work[-1][0]], low[node])
                if low[node] == index[node]:
                    scc = []
                    while True:
                        w = stack.pop()
                        onstack[w] = False
                        scc.append(w)
                        if w == node:
                            break
                    sccs.append(scc)

        inst0 = self.insts.get(start)
        if inst0 is None or inst0.state != "committed":
            return inst0 is not None and inst0.state == "executed"
        strongconnect(start)
        if blocked[0]:
            return False   # retried by _drain_exec when the dep commits
        for scc in sccs:   # Tarjan emits SCCs in reverse topological order
            for iid in sorted(scc, key=lambda i: (self.insts[i].seq, i)):
                self._execute(iid)
        return True

    def _execute(self, inst_id: tuple) -> None:
        inst = self.insts[inst_id]
        if inst.state == "executed":
            return
        cmd = inst.cmd
        if cmd is None:
            # recovered no-op (no quorum member ever saw the command): mark
            # executed without touching the store — successors unblock, the
            # client's retry re-proposes the real command elsewhere
            inst.state = "executed"
            return
        if cmd.__class__ is BatchCmd:
            # apply sub-commands in batch order, each through the same
            # at-most-once dedup; replicas make identical skip decisions
            done = self._done_ops
            results = []
            for c in cmd.cmds:
                op_id = (c.client_id, c.seq)
                if op_id in done:
                    results.append(done[op_id])
                    continue
                val = self.store.apply(c)
                done[op_id] = val
                self.applied_log.append((inst_id, c))
                if c.op == "put":
                    v = self._applied_ver.get(c.key)
                    self._applied_ver[c.key] = ((v[0] if v else 0) + 1, op_id)
                results.append(val)
            inst.state = "executed"
            srcs = inst.client_srcs
            if inst.is_mine and srcs:
                tr = self.net.tracer
                owner = (tr.meta[inst.trace[0]]["client"]
                         if tr is not None and inst.trace is not None else -1)
                for c, src, val in zip(cmd.cmds, srcs, results):
                    if src >= 0:
                        reply = ClientReply(client_id=c.client_id,
                                            seq=c.seq, ok=True, value=val)
                        if src == owner:
                            tr.attach(reply, inst.trace)
                        self.send(src, reply)
            return
        op_id = (cmd.client_id, cmd.seq)
        done = self._done_ops
        if op_id in done:
            # duplicate instance of an already-executed op (client timeout
            # retry): skip the apply, answer from the cached result
            inst.state = "executed"
            if inst.is_mine and inst.client_src >= 0:
                self.send(inst.client_src,
                          ClientReply(client_id=cmd.client_id, seq=cmd.seq,
                                      ok=True, value=done[op_id]))
            return
        if cmd.op != "put" and cmd.op != "get":
            # configuration command: activates membership, not the store
            done[op_id] = None
            self.applied_log.append((inst_id, cmd))
            inst.state = "executed"
            self._apply_membership(cmd)
            return
        val = self.store.apply(cmd)
        done[op_id] = val
        self.applied_log.append((inst_id, cmd))
        if cmd.op == "put":
            v = self._applied_ver.get(cmd.key)
            self._applied_ver[cmd.key] = ((v[0] if v else 0) + 1, op_id)
        inst.state = "executed"
        if inst.is_mine and inst.client_src >= 0:
            reply = ClientReply(client_id=cmd.client_id,
                                seq=cmd.seq, ok=True, value=val)
            tr = self.net.tracer
            if tr is not None and inst.trace is not None:
                tr.attach(reply, inst.trace)
            self.send(inst.client_src, reply)

    # ========================================================== quorum reads
    def on_ReadProbe(self, msg: ReadProbe) -> None:
        """Per-key frontier for client-side quorum reads.  ``applied`` is
        this replica's executed-put count for the key; ``accepted`` adds 1
        when a known interfering instance has not executed here yet (the
        client rinses until some quorum member has executed everything the
        quorum knows about)."""
        key = msg.key
        av = self._applied_ver.get(key)
        ver, wtag = av if av is not None else (0, None)
        acc = ver
        m = self.interf.get(key)
        if m:
            for iid in m.values():
                inst = self.insts.get(iid)
                if inst is None or (inst.state != "executed"
                                    and inst.cmd is not None
                                    and inst.cmd.op != "get"):
                    acc = ver + 1
                    break
        self.send(msg.src, ReadReply(rid=msg.rid, key=key, applied=ver,
                                     accepted=acc,
                                     value=self.store.data.get(key),
                                     wtag=wtag))

    # ===================================================== membership change
    def propose_reconfig(self, op: str, nid: int) -> bool:
        """Propose a single-server membership change as a cfg instance.
        Routed by ``Cluster`` to one deterministic proposer (the lowest
        member), which refuses a second cfg while one is still un-executed —
        the one-at-a-time invariant, leaderless edition."""
        if self.joining or self.removed:
            return False
        lc = self._last_cfg
        if lc is not None:
            prev = self.insts.get(lc)
            if prev is not None and prev.state != "executed":
                return False               # previous cfg still in flight
        if (op == "add_node") == (nid in self.members):
            return False                   # no-op change
        self._cfg_seq += 1
        cmd = Command(client_id=-(self.id + 1), seq=self._cfg_seq,
                      op=op, key=nid)
        self._propose_cmd(cmd, client_src=-1)
        return True

    def _apply_membership(self, cmd: Command) -> None:
        """Activate an executed cfg command.  Ordered identically on every
        replica because cfg instances interfere with everything."""
        nid = cmd.key
        members = self.members
        if cmd.op == "add_node":
            if nid not in members:
                members.append(nid)
                members.sort()
        elif cmd.op == "remove_node":
            if nid in members:
                members.remove(nid)
            if nid == self.id:
                self.removed = True
                if self._batching:
                    self._drop_buffers(bounce=True)
        else:
            raise RuntimeError(f"unknown configuration op {cmd.op!r}")
        self._refresh_quorums()
        if cmd.op == "add_node" and nid != self.id \
                and cmd.client_id == -(self.id + 1):
            # the proposer confirms the join directly: the new node never
            # executes this cfg instance (it has no dependency history), so
            # it learns "you are a member now" out of band
            self.send(nid, Snapshot(members=tuple(members),
                                    payload={"confirm": True}))
        cb = self.on_membership_change
        if cb is not None:
            cb(self, cmd.op, nid)

    def _refresh_quorums(self) -> None:
        self.peers = list(self.members)
        self.n = len(self.peers)
        self.fq = fast_quorum(self.n)
        self.maj = majority(self.n)

    def begin_join(self, leader_ref, catch_up: bool = True) -> None:
        """Learner protocol: fetch a state snapshot from the cfg proposer,
        then stay mute (recording but never voting) until the proposer's
        confirm promotes this node to a member.  ``catch_up=False`` is the
        deliberately-broken control for the auditor tests."""
        self.joining = True
        self._leader_ref = leader_ref
        self._join_catch_up = catch_up
        self._snap_installed = False
        self._send_join()

    def _send_join(self) -> None:
        if not self.joining or self.crashed:
            return
        self.send(self._leader_ref(), JoinReq(node=self.id))
        self.set_timer(4 * self.recovery_timeout, self._send_join)

    def on_JoinReq(self, msg: JoinReq) -> None:
        if self.joining or self.removed:
            return
        nid = msg.node
        payload = {
            "interf": {k: dict(m) for k, m in self.interf.items()},
            # executed instances ship as stubs: the execution graph skips
            # executed-state dependencies, so the joiner can order new
            # commands without replaying history
            "executed": [(iid, inst.seq) for iid, inst in self.insts.items()
                         if inst.state == "executed"],
            "last_cfg": self._last_cfg,
        }
        self.send(nid, Snapshot(store=dict(self.store.data),
                                session=dict(self._done_ops),
                                members=tuple(self.members),
                                payload=payload))
        if nid not in self.members:
            self.propose_reconfig("add_node", nid)

    def on_Snapshot(self, msg: Snapshot) -> None:
        p = msg.payload or {}
        if p.get("confirm"):
            if self.joining:
                self.members = sorted(set(msg.members) | {self.id})
                self._refresh_quorums()
                self.joining = False
            return
        if not self.joining or self._snap_installed:
            return                         # only the first snapshot installs
        self._snap_installed = True
        if self._join_catch_up:
            self.store.data = dict(msg.store)
            self._done_ops = dict(msg.session)
            self.interf = {k: dict(m) for k, m in p.get("interf", {}).items()}
            for iid, seq in p.get("executed", ()):
                self.insts.setdefault(iid, _Inst(state="executed", seq=seq))
            self._last_cfg = p.get("last_cfg")
        self.applied_log = []
        self.members = sorted(msg.members)
        self._refresh_quorums()

    # ======================================================= recovery (§4.7)
    # Explicit-prepare instance recovery: when a command leader crashes with
    # instances in flight, peers whose execution stays blocked on them run a
    # per-instance prepare phase with a higher ballot, adopt the highest
    # (pre-)accepted attributes a majority reports, and re-commit through a
    # Paxos-accept round — or commit a no-op when no quorum member ever saw
    # the command.  Enabled by ``faults.apply_plan`` (fault scenarios only):
    # probe timers on every transiently-blocked dependency would perturb the
    # fault-free golden traces for nothing.
    #
    # Decision safety mirrors full EPaxos restricted to what this simulation
    # can produce: a fast-path commit broadcasts ECommit to every peer in
    # the same handler that decides it (before the client can be answered),
    # so a committed-but-unknown-to-everyone instance never outlives the
    # ~one-hop delivery window — orders of magnitude shorter than the probe
    # timeout that gates any recovery.  By probe time, either some quorum
    # member reports "committed" (adopted verbatim) or no fast-path commit
    # happened and the accepted/pre-accepted union is free to win.
    def enable_recovery(self) -> None:
        self.recovery_enabled = True

    def recover(self) -> None:
        """Crash-recover with protocol semantics: suppressed probe timers
        are forgotten (they died with the crash), and the node's own
        in-flight instances — whose replies were dropped while it was down —
        re-run through the explicit-prepare path (re-commit or no-op)."""
        if not self.crashed:
            return
        super().recover()
        if self._batching:
            # buffered commands are volatile: the crash lost them (clients
            # retry; _done_ops absorbs duplicates) and gated flags re-derive
            self._drop_buffers(bounce=False)
            for inst in self.insts.values():
                inst.gated = False
        if not self.recovery_enabled:
            return
        self._recover_armed.clear()
        self._recoveries.clear()
        for iid, inst in list(self.insts.items()):
            if iid[0] == self.id and inst.state in ("preaccepted", "accepted"):
                inst.replies = []
                inst.accept_acks = 0
                self._start_prepare(iid)
        self._drain_exec()

    def _arm_recovery(self, inst_id: tuple) -> None:
        if inst_id in self._recover_armed or inst_id in self._recoveries:
            return
        self._recover_armed.add(inst_id)
        # stagger by distance from the owner so probes rarely duel: the
        # recovered owner itself re-commits fastest, then successive peers
        rank = (self.id - inst_id[0]) % self.n
        delay = self.recovery_timeout * (1.0 + 0.25 * rank)
        self.set_timer(delay, lambda: self._probe_recovery(inst_id))

    def _probe_recovery(self, inst_id: tuple) -> None:
        self._recover_armed.discard(inst_id)
        inst = self.insts.get(inst_id)
        if inst is not None and inst.state in ("committed", "executed"):
            return
        if inst_id in self._recoveries:
            return
        self._start_prepare(inst_id)

    def _start_prepare(self, inst_id: tuple) -> None:
        inst = self.insts.setdefault(inst_id, _Inst())
        b = (max(inst.max_ballot[0], inst.ballot[0]) + 1, self.id)
        inst.max_ballot = b
        rec = _Recovery(ballot=b)
        self._recoveries[inst_id] = rec
        # the local snapshot is this node's own prepare reply
        rec.replies[self.id] = EPrepareReply(
            inst=inst_id, ok=True, ballot=b, state=inst.state, cmd=inst.cmd,
            deps=inst.deps, seq=inst.seq, accepted_ballot=inst.ballot,
            n_cluster=self.n)
        m = EPrepare(inst=inst_id, ballot=b, n_cluster=self.n)
        for p in self.peers:
            if p != self.id:
                self.send(p, m)
        # stall guard: a round started while a quorum was unreachable (its
        # EPrepares were dropped at crashed peers) would otherwise pend
        # forever and block re-arming — abandon and re-probe
        self.set_timer(4 * self.recovery_timeout,
                       lambda: self._abandon_stalled(inst_id, b))

    def _abandon_stalled(self, inst_id: tuple, ballot: tuple) -> None:
        rec = self._recoveries.get(inst_id)
        if rec is None or rec.ballot != ballot:
            return
        del self._recoveries[inst_id]
        inst = self.insts.get(inst_id)
        if inst is not None and inst.state not in ("committed", "executed"):
            self._arm_recovery(inst_id)

    def on_EPrepare(self, msg: EPrepare) -> None:
        inst = self.insts.setdefault(msg.inst, _Inst())
        if self.joining or self.removed:
            return    # non-members don't vote in recovery rounds either
        if msg.ballot > inst.max_ballot:
            inst.max_ballot = msg.ballot
            r = EPrepareReply(inst=msg.inst, ok=True, ballot=msg.ballot,
                              state=inst.state, cmd=inst.cmd, deps=inst.deps,
                              seq=inst.seq, accepted_ballot=inst.ballot,
                              n_cluster=self.n)
        else:
            r = EPrepareReply(inst=msg.inst, ok=False, ballot=inst.max_ballot)
        self.send(msg.src, r)

    def on_EPrepareReply(self, msg: EPrepareReply) -> None:
        rec = self._recoveries.get(msg.inst)
        if rec is None or rec.phase != "prepare" or msg.ballot != rec.ballot:
            # a reject is only a preemption when the promise it carries
            # beats OUR current round — late rejects answering an earlier
            # abandoned round must not tear down the live one
            if rec is not None and rec.phase == "prepare" and not msg.ok \
                    and msg.ballot > rec.ballot:
                del self._recoveries[msg.inst]
                self._arm_recovery(msg.inst)
            return
        rec.replies[msg.src] = msg
        if len(rec.replies) >= self.maj:
            self._decide_recovery(msg.inst, rec)

    def _decide_recovery(self, inst_id: tuple, rec: _Recovery) -> None:
        rs = list(rec.replies.values())
        committed = [r for r in rs if r.state in ("committed", "executed")]
        if committed:
            del self._recoveries[inst_id]
            r0 = committed[0]
            self._commit_recovered(inst_id, r0.cmd, r0.deps, r0.seq)
            return
        accepted = [r for r in rs if r.state == "accepted"]
        if accepted:
            r0 = max(accepted, key=lambda r: r.accepted_ballot)
            cmd, deps, seq = r0.cmd, r0.deps, r0.seq
        else:
            pre = [r for r in rs
                   if r.state == "preaccepted" and r.cmd is not None]
            if pre:
                cmd = pre[0].cmd
                deps = frozenset().union(*[r.deps for r in pre])
                seq = max(r.seq for r in pre)
            else:
                cmd, deps, seq = None, frozenset(), 0   # no-op the instance
        rec.phase, rec.acks = "accept", 1
        inst = self.insts[inst_id]
        inst.cmd, inst.deps, inst.seq = cmd, deps, seq
        inst.state = "accepted"
        inst.ballot = rec.ballot
        if cmd is not None:
            self._note_cmd(cmd, inst_id)
        m = EAccept(inst=inst_id, ballot=rec.ballot, cmd=cmd, deps=deps,
                    seq=seq, n_cluster=self.n)
        for p in self.peers:
            if p != self.id:
                self.send(p, m)

    def _recovery_accept_reply(self, inst_id: tuple, rec: _Recovery,
                               msg: EAcceptReply) -> None:
        if not msg.ok:
            if msg.ballot > rec.ballot:        # genuinely preempted
                del self._recoveries[inst_id]
                self._arm_recovery(inst_id)
            return                             # stale reject: ignore
        if msg.ballot != rec.ballot:
            return                             # stale round
        rec.acks += 1
        if rec.acks >= self.maj:
            del self._recoveries[inst_id]
            inst = self.insts[inst_id]
            if inst.state not in ("committed", "executed"):
                self._commit(inst_id, inst)

    def _commit_recovered(self, inst_id: tuple, cmd, deps, seq) -> None:
        """Adopt a commit learned through a prepare quorum; _commit
        re-broadcasts ECommit — the original may have been lost to the
        crash window."""
        inst = self.insts[inst_id]
        if inst.state in ("committed", "executed"):
            return
        inst.cmd, inst.deps, inst.seq = cmd, deps, seq
        if cmd is not None:
            self._note_cmd(cmd, inst_id)
        self._commit(inst_id, inst)
