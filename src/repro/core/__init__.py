"""The paper's contribution: Pig + PigPaxos (and baselines) on a
deterministic discrete-event cluster runtime."""
from .analytical import (follower_messages, leader_messages,
                         total_messages_per_round)  # noqa: F401
from .cluster import (Client, Cluster, OpenLoopClient, Stats,  # noqa: F401
                      TaggedBytes, WorkloadConfig, agreement_ok, zipf_cdf)
from .epaxos import EPaxosNode  # noqa: F401
from .events import Scheduler  # noqa: F401
from .messages import BatchCmd, Command, CostModel  # noqa: F401
from .network import Network, Topology, wan_topology  # noqa: F401
from .paxos import BatchConfig, PaxosNode  # noqa: F401
from .pig import DirectComm, PigComm, PigConfig  # noqa: F401
