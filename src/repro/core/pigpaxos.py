"""PigPaxos (§3.2) = the unchanged Multi-Paxos core + the Pig communication
layer.  This module exists to make the paper's composition explicit: there
is intentionally no PigPaxos-specific consensus logic anywhere (§3.3 —
"required almost no changes to the core Paxos code").

Membership change composes the same way: the single-server reconfiguration
commands live entirely in the Paxos core (``PaxosNode.propose_reconfig`` /
``_apply_membership``), and the Pig overlay only reacts through
``PigComm.set_members`` — applied configuration changes invalidate the
cached ``pig.partition_followers`` relay partition, so the next round
fans out over groups derived from the membership now in force.  Rounds in
flight across a re-partition resolve through the leader's ordinary
timeout/retry path (§3.4), exactly like a relay crash.
"""
from __future__ import annotations

from typing import Optional

from .events import Scheduler
from .network import Network
from .paxos import BatchConfig, PaxosNode
from .pig import PigConfig
from .quorums import QuorumSystem


class PigPaxosNode(PaxosNode):
    """A Paxos node whose communication layer is always a Pig overlay."""

    def __init__(self, node_id: int, net: Network, sched: Scheduler,
                 peers: list[int], pig: Optional[PigConfig] = None,
                 leader_timeout: float = 50e-3,
                 quorums: Optional[QuorumSystem] = None,
                 batch: Optional[BatchConfig] = None,
                 pipeline_depth: int = 0):
        super().__init__(node_id, net, sched, peers,
                         pig=pig or PigConfig(),
                         leader_timeout=leader_timeout, quorums=quorums,
                         batch=batch, pipeline_depth=pipeline_depth)
