"""Reference (seed) discrete-event engine, kept verbatim for equivalence.

This module preserves the original closure-chain engine exactly as it shipped
in the seed: three heap events per message hop (serialize -> transmit ->
arrive -> handle), numpy accounting, and an unbounded ``_cancelled`` set.

It exists for two reasons:
  1. the golden-trace equivalence tests (tests/test_golden_trace.py) run the
     fast engine and this reference side by side and require *identical*
     applied command logs, committed counts, and executed event counts;
  2. benchmarks/sim_engine_bench.py uses it as the baseline for the
     events/sec speedup figure tracked in BENCH_sim.json.

To keep the baseline honest, this module also preserves the seed's per-hop
machinery that has since been optimized in the shared layers: the
string-concatenation handler dispatch (``getattr(node, "on_" + msg.kind)``
per delivery, seed node.py) and the uncached cost computation
(``getattr(msg, "n_cluster", 0)`` per send, seed messages.py).

Do not optimize this file: its value is that it never changes behavior.
"""
from __future__ import annotations

import heapq
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from .messages import CostModel, Msg
from .network import Topology


class RefScheduler:
    """The seed scheduler: (time, seq, closure) heap entries."""

    __slots__ = ("now", "_heap", "_seq", "rng", "_cancelled", "events")

    def __init__(self, seed: int = 0):
        self.now: float = 0.0
        self._heap: list = []
        self._seq: int = 0
        self.rng = np.random.default_rng(seed)
        self._cancelled: set[int] = set()
        self.events: int = 0          # cumulative executed (bench accounting)

    def at(self, t: float, fn: Callable[[], None]) -> int:
        self._seq += 1
        heapq.heappush(self._heap, (t, self._seq, fn))
        return self._seq

    def after(self, dt: float, fn: Callable[[], None]) -> int:
        return self.at(self.now + dt, fn)

    def cancel(self, timer_id: int) -> None:
        self._cancelled.add(timer_id)

    def run(self, until: float = float("inf"), max_events: Optional[int] = None) -> int:
        n = 0
        heap = self._heap
        cancelled = self._cancelled
        while heap:
            t, seq, fn = heap[0]
            if t > until:
                break
            heapq.heappop(heap)
            if seq in cancelled:
                cancelled.discard(seq)
                continue
            self.now = t
            fn()
            n += 1
            if max_events is not None and n >= max_events:
                break
        if self.now < until < float("inf"):
            self.now = until
        self.events += n
        return n

    def idle(self) -> bool:
        return not self._heap


class RefNetwork:
    """The seed transport: one closure-chain event per message stage."""

    def __init__(self, sched: RefScheduler, topo: Topology,
                 cost: CostModel | None = None):
        self.sched = sched
        self.topo = topo
        self.cost = cost or CostModel()
        self.nodes: Dict[int, "object"] = {}
        self.cpu_free: Dict[int, float] = {}
        self.cpu_busy: Dict[int, float] = {}
        cap = topo.n + 1024
        self.msgs_out = np.zeros(cap, dtype=np.int64)
        self.msgs_in = np.zeros(cap, dtype=np.int64)
        self.flight_matrix = np.zeros((cap, cap), dtype=np.int64)
        self.partitioned: set[Tuple[int, int]] = set()
        self.accounting = True

    def register(self, node_id: int, node) -> None:
        self.nodes[node_id] = node
        self.cpu_free[node_id] = 0.0
        self.cpu_busy[node_id] = 0.0

    # -------------------------------------------------------------- failure
    def partition(self, a: int, b: int) -> None:
        self.partitioned.add((a, b))
        self.partitioned.add((b, a))

    def heal(self, a: int, b: int) -> None:
        self.partitioned.discard((a, b))
        self.partitioned.discard((b, a))

    # -------------------------------------------------------------- CPU
    def _cpu(self, node_id: int, cost: float, fn: Callable[[], None]) -> None:
        start = max(self.sched.now, self.cpu_free[node_id])
        done = start + cost
        self.cpu_free[node_id] = done
        self.cpu_busy[node_id] += cost
        self.sched.at(done, fn)

    # -------------------------------------------------------------- send
    def _seed_cpu_cost(self, msg: Msg) -> float:
        """The seed's uncached cost computation (pre-caching messages.py)."""
        cost = self.cost
        c = cost.base + cost.per_byte * msg.wire_size()
        n = getattr(msg, "n_cluster", 0)
        if n:
            c += cost.epaxos_extra_per_node * n
        return c

    def send(self, src: int, dst: int, msg: Msg) -> None:
        msg.src = src
        node_src = self.nodes.get(src)
        if node_src is not None and getattr(node_src, "crashed", False):
            return
        c = self._seed_cpu_cost(msg)
        if self.accounting:
            self.msgs_out[src] += 1
            self.flight_matrix[src][dst] += 1

        def _transmit() -> None:
            if (src, dst) in self.partitioned:
                return
            lat = self.topo.latency(self.sched.rng, src, dst)
            self.sched.after(lat, lambda: self._arrive(src, dst, msg, c))

        if src < self.topo.n:
            self._cpu(src, c, _transmit)
        else:
            self.sched.after(0.0, _transmit)

    def _arrive(self, src: int, dst: int, msg: Msg, c: float) -> None:
        node = self.nodes.get(dst)
        if node is None or getattr(node, "crashed", False):
            return

        def _handle() -> None:
            n2 = self.nodes.get(dst)
            if n2 is None or getattr(n2, "crashed", False):
                return
            if self.accounting:
                self.msgs_in[dst] += 1
            # seed dispatch: string-keyed getattr per delivery (seed node.py)
            handler = getattr(n2, "on_" + msg.kind, None)
            if handler is None:
                n2.deliver(msg)       # Client & handler-error path
            else:
                handler(msg)

        if dst < self.topo.n:
            self._cpu(dst, c, _handle)
        else:
            self.sched.after(0.0, _handle)

    # -------------------------------------------------------------- stats
    def reset_stats(self) -> None:
        self.msgs_out[:] = 0
        self.msgs_in[:] = 0
        self.flight_matrix[:] = 0
        for k in self.cpu_busy:
            self.cpu_busy[k] = 0.0

    def message_load(self, node_id: int) -> int:
        return int(self.msgs_out[node_id] + self.msgs_in[node_id])


# ===========================================================================
# Seed protocol snapshot (commit e247e29), kept verbatim like the engine
# above.  The golden-trace tests run this full seed stack (seed scheduler +
# seed network + seed dispatch + seed protocol classes) against the
# optimized stack and require identical traces, which proves BOTH the engine
# rewrite AND the protocol-layer optimizations are behavior-preserving.
# benchmarks/sim_engine_bench.py uses it as the end-to-end seed baseline.
# Only the class names carry a Ref prefix so the two stacks can coexist.
# ===========================================================================
from dataclasses import dataclass, field
from typing import Callable as _Callable, Sequence

from .messages import (ClientReply, ClientRequest, Command, EAccept,
                       EAcceptReply, ECommit, P1a, P1b, P2a, P2b, P3,
                       PigAggregate, PigFanout, PigRelayed, PigReply,
                       PreAccept, PreAcceptReply)
from .node import KVStore
from .paxos import CatchUpReq, CatchUpResp
from .pig import PigConfig
from .quorums import QuorumSystem, fast_quorum, majority

class RefNode:
    """Base class: protocol nodes subclass and add ``on_<MsgType>`` handlers."""

    def __init__(self, node_id: int, net: Network, sched: Scheduler):
        self.id = node_id
        self.net = net
        self.sched = sched
        self.crashed = False
        self.store = KVStore()
        self.applied_log: list = []   # sequence of (slot/inst, command) applied
        net.register(node_id, self)

    # ------------------------------------------------------------ transport
    def send(self, dst: int, msg: Msg) -> None:
        self.net.send(self.id, dst, msg)

    def deliver(self, msg: Msg) -> None:
        if self.crashed:
            return
        handler = getattr(self, "on_" + msg.kind, None)
        if handler is None:
            raise RuntimeError(f"{type(self).__name__} has no handler for {msg.kind}")
        handler(msg)

    # ------------------------------------------------------------ timers
    def set_timer(self, delay: float, fn) -> int:
        def _fire():
            if not self.crashed:
                fn()
        return self.sched.after(delay, _fire)

    def cancel_timer(self, timer_id: int) -> None:
        self.sched.cancel(timer_id)

    # ------------------------------------------------------------ failure
    def crash(self) -> None:
        self.crashed = True

    def recover(self) -> None:
        self.crashed = False


class RefDirectComm:
    """Classic Paxos communication: leader <-> every follower directly."""

    name = "direct"

    def __init__(self, node, peers: Sequence[int]):
        self.node = node
        self.peers = [p for p in peers if p != node.id]

    # leader side -----------------------------------------------------------
    def broadcast(self, make_msg: Callable[[], Msg], round_key=None) -> list:
        for p in self.peers:
            self.node.send(p, make_msg())
        return []

    # follower side ---------------------------------------------------------
    def reply(self, to: int, msg: Msg) -> None:
        self.node.send(to, msg)

    # no-op hooks so Paxos can stay comm-agnostic
    def note_commit(self, slot: int) -> None:
        pass

    def note_committed_up_to(self, ci: int) -> None:
        pass

    def on_round_timeout(self, round_ids) -> None:
        pass


class RefPigComm:
    """Pig overlay communication used by the leader and all followers."""

    name = "pig"

    def __init__(self, node, peers: Sequence[int], cfg: PigConfig):
        self.node = node
        self.cfg = cfg
        self.all_nodes = list(peers)
        self._groups_cache: Dict[int, List[List[int]]] = {}
        self._pig_seq = node.id << 40
        # relay-side aggregation state: pig_id -> dict
        self._agg: Dict[int, dict] = {}
        # leader-side: pig_id -> (group_idx, relay, round_key)
        self._outstanding: Dict[int, tuple] = {}
        self._pending_sup: Dict[int, int] = {}   # slot -> pig_id (late votes)
        self.gray: Dict[int, float] = {}     # node -> expiry time (§4.2)

    @staticmethod
    def _partition(members: Sequence[int], r: int) -> List[List[int]]:
        r = max(1, min(r, len(members)))
        out: List[List[int]] = [[] for _ in range(r)]
        for i, m in enumerate(members):
            out[i % r].append(m)
        return out

    def groups_for(self, leader: int) -> List[List[int]]:
        """Relay groups are a cluster-wide static partition of the *followers*
        (paper §3.2) — i.e. of all nodes except the current leader.  Every
        node derives the same partition deterministically from the leader id,
        so relays and the leader agree without extra coordination."""
        g = self._groups_cache.get(leader)
        if g is None:
            if self.cfg.groups is not None:
                g = [[m for m in grp if m != leader] for grp in self.cfg.groups]
                g = [grp for grp in g if grp]
            else:
                g = self._partition([p for p in self.all_nodes if p != leader],
                                    self.cfg.n_groups)
            self._groups_cache[leader] = g
        return g

    # ---------------------------------------------------------------- leader
    def _pick_relay(self, group: List[int]) -> int:
        rng = self.node.sched.rng
        if not self.cfg.rotate_relays:
            return group[0]
        candidates = group
        if self.cfg.use_gray_list:
            now = self.node.sched.now
            healthy = [g for g in group if self.gray.get(g, 0.0) <= now]
            if healthy and (len(healthy) == len(group)
                            or rng.random() > self.cfg.gray_probe_prob):
                candidates = healthy
        return candidates[int(rng.integers(len(candidates)))]

    def _required_per_group(self, groups: List[List[int]]) -> List[int]:
        """PRC thresholds q_i = n_i - PRC, subject to the paper's §4.1
        constraint sum(q_i) >= majority - 1 (the leader votes for itself);
        violating it would let a single crashed group block liveness."""
        maj = len(self.all_nodes) // 2 + 1
        if self.cfg.single_group_majority and len(groups) == 1:
            return [min(len(groups[0]), maj - 1)]     # §4.3: global majority
        req = [max(1, len(g) - self.cfg.prc) for g in groups]
        i = 0
        while sum(req) < maj - 1:
            if req[i % len(req)] < len(groups[i % len(req)]):
                req[i % len(req)] += 1
            i += 1
            if i > 4 * len(req):       # all groups already at n_i
                break
        return req

    def broadcast(self, make_msg: Callable[[], Msg], round_key=None) -> list:
        """Start one Pig round per relay group.  Returns the pig ids used,
        so the caller can gray non-responsive relays on its own timeout."""
        ids = []
        groups = self.groups_for(self.node.id)
        required = self._required_per_group(groups)
        for gi, group in enumerate(groups):
            self._pig_seq += 1
            pid = self._pig_seq
            relay = self._pick_relay(group)
            self._outstanding[pid] = (gi, relay, round_key)
            self.node.send(relay, PigFanout(pig_id=pid, group=gi,
                                            inner=make_msg(),
                                            required=required[gi]))
            ids.append(pid)
        return ids

    def on_round_timeout(self, pig_ids) -> None:
        """Leader timed out on a round: gray the relays that never replied."""
        now = self.node.sched.now
        for pid in pig_ids:
            st = self._outstanding.pop(pid, None)
            if st is not None and self.cfg.use_gray_list:
                self.gray[st[1]] = now + self.cfg.gray_duration

    def leader_handle_aggregate(self, msg: PigAggregate) -> None:
        st = self._outstanding.pop(msg.pig_id, None)
        if st is None:
            return None
        # only nodes that made the relay *time out* are failure suspects;
        # nodes skipped by early PRC flushes are merely slow-this-round (§4.2)
        if self.cfg.use_gray_list and msg.timed_out:
            now = self.node.sched.now
            for m in msg.missing:
                self.gray[m] = now + self.cfg.gray_duration
        return None

    # ---------------------------------------------------------------- relay
    def on_PigFanout(self, msg: PigFanout) -> None:
        node = self.node
        gi = msg.group
        groups = self.groups_for(msg.src)   # groups relative to the leader
        group = groups[gi] if gi < len(groups) else []
        peers = [p for p in group if p != node.id]
        st = {
            "replies": [],
            "voters": set(),
            "required": msg.required,
            "leader": msg.src,
            "group": gi,
            "expect": set(peers),
            "done": False,
            "timer": None,
        }
        self._agg[msg.pig_id] = st
        # 1) act as a regular follower on the inner message
        my_reply = node.process_inner(msg.inner)
        if my_reply is not None:
            self._accumulate(msg.pig_id, node.id, my_reply)
        # 2) re-transmit to the rest of the group
        for p in peers:
            node.send(p, PigRelayed(pig_id=msg.pig_id, relay=node.id,
                                    inner=msg.inner))
        # 3) arm the relay timeout T_r (§3.4)
        st["timer"] = node.set_timer(self.cfg.relay_timeout,
                                     lambda: self._flush(msg.pig_id, timeout=True))
        self._maybe_flush(msg.pig_id)

    # ---------------------------------------------------------------- follower
    def on_PigRelayed(self, msg: PigRelayed) -> None:
        reply = self.node.process_inner(msg.inner)
        if reply is not None:
            self.node.send(msg.relay, PigReply(pig_id=msg.pig_id, inner=reply))

    def on_PigReply(self, msg: PigReply) -> None:
        self._accumulate(msg.pig_id, msg.src, msg.inner)
        self._maybe_flush(msg.pig_id)

    # ---------------------------------------------------------------- agg
    def _accumulate(self, pig_id: int, voter: int, reply: Msg) -> None:
        st = self._agg.get(pig_id)
        if st is None:
            return
        if st["done"]:
            self._queue_late_vote(pig_id, st, voter, reply)
            return
        st["voters"].add(voter)
        st["replies"].append(reply)
        # reject short-circuit: don't wait for aggregation (§3.2, footnote 1)
        if getattr(reply, "ok", True) is False:
            self._flush(pig_id, reject=True)

    def _queue_late_vote(self, pig_id: int, st: dict, voter: int,
                         reply: Msg) -> None:
        """A vote arriving after the PRC/timeout flush.  The leader usually
        doesn't need it (other groups give the majority), so batch it for
        T_r and cancel if the slot is seen committed in the meantime; only a
        starved round actually pays the extra message (§4.1: 'requiring more
        communication to learn the missing votes')."""
        if voter in st["voters"] or not getattr(reply, "ok", True):
            return
        st["voters"].add(voter)
        if isinstance(reply, P1b):
            # leader election is liveness-critical: forward immediately
            sup = _RefP1Aggregate(PigAggregate(
                pig_id=pig_id, group=st["group"], ballot=reply.ballot,
                slot=-1, acks=1, voters=(voter,)), [reply])
            self.node.send(st["leader"], sup)
            return
        st.setdefault("late", []).append((voter, reply))
        if st.get("sup_timer") is None:
            st["sup_timer"] = self.node.set_timer(
                self.cfg.relay_timeout,
                lambda: self._send_supplement(pig_id))
            slot = getattr(reply, "slot", None)
            if slot is not None and slot >= 0:
                self._pending_sup[slot] = pig_id

    def _send_supplement(self, pig_id: int) -> None:
        st = self._agg.get(pig_id)
        if st is None or not st.get("late"):
            return
        late = st.pop("late")
        st["sup_timer"] = None
        first = late[0][1]
        self.node.send(st["leader"], PigAggregate(
            pig_id=pig_id, group=st["group"],
            ballot=getattr(first, "ballot", (0, 0)),
            slot=getattr(first, "slot", -1), acks=len(late),
            voters=tuple(v for v, _ in late), missing=()))

    def note_committed_up_to(self, ci: int) -> None:
        """Called when this node learns a commit index: pending supplements
        for committed slots are unnecessary — drop them."""
        if not self._pending_sup:
            return
        for slot in [s for s in self._pending_sup if s <= ci]:
            pid = self._pending_sup.pop(slot)
            st = self._agg.get(pid)
            if st is not None:
                st["late"] = []
                if st.get("sup_timer") is not None:
                    self.node.cancel_timer(st["sup_timer"])
                    st["sup_timer"] = None

    def _maybe_flush(self, pig_id: int) -> None:
        st = self._agg.get(pig_id)
        if st is None or st["done"]:
            return
        # group size = peers + the relay itself
        full = len(st["expect"]) + 1
        if len(st["voters"]) >= min(st["required"], full):
            self._flush(pig_id)

    def _flush(self, pig_id: int, timeout: bool = False, reject: bool = False) -> None:
        st = self._agg.get(pig_id)
        if st is None or st["done"]:
            return
        st["done"] = True
        if st["timer"] is not None:
            self.node.cancel_timer(st["timer"])
        replies: List[Msg] = st["replies"]
        oks = [r for r in replies if getattr(r, "ok", True)]
        rejects = [r for r in replies if not getattr(r, "ok", True)]
        missing = tuple(sorted((st["expect"] | {self.node.id}) - st["voters"]))
        proto = replies[0] if replies else None
        agg = PigAggregate(
            pig_id=pig_id,
            group=st["group"],
            ballot=getattr(proto, "ballot", (0, 0)),
            slot=getattr(proto, "slot", -1),
            acks=len(oks),
            voters=tuple(sorted(st["voters"])) if replies else (),
            missing=missing,
            timed_out=timeout,
            reject=bool(rejects) or reject,
            reject_ballot=max((getattr(r, "ballot", (0, 0)) for r in rejects),
                              default=(0, 0)),
        )
        # Phase-1 aggregation must carry the accepted-log bodies upward.
        p1 = [r for r in replies if isinstance(r, P1b)]
        if p1:
            agg = _RefP1Aggregate(agg, p1)
        self.node.send(st["leader"], agg)
        # keep the entry briefly so late votes become supplementary
        # aggregates (§4.1), then GC it
        st["replies"] = []
        self.node.set_timer(4 * self.cfg.relay_timeout,
                            lambda: self._agg.pop(pig_id, None))

    # ---------------------------------------------------------------- misc
    def note_commit(self, slot: int) -> None:
        pass


class _RefP1Aggregate(PigAggregate):
    """PigAggregate that additionally carries P1b bodies (value recovery)."""

    def __init__(self, base: PigAggregate, p1bs: List[P1b]):
        super().__init__(pig_id=base.pig_id, group=base.group,
                         ballot=base.ballot, slot=base.slot, acks=base.acks,
                         voters=base.voters, missing=base.missing,
                         timed_out=base.timed_out,
                         reject=base.reject, reject_ballot=base.reject_ballot)
        self.p1bs = p1bs

    @property
    def kind(self) -> str:  # dispatch as the base type
        return "PigAggregate"

    def wire_size(self) -> int:
        return super().wire_size() + sum(m.wire_size() for m in self.p1bs)


@dataclass
class _Slot:
    cmd: Command
    client_src: int = -1
    voters: set = field(default_factory=set)
    committed: bool = False
    pig_ids: list = field(default_factory=list)
    timer: Optional[int] = None
    retries: int = 0


class RefPaxosNode(RefNode):
    def __init__(self, node_id: int, net: Network, sched: Scheduler,
                 peers: list[int], pig: Optional[PigConfig] = None,
                 leader_timeout: float = 50e-3,
                 quorums: Optional["QuorumSystem"] = None):
        super().__init__(node_id, net, sched)
        self.peers = list(peers)
        self.n = len(peers)
        # flexible quorums (FPaxos, paper §7.1): Q1+Q2 > N; classic Paxos
        # uses majorities for both.  Pig composes with either (§7.1).
        self.quorums = quorums
        self.majority = quorums.q2 if quorums else majority(self.n)
        self.q1 = quorums.q1 if quorums else majority(self.n)
        self.comm = (RefPigComm(self, peers, pig) if pig is not None
                     else RefDirectComm(self, peers))
        self.leader_timeout = leader_timeout

        # acceptor state
        self.promised: tuple = (0, 0)
        self.accepted: Dict[int, tuple] = {}      # slot -> (ballot, cmd)
        # learner state
        self.committed: Dict[int, Command] = {}
        self.commit_index: int = -1               # contiguous applied prefix
        self._catching_up: set = set()
        # leader state
        self.ballot: tuple = (0, 0)
        self.is_leader = False
        self.next_slot: int = 0
        self.log: Dict[int, _Slot] = {}
        self._p1_voters: set = set()
        self._p1_accepted: Dict[int, tuple] = {}
        self._p1_timer: Optional[int] = None
        self._p1_max_ci: tuple = (-1, -1)
        # metrics
        self.committed_count = 0

    # ================================================================ leader
    def start_phase1(self) -> None:
        b = (max(self.promised[0], self.ballot[0]) + 1, self.id)
        self.ballot = b
        self.is_leader = False
        self._p1_voters = {self.id}
        self._p1_accepted = {s: v for s, v in self.accepted.items()
                             if s > self.commit_index}
        self._p1_max_ci = (-1, -1)
        self.promised = b
        self.comm.broadcast(lambda: P1a(ballot=b), round_key=("p1", b))
        self._p1_timer = self.set_timer(self.leader_timeout, self._p1_retry)

    def _p1_retry(self) -> None:
        if not self.is_leader and self.ballot[1] == self.id:
            self.start_phase1()

    def _ingest_p1(self, voter: int, msg: P1b) -> None:
        if self.is_leader or msg.ballot != self.ballot:
            if not msg.ok and msg.ballot > self.ballot:
                self._step_down(msg.ballot)
            return
        self._p1_voters.add(voter)
        ci = getattr(msg, "commit_index", -1)
        if ci > self._p1_max_ci[0]:
            self._p1_max_ci = (ci, voter)
        for s, (b, cmd) in msg.accepted.items():
            cur = self._p1_accepted.get(s)
            if cur is None or b > cur[0]:
                self._p1_accepted[s] = (b, cmd)
        if len(self._p1_voters) >= self.q1:
            self._become_leader()

    def _become_leader(self) -> None:
        self.is_leader = True
        if self._p1_timer is not None:
            self.cancel_timer(self._p1_timer)
        # catch up slots that a quorum already committed (they are pruned
        # from P1b.accepted, so they must be *learned*, not re-proposed)
        max_ci, ci_src = self._p1_max_ci
        if max_ci > self.commit_index and ci_src >= 0:
            self._learn_commit(max_ci, ci_src)
        # re-propose uncommitted values found during phase-1 (§2.1)
        slots = sorted(self._p1_accepted)
        for s in slots:
            _, cmd = self._p1_accepted[s]
            if s <= max(self.commit_index, max_ci) or s in self.log:
                continue
            self.next_slot = max(self.next_slot, s + 1)
            self._propose_at(s, cmd, client_src=-1)
        self.next_slot = max(self.next_slot, self.commit_index + 1,
                             max_ci + 1)

    def _step_down(self, higher: tuple) -> None:
        self.is_leader = False
        for e in self.log.values():
            if e.timer is not None:
                self.cancel_timer(e.timer)
        self.log.clear()

    # -------------------------------------------------------------- phase 2
    def on_ClientRequest(self, msg: ClientRequest) -> None:
        if not self.is_leader:
            self.send(msg.src, ClientReply(client_id=msg.cmd.client_id,
                                           seq=msg.cmd.seq, ok=False))
            return
        slot = self.next_slot
        self.next_slot += 1
        self._propose_at(slot, msg.cmd, client_src=msg.src)

    def _propose_at(self, slot: int, cmd: Command, client_src: int) -> None:
        entry = _Slot(cmd=cmd, client_src=client_src)
        entry.voters.add(self.id)
        self.log[slot] = entry
        # leader accepts locally
        self.accepted[slot] = (self.ballot, cmd)
        self._send_p2a(slot)

    def _send_p2a(self, slot: int) -> None:
        entry = self.log[slot]
        b, ci = self.ballot, self.commit_index

        def make() -> P2a:
            return P2a(ballot=b, slot=slot, cmd=entry.cmd, commit_index=ci)

        entry.pig_ids = self.comm.broadcast(make, round_key=slot) or []
        entry.timer = self.set_timer(self.leader_timeout,
                                     lambda: self._slot_timeout(slot))

    def _slot_timeout(self, slot: int) -> None:
        entry = self.log.get(slot)
        if entry is None or entry.committed or not self.is_leader:
            return
        # gray non-responsive relays, then retry with fresh random relays (§3.4)
        self.comm.on_round_timeout(entry.pig_ids)
        entry.retries += 1
        self._send_p2a(slot)

    def ingest_vote(self, ballot: tuple, slot: int, voter: int, ok: bool,
                    reject_ballot: tuple = (0, 0)) -> None:
        if not ok:
            if reject_ballot > self.ballot:
                self._step_down(reject_ballot)
            return
        if ballot != self.ballot or not self.is_leader:
            return
        entry = self.log.get(slot)
        if entry is None or entry.committed:
            return
        entry.voters.add(voter)   # set => duplicate votes counted once (§3.4)
        if len(entry.voters) >= self.majority:
            self._commit(slot)

    def _commit(self, slot: int) -> None:
        entry = self.log[slot]
        entry.committed = True
        if entry.timer is not None:
            self.cancel_timer(entry.timer)
        self.committed[slot] = entry.cmd
        self.committed_count += 1
        self._advance()

    def _advance(self) -> None:
        """Apply contiguously committed slots; reply to waiting clients."""
        while (self.commit_index + 1) in self.committed:
            s = self.commit_index + 1
            cmd = self.committed[s]
            val = self.store.apply(cmd)
            self.applied_log.append((s, cmd))
            self.commit_index = s
            e = self.log.get(s)
            if e is not None and e.client_src >= 0:
                self.send(e.client_src,
                          ClientReply(client_id=cmd.client_id, seq=cmd.seq,
                                      ok=True, value=val))

    def flush_commits(self) -> None:
        """Idle-time commit propagation (harness use; P3 is normally
        piggybacked on the next P2a)."""
        for p in self.peers:
            if p != self.id:
                self.send(p, P3(commit_index=self.commit_index))

    # ============================================================== acceptor
    def process_inner(self, msg: Msg):
        """Handle a (possibly relayed) leader message; return the reply."""
        if isinstance(msg, P2a):
            return self._accept(msg)
        if isinstance(msg, P1a):
            return self._promise(msg)
        if isinstance(msg, P3):
            self._learn_commit(msg.commit_index, msg.src)
            return None
        raise RuntimeError(f"unexpected inner {msg.kind}")

    def _accept(self, msg: P2a) -> P2b:
        if msg.ballot >= self.promised:
            self.promised = msg.ballot
            self.accepted[msg.slot] = (msg.ballot, msg.cmd)
            self._learn_commit(msg.commit_index, msg.src)
            r = P2b(ballot=msg.ballot, slot=msg.slot, ok=True)
        else:
            r = P2b(ballot=self.promised, slot=msg.slot, ok=False)
        r.src = self.id
        return r

    def _promise(self, msg: P1a) -> P1b:
        if msg.ballot > self.promised:
            self.promised = msg.ballot
            acc = {s: v for s, v in self.accepted.items()
                   if s > self.commit_index}
            r = P1b(ballot=msg.ballot, ok=True, accepted=acc,
                    commit_index=self.commit_index)
        else:
            r = P1b(ballot=self.promised, ok=False)
        r.src = self.id
        return r

    def _learn_commit(self, ci: int, leader_src: int) -> None:
        self.comm.note_committed_up_to(ci)
        while self.commit_index < ci:
            s = self.commit_index + 1
            if s in self.committed:
                cmd = self.committed[s]
            elif s in self.accepted:
                cmd = self.accepted[s][1]
            else:
                if s not in self._catching_up and leader_src >= 0:
                    self._catching_up.add(s)
                    self.send(leader_src, CatchUpReq(slots=(s,)))
                    # allow a re-request if the response gets lost
                    self.set_timer(2 * self.leader_timeout,
                                   lambda s=s: self._catching_up.discard(s))
                return
            self.committed.setdefault(s, cmd)
            self.store.apply(cmd)
            self.applied_log.append((s, cmd))
            self.commit_index = s

    def on_CatchUpReq(self, msg: CatchUpReq) -> None:
        ent = {s: self.committed[s] for s in msg.slots if s in self.committed}
        if ent:
            self.send(msg.src, CatchUpResp(entries=ent))

    def on_CatchUpResp(self, msg: CatchUpResp) -> None:
        for s, cmd in msg.entries.items():
            self.committed.setdefault(s, cmd)
            self._catching_up.discard(s)
        # replay contiguous applies
        while (self.commit_index + 1) in self.committed:
            s = self.commit_index + 1
            cmd = self.committed[s]
            self.store.apply(cmd)
            self.applied_log.append((s, cmd))
            self.commit_index = s

    # ====================================================== direct handlers
    def on_P2a(self, msg: P2a) -> None:
        self.send(msg.src, self._accept(msg))

    def on_P1a(self, msg: P1a) -> None:
        self.send(msg.src, self._promise(msg))

    def on_P3(self, msg: P3) -> None:
        self._learn_commit(msg.commit_index, msg.src)

    def on_P2b(self, msg: P2b) -> None:
        self.ingest_vote(msg.ballot, msg.slot, msg.src, msg.ok,
                         reject_ballot=msg.ballot)

    def on_P1b(self, msg: P1b) -> None:
        self._ingest_p1(msg.src, msg)

    # ========================================================= pig handlers
    def on_PigFanout(self, msg) -> None:
        self.comm.on_PigFanout(msg)

    def on_PigRelayed(self, msg) -> None:
        self.comm.on_PigRelayed(msg)

    def on_PigReply(self, msg) -> None:
        self.comm.on_PigReply(msg)

    def on_PigAggregate(self, msg: PigAggregate) -> None:
        self.comm.leader_handle_aggregate(msg)
        if isinstance(msg, _RefP1Aggregate):
            for p1b in msg.p1bs:
                self._ingest_p1(p1b.src, p1b)
            return
        if msg.reject:
            self.ingest_vote(msg.ballot, msg.slot, -1, False,
                             reject_ballot=msg.reject_ballot)
        for v in msg.voters:
            self.ingest_vote(msg.ballot, msg.slot, v, True)


@dataclass
class _Inst:
    cmd: Optional[Command] = None
    deps: frozenset = frozenset()
    seq: int = 0
    state: str = "none"       # none|preaccepted|accepted|committed|executed
    client_src: int = -1
    replies: list = field(default_factory=list)
    accept_acks: int = 0
    is_mine: bool = False


class RefEPaxosNode(RefNode):
    def __init__(self, node_id: int, net: Network, sched: Scheduler,
                 peers: list[int]):
        super().__init__(node_id, net, sched)
        self.peers = list(peers)
        self.n = len(peers)
        self.fq = fast_quorum(self.n)
        self.maj = majority(self.n)
        self.next_inum = 0
        self.insts: Dict[tuple, _Inst] = {}
        # per-key: latest interfering instance per replica (standard EPaxos
        # optimization: depend on the most recent conflict per replica)
        self.interf: Dict[int, Dict[int, tuple]] = {}
        self._pending_exec: list = []
        self.committed_count = 0

    # ---------------------------------------------------------------- leader
    def on_ClientRequest(self, msg: ClientRequest) -> None:
        cmd = msg.cmd
        inst_id = (self.id, self.next_inum)
        self.next_inum += 1
        deps = self._conflicts(cmd.key, exclude=inst_id)
        seq = 1 + max([self.insts[d].seq for d in deps], default=0)
        inst = _Inst(cmd=cmd, deps=deps, seq=seq, state="preaccepted",
                     client_src=msg.src, is_mine=True)
        self.insts[inst_id] = inst
        self._note_interf(cmd.key, inst_id)
        for p in self.peers:
            if p != self.id:
                self.send(p, PreAccept(inst=inst_id, cmd=cmd, deps=deps,
                                       seq=seq, n_cluster=self.n))

    def _conflicts(self, key: int, exclude: tuple) -> frozenset:
        m = self.interf.get(key)
        if not m:
            return frozenset()
        return frozenset(v for v in m.values() if v != exclude)

    def _note_interf(self, key: int, inst_id: tuple) -> None:
        self.interf.setdefault(key, {})[inst_id[0]] = inst_id

    # -------------------------------------------------------------- replicas
    def on_PreAccept(self, msg: PreAccept) -> None:
        local = self._conflicts(msg.cmd.key, exclude=msg.inst)
        deps = msg.deps | local
        seq = max(msg.seq, 1 + max([self.insts[d].seq for d in local
                                    if d in self.insts], default=0))
        inst = self.insts.setdefault(msg.inst, _Inst())
        if inst.state in ("committed", "executed"):
            return
        inst.cmd, inst.deps, inst.seq, inst.state = msg.cmd, deps, seq, "preaccepted"
        self._note_interf(msg.cmd.key, msg.inst)
        self.send(msg.src, PreAcceptReply(inst=msg.inst, ok=True, deps=deps,
                                          seq=seq, n_cluster=self.n))

    def on_PreAcceptReply(self, msg: PreAcceptReply) -> None:
        inst = self.insts.get(msg.inst)
        if inst is None or not inst.is_mine or inst.state != "preaccepted":
            return
        inst.replies.append(msg)
        if len(inst.replies) < self.fq - 1:
            return
        # fast path: fast quorum (incl. self) agrees on (deps, seq)
        if all(r.deps == inst.deps and r.seq == inst.seq for r in inst.replies):
            self._commit(msg.inst, inst)
        else:
            # slow path: union deps, max seq, Paxos-accept round
            for r in inst.replies:
                inst.deps = inst.deps | r.deps
                inst.seq = max(inst.seq, r.seq)
            inst.state = "accepted"
            inst.accept_acks = 1
            for p in self.peers:
                if p != self.id:
                    self.send(p, EAccept(inst=msg.inst, cmd=inst.cmd,
                                         deps=inst.deps, seq=inst.seq,
                                         n_cluster=self.n))

    def on_EAccept(self, msg: EAccept) -> None:
        inst = self.insts.setdefault(msg.inst, _Inst())
        if inst.state in ("committed", "executed"):
            return
        inst.cmd, inst.deps, inst.seq, inst.state = msg.cmd, msg.deps, msg.seq, "accepted"
        self._note_interf(msg.cmd.key, msg.inst)
        self.send(msg.src, EAcceptReply(inst=msg.inst, ok=True))

    def on_EAcceptReply(self, msg: EAcceptReply) -> None:
        inst = self.insts.get(msg.inst)
        if inst is None or not inst.is_mine or inst.state != "accepted":
            return
        inst.accept_acks += 1
        if inst.accept_acks >= self.maj:
            self._commit(msg.inst, inst)

    # ---------------------------------------------------------------- commit
    def _commit(self, inst_id: tuple, inst: _Inst) -> None:
        inst.state = "committed"
        self.committed_count += 1
        for p in self.peers:
            if p != self.id:
                self.send(p, ECommit(inst=inst_id, cmd=inst.cmd,
                                     deps=inst.deps, seq=inst.seq,
                                     n_cluster=self.n))
        self._pending_exec.append(inst_id)
        self._drain_exec()

    def on_ECommit(self, msg: ECommit) -> None:
        inst = self.insts.setdefault(msg.inst, _Inst())
        inst.cmd, inst.deps, inst.seq = msg.cmd, msg.deps, msg.seq
        if inst.state != "executed":
            inst.state = "committed"
        self._note_interf(msg.cmd.key, msg.inst)
        self._pending_exec.append(msg.inst)
        self._drain_exec()

    def _drain_exec(self) -> None:
        """Retry blocked instances until no more progress can be made."""
        progress = True
        while progress:
            progress = False
            still = []
            for iid in self._pending_exec:
                if self.insts[iid].state == "executed":
                    progress = True
                    continue
                if self._try_execute(iid):
                    progress = True
                else:
                    still.append(iid)
            self._pending_exec = still

    # --------------------------------------------------------------- execute
    def _try_execute(self, start: tuple) -> bool:
        """Execute committed instances: SCCs in dependency order, ties by
        (seq, instance id) — the EPaxos execution algorithm."""
        # Tarjan over committed subgraph reachable from ``start``
        sys_stack = [start]
        index: Dict[tuple, int] = {}
        low: Dict[tuple, int] = {}
        onstack: Dict[tuple, bool] = {}
        stack: list = []
        counter = [0]
        sccs: list = []
        blocked = [False]

        def strongconnect(v: tuple) -> None:
            work = [(v, iter(sorted(self.insts[v].deps)))]
            index[v] = low[v] = counter[0]
            counter[0] += 1
            stack.append(v)
            onstack[v] = True
            while work:
                node, it = work[-1]
                advanced = False
                for w in it:
                    iw = self.insts.get(w)
                    if iw is None or iw.state in ("none", "preaccepted", "accepted"):
                        blocked[0] = True    # an uncommitted dep: defer
                        continue
                    if iw.state == "executed":
                        continue
                    if w not in index:
                        index[w] = low[w] = counter[0]
                        counter[0] += 1
                        stack.append(w)
                        onstack[w] = True
                        work.append((w, iter(sorted(self.insts[w].deps))))
                        advanced = True
                        break
                    elif onstack.get(w):
                        low[node] = min(low[node], index[w])
                if advanced:
                    continue
                work.pop()
                if work:
                    low[work[-1][0]] = min(low[work[-1][0]], low[node])
                if low[node] == index[node]:
                    scc = []
                    while True:
                        w = stack.pop()
                        onstack[w] = False
                        scc.append(w)
                        if w == node:
                            break
                    sccs.append(scc)

        inst0 = self.insts.get(start)
        if inst0 is None or inst0.state != "committed":
            return inst0 is not None and inst0.state == "executed"
        strongconnect(start)
        if blocked[0]:
            return False   # retried by _drain_exec when the dep commits
        for scc in sccs:   # Tarjan emits SCCs in reverse topological order
            for iid in sorted(scc, key=lambda i: (self.insts[i].seq, i)):
                self._execute(iid)
        return True

    def _execute(self, inst_id: tuple) -> None:
        inst = self.insts[inst_id]
        if inst.state == "executed":
            return
        val = self.store.apply(inst.cmd)
        self.applied_log.append((inst_id, inst.cmd))
        inst.state = "executed"
        if inst.is_mine and inst.client_src >= 0:
            self.send(inst.client_src,
                      ClientReply(client_id=inst.cmd.client_id,
                                  seq=inst.cmd.seq, ok=True, value=val))
