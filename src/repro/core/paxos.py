"""Multi-Paxos with a pluggable communication layer.

The consensus core below is *identical* for Paxos and PigPaxos — only the
``comm`` strategy object differs (DirectComm vs PigComm), mirroring the
paper's central claim (§3.3) that Pig modifies only the communication
implementation and therefore inherits Paxos's safety/liveness proofs.

Multi-Paxos specifics implemented (§2.1):
  * phase-1 once per leadership, subsequent instances go straight to phase-2;
  * phase-3 (commit) piggybacked on the next phase-2 via ``commit_index``;
  * pipelined slots (multiple outstanding instances);
  * duplicate-vote suppression at the leader (voter-id sets, §3.4);
  * leader retry with fresh relays on timeout (§3.4);
  * catch-up path for followers that miss a slot body.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

from .events import Scheduler
from .messages import (BatchCmd, ClientReply, ClientRequest, Command, JoinReq,
                       LeaseAck, LeaseGrant, Msg, P1a, P1b, P2a, P2b, P3,
                       PigAggregate, ReadProbe, ReadReply, Snapshot)
from .network import Network
from .node import Node
from .pig import DirectComm, PigComm, PigConfig, _P1Aggregate
from .quorums import QuorumSystem, majority


@dataclass(slots=True)
class CatchUpReq(Msg):
    slots: tuple = ()


@dataclass(slots=True)
class CatchUpResp(Msg):
    entries: dict = field(default_factory=dict)   # slot -> Command

    def wire_size(self) -> int:
        return 24 + sum(16 + c.wire_size() for c in self.entries.values())


@dataclass(frozen=True)
class BatchConfig:
    """Leader-side request batching (HT-Paxos-style ordering-stage batching).

    The leader buffers incoming client commands and packs up to
    ``max_batch`` of them into ONE slot (one phase-2 fan-out/fan-in — and
    one Pig relay round — amortized across the batch).  A partial buffer
    flushes after ``max_delay_ms``.  ``max_batch=1`` is byte-identical to
    the unbatched engine: the buffer flushes on the first enqueue, arms no
    timer, and proposes the bare command (no BatchCmd envelope).
    """
    max_batch: int = 8
    max_delay_ms: float = 1.0

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.max_delay_ms < 0:
            raise ValueError("max_delay_ms must be >= 0")


@dataclass(frozen=True)
class LeaseConfig:
    """Leader leases for linearizable local reads (Spinnaker-style).

    A quorum of ``LeaseAck``s lets the leader answer ``get`` requests from
    its own store for ``duration_ms`` — measured on each node's LOCAL clock,
    which drifts at an unknown per-node rate bounded by ``drift_bound``
    (|rate error| <= drift_bound, e.g. 1e-4 = 100 ppm).  Followers holding
    an unexpired lease promise withhold their phase-1 vote from any OTHER
    candidate, so a new leader cannot be elected until the lease drains.

    Safety under drift: the leader only believes the lease for
    ``duration * (1 - 2*drift_bound)`` of its own clock, which is provably
    inside every follower's promise window for any rates within the bound
    ((1-2b)(1+b) <= 1-b).  ``lease_safety=False`` drops that margin — the
    deliberately-broken control: under adversarial drift the leader keeps
    serving reads after a quorum of promises has really expired, and the
    linearizability auditor must flag the resulting stale reads.
    """
    duration_ms: float = 200.0
    renew_ms: Optional[float] = None     # default: duration_ms / 3
    drift_bound: float = 1e-4
    lease_safety: bool = True

    def __post_init__(self):
        if self.duration_ms <= 0:
            raise ValueError("lease duration_ms must be > 0")
        if self.renew_ms is not None and not (0 < self.renew_ms <= self.duration_ms):
            raise ValueError("lease renew_ms must be in (0, duration_ms]")
        if not (0.0 <= self.drift_bound < 0.4):
            raise ValueError("drift_bound must be in [0, 0.4) — the safety "
                             "margin 1 - 2*drift_bound must stay positive")

    @property
    def duration_s(self) -> float:
        return self.duration_ms * 1e-3

    @property
    def renew_s(self) -> float:
        r = self.renew_ms if self.renew_ms is not None else self.duration_ms / 3.0
        return r * 1e-3


@dataclass
class _Slot:
    cmd: Command
    client_src: int = -1
    voters: set = field(default_factory=set)
    committed: bool = False
    pig_ids: list = field(default_factory=list)
    timer: Optional[int] = None
    retries: int = 0
    # batching/pipelining extensions (None/False on the unbatched path)
    client_srcs: Optional[tuple] = None   # per-sub-command reply routing
    gated: bool = False                   # counted against pipeline_depth
    # observability: trace ctx of the op that caused this slot (None when
    # untraced).  Carried so timer-driven re-proposals and the commit-time
    # client reply rejoin the op's span tree (repro.obs).
    trace: Optional[tuple] = None


class PaxosNode(Node):
    def __init__(self, node_id: int, net: Network, sched: Scheduler,
                 peers: list[int], pig: Optional[PigConfig] = None,
                 leader_timeout: float = 50e-3,
                 quorums: Optional["QuorumSystem"] = None,
                 batch: Optional[BatchConfig] = None,
                 pipeline_depth: int = 0,
                 lease: Optional[LeaseConfig] = None,
                 clock_rate: float = 0.0, clock_offset: float = 0.0):
        super().__init__(node_id, net, sched)
        self.peers = list(peers)
        self.n = len(peers)
        # flexible quorums (FPaxos, paper §7.1): Q1+Q2 > N; classic Paxos
        # uses majorities for both.  Pig composes with either (§7.1).
        self.quorums = quorums
        self.majority = quorums.q2 if quorums else majority(self.n)
        self.q1 = quorums.q1 if quorums else majority(self.n)
        self.comm = (PigComm(self, peers, pig) if pig is not None
                     else DirectComm(self, peers))
        if pig is not None:
            # bind relay-path handlers directly (instance attrs shadow the
            # delegating methods below — saves a frame on ~60% of hops)
            self.on_PigFanout = self.comm.on_PigFanout
            self.on_PigRelayed = self.comm.on_PigRelayed
            self.on_PigReply = self.comm.on_PigReply
        self.leader_timeout = leader_timeout

        # acceptor state
        self.promised: tuple = (0, 0)
        self.accepted: Dict[int, tuple] = {}      # slot -> (ballot, cmd)
        # learner state
        self.committed: Dict[int, Command] = {}
        self.commit_index: int = -1               # contiguous applied prefix
        self._catching_up: set = set()
        # leader state
        self.ballot: tuple = (0, 0)
        self.is_leader = False
        self.next_slot: int = 0
        self.log: Dict[int, _Slot] = {}
        # leader-side batching + slot pipelining.  pipeline_depth == 0 is
        # "unbounded" — the seed engine's native behavior (every request
        # proposes immediately); depth k > 0 throttles to k uncommitted
        # gated slots, queueing sealed batches in _held until a commit
        # frees a pipeline stage.
        if pipeline_depth < 0:
            raise ValueError("pipeline_depth must be >= 0")
        self.batch = batch
        self.pipeline_depth = pipeline_depth
        self._batching = batch is not None or pipeline_depth > 0
        self._buf: list = []            # (cmd, client_src) awaiting a slot
        self._buf_timer: Optional[int] = None
        self._held: list = []           # sealed batches awaiting pipeline room
        self._inflight = 0              # gated slots proposed, not committed
        self._p1_voters: set = set()
        self._p1_accepted: Dict[int, tuple] = {}
        self._p1_timer: Optional[int] = None
        self._p1_max_ci: tuple = (-1, -1)
        # at-most-once session state: client_id -> (last applied seq, result).
        # Client request-timeout retries re-send the same (client_id, seq),
        # which can legitimately get proposed in two slots (e.g. the original
        # commits via post-crash value recovery after the retry was already
        # proposed); the duplicate is skipped at apply time — identically on
        # every replica, since the decision depends only on the shared log
        # prefix — and answered from the cached result.
        self._session: Dict[int, tuple] = {}
        # membership state (single-server reconfiguration, Raft-style):
        # ``members`` is the replica set this node believes is in force;
        # configuration commands ride the normal log and activate at apply
        # time, which is safe for single-server changes because any old and
        # new majority intersect.  A ``joining`` learner accepts state but
        # never votes; a ``removed`` node stops voting permanently.
        self.members: list = sorted(peers)
        self.joining = False
        self.removed = False
        self._cfg_inflight: Optional[int] = None   # slot of the pending cfg cmd
        self._cfg_seq = 0
        self._learners: set = set()     # joiners fed P2a directly, pre-membership
        self._leader_ref: Optional[Callable[[], int]] = None
        self._join_catch_up = True
        self._snap_installed = False
        # cluster-level hooks (no protocol semantics; used by Cluster to track
        # the current leader / membership view for client routing and audits)
        self.on_became_leader: Optional[Callable] = None
        self.on_membership_change: Optional[Callable] = None
        # ---- read paths: leader leases + per-key commit frontiers ----
        # each node owns a drifting local clock: local = (1+rate)*t + offset.
        # All lease comparisons are elapsed-local (offsets cancel); the rate
        # term is what makes an unsafe lease margin a REAL stale-read hazard.
        self.lease = lease
        self.clock_rate = clock_rate
        self.clock_offset = clock_offset
        self._lease_seq = 0                       # leader: renewal counter
        self._lease_acks: Dict[int, set] = {}     # lseq -> acked node ids
        self._lease_sent_local: Dict[int, float] = {}
        self._lease_held_until_local = float("-inf")
        self._lease_timer: Optional[int] = None
        self._lease_promise: Optional[tuple] = None  # (holder, expiry_local)
        # per-key frontiers for quorum reads: applied = (slot, wtag) of the
        # latest locally-applied put; accepted = highest slot that MIGHT
        # hold a put to the key (accepted-but-unapplied included)
        self._applied_frontier: Dict[int, tuple] = {}
        self._accepted_frontier: Dict[int, int] = {}
        # metrics
        self.committed_count = 0
        self.lease_reads = 0

    # ================================================================ leader
    def start_phase1(self) -> None:
        if self.joining or self.removed:
            return      # non-members never campaign
        b = (max(self.promised[0], self.ballot[0]) + 1, self.id)
        self.ballot = b
        self.is_leader = False
        self._p1_voters = {self.id}
        self._p1_accepted = {s: v for s, v in self.accepted.items()
                             if s > self.commit_index}
        self._p1_max_ci = (-1, -1)
        self.promised = b
        self.comm.broadcast(lambda: P1a(ballot=b), round_key=("p1", b))
        self._p1_timer = self.set_timer(self.leader_timeout, self._p1_retry)

    def _p1_retry(self) -> None:
        if not self.is_leader and self.ballot[1] == self.id:
            self.start_phase1()

    def _ingest_p1(self, voter: int, msg: P1b) -> None:
        if self.is_leader or msg.ballot != self.ballot:
            if not msg.ok and msg.ballot > self.ballot:
                self._step_down(msg.ballot)
            return
        self._p1_voters.add(voter)
        ci = getattr(msg, "commit_index", -1)
        if ci > self._p1_max_ci[0]:
            self._p1_max_ci = (ci, voter)
        for s, (b, cmd) in msg.accepted.items():
            cur = self._p1_accepted.get(s)
            if cur is None or b > cur[0]:
                self._p1_accepted[s] = (b, cmd)
        if len(self._p1_voters) >= self.q1:
            self._become_leader()

    def _become_leader(self) -> None:
        self.is_leader = True
        if self._p1_timer is not None:
            self.cancel_timer(self._p1_timer)
        if self._batching:
            # buffered commands are volatile leader state: a crash lost them
            # (clients retry; session dedup absorbs duplicates), and surviving
            # log entries re-arm ungated — recovery correctness outranks the
            # pipeline throttle for one round
            self._drop_buffers(bounce=False)
            for e in self.log.values():
                e.gated = False
        # catch up slots that a quorum already committed (they are pruned
        # from P1b.accepted, so they must be *learned*, not re-proposed)
        max_ci, ci_src = self._p1_max_ci
        if max_ci > self.commit_index and ci_src >= 0:
            self._learn_commit(max_ci, ci_src)
        # re-propose uncommitted values found during phase-1 (§2.1)
        pre_existing = sorted(self.log)   # local proposals surviving a crash
        slots = sorted(self._p1_accepted)
        for s in slots:
            _, cmd = self._p1_accepted[s]
            if s <= max(self.commit_index, max_ci) or s in self.log:
                continue
            self.next_slot = max(self.next_slot, s + 1)
            self._propose_at(s, cmd, client_src=-1)
        self.next_slot = max(self.next_slot, self.commit_index + 1,
                             max_ci + 1)
        # re-arm uncommitted local proposals that survived a crash-recover:
        # their slot timers died with the crash (set_timer suppresses fires
        # on crashed nodes) and the phase-1 recovery above deliberately
        # skips slots still present in self.log — without this, an in-flight
        # slot at crash time would stall the contiguous-apply prefix forever.
        # Only PRE-EXISTING entries re-arm (slots the recovery loop just
        # proposed already broadcast); first-time elections have an empty
        # log, so this is a no-op there.
        for s in pre_existing:
            entry = self.log[s]
            if entry.committed or s <= self.commit_index:
                continue
            if entry.timer is not None:    # pre-crash timer may still pend
                self.cancel_timer(entry.timer)
            entry.voters = {self.id}       # stale-ballot votes don't count
            self.accepted[s] = (self.ballot, entry.cmd)
            self._send_p2a(s)
        if self.lease is not None:
            self._lease_renew()
        cb = self.on_became_leader
        if cb is not None:
            cb(self)

    # ================================================================ leases
    def local_now(self) -> float:
        """This node's drifting local clock (lease math only — timers and
        the network stay on simulated real time)."""
        return (1.0 + self.clock_rate) * self.sched.now + self.clock_offset

    def lease_held(self) -> bool:
        return self.local_now() < self._lease_held_until_local

    def _lease_renew(self) -> None:
        if not self.is_leader or self.lease is None or self.crashed:
            return
        lz = self.lease
        self._lease_seq += 1
        lseq = self._lease_seq
        # the grant-SEND instant anchors the belief window: it precedes
        # every follower's receipt, so leader-elapsed >= follower-elapsed
        # modulo drift (which the margin covers)
        self._lease_sent_local[lseq] = self.local_now()
        self._lease_acks[lseq] = {self.id}       # self-ack: own promise
        stale = [q for q in self._lease_acks if q < lseq - 8]
        for q in stale:
            self._lease_acks.pop(q, None)
            self._lease_sent_local.pop(q, None)
        m = LeaseGrant(ballot=self.ballot, lseq=lseq, duration=lz.duration_s)
        for p in self.members:
            if p != self.id:
                self.send(p, m)
        self._lease_timer = self.set_timer(lz.renew_s, self._lease_renew)

    def on_LeaseGrant(self, msg: LeaseGrant) -> None:
        if self.joining or self.removed:
            return
        if msg.ballot < self.promised:
            return        # a newer leader exists: never re-arm an old lease
        holder = msg.ballot[1]
        now_l = self.local_now()
        pr = self._lease_promise
        if pr is not None and pr[0] != holder and pr[1] > now_l:
            return        # conflicting unexpired promise: refuse silently
        # promise duration runs on THIS node's clock from receipt
        self._lease_promise = (holder, now_l + msg.duration)
        self.send(msg.src, LeaseAck(ballot=msg.ballot, lseq=msg.lseq))

    def on_LeaseAck(self, msg: LeaseAck) -> None:
        if not self.is_leader or msg.ballot != self.ballot:
            return
        acks = self._lease_acks.get(msg.lseq)
        if acks is None:
            return
        acks.add(msg.src)
        if len(acks) >= self.majority:
            sent = self._lease_sent_local.get(msg.lseq)
            if sent is None:
                return
            lz = self.lease
            # the safety margin: believe only (1 - 2b) of the granted
            # duration (measured on our clock) — see LeaseConfig docstring.
            # lease_safety=False is the checkable broken control.
            margin = (1.0 - 2.0 * lz.drift_bound) if lz.lease_safety else 1.0
            until = sent + lz.duration_s * margin
            if until > self._lease_held_until_local:
                self._lease_held_until_local = until

    def _lease_clear(self) -> None:
        self._lease_held_until_local = float("-inf")
        self._lease_acks.clear()
        self._lease_sent_local.clear()
        if self._lease_timer is not None:
            self.cancel_timer(self._lease_timer)
            self._lease_timer = None

    # ========================================================== quorum reads
    def on_ReadProbe(self, msg: ReadProbe) -> None:
        key = msg.key
        ap = self._applied_frontier.get(key)
        acc = self._accepted_frontier.get(key, -1)
        applied = ap[0] if ap is not None else -1
        self.send(msg.src, ReadReply(
            rid=msg.rid, key=key, applied=applied,
            accepted=max(acc, applied),
            value=self.store.data.get(key),
            wtag=ap[1] if ap is not None else None))

    def _note_accepted(self, slot: int, cmd: Command) -> None:
        if cmd.__class__ is BatchCmd:
            fr = self._accepted_frontier
            for c in cmd.cmds:
                if c.op == "put" and slot > fr.get(c.key, -1):
                    fr[c.key] = slot
        elif cmd.op == "put":
            fr = self._accepted_frontier
            if slot > fr.get(cmd.key, -1):
                fr[cmd.key] = slot

    def _step_down(self, higher: tuple) -> None:
        self.is_leader = False
        self._lease_clear()
        self._cfg_inflight = None      # a pending cfg cmd is the new leader's
        for e in self.log.values():
            if e.timer is not None:
                self.cancel_timer(e.timer)
        self.log.clear()
        if self._batching:
            self._drop_buffers(bounce=True)

    def _drop_buffers(self, bounce: bool) -> None:
        """Clear the batching buffers.  ``bounce=True`` (step-down) answers
        each buffered client ok=False — the same fast not-leader bounce an
        unbatched follower sends — so clients re-route without waiting out
        their request timeout."""
        if self._buf_timer is not None:
            self.cancel_timer(self._buf_timer)
            self._buf_timer = None
        pending = self._buf + [p for b in self._held for p in b]
        self._buf = []
        self._held = []
        self._inflight = 0
        if bounce:
            for cmd, src in pending:
                if src >= 0:
                    self.send(src, ClientReply(client_id=cmd.client_id,
                                               seq=cmd.seq, ok=False))

    # -------------------------------------------------------------- phase 2
    def on_ClientRequest(self, msg: ClientRequest) -> None:
        if not self.is_leader:
            self.send(msg.src, ClientReply(client_id=msg.cmd.client_id,
                                           seq=msg.cmd.seq, ok=False))
            return
        cmd = msg.cmd
        if (self.lease is not None and cmd.op == "get"
                and self.local_now() < self._lease_held_until_local):
            # leased local read: the store reflects every write this leader
            # has acked (acks happen at apply), and the lease promise quorum
            # blocks any other leader from committing writes we can't see —
            # no slot, no fan-out, no round trip.  Linearizable iff the
            # belief window really is inside the promise windows (the
            # drift-margin argument in LeaseConfig).
            self.lease_reads += 1
            self.send(msg.src, ClientReply(client_id=cmd.client_id,
                                           seq=cmd.seq, ok=True,
                                           value=self.store.data.get(cmd.key),
                                           path="lease"))
            return
        if self._batching:
            self._enqueue(msg.cmd, msg.src)
            return
        slot = self.next_slot
        self.next_slot += 1
        self._propose_at(slot, msg.cmd, client_src=msg.src)

    # ------------------------------------------------ batching + pipelining
    def _enqueue(self, cmd: Command, client_src: int) -> None:
        self._buf.append((cmd, client_src))
        b = self.batch
        if b is None or len(self._buf) >= b.max_batch:
            self._flush_buf()
        elif self._buf_timer is None:
            self._buf_timer = self.set_timer(b.max_delay_ms * 1e-3,
                                             self._buf_timeout)

    def _buf_timeout(self) -> None:
        self._buf_timer = None
        self._flush_buf()

    def _flush_buf(self) -> None:
        if self._buf_timer is not None:
            self.cancel_timer(self._buf_timer)
            self._buf_timer = None
        if not self._buf:
            return
        buf = self._buf
        self._buf = []
        d = self.pipeline_depth
        if d > 0 and self._inflight >= d:
            self._held.append(buf)     # pipeline full: hold the sealed batch
            return
        self._propose_batch(buf)

    def _propose_batch(self, buf: list) -> None:
        slot = self.next_slot
        self.next_slot += 1
        gated = self.pipeline_depth > 0
        if gated:
            self._inflight += 1
        if len(buf) == 1:
            # size-1 batch proposes the bare command: identical wire bytes,
            # replies, and session state to the unbatched engine
            cmd, src = buf[0]
            self._propose_at(slot, cmd, client_src=src)
        else:
            self._propose_at(slot, BatchCmd(cmds=tuple(c for c, _ in buf)),
                             client_src=-1,
                             client_srcs=tuple(s for _, s in buf))
        if gated:
            self.log[slot].gated = True

    def _release_held(self) -> None:
        d = self.pipeline_depth
        while self._held and (d <= 0 or self._inflight < d):
            self._propose_batch(self._held.pop(0))

    def _propose_at(self, slot: int, cmd: Command, client_src: int,
                    client_srcs: Optional[tuple] = None) -> None:
        entry = _Slot(cmd=cmd, client_src=client_src, client_srcs=client_srcs)
        entry.voters.add(self.id)
        tr = self.net.tracer
        if tr is not None:
            # the ambient ctx (the ClientRequest hop that proposed, when
            # message-driven; None from batch-flush/retry timers)
            entry.trace = tr.cur
        self.log[slot] = entry
        # leader accepts locally
        self.accepted[slot] = (self.ballot, cmd)
        self._note_accepted(slot, cmd)
        self._send_p2a(slot)

    def _send_p2a(self, slot: int) -> None:
        entry = self.log[slot]
        b, ci = self.ballot, self.commit_index

        def make() -> P2a:
            return P2a(ballot=b, slot=slot, cmd=entry.cmd, commit_index=ci)

        tr = self.net.tracer
        if tr is not None and entry.trace is not None:
            # re-establish the op's ctx so timer-driven re-proposals (slot
            # timeout retries) broadcast hops that rejoin its span tree
            prev = tr.cur
            tr.cur = entry.trace
            entry.pig_ids = self.comm.broadcast(make, round_key=slot) or []
            tr.cur = prev
        else:
            entry.pig_ids = self.comm.broadcast(make, round_key=slot) or []
        if self._learners:
            # joining learners are outside the comm's member set: feed them
            # the P2a directly so they follow the log (they never vote)
            m = make()
            for lid in self._learners:
                self.send(lid, m)
        entry.timer = self.set_timer(self.leader_timeout,
                                     lambda: self._slot_timeout(slot))

    def _slot_timeout(self, slot: int) -> None:
        entry = self.log.get(slot)
        if entry is None or entry.committed or not self.is_leader:
            return
        # gray non-responsive relays, then retry with fresh random relays (§3.4)
        self.comm.on_round_timeout(entry.pig_ids)
        entry.retries += 1
        self._send_p2a(slot)

    def ingest_vote(self, ballot: tuple, slot: int, voter: int, ok: bool,
                    reject_ballot: tuple = (0, 0)) -> None:
        if not ok:
            if reject_ballot > self.ballot:
                self._step_down(reject_ballot)
            return
        if ballot != self.ballot or not self.is_leader:
            return
        entry = self.log.get(slot)
        if entry is None or entry.committed:
            return
        entry.voters.add(voter)   # set => duplicate votes counted once (§3.4)
        if len(entry.voters) >= self.majority:
            self._commit(slot)

    def _commit(self, slot: int) -> None:
        entry = self.log[slot]
        entry.committed = True
        if entry.timer is not None:
            self.cancel_timer(entry.timer)
        self.committed[slot] = entry.cmd
        self.committed_count += 1
        if entry.gated:
            entry.gated = False
            self._inflight -= 1
            if self._held:
                self._release_held()
        self._advance()

    def _apply_slot(self, s: int, cmd: Command) -> tuple:
        """Apply one contiguously-committed slot with at-most-once session
        dedup.  THE single apply path — every caller (_advance,
        _learn_commit, on_CatchUpResp) must go through it, because the
        auditor's replica-agreement check relies on all replicas making
        byte-identical apply/skip decisions over the shared log prefix.

        Returns ``(ack, val)``: ``ack`` is True when a waiting client
        should be answered with ``val`` — either a fresh apply or an exact
        duplicate (timeout retry) answered from the session cache; a stale
        duplicate (seq below the session high-water mark) gets neither an
        apply nor a reply.

        A ``BatchCmd`` applies its sub-commands in order, each through the
        same dedup logic (identical skip decisions on every replica); the
        return value is then ``(True, [(ack, val), ...])`` — one pair per
        sub-command, in batch order."""
        if cmd.__class__ is BatchCmd:
            return True, [self._apply_slot(s, c) for c in cmd.cmds]
        sess = self._session.get(cmd.client_id)
        if sess is not None and cmd.seq <= sess[0]:
            if cmd.seq == sess[0]:
                return True, sess[1]       # duplicate: cached result
            return False, None             # stale duplicate: drop
        store = self.store                 # inline KVStore.apply (hot path)
        store.applied_ops += 1
        if cmd.op == "put":
            store.data[cmd.key] = cmd.value
            self._applied_frontier[cmd.key] = (s, (cmd.client_id, cmd.seq))
            val = None
        elif cmd.op == "get":
            val = store.data.get(cmd.key)
        else:
            val = None                     # configuration command
            self._apply_membership(cmd)
        self._session[cmd.client_id] = (cmd.seq, val)
        self.applied_log.append((s, cmd))
        return True, val

    def _advance(self) -> None:
        """Apply contiguously committed slots; reply to waiting clients."""
        while (self.commit_index + 1) in self.committed:
            s = self.commit_index + 1
            cmd = self.committed[s]
            self.commit_index = s
            ack, val = self._apply_slot(s, cmd)
            e = self.log.get(s)
            if e is None:
                continue
            tr = self.net.tracer
            if cmd.__class__ is BatchCmd:
                srcs = e.client_srcs
                if srcs:    # None after crash-recovery re-propose: no replies
                    owner = (tr.meta[e.trace[0]]["client"]
                             if tr is not None and e.trace is not None
                             else -1)
                    for c, src, (a, v) in zip(cmd.cmds, srcs, val):
                        if a and src >= 0:
                            reply = ClientReply(client_id=c.client_id,
                                                seq=c.seq, ok=True, value=v)
                            if src == owner:
                                # only the slot-owning op's reply rejoins
                                # its span tree (the batch shares one ctx)
                                tr.attach(reply, e.trace)
                            self.send(src, reply)
            elif ack and e.client_src >= 0:
                reply = ClientReply(client_id=cmd.client_id, seq=cmd.seq,
                                    ok=True, value=val)
                if tr is not None and e.trace is not None:
                    tr.attach(reply, e.trace)
                self.send(e.client_src, reply)

    # ===================================================== membership change
    def propose_reconfig(self, op: str, nid: int) -> bool:
        """Propose a single-server membership change (``add_node`` /
        ``remove_node``) through the normal log.  At most ONE configuration
        command may be in flight at a time — the Raft one-at-a-time
        invariant that keeps every old/new majority pair intersecting.
        Returns False (caller retries later) when this node is not the
        leader, a cfg command is already pending, or the change is a no-op.
        """
        if (not self.is_leader or self.removed
                or self._cfg_inflight is not None):
            return False
        if (op == "add_node") == (nid in self.members):
            return False                   # no-op change
        self._cfg_seq += 1
        # negative client ids keep cfg commands out of the client session
        # space; the session table still dedups re-proposed cfg commands
        cmd = Command(client_id=-(self.id + 1), seq=self._cfg_seq,
                      op=op, key=nid)
        slot = self.next_slot
        self.next_slot += 1
        self._cfg_inflight = slot
        self._propose_at(slot, cmd, client_src=-1)
        return True

    def _apply_membership(self, cmd: Command) -> None:
        """Activate a committed configuration command.  Runs on every
        replica at apply time (the single shared apply path), so all members
        switch configurations at the same log position."""
        nid = cmd.key
        members = self.members
        changed = False
        if cmd.op == "add_node":
            if nid not in members:
                members.append(nid)
                members.sort()
                changed = True
            if nid == self.id:
                self.joining = False       # promoted from learner to member
        elif cmd.op == "remove_node":
            if nid in members:
                members.remove(nid)
                changed = True
        else:
            raise RuntimeError(f"unknown configuration op {cmd.op!r}")
        # one-at-a-time: the cfg command being applied IS the pending one
        self._cfg_inflight = None
        if not changed:
            return
        self._refresh_membership()
        if cmd.op == "remove_node":
            self._learners.discard(nid)
            if nid == self.id:
                self.removed = True
                if self.is_leader:
                    self._step_down(self.ballot)
        cb = self.on_membership_change
        if cb is not None:
            cb(self, cmd.op, nid)

    def _refresh_membership(self) -> None:
        """Re-derive quorum sizes and the comm topology from ``members`` —
        for PigComm this re-partitions the relay groups (stale cached
        partitions are dropped; in-flight rounds finish under the leader's
        timeout/retry path)."""
        self.peers = list(self.members)
        self.n = len(self.peers)
        q = self.quorums
        self.majority = q.q2 if q else majority(self.n)
        self.q1 = q.q1 if q else majority(self.n)
        self.comm.set_members(self.peers)

    def begin_join(self, leader_ref: Callable[[], int],
                   catch_up: bool = True) -> None:
        """Start the learner protocol: ask the leader for a state snapshot,
        then follow the log (via the direct learner P2a feed + the normal
        commit_index/CatchUp suffix path) WITHOUT voting until the
        ``add_node`` command naming this node is applied.  ``catch_up=False``
        is the deliberately-broken control for the auditor tests: the joiner
        skips the snapshot state and serves from an empty store."""
        self.joining = True
        self._leader_ref = leader_ref
        self._join_catch_up = catch_up
        self._snap_installed = False
        self._send_join()

    def _send_join(self) -> None:
        if not self.joining or self.crashed:
            return
        self.send(self._leader_ref(), JoinReq(node=self.id))
        # retried against the (possibly new) leader until membership lands
        self.set_timer(4 * self.leader_timeout, self._send_join)

    def on_JoinReq(self, msg: JoinReq) -> None:
        if not self.is_leader:
            return                         # joiner retries on its timer
        nid = msg.node
        self._learners.add(nid)
        self.send(nid, Snapshot(commit_index=self.commit_index,
                                store=dict(self.store.data),
                                session=dict(self._session),
                                members=tuple(self.members)))
        if nid not in self.members:
            self.propose_reconfig("add_node", nid)

    def on_Snapshot(self, msg: Snapshot) -> None:
        if not self.joining or self._snap_installed:
            return                         # only the first snapshot installs
        self._snap_installed = True
        if self._join_catch_up:
            self.store.data = dict(msg.store)
            self._session = dict(msg.session)
        # state below the snapshot point arrives as *state*, not log: the
        # applied log restarts here (the auditor checks joiner logs as a
        # contiguous infix of the witness order)
        self.applied_log = []
        self.committed = {}
        self.accepted = {s: v for s, v in self.accepted.items()
                         if s > msg.commit_index}
        self.commit_index = max(self.commit_index, msg.commit_index)
        self.members = sorted(msg.members)
        self._refresh_membership()

    # ============================================================== recovery
    def recover(self) -> None:
        """Node recovery with protocol semantics (the base class only clears
        the crashed flag).  A recovered follower needs nothing — it catches
        up through the commit_index piggybacked on later traffic.  A
        recovered *leader* (the owner of the current ballot) must re-run
        phase 1 with a fresh ballot: all its timers died while it was down
        (``set_timer`` suppresses fires on crashed nodes), so without a
        re-election every slot that was in flight at crash time — and hence
        the contiguous-apply prefix — would stall forever.  ``_become_leader``
        then re-proposes both phase-1-recovered values and the surviving
        local log entries (client reply routing intact)."""
        if not self.crashed:
            return
        super().recover()
        # a CatchUpReq outstanding at crash time is lost (its response was
        # dropped and the discard timer was suppressed while down): forget
        # it so _learn_commit re-requests instead of wedging at that slot
        self._catching_up.clear()
        # the lease BELIEF is volatile (a restarted leader must re-acquire
        # before serving local reads); the lease PROMISE survives — the
        # conservative direction, a restarted follower keeps withholding
        self._lease_clear()
        if self.ballot[1] == self.id and not self.removed:
            self.is_leader = False
            self.start_phase1()

    def flush_commits(self) -> None:
        """Idle-time commit propagation (harness use; P3 is normally
        piggybacked on the next P2a)."""
        for p in self.peers:
            if p != self.id:
                self.send(p, P3(commit_index=self.commit_index))

    # ============================================================== acceptor
    def process_inner(self, msg: Msg):
        """Handle a (possibly relayed) leader message; return the reply."""
        if isinstance(msg, P2a):
            return self._accept(msg)
        if isinstance(msg, P1a):
            return self._promise(msg)
        if isinstance(msg, P3):
            self._learn_commit(msg.commit_index, msg.src)
            return None
        raise RuntimeError(f"unexpected inner {msg.kind}")

    def _accept(self, msg: P2a) -> Optional[P2b]:
        if msg.ballot >= self.promised:
            self.promised = msg.ballot
            self.accepted[msg.slot] = (msg.ballot, msg.cmd)
            self._note_accepted(msg.slot, msg.cmd)
            self._learn_commit(msg.commit_index, msg.src)
            if self.joining or self.removed:
                return None    # learners/removed nodes follow but never vote
            r = P2b(ballot=msg.ballot, slot=msg.slot, ok=True)
        else:
            if self.joining or self.removed:
                return None
            r = P2b(ballot=self.promised, slot=msg.slot, ok=False)
        r.src = self.id
        return r

    def _promise(self, msg: P1a) -> Optional[P1b]:
        if self.joining or self.removed:
            return None        # non-members don't vote in elections either
        pr = self._lease_promise
        if (pr is not None and pr[0] != msg.ballot[1]
                and pr[1] > self.local_now()):
            # lease promise in force for another node: withhold the vote
            # entirely (the candidate re-campaigns on its leader timeout),
            # so a new leader is blocked until the lease drains — the
            # availability price of leased reads, measured by the `lease`
            # scenario family
            return None
        if msg.ballot > self.promised:
            if self.is_leader:
                # a live leader yielding to a higher ballot (planned handoff
                # via replace_leader, or a competing campaign): step down so
                # in-flight slots fail over to the new leader's phase-1
                self._step_down(msg.ballot)
            self.promised = msg.ballot
            acc = {s: v for s, v in self.accepted.items()
                   if s > self.commit_index}
            r = P1b(ballot=msg.ballot, ok=True, accepted=acc,
                    commit_index=self.commit_index)
        else:
            r = P1b(ballot=self.promised, ok=False)
        r.src = self.id
        return r

    def _learn_commit(self, ci: int, leader_src: int) -> None:
        comm = self.comm
        if comm._pending_sup:       # no-op unless supplements are pending
            comm.note_committed_up_to(ci)
        while self.commit_index < ci:
            s = self.commit_index + 1
            if s in self.committed:
                cmd = self.committed[s]
            elif s in self.accepted:
                cmd = self.accepted[s][1]
            else:
                if s not in self._catching_up and leader_src >= 0:
                    self._catching_up.add(s)
                    self.send(leader_src, CatchUpReq(slots=(s,)))
                    # allow a re-request if the response gets lost
                    self.set_timer(2 * self.leader_timeout,
                                   lambda s=s: self._catching_up.discard(s))
                return
            self.committed.setdefault(s, cmd)
            self.commit_index = s
            self._apply_slot(s, cmd)

    def on_CatchUpReq(self, msg: CatchUpReq) -> None:
        ent = {s: self.committed[s] for s in msg.slots if s in self.committed}
        if ent:
            self.send(msg.src, CatchUpResp(entries=ent))

    def on_CatchUpResp(self, msg: CatchUpResp) -> None:
        for s, cmd in msg.entries.items():
            self.committed.setdefault(s, cmd)
            self._catching_up.discard(s)
        # replay contiguous applies (shared apply path: caught-up replicas
        # make identical apply decisions)
        while (self.commit_index + 1) in self.committed:
            s = self.commit_index + 1
            cmd = self.committed[s]
            self.commit_index = s
            self._apply_slot(s, cmd)

    # ====================================================== direct handlers
    def on_P2a(self, msg: P2a) -> None:
        r = self._accept(msg)
        if r is not None:       # None => non-voting learner/removed node
            self.send(msg.src, r)

    def on_P1a(self, msg: P1a) -> None:
        r = self._promise(msg)
        if r is not None:
            self.send(msg.src, r)

    def on_P3(self, msg: P3) -> None:
        self._learn_commit(msg.commit_index, msg.src)

    def on_P2b(self, msg: P2b) -> None:
        self.ingest_vote(msg.ballot, msg.slot, msg.src, msg.ok,
                         reject_ballot=msg.ballot)

    def on_P1b(self, msg: P1b) -> None:
        self._ingest_p1(msg.src, msg)

    # ========================================================= pig handlers
    def on_PigFanout(self, msg) -> None:
        self.comm.on_PigFanout(msg)

    def on_PigRelayed(self, msg) -> None:
        self.comm.on_PigRelayed(msg)

    def on_PigReply(self, msg) -> None:
        self.comm.on_PigReply(msg)

    def on_PigAggregate(self, msg: PigAggregate) -> None:
        self.comm.leader_handle_aggregate(msg)
        if isinstance(msg, _P1Aggregate):
            for p1b in msg.p1bs:
                self._ingest_p1(p1b.src, p1b)
            return
        if msg.reject:
            self.ingest_vote(msg.ballot, msg.slot, -1, False,
                             reject_ballot=msg.reject_ballot)
        # batch-ingest the ok votes (same guards as ingest_vote, hoisted out
        # of the per-voter loop; set.update dedups exactly like repeated .add)
        voters = msg.voters
        if not voters or msg.ballot != self.ballot or not self.is_leader:
            return
        entry = self.log.get(msg.slot)
        if entry is None or entry.committed:
            return
        entry.voters.update(voters)
        if len(entry.voters) >= self.majority:
            self._commit(msg.slot)
