"""Multi-Paxos with a pluggable communication layer.

The consensus core below is *identical* for Paxos and PigPaxos — only the
``comm`` strategy object differs (DirectComm vs PigComm), mirroring the
paper's central claim (§3.3) that Pig modifies only the communication
implementation and therefore inherits Paxos's safety/liveness proofs.

Multi-Paxos specifics implemented (§2.1):
  * phase-1 once per leadership, subsequent instances go straight to phase-2;
  * phase-3 (commit) piggybacked on the next phase-2 via ``commit_index``;
  * pipelined slots (multiple outstanding instances);
  * duplicate-vote suppression at the leader (voter-id sets, §3.4);
  * leader retry with fresh relays on timeout (§3.4);
  * catch-up path for followers that miss a slot body.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

from .events import Scheduler
from .messages import (ClientReply, ClientRequest, Command, Msg, P1a, P1b,
                       P2a, P2b, P3, PigAggregate)
from .network import Network
from .node import Node
from .pig import DirectComm, PigComm, PigConfig, _P1Aggregate
from .quorums import QuorumSystem, majority


@dataclass(slots=True)
class CatchUpReq(Msg):
    slots: tuple = ()


@dataclass(slots=True)
class CatchUpResp(Msg):
    entries: dict = field(default_factory=dict)   # slot -> Command

    def wire_size(self) -> int:
        return 24 + sum(16 + c.wire_size() for c in self.entries.values())


@dataclass
class _Slot:
    cmd: Command
    client_src: int = -1
    voters: set = field(default_factory=set)
    committed: bool = False
    pig_ids: list = field(default_factory=list)
    timer: Optional[int] = None
    retries: int = 0


class PaxosNode(Node):
    def __init__(self, node_id: int, net: Network, sched: Scheduler,
                 peers: list[int], pig: Optional[PigConfig] = None,
                 leader_timeout: float = 50e-3,
                 quorums: Optional["QuorumSystem"] = None):
        super().__init__(node_id, net, sched)
        self.peers = list(peers)
        self.n = len(peers)
        # flexible quorums (FPaxos, paper §7.1): Q1+Q2 > N; classic Paxos
        # uses majorities for both.  Pig composes with either (§7.1).
        self.quorums = quorums
        self.majority = quorums.q2 if quorums else majority(self.n)
        self.q1 = quorums.q1 if quorums else majority(self.n)
        self.comm = (PigComm(self, peers, pig) if pig is not None
                     else DirectComm(self, peers))
        if pig is not None:
            # bind relay-path handlers directly (instance attrs shadow the
            # delegating methods below — saves a frame on ~60% of hops)
            self.on_PigFanout = self.comm.on_PigFanout
            self.on_PigRelayed = self.comm.on_PigRelayed
            self.on_PigReply = self.comm.on_PigReply
        self.leader_timeout = leader_timeout

        # acceptor state
        self.promised: tuple = (0, 0)
        self.accepted: Dict[int, tuple] = {}      # slot -> (ballot, cmd)
        # learner state
        self.committed: Dict[int, Command] = {}
        self.commit_index: int = -1               # contiguous applied prefix
        self._catching_up: set = set()
        # leader state
        self.ballot: tuple = (0, 0)
        self.is_leader = False
        self.next_slot: int = 0
        self.log: Dict[int, _Slot] = {}
        self._p1_voters: set = set()
        self._p1_accepted: Dict[int, tuple] = {}
        self._p1_timer: Optional[int] = None
        self._p1_max_ci: tuple = (-1, -1)
        # at-most-once session state: client_id -> (last applied seq, result).
        # Client request-timeout retries re-send the same (client_id, seq),
        # which can legitimately get proposed in two slots (e.g. the original
        # commits via post-crash value recovery after the retry was already
        # proposed); the duplicate is skipped at apply time — identically on
        # every replica, since the decision depends only on the shared log
        # prefix — and answered from the cached result.
        self._session: Dict[int, tuple] = {}
        # metrics
        self.committed_count = 0

    # ================================================================ leader
    def start_phase1(self) -> None:
        b = (max(self.promised[0], self.ballot[0]) + 1, self.id)
        self.ballot = b
        self.is_leader = False
        self._p1_voters = {self.id}
        self._p1_accepted = {s: v for s, v in self.accepted.items()
                             if s > self.commit_index}
        self._p1_max_ci = (-1, -1)
        self.promised = b
        self.comm.broadcast(lambda: P1a(ballot=b), round_key=("p1", b))
        self._p1_timer = self.set_timer(self.leader_timeout, self._p1_retry)

    def _p1_retry(self) -> None:
        if not self.is_leader and self.ballot[1] == self.id:
            self.start_phase1()

    def _ingest_p1(self, voter: int, msg: P1b) -> None:
        if self.is_leader or msg.ballot != self.ballot:
            if not msg.ok and msg.ballot > self.ballot:
                self._step_down(msg.ballot)
            return
        self._p1_voters.add(voter)
        ci = getattr(msg, "commit_index", -1)
        if ci > self._p1_max_ci[0]:
            self._p1_max_ci = (ci, voter)
        for s, (b, cmd) in msg.accepted.items():
            cur = self._p1_accepted.get(s)
            if cur is None or b > cur[0]:
                self._p1_accepted[s] = (b, cmd)
        if len(self._p1_voters) >= self.q1:
            self._become_leader()

    def _become_leader(self) -> None:
        self.is_leader = True
        if self._p1_timer is not None:
            self.cancel_timer(self._p1_timer)
        # catch up slots that a quorum already committed (they are pruned
        # from P1b.accepted, so they must be *learned*, not re-proposed)
        max_ci, ci_src = self._p1_max_ci
        if max_ci > self.commit_index and ci_src >= 0:
            self._learn_commit(max_ci, ci_src)
        # re-propose uncommitted values found during phase-1 (§2.1)
        pre_existing = sorted(self.log)   # local proposals surviving a crash
        slots = sorted(self._p1_accepted)
        for s in slots:
            _, cmd = self._p1_accepted[s]
            if s <= max(self.commit_index, max_ci) or s in self.log:
                continue
            self.next_slot = max(self.next_slot, s + 1)
            self._propose_at(s, cmd, client_src=-1)
        self.next_slot = max(self.next_slot, self.commit_index + 1,
                             max_ci + 1)
        # re-arm uncommitted local proposals that survived a crash-recover:
        # their slot timers died with the crash (set_timer suppresses fires
        # on crashed nodes) and the phase-1 recovery above deliberately
        # skips slots still present in self.log — without this, an in-flight
        # slot at crash time would stall the contiguous-apply prefix forever.
        # Only PRE-EXISTING entries re-arm (slots the recovery loop just
        # proposed already broadcast); first-time elections have an empty
        # log, so this is a no-op there.
        for s in pre_existing:
            entry = self.log[s]
            if entry.committed or s <= self.commit_index:
                continue
            if entry.timer is not None:    # pre-crash timer may still pend
                self.cancel_timer(entry.timer)
            entry.voters = {self.id}       # stale-ballot votes don't count
            self.accepted[s] = (self.ballot, entry.cmd)
            self._send_p2a(s)

    def _step_down(self, higher: tuple) -> None:
        self.is_leader = False
        for e in self.log.values():
            if e.timer is not None:
                self.cancel_timer(e.timer)
        self.log.clear()

    # -------------------------------------------------------------- phase 2
    def on_ClientRequest(self, msg: ClientRequest) -> None:
        if not self.is_leader:
            self.send(msg.src, ClientReply(client_id=msg.cmd.client_id,
                                           seq=msg.cmd.seq, ok=False))
            return
        slot = self.next_slot
        self.next_slot += 1
        self._propose_at(slot, msg.cmd, client_src=msg.src)

    def _propose_at(self, slot: int, cmd: Command, client_src: int) -> None:
        entry = _Slot(cmd=cmd, client_src=client_src)
        entry.voters.add(self.id)
        self.log[slot] = entry
        # leader accepts locally
        self.accepted[slot] = (self.ballot, cmd)
        self._send_p2a(slot)

    def _send_p2a(self, slot: int) -> None:
        entry = self.log[slot]
        b, ci = self.ballot, self.commit_index

        def make() -> P2a:
            return P2a(ballot=b, slot=slot, cmd=entry.cmd, commit_index=ci)

        entry.pig_ids = self.comm.broadcast(make, round_key=slot) or []
        entry.timer = self.set_timer(self.leader_timeout,
                                     lambda: self._slot_timeout(slot))

    def _slot_timeout(self, slot: int) -> None:
        entry = self.log.get(slot)
        if entry is None or entry.committed or not self.is_leader:
            return
        # gray non-responsive relays, then retry with fresh random relays (§3.4)
        self.comm.on_round_timeout(entry.pig_ids)
        entry.retries += 1
        self._send_p2a(slot)

    def ingest_vote(self, ballot: tuple, slot: int, voter: int, ok: bool,
                    reject_ballot: tuple = (0, 0)) -> None:
        if not ok:
            if reject_ballot > self.ballot:
                self._step_down(reject_ballot)
            return
        if ballot != self.ballot or not self.is_leader:
            return
        entry = self.log.get(slot)
        if entry is None or entry.committed:
            return
        entry.voters.add(voter)   # set => duplicate votes counted once (§3.4)
        if len(entry.voters) >= self.majority:
            self._commit(slot)

    def _commit(self, slot: int) -> None:
        entry = self.log[slot]
        entry.committed = True
        if entry.timer is not None:
            self.cancel_timer(entry.timer)
        self.committed[slot] = entry.cmd
        self.committed_count += 1
        self._advance()

    def _apply_slot(self, s: int, cmd: Command) -> tuple:
        """Apply one contiguously-committed slot with at-most-once session
        dedup.  THE single apply path — every caller (_advance,
        _learn_commit, on_CatchUpResp) must go through it, because the
        auditor's replica-agreement check relies on all replicas making
        byte-identical apply/skip decisions over the shared log prefix.

        Returns ``(ack, val)``: ``ack`` is True when a waiting client
        should be answered with ``val`` — either a fresh apply or an exact
        duplicate (timeout retry) answered from the session cache; a stale
        duplicate (seq below the session high-water mark) gets neither an
        apply nor a reply."""
        sess = self._session.get(cmd.client_id)
        if sess is not None and cmd.seq <= sess[0]:
            if cmd.seq == sess[0]:
                return True, sess[1]       # duplicate: cached result
            return False, None             # stale duplicate: drop
        store = self.store                 # inline KVStore.apply (hot path)
        store.applied_ops += 1
        if cmd.op == "put":
            store.data[cmd.key] = cmd.value
            val = None
        else:
            val = store.data.get(cmd.key)
        self._session[cmd.client_id] = (cmd.seq, val)
        self.applied_log.append((s, cmd))
        return True, val

    def _advance(self) -> None:
        """Apply contiguously committed slots; reply to waiting clients."""
        while (self.commit_index + 1) in self.committed:
            s = self.commit_index + 1
            cmd = self.committed[s]
            self.commit_index = s
            ack, val = self._apply_slot(s, cmd)
            e = self.log.get(s)
            if ack and e is not None and e.client_src >= 0:
                self.send(e.client_src,
                          ClientReply(client_id=cmd.client_id, seq=cmd.seq,
                                      ok=True, value=val))

    # ============================================================== recovery
    def recover(self) -> None:
        """Node recovery with protocol semantics (the base class only clears
        the crashed flag).  A recovered follower needs nothing — it catches
        up through the commit_index piggybacked on later traffic.  A
        recovered *leader* (the owner of the current ballot) must re-run
        phase 1 with a fresh ballot: all its timers died while it was down
        (``set_timer`` suppresses fires on crashed nodes), so without a
        re-election every slot that was in flight at crash time — and hence
        the contiguous-apply prefix — would stall forever.  ``_become_leader``
        then re-proposes both phase-1-recovered values and the surviving
        local log entries (client reply routing intact)."""
        if not self.crashed:
            return
        super().recover()
        # a CatchUpReq outstanding at crash time is lost (its response was
        # dropped and the discard timer was suppressed while down): forget
        # it so _learn_commit re-requests instead of wedging at that slot
        self._catching_up.clear()
        if self.ballot[1] == self.id:
            self.is_leader = False
            self.start_phase1()

    def flush_commits(self) -> None:
        """Idle-time commit propagation (harness use; P3 is normally
        piggybacked on the next P2a)."""
        for p in self.peers:
            if p != self.id:
                self.send(p, P3(commit_index=self.commit_index))

    # ============================================================== acceptor
    def process_inner(self, msg: Msg):
        """Handle a (possibly relayed) leader message; return the reply."""
        if isinstance(msg, P2a):
            return self._accept(msg)
        if isinstance(msg, P1a):
            return self._promise(msg)
        if isinstance(msg, P3):
            self._learn_commit(msg.commit_index, msg.src)
            return None
        raise RuntimeError(f"unexpected inner {msg.kind}")

    def _accept(self, msg: P2a) -> P2b:
        if msg.ballot >= self.promised:
            self.promised = msg.ballot
            self.accepted[msg.slot] = (msg.ballot, msg.cmd)
            self._learn_commit(msg.commit_index, msg.src)
            r = P2b(ballot=msg.ballot, slot=msg.slot, ok=True)
        else:
            r = P2b(ballot=self.promised, slot=msg.slot, ok=False)
        r.src = self.id
        return r

    def _promise(self, msg: P1a) -> P1b:
        if msg.ballot > self.promised:
            self.promised = msg.ballot
            acc = {s: v for s, v in self.accepted.items()
                   if s > self.commit_index}
            r = P1b(ballot=msg.ballot, ok=True, accepted=acc,
                    commit_index=self.commit_index)
        else:
            r = P1b(ballot=self.promised, ok=False)
        r.src = self.id
        return r

    def _learn_commit(self, ci: int, leader_src: int) -> None:
        comm = self.comm
        if comm._pending_sup:       # no-op unless supplements are pending
            comm.note_committed_up_to(ci)
        while self.commit_index < ci:
            s = self.commit_index + 1
            if s in self.committed:
                cmd = self.committed[s]
            elif s in self.accepted:
                cmd = self.accepted[s][1]
            else:
                if s not in self._catching_up and leader_src >= 0:
                    self._catching_up.add(s)
                    self.send(leader_src, CatchUpReq(slots=(s,)))
                    # allow a re-request if the response gets lost
                    self.set_timer(2 * self.leader_timeout,
                                   lambda s=s: self._catching_up.discard(s))
                return
            self.committed.setdefault(s, cmd)
            self.commit_index = s
            self._apply_slot(s, cmd)

    def on_CatchUpReq(self, msg: CatchUpReq) -> None:
        ent = {s: self.committed[s] for s in msg.slots if s in self.committed}
        if ent:
            self.send(msg.src, CatchUpResp(entries=ent))

    def on_CatchUpResp(self, msg: CatchUpResp) -> None:
        for s, cmd in msg.entries.items():
            self.committed.setdefault(s, cmd)
            self._catching_up.discard(s)
        # replay contiguous applies (shared apply path: caught-up replicas
        # make identical apply decisions)
        while (self.commit_index + 1) in self.committed:
            s = self.commit_index + 1
            cmd = self.committed[s]
            self.commit_index = s
            self._apply_slot(s, cmd)

    # ====================================================== direct handlers
    def on_P2a(self, msg: P2a) -> None:
        self.send(msg.src, self._accept(msg))

    def on_P1a(self, msg: P1a) -> None:
        self.send(msg.src, self._promise(msg))

    def on_P3(self, msg: P3) -> None:
        self._learn_commit(msg.commit_index, msg.src)

    def on_P2b(self, msg: P2b) -> None:
        self.ingest_vote(msg.ballot, msg.slot, msg.src, msg.ok,
                         reject_ballot=msg.ballot)

    def on_P1b(self, msg: P1b) -> None:
        self._ingest_p1(msg.src, msg)

    # ========================================================= pig handlers
    def on_PigFanout(self, msg) -> None:
        self.comm.on_PigFanout(msg)

    def on_PigRelayed(self, msg) -> None:
        self.comm.on_PigRelayed(msg)

    def on_PigReply(self, msg) -> None:
        self.comm.on_PigReply(msg)

    def on_PigAggregate(self, msg: PigAggregate) -> None:
        self.comm.leader_handle_aggregate(msg)
        if isinstance(msg, _P1Aggregate):
            for p1b in msg.p1bs:
                self._ingest_p1(p1b.src, p1b)
            return
        if msg.reject:
            self.ingest_vote(msg.ballot, msg.slot, -1, False,
                             reject_ballot=msg.reject_ballot)
        # batch-ingest the ok votes (same guards as ingest_vote, hoisted out
        # of the per-voter loop; set.update dedups exactly like repeated .add)
        voters = msg.voters
        if not voters or msg.ballot != self.ballot or not self.is_leader:
            return
        entry = self.log.get(msg.slot)
        if entry is None or entry.committed:
            return
        entry.voters.update(voters)
        if len(entry.voters) >= self.majority:
            self._commit(msg.slot)
