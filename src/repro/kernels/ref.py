"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models.layers import attention_ref
from ..models.ssm import chunked_linear_scan


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True) -> jax.Array:
    """q: (B,Hq,Sq,Dh); k/v: (B,Hkv,Sk,Dh) -> (B,Hq,Sq,Dh)."""
    B, Hq, Sq, Dh = q.shape
    Sk = k.shape[2]
    qs = q.transpose(0, 2, 1, 3)      # (B,S,H,D) layout of attention_ref
    ks = k.transpose(0, 2, 1, 3)
    vs = v.transpose(0, 2, 1, 3)
    q_pos = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))
    if not causal:
        q_pos = jnp.full((B, Sq), Sk - 1, jnp.int32)
    k_pos = jnp.broadcast_to(jnp.arange(Sk)[None], (B, Sk))
    out = attention_ref(qs, ks, vs, q_pos, k_pos)
    return out.transpose(0, 2, 1, 3)


def ssm_scan_ref(q: jax.Array, k: jax.Array, v: jax.Array, log_a: jax.Array,
                 u: jax.Array | None = None, chunk: int = 64) -> jax.Array:
    """Same signature as kernels.ssm_scan.ssm_scan_bhtd (BH-major layout)."""
    BH, T, Dk = q.shape
    pad = (-T) % chunk
    if pad:
        zp = lambda a: jnp.pad(a, [(0, 0), (0, pad), (0, 0)])
        out = ssm_scan_ref(zp(q), zp(k), zp(v), zp(log_a), u=u, chunk=chunk)
        return out[:, :T]
    add = lambda a: a[:, :, None]     # (BH,T,D) -> (B=BH, T, H=1, D)
    if u is not None:
        # chunked_linear_scan wants bonus (H, Dk); fold BH into batch, H=1:
        # handle per-row bonus by vmapping over BH
        def one(qr, kr, vr, lr, ur):
            return chunked_linear_scan(qr[None, :, None], kr[None, :, None],
                                       vr[None, :, None], lr[None, :, None],
                                       chunk=chunk, bonus=ur[None])[0, :, 0]
        return jax.vmap(one)(q, k, v, log_a, u)
    out = chunked_linear_scan(add(q), add(k), add(v), add(log_a), chunk=chunk)
    return out[:, :, 0]


def pig_aggregate_ref(shards: jax.Array, scales: jax.Array,
                      block: int = 1024) -> jax.Array:
    G, N = shards.shape
    nb = N // block
    x = shards.reshape(G, nb, block).astype(jnp.float32) * scales[:, :, None]
    return x.sum(axis=0).reshape(N)


def seg_fanin_ref(vals: jax.Array, coef: jax.Array, segid: jax.Array,
                  kcap: jax.Array, vcoef, md1, c, anchor) -> jax.Array:
    """The production ``lax`` fan-in path (lexicographic sort + segmented
    cumulative max, ``core.segscan``) with ``kernels.ops.seg_fanin``'s
    signature: vals/coef (B, F), segid/kcap (F,), anchor (B,), scalars
    vcoef/md1/c.  Same preconditions as the kernel: contiguous segments,
    segment-constant coef/kcap, >= kcap+1 finite entries per consumed
    segment.  Returns each slot's capped segment max (B, F)."""
    from ..core.segscan import seg_cummax, seg_start_index

    B, F = vals.shape
    segid = segid.astype(jnp.int32)
    sid_b = jnp.broadcast_to(segid[None, :], (B, F))
    # two-key stable sort: segment blocks stay in place, values ascend
    _, arr_s = jax.lax.sort((sid_b, vals), num_keys=2)
    first = segid != jnp.concatenate([segid[:1] - 1, segid[:-1]])
    first_b = jnp.broadcast_to(first[None, :], (B, F))
    gsl = seg_start_index(first, axis=0)                   # (F,)
    posf = (jnp.arange(F) - gsl).astype(jnp.float32)
    anchor = jnp.asarray(anchor, jnp.float32).reshape(B, 1)
    y = arr_s + jnp.maximum(coef + vcoef * (arr_s - anchor), 0.0) \
        + md1 - posf[None, :] * c
    pref = seg_cummax(y, first_b, axis=1)
    idx = jnp.clip(gsl + kcap.astype(jnp.int32), 0, F - 1)
    return jnp.take_along_axis(pref, jnp.broadcast_to(idx[None, :], (B, F)),
                               axis=1)
