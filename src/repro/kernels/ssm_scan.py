"""Chunked linear-recurrence scan kernel (TPU Pallas).

Computes, per (batch*head) slice with matrix state S in R^{Dk x Dv}:
    S_t = diag(a_t) S_{t-1} + k_t v_t^T
    y_t = q_t^T S_t                        (inclusive; Mamba2)
    y_t = q_t^T (diag(a_t) S_{t-1}) + (q_t . (u ⊙ k_t)) v_t   (RWKV bonus)

Grid (BH, nc) with the chunk axis minor-most: the state scratch carries
across chunks sequentially.  Per chunk the kernel evaluates the intra-chunk
quadratic term with the factored decay trick (q e^{A})(k e^{-A})^T — safe in
f32 because callers clamp per-step log-decay (see models/rwkv.py) — plus the
inter-chunk term against the carried state.  This is the TPU-native
restructuring of Mamba's CUDA selective-scan: chunk-parallel MXU matmuls
instead of a warp-level sequential scan.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(q_ref, k_ref, v_ref, la_ref, u_ref, o_ref, s_scr, *,
                chunk: int, nc: int, use_bonus: bool):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    q = q_ref[0].astype(jnp.float32)          # (c, dk)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)          # (c, dv)
    la = la_ref[0].astype(jnp.float32)        # (c, dk)

    A = jnp.cumsum(la, axis=0)                # inclusive cumulative decay
    atot = A[-1]                              # (dk,)
    q_in = q * jnp.exp(A)
    k_in = k * jnp.exp(-A)
    s = jax.lax.dot_general(q_in, k_in, (((1,), (1,)), ((), ())))   # (c, c)
    r = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    c_ = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = (c_ < r) if use_bonus else (c_ <= r)     # strict for RWKV
    s = jnp.where(mask, s, 0.0)
    y = jax.lax.dot_general(s, v, (((1,), (0,)), ((), ())))         # intra
    if use_bonus:
        u = u_ref[...].astype(jnp.float32)          # (1, dk)
        diag = jnp.sum(q * u * k, axis=1, keepdims=True)            # (c, 1)
        y = y + diag * v
    y = y + jax.lax.dot_general(q_in, s_scr[...], (((1,), (0,)), ((), ())))
    o_ref[0] = y.astype(o_ref.dtype)

    k_state = k * jnp.exp(atot[None, :] - A)        # (c, dk)
    s_scr[...] = s_scr[...] * jnp.exp(atot)[:, None] + jax.lax.dot_general(
        k_state, v, (((0,), (0,)), ((), ())))


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_scan_bhtd(q: jax.Array, k: jax.Array, v: jax.Array, log_a: jax.Array,
                  u: jax.Array | None = None, chunk: int = 64,
                  interpret: bool = False) -> jax.Array:
    """q,k,log_a: (BH, T, Dk); v: (BH, T, Dv); u: (BH, Dk) bonus or None.
    T must be a multiple of ``chunk`` (ops.py pads)."""
    BH, T, Dk = q.shape
    Dv = v.shape[-1]
    nc = T // chunk
    use_bonus = u is not None
    kernel = functools.partial(_ssm_kernel, chunk=chunk, nc=nc,
                               use_bonus=use_bonus)
    in_specs = [
        pl.BlockSpec((1, chunk, Dk), lambda b, c: (b, c, 0)),
        pl.BlockSpec((1, chunk, Dk), lambda b, c: (b, c, 0)),
        pl.BlockSpec((1, chunk, Dv), lambda b, c: (b, c, 0)),
        pl.BlockSpec((1, chunk, Dk), lambda b, c: (b, c, 0)),
    ]
    if use_bonus:
        in_specs.append(pl.BlockSpec((1, Dk), lambda b, c: (b, 0)))
        args = (q, k, v, log_a, u)
    else:
        # feed a dummy 1-row buffer so the kernel signature stays uniform
        in_specs.append(pl.BlockSpec((1, Dk), lambda b, c: (0, 0)))
        args = (q, k, v, log_a, jnp.zeros((1, Dk), q.dtype))
    return pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, chunk, Dv), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, Dv), v.dtype),
        scratch_shapes=[pltpu.VMEM((Dk, Dv), jnp.float32)],
        interpret=interpret,
    )(*args)
