"""Pig relay aggregation kernel (TPU Pallas).

The TPU analogue of the relay's ack aggregation hot loop (§3.1 step 4 /
§6.4): fuse the dequantize + accumulate of G group members' int8-compressed
gradient shards into one pass, so the "relay" chip never materializes the
dequantized f32 copies in HBM.

Inputs per block:  shards (G, block) int8, scales (G, 1) f32 per block.
Output:            sum_g shards[g] * scales[g]  (f32, one block).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _agg_kernel(q_ref, s_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)          # (G, blk)
    s = s_ref[...].astype(jnp.float32)          # (G, 1)
    o_ref[...] = jnp.sum(q * s, axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def pig_aggregate(shards: jax.Array, scales: jax.Array, block: int = 1024,
                  interpret: bool = False) -> jax.Array:
    """shards: (G, N) int8 with N % block == 0; scales: (G, N // block) f32.
    Returns (N,) f32: the dequantized sum across the G group members."""
    G, N = shards.shape
    nb = N // block
    assert scales.shape == (G, nb), (scales.shape, (G, nb))
    out = pl.pallas_call(
        _agg_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((G, block), lambda b: (0, b)),
            pl.BlockSpec((G, 1), lambda b: (0, b)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda b: (0, b)),
        out_shape=jax.ShapeDtypeStruct((1, N), jnp.float32),
        interpret=interpret,
    )(shards, scales)
    return out[0]


def quantize_blockwise(x: jax.Array, block: int = 1024) -> tuple:
    """Symmetric per-block int8 quantization.  x: (N,) -> (int8 (N,),
    scales (N//block,))."""
    N = x.shape[0]
    xb = x.reshape(N // block, block).astype(jnp.float32)
    amax = jnp.max(jnp.abs(xb), axis=1)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xb / scale[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(N), scale
