"""Public jit'd wrappers around the Pallas kernels.

Handles layout conversion, padding to hardware-aligned block shapes, and
backend selection: on CPU (this container) kernels run in interpret mode;
on TPU they compile natively.  Model code calls these, never pallas_call.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_bhsd
from .pig_aggregate import pig_aggregate as _pig_aggregate_kernel
from .pig_aggregate import quantize_blockwise  # noqa: F401 (re-export)
from .segfanin import seg_fanin_bf
from .ssm_scan import ssm_scan_bhtd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128) -> jax.Array:
    """Model-layout entry point: q (B,S,Hq,Dh), k/v (B,S,Hkv,Dh)."""
    B, S, Hq, Dh = q.shape
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    bq = min(block_q, S)
    bk = min(block_k, S)
    qt, pq = _pad_to(qt, 2, bq)
    kt, pk = _pad_to(kt, 2, bk)
    vt, _ = _pad_to(vt, 2, bk)
    qt, pd = _pad_to(qt, 3, 128)
    kt, _ = _pad_to(kt, 3, 128)
    vt, _ = _pad_to(vt, 3, 128)
    # padded k rows must never win the softmax: they are masked by causality
    # only when pq == pk pads align; mask explicitly via huge negative keys
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, block_q=bq,
                               block_k=bk, interpret=_interpret(),
                               sm_scale=1.0 / (Dh ** 0.5))
    out = out[:, :, :S, :Dh]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def ssm_scan(q: jax.Array, k: jax.Array, v: jax.Array, log_a: jax.Array,
             u: jax.Array | None = None, chunk: int = 64) -> jax.Array:
    """Model-layout entry point: q/k/log_a (B,T,H,Dk), v (B,T,H,Dv),
    u (H,Dk) or None.  Returns (B,T,H,Dv)."""
    B, T, H, Dk = q.shape
    Dv = v.shape[-1]
    fold = lambda a: a.transpose(0, 2, 1, 3).reshape(B * H, T, a.shape[-1])
    qf, kf, vf, lf = fold(q), fold(k), fold(v), fold(log_a)
    qf, pt = _pad_to(qf, 1, chunk)
    kf, _ = _pad_to(kf, 1, chunk)
    vf, _ = _pad_to(vf, 1, chunk)
    lf, _ = _pad_to(lf, 1, chunk)       # log_a = 0 pad => decay 1, harmless
    uf = None if u is None else jnp.tile(u, (B, 1))
    out = ssm_scan_bhtd(qf, kf, vf, lf, uf, chunk=chunk,
                        interpret=_interpret())
    out = out[:, :T]
    return out.reshape(B, H, T, Dv).transpose(0, 2, 1, 3)


def pig_aggregate(shards: jax.Array, scales: jax.Array,
                  block: int = 1024) -> jax.Array:
    """shards (G, N) int8 + scales (G, N//block) f32 -> (N,) f32 sum."""
    return _pig_aggregate_kernel(shards, scales, block=block,
                                 interpret=_interpret())


def seg_fanin(vals: jax.Array, coef: jax.Array, segid: jax.Array,
              kcap: jax.Array, vcoef, md1, c, anchor) -> jax.Array:
    """Segmented quorum fan-in (see ``segfanin`` for the model and its
    preconditions).  vals/coef: (B, F) f32 (+inf = masked slot); segid /
    kcap: (F,) per-slot segment id and order-statistic cap (both
    segment-constant); vcoef/md1/c: scalars; anchor: (B,).  Returns (B, F):
    each slot's capped segment max m, -inf where the admissible set is
    empty.  Values can be traced scalars (called per scan step)."""
    B, F = vals.shape
    f32 = jnp.float32
    # pad the slot axis to the TPU lane width; padded slots form their own
    # segment (id -1) so they never contribute to a real segment's max
    pad = (-F) % 128
    vals = jnp.pad(vals, ((0, 0), (0, pad)), constant_values=jnp.inf)
    coef = jnp.pad(coef.astype(f32), ((0, 0), (0, pad)))
    sid = jnp.pad(segid.astype(f32), (0, pad), constant_values=-1.0)
    kc = jnp.pad(kcap.astype(f32), (0, pad))
    ones = jnp.ones((B,), f32)
    scal = jnp.stack([vcoef * ones, md1 * ones, c * ones,
                      jnp.broadcast_to(jnp.asarray(anchor, f32), (B,))],
                     axis=1)
    out = seg_fanin_bf(vals, coef,
                       jnp.broadcast_to(sid[None, :], (B, F + pad)),
                       jnp.broadcast_to(kc[None, :], (B, F + pad)),
                       scal, interpret=_interpret())
    return out[:, :F]
