"""Tiled causal flash attention (TPU Pallas) with GQA support.

Layout: q (B, Hq, Sq, Dh), k/v (B, Hkv, Sk, Dh) -> o (B, Hq, Sq, Dh).
Grid (B, Hq, nq, nk); the k-block axis is minor-most, so the online-softmax
scratch (m, l, acc) carries across k blocks sequentially (TPU grid order).
Block sizes target VMEM: q/k/v tiles of (block, Dh) with Dh padded to a
multiple of 128 by the ops.py wrapper so the MXU sees aligned matmuls.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_k: int, nk: int, scale: float,
                  causal: bool):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qi = pl.program_id(2)
    run = True
    if causal:
        # skip blocks strictly above the diagonal
        run = (ki * block_k) <= (qi * block_q + block_q - 1)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, dh)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))   # (bq, bk)
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        m_scr[...] = m_new
        l_scr[...] = l_new
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))

    @pl.when(ki == nk - 1)
    def _fin():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret", "sm_scale"))
def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array,
                         causal: bool = True, block_q: int = 128,
                         block_k: int = 128, interpret: bool = False,
                         sm_scale: float | None = None) -> jax.Array:
    """q: (B,Hq,Sq,Dh); k/v: (B,Hkv,Sk,Dh).  Dh and S must be multiples of
    the block sizes (the ops.py wrapper pads)."""
    B, Hq, Sq, Dh = q.shape
    _, Hkv, Sk, _ = k.shape
    rep = Hq // Hkv
    nq = Sq // block_q
    nk = Sk // block_k
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(Dh)
    kernel = functools.partial(_flash_kernel, block_q=block_q, block_k=block_k,
                               nk=nk, scale=scale, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, Dh), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, Dh), lambda b, h, qi, ki: (b, h // rep, ki, 0)),
            pl.BlockSpec((1, 1, block_k, Dh), lambda b, h, qi, ki: (b, h // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, Dh), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
