"""Segmented quorum fan-in kernel (TPU Pallas).

The batch backend's hot spot: every scan step, every relay FIFOs its
group's reply fan-in and flushes at the k-th completion — per-group order
statistics over a flat group-contiguous slot axis.  The ``lax`` path pays a
lexicographic two-key sort plus a segmented cumulative max per burst
(``core.vectorsim``); sorts lower to O(F log^2 F) sorting networks on TPU
and leave the VPU idle between compare-exchange passes.

This kernel replaces the sort with *rank-by-comparison-counting*: the rank
of slot i among its segment equals the number of segment peers that sort
before it (value ascending, index tie-break — exactly ``lax.sort``'s stable
order), computed as one dense masked (F, F) comparison reduction.  That is
valid because the downstream per-slot transform

    y_j = v_j + max(coef_j + vcoef * (v_j - anchor), 0) + md1 - rank_j * c

has a segment-CONSTANT coefficient ``coef`` (the relay's backlog at the
leader's pacing point), so sorting never permutes it, and the FIFO position
offset equals the rank.  Only the order statistic at the per-segment
threshold ``kcap`` is consumed, so the kernel emits each slot's *capped
segment max* directly:

    m_i = max over {j in seg(i) : rank_j <= kcap_i, v_j finite} of y_j

(-inf when the admissible set is empty).  Dense compares + reductions are
pure VPU work — no scatter, no sort — at O(F^2) per burst row, a win for
the model's group sizes (F = N - 1, segments of ~N/R slots).

Preconditions (hold by construction in ``vectorsim._group_cell``):
segments occupy contiguous slot runs; ``coef``/``kcap`` are constant within
each segment; every segment consumed downstream has at least ``kcap + 1``
finite entries; masked slots carry ``+inf``.  ``vcoef`` must be non-zero
when any slot is +inf (vectorsim's utilization coefficient is <= -0.05).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _fanin_kernel(v_ref, u_ref, s_ref, k_ref, c_ref, o_ref):
    f32 = jnp.float32
    v = v_ref[...]                       # (1, F) fan-in arrivals, +inf masked
    u = u_ref[...]                       # (1, F) segment-constant coefficient
    sid = s_ref[...]                     # (1, F) segment id (f32, exact ints)
    kcap = k_ref[...]                    # (1, F) per-segment threshold cap
    sc = c_ref[...]                      # (1, 4) [vcoef, md1, c, anchor]
    vcoef, md1, c, anchor = sc[0, 0], sc[0, 1], sc[0, 2], sc[0, 3]
    F = v.shape[1]
    vt = jnp.transpose(v, (1, 0))        # (F, 1): slot i down the rows
    st = jnp.transpose(sid, (1, 0))
    j_idx = lax.broadcasted_iota(jnp.int32, (F, F), 1)
    i_idx = lax.broadcasted_iota(jnp.int32, (F, F), 0)
    same = sid == st                     # (F, F): j in segment(i)
    # j sorts before i: stable (value, index) order == lax.sort's tie-break
    before = (v < vt) | ((v == vt) & (j_idx < i_idx))
    rank_i = jnp.sum(jnp.where(same & before, f32(1.0), f32(0.0)),
                     axis=1, keepdims=True)            # (F, 1) rank of i
    rank = jnp.transpose(rank_i, (1, 0))               # (1, F) rank of j
    y = v + jnp.maximum(u + vcoef * (v - anchor), 0.0) + md1 - rank * c
    ok = same & (rank <= kcap) & (v < jnp.inf)
    contrib = jnp.where(ok, jnp.broadcast_to(y, (F, F)), -jnp.inf)
    o_ref[...] = jnp.transpose(jnp.max(contrib, axis=1, keepdims=True),
                               (1, 0))


@functools.partial(jax.jit, static_argnames=("interpret",))
def seg_fanin_bf(vals: jax.Array, coef: jax.Array, segid: jax.Array,
                 kcap: jax.Array, scal: jax.Array,
                 interpret: bool = False) -> jax.Array:
    """vals/coef/segid/kcap: (B, F) f32; scal: (B, 4) f32 rows of
    [vcoef, md1, c, anchor].  Returns (B, F) f32 capped segment maxes."""
    B, F = vals.shape
    spec = pl.BlockSpec((1, F), lambda b: (b, 0))
    return pl.pallas_call(
        _fanin_kernel,
        grid=(B,),
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((1, 4), lambda b: (b, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, F), jnp.float32),
        interpret=interpret,
    )(vals, coef, segid, kcap, scal)
