"""The ``megagrid`` study: the full N x R x PRC x conflict x WAN
cross-product as one million-cell batch-backend run.

The paper's analytical claim — throughput is maximized at one rotating
relay and the bottleneck shifts predictably with N, R and PRC (§6,
Eq. 1-3) — is only fully testable over the cross-product of all those
axes.  This module enumerates it:

* **group kernel** — Paxos plus rotating PigPaxos at every valid
  (N, R, PRC) combination of ``GROUP_N`` x ``R_AXIS`` x ``PRC_AXIS``;
* **epaxos kernel** — the conflict axis (``CONFLICT_AXIS`` hot-key rates)
  at ``EPAXOS_N``;
* **WAN** — every point twice: LAN and the fig10 three-region topology
  scaled to N (``wan3``);
* **clients x seeds** — the cell grid within each point (seeds are the
  replicate axis and the knob that scales the run to a target cell count).

Cells are executed by ``vectorsim.simulate_grid_sharded``: points are
bucketed by compiled signature (kernel kind, follower-axis size class,
client class, topology class) so the whole study compiles once per bucket,
then each bucket streams through the device-sharded runner chunk by chunk
(donated inputs, bounded device memory).  Results aggregate into ONE
``repro-experiments/v1`` artifact — per-point curve entries under the
``megagrid`` family plus a ``megagrid`` section with per-chunk walls,
cells/s, device count, kernel flag, and a roofline note locating the run
against this host's measured compute/memory ceilings.

CLI:  ``python -m repro.experiments.megagrid --cells 1000000 --out FILE``
(``--preset smoke`` is the CI slice).  On GPU/TPU hosts the same command
shards across all visible devices; on CPU, multi-device execution is
forced with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional

import numpy as np

from ..core import PigConfig, WorkloadConfig
from ..core import vectorsim as vs
from .runner import ARTIFACT_SCHEMA, _agg
from .scenario import build_topology

# the committed 384-cell fig8-grid baseline this PR's acceptance is
# measured against (BENCH_vectorsim.json, PR 3): 31.3 s cold / 384 cells
BASELINE_PER_CELL_MS = 31.3e3 / 384

_WAN3_MS = [[0.15, 31, 35], [31, 0.15, 11], [35, 11, 0.15]]   # fig10

FULL_AXES = {
    "group_n": (5, 9, 17, 25),
    "r": (1, 2, 4, 8),
    "prc": (0, 1, 2),
    "epaxos_n": (5, 9, 17),
    "conflict": (0.0, 0.1, 0.5),
    "wan": ("lan", "wan3"),
    "clients": (2, 4, 8, 16),
}

# the CI slice: same code path (both kernels, both topology classes,
# sharded dispatch) at ~1/500 the cell count and 3 compiles
SMOKE_AXES = {
    "group_n": (5, 9),
    "r": (1, 2),
    "prc": (0, 1),
    "epaxos_n": (5,),
    "conflict": (0.0, 0.5),
    "wan": ("lan", "wan3"),
    "clients": (4,),
}

_TIMEOUT = {"lan": 50e-3, "wan3": 400e-3}   # retry_risk classification


def _topo_spec(wan: str, n: int) -> Optional[dict]:
    if wan == "lan":
        return None
    per = [n - 2 * (n // 3), n // 3, n // 3]
    return {"kind": "wan", "nodes_per_region": per, "oneway_ms": _WAN3_MS}


def build_points(axes: Dict = FULL_AXES) -> List[dict]:
    """One entry per config point of the cross-product: {name, kind, axes,
    cfg, weight} — clients x seeds fill the cell grid within each point.
    ``weight`` down-scales the seed allocation of expensive kinds."""
    pts = []
    for wan in axes["wan"]:
        for n in axes["group_n"]:
            topo = build_topology(_topo_spec(wan, n))
            pts.append(dict(
                name=f"paxos/N={n}/{wan}", kind="group", weight=1.0,
                axes=dict(protocol="paxos", n=n, wan=wan),
                cfg=vs.build_config("paxos", n, topo=topo,
                                    label=f"paxos/N={n}/{wan}")))
            for r in axes["r"]:
                if r > n - 1:
                    continue
                for prc in axes["prc"]:
                    pts.append(dict(
                        name=f"pig/N={n}/R={r}/PRC={prc}/{wan}",
                        kind="group", weight=1.0,
                        axes=dict(protocol="pigpaxos", n=n, r=r, prc=prc,
                                  wan=wan),
                        cfg=vs.build_config(
                            "pigpaxos", n, pig=PigConfig(n_groups=r, prc=prc),
                            topo=topo,
                            label=f"pig/N={n}/R={r}/PRC={prc}/{wan}")))
        for n in axes["epaxos_n"]:
            topo = build_topology(_topo_spec(wan, n))
            for c in axes["conflict"]:
                wl = (WorkloadConfig(key_dist="conflict", conflict_rate=c)
                      if c > 0 else WorkloadConfig())
                # epaxos pops one request per scan step (no burst batching)
                # -> ~8x the per-cell cost; give it 1/8 the seed budget
                pts.append(dict(
                    name=f"epaxos/N={n}/c={c}/{wan}", kind="epaxos",
                    weight=0.125,
                    axes=dict(protocol="epaxos", n=n, conflict=c, wan=wan),
                    cfg=vs.build_config(
                        "epaxos", n, topo=topo, workload=wl,
                        label=f"epaxos/N={n}/c={c}/{wan}")))
    return pts


def _bucket_key(pt: dict, k: int) -> tuple:
    """Compiled-signature bucket: kind + follower-axis size class + client
    class + topology class.  Everything inside one bucket shares padded
    shapes and a step budget, so it compiles exactly once."""
    n = pt["cfg"].n
    wan = pt["axes"]["wan"]
    kcls = 4 if k <= 4 else 16
    if pt["kind"] == "epaxos":
        return ("epaxos", n, kcls, wan)
    fcls = 8 if n <= 9 else 16 if n <= 17 else 24
    return ("group", fcls, kcls, wan)


# ------------------------------------------------------------------ roofline
def measure_ceilings() -> Dict[str, float]:
    """Empirical single-host ceilings the roofline note is drawn against:
    peak f32 GEMM throughput (compute) and large-array streaming bandwidth
    (memory).  Measured, not quoted — the container's one CPU core is the
    'hardware limit' the acceptance speaks of."""
    import jax
    import jax.numpy as jnp
    m = 1024
    a = jnp.ones((m, m), jnp.float32)
    f = jax.jit(lambda x: x @ x)
    jax.block_until_ready(f(a))
    t0 = time.perf_counter()
    reps = 8
    for _ in range(reps):
        jax.block_until_ready(f(a))
    gemm_s = (time.perf_counter() - t0) / reps
    x = jnp.ones((32 * 1024 * 1024,), jnp.float32)      # 128 MiB
    g = jax.jit(lambda a, b: a + b)
    jax.block_until_ready(g(x, x))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(g(x, x))
    add_s = (time.perf_counter() - t0) / reps
    return {
        "peak_flops": 2.0 * m ** 3 / gemm_s,            # f32 FMA ceiling
        "peak_bytes_per_s": 3.0 * x.size * 4 / add_s,   # 2 reads + 1 write
    }


def _cell_step_ops(kind: str, F: int, G: int, B: int) -> float:
    """Model op count of one scan step of one cell (element-ops, counted
    from the kernel body: ~70 (B,F)-shaped passes + ~30 (B,G) + threefry
    RNG at ~40 ops/draw + the O(F log^2 F) sort network).  An estimate for
    the roofline NOTE, not a profile."""
    if kind == "epaxos":
        n = F            # callers pass n as F for the epaxos kernel
        return 40.0 * (2 * n + 4) + 60.0 * n
    logf = max(np.log2(max(F, 2)), 1.0)
    return (40.0 * B * (2 + 2 * G + 2 * F)      # threefry jitter draws
            + 70.0 * B * F + 30.0 * B * G       # elementwise pipeline
            + 2.0 * B * F * logf * logf)        # lexicographic sort

def roofline_note(buckets: List[dict], ceilings: Dict[str, float]) -> dict:
    """How far from the hardware limit the batch backend lands: achieved
    element-ops/s (model count / measured wall) against the measured GEMM
    ceiling, and the implied bytes/s (4 B per element-op, ~1.5 access
    amplification) against the streaming ceiling."""
    ops = sum(b["est_ops"] for b in buckets)
    wall = sum(b["wall_s"] for b in buckets)
    achieved = ops / max(wall, 1e-9)
    bytes_ps = achieved * 4.0 * 1.5
    f_c = achieved / ceilings["peak_flops"]
    f_m = bytes_ps / ceilings["peak_bytes_per_s"]
    return {
        "est_element_ops": ops,
        "achieved_gops": round(achieved / 1e9, 3),
        "peak_gflops": round(ceilings["peak_flops"] / 1e9, 1),
        "peak_stream_gbps": round(ceilings["peak_bytes_per_s"] / 1e9, 1),
        "frac_of_compute_roof": round(f_c, 4),
        "frac_of_memory_roof": round(f_m, 4),
        "bound": "memory" if f_m >= f_c else "compute",
    }


# ------------------------------------------------------------------ the run
def run_megagrid(cells: int = 1_000_000, *, axes: Dict = FULL_AXES,
                 chunk: int = 4096, kernel: str = "auto",
                 impl: str = "auto", duration: float = 0.1,
                 warmup: float = 0.05, progress=print) -> dict:
    """Run the cross-product study at >= ``cells`` total grid cells and
    return the ``repro-experiments/v1`` artifact (see module docstring).

    Memory is bounded by ``chunk`` (sharded dispatch donates each chunk's
    buffers); compile cost is one trace per bucket.  ``kernel`` and
    ``impl`` pass through to ``simulate_grid_sharded``.
    """
    import jax

    t_start = time.perf_counter()
    pts = build_points(axes)
    kaxis = list(axes["clients"])
    wsum = sum(p["weight"] for p in pts) * len(kaxis)
    seeds = max(1, int(np.ceil(cells / wsum)))
    for p in pts:
        p["seeds"] = max(1, int(round(seeds * p["weight"])))

    buckets: Dict[tuple, List] = {}
    for pi, p in enumerate(pts):
        for k in kaxis:
            buckets.setdefault(_bucket_key(p, k), []).append((pi, k))

    acc: Dict[int, Dict[int, dict]] = {pi: {} for pi in range(len(pts))}
    bmeta, all_chunks = [], []
    total_cells = 0
    for bkey in sorted(buckets, key=str):
        pairs = buckets[bkey]
        pis = sorted({pi for pi, _ in pairs})
        cfgs = [pts[pi]["cfg"] for pi in pis]
        grid, spans = [], []
        for pi, k in pairs:
            s0 = len(grid)
            grid += [(pis.index(pi), k, s) for s in range(pts[pi]["seeds"])]
            spans.append((pi, k, s0, len(grid)))
        t0 = time.perf_counter()
        out = vs.simulate_grid_sharded(cfgs, grid, duration, warmup,
                                       chunk=chunk, kernel=kernel, impl=impl)
        wall = time.perf_counter() - t0
        for pi, k, lo, hi in spans:
            tput = out["throughput"][lo:hi]
            med = out["median_s"][lo:hi] * 1e3
            p99 = out["p99_s"][lo:hi] * 1e3
            to = _TIMEOUT[pts[pi]["axes"]["wan"]]
            acc[pi][k] = {
                "throughput": _agg([float(v) for v in tput]),
                "median_ms": _agg([float(v) for v in med]),
                "p99_ms": _agg([float(v) for v in p99]),
                "committed": int(out["committed"][lo:hi].sum()),
                "retry_risk_frac": float(
                    (out["p99_s"][lo:hi] >= to).mean()),
                "exhausted": int(out["exhausted"][lo:hi].sum()),
            }
        ncell = len(grid)
        total_cells += ncell
        kind = "epaxos" if bkey[0] == "epaxos" else "group"
        if kind == "group":
            F, B = bkey[1], min(8, bkey[2])
            G = max(c.rmax for c in cfgs)
        else:
            F, G, B = bkey[1], 1, 1
        steps = float(np.mean([m["steps"] for m in
                               out["sharding"]["chunks"]]))
        breq = min(8, bkey[2]) if kind == "group" else 1
        est = ncell * (steps / breq) * _cell_step_ops(kind, F, G, B)
        bmeta.append({"bucket": list(map(str, bkey)), "cells": ncell,
                      "wall_s": round(wall, 2), "est_ops": est,
                      "steps": int(steps),
                      "chunks": len(out["sharding"]["chunks"])})
        all_chunks += [{"bucket": str(bkey), **m}
                       for m in out["sharding"]["chunks"]]
        if progress:
            progress(f"[megagrid] {bkey}: {ncell} cells in {wall:.1f}s "
                     f"({ncell / max(wall, 1e-9):.0f} cells/s)")

    wall_total = time.perf_counter() - t_start
    ceilings = measure_ceilings()
    per_cell_ms = wall_total / max(total_cells, 1) * 1e3
    scenarios = []
    for pi, p in enumerate(pts):
        per_k = acc[pi]
        alln = [per_k[k]["throughput"] for k in per_k]
        scenarios.append({
            "name": f"megagrid/{p['name']}", "family": "megagrid",
            "grid_mode": "curve", "backend": "batch", "quick": False,
            "consistency": "model",
            "spec": {**p["axes"], "clients": kaxis, "seeds": p["seeds"],
                     "duration": duration, "warmup": warmup},
            "units": [],          # 10^6 raw units stay out of the artifact
            "replicates": [],
            "points": [{"clients": k, **per_k[k]}
                       for k in sorted(per_k)],
            "summary": {
                "throughput": _agg([a["mean"] for a in alln
                                    if a["mean"] is not None]),
                "median_ms": _agg(
                    [per_k[k]["median_ms"]["mean"] for k in per_k
                     if per_k[k]["median_ms"]["mean"] is not None]),
                "p99_ms": _agg(
                    [per_k[k]["p99_ms"]["mean"] for k in per_k
                     if per_k[k]["p99_ms"]["mean"] is not None]),
                "committed": sum(per_k[k]["committed"] for k in per_k),
                "cells": sum(a["n"] for a in alln),
            },
        })
    return {
        "schema": ARTIFACT_SCHEMA, "quick": False, "processes": 1,
        "scenarios": scenarios,
        "megagrid": {
            "cells": total_cells,
            "points": len(pts),
            "wall_s": round(wall_total, 1),
            "cells_per_s": round(total_cells / max(wall_total, 1e-9), 1),
            "per_cell_ms": round(per_cell_ms, 4),
            "baseline_per_cell_ms": round(BASELINE_PER_CELL_MS, 2),
            "speedup_per_cell": round(BASELINE_PER_CELL_MS / per_cell_ms, 1),
            "device_count": int(jax.device_count()),
            "backend": jax.default_backend(),
            "kernel": vs._resolve_kernel(kernel, "group"),
            "impl": impl,
            "chunk": chunk,
            "duration_s": duration, "warmup_s": warmup,
            "buckets": bmeta,
            "chunk_walls": all_chunks,
            "roofline": roofline_note(bmeta, ceilings),
        },
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--cells", type=int, default=1_000_000)
    ap.add_argument("--preset", choices=("full", "smoke"), default="full")
    ap.add_argument("--chunk", type=int, default=4096)
    ap.add_argument("--kernel", default="auto",
                    choices=("auto", "lax", "pallas"))
    ap.add_argument("--impl", default="auto",
                    choices=("auto", "shard_map", "pmap"))
    ap.add_argument("--duration", type=float, default=0.1)
    ap.add_argument("--warmup", type=float, default=0.05)
    ap.add_argument("--out", default="megagrid.json")
    args = ap.parse_args(argv)
    axes = SMOKE_AXES if args.preset == "smoke" else FULL_AXES
    art = run_megagrid(args.cells, axes=axes, chunk=args.chunk,
                       kernel=args.kernel, impl=args.impl,
                       duration=args.duration, warmup=args.warmup)
    with open(args.out, "w") as f:
        json.dump(art, f, indent=1, sort_keys=True)
    mg = art["megagrid"]
    print(f"[megagrid] {mg['cells']} cells in {mg['wall_s']}s "
          f"({mg['cells_per_s']} cells/s, {mg['per_cell_ms']} ms/cell; "
          f"{mg['speedup_per_cell']}x the committed 384-cell baseline) "
          f"-> {args.out}")
    print(f"[megagrid] roofline: {mg['roofline']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
