"""Scenario registry: name -> :class:`Scenario`.

The registry is append-only within a process; names are unique and namespaced
by family prefix ("fig8/...", "table1/...", "zipf/...").  ``select()``
implements the ``--filter`` semantics used by ``benchmarks/run.py``:
comma-separated fnmatch globs, where a bare family name matches the whole
family.
"""
from __future__ import annotations

from fnmatch import fnmatchcase
from typing import Dict, Iterable, List, Optional

from .scenario import Scenario

_REGISTRY: Dict[str, Scenario] = {}


def register(scenario: Scenario) -> Scenario:
    if scenario.name in _REGISTRY:
        raise ValueError(f"duplicate scenario name {scenario.name!r}")
    _REGISTRY[scenario.name] = scenario
    return scenario


def get(name: str) -> Scenario:
    _ensure_catalog()
    return _REGISTRY[name]


def names() -> List[str]:
    _ensure_catalog()
    return list(_REGISTRY)


def families() -> List[str]:
    _ensure_catalog()
    seen: List[str] = []
    for s in _REGISTRY.values():
        if s.family not in seen:
            seen.append(s.family)
    return seen


def select(filter_expr: Optional[str] = None,
           families_subset: Optional[Iterable[str]] = None) -> List[Scenario]:
    """Scenarios matching a ``--filter`` expression (comma-separated fnmatch
    globs; a bare family name selects the family), optionally restricted to
    a subset of families.  No filter -> everything (in registration order).

    A pattern that matches nothing raises ``ValueError`` — a renamed or
    removed scenario must fail a filtered run (e.g. the CI smoke) loudly,
    not degrade it to a green no-op.
    """
    _ensure_catalog()
    out = list(_REGISTRY.values())
    if families_subset is not None:
        fams = set(families_subset)
        out = [s for s in out if s.family in fams]
    if filter_expr:
        pats = [p.strip() for p in filter_expr.split(",") if p.strip()]
        matched = {p: [s for s in out
                       if fnmatchcase(s.name, p) or s.family == p]
                   for p in pats}
        dead = [p for p, ss in matched.items() if not ss]
        if dead:
            raise ValueError(f"--filter pattern(s) matched no scenario: "
                             f"{', '.join(dead)}")
        keep = {x.name for ss in matched.values() for x in ss}
        out = [s for s in out if s.name in keep]
    return out


def _ensure_catalog() -> None:
    """Late-import the catalog so `import repro.experiments.registry` never
    cycles, while any read of the registry sees the full catalog."""
    from . import catalog  # noqa: F401  (import side effect: registration)
