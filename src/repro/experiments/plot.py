"""Artifact -> SVG plots, with zero plotting dependencies.

The runner's JSON artifact already carries everything a figure needs
(per-point aggregates for curve-mode scenarios, per-seed replicates with
latency quantiles), so this module renders the two standard views directly
as hand-built SVG — no matplotlib in the container, none required:

* ``throughput_vs_load`` — one polyline per scenario of a family, offered
  load (or client count) on x, achieved throughput on y.  For overload
  scenarios a dashed goodput line rides along, which is the whole story of
  that family: achieved stays up while goodput collapses without admission
  control.
* ``latency_cdf`` — quantile-interpolated CDF per scenario (p25/median/
  p75/p99 and, where the overload extras recorded it, p99.9).
* ``utilization_heat`` — per-node CPU-busy heat strip over virtual time,
  from the obs timelines (ISSUE 9): relay hotspots under static relays
  show up as one solid red row, rotation as an even pink wash.
* ``critpath_waterfall`` — stacked critical-path segments per traced
  scenario (queue/svc/ser/relay/net/wait mean ms per op), the
  bottleneck-attribution view.

``render_artifact`` walks a suite artifact and writes every view a
family has the data to support; ``benchmarks/run.py --plot DIR`` is the
CLI entry point.
"""
from __future__ import annotations

import os
from typing import Dict, List, Optional, Sequence, Tuple

# Okabe-Ito palette: colorblind-safe, distinct on white
_COLORS = ("#0072B2", "#D55E00", "#009E73", "#CC79A7",
           "#E69F00", "#56B4E9", "#F0E442", "#000000")

_W, _H = 720, 440
_ML, _MR, _MT, _MB = 70, 24, 34, 52        # margins: left/right/top/bottom


def _fmt(v: float) -> str:
    if v == 0:
        return "0"
    if abs(v) >= 1000:
        return f"{v / 1000:.3g}k"
    return f"{v:.3g}"


def _ticks(lo: float, hi: float, n: int = 5) -> List[float]:
    if hi <= lo:
        hi = lo + 1.0
    step = (hi - lo) / n
    return [lo + i * step for i in range(n + 1)]


class _Chart:
    """One x/y chart: polylines + axes + legend, emitted as SVG text."""

    def __init__(self, title: str, xlabel: str, ylabel: str):
        self.title, self.xlabel, self.ylabel = title, xlabel, ylabel
        self.series: List[tuple] = []   # (label, [(x, y)], dashed)

    def add(self, label: str, pts: Sequence[Tuple[float, float]],
            dashed: bool = False) -> None:
        pts = [(float(x), float(y)) for x, y in pts
               if x is not None and y is not None]
        if pts:
            self.series.append((label, sorted(pts), dashed))

    def _scale(self):
        xs = [x for _, pts, _ in self.series for x, _ in pts]
        ys = [y for _, pts, _ in self.series for _, y in pts]
        x0, x1 = min(xs), max(xs)
        y0, y1 = 0.0, max(ys)            # rate/fraction axes start at 0
        if x1 <= x0:
            x1 = x0 + 1.0
        if y1 <= y0:
            y1 = y0 + 1.0
        pw, ph = _W - _ML - _MR, _H - _MT - _MB

        def px(x):
            return _ML + (x - x0) / (x1 - x0) * pw

        def py(y):
            return _H - _MB - (y - y0) / (y1 - y0) * ph

        return (x0, x1, y0, y1, px, py)

    def svg(self) -> str:
        if not self.series:
            return ""
        x0, x1, y0, y1, px, py = self._scale()
        e: List[str] = [
            f'<svg xmlns="http://www.w3.org/2000/svg" width="{_W}" '
            f'height="{_H}" viewBox="0 0 {_W} {_H}" '
            f'font-family="sans-serif" font-size="11">',
            f'<rect width="{_W}" height="{_H}" fill="white"/>',
            f'<text x="{_W / 2}" y="20" text-anchor="middle" '
            f'font-size="14">{self.title}</text>',
        ]
        # gridlines + tick labels
        for t in _ticks(y0, y1):
            y = py(t)
            e.append(f'<line x1="{_ML}" y1="{y:.1f}" x2="{_W - _MR}" '
                     f'y2="{y:.1f}" stroke="#ddd"/>')
            e.append(f'<text x="{_ML - 6}" y="{y + 4:.1f}" '
                     f'text-anchor="end">{_fmt(t)}</text>')
        for t in _ticks(x0, x1):
            x = px(t)
            e.append(f'<line x1="{x:.1f}" y1="{_MT}" x2="{x:.1f}" '
                     f'y2="{_H - _MB}" stroke="#eee"/>')
            e.append(f'<text x="{x:.1f}" y="{_H - _MB + 16}" '
                     f'text-anchor="middle">{_fmt(t)}</text>')
        e.append(f'<rect x="{_ML}" y="{_MT}" width="{_W - _ML - _MR}" '
                 f'height="{_H - _MT - _MB}" fill="none" stroke="#333"/>')
        e.append(f'<text x="{_W / 2}" y="{_H - 12}" text-anchor="middle">'
                 f'{self.xlabel}</text>')
        e.append(f'<text x="16" y="{_H / 2}" text-anchor="middle" '
                 f'transform="rotate(-90 16 {_H / 2})">{self.ylabel}</text>')
        # series + legend
        for i, (label, pts, dashed) in enumerate(self.series):
            color = _COLORS[i % len(_COLORS)]
            dash = ' stroke-dasharray="6 4"' if dashed else ""
            path = " ".join(f"{px(x):.1f},{py(y):.1f}" for x, y in pts)
            e.append(f'<polyline points="{path}" fill="none" '
                     f'stroke="{color}" stroke-width="1.8"{dash}/>')
            for x, y in pts:
                e.append(f'<circle cx="{px(x):.1f}" cy="{py(y):.1f}" '
                         f'r="2.6" fill="{color}"/>')
            ly = _MT + 14 + 14 * i
            e.append(f'<line x1="{_ML + 8}" y1="{ly - 4}" x2="{_ML + 30}" '
                     f'y2="{ly - 4}" stroke="{color}" '
                     f'stroke-width="1.8"{dash}/>')
            e.append(f'<text x="{_ML + 34}" y="{ly}">{label}</text>')
        e.append("</svg>")
        return "\n".join(e)


def _offered(sa: dict, clients: int) -> Optional[float]:
    wl = (sa.get("spec") or {}).get("workload") or {}
    if wl.get("arrival", "closed") != "closed":
        return clients * wl.get("rate_hz", 0.0)
    return None


def _unit_goodputs(sa: dict) -> Dict[int, float]:
    """Mean goodput per client-grid point (overload extras), where present."""
    acc: Dict[int, List[float]] = {}
    for u in sa.get("units", []):
        g = (u.get("extras") or {}).get("goodput")
        if g is not None:
            acc.setdefault(u["clients"], []).append(g)
    return {k: sum(v) / len(v) for k, v in acc.items()}


def throughput_vs_load(family: str, arts: Dict[str, dict]) -> Optional[str]:
    """Achieved throughput (and goodput, when the overload extras carry it)
    vs offered load for every curve-mode scenario of ``family`` with at
    least two grid points — or one, when a sibling provides the second."""
    open_loop = any(_offered(sa, 1) is not None for sa in arts.values())
    xlabel = "offered load (req/s)" if open_loop else "clients"
    ch = _Chart(f"{family}: throughput vs load", xlabel, "req/s")
    for name, sa in sorted(arts.items()):
        pts = sa.get("points") or []
        label = name[len(family) + 1:] or name
        xy = []
        gxy = []
        goodputs = _unit_goodputs(sa)
        for p in pts:
            x = _offered(sa, p["clients"])
            x = p["clients"] if x is None else x
            xy.append((x, (p["throughput"] or {}).get("mean")))
            if p["clients"] in goodputs:
                gxy.append((x, goodputs[p["clients"]]))
        ch.add(label, xy)
        if gxy:
            ch.add(label + " (goodput)", gxy, dashed=True)
    if sum(len(pts) for _, pts, _ in ch.series) < 2:
        return None
    return ch.svg()


# latency quantiles available on every unit; p99.9 rides in the overload
# extras when collected
_QUANTS = (("p25_ms", 0.25), ("median_ms", 0.50),
           ("p75_ms", 0.75), ("p99_ms", 0.99))


def latency_cdf(family: str, arts: Dict[str, dict]) -> Optional[str]:
    """Quantile-interpolated latency CDF, one line per scenario (the
    highest-load grid point of its replicates)."""
    ch = _Chart(f"{family}: latency CDF", "latency (ms)", "P(latency <= x)")
    for name, sa in sorted(arts.items()):
        reps = sa.get("replicates") or []
        if not reps:
            continue
        u = max(reps, key=lambda r: r["clients"])
        pts = [(u[k], q) for k, q in _QUANTS if u.get(k) is not None]
        p999 = (u.get("extras") or {}).get("p999_ms")
        if p999 is not None:
            pts.append((p999, 0.999))
        if len(pts) >= 2:
            ch.add(name[len(family) + 1:] or name, pts)
    if not ch.series:
        return None
    return ch.svg()


# ------------------------------------------------------- obs views
def _obs_of(sa: dict) -> Optional[dict]:
    """The obs extras of a scenario's first unit that carries them."""
    for u in sa.get("units", []):
        ob = (u.get("extras") or {}).get("obs")
        if ob:
            return ob
    return None


def _heat_color(f: float) -> str:
    """0..1 busy fraction -> white-to-red ramp."""
    f = min(max(f, 0.0), 1.0)
    g = int(255 * (1.0 - f))
    return f"#ff{g:02x}{g:02x}"


def utilization_heat(family: str, arts: Dict[str, dict]) -> Optional[str]:
    """Per-node utilization heat strip: one row per node, time on x, cell
    color = CPU busy fraction over the sampler period — the view that makes
    a static relay hotspot (vs rotation's even spread) visible at a glance.
    Rendered from the first scenario of the family whose timelines carry
    ``busy_frac/i`` series."""
    for name, sa in sorted(arts.items()):
        ob = _obs_of(sa)
        series = ((ob or {}).get("timelines") or {}).get("series") or {}
        rows = sorted((int(k.split("/")[1]), v) for k, v in series.items()
                      if k.startswith("busy_frac/") and v["t"])
        if not rows:
            continue
        t0 = min(v["t"][0] for _, v in rows)
        t1 = max(v["t"][-1] for _, v in rows)
        if t1 <= t0:
            continue
        n = len(rows)
        rh = max(4, min(14, 360 // n))                 # row height
        h = _MT + n * rh + _MB
        e = [
            f'<svg xmlns="http://www.w3.org/2000/svg" width="{_W}" '
            f'height="{h}" viewBox="0 0 {_W} {h}" '
            f'font-family="sans-serif" font-size="11">',
            f'<rect width="{_W}" height="{h}" fill="white"/>',
            f'<text x="{_W / 2}" y="20" text-anchor="middle" '
            f'font-size="14">{name}: per-node CPU busy fraction</text>',
        ]
        pw = _W - _ML - _MR
        for ri, (node, v) in enumerate(rows):
            y = _MT + ri * rh
            pts = list(zip(v["t"], v["v"]))
            for j, (t, f) in enumerate(pts):
                tn = pts[j + 1][0] if j + 1 < len(pts) else t1
                x = _ML + (t - t0) / (t1 - t0) * pw
                w = max((tn - t) / (t1 - t0) * pw, 0.5)
                e.append(f'<rect x="{x:.1f}" y="{y}" width="{w:.1f}" '
                         f'height="{rh}" fill="{_heat_color(f)}"/>')
            if n <= 30 or node % 5 == 0:
                e.append(f'<text x="{_ML - 6}" y="{y + rh - 1}" '
                         f'text-anchor="end">{node}</text>')
        e.append(f'<rect x="{_ML}" y="{_MT}" width="{pw}" '
                 f'height="{n * rh}" fill="none" stroke="#333"/>')
        for frac in (0.0, 0.5, 1.0):
            x = _ML + frac * pw
            e.append(f'<text x="{x:.1f}" y="{_MT + n * rh + 16}" '
                     f'text-anchor="middle">'
                     f'{_fmt(t0 + frac * (t1 - t0))}s</text>')
        e.append(f'<text x="{_W / 2}" y="{h - 12}" text-anchor="middle">'
                 f'virtual time (node id on y; white=idle, red=busy)</text>')
        e.append("</svg>")
        return "\n".join(e)
    return None


# critical-path segment palette, in stack order
_SEG_ORDER = ("queue", "svc", "ser", "relay", "net", "wait")
_SEG_COLORS = {"queue": "#D55E00", "svc": "#0072B2", "ser": "#CC79A7",
               "relay": "#E69F00", "net": "#009E73", "wait": "#999999"}


def critpath_waterfall(family: str, arts: Dict[str, dict]) -> Optional[str]:
    """Critical-path waterfall: one horizontal stacked bar per traced
    scenario, segments = mean per-op milliseconds attributed to queue wait,
    CPU service, serialization, relay aggregation, network, and residual
    wait — the bottleneck-attribution picture (segments sum to the mean
    traced op latency by construction)."""
    bars = []
    for name, sa in sorted(arts.items()):
        ob = _obs_of(sa)
        cp = (ob or {}).get("critical_path") or {}
        mean = cp.get("mean_ms") or {}
        if mean and cp.get("n_ops"):
            bars.append((name[len(family) + 1:] or name, mean))
    if not bars:
        return None
    total_max = max(sum(m.get(s, 0.0) for s in _SEG_ORDER) for _, m in bars)
    if total_max <= 0:
        return None
    bh, gap = 34, 18
    h = _MT + 30 + len(bars) * (bh + gap) + _MB
    pw = _W - _ML - _MR
    e = [
        f'<svg xmlns="http://www.w3.org/2000/svg" width="{_W}" '
        f'height="{h}" viewBox="0 0 {_W} {h}" '
        f'font-family="sans-serif" font-size="11">',
        f'<rect width="{_W}" height="{h}" fill="white"/>',
        f'<text x="{_W / 2}" y="20" text-anchor="middle" font-size="14">'
        f'{family}: critical-path attribution (mean ms/op)</text>',
    ]
    lx = _ML
    for s in _SEG_ORDER:
        e.append(f'<rect x="{lx}" y="{_MT + 2}" width="10" height="10" '
                 f'fill="{_SEG_COLORS[s]}"/>')
        e.append(f'<text x="{lx + 13}" y="{_MT + 11}">{s}</text>')
        lx += 24 + 7 * len(s)
    for bi, (label, mean) in enumerate(bars):
        y = _MT + 30 + bi * (bh + gap)
        e.append(f'<text x="{_ML}" y="{y - 3}">{label} '
                 f'(total {sum(mean.get(s, 0.0) for s in _SEG_ORDER):.2f}'
                 f'ms)</text>')
        x = float(_ML)
        for s in _SEG_ORDER:
            v = mean.get(s, 0.0)
            if v <= 0:
                continue
            w = v / total_max * pw
            e.append(f'<rect x="{x:.1f}" y="{y}" width="{w:.1f}" '
                     f'height="{bh}" fill="{_SEG_COLORS[s]}" '
                     f'stroke="white" stroke-width="0.5"/>')
            if w > 34:
                e.append(f'<text x="{x + w / 2:.1f}" y="{y + bh / 2 + 4}" '
                         f'text-anchor="middle" fill="white">'
                         f'{v:.2f}</text>')
            x += w
    e.append("</svg>")
    return "\n".join(e)


def render_artifact(artifact: dict, outdir: str) -> List[str]:
    """Write throughput-vs-load, latency-CDF, utilization-heat and
    critical-path SVGs for every family in ``artifact`` that has the data;
    returns the written paths."""
    by_family: Dict[str, Dict[str, dict]] = {}
    for sa in artifact.get("scenarios", []):
        by_family.setdefault(sa["family"], {})[sa["name"]] = sa
    os.makedirs(outdir, exist_ok=True)
    written = []
    for family, arts in sorted(by_family.items()):
        for suffix, fn in (("throughput", throughput_vs_load),
                           ("latency_cdf", latency_cdf),
                           ("util_heat", utilization_heat),
                           ("critpath", critpath_waterfall)):
            svg = fn(family, arts)
            if not svg:
                continue
            path = os.path.join(outdir, f"{family}_{suffix}.svg")
            with open(path, "w") as f:
                f.write(svg)
            written.append(path)
    return written
