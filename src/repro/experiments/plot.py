"""Artifact -> SVG plots, with zero plotting dependencies.

The runner's JSON artifact already carries everything a figure needs
(per-point aggregates for curve-mode scenarios, per-seed replicates with
latency quantiles), so this module renders the two standard views directly
as hand-built SVG — no matplotlib in the container, none required:

* ``throughput_vs_load`` — one polyline per scenario of a family, offered
  load (or client count) on x, achieved throughput on y.  For overload
  scenarios a dashed goodput line rides along, which is the whole story of
  that family: achieved stays up while goodput collapses without admission
  control.
* ``latency_cdf`` — quantile-interpolated CDF per scenario (p25/median/
  p75/p99 and, where the overload extras recorded it, p99.9).

``render_artifact`` walks a suite artifact and writes both views for every
family that has the data to support them; ``benchmarks/run.py --plot DIR``
is the CLI entry point.
"""
from __future__ import annotations

import os
from typing import Dict, List, Optional, Sequence, Tuple

# Okabe-Ito palette: colorblind-safe, distinct on white
_COLORS = ("#0072B2", "#D55E00", "#009E73", "#CC79A7",
           "#E69F00", "#56B4E9", "#F0E442", "#000000")

_W, _H = 720, 440
_ML, _MR, _MT, _MB = 70, 24, 34, 52        # margins: left/right/top/bottom


def _fmt(v: float) -> str:
    if v == 0:
        return "0"
    if abs(v) >= 1000:
        return f"{v / 1000:.3g}k"
    return f"{v:.3g}"


def _ticks(lo: float, hi: float, n: int = 5) -> List[float]:
    if hi <= lo:
        hi = lo + 1.0
    step = (hi - lo) / n
    return [lo + i * step for i in range(n + 1)]


class _Chart:
    """One x/y chart: polylines + axes + legend, emitted as SVG text."""

    def __init__(self, title: str, xlabel: str, ylabel: str):
        self.title, self.xlabel, self.ylabel = title, xlabel, ylabel
        self.series: List[tuple] = []   # (label, [(x, y)], dashed)

    def add(self, label: str, pts: Sequence[Tuple[float, float]],
            dashed: bool = False) -> None:
        pts = [(float(x), float(y)) for x, y in pts
               if x is not None and y is not None]
        if pts:
            self.series.append((label, sorted(pts), dashed))

    def _scale(self):
        xs = [x for _, pts, _ in self.series for x, _ in pts]
        ys = [y for _, pts, _ in self.series for _, y in pts]
        x0, x1 = min(xs), max(xs)
        y0, y1 = 0.0, max(ys)            # rate/fraction axes start at 0
        if x1 <= x0:
            x1 = x0 + 1.0
        if y1 <= y0:
            y1 = y0 + 1.0
        pw, ph = _W - _ML - _MR, _H - _MT - _MB

        def px(x):
            return _ML + (x - x0) / (x1 - x0) * pw

        def py(y):
            return _H - _MB - (y - y0) / (y1 - y0) * ph

        return (x0, x1, y0, y1, px, py)

    def svg(self) -> str:
        if not self.series:
            return ""
        x0, x1, y0, y1, px, py = self._scale()
        e: List[str] = [
            f'<svg xmlns="http://www.w3.org/2000/svg" width="{_W}" '
            f'height="{_H}" viewBox="0 0 {_W} {_H}" '
            f'font-family="sans-serif" font-size="11">',
            f'<rect width="{_W}" height="{_H}" fill="white"/>',
            f'<text x="{_W / 2}" y="20" text-anchor="middle" '
            f'font-size="14">{self.title}</text>',
        ]
        # gridlines + tick labels
        for t in _ticks(y0, y1):
            y = py(t)
            e.append(f'<line x1="{_ML}" y1="{y:.1f}" x2="{_W - _MR}" '
                     f'y2="{y:.1f}" stroke="#ddd"/>')
            e.append(f'<text x="{_ML - 6}" y="{y + 4:.1f}" '
                     f'text-anchor="end">{_fmt(t)}</text>')
        for t in _ticks(x0, x1):
            x = px(t)
            e.append(f'<line x1="{x:.1f}" y1="{_MT}" x2="{x:.1f}" '
                     f'y2="{_H - _MB}" stroke="#eee"/>')
            e.append(f'<text x="{x:.1f}" y="{_H - _MB + 16}" '
                     f'text-anchor="middle">{_fmt(t)}</text>')
        e.append(f'<rect x="{_ML}" y="{_MT}" width="{_W - _ML - _MR}" '
                 f'height="{_H - _MT - _MB}" fill="none" stroke="#333"/>')
        e.append(f'<text x="{_W / 2}" y="{_H - 12}" text-anchor="middle">'
                 f'{self.xlabel}</text>')
        e.append(f'<text x="16" y="{_H / 2}" text-anchor="middle" '
                 f'transform="rotate(-90 16 {_H / 2})">{self.ylabel}</text>')
        # series + legend
        for i, (label, pts, dashed) in enumerate(self.series):
            color = _COLORS[i % len(_COLORS)]
            dash = ' stroke-dasharray="6 4"' if dashed else ""
            path = " ".join(f"{px(x):.1f},{py(y):.1f}" for x, y in pts)
            e.append(f'<polyline points="{path}" fill="none" '
                     f'stroke="{color}" stroke-width="1.8"{dash}/>')
            for x, y in pts:
                e.append(f'<circle cx="{px(x):.1f}" cy="{py(y):.1f}" '
                         f'r="2.6" fill="{color}"/>')
            ly = _MT + 14 + 14 * i
            e.append(f'<line x1="{_ML + 8}" y1="{ly - 4}" x2="{_ML + 30}" '
                     f'y2="{ly - 4}" stroke="{color}" '
                     f'stroke-width="1.8"{dash}/>')
            e.append(f'<text x="{_ML + 34}" y="{ly}">{label}</text>')
        e.append("</svg>")
        return "\n".join(e)


def _offered(sa: dict, clients: int) -> Optional[float]:
    wl = (sa.get("spec") or {}).get("workload") or {}
    if wl.get("arrival", "closed") != "closed":
        return clients * wl.get("rate_hz", 0.0)
    return None


def _unit_goodputs(sa: dict) -> Dict[int, float]:
    """Mean goodput per client-grid point (overload extras), where present."""
    acc: Dict[int, List[float]] = {}
    for u in sa.get("units", []):
        g = (u.get("extras") or {}).get("goodput")
        if g is not None:
            acc.setdefault(u["clients"], []).append(g)
    return {k: sum(v) / len(v) for k, v in acc.items()}


def throughput_vs_load(family: str, arts: Dict[str, dict]) -> Optional[str]:
    """Achieved throughput (and goodput, when the overload extras carry it)
    vs offered load for every curve-mode scenario of ``family`` with at
    least two grid points — or one, when a sibling provides the second."""
    open_loop = any(_offered(sa, 1) is not None for sa in arts.values())
    xlabel = "offered load (req/s)" if open_loop else "clients"
    ch = _Chart(f"{family}: throughput vs load", xlabel, "req/s")
    for name, sa in sorted(arts.items()):
        pts = sa.get("points") or []
        label = name[len(family) + 1:] or name
        xy = []
        gxy = []
        goodputs = _unit_goodputs(sa)
        for p in pts:
            x = _offered(sa, p["clients"])
            x = p["clients"] if x is None else x
            xy.append((x, (p["throughput"] or {}).get("mean")))
            if p["clients"] in goodputs:
                gxy.append((x, goodputs[p["clients"]]))
        ch.add(label, xy)
        if gxy:
            ch.add(label + " (goodput)", gxy, dashed=True)
    if sum(len(pts) for _, pts, _ in ch.series) < 2:
        return None
    return ch.svg()


# latency quantiles available on every unit; p99.9 rides in the overload
# extras when collected
_QUANTS = (("p25_ms", 0.25), ("median_ms", 0.50),
           ("p75_ms", 0.75), ("p99_ms", 0.99))


def latency_cdf(family: str, arts: Dict[str, dict]) -> Optional[str]:
    """Quantile-interpolated latency CDF, one line per scenario (the
    highest-load grid point of its replicates)."""
    ch = _Chart(f"{family}: latency CDF", "latency (ms)", "P(latency <= x)")
    for name, sa in sorted(arts.items()):
        reps = sa.get("replicates") or []
        if not reps:
            continue
        u = max(reps, key=lambda r: r["clients"])
        pts = [(u[k], q) for k, q in _QUANTS if u.get(k) is not None]
        p999 = (u.get("extras") or {}).get("p999_ms")
        if p999 is not None:
            pts.append((p999, 0.999))
        if len(pts) >= 2:
            ch.add(name[len(family) + 1:] or name, pts)
    if not ch.series:
        return None
    return ch.svg()


def render_artifact(artifact: dict, outdir: str) -> List[str]:
    """Write throughput-vs-load and latency-CDF SVGs for every family in
    ``artifact`` that has the data; returns the written paths."""
    by_family: Dict[str, Dict[str, dict]] = {}
    for sa in artifact.get("scenarios", []):
        by_family.setdefault(sa["family"], {})[sa["name"]] = sa
    os.makedirs(outdir, exist_ok=True)
    written = []
    for family, arts in sorted(by_family.items()):
        for suffix, fn in (("throughput", throughput_vs_load),
                           ("latency_cdf", latency_cdf)):
            svg = fn(family, arts)
            if not svg:
                continue
            path = os.path.join(outdir, f"{family}_{suffix}.svg")
            with open(path, "w") as f:
                f.write(svg)
            written.append(path)
    return written
