"""Process-parallel scenario runner.

The DES is single-threaded Python, so a suite of scenarios x client-grid
points x seeds is embarrassingly parallel: each (scenario, clients, seed)
triple is one independent simulation, farmed out to a ``multiprocessing``
pool (``processes > 1``) or run inline (``processes in (0, 1)``).

Every run emits the same artifact schema (``schema`` = ``ARTIFACT_SCHEMA``):

.. code-block:: python

    {"schema": "repro-experiments/v1", "quick": bool, "processes": int,
     "wall_s": float,
     "scenarios": [
        {"name": "fig8/rotating/R=1", "family": "fig8", "grid_mode": "max",
         "spec": {...},                      # full declarative Scenario
         "units": [ {clients, seed, throughput, median_ms, ...}, ... ],
         "replicates": [ ... ],              # one best-over-grid unit per seed
         "points": [ ... ],                  # curve mode: per-grid aggregates
         "summary": {"throughput": {mean, std, min, max, n}, ...}},
        ...]}

``units`` are the raw per-(clients, seed) measurements; ``replicates`` are
the per-seed results after applying the grid policy (the paper's
max-throughput methodology folds the offered-load sweep here — the single
shared implementation of what ``benchmarks/common.max_throughput`` and
fig9's inline loop used to duplicate).
"""
from __future__ import annotations

import dataclasses
import math
import multiprocessing
import time
from typing import Dict, List, Optional, Sequence

from repro.core import Cluster

from .scenario import Scenario, build_topology

ARTIFACT_SCHEMA = "repro-experiments/v1"
TIMELINE_BUCKET_S = 0.05
# goodput SLO for the overload family: a completion counts toward goodput
# only if its client-observed latency (first send -> reply, including any
# shed/bounce/retry loops) is within this budget
OVERLOAD_SLO_MS = 50.0


def _f(x) -> Optional[float]:
    """JSON-safe float: NaN/inf -> None, else rounded."""
    x = float(x)
    if math.isnan(x) or math.isinf(x):
        return None
    return round(x, 6)


def _run_unit(payload) -> dict:
    """One independent DES run.  Top-level so it pickles for pool workers."""
    from repro.faults import apply_plan, audit_cluster

    sc, clients, seed, duration, warmup = payload
    t0 = time.time()
    from repro.core import BatchConfig
    bc = BatchConfig(**sc.batch) if sc.batch is not None else None
    c = Cluster(sc.protocol, sc.n, pig=sc.pig, seed=seed,
                topo=build_topology(sc.topo),
                leader_timeout=sc.leader_timeout, engine=sc.engine,
                record_history=sc.audit, spare_nodes=sc.spare_nodes,
                batch=bc, pipeline_depth=sc.pipeline_depth,
                obs=(dict(sc.obs) if sc.obs is not None else None),
                lease=(dict(sc.lease) if sc.lease is not None else None))
    plan = sc.fault_plan()
    evs = []
    if plan is not None:
        evs = apply_plan(c, plan, horizon=warmup + duration + 0.5)
    fo_events = None
    if sc.failover is not None:
        from repro.runtime.policy import FailoverPolicy, attach_failover
        fo_events = attach_failover(c, FailoverPolicy(**sc.failover),
                                    stop_at=warmup + duration)
    adm_stats = None
    if sc.admission is not None:
        if "slo_ms" in sc.admission:
            from repro.runtime.policy import (LatencyAdmissionPolicy,
                                              attach_latency_admission)
            adm_stats = attach_latency_admission(
                c, LatencyAdmissionPolicy(**sc.admission),
                stop_at=warmup + duration)
        else:
            from repro.runtime.policy import (AdmissionPolicy,
                                              attach_admission)
            adm_stats = attach_admission(c, AdmissionPolicy(**sc.admission),
                                         stop_at=warmup + duration)
        # the metrics sampler's shed_total gauge reads these counters
        c.admission_stats = adm_stats
    st = c.measure(duration=duration, warmup=warmup, clients=clients,
                   workload=sc.workload)
    unit = {
        "scenario": sc.name, "clients": clients, "seed": seed,
        "duration_s": duration, "warmup_s": warmup,
        "throughput": _f(st.throughput), "mean_ms": _f(st.mean_ms),
        "median_ms": _f(st.median_ms), "p25_ms": _f(st.p25_ms),
        "p75_ms": _f(st.p75_ms), "p99_ms": _f(st.p99_ms),
        "count": st.count, "committed": st.committed,
        "wall_s": round(time.time() - t0, 3),
    }
    extras = {}
    if "per_node_msgs" in sc.collect:
        extras["leader_msgs_per_op"] = _f(st.messages_per_op(0))
        extras["follower_msgs_per_op"] = _f(
            sum(st.messages_per_op(i) for i in range(1, sc.n)) / (sc.n - 1))
    if "flight" in sc.collect:
        m = st.flight.astype(float) / max(st.committed, 1)
        extras["flight_per_op"] = [[_f(v) for v in r] for r in m.tolist()]
    if "timeline" in sc.collect:
        # completion counts per fixed virtual-time bucket (from t=0), for
        # throughput-over-time views (e.g. fig16's failure transient)
        end = warmup + duration
        counts = [0] * (int(end / TIMELINE_BUCKET_S) + 1)
        for cl in c.clients:
            for (t, _lat) in cl.latencies:
                b = int(t / TIMELINE_BUCKET_S)
                if b < len(counts):
                    counts[b] += 1
        extras["timeline"] = {"bucket_s": TIMELINE_BUCKET_S, "counts": counts}
    if "overload" in sc.collect:
        # overload-study metrics: tail beyond p99, goodput under an SLO,
        # offered rate, and every shed/bounce counter in the loop
        stop = warmup + duration
        lats = sorted(l for cl in c.clients
                      for (t, l) in cl.latencies if warmup <= t <= stop)
        extras["p999_ms"] = (_f(lats[min(len(lats) - 1,
                                         int(0.999 * len(lats)))] * 1e3)
                             if lats else None)
        extras["slo_ms"] = OVERLOAD_SLO_MS
        extras["goodput"] = _f(sum(1 for l in lats
                                   if l * 1e3 <= OVERLOAD_SLO_MS) / duration)
        wl = sc.workload
        extras["offered"] = (_f(wl.rate_hz * clients)
                             if wl is not None and wl.arrival != "closed"
                             else None)
        extras["client_shed"] = sum(getattr(cl, "shed", 0)
                                    for cl in c.clients)
        extras["client_rejected"] = sum(getattr(cl, "rejected", 0)
                                        for cl in c.clients)
    if adm_stats is not None:
        extras["admission"] = dict(adm_stats)
    rw = (c.read_write_split()
          if sc.workload is not None and sc.workload.read_ratio is not None
          else None)
    if rw is not None:
        extras["rw"] = {k: (_f(v) if isinstance(v, float) else v)
                        for k, v in rw.items()}
    if plan is not None:
        # availability metrics: the longest client-visible completion gap
        # inside the measurement window, and the timeout re-send count
        stop = warmup + duration
        times = sorted(t for cl in c.clients for (t, _l) in cl.latencies
                       if warmup <= t <= stop)
        edges = [warmup] + times + [stop]
        extras["unavail_ms"] = _f(max(
            (b - a) for a, b in zip(edges, edges[1:])) * 1e3)
        extras["client_retries"] = sum(cl.retries for cl in c.clients)
        # per-outage unavailability: for every crash/recover pair in the
        # materialized plan, the longest completion gap inside the outage
        # window (+0.25s tail for the recovery transient) — the per-restart
        # metric rolling-upgrade scenarios report
        open_crash = {}
        per_fault = []
        for ev in evs:
            if ev[0] == "crash":
                open_crash[ev[1]] = float(ev[2])
            elif ev[0] == "recover" and ev[1] in open_crash:
                ft0 = open_crash.pop(ev[1])
                ft1 = float(ev[2])
                lo, hi = max(ft0, warmup), min(ft1 + 0.25, stop)
                if lo >= hi:
                    continue
                w = [lo] + [t for t in times if lo <= t <= hi] + [hi]
                per_fault.append({
                    "node": ev[1], "t0": _f(ft0), "t1": _f(ft1),
                    "unavail_ms": _f(max(b - a for a, b in
                                         zip(w, w[1:])) * 1e3)})
        if per_fault:
            extras["per_fault_unavail_ms"] = per_fault
    if fo_events is not None:
        extras["failover_events"] = [
            {"t": _f(e["t"]), "from": e["from"], "to": e["to"]}
            for e in fo_events]
    if sc.obs is not None:
        from repro.obs import obs_artifact_section
        extras["obs"] = obs_artifact_section(c)
    if sc.audit:
        res = audit_cluster(c)
        unit["consistency"] = "ok" if res.ok else "violation"
        unit["audit"] = res.summary()
    if extras:
        unit["extras"] = extras
    return unit


def _run_batch_scenario(sc: Scenario, rs) -> List[dict]:
    """One batch-backend scenario: the whole clients x seeds grid in ONE
    jitted vectorsim call.  Returns unit dicts in ``rs.units()`` order with
    the same schema as the DES path (wall_s is the amortized grid wall).
    Mask-expressible fault plans run as time-varying availability masks;
    their units carry the completion timeline and ``consistency="model"``
    (the round-level model commits by construction — the linearizability
    audit is a DES-engine check)."""
    from repro.core import vectorsim

    t0 = time.time()
    plan = sc.fault_plan()
    masks = (plan.to_masks(sc.n, rs.warmup + rs.duration + 0.5)
             if plan is not None else None)
    raw = vectorsim.simulate_scenario(
        sc.protocol, sc.n, pig=sc.pig, topo=build_topology(sc.topo),
        workload=sc.workload, clients=rs.clients, seeds=rs.seeds,
        duration=rs.duration, warmup=rs.warmup,
        leader_timeout=sc.leader_timeout, masks=masks,
        batch_m=(sc.batch or {}).get("max_batch", 1),
        obs=sc.obs is not None)
    wall = time.time() - t0
    units = []
    for u in raw:
        unit = {
            "scenario": sc.name, "clients": u["clients"], "seed": u["seed"],
            "duration_s": rs.duration, "warmup_s": rs.warmup,
            "throughput": _f(u["throughput"]), "mean_ms": _f(u["mean_ms"]),
            "median_ms": _f(u["median_ms"]), "p25_ms": _f(u["p25_ms"]),
            "p75_ms": _f(u["p75_ms"]), "p99_ms": _f(u["p99_ms"]),
            "count": u["count"], "committed": u["committed"],
            "wall_s": round(wall / max(len(raw), 1), 4),
            "backend": "batch",
            "retry_risk": u["retry_risk"],
            "exhausted": u["exhausted"],
        }
        extras = {}
        if "per_node_msgs" in sc.collect:
            extras["leader_msgs_per_op"] = _f(u["leader_msgs_per_op"])
            extras["follower_msgs_per_op"] = _f(u["follower_msgs_per_op"])
        if "timeline" in u:
            extras["timeline"] = u["timeline"]
        if "obs" in u:
            extras["obs"] = u["obs"]
        if "rw" in u:
            extras["rw"] = {k: (_f(v) if isinstance(v, float) else v)
                            for k, v in u["rw"].items()}
        if plan is not None:
            unit["consistency"] = "model"
        if extras:
            unit["extras"] = extras
        units.append(unit)
    return units


def _unit_cost_estimate(payload) -> float:
    sc, clients, _seed, duration, warmup = payload
    # epaxos dependency graphs make its events much heavier than (pig)paxos
    proto_w = 4.0 if sc.protocol == "epaxos" else 1.0
    return (warmup + duration) * sc.n * clients * proto_w


def _agg(values: Sequence[float]) -> dict:
    vals = [v for v in values if v is not None]
    if not vals:
        return {"mean": None, "std": None, "min": None, "max": None, "n": 0}
    mean = sum(vals) / len(vals)
    var = sum((v - mean) ** 2 for v in vals) / len(vals)
    return {"mean": _f(mean), "std": _f(math.sqrt(var)),
            "min": _f(min(vals)), "max": _f(max(vals)), "n": len(vals)}


def _scenario_artifact(sc: Scenario, units: List[dict], quick: bool) -> dict:
    from repro.faults.plan import jsonify_events

    art = {"name": sc.name, "family": sc.family, "grid_mode": sc.grid_mode,
           "quick": quick, "backend": sc.backend, "spec": sc.spec_dict(),
           # consistency provenance: "audited" = every DES unit ran the
           # linearizability auditor (per-unit verdicts in units[].
           # consistency); "model" = batch backend (commits by
           # construction); "unchecked" = plain perf run
           "consistency": ("audited" if sc.audit and sc.backend == "des"
                           else "model" if sc.backend == "batch"
                           else "unchecked"),
           "units": units}
    plan = sc.fault_plan()
    if plan is not None:
        # the materialized fault timeline (storms expanded) for this run —
        # over the RESOLVED horizon, so quick-mode artifacts record exactly
        # the events the run applied, not the full-mode schedule
        rs = sc.resolve(quick)
        art["faults"] = jsonify_events(
            plan.materialize(rs.warmup + rs.duration + 0.5))
    # per-seed replicates: apply the grid policy within each seed
    by_seed: Dict[int, List[dict]] = {}
    for u in units:
        by_seed.setdefault(u["seed"], []).append(u)
    if sc.grid_mode == "max":
        reps = [max(us, key=lambda u: u["throughput"] or 0.0)
                for us in by_seed.values()]
    else:
        reps = units
    art["replicates"] = reps
    if sc.grid_mode == "curve":
        by_clients: Dict[int, List[dict]] = {}
        for u in units:
            by_clients.setdefault(u["clients"], []).append(u)
        art["points"] = [
            {"clients": k,
             "throughput": _agg([u["throughput"] for u in us]),
             "median_ms": _agg([u["median_ms"] for u in us]),
             "p99_ms": _agg([u["p99_ms"] for u in us])}
            for k, us in sorted(by_clients.items())]
    art["summary"] = {
        "throughput": _agg([u["throughput"] for u in reps]),
        "median_ms": _agg([u["median_ms"] for u in reps]),
        "p99_ms": _agg([u["p99_ms"] for u in reps]),
        "committed": sum(u["committed"] for u in units),
        "wall_s": round(sum(u["wall_s"] for u in units), 3),
    }
    return art


def run_scenarios(scenarios: Sequence[Scenario], quick: bool = True,
                  processes: int = 0,
                  ignore_quick_skip: bool = False,
                  backend_override: Optional[str] = None) -> dict:
    """Run a suite of scenarios; return the suite artifact.

    ``processes``: 0/1 -> inline (deterministic ordering, easy debugging);
    N > 1 -> a pool of N workers over all units of all scenarios at once,
    so a wide scenario cannot serialize the tail of the suite.  Scenarios
    with ``backend="batch"`` never enter the pool: each one's entire
    clients x seeds grid is ONE jitted call on the vectorized backend.

    ``ignore_quick_skip``: run ``quick_skip`` scenarios anyway — set when
    the caller selected scenarios explicitly (``--filter``), so an explicit
    selection can never degrade to a silent green no-op.

    ``backend_override="batch"`` switches every ``batch_ok`` scenario to
    the batch backend (DES <-> batch cross-checks on identical grids);
    ``"des"`` forces everything onto the DES.
    """
    active = [sc for sc in scenarios
              if ignore_quick_skip or not (quick and sc.quick_skip)]
    if backend_override == "batch":
        # batch keeps per_node_msgs always, and timeline when a fault plan
        # rides along (fault runs emit the completion timeline natively)
        active = [dataclasses.replace(sc, backend="batch", collect=tuple(
            c for c in sc.collect
            if c == "per_node_msgs"
            or (c == "timeline" and sc.fault_plan() is not None)))
            if sc.batch_ok else sc for sc in active]
    elif backend_override == "des":
        active = [dataclasses.replace(sc, backend="des") if
                  sc.backend == "batch" else sc for sc in active]
    elif backend_override is not None:
        raise ValueError(f"unknown backend override {backend_override!r}")
    t0 = time.time()     # suite wall includes the batch-backend calls below
    payloads = []
    batch_units: Dict[str, List[dict]] = {}
    for sc in active:
        rs = sc.resolve(quick)
        if sc.backend == "batch":
            batch_units[sc.name] = _run_batch_scenario(sc, rs)
            continue
        for (k, s) in rs.units():
            payloads.append((sc, k, s, rs.duration, rs.warmup))
    if processes and processes > 1 and len(payloads) > 1:
        # longest-processing-time-first: schedule the expensive units early
        # so the pool tail is short (simulated work ~ duration x n x load);
        # results are un-sorted afterwards so the artifact is identical to
        # a serial run
        order = sorted(range(len(payloads)), reverse=True,
                       key=lambda i: _unit_cost_estimate(payloads[i]))
        with multiprocessing.get_context().Pool(processes) as pool:
            res = pool.map(_run_unit, [payloads[i] for i in order],
                           chunksize=1)
        results = [None] * len(payloads)
        for i, r in zip(order, res):
            results[i] = r
    else:
        results = [_run_unit(p) for p in payloads]
    by_name: Dict[str, List[dict]] = dict(batch_units)
    for u in results:
        by_name.setdefault(u["scenario"], []).append(u)
    return {"schema": ARTIFACT_SCHEMA, "quick": quick,
            "processes": int(processes or 0),
            "wall_s": round(time.time() - t0, 3),
            "scenarios": [_scenario_artifact(sc, by_name.get(sc.name, []), quick)
                          for sc in active]}


def run_families(families: Sequence[str], quick: bool = True,
                 processes: int = 0, filter_expr: Optional[str] = None,
                 backend_override: Optional[str] = None) -> dict:
    from . import registry
    return run_scenarios(registry.select(filter_expr, families_subset=families),
                         quick=quick, processes=processes,
                         ignore_quick_skip=bool(filter_expr),
                         backend_override=backend_override)
