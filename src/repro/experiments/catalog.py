"""The scenario catalog: every paper reproduction (table1/2, fig8-fig17) and
the post-paper regimes the PR-1 engine headroom opened, as declarative
registry entries.

Importing this module populates the registry.  Entries are plain data — a
new experiment regime is one ``register(Scenario(...))`` call (see the
``zipf``/``openloop``/``conflict`` families at the bottom for the pattern).
Row formatting / paper-claim summaries live in ``report.py``; execution in
``runner.py``.
"""
from __future__ import annotations

import math

from repro.core import PigConfig, WorkloadConfig
from repro.faults import (add_node, crash_window, remove_node,
                          replace_leader, rolling_restart, slow_window,
                          storm)

from .registry import register
from .scenario import Scenario

# --------------------------------------------------------------- tables 1/2
# Analytical message-load tables, each validated against DES-measured
# per-node message counts at representative R (the asserts live in report.py).
# batch_ok: the batch backend reproduces the same per-node loads, so the
# Eq. 1-3 cross-check runs on either backend (--backend batch).
for r in (1, 3):
    register(Scenario(
        name=f"table1/validate/R={r}", protocol="pigpaxos", n=25,
        pig=PigConfig(n_groups=r), clients=(20,), seeds=(7,),
        duration=1.0, warmup=0.2, quick_duration=0.4,
        batch_ok=True, collect=("per_node_msgs",)))

for r in (1, 2):
    register(Scenario(
        name=f"table2/validate/R={r}", protocol="pigpaxos", n=5,
        pig=PigConfig(n_groups=r), clients=(20,), seeds=(7,),
        duration=1.0, warmup=0.2, quick_duration=0.4,
        batch_ok=True, collect=("per_node_msgs",)))

# ------------------------------------------------------------------- fig 8
# Max throughput vs number of relay groups, rotating vs static, 25 nodes.
for rotate in (True, False):
    for r in (1, 2, 3, 4, 5, 6, 8):
        register(Scenario(
            name=f"fig8/{'rotating' if rotate else 'static'}/R={r}",
            protocol="pigpaxos", n=25,
            pig=PigConfig(n_groups=r, prc=1, rotate_relays=rotate,
                          single_group_majority=(r == 1 and rotate)),
            clients=(20, 60, 120), quick_clients=(40, 120),
            duration=1.0, quick_duration=0.4, warmup=0.25,
            batch_ok=True, quick_skip=(r in (4, 6, 8))))

# Beyond the paper: the same relay-group sweep at N in {25, 49, 101} on the
# flattened fast engine (the paper's testbed stopped at 25 nodes).
for n in (25, 49, 101):
    for r in sorted({3, int(round(math.sqrt(n)))}):
        register(Scenario(
            name=f"fig8/scale/N={n}/R={r}", protocol="pigpaxos", n=n,
            pig=PigConfig(n_groups=r, prc=1), engine="fast",
            clients=(60, 120), quick_clients=(60,),
            duration=0.6, quick_duration=0.3, warmup=0.25,
            batch_ok=True))

# ------------------------------------------------------------------- fig 9
# Latency vs throughput curves, 25 nodes, Paxos vs EPaxos vs PigPaxos(R=3).
for proto, pig in (("paxos", None), ("epaxos", None),
                   ("pigpaxos", PigConfig(n_groups=3, prc=1))):
    register(Scenario(
        name=f"fig9/{proto}", protocol=proto, n=25, pig=pig,
        grid_mode="curve",
        clients=(5, 10, 20, 40, 80, 120), quick_clients=(10, 40, 120),
        duration=1.0, quick_duration=0.4))

# ------------------------------------------------------------------ fig 10
# 15-node WAN (Virginia/California/Oregon), per-region relay groups.
_WAN3 = {"kind": "wan", "nodes_per_region": [5, 5, 5],
         "oneway_ms": [[0.15, 31, 35], [31, 0.15, 11], [35, 11, 0.15]]}
_WAN3_GROUPS = [[1, 2, 3, 4], [5, 6, 7, 8, 9], [10, 11, 12, 13, 14]]
for proto, pig in (("paxos", None),
                   ("pigpaxos", PigConfig(n_groups=3, groups=_WAN3_GROUPS, prc=1))):
    register(Scenario(
        name=f"fig10/{proto}", protocol=proto, n=15, pig=pig, topo=_WAN3,
        grid_mode="curve", leader_timeout=400e-3,
        clients=(10, 40, 120, 200), quick_clients=(20, 120),
        duration=2.0, quick_duration=0.8))

# ------------------------------------------------------------------ fig 11
# 5-node cluster: PigPaxos R=1 (single-relay majority) and R=2 vs baselines.
for label, proto, pig in (
        ("paxos", "paxos", None),
        ("epaxos", "epaxos", None),
        ("pig_R1", "pigpaxos", PigConfig(n_groups=1, single_group_majority=True)),
        ("pig_R2", "pigpaxos", PigConfig(n_groups=2))):
    register(Scenario(
        name=f"fig11/{label}", protocol=proto, n=5, pig=pig,
        clients=(20, 60, 120), quick_clients=(40, 120),
        duration=1.0, quick_duration=0.4, warmup=0.25))

# ------------------------------------------------------------------ fig 12
for label, proto, pig in (
        ("paxos", "paxos", None),
        ("pig_R2", "pigpaxos", PigConfig(n_groups=2, prc=1)),
        ("pig_R3", "pigpaxos", PigConfig(n_groups=3, prc=1))):
    register(Scenario(
        name=f"fig12/{label}", protocol=proto, n=9, pig=pig,
        clients=(20, 60, 120), quick_clients=(40, 120),
        duration=1.0, quick_duration=0.4, warmup=0.25))

# ------------------------------------------------------------------ fig 13
# Max throughput vs payload size, write-only workload.
for proto, pig in (("paxos", None), ("pigpaxos", PigConfig(n_groups=3, prc=1))):
    for size in (8, 64, 256, 512, 1024, 1280):
        register(Scenario(
            name=f"fig13/{proto}/payload={size}", protocol=proto, n=25, pig=pig,
            workload=WorkloadConfig(payload_bytes=size, write_fraction=1.0),
            clients=(60, 150), quick_clients=(120,),
            duration=1.0, quick_duration=0.4, warmup=0.25,
            quick_skip=(size not in (8, 256, 1280))))

# ------------------------------------------------------------------ fig 14
# Steady-state latency vs partial-response-collection level, fixed load.
# The paper's failure-section reproductions (figs 14-16) run with the
# linearizability auditor on (ISSUE 5): they are *checked* fault scenarios,
# not just latency plots.
for r in (1, 3):
    for prc in (0, 1, 2):
        register(Scenario(
            name=f"fig14/R={r}/PRC={prc}", protocol="pigpaxos", n=25,
            pig=PigConfig(n_groups=r, prc=prc, single_group_majority=False),
            audit=True, grid_mode="curve", clients=(18,),
            duration=2.0, quick_duration=0.6))

# ------------------------------------------------------------------ fig 15
# PRC x gray-list latency under one node failure; §4.2 group shape where
# the faulty group is required for majority.  The node-7 failure is a
# FaultPlan (open-ended crash window — the paper's node never returns).
_F15_GROUPS = [list(range(1, 14)), list(range(14, 25))]
for prc in (0, 1):
    for gray in (False, True):
        register(Scenario(
            name=f"fig15/PRC={prc}/gray={int(gray)}", protocol="pigpaxos",
            n=25,
            pig=PigConfig(n_groups=2, groups=_F15_GROUPS, prc=prc,
                          use_gray_list=gray),
            faults=crash_window(7, 0.1), audit=True,
            grid_mode="curve", clients=(30,), seeds=(5,),
            duration=2.0, quick_duration=0.8))
register(Scenario(
    name="fig15/fault_free", protocol="pigpaxos", n=25,
    pig=PigConfig(n_groups=2, groups=_F15_GROUPS), audit=True,
    grid_mode="curve", clients=(30,), seeds=(5,),
    duration=2.0, quick_duration=0.8))

# ------------------------------------------------------------------ fig 16
# Throughput timeline with one of 3 relay groups partially crashed mid-run.
register(Scenario(
    name="fig16/group_failure", protocol="pigpaxos", n=25,
    pig=PigConfig(n_groups=3, relay_timeout=50e-3),
    faults=(crash_window(3, 0.8) + crash_window(6, 0.8)
            + crash_window(9, 0.8)),
    audit=True, grid_mode="curve", clients=(60,), seeds=(9,),
    duration=3.0, quick_duration=1.2, warmup=0.3,
    collect=("timeline",)))

# ------------------------------------------------------------------ fig 17
# In-flight message heatmap, 9-node Paxos vs PigPaxos(R=3).
for proto, pig in (("paxos", None), ("pigpaxos", PigConfig(n_groups=3))):
    register(Scenario(
        name=f"fig17/{proto}", protocol=proto, n=9, pig=pig,
        grid_mode="curve", clients=(15,),
        duration=1.5, quick_duration=0.5,
        collect=("flight",)))

# ======================================================================
# Post-paper regimes (data-only entries over the generalized workload layer)
# ======================================================================

# Zipf-skewed PigPaxos: YCSB-style key popularity skew at N=25, R=3.  The
# paper only evaluates uniform keys; skew stresses nothing in Pig's relay
# layer (keys never route), so throughput should be flat across theta —
# a falsifiable no-op check the summarizer reports.  batch_ok because keys
# are performance-neutral in (Pig)Paxos — but note the batch backend makes
# the flatness exact by construction (it never samples keys), so the
# *falsifiable* version of this check is the DES run.
for theta in (0.6, 0.9, 0.99, 1.2):
    register(Scenario(
        name=f"zipf/pigpaxos/theta={theta}", protocol="pigpaxos", n=25,
        pig=PigConfig(n_groups=3, prc=1),
        workload=WorkloadConfig(key_dist="zipfian", zipf_theta=theta),
        clients=(60,), seeds=(1, 2, 3),
        duration=0.8, quick_duration=0.3, batch_ok=True))
register(Scenario(
    name="zipf/pigpaxos/uniform", protocol="pigpaxos", n=25,
    pig=PigConfig(n_groups=3, prc=1),
    workload=WorkloadConfig(key_dist="uniform"),
    clients=(60,), seeds=(1, 2, 3),
    duration=0.8, quick_duration=0.3, batch_ok=True))

# Open-loop Poisson fig9 variant: offered load fixed at clients x 100 req/s
# regardless of completion rate — latency blows up past saturation instead
# of the closed-loop self-throttling the paper's testbed had.
for proto, pig in (("paxos", None), ("epaxos", None),
                   ("pigpaxos", PigConfig(n_groups=3, prc=1))):
    register(Scenario(
        name=f"openloop/{proto}", protocol=proto, n=25, pig=pig,
        workload=WorkloadConfig(arrival="poisson", rate_hz=100.0),
        grid_mode="curve",
        clients=(10, 40, 80, 160), quick_clients=(10, 40),
        seeds=(2, 3), quick_seeds=(2,),
        duration=1.0, quick_duration=0.4))

# EPaxos conflict-rate sweeps at scale: hot-key probability c drives the
# dependency/interference rate; N=49 rides the fast engine (a regime the
# paper's 25-node testbed could not reach).  Each (N, c) point also runs on
# the batch backend (the vectorsim conflict/slow-path model, ISSUE 5): the
# whole grid is one jitted call, and the conflict summarizer emits a
# DES<->batch xcheck ratio per point that the regression gate bounds to
# [0.90, 1.10].
for n, engine in ((25, "exact"), (49, "fast")):
    for c in (0.0, 0.02, 0.1, 0.5):
        register(Scenario(
            name=f"conflict/N={n}/c={c}", protocol="epaxos", n=n,
            engine=engine, batch_ok=True,
            workload=WorkloadConfig(key_dist="conflict", conflict_rate=c),
            clients=(40,), seeds=(1, 2, 3), quick_seeds=(1, 2),
            duration=0.8, quick_duration=0.3))
        register(Scenario(
            name=f"conflict/N={n}/c={c}/batch", protocol="epaxos", n=n,
            backend="batch", batch_ok=True,
            workload=WorkloadConfig(key_dist="conflict", conflict_rate=c),
            clients=(40,), seeds=tuple(range(1, 9)), quick_seeds=(1, 2, 3),
            duration=0.8, quick_duration=0.3))

# WAN sweeps at N in {25, 49, 101} (ROADMAP open item from PR 1): the fig10
# three-region topology scaled up, per-region relay groups (paper §5.3).
# Each size runs twice — on the fast DES engine and on the batch backend —
# so the wan summarizer doubles as a DES<->batch cross-check at WAN scale.


def _wan_scaled(n: int):
    """N nodes over 3 regions (fig10 latencies), per-region groups."""
    per = [n - 2 * (n // 3), n // 3, n // 3]
    spec = {"kind": "wan", "nodes_per_region": per,
            "oneway_ms": _WAN3["oneway_ms"]}
    bounds = [0, per[0], per[0] + per[1], n]
    groups = [list(range(bounds[i], bounds[i + 1])) for i in range(3)]
    return spec, groups


for n in (25, 49, 101):
    spec, groups = _wan_scaled(n)
    for backend in ("des", "batch"):
        register(Scenario(
            name=f"wan/N={n}" + ("/batch" if backend == "batch" else ""),
            protocol="pigpaxos", n=n,
            pig=PigConfig(n_groups=3, groups=groups, prc=1),
            topo=spec, engine="fast", backend=backend, batch_ok=True,
            leader_timeout=400e-3,
            clients=(40, 120), quick_clients=(40,),
            seeds=(2, 3) if backend == "des" else tuple(range(16)),
            quick_seeds=(2,) if backend == "des" else (0, 1, 2, 3),
            duration=2.0, quick_duration=0.8, warmup=0.5,
            quick_skip=(n == 101 and backend == "des")))

# ======================================================================
# Batch-backend headroom: grids the DES cannot touch (one jitted call per
# scenario; N up to 1025 and hundreds of seed replicates per point).
# ======================================================================
for n, r, nseeds, qseeds in ((257, 16, 128, 8), (1025, 32, 24, 4)):
    register(Scenario(
        name=f"scale/batch/N={n}/R={r}", protocol="pigpaxos", n=n,
        pig=PigConfig(n_groups=r, prc=1), backend="batch", batch_ok=True,
        clients=(60, 120), quick_clients=(60,),
        seeds=tuple(range(nseeds)), quick_seeds=tuple(range(qseeds)),
        duration=0.5, quick_duration=0.25, warmup=0.25,
        quick_skip=(n == 1025)))
# the paper-grade relay-group sweep with hundreds of replicates per R:
# 7 R values x 3 client counts x 64 seeds = 1344 cells, one compiled call
# per scenario (~seconds each on the batch backend)
for r in (1, 2, 3, 5, 8, 12, 24):
    register(Scenario(
        name=f"scale/batch/replicates/R={r}", protocol="pigpaxos", n=25,
        pig=PigConfig(n_groups=r, prc=1,
                      single_group_majority=(r == 1)),
        backend="batch", batch_ok=True,
        clients=(20, 60, 120), quick_clients=(60,),
        seeds=tuple(range(64)), quick_seeds=tuple(range(8)),
        duration=0.5, quick_duration=0.25, warmup=0.25))

# ======================================================================
# Fault-injection families (repro.faults): declarative fault plans with
# the linearizability auditor on, extending the paper's failure section
# (figs 14-16) to full crash-RECOVER cycles and randomized storms.
# ======================================================================

# avail: availability under a leader (or relay) crash-recover window.
# Clients run with a request timeout so ops lost to the down node are
# re-sent (the replicas' at-most-once session dedup absorbs duplicates);
# the summarizer reports the unavailability window and throughput-dip
# depth from the completion timeline.  The N=25 variants also run on the
# batch backend (the plan is mask-expressible), giving a DES<->batch
# dip-depth cross-check the wan family's throughput xcheck can't see.
_AVAIL_WL = WorkloadConfig(request_timeout=25e-3)
_AVAIL_PLANS = {
    # node 0 is the (only) leader; recovery re-elects with a fresh ballot
    "leader": crash_window(0, 0.8, 1.2),
    # node 1 relays ~1/R of its group's rounds; node 2 is gray throughout
    # (the fig15 regime, but with recovery and the §4.2 gray list active);
    # the open-ended slow window (t1=inf) is the horizon-proof spelling of
    # "throughout" and stays mask-expressible under any duration change
    "relay": crash_window(1, 0.8, 1.2) + slow_window(2, extra_latency=2e-3),
}
for n in (25, 49):
    for role, plan in _AVAIL_PLANS.items():
        register(Scenario(
            name=f"avail/{role}/N={n}", protocol="pigpaxos", n=n,
            pig=PigConfig(n_groups=3, prc=1, use_gray_list=True),
            workload=_AVAIL_WL, faults=plan, audit=True,
            engine="exact" if n == 25 else "fast",
            grid_mode="curve", clients=(30,), seeds=(3,),
            duration=2.2, warmup=0.3, quick_duration=1.2,
            collect=("timeline",), batch_ok=True,
            quick_skip=(n == 49)))
for role, plan in _AVAIL_PLANS.items():
    register(Scenario(
        name=f"avail/{role}/N=25/batch", protocol="pigpaxos", n=25,
        pig=PigConfig(n_groups=3, prc=1, use_gray_list=True),
        workload=_AVAIL_WL, faults=plan, backend="batch", batch_ok=True,
        grid_mode="curve", clients=(30,), seeds=(3, 4, 5, 6),
        quick_seeds=(3, 4),
        duration=2.2, warmup=0.3, quick_duration=1.2,
        collect=("timeline",)))

# avail/epaxos: coordinator crash-recover with explicit-prepare instance
# recovery (ISSUE 5).  Node 2 is an opportunistic command leader for ~1/N
# of the offered load; while it is down its in-flight instances wedge their
# keys until peers run the explicit-prepare phase (probe timers fire two
# leader-timeouts after an execution stays blocked), so the dip heals and
# the audit stays green with NO hung clients — the pre-recovery protocol
# left those keys wedged forever.  DES-only: EPaxos faults have no batch
# mask lowering (the conflict model is fault-free).
for n in (25, 49):
    register(Scenario(
        name=f"avail/epaxos/N={n}", protocol="epaxos", n=n,
        workload=_AVAIL_WL, faults=crash_window(2, 0.8, 1.2), audit=True,
        engine="exact" if n == 25 else "fast",
        grid_mode="curve", clients=(30,), seeds=(3,),
        duration=2.2, warmup=0.3, quick_duration=1.2,
        collect=("timeline",), quick_skip=(n == 49)))

# storm: randomized crash-recover storms (seeded Poisson arrivals over the
# followers, Exp downtimes, concurrency-capped so a quorum can never be
# down at once), audit always on, at N the paper's testbed could not reach.
_STORM_WL = WorkloadConfig(request_timeout=25e-3)


def _storm_plan(n: int, seed: int, rate: float = 6.0):
    return storm(targets=tuple(range(1, n)), rate_hz=rate, t0=0.35, t1=1.3,
                 mean_downtime=0.15, seed=seed, max_concurrent=2)


for n in (25, 49, 101):
    register(Scenario(
        name=f"storm/pigpaxos/N={n}", protocol="pigpaxos", n=n,
        pig=PigConfig(n_groups=3 if n == 25 else int(round(math.sqrt(n))),
                      prc=1, use_gray_list=True),
        workload=_STORM_WL, faults=_storm_plan(n, seed=11), audit=True,
        engine="fast", clients=(30,), seeds=(1, 2), quick_seeds=(1,),
        duration=1.5, warmup=0.3, quick_duration=1.2,
        collect=("timeline",), quick_skip=(n == 49)))
register(Scenario(
    name="storm/paxos/N=25", protocol="paxos", n=25,
    workload=_STORM_WL, faults=_storm_plan(25, seed=13), audit=True,
    engine="fast", clients=(30,), seeds=(1, 2), quick_seeds=(1,),
    duration=1.5, warmup=0.3, quick_duration=1.2, collect=("timeline",)))
# EPaxos storms.  The original gentle variant (rate 2, one node at a time)
# predates instance recovery and is kept for trajectory continuity; the
# epaxos-recovery variant runs the SAME storm intensity as the pigpaxos
# one (rate 6, two concurrent crashes) — survivable only because crashed
# coordinators' in-flight instances now heal via explicit prepare.
register(Scenario(
    name="storm/epaxos/N=25", protocol="epaxos", n=25,
    workload=_STORM_WL,
    faults=storm(targets=tuple(range(25)), rate_hz=2.0, t0=0.35, t1=1.3,
                 mean_downtime=0.1, seed=17, max_concurrent=1),
    audit=True, engine="fast", clients=(30,), seeds=(1, 2), quick_seeds=(1,),
    duration=1.5, warmup=0.3, quick_duration=1.2, collect=("timeline",)))
register(Scenario(
    name="storm/epaxos-recovery/N=25", protocol="epaxos", n=25,
    workload=_STORM_WL,
    faults=storm(targets=tuple(range(25)), rate_hz=6.0, t0=0.35, t1=1.3,
                 mean_downtime=0.15, seed=19, max_concurrent=2),
    audit=True, engine="fast", clients=(30,), seeds=(1, 2), quick_seeds=(1,),
    duration=1.5, warmup=0.3, quick_duration=1.2, collect=("timeline",)))

# avail/prc: availability as a function of partial response collection
# (satellite of PR 6): the SAME relay crash + gray-relay plan swept over
# PRC in {0, 1, 2} — §4.1 predicts PRC>=1 masks the crashed relay's group
# entirely (the leader proceeds on R-1 groups + partial responses) while
# PRC=0 waits out every relay timeout, so the unavailability window and
# dip depth should fall monotonically with PRC.
for prc in (0, 1, 2):
    register(Scenario(
        name=f"avail/prc/N=25/PRC={prc}", protocol="pigpaxos", n=25,
        pig=PigConfig(n_groups=3, prc=prc, use_gray_list=True),
        workload=_AVAIL_WL, faults=_AVAIL_PLANS["relay"], audit=True,
        engine="exact", grid_mode="curve", clients=(30,), seeds=(3,),
        duration=2.2, warmup=0.3, quick_duration=1.2,
        collect=("timeline",), quick_skip=(prc == 2)))

# ======================================================================
# Membership-change families (PR 6): reconfiguration, rolling upgrades,
# and failover policies — all under the linearizability auditor, with the
# replica set treated as time-varying (audit durability = final members).
# ======================================================================

# reconfig: single-server membership changes under closed-loop load.
#   add     — a spare node (id N) joins from a leader snapshot + log
#             suffix, then an add_node command commits through the log;
#   remove  — follower N-1 is removed (quorums shrink mid-run);
#   replace — the LEADER is removed (leadership moves to the next member)
#             and a spare joins: a full node replacement;
#   handoff — planned leader handoff via a higher-ballot phase-1 (the
#             no-crash baseline for the failover family's windows).
_RC_WL = WorkloadConfig(request_timeout=25e-3)
_RC_PLANS = {
    "add": lambda n: (add_node(n, 0.8), 1),
    "remove": lambda n: (remove_node(n - 1, 0.8), 0),
    "replace": lambda n: (remove_node(0, 0.7) + add_node(n, 1.1), 1),
    "handoff": lambda n: (replace_leader(3, 0.8), 0),
}
for n in (25, 49):
    for kind, mk in _RC_PLANS.items():
        plan, spares = mk(n)
        register(Scenario(
            name=f"reconfig/{kind}/N={n}", protocol="pigpaxos", n=n,
            pig=PigConfig(n_groups=3, prc=1, use_gray_list=True),
            workload=_RC_WL, faults=plan, audit=True, spare_nodes=spares,
            engine="exact" if n == 25 else "fast",
            grid_mode="curve", clients=(30,), seeds=(3,),
            duration=2.2, warmup=0.3, quick_duration=1.2,
            collect=("timeline",),
            quick_skip=(n == 49 or kind == "handoff")))
# EPaxos membership change (leaderless): add a spare + remove a peer.
register(Scenario(
    name="reconfig/epaxos/N=25", protocol="epaxos", n=25,
    workload=_RC_WL, faults=add_node(25, 0.8) + remove_node(3, 1.3),
    audit=True, spare_nodes=1, engine="exact",
    grid_mode="curve", clients=(30,), seeds=(3,),
    duration=2.2, warmup=0.3, quick_duration=1.2,
    collect=("timeline",), quick_skip=True))

# rolling: restart every node in sequence (the rolling-upgrade model) with
# the auditor on.  At most one node is ever down (gap > downtime); the
# leader's own restart is the deep dip, follower restarts should barely
# register.  The per-restart unavailability windows land in the artifact
# (extras.per_fault_unavail_ms), one entry per node.
for proto, quick_skip in (("pigpaxos", False), ("epaxos", True)):
    register(Scenario(
        name=f"rolling/{proto}/N=25", protocol=proto, n=25,
        pig=PigConfig(n_groups=3, prc=1, use_gray_list=True)
        if proto == "pigpaxos" else None,
        workload=_RC_WL,
        faults=rolling_restart(tuple(range(25)), t0=0.45,
                               downtime=0.06, gap=0.14),
        audit=True, engine="fast", grid_mode="curve",
        clients=(30,), seeds=(3,),
        duration=4.0, warmup=0.3, quick_duration=4.0,
        collect=("timeline",), quick_skip=quick_skip))

# failover: the leader crashes at t=0.8 and NEVER recovers; recovery is
# entirely up to the external failover policy (runtime.FailoverPolicy),
# swept over its detection budget.  The measured unavailability window
# decomposes as crash->detect (the swept knob) + election + client retry,
# so unavail_ms should track detect_timeout nearly 1:1.
for detect_ms in (50, 100, 200):
    register(Scenario(
        name=f"failover/detect={detect_ms}ms", protocol="pigpaxos", n=25,
        pig=PigConfig(n_groups=3, prc=1),
        workload=_RC_WL, faults=crash_window(0, 0.8), audit=True,
        failover={"detect_timeout": detect_ms * 1e-3,
                  "check_interval": 0.01, "successor": "next"},
        engine="exact", grid_mode="curve", clients=(30,), seeds=(3,),
        duration=2.2, warmup=0.3, quick_duration=1.2,
        collect=("timeline",), quick_skip=(detect_ms == 200)))

# ======================================================================
# Leader-side batching + slot pipelining (ISSUE 8): closed-loop saturation
# sweeps with the leader packing up to m commands per slot — one phase-2
# fan-out/fan-in (and one Pig relay round) amortized over the batch.  The
# m=1 cells ARE the unbatched baselines (max_batch=1 flushes on first
# enqueue and proposes the bare command — byte-identical to the native
# path); the regression gate requires the m=8 paxos/N=25 cell to reach
# >= 2x its m=1 baseline.  For paxos/pigpaxos each m also runs on the
# batch backend (vectorsim's saturated-batch cost reparameterization) and
# the summarizer emits batch/des fidelity ratios the gate bounds to
# [0.90, 1.10]; batched EPaxos is DES-authoritative (leaderless batching
# has no group-kernel lowering).
# ======================================================================
for proto, pig in (("paxos", None),
                   ("pigpaxos", PigConfig(n_groups=3, prc=1)),
                   ("epaxos", None)):
    for m in (1, 4, 8):
        register(Scenario(
            name=f"batching/{proto}/m={m}", protocol=proto, n=25, pig=pig,
            engine="fast", batch={"max_batch": m, "max_delay_ms": 1.0},
            clients=(64,), seeds=(1, 2), quick_seeds=(1,),
            duration=0.6, warmup=0.3, quick_duration=0.3,
            quick_skip=(m == 4 and proto != "paxos")))
        if proto != "epaxos":
            register(Scenario(
                name=f"batching/{proto}/m={m}/batch", protocol=proto, n=25,
                pig=pig, backend="batch", batch_ok=True,
                batch={"max_batch": m, "max_delay_ms": 1.0},
                clients=(64,), seeds=tuple(range(1, 9)), quick_seeds=(1, 2),
                duration=0.6, warmup=0.3, quick_duration=0.3,
                quick_skip=(m == 4 and proto != "paxos")))
# Slot pipelining: finite in-flight budgets (depth = max uncommitted
# proposals at the leader) under the same saturated load.  depth=0 is the
# protocol-native unbounded default (every other cell above); small finite
# depths trade throughput for bounded leader state — DES only (the batch
# backend's Lindley-chain leader FIFO pipelines implicitly).
for depth in (1, 2, 4):
    register(Scenario(
        name=f"batching/pipeline/depth={depth}", protocol="paxos", n=25,
        engine="fast", batch={"max_batch": 4, "max_delay_ms": 1.0},
        pipeline_depth=depth,
        clients=(64,), seeds=(1,),
        duration=0.6, warmup=0.3, quick_duration=0.3,
        quick_skip=(depth != 2)))

# ======================================================================
# Overload + admission control (ISSUE 8): open-loop arrivals pushed past
# saturation.  Unbatched paxos/N=25 saturates near ~2k req/s on this
# stack, so the clients grid at rate 100 Hz/client sweeps offered load
# from ~0.5x to ~4x saturation.  collect=("overload",) adds p99.9,
# goodput under the 50 ms SLO (runner.OVERLOAD_SLO_MS), the offered rate
# and every shed counter to each unit.  The paired noadm/adm cells are
# the family's headline claim (and a regression-gate section): WITHOUT
# admission control goodput collapses toward zero past saturation (every
# completion blows the SLO in the unbounded queue); WITH queue-length
# backpressure + token-bucket shedding goodput stays flat (+-10%) from
# 2x to 4x offered load.
# ======================================================================
_OVL_WL = dict(arrival="poisson", rate_hz=100.0, max_outstanding=32,
               reject_action="drop")
# token bucket at ~0.9x the unbatched saturation rate (the classic
# headroom rule: admit below capacity so the queue never builds), plus a
# queue-length guard for transients the bucket's burst lets through
_OVL_ADM = {"max_queue": 32, "rate_hz": 1800.0, "burst": 64.0}
for label, adm in (("noadm", None), ("adm", _OVL_ADM)):
    register(Scenario(
        name=f"overload/paxos/{label}", protocol="paxos", n=25,
        engine="fast", workload=WorkloadConfig(**_OVL_WL),
        admission=adm, grid_mode="curve", collect=("overload",),
        clients=(10, 20, 40, 80), quick_clients=(20, 80),
        seeds=(2,), duration=0.6, warmup=0.2, quick_duration=0.4))
# batching raises the saturation point: the same 4x offered load that
# floors the unbatched leader is absorbed outright with m=8 slots
register(Scenario(
    name="overload/paxos/adm+batch", protocol="paxos", n=25,
    engine="fast", workload=WorkloadConfig(**_OVL_WL),
    admission=_OVL_ADM, batch={"max_batch": 8, "max_delay_ms": 0.2},
    grid_mode="curve", collect=("overload",),
    clients=(20, 80), seeds=(2,),
    duration=0.6, warmup=0.2, quick_duration=0.4))
# bursty/diurnal traces: mean offered ~2x saturation with the bursty ON
# phase running 8x of that for 10% of each period (transient overload the
# token bucket's burst absorbs or sheds), and a diurnal peak at ~1.8x
for label, adm in (("bursty", None), ("bursty/adm", _OVL_ADM)):
    register(Scenario(
        name=f"overload/paxos/{label}", protocol="paxos", n=25,
        engine="fast",
        workload=WorkloadConfig(arrival="bursty", rate_hz=100.0,
                                max_outstanding=32, reject_action="drop",
                                burst_factor=8.0, burst_on=0.1,
                                burst_period=0.2),
        admission=adm, grid_mode="curve", collect=("overload",),
        clients=(40,), seeds=(2,),
        duration=0.6, warmup=0.2, quick_duration=0.4))
register(Scenario(
    name="overload/paxos/diurnal/adm", protocol="paxos", n=25,
    engine="fast",
    workload=WorkloadConfig(arrival="diurnal", rate_hz=100.0,
                            max_outstanding=32, reject_action="drop",
                            diurnal_period=0.4, diurnal_amp=0.8),
    admission=_OVL_ADM, grid_mode="curve", collect=("overload",),
    clients=(40,), seeds=(2,),
    duration=0.6, warmup=0.2, quick_duration=0.4, quick_skip=True))
# latency-driven admission (ISSUE 9): shed on the observed p99 EWMA
# against the same 50 ms SLO the goodput metric uses, head-to-head with
# the queue-length policy above — the latadm_summary row compares
# goodput and shed volume at 4x offered load
register(Scenario(
    name="overload/paxos/latadm", protocol="paxos", n=25,
    engine="fast", workload=WorkloadConfig(**_OVL_WL),
    admission={"slo_ms": 50.0, "check_interval": 0.005},
    grid_mode="curve", collect=("overload",),
    clients=(10, 20, 40, 80), quick_clients=(20, 80),
    seeds=(2,), duration=0.6, warmup=0.2, quick_duration=0.4))
# the family generalizes past plain paxos: Pig relays under overload
register(Scenario(
    name="overload/pigpaxos/adm", protocol="pigpaxos", n=25,
    pig=PigConfig(n_groups=3, prc=1), engine="fast",
    workload=WorkloadConfig(**_OVL_WL),
    admission=_OVL_ADM, grid_mode="curve", collect=("overload",),
    clients=(20, 80), seeds=(2,),
    duration=0.6, warmup=0.2, quick_duration=0.4, quick_skip=True))
# audited overload smoke (the CI PR-job cells): one admission cell and one
# batched+admission cell with the linearizability auditor on — shedding,
# bounce-retry loops and batch slots must not cost consistency
register(Scenario(
    name="overload/audit/adm", protocol="paxos", n=25,
    engine="fast", workload=WorkloadConfig(**_OVL_WL),
    admission=_OVL_ADM, audit=True, grid_mode="curve",
    collect=("overload",), clients=(40,), seeds=(2,),
    duration=0.5, warmup=0.2, quick_duration=0.4))
register(Scenario(
    name="overload/audit/adm+batch", protocol="paxos", n=25,
    engine="fast", workload=WorkloadConfig(**_OVL_WL),
    admission=_OVL_ADM, batch={"max_batch": 8, "max_delay_ms": 0.2},
    audit=True, grid_mode="curve",
    collect=("overload",), clients=(40,), seeds=(2,),
    duration=0.5, warmup=0.2, quick_duration=0.4))

# ======================================================================
# Observability (ISSUE 9): traced cells for all three protocols (per-op
# span trees -> critical-path decomposition in the artifact's obs extras),
# the relay-fairness pair (rotating vs static relays, fig8-style, with the
# per-follower busy-seconds the fairness summarizer turns into max/mean +
# Gini — the paper's 'rotation spreads relay load' claim as a number), and
# a batch-backend cell carrying the leader-backlog timeline.
# ======================================================================
_OBS_FULL = {"sample_rate": 0.1, "metrics_dt": 0.01, "perfetto_limit": 2000}
for proto, pig, qskip in (
        ("pigpaxos", PigConfig(n_groups=5, prc=1), False),
        ("paxos", None, False),
        ("epaxos", None, True)):
    register(Scenario(
        name=f"obs/{proto}/traced", protocol=proto, n=25, pig=pig,
        obs=_OBS_FULL, clients=(40,), seeds=(2,),
        duration=0.6, warmup=0.25, quick_duration=0.3,
        quick_skip=qskip))
# fairness pair: same seed/load/groups, only relay rotation differs; the
# fast engine's busy accounting is enough (no span tracing needed), so
# sample_rate=0 keeps the cells cheap while metrics_dt still samples the
# utilization timelines the heat-strip plot renders
for rotate in (True, False):
    register(Scenario(
        name=f"obs/fairness/{'rotating' if rotate else 'static'}",
        protocol="pigpaxos", n=25,
        pig=PigConfig(n_groups=5, prc=1, rotate_relays=rotate),
        engine="fast", obs={"sample_rate": 0.0, "metrics_dt": 0.01},
        clients=(40,), seeds=(7,),
        duration=0.6, warmup=0.25, quick_duration=0.3))
register(Scenario(
    name="obs/pigpaxos/backlog/batch", protocol="pigpaxos", n=25,
    pig=PigConfig(n_groups=5, prc=1), backend="batch", batch_ok=True,
    obs={"sample_rate": 0.0}, clients=(40,), seeds=(1, 2, 3, 4),
    quick_seeds=(1, 2), duration=0.6, warmup=0.25, quick_duration=0.3))

# ======================================================================
# megagrid slices: registry-visible samples of the million-cell
# cross-product study (experiments.megagrid).  The full run streams
# through vectorsim.simulate_grid_sharded from the CLI; these four
# points keep the family in the registry (summarizer, nightly gate) and
# cross-check the study's axes against the standard runner path.
# ======================================================================
for n, r, prc, wan in ((9, 2, 1, False), (9, 2, 1, True),
                       (25, 4, 0, False), (25, 4, 2, True)):
    spec = _wan_scaled(n)[0] if wan else None
    register(Scenario(
        name=f"megagrid/slice/N={n}/R={r}/PRC={prc}/"
             + ("wan3" if wan else "lan"),
        protocol="pigpaxos", n=n, pig=PigConfig(n_groups=r, prc=prc),
        topo=spec, backend="batch", batch_ok=True,
        leader_timeout=400e-3 if wan else 50e-3,
        clients=(4, 16), quick_clients=(4,),
        seeds=tuple(range(16)), quick_seeds=(0, 1, 2, 3),
        duration=0.1, quick_duration=0.1, warmup=0.05,
        quick_skip=(n == 25 and prc == 2)))

# ======================================================================
# Read paths (ISSUE 10): leader leases + quorum reads under read-heavy
# closed-loop traffic, every DES cell under the read-aware auditor
# (stale / phantom / inverted non-logged reads are hard violations).
#
#   reads/*/lease/r=R   — quorum-granted leader lease, leader serves gets
#                         locally (no log round); r sweeps the crossover:
#                         at r=0 Pig's relay fan-out beats Paxos on write
#                         throughput, at r=0.9 the lease path collapses
#                         both protocols onto the leader and plain Paxos
#                         catches back up — the crossover summarizer row.
#   reads/*/log/r=0.9   — the same read mix through the replicated log
#                         (the paper's only read path): the speedup
#                         denominator for the >= 2x leased-read gate.
#   reads/*/quorum, /subgroup — client-side quorum reads (PQR-style
#                         probe + rinse): a random majority on paxos /
#                         epaxos, the geo-closest relay subgroup + leader
#                         on pigpaxos ("subgroup").
#   reads/wan/*         — the fig10 three-region WAN: geo-routed subgroup
#                         probes answer from the client's region while
#                         random-majority probes pay cross-region RTTs.
# The paxos lease/log r=0.9 cells also run on the batch backend
# (vectorsim's leased-read Lindley model) — the reads summarizer emits
# DES<->batch fidelity ratios the regression gate bounds to [0.90, 1.10].
# ======================================================================
_LEASE = {"duration_ms": 200.0}
for proto, pig in (("paxos", None), ("pigpaxos", PigConfig(n_groups=3, prc=1))):
    for r in (0.0, 0.5, 0.9):
        register(Scenario(
            name=f"reads/{proto}/lease/r={r}", protocol=proto, n=25,
            pig=pig,
            workload=WorkloadConfig(read_ratio=r, read_path="lease"),
            lease=_LEASE, audit=True,
            clients=(60,), seeds=(1, 2), quick_seeds=(1,),
            duration=0.6, warmup=0.3, quick_duration=0.3))
    register(Scenario(
        name=f"reads/{proto}/log/r=0.9", protocol=proto, n=25, pig=pig,
        workload=WorkloadConfig(read_ratio=0.9, read_path="log"),
        audit=True, clients=(60,), seeds=(1, 2), quick_seeds=(1,),
        duration=0.6, warmup=0.3, quick_duration=0.3))
for path in ("lease", "log"):
    register(Scenario(
        name=f"reads/paxos/{path}/r=0.9/batch", protocol="paxos", n=25,
        backend="batch", batch_ok=True,
        workload=WorkloadConfig(read_ratio=0.9, read_path=path),
        lease=_LEASE if path == "lease" else None,
        clients=(60,), seeds=tuple(range(1, 9)), quick_seeds=(1, 2),
        duration=0.6, warmup=0.3, quick_duration=0.3))
for proto, pig, label in (
        ("paxos", None, "quorum"),
        ("epaxos", None, "quorum"),
        ("pigpaxos", PigConfig(n_groups=3, prc=1), "subgroup")):
    register(Scenario(
        name=f"reads/{proto}/{label}/r=0.9", protocol=proto, n=25, pig=pig,
        workload=WorkloadConfig(read_ratio=0.9, read_path="quorum"),
        audit=True, clients=(60,), seeds=(1, 2), quick_seeds=(1,),
        duration=0.6, warmup=0.3, quick_duration=0.3))
for proto, pig in (
        ("pigpaxos", PigConfig(n_groups=3, groups=_WAN3_GROUPS, prc=1)),
        ("paxos", None)):
    register(Scenario(
        name=f"reads/wan/{proto}/quorum", protocol=proto, n=15, pig=pig,
        topo=_WAN3, leader_timeout=400e-3,
        workload=WorkloadConfig(read_ratio=0.9, read_path="quorum"),
        audit=True, grid_mode="curve", clients=(30,), seeds=(2,),
        duration=1.5, warmup=0.4, quick_duration=0.8,
        quick_skip=(proto == "paxos")))

# lease: expiry/failover availability windows.  The leader crashes at
# t=0.8 and never recovers; the failover policy elects a successor, but
# follower lease PROMISES block the new leader's phase 1 until the old
# lease drains — so the measured unavailability window must grow with the
# lease duration (the safety/availability trade every lease system makes).
# The auditor stays on: no read served across the failover may be stale.
_LEASE_FO = {"detect_timeout": 0.05, "check_interval": 0.01,
             "successor": "next"}
for d in (50, 400):
    register(Scenario(
        name=f"lease/expiry/d={d}ms", protocol="pigpaxos", n=25,
        pig=PigConfig(n_groups=3, prc=1),
        workload=WorkloadConfig(read_ratio=0.5, read_path="lease",
                                request_timeout=25e-3),
        lease={"duration_ms": float(d)},
        faults=crash_window(0, 0.8), audit=True, failover=_LEASE_FO,
        grid_mode="curve", clients=(30,), seeds=(3,),
        duration=2.2, warmup=0.3, quick_duration=1.2,
        collect=("timeline",)))
